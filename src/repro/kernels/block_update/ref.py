"""Pure-jnp oracle for the fused block-vector update kernel."""

from __future__ import annotations


def block_update_ref(x, r, p, ap, c):
    """X += P·c ; R -= AP·c   (ECG Alg 1 lines 7–8, one fused pass)."""
    return x + p @ c, r - ap @ c


def ecg_tail_ref(x, r, p, ap, p_old, c, d, d_old):
    """Full iteration tail: X += P·c ; R -= AP·c ; Z = AP − P·d − P_old·d_old."""
    return x + p @ c, r - ap @ c, ap - p @ d - p_old @ d_old
