"""Enlarged Conjugate Gradients (paper Algorithms 1–3).

Communication-efficient Grigori–Tissot form:

  per iteration —
    AZ   = A * Z                          SpMBV             (p2p comm)
    G    = ZᵀAZ                           block inner prod  (allreduce #1, t²)
    CᵀC  = chol(G)                        local Cholesky
    P    = Z C⁻¹ ;  AP = AZ C⁻¹           local TRSMs (AP reuses AZ — no 2nd SpMBV)
    c    = PᵀR ; d = APᵀAP ; d_old = AP_oldᵀAP
                                          fused block inner prods (allreduce #2, 3t²)
    X   += P c ;  R -= AP c
    Z    = AP − P d − P_old d_old

Exactly two allreduce-shaped collectives per iteration, matching §3.1.  The
``allreduce`` argument is identity for a single-shard run and a ``psum`` for
the shard_map-distributed run, so the same iteration body serves both — and
the fusion of the second reduction (c, d, d_old packed in one buffer) is
structural, not cosmetic.

The per-iteration maths above is the *classic* scheme — one of three
pluggable iteration schemes (:mod:`repro.core.methods`): ``pipelined``
overlaps the packed Gram reduction with the SpMBV exchange via an AZ
recurrence, and ``sstep`` amortizes both psums over s SpMBV sweeps with a
rank-revealing safeguard.  This module is the method-agnostic driver.

Two layers live here:

* :func:`make_ecg_runner` — builds the pure iteration machinery once (an
  :class:`ECGRunner` with ``init``/``step``/``run``), all jit-traceable.
  This is what :class:`repro.solver.ECGSolver` compiles exactly once per
  width and reuses across right-hand sides, and what the ``t="auto"``
  probes drive step-by-step for early stopping.
* :func:`ecg_solve` — the legacy one-shot functional spelling (resolve
  config, build a runner, run it, wrap a :class:`SolveResult`).  New code
  should build a :class:`repro.solver.ECGSolver` handle instead; the
  handle amortizes setup and compilation over many solves.

Backend switch: ``backend="jnp"`` (default) runs the iteration body on plain
XLA ops; ``backend="pallas"`` routes the two per-iteration hot spots that the
paper's performance model singles out through the Pallas kernel suite —
``kernels/fused_gram`` for the packed [PᵀR | APᵀAP | AP_oldᵀAP] product (one
HBM pass over P/R/AP/AP_old instead of three GEMM passes) and
``kernels/block_update.ecg_tail`` for the X/R/Z tail (one pass over P/AP
instead of two).  On non-TPU platforms the kernel ops dispatch to their
pure-jnp oracles, so the switch is always safe to flip; the SpMBV itself is
owned by the caller via ``a_apply``.

Adaptivity (:mod:`repro.adaptive`): a ``ReductionPolicy`` replaces the bare
Cholesky with a pivoted, rank-revealing factorization so a singular Gram
matrix drops the dependent directions (zero-masked columns, static shapes)
instead of poisoning the solve with NaNs; the flexible-ECG stagnation
criterion additionally retires stagnant directions, with an optional
plateau re-enlarge/restart.  Every solve is breakdown-guarded: a non-finite
iterate freezes the state at the last finite iteration and sets
``SolveResult.breakdown``.
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Callable

import jax
import jax.numpy as jnp

from repro.adaptive.reduce import resolve_policy
from repro.core.cg import SolveResult, _guarded_while
from repro.core.enlarging import split_residual
from repro.core.methods import MethodContext, get_method
from repro.core.methods.base import _apply_vec, _chol_inv_apply  # noqa: F401  (back-compat re-exports)
from repro.kernels.block_update.ops import ecg_tail
from repro.kernels.fused_gram.ops import fused_gram


@dataclasses.dataclass(frozen=True)
class ECGRunner:
    """The compiled-once iteration machinery of one ECG configuration.

    ``init(b, x0) -> carry`` builds the initial loop carry (initial residual
    SpMV, splitting, norm); ``step(carry) -> carry`` is one raw, unguarded
    iteration of Algorithm 3 (used by the ``t="auto"`` probes to drive the
    loop one iteration at a time); ``run(carry) -> carry`` is the
    breakdown-guarded ``lax.while_loop`` to convergence (or to a width-exit
    event).  All three are pure and jit-traceable — the solver handle wraps
    ``lambda b, x0: run(init(b, x0))`` in one ``jax.jit`` and reuses it for
    every right-hand side, which is what makes ``solve_many`` retrace-free.
    """

    t: int
    tol: float
    max_iters: int
    policy: object
    use_mask: bool
    init: Callable
    step: Callable
    run: Callable
    method: str = "classic"
    s: int = 1


def make_ecg_runner(
    a_apply: Callable[[jax.Array], jax.Array],
    t: int,
    *,
    tol: float = 1e-8,
    max_iters: int = 1000,
    mapping: str = "contiguous",
    allreduce: Callable[[jax.Array], jax.Array] = lambda x: x,
    split: Callable[[jax.Array, int], jax.Array] | None = None,
    chol_eps: float = 0.0,
    gram1: Callable | None = None,
    gram2: Callable | None = None,
    sqnorm: Callable | None = None,
    tail: Callable | None = None,
    backend: str = "jnp",
    policy: object = None,
    a_apply_masked: Callable | None = None,
    exit_below_width: int | None = None,
    method: str = "classic",
    s: int = 1,
    reorth: bool = False,
    rank_rtol: float | None = None,
    precond: Callable | None = None,
    gram2p: Callable | None = None,
    precond_reseed: int | None = None,
    groups: object = None,
    sqnorm_cols: Callable | None = None,
) -> ECGRunner:
    """Build the ECG iteration machinery for one fixed configuration.

    Arguments mirror :func:`ecg_solve` (which is implemented on top of this)
    except that ``t`` must already be an int and ``policy`` an already
    resolved :class:`~repro.adaptive.ReductionPolicy` (or None).  See the
    module docstring of :mod:`repro.core.ecg` for the iteration body and
    :func:`ecg_solve` for the meaning of each hook.

    ``method`` selects the iteration scheme ("classic" | "pipelined" |
    "sstep" — see :mod:`repro.core.methods`); ``s``/``reorth``/``rank_rtol``
    parameterize the s-step scheme (inner-step count, per-block
    Cholesky-QR2 second pass, safeguard pivot threshold).  This driver owns
    only the reduction-closure defaults, the convergence condition, and the
    breakdown-guarded while-loop; the per-iteration maths lives in the
    method spec.

    ``precond`` is the preconditioner apply ``(V, k) -> M⁻¹ₖ V`` (see
    :mod:`repro.precondition`); ``gram2p`` the matching 5-operand packed
    reduction ``[PᵀR | APᵀW | AP_oldᵀW]`` (defaulted here sequentially, one
    psum distributed) the preconditioned recurrence needs in place of the
    symmetric ``gram2`` payload.

    ``groups`` (a :class:`~repro.adaptive.GroupSpec`, classic only) turns
    the runner into a *packed* multi-RHS program: ``t`` is the total width
    ``n_groups · t_each``, ``init`` takes (n, n_groups) operands, each group
    converges against its own tolerance and retires independently, and the
    loop runs while any group is live.  ``sqnorm_cols`` is the per-column
    squared-norm reduction ``(n, g) -> (g,)`` that replaces the scalar
    ``sqnorm`` collective in group mode (identity-wrapped local sum by
    default; one psum of g floats distributed).
    """
    if policy is not None and chol_eps:
        raise ValueError(
            "chol_eps regularization and adaptive= are mutually exclusive: the "
            "rank-revealing factorization handles near-singular G structurally "
            "(tune ReductionPolicy.rank_rtol instead of eps-jitter)"
        )
    if backend not in ("jnp", "pallas"):
        raise ValueError(f"unknown backend {backend!r}")
    if not isinstance(s, int) or s < 1:
        raise ValueError(f"s must be an int >= 1, got {s!r}")
    spec = get_method(method)

    # The fixed-shape Pallas gram/tail kernels assume the classic (t, 3t)
    # packed layout; s-step reduces mixed widths ((st, t+2st) packed, (n, st)
    # blocks), so its default reductions always go through the
    # width-polymorphic jnp path regardless of ``backend`` — the SpMBV keeps
    # whatever backend the operator was built with.
    kernel_backend = backend if spec.name != "sstep" else "jnp"
    if gram2p is None:
        # preconditioned packed reduction: [PᵀR | APᵀW | AP_oldᵀW] — three
        # asymmetric products the fused_gram kernel cannot express (its
        # middle term is the symmetric APᵀAP), concatenated locally so the
        # payload still rides ONE psum (the tail kernel is reused unchanged;
        # the W correction is a single (n, t) add after it)
        gram2p = lambda p, r, ap, apo, w: allreduce(
            jnp.concatenate([p.T @ r, ap.T @ w, apo.T @ w], axis=1)
        )
    if gram1 is None:
        gram1 = lambda z, az: allreduce(z.T @ az)
    if gram2 is None:
        if kernel_backend == "pallas":
            gram2 = lambda p, r, ap, apo: allreduce(fused_gram(p, r, ap, apo))
        else:
            gram2 = lambda p, r, ap, apo: allreduce(
                jnp.concatenate([p.T @ r, ap.T @ ap, apo.T @ ap], axis=1)
            )
    if sqnorm is None:
        sqnorm = lambda v: allreduce(jnp.asarray([[v @ v]], v.dtype))[0, 0]
    if tail is None:
        if kernel_backend == "pallas":
            tail = ecg_tail
        else:
            tail = lambda x, r, p, ap, po, c, d, do: (
                x + p @ c, r - ap @ c, ap - p @ d - po @ do
            )
    split_fn = split if split is not None else (
        lambda r_, t_: split_residual(r_, t_, mapping)
    )
    if groups is not None:
        if spec.name != "classic":
            raise ValueError(
                f"packed group solves require method 'classic', got {spec.name!r}"
            )
        if groups.width != t:
            raise ValueError(
                f"groups describe width {groups.width} "
                f"({groups.n_groups}×{groups.t_each}) but t={t}"
            )
        if policy is None:
            raise ValueError(
                "packed group solves require a rank-revealing policy "
                "(adaptive='rankrev' at minimum): retirement zeroes Z "
                "columns, so the Gram matrix is structurally singular from "
                "the first retirement on, and the direction budget is "
                "enforced through the pivoted factorization's column mask"
            )
        if policy.restart:
            raise ValueError(
                "packed group solves cannot run a restart policy: the "
                "re-enlarge rebuilds the splitting from the summed residual, "
                "which would mix request boundaries"
            )
        if sqnorm_cols is None:
            sqnorm_cols = lambda m: jnp.sum(m * m, axis=0)
    use_mask = a_apply_masked is not None and policy is not None

    ctx = MethodContext(
        t=t, s=s, max_iters=max_iters, policy=policy, use_mask=use_mask,
        chol_eps=chol_eps, reorth=reorth, rank_rtol=rank_rtol,
        backend=backend, a_apply=a_apply, a_apply_masked=a_apply_masked,
        split_fn=split_fn, gram1=gram1, gram2=gram2, sqnorm=sqnorm, tail=tail,
        precond=precond, gram2p=gram2p, precond_reseed=precond_reseed,
        groups=groups, sqnorm_cols=sqnorm_cols,
    )
    spec.validate(ctx)
    init, iterate = spec.build(ctx)

    def cond(c):
        if groups is None:
            go = (c["rn"] > tol) & (c["k"] < max_iters)
        else:
            # packed solve: run while ANY request is live — each group's own
            # tolerance already gated its retirement inside the iteration
            go = jnp.any(c["grp_live"]) & (c["k"] < max_iters)
        if exit_below_width is not None and use_mask:
            # width-reduction event: hand control back so the caller can
            # re-slice the exchange plan at the shrunken width and resume
            go = go & (jnp.sum(c["act"]) >= exit_below_width)
        return go

    def run(carry):
        return _guarded_while(cond, iterate, carry)

    return ECGRunner(
        t=t, tol=tol, max_iters=max_iters, policy=policy, use_mask=use_mask,
        init=init, step=iterate, run=run, method=spec.name, s=s,
    )


def finalize_result(
    out: dict,
    *,
    x0,
    t: int,
    tol: float,
    policy: object = None,
    selection: object = None,
) -> SolveResult:
    """Convert a final loop carry into a :class:`SolveResult` (host syncs)."""
    x = x0 + out["X"].sum(axis=1)  # line 14: x = Σᵢ (X)ᵢ
    breakdown = bool(out["bd"])
    return SolveResult(
        x=x,
        n_iters=int(out["k"]),
        res_hist=out["hist"],
        converged=bool(out["rn"] <= tol) and not breakdown,
        breakdown=breakdown,
        t=t,
        active_hist=out["ahist"] if policy is not None else None,
        restarts=int(out["restarts"]) if policy is not None else 0,
        selection=selection,
        event_hist=out.get("evhist"),
        final_carry=out,
    )


def _ecg_solve(
    a_apply: Callable[[jax.Array], jax.Array],
    b: jax.Array,
    t: int | str,
    x0: jax.Array | None = None,
    tol: float = 1e-8,
    max_iters: int = 1000,
    mapping: str = "contiguous",
    allreduce: Callable[[jax.Array], jax.Array] = lambda x: x,
    split: Callable[[jax.Array, int], jax.Array] | None = None,
    chol_eps: float = 0.0,
    gram1: Callable | None = None,
    gram2: Callable | None = None,
    sqnorm: Callable | None = None,
    tail: Callable | None = None,
    backend: str = "jnp",
    tuned: object | None = None,
    adaptive: object = None,
    matrix: object = None,
    select: object = None,
    t_candidates: tuple = (1, 2, 4, 8, 16),
    machine: object = None,
    a_apply_masked: Callable | None = None,
    exit_below_width: int | None = None,
    resume_state: dict | None = None,
    method: str = "classic",
    s: int = 1,
    reorth: bool = False,
    rank_rtol: float | None = None,
    precond: Callable | None = None,
    gram2p: Callable | None = None,
    precond_reseed: int | None = None,
) -> SolveResult:
    """One-shot functional ECG solve (the engine behind :func:`ecg_solve`).

    Internal — callers inside ``repro.*`` use this (or a runner / the
    :class:`repro.solver.ECGSolver` handle) so that only genuinely external
    code goes through the deprecated public spelling.
    """
    selection = select
    if isinstance(t, str):
        from repro.adaptive.select_t import resolve_auto_t

        t, selection, adaptive = resolve_auto_t(
            t, adaptive, a=matrix, b=b, select=select,
            candidates=t_candidates, tol=tol, machine=machine, backend=backend,
        )
    policy = resolve_policy(adaptive)
    if tuned is not None:
        backend = getattr(tuned, "backend", backend)

    runner = make_ecg_runner(
        a_apply, t, tol=tol, max_iters=max_iters, mapping=mapping,
        allreduce=allreduce, split=split, chol_eps=chol_eps, gram1=gram1,
        gram2=gram2, sqnorm=sqnorm, tail=tail, backend=backend, policy=policy,
        a_apply_masked=a_apply_masked, exit_below_width=exit_below_width,
        method=method, s=s, reorth=reorth, rank_rtol=rank_rtol,
        precond=precond, gram2p=gram2p, precond_reseed=precond_reseed,
    )
    # Run the whole program (init + guarded loop) under one jit — the same
    # compiled shape the ECGSolver handle caches, so the one-shot legacy
    # spelling and a handle solve are bit-identical by construction.
    x0 = jnp.zeros_like(b) if x0 is None else x0
    if resume_state is not None:
        # continue a width-segmented solve from the carried loop state
        out = jax.jit(runner.run)(dict(resume_state))
    else:
        out = jax.jit(lambda b_, x0_: runner.run(runner.init(b_, x0_)))(b, x0)
    return finalize_result(
        out, x0=x0, t=t, tol=tol, policy=policy, selection=selection
    )


def ecg_solve(a_apply, b, t, *args, **kwargs) -> SolveResult:
    """Solve A x = b with ECG using enlarging factor ``t``.

    .. deprecated::
        ``ecg_solve`` is the legacy one-shot spelling: it re-derives the
        whole configuration and re-traces the solve loop on every call.
        Build a :class:`repro.solver.ECGSolver` handle instead —
        ``ECGSolver.build(a, config=SolverConfig(t=4)).solve(b)`` — which
        pays setup and compilation once and solves many right-hand sides
        without retracing.

    a_apply:   SpMBV — maps (n, t) block vectors to (n, t) block vectors
               (applied column-wise to A).  For the distributed solver this is
               the node-aware halo-exchange SpMBV.
    t:         enlarging factor, or ``"auto"`` to pick one from the
               iterations-vs-cost model (needs ``matrix=`` — the CSRMatrix
               behind ``a_apply`` — or a precomputed ``select=`` TSelection;
               ``t_candidates``/``machine`` parameterize the model).
    allreduce: reduction applied to every *local* t x t (or packed t x 3t)
               gram product; identity when running single-shard.
    gram1:     (Z, AZ) -> ZᵀAZ, globally reduced     (allreduce #1, t²)
    gram2:     (P, R, AP, AP_old) -> [PᵀR | APᵀAP | AP_oldᵀAP] packed and
               globally reduced in ONE collective     (allreduce #2, 3t²)
    sqnorm:    v -> globally-reduced vᵀv.
    The defaults compute local products wrapped in ``allreduce``; the
    distributed solver substitutes fused shard_map psums so the lowered HLO
    carries exactly two collectives per iteration (paper §3.1).
    split:     optional override of T_{r,t} (e.g. distributed splitting).
    tail:      (X, R, P, AP, P_old, c, d, d_old) -> (X, R, Z) — the local
               block-vector updates; defaults per ``backend``.
    backend:   "jnp" | "pallas" — see module docstring.
    tuned:     optional :class:`repro.tune.TunedConfig` (duck-typed, so core
               stays import-cycle-free): adopts its ``backend``.
    adaptive:  None/"off" (exact historical behavior), "rankrev" (breakdown-
               safe rank-revealing factorization, drop dependent directions),
               "reduce" (+ flexible-ECG stagnation drops),
               "reduce+restart" (+ re-enlarge on plateau), or a
               :class:`repro.adaptive.ReductionPolicy`.

    Width-segmented execution (used by the width-aware distributed solver —
    see :class:`repro.solver.ECGSolver`): ``a_apply_masked`` is an
    ``(V, active_mask) -> W`` operator that may exploit the (t,) bool mask
    of live directions (e.g. compact the halo-exchange payload to the
    active columns); when given (and a policy is on) it replaces ``a_apply``
    inside the loop and the mask is carried across iterations.
    ``exit_below_width`` additionally terminates the while-loop as soon as
    the active width falls below it — the caller then re-slices its
    operator at the shrunken width and *resumes* by passing
    ``SolveResult.final_carry`` back in as ``resume_state`` (all counters,
    histories, and block vectors continue; the maths is identical to the
    monolithic loop because only the exchange payload changes).
    """
    warnings.warn(
        "ecg_solve() is the legacy one-shot spelling; build a "
        "repro.solver.ECGSolver handle (compile-once / solve-many, typed "
        "SolverConfig) instead",
        DeprecationWarning,
        stacklevel=2,
    )
    return _ecg_solve(a_apply, b, t, *args, **kwargs)


@dataclasses.dataclass(frozen=True)
class ECGOperationCounts:
    """Per-iteration flop/communication counts of Algorithm 3 (used by the
    performance model, eq. 3.3)."""

    n: int
    nnz: int
    p: int
    t: int

    @property
    def spmbv_flops(self) -> float:  # 2·t·nnz/p
        return 2 * self.t * self.nnz / self.p

    @property
    def gram_flops(self) -> float:  # ZᵀAZ: 2·(n/p)·t² … counted as n/p·t² per Alg 3
        return self.n / self.p * self.t**2

    @property
    def fused_gram_flops(self) -> float:  # c,d,d_old: 3 products
        return 3 * self.n / self.p * self.t**2

    @property
    def cholesky_flops(self) -> float:  # (1/6)t³ (+ ~(1/2)t² triangular work)
        return self.t**3 / 6 + self.t**2 / 2

    @property
    def trsm_flops(self) -> float:  # two TRSMs with n/p rhs rows: 2·(n/p)·t²
        return 2 * self.n / self.p * self.t**2

    @property
    def update_flops(self) -> float:  # X += Pc, R -= APc, Z = AP − Pd − P_old d_old
        return (2 + 2) * self.n / self.p * self.t + 4 * self.n / self.p * self.t**2

    @property
    def total_flops(self) -> float:
        """Paper eq. (3.3): γ-weighted flop count per iteration."""
        return (
            (2 + 2 * self.t) * self.nnz / self.p
            + (4 * self.t + 4 * self.t**2) * self.n / self.p
            + self.t**2 / 2
            + self.t**3 / 6
        )

    @property
    def allreduce_payload_floats(self) -> tuple[int, int]:
        """(t², 3t²) — the two fused reductions of §3.1."""
        return (self.t**2, 3 * self.t**2)
