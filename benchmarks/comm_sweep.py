"""Width-aware exchange sweep: bytes/iteration and dispatch count vs t_active.

    PYTHONPATH=src python benchmarks/comm_sweep.py [--smoke] [--json PATH]

Two measurements, one table:

* **per-apply payload** — for every exchange strategy at compile width t=8,
  the halo-exchange bytes of one SpMBV application at active widths
  t_active in {8, 4, 2, 1}, counted two independent ways: from the plan
  (``plan.at_width(w).wire_bytes``) and *measured from the lowered
  executable* (sum of ``collective-permute`` operand bytes in the compiled
  HLO).  Both must scale like t_active/t — the width-aware re-slice moves
  exactly the active columns, not full-width zeros.  Dispatch counts (the
  packed executor's pack/ppermute/unpack ops vs the historical per-step
  gather/permute/scatter chain) ride along.
* **reduced-width solve** — a rank-deficient splitting drops a t=8 solve to
  t_active=2 at the first iteration; ``adaptive="reduce"`` + the segmented
  width-aware executor re-slice the plan at the event.  The tail segment's
  per-iteration exchange bytes must measure ≤ 0.35× the fixed-width bytes
  (it is t_active/t = 0.25× by construction), with the solve converging to
  the same answer.

A third measurement rides along: the **measured per-dispatch overhead** of
the packed executor's pack/ppermute/unpack triple
(``repro.tune.measure_dispatch_overhead``), recorded as
``summary.dispatch_overhead_measured_s`` — the calibration input for
``MachineParams.dispatch_overhead`` and the ``tune="model:structural"``
cost model.

Writes machine-readable ``BENCH_comm_sweep.json``; the CI bench-smoke job
asserts the byte ratios stay within 15% of t_active/t and the ≤ 0.35×
payload criterion.  Fixed RNG seed + structural byte accounting make the
numbers bit-reproducible run-to-run (the measured dispatch overhead is the
one wall-clock-derived field).
"""

import argparse
import json
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import numpy as np

# the HLO byte counter moved to the observability layer (the model-drift
# comparison needs it too); same function, one home
from repro.observe.drift import hlo_collective_bytes as hlo_permute_bytes


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="small problem for CI")
    ap.add_argument("--t", type=int, default=8)
    ap.add_argument("--widths", type=int, nargs="+", default=[8, 4, 2, 1])
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json", default="BENCH_comm_sweep.json")
    args = ap.parse_args()

    jax.config.update("jax_enable_x64", True)
    import jax.numpy as jnp

    from repro.core.machines import BLUE_WATERS
    from repro.solver import CommConfig, ECGSolver, SolverConfig
    from repro.sparse import dg_laplace_2d, fd_laplace_2d
    from repro.tune import measure_dispatch_overhead

    n_dev = len(jax.devices())
    assert n_dev >= 8, f"need >= 8 devices, got {n_dev}"
    mesh = jax.sharding.Mesh(
        np.array(jax.devices()[:8]).reshape(2, 4), ("node", "proc")
    )
    t = args.t
    a = fd_laplace_2d(13) if args.smoke else dg_laplace_2d((16, 12), block=8)
    n = a.shape[0]
    f = 8  # float64 solver data
    print(f"# comm_sweep: {n} rows, {a.nnz} nnz, t={t}, "
          f"t_active in {args.widths}")

    rows, ratio_checks = [], []
    print("name,plan_bytes,hlo_bytes,dispatches_packed,dispatches_perstep")
    pm = None
    for strategy in ("standard", "2step", "3step", "optimal"):
        solver = ECGSolver.build(a, mesh, SolverConfig(
            t=t, comm=CommConfig(strategy=strategy, machine=BLUE_WATERS),
        ), pm=pm)
        pm = solver.partition  # reuse the row partition across strategy builds
        op = solver.op
        full_plan = op.plan.wire_bytes(f)
        sds = jax.ShapeDtypeStruct((op.n_padded, t), jnp.float64)
        full_hlo = None
        for w in sorted(set(args.widths), reverse=True):
            plan_w = op.plan.at_width(w)
            plan_bytes = plan_w.wire_bytes(f)
            sds_w = jax.ShapeDtypeStruct((op.n_padded, w), jnp.float64)
            txt = jax.jit(op.matvec_fn(t_active=w)).lower(sds_w).compile().as_text()
            hlo_bytes = hlo_permute_bytes(txt, op.p)
            if w == t:
                full_hlo = hlo_bytes
                # a silent parser miss would degrade the gauge to plan-only
                assert full_hlo > 0, (strategy, "no collective-permute in HLO")
            name = f"comm/{strategy}_t{t}_active{w}"
            rows.append(dict(
                name=name, strategy=strategy, t=t, t_active=w,
                plan_bytes=plan_bytes, hlo_bytes=hlo_bytes,
                dispatches_packed=plan_w.dispatch_count(packed=True),
                dispatches_perstep=plan_w.dispatch_count(packed=False),
            ))
            print(f"{name},{plan_bytes},{hlo_bytes},"
                  f"{plan_w.dispatch_count(True)},{plan_w.dispatch_count(False)}",
                  flush=True)
            expect = w / t
            ratio_checks.append(dict(
                strategy=strategy, t_active=w, expect=expect,
                plan_ratio=plan_bytes / full_plan,
                hlo_ratio=hlo_bytes / full_hlo if full_hlo else None,
            ))

    # ---- reduced-width solve: t=8 -> t_active=2 on a deficient splitting
    m = 2
    rng = np.random.default_rng(args.seed)
    b_def = np.zeros(n)
    b_def[: (m * n) // t] = rng.standard_normal((m * n) // t)
    solver = ECGSolver.build(a, mesh, SolverConfig(
        t=t, tol=1e-8, max_iters=600, adaptive="reduce",
        comm=CommConfig(strategy="3step", machine=BLUE_WATERS),
    ), pm=pm)
    res = solver.solve(b_def)
    op = solver.op
    segs = res.comm_segments or [(t, res.n_iters)]
    full_bytes = op.plan.wire_bytes(f)
    seg_bytes = [(w, it, op.plan.at_width(w).wire_bytes(f)) for w, it in segs]
    total_iters = max(sum(it for _, it in segs), 1)
    avg_bytes = sum(it * bb for _, it, bb in seg_bytes) / total_iters
    tail_w, _, tail_bytes = seg_bytes[-1]
    tail_ratio = tail_bytes / full_bytes
    print(f"# solve t={t}->t_active={tail_w}: segments={segs} "
          f"bytes/iter {full_bytes} -> {tail_bytes} ({tail_ratio:.3f}x, "
          f"avg {avg_bytes:.0f}) converged={res.converged}")

    # ---- measured per-dispatch overhead (pack/ppermute/unpack microbench):
    # the constant the structural cost model charges per executor op —
    # calibrate MachineParams.dispatch_overhead from this on a new machine
    overhead_s = measure_dispatch_overhead(mesh)
    print(f"# measured dispatch overhead: {overhead_s*1e6:.1f}us/op "
          f"(HOST model constant: 15.0us)")

    ratio_ok = all(
        abs(c["plan_ratio"] / c["expect"] - 1.0) <= 0.15
        and (c["hlo_ratio"] is None or abs(c["hlo_ratio"] / c["expect"] - 1.0) <= 0.15)
        for c in ratio_checks
    )
    dispatch_cut = {
        r["strategy"]: r["dispatches_perstep"] - r["dispatches_packed"]
        for r in rows if r["t_active"] == t
    }
    summary = dict(
        bytes_ratio_within_15pct=bool(ratio_ok),
        dispatch_overhead_measured_s=overhead_s,
        reduced_solve=dict(
            t=t, t_active=tail_w, segments=segs,
            bytes_per_iter_full=full_bytes, bytes_per_iter_tail=tail_bytes,
            tail_ratio=tail_ratio, avg_bytes_per_iter=avg_bytes,
            converged=bool(res.converged), breakdown=bool(res.breakdown),
        ),
        payload_leq_035=bool(tail_ratio <= 0.35),
        dispatch_cut_packed_vs_perstep=dispatch_cut,
        packed_never_worse=bool(all(v >= 0 for v in dispatch_cut.values())),
    )
    print(f"# gauges: bytes_ratio_within_15pct={summary['bytes_ratio_within_15pct']} "
          f"payload_leq_035={summary['payload_leq_035']} "
          f"dispatch_cut={dispatch_cut}")

    with open(args.json, "w") as fh:
        json.dump(dict(benchmark="comm_sweep", smoke=args.smoke, seed=args.seed,
                       rows=rows, ratio_checks=ratio_checks, summary=summary),
                  fh, indent=2)
    print(f"# wrote {args.json}")


if __name__ == "__main__":
    main()
