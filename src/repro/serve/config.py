"""Typed, validated configuration for the ECG serving layer.

One frozen :class:`ServeConfig`, following the :class:`~repro.solver.
SolverConfig` conventions (validate at construction, coerce convenient
spellings, cheap ``dataclasses.replace`` derivation): the solver template
every registered operator is built with, the registry byte budget, the
warm-start cache location, and the batching/backpressure policy.
"""

from __future__ import annotations

import dataclasses
from typing import Any

from repro.serve.packing import PackingConfig
from repro.solver.config import SolverConfig


def _default_solver() -> SolverConfig:
    # rankrev keeps batched requests safe by default: a localized or
    # near-degenerate RHS produces rank-deficient splittings, and a server
    # cannot pre-screen what clients send
    return SolverConfig(t=4, adaptive="rankrev")


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Configuration of one :class:`~repro.serve.ECGServer`.

    solver:         the :class:`~repro.solver.SolverConfig` template each
                    registered operator's session is built from (dict /
                    None spellings coerced).  Warm-start loads override its
                    ``tune.tuned`` / ``adaptive.select`` fields per
                    operator.
    registry_bytes: LRU byte budget of the operator registry, measured in
                    CSR bytes (:func:`~repro.serve.operator_nbytes`).  The
                    most recently used session always survives, even when
                    it alone exceeds the budget.
    cache_dir:      directory for the disk-backed warm-start cache (tuning
                    + t-selection JSON per operator); ``None`` disables
                    persistence.
    max_batch:      coalescing limit — a per-operator group of this many
                    distinct pending requests is dispatched eagerly at
                    ``submit`` time; ``flush()`` drains regardless.
    max_wait_s:     age-based flush: a ``submit`` that finds requests
                    older than this drains the queue first.  ``0`` (the
                    default) disables the clock — batches close on
                    ``max_batch`` or an explicit ``flush()`` only, which
                    keeps request traces deterministic.
    max_pending:    bounded-queue backpressure: a ``submit`` beyond this
                    many pending requests raises
                    :class:`~repro.serve.ServeOverloaded` instead of
                    growing the queue without bound.
    dedup:          share one solve among concurrent requests with
                    identical (operator, b, x0) payloads — cross-request
                    result reuse, bit-identical by construction.
    packing:        the opt-in width-packing policy
                    (:class:`~repro.serve.PackingConfig`; a pack-mode
                    string or dict coerces).  ``pack="width"`` coalesces
                    compatible requests into one enlarged block solve with
                    per-request retirement — higher req/s, measured-relres
                    contract instead of bit-identity.  The default
                    (``pack="off"``) changes nothing.
    """

    solver: SolverConfig = dataclasses.field(default_factory=_default_solver)
    registry_bytes: int = 256 * 1024 * 1024
    cache_dir: str | None = None
    max_batch: int = 8
    max_wait_s: float = 0.0
    max_pending: int = 256
    dedup: bool = True
    packing: PackingConfig = dataclasses.field(default_factory=PackingConfig)

    def __post_init__(self):
        object.__setattr__(self, "solver", SolverConfig.coerce(self.solver))
        if not isinstance(self.registry_bytes, int) or self.registry_bytes < 1:
            raise ValueError(
                f"registry_bytes must be an int >= 1, got {self.registry_bytes!r}"
            )
        if not isinstance(self.max_batch, int) or self.max_batch < 1:
            raise ValueError(f"max_batch must be an int >= 1, got {self.max_batch!r}")
        if not self.max_wait_s >= 0:
            raise ValueError(f"max_wait_s must be >= 0, got {self.max_wait_s!r}")
        if not isinstance(self.max_pending, int) or self.max_pending < 1:
            raise ValueError(
                f"max_pending must be an int >= 1, got {self.max_pending!r}"
            )
        if self.cache_dir is not None and not isinstance(self.cache_dir, str):
            raise ValueError(f"cache_dir must be a str or None, got {self.cache_dir!r}")
        object.__setattr__(self, "dedup", bool(self.dedup))
        object.__setattr__(self, "packing", PackingConfig.coerce(self.packing))

    @classmethod
    def coerce(cls, value) -> "ServeConfig":
        if value is None:
            return cls()
        if isinstance(value, cls):
            return value
        if isinstance(value, dict):
            return cls(**value)
        raise TypeError(
            f"config must be a ServeConfig or dict of its fields, got {type(value)}"
        )

    def replace(self, **overrides) -> "ServeConfig":
        """Return a new config with ``overrides`` applied (field names
        only; for solver-template tweaks compose with
        ``SolverConfig.replace``)."""
        own = {f.name for f in dataclasses.fields(self)}
        unknown = set(overrides) - own
        if unknown:
            raise ValueError(
                f"unknown ServeConfig override(s) {sorted(unknown)}; "
                f"expected one of {sorted(own)}"
            )
        return dataclasses.replace(self, **overrides)
