"""Public ops: fused ECG block updates (Pallas on TPU, oracle elsewhere).

``block_update`` is the historical two-output op (X/R only); ``ecg_tail`` is
the full per-iteration tail used by the solver hot path when
``backend="pallas"`` — it additionally produces Z = AP − P·d − P_old·d_old
in the same row pass, so P and AP stream from HBM once per iteration.
"""

from __future__ import annotations

from repro.kernels.block_update.kernel import block_update_pallas, ecg_tail_pallas
from repro.kernels.block_update.ref import block_update_ref, ecg_tail_ref
from repro.kernels.dispatch import resolve_dispatch


def block_update(x, r, p, ap, c, use_pallas: bool | None = None, block_rows: int = 512):
    use_pallas, interpret = resolve_dispatch("block_update", use_pallas)
    if use_pallas:
        return block_update_pallas(x, r, p, ap, c, block_rows=block_rows, interpret=interpret)
    return block_update_ref(x, r, p, ap, c)


def ecg_tail(x, r, p, ap, p_old, c, d, d_old, use_pallas: bool | None = None,
             block_rows: int = 512):
    """Fused tail of one ECG iteration; see :func:`ecg_tail_ref` for the math."""
    use_pallas, interpret = resolve_dispatch("ecg_tail", use_pallas)
    if use_pallas:
        return ecg_tail_pallas(
            x, r, p, ap, p_old, c, d, d_old, block_rows=block_rows, interpret=interpret
        )
    return ecg_tail_ref(x, r, p, ap, p_old, c, d, d_old)
