"""Residual enlarging operators T_{r,t} (paper §2.1, Fig 2.1).

T_{r,t} projects r ∈ R^n to an n x t block vector whose columns sum to r
(row-sum preservation, eq. 2.3) and are linearly independent: column i of
T carries the entries of r belonging to subdomain i, zeros elsewhere.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def subdomain_map_contiguous(n: int, t: int) -> jax.Array:
    """Row -> subdomain id, contiguous blocks (Fig 2.1 left; aligned with the
    contiguous row partition the paper uses)."""
    idx = jnp.arange(n)
    return (idx * t) // n


def subdomain_map_round_robin(n: int, t: int) -> jax.Array:
    """Row -> subdomain id, cyclic assignment (Fig 2.1 middle)."""
    return jnp.arange(n) % t


def split_residual(r: jax.Array, t: int, mapping: str = "contiguous") -> jax.Array:
    """T_{r,t}: split r into an (n, t) block vector along subdomains."""
    n = r.shape[0]
    if mapping == "contiguous":
        sub = subdomain_map_contiguous(n, t)
    elif mapping == "round_robin":
        sub = subdomain_map_round_robin(n, t)
    else:
        raise ValueError(f"unknown mapping {mapping!r}")
    onehot = jax.nn.one_hot(sub, t, dtype=r.dtype)
    return r[:, None] * onehot


def collapse(block: jax.Array) -> jax.Array:
    """Inverse direction of (2.3): sum block-vector columns back to a vector."""
    return block.sum(axis=1)


def split_rank(r: jax.Array, t: int, mapping: str = "contiguous") -> jax.Array:
    """Number of nonzero columns of T_{r,t}(r).

    The columns of the splitting have disjoint supports, so they are linearly
    independent iff nonzero — this is the exact rank of the initial enlarged
    block, i.e. the width a breakdown-safe solve (:mod:`repro.adaptive`)
    reduces to on its first iteration when some subdomains carry no residual.
    """
    big = split_residual(r, t, mapping)
    return jnp.sum(jnp.any(big != 0, axis=0))
