"""Model-predicted vs measured winners over the tuner grid (8 host devices).

    PYTHONPATH=src python benchmarks/tuner_sweep.py [--t 4 8] [--json PATH]

For every (strategy x tile x schedule) config at each t, prints the measured
wall microseconds of one distributed SpMBV application next to the
model-predicted microseconds, then a per-t summary naming the measured
winner, the model winner, and the *gap*: how much slower the model's pick
runs than the measured best.  The gap is the acceptance gauge for
``tune="model"`` — it should stay within ~10% on a machine whose
:class:`~repro.core.machines.MachineParams` constants are calibrated (on
forced host devices, where ppermute is a memcpy, expect the model's comm
terms to overstate; ``--machine`` selects the parameter set).

Writes machine-readable ``BENCH_tuner_sweep.json`` so the perf trajectory
(and the model-vs-measured gap) is tracked across PRs.
"""

import argparse
import json
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--t", type=int, nargs="+", default=[4, 8])
    ap.add_argument("--tiles", default="4x4,8x8,16x16")
    ap.add_argument("--machine", default="BlueWaters")
    ap.add_argument("--repeats", type=int, default=5,
                    help="timing repeats per config; the median is reported")
    ap.add_argument("--seed", type=int, default=0,
                    help="operand RNG seed (fixed for run-to-run reproducibility)")
    ap.add_argument("--json", default="BENCH_tuner_sweep.json")
    args = ap.parse_args()

    jax.config.update("jax_enable_x64", True)
    from repro.core.comm_graph import build_comm_graph
    from repro.core.machines import MACHINES
    from repro.core.models import STRATEGIES
    from repro.sparse import dg_laplace_2d, partition_csr
    from repro.tune import measure_config, predict_config, tile_stats, tune

    n_dev = len(jax.devices())
    assert n_dev >= 8, f"need >= 8 devices, got {n_dev}"
    mesh = jax.sharding.Mesh(
        np.array(jax.devices()[:8]).reshape(2, 4), ("node", "proc")
    )
    a = dg_laplace_2d((16, 12), block=8)  # 1536 rows over 8 ranks
    pm = partition_csr(a, 8)
    g = build_comm_graph(pm, ppn=4)
    machine = MACHINES[args.machine].with_ppn(4)
    tiles = [tuple(map(int, s.split("x"))) for s in args.tiles.split(",")]

    rows, summary = [], {}
    print("name,us_per_call,model_us")
    for t in args.t:
        stats = {tl: tile_stats(pm, *tl) for tl in tiles}
        for strategy in STRATEGIES:
            for tl in tiles:
                for overlap in (False, True):
                    mode = "overlap" if overlap else "blocking"
                    name = f"tuner/{strategy}_{tl[0]}x{tl[1]}_{mode}_t{t}"
                    us = measure_config(
                        a, mesh, t, strategy, tl, overlap, backend="pallas",
                        machine=machine, pm=pm, repeats=args.repeats,
                        seed=args.seed,
                    )
                    model_us = 1e6 * predict_config(
                        pm, g, t, machine, strategy, stats[tl], overlap, "pallas"
                    )
                    rows.append(dict(
                        name=name, us=us, model_us=model_us, t=t,
                        strategy=strategy, tile=f"{tl[0]}x{tl[1]}", overlap=overlap,
                    ))
                    print(f"{name},{us:.1f},{model_us:.2f}", flush=True)
        sub = [r for r in rows if r["t"] == t]
        meas_best = min(sub, key=lambda r: r["us"])
        model_best = min(sub, key=lambda r: r["model_us"])
        gap = model_best["us"] / meas_best["us"] - 1.0
        cfg = tune(a, t=t, machine=machine, mesh=mesh, backend="pallas",
                   tiles=tiles, pm=pm)
        # the pick is serialized losslessly (TunedConfig.to_json) so a later
        # run can reload it from this file and feed it straight back through
        # SolverConfig(tune=TunedConfig.from_json(...)) without re-tuning
        from repro.tune import TunedConfig, tunedconfig_to_dict

        cfg_dict = tunedconfig_to_dict(cfg)
        assert TunedConfig.from_json(cfg_dict).to_json() == cfg.to_json()
        summary[f"t{t}"] = dict(
            measured_winner=meas_best["name"],
            model_winner=model_best["name"],
            tune_model_pick=(
                f"{cfg.strategy}/{cfg.br}x{cfg.bc}/"
                f"{'overlap' if cfg.overlap else 'blocking'}"
            ),
            model_pick_gap=gap,
            within_10pct=bool(gap <= 0.10),
            tuned_config=cfg_dict,
        )
        print(
            f"# t={t}: measured winner={meas_best['name']} "
            f"model winner={model_best['name']} gap={gap:+.1%}",
            flush=True,
        )

    with open(args.json, "w") as fh:
        json.dump(dict(benchmark="tuner_sweep", seed=args.seed,
                       repeats=args.repeats, rows=rows, summary=summary),
                  fh, indent=2)
    print(f"# wrote {args.json}")


if __name__ == "__main__":
    main()
