"""jnp oracle: batched SPD block solve against precomputed Cholesky factors."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def block_trisolve_ref(l, x):
    """Solve ``L[i] L[i]ᵀ y[i] = x[i]`` for every block.

    l: (nb, bs, bs) lower Cholesky factors
    x: (nb, bs, t)  right-hand-side blocks
    returns (nb, bs, t)
    """
    l = l.astype(x.dtype)
    solve = jax.vmap(lambda li, xi: jax.scipy.linalg.cho_solve((li, True), xi))
    return solve(l, x)


def block_trisolve_dense(l, x):
    """Substitution-form oracle (no LAPACK): the exact arithmetic the Pallas
    kernel performs, row by row — used to pin the kernel's numerics."""
    nb, bs, _ = l.shape
    l = l.astype(x.dtype)

    def one(li, xi):
        y = jnp.zeros_like(xi)
        for i in range(bs):
            s = li[i] @ y
            y = y.at[i].set((xi[i] - s) / li[i, i])
        z = jnp.zeros_like(xi)
        for i in range(bs - 1, -1, -1):
            s = li[:, i] @ z
            z = z.at[i].set((y[i] - s) / li[i, i])
        return z

    return jax.vmap(one)(l, x)
