"""whisper-medium [audio enc-dec]: 24+24L d=1024 16H d_ff=4096 vocab=51865
[arXiv:2212.04356].  Conv/mel frontend STUBBED: input_specs provides
precomputed frame embeddings (B, 1500, D)."""

from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="whisper-medium",
    family="encdec",
    n_layers=24,        # decoder layers
    n_enc_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab=51865,
    mlp="gelu",
    enc_ctx=1500,
    tie_embeddings=True,
)

SMOKE = CONFIG.with_(
    name="whisper-smoke", n_layers=2, n_enc_layers=2, d_model=64, n_heads=4,
    n_kv_heads=4, d_ff=128, vocab=512, enc_ctx=32, remat=False,
)

SHAPES = {
    "train_4k": "run",
    "prefill_32k": "run",      # decoder prefill against the 1500-frame encoder
    "decode_32k": "run",
    "long_500k": "skip:full-attention decoder; encoder context bounded at 1500 frames",
}
