"""Public op: Block-ELL SpMBV with Pallas-on-TPU / oracle-on-CPU dispatch."""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.sparse.csr import BSRMatrix, CSRMatrix, csr_to_bsr
from repro.kernels.bsr_spmbv.kernel import bsr_spmbv_pallas
from repro.kernels.bsr_spmbv.ref import bsr_spmbv_ref


def bsr_to_block_ell(b: BSRMatrix, kmax: int | None = None):
    """BSR -> Block-ELL (fixed tiles per block row; zero-padded)."""
    nbr = b.n_block_rows
    indptr = np.asarray(b.block_indptr)
    per_row = np.diff(indptr)
    kmax = int(per_row.max()) if kmax is None else kmax
    br, bc = b.block_shape
    blocks = np.zeros((nbr, kmax, br, bc), dtype=np.asarray(b.blocks).dtype)
    indices = np.zeros((nbr, kmax), dtype=np.int32)
    src_blocks = np.asarray(b.blocks)
    src_idx = np.asarray(b.block_indices)
    for i in range(nbr):
        s, e = indptr[i], indptr[i + 1]
        blocks[i, : e - s] = src_blocks[s:e]
        indices[i, : e - s] = src_idx[s:e]
    return jnp.asarray(blocks), jnp.asarray(indices)


def block_ell_from_csr(a: CSRMatrix, br: int, bc: int):
    return bsr_to_block_ell(csr_to_bsr(a, br, bc))


def bsr_spmbv(blocks, indices, v, use_pallas: bool | None = None):
    """W = A @ V.  Pallas kernel on TPU; interpret-mode Pallas or the jnp
    oracle elsewhere (``use_pallas=True`` forces interpret-mode validation)."""
    on_tpu = jax.default_backend() == "tpu"
    if use_pallas is None:
        use_pallas = on_tpu
    if use_pallas:
        return bsr_spmbv_pallas(blocks, indices, v, interpret=not on_tpu)
    return bsr_spmbv_ref(blocks, indices, v)
