"""Training substrate: optimizer math, data determinism, checkpoint/restart,
fault tolerance, elasticity."""

import os
import signal
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.models.common import ArchConfig
from repro.models.registry import model_api
from repro.train import (
    AdamWConfig,
    init_opt_state,
    apply_adamw,
    build_train_step,
    DataConfig,
    batch_at,
    save_checkpoint,
    restore_checkpoint,
    latest_step,
    install_preemption_handler,
)
from repro.train.optimizer import lr_at, zero1_specs
from jax.sharding import PartitionSpec as P


TINY = ArchConfig(
    name="tiny", family="dense", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=128, vocab=256, dtype=jnp.float32, remat=False,
)


class TestOptimizer:
    def test_adamw_matches_reference_math(self):
        cfg = AdamWConfig(lr=0.1, b1=0.9, b2=0.99, eps=1e-8, weight_decay=0.0,
                          grad_clip=1e9, warmup_steps=0, total_steps=10**9, min_lr_ratio=1.0)
        params = {"w": jnp.asarray([1.0, -2.0])}
        grads = {"w": jnp.asarray([0.5, 0.5])}
        state = init_opt_state(params)
        new, state, stats = apply_adamw(cfg, params, grads, state)
        # step 1: mhat = g, nhat = g^2  => delta = g/(|g|+eps) = sign(g)
        np.testing.assert_allclose(np.asarray(new["w"]), [0.9, -2.1], rtol=1e-5)
        assert float(stats["grad_norm"]) == pytest.approx(np.sqrt(0.5), rel=1e-5)

    def test_grad_clip(self):
        cfg = AdamWConfig(lr=0.0, grad_clip=1.0)
        params = {"w": jnp.ones(4)}
        grads = {"w": jnp.full(4, 100.0)}
        _, _, stats = apply_adamw(cfg, params, grads, init_opt_state(params))
        assert float(stats["grad_norm"]) == pytest.approx(200.0)

    def test_lr_schedule(self):
        cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=110, min_lr_ratio=0.1)
        assert float(lr_at(cfg, 5)) == pytest.approx(0.5)
        assert float(lr_at(cfg, 10)) == pytest.approx(1.0)
        assert float(lr_at(cfg, 110)) == pytest.approx(0.1, rel=1e-3)

    def test_zero1_spreads_over_data(self):
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        from repro.models.common import MeshAxes
        axes = MeshAxes.from_mesh(mesh)
        specs = {"w": P(None, "model")}
        shapes = {"w": jax.ShapeDtypeStruct((8, 4), jnp.float32)}
        # data axis size 1 here, but the rule must still fire structurally
        out = zero1_specs(specs, axes, shapes)
        assert out["w"] == P("data", "model")


class TestData:
    def test_deterministic(self):
        cfg = DataConfig(vocab=100, batch=4, seq=16, seed=3)
        a = batch_at(cfg, 7)
        b = batch_at(cfg, 7)
        assert np.array_equal(np.asarray(a["tokens"]), np.asarray(b["tokens"]))

    def test_steps_differ(self):
        cfg = DataConfig(vocab=100, batch=4, seq=16, seed=3)
        a = batch_at(cfg, 1)
        b = batch_at(cfg, 2)
        assert not np.array_equal(np.asarray(a["tokens"]), np.asarray(b["tokens"]))

    def test_labels_are_shifted_tokens(self):
        cfg = DataConfig(vocab=100, batch=2, seq=8, seed=0)
        b = batch_at(cfg, 0)
        assert np.array_equal(np.asarray(b["tokens"][:, 1:]), np.asarray(b["labels"][:, :-1]))

    def test_learnable_structure(self):
        # Markov repeats: P(label == token) must be well above 1/vocab
        cfg = DataConfig(vocab=1000, batch=8, seq=128, seed=1, repeat_p=0.3)
        b = batch_at(cfg, 0)
        frac = float((np.asarray(b["tokens"]) == np.asarray(b["labels"])).mean())
        assert frac > 0.15


class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        tree = {"a": jnp.arange(6.0).reshape(2, 3), "b": {"c": jnp.ones(4, jnp.int32)}}
        save_checkpoint(tmp_path, 42, tree, extra={"note": "hi"})
        assert latest_step(tmp_path) == 42
        restored, meta = restore_checkpoint(tmp_path, tree)
        assert meta["extra"]["note"] == "hi"
        for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))

    def test_latest_pointer_advances(self, tmp_path):
        tree = {"a": jnp.zeros(2)}
        save_checkpoint(tmp_path, 1, tree)
        save_checkpoint(tmp_path, 2, tree)
        assert latest_step(tmp_path) == 2
        _, meta = restore_checkpoint(tmp_path, tree, step=1)
        assert meta["step"] == 1

    def test_restore_onto_different_mesh_shape(self, tmp_path):
        """Elasticity: save under one sharding, restore under another."""
        mesh_a = jax.make_mesh((1, 1), ("data", "model"))
        tree = {"w": jax.device_put(jnp.arange(16.0).reshape(4, 4),
                                    jax.NamedSharding(mesh_a, P(None, None)))}
        save_checkpoint(tmp_path, 3, tree)
        mesh_b = jax.make_mesh((1,), ("x",))
        sh = {"w": jax.NamedSharding(mesh_b, P("x", None))}
        restored, _ = restore_checkpoint(tmp_path, tree, shardings=sh)
        np.testing.assert_array_equal(np.asarray(restored["w"]), np.arange(16.0).reshape(4, 4))
        assert restored["w"].sharding.mesh.axis_names == ("x",)

    def test_resume_training_exact(self, tmp_path):
        """Train 4 steps straight == train 2, checkpoint, restore, train 2."""
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        api = model_api(TINY)
        bundle = build_train_step(TINY, mesh, AdamWConfig(lr=1e-3), batch=2, seq=16, donate=False)
        dcfg = DataConfig(vocab=TINY.vocab, batch=2, seq=16)

        params = api.init_params(TINY, jax.random.key(0))
        opt = init_opt_state(params)
        for step in range(4):
            params, opt, _ = bundle.step_fn(params, opt, batch_at(dcfg, step))
        straight = [np.asarray(x) for x in jax.tree.leaves(params)]

        params = api.init_params(TINY, jax.random.key(0))
        opt = init_opt_state(params)
        for step in range(2):
            params, opt, _ = bundle.step_fn(params, opt, batch_at(dcfg, step))
        save_checkpoint(tmp_path, 2, {"params": params, "opt": opt})
        (restored, ), meta = restore_checkpoint(tmp_path, ({"params": params, "opt": opt},))
        params, opt = restored["params"], restored["opt"]
        for step in range(meta["step"], 4):
            params, opt, _ = bundle.step_fn(params, opt, batch_at(dcfg, step))
        resumed = [np.asarray(x) for x in jax.tree.leaves(params)]
        for a, b in zip(straight, resumed):
            np.testing.assert_allclose(a, b, atol=1e-6)

    def test_preemption_handler(self, tmp_path):
        calls = []
        install_preemption_handler(lambda: calls.append(1))
        with pytest.raises(SystemExit) as e:
            os.kill(os.getpid(), signal.SIGTERM)
            signal.sigtimedwait([], 0)  # let the handler run (sync delivery)
        assert calls == [1]
        assert e.value.code == 143
        signal.signal(signal.SIGTERM, signal.SIG_DFL)


class TestMicrobatching:
    def test_accumulation_matches_full_batch(self):
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        api = model_api(TINY)
        params = api.init_params(TINY, jax.random.key(1))
        dcfg = DataConfig(vocab=TINY.vocab, batch=4, seq=16)
        batch = batch_at(dcfg, 0)
        opt_cfg = AdamWConfig(lr=1e-3, weight_decay=0.0)
        b1 = build_train_step(TINY, mesh, opt_cfg, batch=4, seq=16, microbatches=1, donate=False)
        b2 = build_train_step(TINY, mesh, opt_cfg, batch=4, seq=16, microbatches=2, donate=False)
        p1, _, m1 = b1.step_fn(params, init_opt_state(params), batch)
        p2, _, m2 = b2.step_fn(params, init_opt_state(params), batch)
        assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=1e-5)
        for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)
