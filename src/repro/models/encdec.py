"""Whisper-style encoder-decoder backbone.

Per the assignment the conv/mel frontend is a STUB: ``input_specs`` feeds
precomputed frame embeddings (B, enc_ctx, D).  The encoder is bidirectional;
the decoder is causal self-attention + cross-attention over encoder output.
Deviation noted in DESIGN.md: rotary positions replace Whisper's learned
decoder positions so the decode_32k shape cell is well-defined.
"""

from __future__ import annotations

from typing import Any

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.models.common import ArchConfig, MeshAxes, constrain
from repro.models import layers as L


def _attn_shapes(cfg, n):
    d, h, kv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    return {
        "wq": (n, d, h, dh), "wk": (n, d, kv, dh), "wv": (n, d, kv, dh), "wo": (n, h, dh, d),
    }


def param_shapes(cfg: ArchConfig) -> dict[str, Any]:
    d, f = cfg.d_model, cfg.d_ff
    ne, nd = cfg.n_enc_layers, cfg.n_layers
    enc = {"ln1": (ne, d), "ln2": (ne, d), "wu": (ne, d, f), "wd": (ne, f, d)}
    enc |= _attn_shapes(cfg, ne)
    dec = {
        "ln1": (nd, d), "lnx": (nd, d), "ln2": (nd, d),
        "wu": (nd, d, f), "wd": (nd, f, d),
        "xq": (nd, d, cfg.n_heads, cfg.head_dim),
        "xk": (nd, d, cfg.n_kv_heads, cfg.head_dim),
        "xv": (nd, d, cfg.n_kv_heads, cfg.head_dim),
        "xo": (nd, cfg.n_heads, cfg.head_dim, d),
    }
    dec |= _attn_shapes(cfg, nd)
    shapes = {
        "enc_pos": (cfg.enc_ctx, d),
        "enc_layers": enc,
        "enc_final_ln": (d,),
        "emb": (cfg.vocab_padded, d),
        "dec_layers": dec,
        "final_ln": (d,),
    }
    if not cfg.tie_embeddings:
        shapes["lm_head"] = (d, cfg.vocab_padded)
    return shapes


def _specs_attn(cfg, axes, pre=("wq", "wk", "wv", "wo")):
    d, h, kv = cfg.d_model, cfg.n_heads, cfg.n_kv_heads
    fs, tp = axes.fs, axes.tp
    q, k, v, o = pre
    return {
        q: P(None, fs(d), tp(h), None),
        k: P(None, fs(d), tp(kv), None),
        v: P(None, fs(d), tp(kv), None),
        o: P(None, tp(h), None, fs(d)),
    }


def param_specs(cfg: ArchConfig, axes: MeshAxes) -> dict[str, Any]:
    d, f = cfg.d_model, cfg.d_ff
    fs, tp = axes.fs, axes.tp
    mlp = {"wu": P(None, fs(d), tp(f)), "wd": P(None, tp(f), fs(d))}
    enc = {"ln1": P(None, None), "ln2": P(None, None)} | mlp | _specs_attn(cfg, axes)
    dec = (
        {"ln1": P(None, None), "lnx": P(None, None), "ln2": P(None, None)}
        | mlp
        | _specs_attn(cfg, axes)
        | _specs_attn(cfg, axes, pre=("xq", "xk", "xv", "xo"))
    )
    specs = {
        "enc_pos": P(None, None),
        "enc_layers": enc,
        "enc_final_ln": P(None),
        "emb": P(tp(cfg.vocab_padded), fs(d)),
        "dec_layers": dec,
        "final_ln": P(None),
    }
    if not cfg.tie_embeddings:
        specs["lm_head"] = P(fs(d), tp(cfg.vocab_padded))
    return specs


def abstract_params(cfg):
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s, cfg.dtype),
        param_shapes(cfg),
        is_leaf=lambda s: isinstance(s, tuple),
    )


def init_params(cfg: ArchConfig, key):
    shapes = param_shapes(cfg)
    flat, treedef = jax.tree_util.tree_flatten_with_path(shapes, is_leaf=lambda s: isinstance(s, tuple))
    keys = jax.random.split(key, len(flat))
    leaves = []
    for k, (path, shape) in zip(keys, flat):
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        if "ln" in name:
            leaves.append(jnp.ones(shape, cfg.dtype))
        else:
            fan_in = shape[-2] if len(shape) > 1 else shape[-1]
            leaves.append((jax.random.normal(k, shape) * fan_in ** -0.5).astype(cfg.dtype))
    return jax.tree.unflatten(treedef, leaves)


# ---------------------------------------------------------------- forwards
def encode(cfg: ArchConfig, mesh: Mesh, params, frames):
    """frames: (B, enc_ctx, D) stub embeddings -> encoder states."""
    axes = MeshAxes.from_mesh(mesh)
    x = frames.astype(cfg.dtype) + params["enc_pos"][None].astype(cfg.dtype)
    rspec = (axes.batch, None, None)
    x = constrain(x, mesh, *rspec)

    def body(carry, lp):
        h = L.rms_norm(carry, lp["ln1"], cfg.norm_eps)
        q, k, v = L.qkv(cfg, h, lp, None)  # no rope: learned enc positions
        o = L.attention(cfg, mesh, axes, q, k, v, None)  # bidirectional
        x = carry + jnp.einsum("bshe,hed->bsd", o, lp["wo"])
        h = L.rms_norm(x, lp["ln2"], cfg.norm_eps)
        x = x + L.mlp_block(cfg, mesh, axes, h, lp)
        return constrain(x, mesh, *rspec), None

    if cfg.remat:
        body = jax.remat(body)
    if cfg.unroll:
        for i in range(cfg.n_enc_layers):
            x, _ = body(x, jax.tree.map(lambda w: w[i], params["enc_layers"]))
    else:
        x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return L.rms_norm(x, params["enc_final_ln"], cfg.norm_eps)


def decode_train(cfg: ArchConfig, mesh: Mesh, params, tokens, enc_out):
    axes = MeshAxes.from_mesh(mesh)
    x = params["emb"][tokens].astype(cfg.dtype)
    b, s, _ = x.shape
    positions = jnp.arange(s)[None, :]
    rspec = (axes.batch, None, None)
    x = constrain(x, mesh, *rspec)
    mask = None if cfg.attn_chunk else L.causal_mask(s)

    def body(carry, lp):
        h = L.rms_norm(carry, lp["ln1"], cfg.norm_eps)
        q, k, v = L.qkv(cfg, h, lp, positions)
        o = L.attention(cfg, mesh, axes, q, k, v, mask, mask_kind="causal")
        x = carry + jnp.einsum("bshe,hed->bsd", o, lp["wo"])
        h = L.rms_norm(x, lp["lnx"], cfg.norm_eps)
        xq = jnp.einsum("bsd,dhe->bshe", h, lp["xq"])
        xk = jnp.einsum("bsd,dhe->bshe", enc_out, lp["xk"])
        xv = jnp.einsum("bsd,dhe->bshe", enc_out, lp["xv"])
        o = L.attention(cfg, mesh, axes, xq, xk, xv, None)
        x = x + jnp.einsum("bshe,hed->bsd", o, lp["xo"])
        h = L.rms_norm(x, lp["ln2"], cfg.norm_eps)
        x = x + L.mlp_block(cfg, mesh, axes, h, lp)
        return constrain(x, mesh, *rspec), None

    if cfg.remat:
        body = jax.remat(body)
    if cfg.unroll:
        for i in range(cfg.n_layers):
            x, _ = body(x, jax.tree.map(lambda w: w[i], params["dec_layers"]))
    else:
        x, _ = jax.lax.scan(body, x, params["dec_layers"])
    return L.rms_norm(x, params["final_ln"], cfg.norm_eps)


def loss_fn(cfg: ArchConfig, mesh: Mesh):
    from repro.models.transformer import lm_loss

    def f(params, batch):
        enc_out = encode(cfg, mesh, params, batch["frames"])
        x = decode_train(cfg, mesh, params, batch["tokens"], enc_out)
        return lm_loss(cfg, mesh, params, x, batch["labels"])

    return f


# ------------------------------------------------------------------ decode
def cache_shapes(cfg: ArchConfig, batch: int, seq: int):
    kv, dh, nd = cfg.n_kv_heads, cfg.head_dim, cfg.n_layers
    return {
        "k": (nd, batch, seq, kv, dh),
        "v": (nd, batch, seq, kv, dh),
        "xk": (nd, batch, cfg.enc_ctx, kv, dh),
        "xv": (nd, batch, cfg.enc_ctx, kv, dh),
    }


def abstract_cache(cfg, batch, seq):
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s, cfg.dtype),
        cache_shapes(cfg, batch, seq),
        is_leaf=lambda s: isinstance(s, tuple),
    )


def init_cache(cfg, batch, seq):
    return jax.tree.map(
        lambda s: jnp.zeros(s, cfg.dtype),
        cache_shapes(cfg, batch, seq),
        is_leaf=lambda s: isinstance(s, tuple),
    )


def cache_specs(cfg: ArchConfig, axes: MeshAxes, batch: int, seq: int) -> dict:
    kv_tp = axes.tp(cfg.n_kv_heads)
    bsz = int(np.prod([axes.size(a) for a in axes.batch]))
    batch_ax = axes.batch if batch % bsz == 0 else None
    spec = P(None, batch_ax, None, kv_tp, None)
    return {"k": spec, "v": spec, "xk": spec, "xv": spec}


def decode_step(cfg: ArchConfig, mesh: Mesh):
    """One-token decoder step; cross-KV precomputed in the cache."""
    axes = MeshAxes.from_mesh(mesh)
    from repro.models.transformer import logits_from_hidden, _scatter_cache

    def f(params, cache, batch):
        token, pos = batch["token"], batch["pos"]
        x = params["emb"][token][:, None].astype(cfg.dtype)
        s_cache = cache["k"].shape[2]

        def body(carry, inp):
            x = carry
            lp, kc, vc, xk, xv = inp
            h = L.rms_norm(x, lp["ln1"], cfg.norm_eps)
            q, k, v = L.qkv(cfg, h, lp, pos[:, None])
            kc = _scatter_cache(kc, k, pos)
            vc = _scatter_cache(vc, v, pos)
            mask = jnp.arange(s_cache)[None, None, None, :] <= pos[:, None, None, None]
            o = L.attention(cfg, mesh, axes, q, kc, vc, mask)
            x = x + jnp.einsum("bshe,hed->bsd", o, lp["wo"])
            h = L.rms_norm(x, lp["lnx"], cfg.norm_eps)
            xq = jnp.einsum("bsd,dhe->bshe", h, lp["xq"])
            o = L.attention(cfg, mesh, axes, xq, xk, xv, None)
            x = x + jnp.einsum("bshe,hed->bsd", o, lp["xo"])
            h = L.rms_norm(x, lp["ln2"], cfg.norm_eps)
            x = x + L.mlp_block(cfg, mesh, axes, h, lp)
            return x, (kc, vc)

        if cfg.unroll:
            kcs, vcs = [], []
            for i in range(cfg.n_layers):
                lp = jax.tree.map(lambda w: w[i], params["dec_layers"])
                x, (kc, vc) = body(x, (lp, cache["k"][i], cache["v"][i], cache["xk"][i], cache["xv"][i]))
                kcs.append(kc), vcs.append(vc)
            kcs, vcs = jnp.stack(kcs), jnp.stack(vcs)
        else:
            x, (kcs, vcs) = jax.lax.scan(
                body, x, (params["dec_layers"], cache["k"], cache["v"], cache["xk"], cache["xv"])
            )
        x = L.rms_norm(x, params["final_ln"], cfg.norm_eps)
        logits = logits_from_hidden(cfg, mesh, params, x)[:, 0]
        return logits, {"k": kcs, "v": vcs, "xk": cache["xk"], "xv": cache["xv"]}

    return f


def prefill_cross_cache(cfg: ArchConfig, mesh: Mesh, params, frames, batch: int, seq: int):
    """Encode frames once and fill the cross-attention cache."""
    enc_out = encode(cfg, mesh, params, frames)
    xks, xvs = [], []
    # stacked per-layer projections (outside scan: one einsum over L)
    xk = jnp.einsum("bsd,ldhe->lbshe", enc_out, params["dec_layers"]["xk"])
    xv = jnp.einsum("bsd,ldhe->lbshe", enc_out, params["dec_layers"]["xv"])
    cache = init_cache(cfg, batch, seq)
    return dict(cache, xk=xk, xv=xv)


def train_input_specs(cfg: ArchConfig, mesh: Mesh, batch: int, seq: int):
    axes = MeshAxes.from_mesh(mesh)
    bspec = P(axes.batch, None)
    return {
        "frames": (
            jax.ShapeDtypeStruct((batch, cfg.enc_ctx, cfg.d_model), cfg.dtype),
            P(axes.batch, None, None),
        ),
        "tokens": (jax.ShapeDtypeStruct((batch, seq), jnp.int32), bspec),
        "labels": (jax.ShapeDtypeStruct((batch, seq), jnp.int32), bspec),
    }
