"""phi3-medium-14b [dense]: 40L d=5120 40H (GQA kv=10) d_ff=17920 vocab=100352
RoPE SwiGLU GQA [arXiv:2404.14219]."""

from repro.models.common import ArchConfig

FULL_ATTENTION = True  # long_500k skipped (quadratic attention)

CONFIG = ArchConfig(
    name="phi3-medium-14b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=40,
    n_kv_heads=10,
    d_ff=17920,
    vocab=100352,
    mlp="swiglu",
    rope_theta=10_000.0,
)

SMOKE = CONFIG.with_(
    name="phi3-medium-smoke", n_layers=2, d_model=128, n_heads=8, n_kv_heads=2,
    d_ff=256, vocab=512, remat=False,
)

SHAPES = {
    "train_4k": "run",
    "prefill_32k": "run",
    "decode_32k": "run",
    "long_500k": "skip:pure full attention — 500k dense KV is out of scope (DESIGN.md §Arch-applicability)",
}
