"""Public ops: packed halo-exchange buffers (Pallas on TPU, oracle elsewhere).

``halo_pack`` assembles one contiguous send buffer for a whole exchange
phase; ``halo_unpack`` delivers a received buffer into its halo/stage slots.
Together they replace the per-step gather/scatter chain of the historical
executor — see :mod:`repro.core.node_aware` (phase grouping) and the packed
executor in :mod:`repro.sparse.spmbv`.
"""

from __future__ import annotations

from repro.kernels.dispatch import resolve_dispatch
from repro.kernels.halo_pack.kernel import halo_pack_pallas, halo_unpack_pallas
from repro.kernels.halo_pack.ref import halo_pack_ref, halo_unpack_ref


def halo_pack(src, idx, use_pallas: bool | None = None):
    """Pack ``src[idx]`` into one contiguous (len(idx), w) phase buffer."""
    use_pallas, interpret = resolve_dispatch("halo_pack", use_pallas)
    if use_pallas:
        return halo_pack_pallas(src, idx, interpret=interpret)
    return halo_pack_ref(src, idx)


def halo_unpack(dst, buf, pos, use_pallas: bool | None = None):
    """Scatter a received phase buffer: ``dst.at[pos].set(buf)``."""
    use_pallas, interpret = resolve_dispatch("halo_unpack", use_pallas)
    if use_pallas:
        return halo_unpack_pallas(dst, buf, pos, interpret=interpret)
    return halo_unpack_ref(dst, buf, pos)
