"""Public op: fused ECG block updates (Pallas on TPU, oracle elsewhere)."""

from __future__ import annotations

import jax

from repro.kernels.block_update.kernel import block_update_pallas
from repro.kernels.block_update.ref import block_update_ref


def block_update(x, r, p, ap, c, use_pallas: bool | None = None, block_rows: int = 512):
    on_tpu = jax.default_backend() == "tpu"
    if use_pallas is None:
        use_pallas = on_tpu
    if use_pallas:
        return block_update_pallas(x, r, p, ap, c, block_rows=block_rows, interpret=not on_tpu)
    return block_update_ref(x, r, p, ap, c)
