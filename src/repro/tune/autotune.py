"""Model-driven joint selection of (strategy, tile shape, overlap).

All quantities are derived at setup time from the partitioned matrix — the
same host-side phase that builds the MPI-analogue communicator — so tuning
adds no device work:

* **Exchange strategy** — ``repro.core.models.t_p2p`` over the exact Table-1
  communication statistics of :class:`repro.core.comm_graph.CommGraph`,
  including the §4.3 nodal-optimal byte model.
* **Block-ELL tile** — for each candidate (br, bc), the block-structure
  histogram of the per-rank [own ‖ halo] CSR gives the stacked kernel's grid
  (nbr x kmax).  The model charges every stored tile, sublane-padded to the
  hardware's 8-element granularity, so it captures both failure modes: small
  tiles waste alignment padding, large tiles waste zero fill.
* **Overlap** — the busiest rank's nonzeros split into interior/boundary at
  block-row granularity; overlap wins when hiding the exchange behind the
  interior product (``max(T_int, T_exch) + T_bnd + overhead``) beats the
  blocking schedule (``T_exch + T_local``).

The selection is a joint argmin over the full (strategy x tile x overlap)
grid — the interaction matters because a faster exchange shrinks the window
the interior compute must cover.

Two exchange-cost models are selectable (``mode=``):

* ``"model"`` — the paper's analytic max-rate terms (eqs. 3.1–3.4, 4.2–4.4)
  over Table-1 message statistics.  Right on an MPI cluster whose
  :class:`MachineParams` are calibrated.
* ``"model:structural"`` — the *executor-structural* model: each strategy's
  actual :class:`~repro.core.node_aware.ExchangePlan` is compiled and charged
  ``dispatches × dispatch_overhead + wire_bytes/R_b + local_bytes/R_bl``.
  This is what the shard_map executor really costs on host/TPU backends,
  where ppermute is a memcpy/ICI hop and per-op dispatch overhead — not NIC
  injection — dominates; the max-rate model mis-ranks strategies there.
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.core.comm_graph import CommGraph, build_comm_graph
from repro.core.machines import MachineParams, TPU_V5E_POD
from repro.core.models import STRATEGIES, t_p2p
from repro.kernels.bsr_spmbv.ops import count_block_ell_tiles
from repro.sparse.partition import (
    PartitionedMatrix,
    interior_boundary_split,
    partition_csr,
)

#: Candidate Block-ELL tile shapes swept by default.  (8, 8) is the DG/FE
#: sweet spot; rectangular shapes trade MXU feed width against fill.
DEFAULT_TILES = ((4, 4), (8, 8), (16, 16), (8, 16), (16, 8), (32, 32))


def _pad8(x: int) -> int:
    """Sublane-align a tile dimension (8-element granularity on TPU)."""
    return -(-x // 8) * 8


@dataclasses.dataclass(frozen=True)
class TileStats:
    """Stacked-kernel geometry for one candidate (br, bc) tile shape."""

    br: int
    bc: int
    nbr: int   # block rows in the per-rank grid (rmax, padded)
    kmax: int  # tiles per block row the stacked layout must budget
    nnz: int   # true nonzeros of the busiest rank's local block

    @property
    def stored(self) -> int:
        """Elements the stacked kernel multiplies per rank, with each tile
        dimension sublane-padded — the zero-fill x alignment cost."""
        return self.nbr * self.kmax * _pad8(self.br) * _pad8(self.bc)

    @property
    def fill(self) -> float:
        """stored / nnz — 1.0 is a perfectly tiled matrix."""
        return self.stored / max(self.nnz, 1)


@dataclasses.dataclass(frozen=True)
class TunedConfig:
    """A jointly selected (strategy, tile, overlap) execution config."""

    strategy: str
    br: int
    bc: int
    kmax: int        # per-tile budget the Block-ELL stacking will use
    overlap: bool
    backend: str
    t: int
    mode: str        # "model" | "measure"
    col_split: int = 1  # §4.3 wide-halo split factor (nodal-optimal only)
    # the resolved MachineParams the decision was made with — forwarded to
    # the plan builder so the applied plan matches the modeled one
    machine: object = dataclasses.field(default=None, compare=False, repr=False)
    predicted: dict = dataclasses.field(
        default_factory=dict, compare=False, repr=False
    )
    # the TSelection when t itself was chosen by t="auto" (None otherwise)
    selection: object = dataclasses.field(default=None, compare=False, repr=False)

    @property
    def ell_block(self) -> tuple[int, int]:
        return (self.br, self.bc)

    def to_json(self) -> str:
        """Serialize to a JSON string (lossless round trip via
        :meth:`from_json`), so a tuned config can be cached on disk and fed
        back through ``SolverConfig(tune=TunedConfig.from_json(...))``
        without re-running the tuner.  The resolved ``machine`` parameters,
        the full ``predicted`` table, and a ``selection`` (when t itself was
        chosen by ``t="auto"``) all round-trip."""
        import json

        return json.dumps(tunedconfig_to_dict(self))

    @classmethod
    def from_json(cls, data) -> "TunedConfig":
        """Inverse of :meth:`to_json`; accepts the JSON string or the
        already-parsed dict."""
        import json

        if isinstance(data, (str, bytes)):
            data = json.loads(data)
        return tunedconfig_from_dict(data)


def _jsonify(obj):
    """Recursively convert numpy scalars / tuples to JSON-native values."""
    if isinstance(obj, dict):
        return {k: _jsonify(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_jsonify(v) for v in obj]
    if isinstance(obj, np.integer):
        return int(obj)
    if isinstance(obj, np.floating):
        return float(obj)
    if isinstance(obj, np.bool_):
        return bool(obj)
    return obj


def tunedconfig_to_dict(cfg: TunedConfig) -> dict:
    """JSON-safe dict form of a TunedConfig (see ``TunedConfig.to_json``)."""
    d = dict(
        strategy=cfg.strategy,
        br=int(cfg.br),
        bc=int(cfg.bc),
        kmax=int(cfg.kmax),
        overlap=bool(cfg.overlap),
        backend=cfg.backend,
        t=int(cfg.t),
        mode=cfg.mode,
        col_split=int(cfg.col_split),
        machine=(
            _jsonify(dataclasses.asdict(cfg.machine))
            if cfg.machine is not None else None
        ),
        predicted=_jsonify(cfg.predicted),
        selection=None,
    )
    if cfg.selection is not None:
        from repro.adaptive.select_t import tselection_to_dict

        d["selection"] = tselection_to_dict(cfg.selection)
    return d


def tunedconfig_from_dict(d: dict) -> TunedConfig:
    """Inverse of :func:`tunedconfig_to_dict`."""
    from repro.core.machines import MachineParams

    sel = d.get("selection")
    if sel is not None:
        from repro.adaptive.select_t import tselection_from_dict

        sel = tselection_from_dict(sel)
    m = d.get("machine")
    return TunedConfig(
        strategy=str(d["strategy"]),
        br=int(d["br"]),
        bc=int(d["bc"]),
        kmax=int(d["kmax"]),
        overlap=bool(d["overlap"]),
        backend=str(d["backend"]),
        t=int(d["t"]),
        mode=str(d["mode"]),
        col_split=int(d.get("col_split", 1)),
        machine=MachineParams(**m) if m is not None else None,
        predicted=d.get("predicted") or {},
        selection=sel,
    )


# --------------------------------------------------------------- tile model
def _rebased_local(pm: PartitionedMatrix):
    """Per-rank (indptr, indices, n_local) with halo columns rebased to rmax
    — exactly the operand ``make_distributed_spmbv`` converts to Block-ELL
    (same helper, so the layouts cannot drift apart)."""
    from repro.sparse.partition import rebased_local_csr

    return [(ptr, ix, n_local) for ptr, ix, _dat, n_local in rebased_local_csr(pm)]


def tile_stats(pm: PartitionedMatrix, br: int, bc: int) -> TileStats:
    """Block-structure histogram of the per-rank [own ‖ halo] blocks for one
    candidate tile shape; mirrors the stacked Block-ELL conversion, so
    ``TileStats.kmax`` equals the kmax ``make_distributed_spmbv`` will pad to.
    """
    rmax = pm.part.max_local_rows
    halo_max = max((len(h) for h in pm.halo_sources), default=0)
    n_cols = rmax + halo_max
    nbr = max(1, (rmax + br - 1) // br)
    kmax, nnz_max = 1, 0
    for ptr, ix, n_local in _rebased_local(pm):
        kmax = max(kmax, count_block_ell_tiles(ptr, ix, n_local, n_cols, br, bc))
        nnz_max = max(nnz_max, len(ix))
    return TileStats(br=br, bc=bc, nbr=nbr, kmax=kmax, nnz=nnz_max)


def tile_time(ts: TileStats, t: int, machine: MachineParams) -> float:
    """Modeled seconds for one local Block-ELL SpMBV on the busiest rank.

    Flop term: 2·stored·t at the machine's γ.  Memory term (when the machine
    declares ``R_mem``): one pass over the stored tiles, one (bc, t) slice of
    V per tile, one output write — the kernel's streaming traffic.
    """
    t_flop = machine.gamma * 2.0 * ts.stored * t
    if machine.R_mem:
        f = machine.f
        nbytes = (
            ts.stored * f
            + ts.nbr * ts.kmax * _pad8(ts.bc) * t * f
            + ts.nbr * _pad8(ts.br) * t * f
        )
        return max(t_flop, nbytes / machine.R_mem)
    return t_flop


def _csr_time(nnz_max: int, t: int, machine: MachineParams) -> float:
    """Modeled seconds for the scalar-gather CSR local SpMBV (jnp backend):
    2·nnz·t flops; per-nonzero traffic of one value, one int32 index, and one
    t-wide gathered row."""
    t_flop = machine.gamma * 2.0 * nnz_max * t
    if machine.R_mem:
        nbytes = nnz_max * (machine.f + 4 + t * machine.f)
        return max(t_flop, nbytes / machine.R_mem)
    return t_flop


# ------------------------------------------------------------ overlap model
def _interior_fraction(pm: PartitionedMatrix, block_row: int) -> float:
    """Interior share of the busiest rank's nonzeros under the block-row
    split the overlapped schedule will actually use.  Cached on the
    partition: the grid argmin probes each block_row many times and the
    split is O(p·nnz) host work."""
    cache = pm.__dict__.setdefault("_interior_frac_cache", {})
    if block_row in cache:
        return cache[block_row]
    io = interior_boundary_split(pm, block_row=block_row)
    worst_nnz, worst_frac = -1, 1.0
    for r, (int_rows, _bnd_rows) in enumerate(io):
        counts = np.diff(np.asarray(pm.local_indptr[r]))
        nnz = int(counts.sum())
        frac = float(counts[int_rows].sum()) / max(nnz, 1)
        if nnz > worst_nnz:
            worst_nnz, worst_frac = nnz, frac
    cache[block_row] = worst_frac
    return worst_frac


def _split_overhead(pm: PartitionedMatrix, t: int, machine: MachineParams) -> float:
    """Cost of the interior/boundary schedule itself: the output block vector
    is assembled through two scatter-adds instead of one contiguous write,
    plus one extra kernel-launch latency."""
    rmax = pm.part.max_local_rows
    extra = 2.0 * machine.alpha_l
    if machine.R_mem:
        extra += 2.0 * rmax * t * machine.f / machine.R_mem
    return extra


# ------------------------------------------------------- structural model
def structural_exchange_cost(
    plan, machine: MachineParams, width: int | None = None
) -> float:
    """Executor-structural seconds for one halo exchange of ``plan``.

    cost = dispatches × dispatch_overhead + wire_bytes/R_b + local_bytes/R_bl
    — the ROADMAP model of what the shard_map executor actually does: a
    fixed number of pack/ppermute/unpack ops (the packed executor's
    O(phases) dispatch count) plus the bytes they move.  ``width`` evaluates
    the byte terms at a reduced active width (``plan.at_width`` payloads).
    """
    disp = plan.dispatch_count(packed=True) * machine.dispatch_overhead
    wire = plan.wire_bytes(machine.f, width=width) / machine.R_b
    local = plan.local_bytes(machine.f, width=width) / machine.R_bl
    return disp + wire + local


def structural_exchange_costs(
    pm: PartitionedMatrix,
    t: int,
    machine: MachineParams,
    n_nodes: int,
    ppn: int,
    strategies=STRATEGIES,
) -> tuple[dict[str, float], dict]:
    """Compile each strategy's actual plan and charge the structural model.

    Returns ``(seconds per strategy, plans per strategy)`` — the plans are
    reused so the winning config's ``col_split`` matches what the builder
    will produce.
    """
    from repro.core.node_aware import build_exchange_plan

    plans = {
        s: build_exchange_plan(pm, n_nodes, ppn, s, t=t, machine=machine)
        for s in strategies
    }
    costs = {s: structural_exchange_cost(p, machine) for s, p in plans.items()}
    return costs, plans


# --------------------------------------------------------------- prediction
def predict_config(
    pm: PartitionedMatrix,
    g: CommGraph,
    t: int,
    machine: MachineParams,
    strategy: str,
    ts: TileStats,
    overlap: bool,
    backend: str = "pallas",
    t_exch: float | None = None,
) -> float:
    """Modeled seconds for one distributed SpMBV under a full config.

    ``t_exch`` overrides the exchange term (e.g. with the structural model's
    plan-derived cost); default is the analytic max-rate p2p model.
    """
    if t_exch is None:
        t_exch = t_p2p(g, t, machine, strategy)
    if backend == "pallas":
        t_local = tile_time(ts, t, machine)
        block_row = ts.br
    else:
        t_local = _csr_time(ts.nnz, t, machine)
        block_row = 1
    if not overlap:
        return t_exch + t_local
    frac = _interior_fraction(pm, block_row)
    t_int, t_bnd = t_local * frac, t_local * (1.0 - frac)
    return max(t_int, t_exch) + t_bnd + _split_overhead(pm, t, machine)


def _resolve_machine(
    machine: MachineParams | None, ppn: int, dtype: np.dtype | None
) -> MachineParams:
    machine = machine or TPU_V5E_POD
    updates: dict = {"ppn": ppn}
    if dtype is not None:
        updates["f"] = np.dtype(dtype).itemsize
    return dataclasses.replace(machine, **updates)


def tune(
    a,
    t: int,
    machine: MachineParams | None = None,
    n_nodes: int | None = None,
    ppn: int | None = None,
    *,
    pm: PartitionedMatrix | None = None,
    mesh=None,
    backend: str = "pallas",
    mode: str = "model",
    tiles=DEFAULT_TILES,
    dtype=None,
) -> TunedConfig:
    """Jointly select (strategy, tile shape, overlap) for ``a`` at width t.

    ``mode="model"`` is pure host work over the paper's analytic performance
    models; ``mode="model:structural"`` replaces the exchange term with the
    executor-structural model (compiles each strategy's actual plan and
    charges dispatches + moved bytes — the right ranking on host/TPU
    backends, see module docstring); ``mode="measure"`` times the candidate
    configs on ``mesh`` (required) with setup-time microbenchmarks — the
    calibration path when the machine constants are in doubt.  ``machine``
    defaults to the TPU-v5e parameter set; its byte width ``f`` is
    re-derived from the matrix dtype.
    """
    if mesh is not None and (n_nodes is None or ppn is None):
        n_nodes, ppn = mesh.devices.shape
    if n_nodes is None or ppn is None:
        raise ValueError("tune() needs a mesh or explicit (n_nodes, ppn)")
    p = n_nodes * ppn
    pm = pm or partition_csr(a, p)
    if dtype is None:
        dtype = pm.comms[0].dtype if pm.comms else None
    machine = _resolve_machine(machine, ppn, dtype)

    if mode == "measure":
        from repro.tune.microbench import tune_measured

        if mesh is None:
            raise ValueError('tune(mode="measure") needs a mesh to time on')
        return tune_measured(
            a, mesh, t, backend=backend, tiles=tiles, machine=machine, pm=pm
        )
    if mode not in ("model", "model:structural"):
        raise ValueError(f"unknown tune mode {mode!r}")
    structural = mode == "model:structural"

    g = build_comm_graph(pm, ppn=ppn)
    rmax = pm.part.max_local_rows
    if backend == "pallas":
        cand_tiles = [(br, bc) for br, bc in tiles if br <= rmax and bc <= rmax]
        cand_tiles = cand_tiles or [(8, 8)]
    else:
        cand_tiles = [(8, 8)]  # tile shape is irrelevant for the CSR backend
    stats = {tile: tile_stats(pm, *tile) for tile in cand_tiles}

    plans = None
    if structural:
        exch, plans = structural_exchange_costs(pm, t, machine, n_nodes, ppn)
    else:
        exch = {s: t_p2p(g, t, machine, s) for s in STRATEGIES}

    grid: dict[str, float] = {}
    best, best_time = None, math.inf
    for strategy in STRATEGIES:
        for tile in cand_tiles:
            for overlap in (False, True):
                sec = predict_config(
                    pm, g, t, machine, strategy, stats[tile], overlap,
                    backend, t_exch=exch[strategy],
                )
                grid[f"{strategy}/{tile[0]}x{tile[1]}/"
                     f"{'overlap' if overlap else 'blocking'}"] = sec
                if sec < best_time:
                    best, best_time = (strategy, tile, overlap), sec
    strategy, tile, overlap = best

    col_split = 1
    if strategy == "optimal":
        if plans is not None:
            col_split = plans["optimal"].col_split
        else:
            from repro.core.node_aware import _auto_col_split, to_node_rows

            col_split = _auto_col_split(to_node_rows(pm, ppn), t, machine, ppn)

    predicted = {
        "p2p": dict(exch),
        "local": {
            f"{br}x{bc}": tile_time(st, t, machine)
            for (br, bc), st in stats.items()
        },
        "grid": grid,
        "best": best_time,
    }
    if structural:
        predicted["plan_stats"] = {
            s: dict(
                dispatches=pl.dispatch_count(packed=True),
                wire_bytes=pl.wire_bytes(machine.f),
                local_bytes=pl.local_bytes(machine.f),
            )
            for s, pl in plans.items()
        }
    return TunedConfig(
        strategy=strategy,
        br=tile[0],
        bc=tile[1],
        kmax=stats[tile].kmax,
        overlap=overlap,
        backend=backend,
        t=t,
        mode=mode,
        col_split=col_split,
        machine=machine,
        predicted=predicted,
    )


# ------------------------------------------------- iteration-scheme ranking
def method_sync_cost(
    method: str,
    t: int,
    p: int,
    machine: MachineParams,
    *,
    s: int = 1,
    reorth: bool = False,
    t_spmbv_window: float = 0.0,
) -> float:
    """Synchronization seconds charged per *effective* iteration of a scheme.

    Reads the collective accounting the :class:`~repro.core.methods.
    MethodSpec` itself declares (psums per block, payload floats, iterations
    per block), so the cost model and the lowered-HLO gates in
    ``tests/dist_worker.py`` count the same collectives:

    * classic   — 2 psums of t² + 3t² floats; exactly the paper's eq. (3.1)
      collective term (``t_collective``), by construction.
    * pipelined — psum #1 (t²) stays on the critical path; psum #2 (3t²) is
      data-independent of the SpMBV, so only its spill past the exchange +
      interior-compute window (``t_spmbv_window``) is charged.
    * sstep     — 2 (+1 with reorth) psums of (st)²-sized payloads amortized
      over s iterations.
    """
    from repro.core.methods import get_method
    from repro.core.models import t_collective_n

    spec = get_method(method)
    if spec.overlaps_gram:
        hidden = t_collective_n(p, machine, 1, 3 * t * t)
        return t_collective_n(p, machine, 1, t * t) + max(
            0.0, hidden - t_spmbv_window
        )
    return t_collective_n(
        p, machine, spec.psums_per_block(s, reorth),
        spec.psum_payload_floats(t, s, reorth),
    ) / spec.iters_per_block(s)


def _method_local_flops(method: str, counts, *, s: int = 1, reorth: bool = False) -> float:
    """Non-SpMBV local flops per effective iteration of a scheme.

    classic is eq. (3.3) minus its SpMBV term; pipelined adds the AZ
    recurrence (two (t, t) products against (n/p, t) blocks); sstep charges
    the (st)-wide Gram/projection/factorization work of one block — the
    classic terms at width st, plus the two-block A-projection (four
    (n/p, st)·(st, st) products) and the wider fused gram1 — divided by s.
    """
    from repro.core.ecg import ECGOperationCounts

    base = counts.total_flops - counts.spmbv_flops
    npp = counts.n / counts.p
    if method == "classic":
        return base
    if method == "pipelined":
        return base + 4 * npp * counts.t**2
    if method == "sstep":
        st = s * counts.t
        wide = ECGOperationCounts(n=counts.n, nnz=counts.nnz, p=counts.p, t=st)
        per_block = (
            wide.total_flops - wide.spmbv_flops
            + 8 * npp * st**2  # V/AV -= P a + P₂ b  (two-block A-projection)
            + 2 * npp * st**2  # gram1 is (3st, st), not (st, st)
        )
        if reorth:
            per_block += 6 * npp * st**2  # second gram + two TRSMs
        return per_block / s
    raise ValueError(f"unknown method {method!r}")


def rank_methods(
    a,
    t: int,
    machine: MachineParams | None = None,
    n_nodes: int = 1,
    ppn: int = 1,
    *,
    s: int = 2,
    reorth: bool = False,
    pm: PartitionedMatrix | None = None,
    backend: str = "jnp",
    mode: str = "model:structural",
    methods: tuple[str, ...] = ("classic", "pipelined", "sstep"),
) -> tuple[str, dict[str, dict[str, float]]]:
    """Rank the iteration schemes by modeled per-effective-iteration seconds.

    Runs :func:`tune` once for the SpMBV term (exchange + local product under
    the winning (strategy, tile, overlap) config — also the overlap window
    the pipelined scheme hides its packed Gram reduction in), then charges
    each scheme its :func:`method_sync_cost` and :func:`_method_local_flops`.
    Returns ``(best, table)`` with per-method ``{sync_s, spmbv_s, local_s,
    iter_s, s}`` rows.  The ranking is per effective iteration: convergence
    per iteration is method-independent to first order (all three schemes
    walk the same enlarged Krylov space), so the cheapest iteration wins —
    the caveat being s-step's slightly weaker A-orthogonality at large s.
    """
    from repro.core.ecg import ECGOperationCounts

    tuned = tune(
        a, t, machine=machine, n_nodes=n_nodes, ppn=ppn, pm=pm,
        backend=backend, mode=mode,
    )
    machine = tuned.machine
    p = n_nodes * ppn
    counts = ECGOperationCounts(n=a.shape[0], nnz=a.nnz, p=p, t=t)
    spmbv_s = float(tuned.predicted["best"])
    table: dict[str, dict[str, float]] = {}
    for m in methods:
        ms = s if m == "sstep" else 1
        mro = reorth if m == "sstep" else False
        sync = method_sync_cost(
            m, t, p, machine, s=ms, reorth=mro, t_spmbv_window=spmbv_s
        )
        local = machine.gamma * _method_local_flops(m, counts, s=ms, reorth=mro)
        table[m] = dict(
            sync_s=sync, spmbv_s=spmbv_s, local_s=local,
            iter_s=sync + spmbv_s + local, s=ms,
        )
    best = min(table, key=lambda m: table[m]["iter_s"])
    return best, table
