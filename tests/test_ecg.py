"""ECG solver: convergence, CG equivalence, algorithmic invariants."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import cg_solve, ecg_solve, split_residual, collapse
from repro.core.ecg import ECGOperationCounts, _chol_inv_apply
from repro.sparse import dg_laplace_2d, fd_laplace_2d, random_spd, csr_spmv, csr_spmbv


@pytest.fixture(scope="module")
def system(rng=np.random.default_rng(0)):
    a = dg_laplace_2d((10, 10), block=8)  # 800 rows
    b = jnp.asarray(rng.standard_normal(a.shape[0]))
    return a, b


class TestSplitting:
    @given(
        n=st.integers(8, 200),
        t=st.integers(1, 12),
        mapping=st.sampled_from(["contiguous", "round_robin"]),
        seed=st.integers(0, 2**16),
    )
    @settings(max_examples=40, deadline=None)
    def test_row_sum_preserved(self, n, t, mapping, seed):
        # eq (2.3): r == sum_i (T_{r,t})_i
        t = min(t, n)
        r = jnp.asarray(np.random.default_rng(seed).standard_normal(n))
        big = split_residual(r, t, mapping)
        assert big.shape == (n, t)
        np.testing.assert_allclose(np.asarray(collapse(big)), np.asarray(r), atol=1e-12)

    def test_columns_linearly_independent(self):
        r = jnp.asarray(np.random.default_rng(1).standard_normal(64) + 0.5)
        big = np.asarray(split_residual(r, 8))
        assert np.linalg.matrix_rank(big) == 8


class TestECG:
    def test_cg_converges(self, system):
        a, b = system
        res = cg_solve(lambda v: csr_spmv(a, v), b, tol=1e-9, max_iters=3000)
        assert res.converged
        d = np.asarray(a.todense(), np.float64)
        relres = np.linalg.norm(d @ np.asarray(res.x) - np.asarray(b)) / np.linalg.norm(b)
        assert relres < 1e-7

    @pytest.mark.parametrize("t", [2, 4, 8])
    def test_ecg_converges_and_solution_correct(self, system, t):
        a, b = system
        res = ecg_solve(lambda V: csr_spmbv(a, V), b, t=t, tol=1e-9, max_iters=3000)
        assert res.converged
        d = np.asarray(a.todense(), np.float64)
        relres = np.linalg.norm(d @ np.asarray(res.x) - np.asarray(b)) / np.linalg.norm(b)
        assert relres < 1e-7

    def test_ecg_t1_equals_cg_iterates(self, system):
        """ECG with t=1 spans the same Krylov space as CG -> same iterates."""
        a, b = system
        res_cg = cg_solve(lambda v: csr_spmv(a, v), b, tol=1e-9, max_iters=3000)
        res_ecg = ecg_solve(lambda V: csr_spmbv(a, V), b, t=1, tol=1e-9, max_iters=3000)
        assert abs(res_cg.n_iters - res_ecg.n_iters) <= 1
        np.testing.assert_allclose(
            np.asarray(res_cg.x), np.asarray(res_ecg.x), rtol=1e-5, atol=1e-7
        )

    def test_iterations_decrease_with_t(self, system):
        """Paper Fig 3.2: enlarging reduces iterations monotonically (weakly)."""
        a, b = system
        iters = []
        for t in (1, 2, 4, 8, 16):
            res = ecg_solve(lambda V: csr_spmbv(a, V), b, t=t, tol=1e-8, max_iters=3000)
            assert res.converged
            iters.append(res.n_iters)
        assert all(iters[i + 1] <= iters[i] for i in range(len(iters) - 1)), iters
        assert iters[-1] < iters[0]

    def test_residual_history_monotone_tail(self, system):
        a, b = system
        res = ecg_solve(lambda V: csr_spmbv(a, V), b, t=4, tol=1e-8, max_iters=3000)
        h = np.asarray(res.res_hist)
        h = h[~np.isnan(h)]
        assert h[-1] <= 1e-8 * 10
        # overall decay by orders of magnitude
        assert h[-1] < h[0] * 1e-6

    def test_random_spd_system(self):
        a = random_spd(96, density=0.1, seed=5)
        rng = np.random.default_rng(2)
        b = jnp.asarray(rng.standard_normal(96))
        res = ecg_solve(lambda V: csr_spmbv(a, V), b, t=6, tol=1e-10, max_iters=500)
        assert res.converged
        d = np.asarray(a.todense(), np.float64)
        assert np.linalg.norm(d @ np.asarray(res.x) - np.asarray(b)) < 1e-6


class TestBackendSwitch:
    def test_pallas_backend_matches_jnp(self, system):
        """The kernel-routed solver (backend="pallas") must reproduce the jnp
        path: same iterate count, same solution to solver accuracy."""
        from repro.kernels import make_block_ell_apply

        a, b = system
        res_jnp = ecg_solve(lambda V: csr_spmbv(a, V), b, t=4, tol=1e-9, max_iters=3000)
        res_pal = ecg_solve(
            make_block_ell_apply(a, block=8), b, t=4, tol=1e-9, max_iters=3000,
            backend="pallas",
        )
        assert res_pal.converged
        assert res_pal.n_iters == res_jnp.n_iters
        assert np.abs(np.asarray(res_pal.x) - np.asarray(res_jnp.x)).max() < 1e-7

    def test_initial_residual_width1(self, system):
        """_apply_vec must hit the operator with a width-1 block (the cheap
        SpMV), not a zero-padded (n, t) block."""
        from repro.core.ecg import _apply_vec

        a, b = system
        seen = []

        def spy(v):
            seen.append(v.shape)
            return csr_spmbv(a, v)

        out = _apply_vec(spy, b, 8)
        assert seen == [(a.shape[0], 1)]
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(csr_spmv(a, b)), atol=1e-12
        )


class TestAOrthonormalization:
    def test_p_is_a_orthonormal(self, system):
        """Line 5 of Alg 1: P = Z(ZᵀAZ)^{-1/2}  =>  PᵀAP = I."""
        a, b = system
        rng = np.random.default_rng(3)
        z = jnp.asarray(rng.standard_normal((a.shape[0], 5)))
        az = csr_spmbv(a, z)
        g = z.T @ az
        p, ap = _chol_inv_apply(g, z, az)
        ptap = np.asarray(p.T @ csr_spmbv(a, p))
        np.testing.assert_allclose(ptap, np.eye(5), atol=1e-8)
        # AP really is A @ P (the TRSM shortcut of Alg 2)
        np.testing.assert_allclose(np.asarray(ap), np.asarray(csr_spmbv(a, p)), atol=1e-8)


class TestOperationCounts:
    def test_eq_3_3_totals(self):
        c = ECGOperationCounts(n=1000, nnz=9000, p=10, t=4)
        expected = (2 + 8) * 900 + (16 + 64) * 100 + 16 / 2 + 64 / 6
        assert c.total_flops == pytest.approx(expected)

    def test_allreduce_payloads(self):
        c = ECGOperationCounts(n=10, nnz=10, p=1, t=7)
        assert c.allreduce_payload_floats == (49, 147)
