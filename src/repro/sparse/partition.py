"""Row-wise partitioning of CSR matrices + communication-graph extraction.

Mirrors the paper's setup (§3): the n x n matrix is partitioned row-wise
across p processes, contiguous rows per process; vectors share the row
distribution.  The local matrix splits into *on-process* and *off-process*
blocks (§2.2, Fig 2.2); the off-process block induces the point-to-point
communication pattern (who needs which remote vector rows).

All of this is host-side numpy — it is the moral equivalent of the MPI
communicator setup phase, executed once.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.sparse.csr import CSRMatrix


@dataclasses.dataclass(frozen=True)
class RowPartition:
    """Contiguous row partition of n rows over p processes."""

    n: int
    p: int

    def __post_init__(self):
        assert self.p >= 1

    @property
    def starts(self) -> np.ndarray:
        # paper: "each process contains at most ceil(n/p) contiguous rows"
        base, rem = divmod(self.n, self.p)
        counts = np.full(self.p, base, dtype=np.int64)
        counts[:rem] += 1
        return np.concatenate([[0], np.cumsum(counts)])

    def owner_of(self, rows: np.ndarray) -> np.ndarray:
        return np.searchsorted(self.starts, rows, side="right") - 1

    def local_range(self, rank: int) -> tuple[int, int]:
        s = self.starts
        return int(s[rank]), int(s[rank + 1])

    @property
    def max_local_rows(self) -> int:
        s = self.starts
        return int(np.max(np.diff(s)))


@dataclasses.dataclass
class ProcessComm:
    """Per-process communication metadata for the halo exchange.

    recv_rows[q]: global row ids this process needs from process q
    send_rows[q]: global row ids this process must send to process q
    """

    rank: int
    recv_rows: dict[int, np.ndarray]
    send_rows: dict[int, np.ndarray]
    dtype: np.dtype = np.dtype(np.float64)  # value dtype of the matrix/vectors

    @property
    def n_recv_msgs(self) -> int:
        return len(self.recv_rows)

    @property
    def n_send_msgs(self) -> int:
        return len(self.send_rows)

    def send_bytes(self, t: int = 1, f: int | None = None) -> int:
        """Total bytes this process sends for a block vector of width t.

        ``f`` (bytes per scalar) defaults to the itemsize of the partitioned
        matrix's value dtype, so f32 solves are not billed at f64 rates.
        """
        f = self.dtype.itemsize if f is None else f
        return sum(len(v) for v in self.send_rows.values()) * t * f


@dataclasses.dataclass
class PartitionedMatrix:
    """A CSR matrix partitioned row-wise with halo-exchange metadata."""

    a: CSRMatrix
    part: RowPartition
    comms: list[ProcessComm]
    # per-rank local CSR pieces (numpy views over the global CSR):
    local_indptr: list[np.ndarray]
    local_indices: list[np.ndarray]  # remapped: [0, n_local) local, >= n_local halo
    local_data: list[np.ndarray]
    halo_sources: list[np.ndarray]  # global row ids backing the halo slots, ordered

    @property
    def p(self) -> int:
        return self.part.p


def interior_boundary_split(
    pm: "PartitionedMatrix", block_row: int = 1
) -> list[tuple[np.ndarray, np.ndarray]]:
    """Per rank, (interior_rows, boundary_rows) — local row ids in [0, n_local).

    A row is *interior* when every nonzero column is on-process (< n_local in
    the remapped local ids), i.e. its SpMBV output never waits on the halo
    exchange.  Boundary rows are the rest.  This is the static analysis
    behind the comm/compute-overlap schedule in ``repro.sparse.spmbv``: the
    interior SpMBV is issued with no data dependence on the exchange rounds,
    so it runs while the inter-node messages are in flight.

    ``block_row > 1`` classifies whole *block rows* (groups of ``block_row``
    consecutive local rows, aligned to local row 0): a block row is boundary
    as soon as any of its rows touches the halo.  This keeps the split
    aligned with the Block-ELL tile rows the ``backend="pallas"`` path just
    built, so gathering the interior/boundary subsets never re-fragments
    tiles (ROADMAP: block-row-granularity split).  The two sets still
    partition [0, n_local) exactly; the block-row split is a conservative
    coarsening of the row split (interior ⊆ row-granular interior).
    """
    out = []
    for r in range(pm.p):
        lo, hi = pm.part.local_range(r)
        n_local = hi - lo
        ptr = np.asarray(pm.local_indptr[r])
        ix = np.asarray(pm.local_indices[r])
        has_halo = np.zeros(n_local, dtype=bool)
        rows_of_nnz = np.repeat(np.arange(n_local, dtype=np.int64), np.diff(ptr))
        np.logical_or.at(has_halo, rows_of_nnz, ix >= n_local)
        if block_row > 1 and n_local:
            blocks = np.arange(n_local) // block_row
            block_has_halo = np.zeros(int(blocks[-1]) + 1, dtype=bool)
            np.logical_or.at(block_has_halo, blocks, has_halo)
            has_halo = block_has_halo[blocks]
        out.append((np.nonzero(~has_halo)[0], np.nonzero(has_halo)[0]))
    return out


def rebased_local_csr(
    pm: "PartitionedMatrix",
) -> list[tuple[np.ndarray, np.ndarray, np.ndarray, int]]:
    """Per rank, (indptr, indices, data, n_local) with halo columns rebased
    from n_local-relative to rmax-relative ids — the [own ‖ halo] operand
    layout the distributed executor pads vectors to, shared by the Block-ELL
    conversion in ``repro.sparse.spmbv`` and the tile cost model in
    ``repro.tune`` (which must see the exact same layout)."""
    rmax = pm.part.max_local_rows
    out = []
    for r in range(pm.p):
        lo, hi = pm.part.local_range(r)
        n_local = hi - lo
        ix = np.asarray(pm.local_indices[r], dtype=np.int64)
        ix = np.where(ix >= n_local, ix - n_local + rmax, ix)
        out.append(
            (np.asarray(pm.local_indptr[r]), ix, np.asarray(pm.local_data[r]), n_local)
        )
    return out


def partition_csr(a: CSRMatrix, p: int) -> PartitionedMatrix:
    """Partition ``a`` row-wise over p processes; extract comm graph.

    The halo (off-process) columns of each local block are remapped to local
    ids ``n_local + k`` where k indexes the (sorted, deduplicated) remote rows
    this process receives — the standard "ghost" layout.
    """
    indptr = np.asarray(a.indptr, dtype=np.int64)
    indices = np.asarray(a.indices, dtype=np.int64)
    data = np.asarray(a.data)
    part = RowPartition(a.shape[0], p)
    starts = part.starts

    # recv side: per rank, remote rows needed
    recv_rows_per_rank: list[dict[int, np.ndarray]] = []
    halo_sources: list[np.ndarray] = []
    local_indptr, local_indices, local_data = [], [], []
    for r in range(p):
        lo, hi = starts[r], starts[r + 1]
        s, e = indptr[lo], indptr[hi]
        cols = indices[s:e]
        vals = data[s:e]
        lptr = indptr[lo : hi + 1] - s
        off_mask = (cols < lo) | (cols >= hi)
        remote = np.unique(cols[off_mask])
        owners = part.owner_of(remote)
        recv: dict[int, np.ndarray] = {}
        for q in np.unique(owners):
            recv[int(q)] = remote[owners == q]
        recv_rows_per_rank.append(recv)
        halo_sources.append(remote)  # sorted by global id

        # remap columns: local -> [0, n_local); remote -> n_local + halo slot
        n_local = hi - lo
        remap = np.empty(len(cols), dtype=np.int32)
        remap[~off_mask] = (cols[~off_mask] - lo).astype(np.int32)
        remap[off_mask] = (n_local + np.searchsorted(remote, cols[off_mask])).astype(np.int32)
        local_indptr.append(lptr.astype(np.int64))
        local_indices.append(remap)
        local_data.append(vals)

    # send side: transpose the recv graph
    send_rows_per_rank: list[dict[int, np.ndarray]] = [dict() for _ in range(p)]
    for r in range(p):
        for q, rows in recv_rows_per_rank[r].items():
            send_rows_per_rank[q][r] = rows

    val_dtype = np.dtype(np.asarray(data).dtype)
    comms = [
        ProcessComm(
            rank=r,
            recv_rows=recv_rows_per_rank[r],
            send_rows=send_rows_per_rank[r],
            dtype=val_dtype,
        )
        for r in range(p)
    ]
    return PartitionedMatrix(
        a=a,
        part=part,
        comms=comms,
        local_indptr=local_indptr,
        local_indices=local_indices,
        local_data=local_data,
        halo_sources=halo_sources,
    )
