"""Preconditioned + flexible ECG: config, operators, and builders.

See :mod:`repro.precondition.config` for the knobs and
``docs/preconditioning.md`` for the criterion, the flexible-ECG interaction
with the adaptive controller, and the cost-model notes.
"""

from repro.precondition.build import (
    build_distributed_preconditioner,
    build_sequential_preconditioner,
)
from repro.precondition.chebyshev import estimate_lambda_max, make_chebyshev_apply
from repro.precondition.config import PRECONDITIONS, PreconditionConfig

__all__ = [
    "PRECONDITIONS",
    "PreconditionConfig",
    "build_sequential_preconditioner",
    "build_distributed_preconditioner",
    "estimate_lambda_max",
    "make_chebyshev_apply",
]
