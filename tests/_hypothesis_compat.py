"""Hypothesis compatibility shim for the test suite.

The tier-1 suite must collect and run everywhere, including containers that
do not ship ``hypothesis``.  When the real library is available we re-export
``given`` / ``settings`` / ``st`` untouched; otherwise we provide a small
deterministic fallback: each strategy exposes a fixed list of representative
examples (endpoints + midpoint) and ``@given`` runs the test body over the
(capped) cartesian product of those examples.  This keeps the property tests
meaningful — boundary values are always exercised — while adding zero
dependencies.
"""

from __future__ import annotations

try:  # pragma: no cover - exercised only when hypothesis is installed
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    import inspect
    import itertools

    HAVE_HYPOTHESIS = False
    _MAX_COMBOS = 24

    class _Strategy:
        """A fixed, deterministic set of example values."""

        def __init__(self, examples):
            self.examples = list(examples)

    class _strategies:
        @staticmethod
        def integers(min_value, max_value):
            mid = (min_value + max_value) // 2
            return _Strategy(sorted({min_value, mid, max_value}))

        @staticmethod
        def floats(min_value, max_value):
            mid = (min_value + max_value) / 2
            return _Strategy(sorted({min_value, mid, max_value}))

        @staticmethod
        def sampled_from(elements):
            return _Strategy(elements)

        @staticmethod
        def booleans():
            return _Strategy([False, True])

    st = _strategies()

    def settings(*_args, **_kwargs):
        def deco(fn):
            return fn

        return deco

    def given(**strategy_kwargs):
        keys = list(strategy_kwargs)
        pools = [strategy_kwargs[k].examples for k in keys]
        combos = list(itertools.product(*pools))
        if len(combos) > _MAX_COMBOS:
            stride = (len(combos) + _MAX_COMBOS - 1) // _MAX_COMBOS
            combos = combos[::stride][:_MAX_COMBOS]

        def deco(fn):
            def wrapper(*args, **kwargs):
                for combo in combos:
                    fn(*args, **{**kwargs, **dict(zip(keys, combo))})

            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            # hide the strategy parameters from pytest's fixture resolution
            sig = inspect.signature(fn)
            wrapper.__signature__ = sig.replace(
                parameters=[p for n, p in sig.parameters.items() if n not in keys]
            )
            return wrapper

        return deco
