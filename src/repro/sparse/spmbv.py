"""Distributed SpMBV:  W = A · V  with node-aware halo exchange (shard_map).

The matrix is row-partitioned over a ("node", "proc") device grid; block
vectors share the row distribution (paper §3).  The halo exchange replays a
static :class:`~repro.core.node_aware.ExchangePlan` — then the local SpMBV
runs on [own rows ‖ halo rows].

The executor is *phase-packed*: the plan's steps are grouped into phases
(consecutive rounds sharing axis/src/dst, see ``ExchangePlan.phases``), and
each phase is executed as ONE ``halo_pack`` kernel (a fused gather into a
contiguous, persistent send-buffer layout), one ``lax.ppermute`` per
nonzero rotation offset, and ONE ``halo_unpack`` kernel (fused scatter into
the halo/stage slots).  Gather/scatter dispatches are therefore O(phases)
instead of O(steps), and the ppermute payload is exactly the packed bytes.

The executor is also *width-aware*: ``matvec_fn(t_active=...)`` applies the
operator at a reduced block width through ``plan.at_width(t_active)`` — the
per-width index arrays are re-sliced on the host (cheap, cached) and the
wire payload shrinks to ``t_active·rows·f`` bytes.  The adaptive solver
uses this to stop paying full-width exchange bytes for retired search
directions (see ``distributed_ecg``).

Three orthogonal execution levers, all fixed at setup time (and all
selectable by the :mod:`repro.tune` autotuner via ``tune="model"|"measure"``
instead of by hand):

* ``backend="jnp" | "pallas"`` — the local SpMBV formulation.  ``jnp`` is the
  scalar-gather CSR ``segment_sum`` reference; ``pallas`` converts each
  rank's local [own ‖ halo] CSR block to Block-ELL once (see
  ``repro.kernels.bsr_spmbv``) so every local product is a pipeline of dense
  (br x bc) @ (bc x t) MXU matmuls.  The one-time conversion cost is
  O(nnz log nnz) host work plus a kmax/nnz_tile densification factor in
  device memory — amortized over all solver iterations.
* ``ell_block=(br, bc)`` — the Block-ELL tile shape for the pallas backend.
  The right shape trades zero-fill flops against MXU/sublane utilization and
  depends on t and the matrix's block structure; the tuner picks it from the
  block-structure histogram (see ``repro.tune``).
* ``overlap=True`` — comm/compute overlap.  At partition time local rows are
  split into *interior* rows (no halo-column dependence) and *boundary* rows
  (see :func:`repro.sparse.partition.interior_boundary_split`; with the
  pallas backend the split is block-row-granular so it never re-fragments
  the tiles).  The device program then issues the interior SpMBV with **no
  data dependence on the ppermute rounds**, so XLA's latency-hiding
  scheduler can run it while the inter-node messages of the ExchangePlan are
  in flight; only the boundary rows wait on the halo.  This is the
  node-aware analogue of the paper's pipeline: the exchange latency is
  hidden behind |interior|/|local| of the SpMBV flops.

Col-split plans (wide-halo payload splitting, nodal-optimal strategy) are
transparent here: the executor reshapes ``(rmax, t) -> (rmax·cs, t/cs)``
around the exchange rounds and reassembles whole halo rows afterwards — see
``repro.core.node_aware``.

This module also provides the distributed ECG wrapper: the same iteration
body as :func:`repro.core.ecg.ecg_solve` with `psum` reductions, executed
entirely inside one shard_map (so the two fused allreduces of §3.1 appear as
exactly two psums per iteration in the lowered HLO).  With
``backend="pallas"`` the packed gram product runs through
``kernels/fused_gram`` and the X/R/Z tail through
``kernels/block_update.ecg_tail`` — per-device Pallas kernels feeding the
same two psums.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.sparse.csr import CSRMatrix
from repro.sparse.partition import (
    PartitionedMatrix,
    interior_boundary_split,
    partition_csr,
    rebased_local_csr,
)
from repro.core.node_aware import ExchangePlan, build_exchange_plan
from repro.kernels.bsr_spmbv.ops import (
    bsr_spmbv,
    count_block_ell_tiles,
    csr_arrays_to_block_ell,
)
from repro.kernels.halo_pack.ops import halo_pack, halo_unpack


@dataclasses.dataclass
class DistributedSpMBV:
    """Device-ready distributed SpMBV operator.

    ``backend`` selects the local SpMBV formulation (CSR segment-sum vs the
    Block-ELL Pallas kernel); ``overlap`` selects the split interior/boundary
    schedule that hides the halo exchange behind interior compute.  The
    corresponding device arrays live in ``ell`` (pallas, blocking) and
    ``split`` (either backend, overlapped); see ``make_distributed_spmbv``.
    """

    mesh: Mesh
    plan: ExchangePlan
    n: int                 # true global rows
    rmax: int              # padded rows per device
    starts: np.ndarray     # (p+1,) partition row offsets (true global ids)
    # stacked per-device CSR (sharded on axis 0 at call time); None when the
    # selected (backend, overlap) mode never reads it — only the matrix
    # representation the device program actually consumes is device_put
    indptr: jax.Array | None   # (p, rmax + 1)
    indices: jax.Array | None  # (p, nnz_max) — local ids; halo ids offset by rmax
    data: jax.Array | None     # (p, nnz_max)
    # stacked per-PHASE exchange arrays (packed executor) at the compiled width
    gathers: list[jax.Array]
    scatters: list[jax.Array]
    backend: str = "jnp"
    overlap: bool = False
    ell_block: int | tuple[int, int] = 8  # Block-ELL tile shape (br, bc)
    # pallas blocking path: Block-ELL of the full [own ‖ halo] local block
    ell: dict = dataclasses.field(default_factory=dict)
    # overlap path: interior/boundary structures (CSR or Block-ELL per backend)
    split: dict = dataclasses.field(default_factory=dict)
    # TunedConfig when the operator was built via tune= (None otherwise)
    tuned: object = None
    # per-width device index arrays, filled on demand by width re-slices
    _width_arrays: dict = dataclasses.field(default_factory=dict)

    @property
    def p(self) -> int:
        return self.plan.p

    @property
    def n_padded(self) -> int:
        return self.p * self.rmax

    # ---------------------------------------------------------------- spec
    @property
    def vec_spec(self) -> P:
        return P(("node", "proc"), None)

    def shard_vector(self, v: np.ndarray | jax.Array, t: int | None = None) -> jax.Array:
        """Lay out a global (n,) or (n, t) array into the padded per-rank
        layout (device r's block holds its partition rows) and device_put."""
        v = np.asarray(v)
        out = np.zeros((self.p * self.rmax,) + v.shape[1:], v.dtype)
        for r in range(self.p):
            lo, hi = self.starts[r], self.starts[r + 1]
            out[r * self.rmax : r * self.rmax + (hi - lo)] = v[lo:hi]
        spec = self.vec_spec if v.ndim > 1 else P(("node", "proc"))
        return jax.device_put(out, NamedSharding(self.mesh, spec))

    def unshard(self, w: jax.Array) -> np.ndarray:
        """Inverse of :meth:`shard_vector`."""
        w = np.asarray(w)
        out = np.zeros((self.n,) + w.shape[1:], w.dtype)
        for r in range(self.p):
            lo, hi = self.starts[r], self.starts[r + 1]
            out[lo:hi] = w[r * self.rmax : r * self.rmax + (hi - lo)]
        return out

    def padded_mask(self) -> np.ndarray:
        """(n_padded,) 1.0 where the slot backs a true row."""
        m = np.zeros(self.p * self.rmax)
        for r in range(self.p):
            lo, hi = self.starts[r], self.starts[r + 1]
            m[r * self.rmax : r * self.rmax + (hi - lo)] = 1.0
        return m

    def true_row_of_slot(self) -> np.ndarray:
        """(n_padded,) true global row id per padded slot (-1 for pads)."""
        m = np.full(self.p * self.rmax, -1, dtype=np.int64)
        for r in range(self.p):
            lo, hi = self.starts[r], self.starts[r + 1]
            m[r * self.rmax : r * self.rmax + (hi - lo)] = np.arange(lo, hi)
        return m

    # ------------------------------------------------------------- exchange
    def _exchange(self, x_local: jax.Array, plan: ExchangePlan, gathers, scatters) -> jax.Array:
        """Per-device packed halo exchange.  x_local: (rmax, t) block rows;
        returns the halo block in row units, (plan.halo_rows, t).

        One ``halo_pack`` + ``halo_unpack`` pair per *phase* (fused gather/
        scatter over all of the phase's rounds), one ppermute per nonzero
        rotation offset operating on a static slice of the packed buffer.

        Col-split plans index (row, column-segment) slots: the executor
        reshapes ``(rmax, t) -> (rmax·cs, t/cs)`` around the rounds (padding
        t up to a multiple of cs when the applied width differs from the
        width the plan was sliced for, e.g. the width-1 initial residual)."""
        t = x_local.shape[-1]
        cs = plan.col_split
        if cs > 1:
            tp = -(-t // cs) * cs
            if tp != t:
                x_local = jnp.pad(x_local, ((0, 0), (0, tp - t)))
            xs = x_local.reshape(self.rmax * cs, tp // cs)
        else:
            xs = x_local
        w = xs.shape[-1]
        halo = jnp.zeros((plan.halo_size + 1, w), x_local.dtype)
        stage = jnp.zeros((plan.stage_size + 1, w), x_local.dtype)
        for phase, g_idx, s_pos in zip(plan.phases, gathers, scatters):
            src = xs if phase.src == "x" else stage
            buf = halo_pack(src, g_idx)  # (phase.width, w) — one dispatch
            if any(phase.offsets):
                axis = ("node", "proc") if phase.axis == "flat" else phase.axis
                parts = []
                for i, off in enumerate(phase.offsets):
                    seg = buf[phase.bounds[i] : phase.bounds[i + 1]]
                    if off:
                        seg = jax.lax.ppermute(
                            seg, axis, _perm(phase.axis, off, plan)
                        )
                    parts.append(seg)
                buf = jnp.concatenate(parts) if len(parts) > 1 else parts[0]
            if phase.dst == "halo":
                halo = halo_unpack(halo, buf, s_pos)
            else:
                stage = halo_unpack(stage, buf, s_pos)
        halo = halo[: plan.halo_size]
        if cs > 1:
            halo = halo.reshape(plan.halo_rows, -1)[:, :t]
        return halo

    # -------------------------------------------------------- local kernels
    def _csr_rows_spmbv(self, xfull, indptr, indices, data, n_rows: int):
        """CSR SpMBV over a (possibly gathered) row set; returns (n_rows, t)."""
        rows = jnp.repeat(
            jnp.arange(n_rows, dtype=jnp.int32),
            jnp.diff(indptr),
            total_repeat_length=indices.shape[0],
        )
        prod = data[:, None] * xfull[indices]
        return jax.ops.segment_sum(prod, rows, num_segments=n_rows)

    def _local_spmbv(self, x_local, halo, indptr, indices, data):
        """CSR SpMBV over [own ‖ halo] rows; returns (rmax, t)."""
        xfull = jnp.concatenate([x_local, halo], axis=0)
        return self._csr_rows_spmbv(xfull, indptr, indices, data, self.rmax)

    def _ell_spmbv(self, xfull, blocks, indices):
        """Block-ELL SpMBV; pads xfull to the tile grid the blocks index."""
        bc = blocks.shape[-1]
        m_pad = (xfull.shape[0] + bc - 1) // bc * bc
        vp = jnp.pad(xfull, ((0, m_pad - xfull.shape[0]), (0, 0)))
        return bsr_spmbv(blocks, indices, vp)

    # ------------------------------------------------- width-sliced arrays
    def exchange_arrays(self, plan: ExchangePlan):
        """Stacked per-phase device index arrays for ``plan`` (cached by the
        plan's width — the host-side cost of a width re-slice event)."""
        key = (plan.t, plan.col_split)
        hit = self._width_arrays.get(key)
        if hit is not None:
            return hit
        sharding = NamedSharding(self.mesh, P(("node", "proc")))
        put = lambda arr: jax.device_put(jnp.asarray(arr), sharding)
        arrays = (
            [put(ph.gather_idx) for ph in plan.phases],
            [put(ph.scatter_pos) for ph in plan.phases],
        )
        self._width_arrays[key] = arrays
        return arrays

    # ------------------------------------------------------------------ api
    def matvec_fn(self, t_active: int | None = None):
        """Returns f(V_sharded (n_padded, t)) -> (n_padded, t), jit-able.

        ``t_active`` applies the operator through the width-sliced sub-plan
        ``plan.at_width(t_active)`` — same matrix arrays, re-sliced exchange
        index arrays, wire payload of exactly t_active columns.  The block
        vectors passed to the returned function must then carry ``t_active``
        columns."""
        plan = self.plan if t_active is None else self.plan.at_width(t_active)
        if plan is self.plan or plan.phases is self.plan.phases:
            # width-sliced plans with shared index arrays (col_split divides
            # t_active) reuse the device-resident copies — no re-upload
            gathers_, scatters_ = self.gathers, self.scatters
        else:
            gathers_, scatters_ = self.exchange_arrays(plan)
        k = len(plan.phases)

        def per_device(v, csr, ell, split, *exchange_arrays):
            gathers = [a[0] for a in exchange_arrays[:k]]
            scatters = [a[0] for a in exchange_arrays[k:]]
            shape = v.shape
            v = v.reshape(self.rmax, -1)
            t = v.shape[1]
            if not self.overlap:
                halo = self._exchange(v, plan, gathers, scatters)
                if self.backend == "pallas":
                    xfull = jnp.concatenate([v, halo], axis=0)
                    w = self._ell_spmbv(xfull, ell["blocks"][0], ell["indices"][0])
                    w = w[: self.rmax]
                else:
                    w = self._local_spmbv(
                        v, halo, csr["indptr"][0], csr["indices"][0], csr["data"][0]
                    )
            else:
                sp = {key: arr[0] for key, arr in split.items()}
                n_int = sp["int_rows"].shape[0]
                n_bnd = sp["bnd_rows"].shape[0]
                w = jnp.zeros((self.rmax + 1, t), v.dtype)  # +1 = dump row
                # Interior SpMBV reads only own rows — no data dependence on
                # the ppermute rounds below, so it overlaps the exchange.
                if n_int:
                    if self.backend == "pallas":
                        w_int = self._ell_spmbv(v, sp["int_blocks"], sp["int_idx"])[:n_int]
                    else:
                        w_int = self._csr_rows_spmbv(
                            v, sp["int_indptr"], sp["int_indices"], sp["int_data"], n_int
                        )
                    w = w.at[sp["int_rows"]].add(w_int)
                halo = self._exchange(v, plan, gathers, scatters)
                # Only the boundary rows wait on the halo.
                if n_bnd:
                    xfull = jnp.concatenate([v, halo], axis=0)
                    if self.backend == "pallas":
                        w_bnd = self._ell_spmbv(xfull, sp["bnd_blocks"], sp["bnd_idx"])[:n_bnd]
                    else:
                        w_bnd = self._csr_rows_spmbv(
                            xfull, sp["bnd_indptr"], sp["bnd_indices"], sp["bnd_data"], n_bnd
                        )
                    w = w.at[sp["bnd_rows"]].add(w_bnd)
                w = w[: self.rmax]
            return w.reshape(shape)

        dev_specs = P(("node", "proc"),)
        smapped = shard_map(
            per_device,
            mesh=self.mesh,
            in_specs=(self.vec_spec, dev_specs, dev_specs, dev_specs)
            + (dev_specs,) * (2 * k),
            out_specs=self.vec_spec,
            check_rep=False,
        )

        def apply(v):
            csr = (
                {}
                if self.indptr is None
                else {"indptr": self.indptr, "indices": self.indices, "data": self.data}
            )
            return smapped(v, csr, self.ell, self.split, *gathers_, *scatters_)

        return apply

    def masked_matvec_fn(self, t_active: int):
        """Width-compacted apply for the adaptive solver.

        Returns ``f(V (n_padded, t), active (t,) bool) -> (n_padded, t)``:
        the ``t_active`` active columns (zero-masked block vectors guarantee
        the rest are zero) are gathered to the front, pushed through the
        width-``t_active`` operator — so the halo exchange moves exactly
        ``t_active`` columns of bytes — and scattered back into a zero
        (n, t) block.  Bit-exact vs the full-width apply: column gather/
        scatter is pure data movement and A·0 = 0 for the retired columns.
        """
        apply_active = self.matvec_fn(t_active=t_active)

        def apply(v, active):
            # stable argsort: active columns first, original order preserved
            cols = jnp.argsort(~active)[:t_active]
            vc = jnp.take(v, cols, axis=1)
            wc = apply_active(vc)
            return jnp.zeros_like(v).at[:, cols].set(wc)

        return apply


def _perm(axis: str, offset: int, plan: ExchangePlan):
    if axis == "proc":
        n = plan.ppn
    elif axis == "node":
        n = plan.n_nodes
    else:
        n = plan.p
    return [(i, (i + offset) % n) for i in range(n)]


def _gather_csr_rows(ptr, ix, dat, rows):
    """Extract the CSR rows ``rows`` as a compact (len(rows), ·) CSR triple."""
    counts = np.diff(ptr)[rows]
    gptr = np.concatenate([[0], np.cumsum(counts)]).astype(np.int64)
    if len(rows):
        gix = np.concatenate([ix[ptr[r] : ptr[r + 1]] for r in rows])
        gdat = np.concatenate([dat[ptr[r] : ptr[r + 1]] for r in rows])
    else:
        gix = np.zeros(0, dtype=np.int64)
        gdat = np.zeros(0, dtype=dat.dtype)
    return gptr, gix, gdat


def _stack_gathered_csr(per_rank, n_rows_max, rmax, dtype):
    """Stack per-rank gathered CSR triples + scatter row ids into (p, ·) arrays.

    per_rank: list of (rows, gptr, gix, gdat); scatter ids pad with the dump
    row ``rmax``; nnz pads with index 0 / value 0 (contribute nothing).
    """
    p = len(per_rank)
    nnz_max = max((int(g[1][-1]) for g in per_rank), default=0)
    rows_ids = np.full((p, n_rows_max), rmax, np.int32)
    indptr = np.zeros((p, n_rows_max + 1), np.int32)
    indices = np.zeros((p, nnz_max), np.int32)
    data = np.zeros((p, nnz_max), dtype)
    for r, (rows, gptr, gix, gdat) in enumerate(per_rank):
        rows_ids[r, : len(rows)] = rows
        indptr[r, : len(gptr)] = gptr
        indptr[r, len(gptr) :] = gptr[-1]
        indices[r, : len(gix)] = gix
        data[r, : len(gdat)] = gdat
    return rows_ids, indptr, indices, data


def _stack_block_ell(per_rank, n_rows_max, n_cols, br, bc, dtype):
    """Convert per-rank gathered CSR triples to one stacked Block-ELL array."""
    p = len(per_rank)
    nbr = max(1, (n_rows_max + br - 1) // br)
    kmax = max(
        [count_block_ell_tiles(g[1], g[2], len(g[0]), n_cols, br, bc) for g in per_rank]
        + [1]
    )
    blocks = np.zeros((p, nbr, kmax, br, bc), dtype)
    idx = np.zeros((p, nbr, kmax), np.int32)
    for r, (rows, gptr, gix, gdat) in enumerate(per_rank):
        blocks[r], idx[r] = csr_arrays_to_block_ell(
            gptr, gix, gdat, len(rows), n_cols, br, bc, nbr, kmax
        )
    return blocks, idx


def make_distributed_spmbv(
    a: CSRMatrix,
    mesh: Mesh,
    strategy: str = "standard",
    t: int = 1,
    machine=None,
    pm: PartitionedMatrix | None = None,
    backend: str = "jnp",
    overlap: bool = False,
    ell_block: int | tuple[int, int] = 8,
    tune: str | object = "off",
    col_split: int | None = None,
) -> DistributedSpMBV:
    """Deprecated spelling of the operator build — the handle API owns it.

    ``ECGSolver.build(a, mesh, SolverConfig(...))`` performs the same
    partition + plan + tune + Block-ELL setup once and exposes the operator
    as ``solver.op``; this function remains for external callers that only
    want the bare SpMBV operator.  See :func:`_make_distributed_spmbv` for
    the argument documentation.
    """
    import warnings

    warnings.warn(
        "make_distributed_spmbv() is the legacy stringly-typed spelling; "
        "build a repro.solver.ECGSolver handle (typed SolverConfig) and use "
        "solver.op instead",
        DeprecationWarning,
        stacklevel=2,
    )
    return _make_distributed_spmbv(
        a, mesh, strategy, t=t, machine=machine, pm=pm, backend=backend,
        overlap=overlap, ell_block=ell_block, tune=tune, col_split=col_split,
    )


def _make_distributed_spmbv(
    a: CSRMatrix,
    mesh: Mesh,
    strategy: str = "standard",
    t: int = 1,
    machine=None,
    pm: PartitionedMatrix | None = None,
    backend: str = "jnp",
    overlap: bool = False,
    ell_block: int | tuple[int, int] = 8,
    tune: str | object = "off",
    col_split: int | None = None,
) -> DistributedSpMBV:
    """Partition ``a`` over ``mesh`` and build the device-ready operator.

    backend="pallas" additionally converts each rank's local [own ‖ halo]
    CSR block to Block-ELL here (one-time host cost, see module docstring);
    overlap=True splits rows into interior/boundary sets so the device
    program hides the exchange rounds behind interior compute; ``ell_block``
    is the Block-ELL tile shape — an int for square (b, b) tiles or an
    explicit (br, bc) pair.

    ``tune`` hands those three knobs to the setup-time autotuner
    (:mod:`repro.tune`): ``"model"`` selects (strategy, tile, overlap) from
    the paper's analytic performance models, ``"model:structural"`` from the
    executor-structural model (plan dispatches + moved bytes — the right
    ranking on host/TPU backends), ``"measure"`` from setup-time
    microbenchmarks on ``mesh``, and a :class:`repro.tune.TunedConfig`
    applies a previously computed choice.  ``"off"`` (default) keeps the
    explicit arguments.  ``col_split`` overrides the nodal-optimal wide-halo
    splitting factor (must divide t; ``None`` = §4.3 byte model).
    """
    if backend not in ("jnp", "pallas"):
        raise ValueError(f"unknown backend {backend!r}")
    n_nodes, ppn = mesh.devices.shape
    p = n_nodes * ppn
    pm = pm or partition_csr(a, p)

    tuned = None
    if not (tune is None or tune == "off"):
        from repro.tune import TunedConfig, tune as run_tune

        if isinstance(tune, TunedConfig):
            tuned = tune
        elif tune in ("model", "model:structural", "measure"):
            tuned = run_tune(
                a, t=t, machine=machine, n_nodes=n_nodes, ppn=ppn,
                pm=pm, backend=backend, mode=tune, mesh=mesh,
            )
        else:
            raise ValueError(f"unknown tune mode {tune!r}")
        strategy = tuned.strategy
        overlap = tuned.overlap
        ell_block = (tuned.br, tuned.bc)
        # keep the built plan consistent with the config's byte-model
        # decisions: the tuner's dtype-resolved machine wins over the raw
        # caller argument it was derived from
        machine = tuned.machine or machine
        if col_split is None and tuned.col_split > 1:
            col_split = tuned.col_split

    plan = build_exchange_plan(
        pm, n_nodes, ppn, strategy, t=t, machine=machine, col_split=col_split
    )

    rmax = pm.part.max_local_rows
    val_dtype = np.asarray(pm.local_data[0]).dtype
    # per-rank (indptr, indices-with-halo-at-rmax, data, n_local): halo ids
    # were n_local-based, re-based to rmax so x can be padded
    rebased = rebased_local_csr(pm)

    # the full stacked CSR is only consumed by the blocking jnp path; don't
    # ship a second copy of the matrix to devices in the other modes
    indptr = indices = data = None
    if backend == "jnp" and not overlap:
        nnz_max = max(len(ix) for ix in pm.local_indices)
        indptr = np.zeros((p, rmax + 1), np.int32)
        indices = np.zeros((p, nnz_max), np.int32)
        data = np.zeros((p, nnz_max), val_dtype)
        for r, (ptr, ix, dat, n_local) in enumerate(rebased):
            indptr[r, : n_local + 1] = ptr
            indptr[r, n_local + 1 :] = ptr[-1]
            indices[r, : len(ix)] = ix
            data[r, : len(dat)] = dat

    n_cols_full = rmax + plan.halo_rows
    br, bc = (ell_block, ell_block) if isinstance(ell_block, int) else ell_block

    ell = {}
    if backend == "pallas" and not overlap:
        per_rank = [
            (np.arange(n_local), ptr, ix, dat) for ptr, ix, dat, n_local in rebased
        ]
        blocks, idx = _stack_block_ell(per_rank, rmax, n_cols_full, br, bc, val_dtype)
        ell = {"blocks": blocks, "indices": idx}

    split = {}
    if overlap:
        # pallas: classify whole (br-aligned) block rows so gathering the
        # interior/boundary subsets preserves the Block-ELL tiles as built
        io = interior_boundary_split(pm, block_row=br if backend == "pallas" else 1)
        n_int_max = max(len(i) for i, _ in io)
        n_bnd_max = max(len(b) for _, b in io)
        int_per_rank, bnd_per_rank = [], []
        for (ptr, ix, dat, n_local), (int_rows, bnd_rows) in zip(rebased, io):
            gi = _gather_csr_rows(ptr, ix, dat, int_rows)
            gb = _gather_csr_rows(ptr, ix, dat, bnd_rows)
            int_per_rank.append((int_rows,) + gi)
            bnd_per_rank.append((bnd_rows,) + gb)
        int_ids, int_ptr, int_ix, int_dat = _stack_gathered_csr(
            int_per_rank, n_int_max, rmax, val_dtype
        )
        bnd_ids, bnd_ptr, bnd_ix, bnd_dat = _stack_gathered_csr(
            bnd_per_rank, n_bnd_max, rmax, val_dtype
        )
        split = {"int_rows": int_ids, "bnd_rows": bnd_ids}
        if backend == "pallas":
            split["int_blocks"], split["int_idx"] = _stack_block_ell(
                int_per_rank, n_int_max, rmax, br, bc, val_dtype
            )
            split["bnd_blocks"], split["bnd_idx"] = _stack_block_ell(
                bnd_per_rank, n_bnd_max, n_cols_full, br, bc, val_dtype
            )
        else:
            split.update(
                int_indptr=int_ptr, int_indices=int_ix, int_data=int_dat,
                bnd_indptr=bnd_ptr, bnd_indices=bnd_ix, bnd_data=bnd_dat,
            )

    dev_sharding = NamedSharding(mesh, P(("node", "proc")))
    put = lambda arr: jax.device_put(jnp.asarray(arr), dev_sharding)
    return DistributedSpMBV(
        mesh=mesh,
        plan=plan,
        n=a.shape[0],
        rmax=rmax,
        starts=pm.part.starts,
        indptr=put(indptr) if indptr is not None else None,
        indices=put(indices) if indices is not None else None,
        data=put(data) if data is not None else None,
        gathers=[put(ph.gather_idx) for ph in plan.phases],
        scatters=[put(ph.scatter_pos) for ph in plan.phases],
        backend=backend,
        overlap=overlap,
        ell_block=(br, bc),
        ell={k2: put(v) for k2, v in ell.items()},
        split={k2: put(v) for k2, v in split.items()},
        tuned=tuned,
    )


# ----------------------------------------------------------------------------
# distributed ECG: same body as core.ecg, inside one shard_map
# ----------------------------------------------------------------------------
def distributed_ecg(
    a: CSRMatrix,
    b: np.ndarray,
    mesh: Mesh,
    t: int | str,
    strategy: str = "standard",
    tol: float = 1e-8,
    max_iters: int = 500,
    machine=None,
    backend: str = "jnp",
    overlap: bool = False,
    ell_block: int | tuple[int, int] = 8,
    tune: str | object = "off",
    adaptive: object = None,
    t_candidates: tuple = (1, 2, 4, 8, 16),
):
    """Distributed ECG solve with the selected node-aware SpMBV strategy.

    Runs the whole while_loop inside jit with the distributed operator; the
    two fused reductions appear as psums over ("node", "proc").  With
    ``backend="pallas"`` the per-device local work (SpMBV, packed gram, X/R/Z
    tail) runs through the Pallas kernel suite — the collective structure
    (two psums per iteration) is unchanged.  ``overlap=True`` additionally
    hides the halo-exchange rounds behind interior SpMBV compute.

    ``tune="model"|"measure"`` (or a precomputed ``TunedConfig``) delegates
    the (strategy, tile shape, overlap) choice to :mod:`repro.tune` — see
    :func:`make_distributed_spmbv`; ``strategy="tuned"`` is shorthand for
    ``tune="model"``.

    ``t="auto"`` picks the enlarging factor at setup time from the
    iterations-vs-cost model of :mod:`repro.adaptive.select_t` (iteration
    probes run on the sequential CSR product — the iteration count depends
    only on the math — and per-iteration cost on this mesh's (n_nodes, ppn)
    via :mod:`repro.tune`); the :class:`TSelection` is recorded on both the
    result and the applied ``TunedConfig``.  With the default ``tune="off"``
    the solver then *executes the tuner config the choice was modeled with*
    — explicit ``strategy``/``overlap``/``ell_block`` arguments are
    overridden (with a warning when non-default), because a t optimized for
    one config but run under another would make the selection meaningless;
    pass a fixed ``t`` to force an explicit config, or ``tune="model"|
    "measure"`` to re-tune at the chosen t.  ``adaptive`` selects the in-
    solve width controller ("rankrev" | "reduce" | "reduce+restart" | a
    :class:`repro.adaptive.ReductionPolicy`): the active-width mask lives in
    the replicated t-wide coefficient space, so the per-device block vectors
    stay (rmax, t) with zero-masked columns and the Pallas kernels and
    two-psum structure are untouched.  The halo exchange, however, is
    *width-aware*: for non-restarting policies the solve runs in width
    segments — the active mask is threaded into the exchange (retired
    columns are compacted out of the wire payload), and each reduction
    event triggers a cheap ``plan.at_width`` re-slice so subsequent
    iterations move ``t_active·rows·f`` bytes instead of full-width zeros.
    ``SolveResult.comm_segments`` records the (width, iterations) trace.

    .. deprecated::
        This is the legacy stringly-typed spelling.  It now builds a
        :class:`repro.solver.ECGSolver` handle, solves once, and discards
        the compiled session — build the handle yourself to amortize setup
        and compilation over many right-hand sides.
    """
    import warnings

    warnings.warn(
        "distributed_ecg() is the legacy stringly-typed spelling; build a "
        "repro.solver.ECGSolver handle (compile-once / solve-many, typed "
        "SolverConfig) instead",
        DeprecationWarning,
        stacklevel=2,
    )
    solver = _build_legacy_solver(
        a, mesh, t, strategy=strategy, tol=tol, max_iters=max_iters,
        machine=machine, backend=backend, overlap=overlap,
        ell_block=ell_block, tune=tune, adaptive=adaptive,
        t_candidates=t_candidates, b=b,
    )
    return solver.solve(b), solver.op


def _build_legacy_solver(
    a, mesh, t, *, strategy="standard", tol=1e-8, max_iters=500, machine=None,
    backend="jnp", overlap=False, ell_block=8, tune="off", adaptive=None,
    t_candidates=(1, 2, 4, 8, 16), b=None,
):
    """Map the legacy ``distributed_ecg`` argument list onto a typed
    :class:`~repro.solver.SolverConfig` and build the handle."""
    from repro.solver import (
        AdaptiveConfig, CommConfig, ECGSolver, KernelConfig, SolverConfig,
        TuneConfig,
    )

    if strategy == "tuned":
        strategy = "standard"
        if tune is None or tune == "off":
            tune = "model"
    config = SolverConfig(
        t=t,
        tol=tol,
        max_iters=max_iters,
        comm=CommConfig(strategy=strategy, overlap=overlap, machine=machine),
        kernel=KernelConfig(backend=backend, ell_block=ell_block),
        tune=TuneConfig.coerce(None if tune == "off" else tune),
        adaptive=AdaptiveConfig(policy=adaptive, t_candidates=tuple(t_candidates)),
    )
    return ECGSolver.build(a, mesh, config, b=b)
