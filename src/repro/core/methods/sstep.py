"""s-step enlarged CG — two psums amortized over s SpMBV sweeps.

Each ``step`` (one *block* = s effective iterations) seeds an s-deep
monomial block-Krylov basis from the current split residual and
A-orthonormalizes the whole (n, s·t) candidate block at once, in the
residual-seeded MSDO/s-step shape of Moufawad's enlarged Krylov methods
(arXiv:1804.10629):

  per block —
    V  = [R, AR, …, A^{s−1}R],  AV = A·V      s SpMBVs           (p2p only)
    [VᵀAV | PᵀAV | P₂ᵀAV]                     fused gram1        (psum #1, 3(st)²)
    V −= P a + P₂ b ; AV −= AP a + AP₂ b      project vs prev two blocks
    G' = G − aᵀa − bᵀb                        (algebraic — no extra psum)
    P', AP' = rank-revealing A-orthonorm. of (V, AV)             (local)
    c  = P'ᵀR                                 gram1              (psum #2, st·t)
    X += P'c ; R −= AP'c

Seeding from the residual (rather than recurring the previous block's AP
through A-powers) is what keeps the two-block projection sufficient: each
block update is the *exact* A-norm projection of the error onto span(P'),
so the A-norm error decreases monotonically per block no matter how much
A-orthogonality to older blocks the monomial powers leak.  The projection
coefficients ride in psum #1 for free — PᵀAV = (AP)ᵀV = PᵀAV is a local
product against the carried AP blocks, packed into the same reduction as
the Gram matrix (and G' follows algebraically from PᵀAP = diag(act),
PᵀAP₂ = 0, so the projected Gram costs no second collective).

The mixed widths ((n, st) blocks against (n, t) residuals, an (st, t)
coefficient block) do not fit the fixed-shape Pallas gram/tail kernels, so
this scheme uses only the width-polymorphic ``gram1``/``sqnorm``
reductions plus inline jnp updates — the SpMBV itself keeps whatever
backend the operator was built with.

Stability: the monomial basis is intentionally communication-free and
correspondingly ill-conditioned (its condition number grows like κ(A)^s),
so the pivoted rank-revealing Cholesky of :mod:`repro.adaptive.rankrev` is
**mandatory** here — dependent candidate columns come out zero-masked
instead of poisoning the block.  ``reorth=True`` adds a per-block
Cholesky-QR2 second pass (one extra (st)² psum) for matrices where a
single pivoted factorization leaves too much A-orthogonality on the
table.

Adaptivity: a :class:`~repro.adaptive.ReductionPolicy` drops stagnant
*seed* columns (the t-wide mask is scored from the transposed coefficient
block, so a dropped residual direction stops spawning basis vectors), and
restart re-enlarges trivially — the seed is rebuilt from the residual
every block anyway, so plateau restarts just clear the mask and the
carried projection blocks.  ``k`` counts blocks; histories have one entry
per s effective iterations.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.adaptive.rankrev import rank_revealing_apply
from repro.adaptive.reduce import plateau_update, stagnation_mask
from repro.core.cg import EV_RECOVERY
from repro.core.methods.base import MethodContext, MethodSpec, _apply_vec


class SStepMethod(MethodSpec):
    """s inner steps per collective pair, rank-revealing safeguarded."""

    name = "sstep"

    def validate(self, ctx: MethodContext) -> None:
        if ctx.s < 1:
            raise ValueError(f"s must be >= 1, got {ctx.s}")
        if ctx.chol_eps:
            raise ValueError(
                "method 'sstep' always factorizes through the pivoted "
                "rank-revealing Cholesky (the monomial basis demands it); "
                "chol_eps jitter does not apply — tune rank_rtol instead"
            )

    def iters_per_block(self, s: int = 1) -> int:
        return s

    def psums_per_block(self, s: int = 1, reorth: bool = False) -> int:
        return 3 if reorth else 2

    def psum_payload_floats(self, t: int, s: int = 1, reorth: bool = False) -> int:
        st = s * t
        payload = 3 * st * st + st * t  # fused gram1+projections, then c = PᵀR
        if reorth:
            payload += st * st  # Cholesky-QR2 second gram
        return payload

    def build(self, ctx: MethodContext):
        t, s = ctx.t, ctx.s
        st = s * t
        max_iters = ctx.max_iters
        policy = ctx.policy
        use_mask = ctx.use_mask
        reorth = ctx.reorth
        a_apply = ctx.a_apply
        a_apply_masked = ctx.a_apply_masked
        split_fn = ctx.split_fn
        gram1, sqnorm = ctx.gram1, ctx.sqnorm
        precond = ctx.precond
        # safeguard threshold: explicit override > policy's > dtype default
        rr_rtol = ctx.rank_rtol
        if rr_rtol is None and policy is not None:
            rr_rtol = policy.rank_rtol

        def iterate(carry):
            big_x, big_r = carry["X"], carry["R"]
            p1, ap1 = carry["P"], carry["AP"]      # previous block
            p2, ap2 = carry["Pp"], carry["APp"]    # block before that
            k, hist = carry["k"], carry["hist"]
            act_t = carry["act"] if policy is not None else None

            # residual-seeded monomial basis: s width-t SpMBVs, p2p exchange
            # only — no collective fires inside this sweep.  Preconditioned,
            # the basis is the M⁻¹A-Krylov sequence [M⁻¹R, (M⁻¹A)M⁻¹R, …]
            # with AV tracked exactly (avs[i] = A·vs[i] by construction), so
            # the A-orthonormalization below — including the MANDATORY
            # rank-revealing safeguard — is untouched: a preconditioned
            # monomial basis conditions *better*, but the pivoted Cholesky
            # still backstops whatever dependence survives.
            seed = big_r
            if policy is not None:
                seed = seed * act_t.astype(seed.dtype)[None, :]
            vs, avs = [], []
            cur = seed if precond is None else precond(seed, k)
            for _ in range(s):
                if use_mask:
                    nxt = a_apply_masked(cur, act_t)  # A zero-col ⇒ zero-col
                else:
                    nxt = a_apply(cur)
                vs.append(cur)
                avs.append(nxt)
                cur = nxt if precond is None else precond(nxt, k)
            v = jnp.concatenate(vs, axis=1)    # (n, st)
            av = jnp.concatenate(avs, axis=1)  # = A·V

            # psum #1: Gram and both projection coefficient blocks fused in
            # one (3st, st) reduction — [VᵀAV ; PᵀAV ; P₂ᵀAV]
            big1 = gram1(jnp.concatenate([v, p1, p2], axis=1), av)
            g = big1[:st]
            a1 = big1[st:2 * st]   # = PᵀAV  (A-projection onto previous block)
            a2 = big1[2 * st:]     # = P₂ᵀAV
            v = v - p1 @ a1 - p2 @ a2
            av = av - ap1 @ a1 - ap2 @ a2
            # projected Gram, algebraically: PᵀAP = diag(act), PᵀAP₂ = 0,
            # and the dead rows of a1/a2 are already zero
            g = g - a1.T @ a1 - a2.T @ a2

            # mandatory safeguard: pivoted rank-revealing A-orthonormalization
            (p, ap), _rank, _active_st = rank_revealing_apply(g, v, av, rtol=rr_rtol)
            # telemetry: live candidate columns = s per live seed column (a
            # dead seed spawns only zero basis vectors); fewer accepted
            # pivots means the safeguard just absorbed a rank loss of the
            # monomial basis — the breakdown-recovery event this scheme's
            # mandatory factorization exists for
            live = s * (jnp.sum(act_t).astype(jnp.int32) if policy is not None
                        else jnp.int32(t))
            recovered = _rank < live
            if reorth:
                # Cholesky-QR2 second pass: one extra (st)² psum per block
                g2 = gram1(p, ap)
                (p, ap), _rank2, _act2 = rank_revealing_apply(g2, p, ap, rtol=rr_rtol)
                recovered = recovered | (_rank2 < _rank)

            c = gram1(p, big_r)  # psum #2: (st, t) coefficient block = PᵀR
            # exact A-norm error projection onto span(P): monotone per block
            big_x = big_x + p @ c
            big_r = big_r - ap @ c

            rsum = big_r.sum(axis=1)
            rn = jnp.sqrt(sqnorm(rsum))
            hist = hist.at[k + 1].set(rn)  # k counts blocks (s iterations each)
            out = dict(
                X=big_x, R=big_r, P=p, AP=ap, Pp=p1, APp=ap1,
                k=k + 1, rn=rn, hist=hist, bd=carry["bd"],
                evhist=carry["evhist"].at[k + 1].set(
                    jnp.where(recovered, EV_RECOVERY, 0)
                ),
            )
            if policy is not None:
                # seed-level stagnation: score residual column l by its
                # coefficient column c[:, l] (rows of cᵀ), mask at width t
                act_t = stagnation_mask(c.T, carry["rn"], act_t, policy)
                n_active = jnp.sum(act_t).astype(jnp.int32)
                best_rn, since = plateau_update(
                    rn, carry["best_rn"], carry["since"], policy
                )
                restarts = carry["restarts"]
                if policy.restart:
                    # re-enlarge: the seed is rebuilt from the residual every
                    # block, so a restart just clears the mask and the carried
                    # projection blocks
                    do_rs = (since >= policy.plateau_window) & (n_active < t)
                    for key in ("P", "AP", "Pp", "APp"):
                        out[key] = jnp.where(do_rs, jnp.zeros_like(out[key]), out[key])
                    act_t = jnp.where(do_rs, jnp.ones_like(act_t), act_t)
                    n_active = jnp.where(do_rs, jnp.int32(t), n_active)
                    since = jnp.where(do_rs, 0, since)
                    best_rn = jnp.where(do_rs, rn, best_rn)
                    restarts = restarts + do_rs.astype(jnp.int32)
                out.update(
                    act=act_t, best_rn=best_rn, since=since, restarts=restarts,
                    ahist=carry["ahist"].at[k + 1].set(n_active),
                )
            return out

        def init(b, x0):
            n = b.shape[0]
            dtype = b.dtype
            r0 = b - _apply_vec(a_apply, x0, t)
            big_r0 = split_fn(r0, t)
            rn0 = jnp.sqrt(sqnorm(r0))
            hist0 = jnp.full((max_iters + 1,), jnp.nan, dtype=dtype).at[0].set(rn0)
            zeros_nst = jnp.zeros((n, st), dtype)
            carry = dict(X=jnp.zeros((n, t), dtype), R=big_r0,
                         P=zeros_nst, AP=zeros_nst, Pp=zeros_nst, APp=zeros_nst,
                         k=jnp.int32(0), rn=rn0, hist=hist0,
                         bd=~jnp.isfinite(rn0),
                         evhist=jnp.full((max_iters + 1,), -1,
                                         jnp.int32).at[0].set(0))
            if policy is not None:
                carry.update(
                    act=jnp.ones((t,), bool),
                    best_rn=rn0,
                    since=jnp.int32(0),
                    restarts=jnp.int32(0),
                    ahist=jnp.full((max_iters + 1,), -1, jnp.int32).at[0].set(t),
                )
            return carry

        return init, iterate
