"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch stablelm_1_6b \
        --preset tiny --steps 200 --ckpt-dir /tmp/ckpt [--resume]

Presets: ``smoke`` uses the per-arch reduced config; ``tiny``/``100m`` scale a
dense config to the requested size (CPU-runnable).  Full configs run on the
production mesh on real hardware with exactly this driver.
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, get_smoke
from repro.launch.mesh import make_smoke_mesh
from repro.models.registry import model_api
from repro.train import (
    AdamWConfig,
    DataConfig,
    batch_at,
    build_train_step,
    init_opt_state,
    install_preemption_handler,
    latest_step,
    restore_checkpoint,
    save_checkpoint,
)


def preset_config(arch: str, preset: str):
    if preset == "smoke":
        return get_smoke(arch)
    cfg = get_config(arch)
    if preset == "tiny":  # ~5M params, CI-speed
        return cfg.with_(n_layers=2, d_model=128, n_heads=4, n_kv_heads=max(1, min(4, cfg.n_kv_heads)),
                         d_ff=512, vocab=2048, remat=False)
    if preset == "100m":  # ~100M params
        return cfg.with_(n_layers=12, d_model=768, n_heads=12,
                         n_kv_heads=12 if cfg.n_kv_heads >= cfg.n_heads else 4,
                         d_ff=3072, vocab=32768, remat=False)
    if preset == "full":
        return cfg
    raise ValueError(preset)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm_1_6b")
    ap.add_argument("--preset", default="tiny", choices=["smoke", "tiny", "100m", "full"])
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = preset_config(args.arch, args.preset).with_(dtype=jax.numpy.float32)
    mesh = make_smoke_mesh() if args.preset != "full" else __import__(
        "repro.launch.mesh", fromlist=["make_production_mesh"]
    ).make_production_mesh()
    api = model_api(cfg)
    print(f"arch={cfg.name} params={cfg.param_count()/1e6:.1f}M preset={args.preset}")

    opt_cfg = AdamWConfig(lr=args.lr, warmup_steps=max(10, args.steps // 20), total_steps=args.steps)
    bundle = build_train_step(cfg, mesh, opt_cfg, batch=args.batch, seq=args.seq, donate=False)
    dcfg = DataConfig(vocab=cfg.vocab, batch=args.batch, seq=args.seq)
    extra = {k: v for k, v in bundle.abstract_batch.items() if k not in ("tokens", "labels")}

    params = api.init_params(cfg, jax.random.key(0))
    opt = init_opt_state(params)
    start = 0
    if args.resume and args.ckpt_dir and latest_step(args.ckpt_dir) is not None:
        (state,), meta = restore_checkpoint(args.ckpt_dir, ({"params": params, "opt": opt},))
        params, opt, start = state["params"], state["opt"], meta["step"]
        print(f"resumed from step {start}")

    if args.ckpt_dir:
        cur = {"step": start}
        install_preemption_handler(
            lambda: save_checkpoint(args.ckpt_dir, cur["step"], {"params": params, "opt": opt})
        )

    t0 = time.time()
    for step in range(start, args.steps):
        batch = batch_at(dcfg, step, extra=extra)
        params, opt, metrics = bundle.step_fn(params, opt, batch)
        if args.ckpt_dir:
            cur = {"step": step + 1}
        if (step + 1) % args.log_every == 0:
            print(
                f"step {step+1:5d} loss {float(metrics['loss']):.4f} "
                f"gnorm {float(metrics['grad_norm']):.3f} lr {float(metrics['lr']):.2e} "
                f"({(time.time()-t0)/(step-start+1)*1e3:.0f} ms/step)",
                flush=True,
            )
        if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
            save_checkpoint(args.ckpt_dir, step + 1, {"params": params, "opt": opt})
    if args.ckpt_dir:
        save_checkpoint(args.ckpt_dir, args.steps, {"params": params, "opt": opt})
    print("done")


if __name__ == "__main__":
    main()
