"""End-to-end LM training with checkpoint/restart fault tolerance.

Trains a reduced stablelm-family model, kills it mid-run (simulated
preemption), resumes from the checkpoint, and verifies the loss curve
continues seamlessly.

    PYTHONPATH=src python examples/train_lm.py [--steps 60] [--preset tiny]
"""

import argparse
import tempfile

import jax
import numpy as np

from repro.launch.train import preset_config
from repro.launch.mesh import make_smoke_mesh
from repro.models.registry import model_api
from repro.train import (
    AdamWConfig, DataConfig, batch_at, build_train_step, init_opt_state,
    save_checkpoint, restore_checkpoint, latest_step,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--preset", default="tiny", choices=["tiny", "100m"])
    ap.add_argument("--arch", default="stablelm_1_6b")
    args = ap.parse_args()

    cfg = preset_config(args.arch, args.preset).with_(dtype=jax.numpy.float32)
    mesh = make_smoke_mesh()
    api = model_api(cfg)
    print(f"training {cfg.name}-{args.preset}: {cfg.param_count()/1e6:.1f}M params")

    opt_cfg = AdamWConfig(lr=1e-3, warmup_steps=5, total_steps=args.steps)
    bundle = build_train_step(cfg, mesh, opt_cfg, batch=8, seq=128, donate=False)
    dcfg = DataConfig(vocab=cfg.vocab, batch=8, seq=128)

    params = api.init_params(cfg, jax.random.key(0))
    opt = init_opt_state(params)

    ckpt = tempfile.mkdtemp(prefix="ecg_lm_ckpt_")
    half = args.steps // 2
    losses = []
    for step in range(half):
        params, opt, m = bundle.step_fn(params, opt, batch_at(dcfg, step))
        losses.append(float(m["loss"]))
        if (step + 1) % 10 == 0:
            print(f"  step {step+1:4d} loss {losses[-1]:.4f}")
    save_checkpoint(ckpt, half, {"params": params, "opt": opt})
    print(f"-- simulated preemption at step {half}; checkpoint saved --")

    # "restart": fresh process state, restore, continue
    del params, opt
    params = api.init_params(cfg, jax.random.key(0))
    opt = init_opt_state(params)
    (state,), meta = restore_checkpoint(ckpt, ({"params": params, "opt": opt},))
    params, opt = state["params"], state["opt"]
    print(f"-- resumed from step {meta['step']} --")
    for step in range(meta["step"], args.steps):
        params, opt, m = bundle.step_fn(params, opt, batch_at(dcfg, step))
        losses.append(float(m["loss"]))
        if (step + 1) % 10 == 0:
            print(f"  step {step+1:4d} loss {losses[-1]:.4f}")

    assert np.mean(losses[-5:]) < np.mean(losses[:5]), "loss did not improve"
    print(f"final loss {losses[-1]:.4f} (from {losses[0]:.4f}) — resume seamless")


if __name__ == "__main__":
    main()
