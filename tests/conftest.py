"""Shared test fixtures.

NOTE: x64 is enabled here for solver accuracy.  Device-count forcing
(XLA_FLAGS) is deliberately NOT set here — multi-device tests run in
subprocesses (see test_distributed.py) so ordinary tests see 1 device.
"""

import jax

jax.config.update("jax_enable_x64", True)

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(1234)


@pytest.fixture(autouse=True)
def _reset_dispatch_warnings():
    """Isolate the kernel dispatchers' warn-once state per test.

    Without this, the first test that triggers a GPU-fallback warning
    consumes it for the whole process and later tests asserting on the
    warning (or its absence) become order-dependent.
    """
    from repro.kernels.dispatch import reset_dispatch_warnings

    reset_dispatch_warnings()
    yield
    reset_dispatch_warnings()
