"""ECG serving driver: replay a synthetic request trace through ECGServer.

    PYTHONPATH=src python -m repro.launch.serve [--requests 32] [--t 4] \
        [--max-batch 8] [--cache-dir DIR] [--devices 8 --ppn 4] [--dups 8] \
        [--pack width --max-pack-width 16 --max-wait-s 0.05]

The driver synthesizes a single-RHS request trace over three operators
(2D Laplacian, anisotropic Laplacian, DG block operator) in shuffled
arrival order, with a configurable number of duplicate payloads (the
cross-request dedup case), and replays it through one
:class:`~repro.serve.ECGServer`:

* first sight of each operator registers + builds its session (warm from
  ``--cache-dir`` when a previous run persisted its tuning there);
* requests coalesce per operator and dispatch through the compiled block
  programs — zero retraces after the per-operator first solve;
* the summary prints per-request convergence, the registry hit rate, the
  batching layout, and build latencies (cold vs warm).

Run it twice with the same ``--cache-dir`` to see the warm-start restart:
the second run's builds skip tuning/probes entirely.

``--pack width`` turns on cross-request width packing: compatible
requests coalesce into one enlarged block solve with per-request
retirement (see ``docs/serve.md``).  The summary then also prints the
pack layouts and each request's measured true relative residual, plus
p50/p95/p99 per-request latency for whichever policy ran.
"""

from __future__ import annotations

import argparse
import os
import sys
import time


def build_trace(requests: int, dups: int, scale: int, seed: int = 0):
    """(operators, [(op_index, rhs)]) — shuffled arrival, seeded dups."""
    import numpy as np

    from repro.sparse import aniso_laplace_2d, dg_laplace_2d, fd_laplace_2d

    ops = [
        ("fd2d", fd_laplace_2d(3 * scale)),
        ("aniso2d", aniso_laplace_2d(2 * scale, eps=0.01)),
        ("dg2d", dg_laplace_2d((scale, scale), block=4)),
    ]
    rng = np.random.default_rng(seed)
    fresh = requests - dups
    trace = [
        (int(i % len(ops)), rng.standard_normal(ops[i % len(ops)][1].shape[0]))
        for i in range(fresh)
    ]
    for i in range(dups):  # duplicate payloads of earlier requests
        trace.append(trace[i % fresh])
    # dedicated shuffle stream: the arrival order (and with it the batch
    # layout every benchmark counter derives from) must not depend on the
    # operator sizes, which shift how much of ``rng`` the draws consume
    order = np.random.default_rng(seed + 1).permutation(len(trace))
    return ops, [trace[i] for i in order]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--dups", type=int, default=8,
                    help="duplicate payloads in the trace (dedup hits)")
    ap.add_argument("--scale", type=int, default=8,
                    help="operator size knob (rows grow ~quadratically)")
    ap.add_argument("--t", default="4",
                    help="enlarging factor of the solver template, or 'auto'")
    ap.add_argument("--tol", type=float, default=1e-8)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--max-pending", type=int, default=256)
    ap.add_argument("--cache-dir", default=None,
                    help="warm-start cache directory (persists tuning)")
    ap.add_argument("--devices", type=int, default=0,
                    help="force host devices for a distributed server (re-execs)")
    ap.add_argument("--ppn", type=int, default=4)
    ap.add_argument("--pack", choices=["off", "width"], default="off",
                    help="width-packing policy (off = dispatch batching)")
    ap.add_argument("--max-pack-width", type=int, default=16,
                    help="total packed column budget (requests per pack = "
                         "max-pack-width // t)")
    ap.add_argument("--max-wait-s", type=float, default=0.0,
                    help="packing deadline: close a partial pack once the "
                         "oldest pending request is this old (0 = off)")
    ap.add_argument("--seed", type=int, default=0,
                    help="trace seed (RHS draws + arrival shuffle)")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="write a span trace of the replay: *.json = Chrome/"
                         "Perfetto trace, *.jsonl = append-only event log")
    args = ap.parse_args()
    if args.dups >= args.requests:
        ap.error(f"--dups must be < --requests, got {args.dups} >= {args.requests}")

    if args.devices and "--xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
        os.environ["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={args.devices}"
        os.execv(sys.executable, [sys.executable, "-m", "repro.launch.serve"] + sys.argv[1:])

    import jax

    jax.config.update("jax_enable_x64", True)

    from repro.serve import ECGServer, ServeConfig, latency_percentiles
    from repro.solver import SolverConfig

    tracer = None
    if args.trace:
        from repro.observe import Tracer, open_sink

        tracer = Tracer(sinks=[open_sink(args.trace)])

    t = "auto" if args.t == "auto" else int(args.t)
    mesh = None
    if args.devices:
        mesh = jax.make_mesh(
            (args.devices // args.ppn, args.ppn), ("node", "proc")
        )
    server = ECGServer(
        ServeConfig(
            solver=SolverConfig(t=t, tol=args.tol, adaptive="rankrev"),
            max_batch=args.max_batch,
            max_pending=args.max_pending,
            cache_dir=args.cache_dir,
            packing=dict(
                pack=args.pack,
                max_pack_width=args.max_pack_width,
                max_wait_s=args.max_wait_s,
            ),
        ),
        mesh=mesh,
        tracer=tracer,
    )

    ops, trace = build_trace(args.requests, args.dups, args.scale,
                             seed=args.seed)
    names = [name for name, _ in ops]
    print(f"# trace: {len(trace)} requests over {len(ops)} operators "
          f"({', '.join(f'{n}={a.shape[0]} rows' for n, a in ops)}), "
          f"{args.dups} duplicate payloads")

    t0 = time.perf_counter()
    tickets = [(op_i, server.submit(ops[op_i][1], b)) for op_i, b in trace]
    done = server.flush()
    wall = time.perf_counter() - t0
    assert all(tk.done for _, tk in tickets) and len(done) == 0 or True

    for op_i, tk in tickets:
        res = tk.result
        tag = " dedup" if tk.deduped else ""
        if tk.pack_id is not None:
            where = f"pack  {tk.pack_id:>2} (w{tk.pack_width} g{tk.group_index})"
            tag += f" relres={tk.relres:.1e}"
        else:
            where = f"batch {tk.batch_id:>2} (x{tk.batch_size})"
        print(f"  req {tk.request_id:>3} {names[op_i]:<8} {where} "
              f"iters={res.n_iters:>4} conv={bool(res.converged)}{tag}")

    st = server.stats()
    reg, q = st["registry"], st["queue"]
    print(f"\n{len(trace)} requests in {wall:.3f}s "
          f"({len(trace) / wall:.1f} req/s, policy={args.pack})")
    print(f"registry: {reg['hits']} hits / {reg['misses']} misses "
          f"({reg['evictions']} evictions, {reg['resident']} resident)")
    for rec in reg["builds"]:
        kind = "warm" if rec["warm"] else "cold"
        print(f"  build {rec['fingerprint'][:12]} n={rec['n']} t={rec['t']} "
              f"{kind} {rec['build_s']:.3f}s")
    print(f"batching: {q['batches']} batches {q['batch_sizes']}, "
          f"{q['dedup_shared']} requests served by dedup")
    if q["packs"]:
        for lay in q["pack_layouts"]:
            segs = "".join(
                f" {w}x{it}" for w, it in lay["comm_segments"]
            ) or " (unsegmented)"
            print(f"  pack {lay['pack_id']:>2}: width {lay['width']} = "
                  f"{lay['groups']} x t{lay['t_each']}, exchange{segs}")
    lat = latency_percentiles([tk for _, tk in tickets])
    if lat["n"]:
        print(f"latency: p50={lat['p50'] * 1e3:.1f}ms "
              f"p95={lat['p95'] * 1e3:.1f}ms p99={lat['p99'] * 1e3:.1f}ms "
              f"mean={lat['mean'] * 1e3:.1f}ms over {lat['n']} requests")
    else:
        print("latency: no completed requests")
    roll = q.get("rolling") or {}
    if roll.get("n"):
        print(f"rolling[{roll['window_s']:.0f}s]: {roll['rate_rps']:.1f} req/s")
    if args.cache_dir and any(not r["warm"] for r in reg["builds"]):
        print(f"re-run with --cache-dir {args.cache_dir} for warm builds")
    if tracer is not None:
        tracer.close()
        print(f"# trace written to {args.trace}")


if __name__ == "__main__":
    main()
