"""Inexact / iteration-varying preconditioner — the flexible-ECG path.

Weighted-Jacobi sweeps whose damping depends on the (traced) iteration
index: ``ω_k = ω · (1 − 1/16 · (k mod 2))`` — a deliberately *non-constant*
M⁻¹ₖ.  Enlarged CG is structurally flexible (Moufawad arXiv:2305.19013):
the recurrence orthogonalizes new directions only against the last two
search blocks, so a preconditioner that changes every iteration perturbs
but does not break the short recurrence — exactly the framework the
adaptive width controller already borrows from.  This kind exists to
exercise and test that path, and as the template for plugging in genuinely
inexact inner solves.

The variation is deliberately *mild* (a few percent in the damping, not a
change of polynomial degree): the depth-2 truncated recurrence tolerates a
slowly-varying M⁻¹ₖ but — like truncated flexible CG generally (Notay,
SIAM J. Sci. Comput. 22(4), 2000) — can stagnate outright when M⁻¹ₖ jumps
between structurally different operators every iteration.  That regime
needs the residual-reseeded s-step scheme (whose per-block reseed is an
implicit flexible restart) and is pinned as such in the test suite.

Each sweep is ``y ← y + ω_k D⁻¹ (x − A y)`` from ``y₀ = ω_k D⁻¹ x``; for
any fixed k the map ``x ↦ y`` is linear with a zero fixed point, so
masked-out (zero) columns stay zero and the padded-slot convention
(D = 1 on padding) keeps pads inert.
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp


def extract_diagonal(a, row_of_slot: np.ndarray | None = None) -> np.ndarray:
    """Diagonal of CSR ``a`` — in slot order when ``row_of_slot`` is given
    (1.0 on padding slots so D⁻¹ is inert there)."""
    indptr = np.asarray(a.indptr)
    indices = np.asarray(a.indices)
    data = np.asarray(a.data)
    n = a.shape[0]
    diag = np.zeros(n, dtype=data.dtype)
    for i in range(n):
        lo, hi = indptr[i], indptr[i + 1]
        hit = np.nonzero(indices[lo:hi] == i)[0]
        if hit.size:
            diag[i] = data[lo + hit[0]]
    if np.any(diag <= 0):
        raise ValueError(
            "matrix has a non-positive diagonal entry — weighted Jacobi "
            "needs an SPD matrix"
        )
    if row_of_slot is None:
        return diag
    out = np.ones(row_of_slot.shape[0], dtype=data.dtype)
    live = row_of_slot >= 0
    out[live] = diag[row_of_slot[live]]
    return out


def make_inexact_apply(a_apply, diag, omega: float, sweeps: int):
    """Return ``f(V, k) -> M⁻¹ₖ V``: ``sweeps`` damped-Jacobi sweeps whose
    damping ``ω_k = ω (1 − (k mod 2)/16)`` varies with the iteration."""
    inv_diag = 1.0 / jnp.asarray(diag)

    def apply(x, k):
        dinv = inv_diag[:, None].astype(x.dtype)
        # k-dependent damping (traced): a mild parity wobble that keeps
        # M⁻¹ₖ SPD (0 < ω_k ≤ ω ≤ 1) while making it genuinely non-constant
        om = omega * (1.0 - (jnp.asarray(k, jnp.int32) % 2) / 16.0)
        om = om.astype(x.dtype)
        y0 = om * dinv * x

        def sweep(_, y):
            return y + om * dinv * (x - a_apply(y))

        return jax.lax.fori_loop(0, sweeps - 1, sweep, y0)

    return apply
