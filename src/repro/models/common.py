"""Shared model configuration + sharding rules.

One ``ArchConfig`` covers every assigned family (dense / moe / ssm / hybrid /
encdec / vlm); family-specific fields are ignored elsewhere.  Sharding rules
implement the 2-D FSDP("data") x TP("model") layout of DESIGN.md §4 with
divisibility-aware fallback (jit in_shardings demand exact divisibility).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: int | None = None        # default d_model // n_heads
    mlp: str = "swiglu"              # swiglu | gelu
    tie_embeddings: bool = False
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    # --- moe ---
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    # --- ssm / hybrid ---
    d_state: int = 0
    expand: int = 2
    ssm_head_dim: int = 64
    conv_width: int = 4
    attn_period: int = 0             # hybrid: shared attn block every N layers
    # --- encdec ---
    n_enc_layers: int = 0
    enc_ctx: int = 1500              # whisper frame positions (frontend stub)
    # --- vlm ---
    n_patches: int = 0               # paligemma image prefix length (stub)
    # --- execution knobs (perf levers; see EXPERIMENTS.md §Perf) ---
    dtype: Any = jnp.bfloat16
    seq_parallel: bool = True        # shard residual stream seq over "model"
    remat: bool = True
    attn_logits_f32: bool = True
    unroll: bool = False             # python-loop layers instead of lax.scan
                                     # (dry-run cost extrapolation — XLA cost
                                     # analysis counts scan bodies once)
    # --- §Perf hillclimb levers (see EXPERIMENTS.md §Perf) ---
    attn_chunk: int = 0              # online-softmax attention over KV chunks
                                     # (kills S×S HBM materialization)
    loss_chunk: int = 0              # CE loss computed over sequence chunks
                                     # (kills fp32 full-logit materialization)
    gqa_shard_fix: bool = False      # constrain K/V repeat to head-TP layout
                                     # (avoids GSPMD involuntary remat on
                                     # kv-uneven archs)
    moe_scatter_combine: bool = False  # EP combine via reduce-scatter into the
                                       # seq-sharded residual (vs all-reduce)
    attn_seq_shard: bool = False     # shard attention by query positions over
                                     # "model" instead of heads (no padding
                                     # waste when H % tp != 0; SP-aligned)
    dense_scatter_combine: bool = False  # row-parallel out-projections emit
                                         # reduce-scatter into the seq-sharded
                                         # residual instead of all-reduce
    # padding of the vocab to a multiple (for TP divisibility); logits masked
    vocab_pad_multiple: int = 256

    @property
    def head_dim(self) -> int:
        if self.d_head:
            return self.d_head
        return self.d_model // self.n_heads if self.n_heads else 0

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def vocab_padded(self) -> int:
        m = self.vocab_pad_multiple
        return (self.vocab + m - 1) // m * m

    def with_(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)

    # ------------------------------------------------------------- counting
    def param_count(self) -> int:
        """Analytic parameter count (validated against published sizes)."""
        d, f, dh = self.d_model, self.d_ff, self.head_dim
        attn = d * self.n_heads * dh * 2 + d * self.n_kv_heads * dh * 2
        mlp = (3 if self.mlp == "swiglu" else 2) * d * f
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        if self.family in ("dense", "vlm"):
            return self.n_layers * (attn + mlp) + emb
        if self.family == "moe":
            router = d * self.n_experts
            return self.n_layers * (attn + self.n_experts * mlp + router) + emb
        if self.family == "ssm":
            return self.n_layers * self._ssm_layer_params() + self.vocab * d
        if self.family == "hybrid":
            shared = attn + mlp
            return self.n_layers * self._ssm_layer_params() + shared + self.vocab * d
        if self.family == "encdec":
            enc = self.n_enc_layers * (attn + mlp)
            dec = self.n_layers * (2 * attn + mlp)
            return enc + dec + self.vocab * d
        raise ValueError(self.family)

    def _ssm_layer_params(self) -> int:
        d, di, n, h = self.d_model, self.d_inner, self.d_state, self.n_ssm_heads
        in_proj = d * (2 * di + 2 * n + h)
        return in_proj + di * d + self.conv_width * (di + 2 * n) + 2 * h + di

    def active_param_count(self) -> int:
        """MoE: params touched per token (for MODEL_FLOPS = 6·N_active·D)."""
        if self.family != "moe":
            return self.param_count()
        d, f = self.d_model, self.d_ff
        attn = d * self.n_heads * self.head_dim * 2 + d * self.n_kv_heads * self.head_dim * 2
        mlp = (3 if self.mlp == "swiglu" else 2) * d * f
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        return self.n_layers * (attn + self.top_k * mlp + d * self.n_experts) + emb


# ----------------------------------------------------------------- sharding
@dataclasses.dataclass(frozen=True)
class MeshAxes:
    """Resolved axis names of the active mesh (pod axis optional)."""

    batch: tuple[str, ...]   # ("pod","data") or ("data",)
    fsdp: str | None         # "data"
    model: str | None        # "model"
    sizes: dict[str, int]

    @classmethod
    def from_mesh(cls, mesh: Mesh) -> "MeshAxes":
        names = mesh.axis_names
        batch = tuple(a for a in ("pod", "data") if a in names) or (names[0],)
        return cls(
            batch=batch,
            fsdp="data" if "data" in names else None,
            model="model" if "model" in names else None,
            sizes={n: s for n, s in zip(names, mesh.devices.shape)},
        )

    def size(self, axis: str | None) -> int:
        return self.sizes.get(axis, 1) if axis else 1

    def tp(self, dim: int) -> str | None:
        """'model' if it divides dim, else None (replicate)."""
        m = self.model
        return m if m and dim % self.sizes[m] == 0 else None

    def fs(self, dim: int) -> str | None:
        f = self.fsdp
        return f if f and dim % self.sizes[f] == 0 else None


def named(mesh: Mesh, *spec) -> NamedSharding:
    return NamedSharding(mesh, P(*spec))


def constrain(x, mesh: Mesh, *spec):
    return jax.lax.with_sharding_constraint(x, named(mesh, *spec))


def logical_to_sharding(rules: dict, mesh: Mesh):
    """Map a pytree of PartitionSpecs to NamedShardings."""
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        rules,
        is_leaf=lambda s: isinstance(s, P),
    )
