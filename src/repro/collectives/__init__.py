from repro.collectives.hierarchical import hierarchical_allreduce, tiered_collective_bytes

__all__ = ["hierarchical_allreduce", "tiered_collective_bytes"]
