"""Distributed SpMBV:  W = A · V  with node-aware halo exchange (shard_map).

The matrix is row-partitioned over a ("node", "proc") device grid; block
vectors share the row distribution (paper §3).  The halo exchange replays a
static :class:`~repro.core.node_aware.ExchangePlan` — gather → ppermute →
scatter rounds — then the local SpMBV runs on [own rows ‖ halo rows].

This module also provides the distributed ECG wrapper: the same iteration
body as :func:`repro.core.ecg.ecg_solve` with `psum` reductions, executed
entirely inside one shard_map (so the two fused allreduces of §3.1 appear as
exactly two psums per iteration in the lowered HLO).
"""

from __future__ import annotations

import dataclasses
from functools import partial

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.sparse.csr import CSRMatrix
from repro.sparse.partition import PartitionedMatrix, partition_csr
from repro.core.node_aware import ExchangePlan, ExchangeStep, build_exchange_plan


@dataclasses.dataclass
class DistributedSpMBV:
    """Device-ready distributed SpMBV operator."""

    mesh: Mesh
    plan: ExchangePlan
    n: int                 # true global rows
    rmax: int              # padded rows per device
    starts: np.ndarray     # (p+1,) partition row offsets (true global ids)
    # stacked per-device CSR (sharded on axis 0 at call time)
    indptr: jax.Array      # (p, rmax + 1)
    indices: jax.Array     # (p, nnz_max)  — local ids; halo ids offset by rmax
    data: jax.Array        # (p, nnz_max)
    # stacked per-step exchange arrays
    gathers: list[jax.Array]
    scatters: list[jax.Array]

    @property
    def p(self) -> int:
        return self.plan.p

    @property
    def n_padded(self) -> int:
        return self.p * self.rmax

    # ---------------------------------------------------------------- spec
    @property
    def vec_spec(self) -> P:
        return P(("node", "proc"), None)

    def shard_vector(self, v: np.ndarray | jax.Array, t: int | None = None) -> jax.Array:
        """Lay out a global (n,) or (n, t) array into the padded per-rank
        layout (device r's block holds its partition rows) and device_put."""
        v = np.asarray(v)
        out = np.zeros((self.p * self.rmax,) + v.shape[1:], v.dtype)
        for r in range(self.p):
            lo, hi = self.starts[r], self.starts[r + 1]
            out[r * self.rmax : r * self.rmax + (hi - lo)] = v[lo:hi]
        spec = self.vec_spec if v.ndim > 1 else P(("node", "proc"))
        return jax.device_put(out, NamedSharding(self.mesh, spec))

    def unshard(self, w: jax.Array) -> np.ndarray:
        """Inverse of :meth:`shard_vector`."""
        w = np.asarray(w)
        out = np.zeros((self.n,) + w.shape[1:], w.dtype)
        for r in range(self.p):
            lo, hi = self.starts[r], self.starts[r + 1]
            out[lo:hi] = w[r * self.rmax : r * self.rmax + (hi - lo)]
        return out

    def padded_mask(self) -> np.ndarray:
        """(n_padded,) 1.0 where the slot backs a true row."""
        m = np.zeros(self.p * self.rmax)
        for r in range(self.p):
            lo, hi = self.starts[r], self.starts[r + 1]
            m[r * self.rmax : r * self.rmax + (hi - lo)] = 1.0
        return m

    def true_row_of_slot(self) -> np.ndarray:
        """(n_padded,) true global row id per padded slot (-1 for pads)."""
        m = np.full(self.p * self.rmax, -1, dtype=np.int64)
        for r in range(self.p):
            lo, hi = self.starts[r], self.starts[r + 1]
            m[r * self.rmax : r * self.rmax + (hi - lo)] = np.arange(lo, hi)
        return m

    # ------------------------------------------------------------- exchange
    def _exchange(self, x_local: jax.Array, gathers, scatters) -> jax.Array:
        """Per-device halo exchange.  x_local: (rmax, t) block rows."""
        t = x_local.shape[-1]
        plan = self.plan
        halo = jnp.zeros((plan.halo_size + 1, t), x_local.dtype)
        stage = jnp.zeros((plan.stage_size + 1, t), x_local.dtype)
        for step, g_idx, s_pos in zip(plan.steps, gathers, scatters):
            src = x_local if step.src == "x" else stage
            buf = src[g_idx]  # (c, t)
            if step.offset:
                axis = ("node", "proc") if step.axis == "flat" else step.axis
                buf = jax.lax.ppermute(buf, axis, _perm(step, plan))
            if step.dst == "halo":
                halo = halo.at[s_pos].set(buf)
            else:
                stage = stage.at[s_pos].set(buf)
        return halo[: plan.halo_size]

    def _local_spmbv(self, x_local, halo, indptr, indices, data):
        """CSR SpMBV over [own ‖ halo] rows; returns (rmax, t)."""
        xfull = jnp.concatenate([x_local, halo], axis=0)
        rows = jnp.repeat(
            jnp.arange(self.rmax, dtype=jnp.int32),
            jnp.diff(indptr),
            total_repeat_length=indices.shape[0],
        )
        prod = data[:, None] * xfull[indices]
        return jax.ops.segment_sum(prod, rows, num_segments=self.rmax)

    # ------------------------------------------------------------------ api
    def matvec_fn(self):
        """Returns f(V_sharded (n_padded, t)) -> (n_padded, t), jit-able."""
        plan = self.plan

        def per_device(v, indptr, indices, data, *exchange_arrays):
            k = len(plan.steps)
            gathers = [a[0] for a in exchange_arrays[:k]]
            scatters = [a[0] for a in exchange_arrays[k:]]
            v = v.reshape(self.rmax, -1)
            halo = self._exchange(v, gathers, scatters)
            w = self._local_spmbv(v, halo, indptr[0], indices[0], data[0])
            return w.reshape(v.shape)

        dev_specs = P(("node", "proc"),)
        smapped = shard_map(
            per_device,
            mesh=self.mesh,
            in_specs=(self.vec_spec, dev_specs, dev_specs, dev_specs)
            + (dev_specs,) * (2 * len(plan.steps)),
            out_specs=self.vec_spec,
            check_rep=False,
        )

        def apply(v):
            return smapped(v, self.indptr, self.indices, self.data, *self.gathers, *self.scatters)

        return apply


def _perm(step: ExchangeStep, plan: ExchangePlan):
    if step.axis == "proc":
        n = plan.ppn
    elif step.axis == "node":
        n = plan.n_nodes
    else:
        n = plan.p
    return [(i, (i + step.offset) % n) for i in range(n)]


def make_distributed_spmbv(
    a: CSRMatrix,
    mesh: Mesh,
    strategy: str = "standard",
    t: int = 1,
    machine=None,
    pm: PartitionedMatrix | None = None,
) -> DistributedSpMBV:
    """Partition ``a`` over ``mesh`` and build the device-ready operator."""
    n_nodes, ppn = mesh.devices.shape
    p = n_nodes * ppn
    pm = pm or partition_csr(a, p)
    plan = build_exchange_plan(pm, n_nodes, ppn, strategy, t=t, machine=machine)

    rmax = pm.part.max_local_rows
    nnz_max = max(len(ix) for ix in pm.local_indices)
    indptr = np.zeros((p, rmax + 1), np.int32)
    indices = np.zeros((p, nnz_max), np.int32)
    data = np.zeros((p, nnz_max), np.asarray(pm.local_data[0]).dtype)
    for r in range(p):
        lo, hi = pm.part.local_range(r)
        n_local = hi - lo
        ptr = pm.local_indptr[r]
        indptr[r, : n_local + 1] = ptr
        indptr[r, n_local + 1 :] = ptr[-1]
        k = len(pm.local_indices[r])
        # halo ids were n_local-based; re-base to rmax so x can be padded
        ix = pm.local_indices[r].astype(np.int64)
        ix = np.where(ix >= n_local, ix - n_local + rmax, ix)
        indices[r, :k] = ix
        data[r, :k] = pm.local_data[r]

    dev_sharding = NamedSharding(mesh, P(("node", "proc")))
    put = lambda arr: jax.device_put(jnp.asarray(arr), dev_sharding)
    return DistributedSpMBV(
        mesh=mesh,
        plan=plan,
        n=a.shape[0],
        rmax=rmax,
        starts=pm.part.starts,
        indptr=put(indptr),
        indices=put(indices),
        data=put(data),
        gathers=[put(s.gather_idx) for s in plan.steps],
        scatters=[put(s.scatter_pos) for s in plan.steps],
    )


# ----------------------------------------------------------------------------
# distributed ECG: same body as core.ecg, inside one shard_map
# ----------------------------------------------------------------------------
def distributed_ecg(
    a: CSRMatrix,
    b: np.ndarray,
    mesh: Mesh,
    t: int,
    strategy: str = "standard",
    tol: float = 1e-8,
    max_iters: int = 500,
    machine=None,
):
    """Distributed ECG solve with the selected node-aware SpMBV strategy.

    Runs the whole while_loop inside jit with the distributed operator; the
    two fused reductions appear as psums over ("node", "proc").
    """
    from repro.core.ecg import ecg_solve

    op = make_distributed_spmbv(a, mesh, strategy, t=t, machine=machine)
    apply_a = op.matvec_fn()
    b_sh = op.shard_vector(b)
    n_pad = op.n_padded
    axes = ("node", "proc")
    vspec = op.vec_spec

    # fused reductions (§3.1): exactly one psum each, via shard_map
    gram1 = shard_map(
        lambda z, az: jax.lax.psum(z.T @ az, axes),
        mesh=mesh,
        in_specs=(vspec, vspec),
        out_specs=P(None, None),
        check_rep=False,
    )
    gram2 = shard_map(
        lambda pp, rr, ap, apo: jax.lax.psum(
            jnp.concatenate([pp.T @ rr, ap.T @ ap, apo.T @ ap], axis=1), axes
        ),
        mesh=mesh,
        in_specs=(vspec,) * 4,
        out_specs=P(None, None),
        check_rep=False,
    )
    sqnorm = shard_map(
        lambda v: jax.lax.psum(jnp.vdot(v, v), axes),
        mesh=mesh,
        in_specs=P(("node", "proc")),
        out_specs=P(),
        check_rep=False,
    )

    # T_{r,t} on the padded layout: subdomains follow *true* global row ids so
    # the splitting matches the sequential solver exactly; pad slots masked.
    true_rows = op.true_row_of_slot()
    sub = np.where(true_rows >= 0, (true_rows * t) // op.n, 0)
    onehot_np = np.zeros((n_pad, t))
    onehot_np[np.arange(n_pad), np.minimum(sub, t - 1)] = (true_rows >= 0).astype(float)
    onehot = jax.device_put(
        jnp.asarray(onehot_np, b_sh.dtype), NamedSharding(mesh, op.vec_spec)
    )

    def split(r, t_):
        return r[:, None] * onehot

    result = ecg_solve(
        apply_a,
        b_sh,
        t=t,
        tol=tol,
        max_iters=max_iters,
        split=split,
        gram1=gram1,
        gram2=gram2,
        sqnorm=sqnorm,
    )
    return result, op
