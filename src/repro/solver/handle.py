"""The compile-once / solve-many ECG session handle.

The paper's premise is that ECG setup cost — partitioning, node-aware
exchange planning, tuning — is paid once and amortized over the solve
(§4).  :class:`ECGSolver` is that amortization made explicit in the API:

    from repro.solver import ECGSolver, SolverConfig, CommConfig

    solver = ECGSolver.build(a, mesh, SolverConfig(
        t=8, tol=1e-8, comm=CommConfig(strategy="3step"),
    ))
    res = solver.solve(b)           # first call traces + compiles the loop
    more = solver.solve_many(bs)    # every further RHS reuses the program

``build`` performs partitioning, :class:`~repro.core.node_aware.ExchangePlan`
construction, autotuning, ``t="auto"`` selection, and Block-ELL conversion
exactly once.  ``solve`` wraps the whole guarded while-loop (initial
residual included) in one ``jax.jit`` per active width, so a second solve
with the same operand shape/dtype is a pure cache hit —
``ECGSolver.stats.traces`` counts retraces and stays flat across repeated
solves (asserted in the test suite).  ``with_config`` derives a sibling
handle cheaply: overrides that only touch the solve loop (tol, max_iters,
the adaptive policy) reuse the operator and plan outright; operator-level
overrides rebuild it but always reuse the row partition.

The legacy functional spellings (``ecg_solve`` / ``distributed_ecg`` /
``make_distributed_spmbv``) are thin deprecated wrappers over this handle
and its machinery — see ``docs/api.md`` for the migration table.
"""

from __future__ import annotations

import dataclasses
import warnings

import numpy as np
import jax
import jax.numpy as jnp

from repro.adaptive.groups import GroupSpec
from repro.adaptive.reduce import resolve_policy
from repro.core.cg import SolveResult
from repro.core.ecg import finalize_result, make_ecg_runner
from repro.observe.tracer import coerce_tracer
from repro.solver.config import SolverConfig


@dataclasses.dataclass
class SolverStats:
    """Build/compile accounting of one handle (reuse made observable)."""

    builds: int = 0            # operator/plan constructions this handle paid
    traces: int = 0            # solve-loop (re)traces; flat across cache hits
    solves: int = 0            # solve() calls served
    partition_reused: bool = False  # with_config reused the parent partition
    op_reused: bool = False         # with_config reused the parent operator
    conv_analyzed: bool = False     # this build ran the CSR→Block-ELL tile
    #                                 analysis (the expensive conversion pass)
    conv_reused: bool = False       # this build skipped conversion entirely
    #                                 (precomputed Block-ELL arrays supplied)


class ECGSolver:
    """Compile-once / solve-many ECG session (see module docstring).

    Attributes after ``build``:

    t:         the resolved enlarging factor (an int, even for ``t="auto"``).
    op:        the :class:`~repro.sparse.spmbv.DistributedSpMBV` operator
               (None for a sequential, single-device handle).
    tuned:     the applied :class:`~repro.tune.TunedConfig` (None untuned).
    selection: the :class:`~repro.adaptive.TSelection` when ``t="auto"``.
    policy:    the resolved in-solve :class:`~repro.adaptive.ReductionPolicy`.
    stats:     :class:`SolverStats` — builds/traces/solves/reuse flags.
    """

    def __init__(self, *args, **kwargs):
        raise TypeError("use ECGSolver.build(a, mesh=None, config=...) ")

    # ------------------------------------------------------------- building
    @classmethod
    def build(
        cls,
        a,
        mesh=None,
        config: SolverConfig | dict | None = None,
        *,
        b=None,
        pm=None,
        conversion=None,
        tracer=None,
    ) -> "ECGSolver":
        """Build a solver handle for matrix ``a``.

        a:      :class:`~repro.sparse.csr.CSRMatrix` (SPD).
        mesh:   a ``("node", "proc")`` device mesh for the distributed
                node-aware solver, or None for the sequential solver.
        config: a :class:`SolverConfig` (or dict of its fields).
        b:      optional probe right-hand side for ``t="auto"`` (defaults to
                a seeded Gaussian — the selection only needs a representative
                RHS, but passing the real one sharpens the probe).
        pm:     optional precomputed partition to reuse.
        conversion: optional CSR→Block-ELL conversion artifacts to reuse
                (sequential ``backend="pallas"`` only) — a dict with
                ``"arrays"`` (a previous handle's ``self.conversion["arrays"]``
                — skips the conversion outright) and/or ``"meta"`` (the tile
                analysis from :func:`repro.kernels.block_ell_meta` — skips
                the analysis pass).  Mismatched artifacts (different tile,
                shape, or dtype) are ignored, never an error.
        tracer: a :class:`repro.observe.Tracer` to record build-phase and
                solve-segment spans on (default: the process tracer —
                normally the free null tracer, so instrumentation is a
                no-op unless one was installed).
        """
        self = cls.__new__(cls)
        self.a = a
        self.mesh = mesh
        self.config = SolverConfig.coerce(config)
        self._tracer = coerce_tracer(tracer)
        self.stats = SolverStats()
        self.selection = None
        self.tuned = None
        self.op = None
        self._pm = pm
        self._probe_b = b
        self._runners: dict = {}
        self._jits: dict = {}
        self._onehot_cache: dict = {}
        self._packed_applies: dict = {}
        self._conversion_in = conversion
        self.conversion = None
        with self._tracer.span(
            "build", cat="build", n=int(a.shape[0]), nnz=int(a.nnz),
            distributed=mesh is not None,
        ) as sp:
            self._build()
            sp.args["t"] = int(self.t)
        self._tracer.counter("solver.builds", self.stats.builds)
        return self

    def _auto_probe_b(self):
        if self._probe_b is not None:
            return self._probe_b
        return np.random.default_rng(0).standard_normal(self.a.shape[0])

    def _build(self):
        if self.mesh is None:
            self._build_sequential()
        else:
            self._build_distributed()

    def _build_sequential(self):
        from repro.sparse.csr import csr_spmbv

        cfg = self.config
        t = cfg.t
        adaptive = "off" if cfg.adaptive.explicit_off else cfg.adaptive.policy
        tuned = cfg.tune.tuned
        if cfg.tune.mode == "measure":
            raise ValueError(
                'tune mode "measure" times candidate operators on a device '
                "mesh; build the handle with mesh= (or use mode='model')"
            )
        if isinstance(t, str):  # "auto"
            from repro.adaptive.select_t import resolve_auto_t

            with self._tracer.span("build/select_t", cat="build"):
                t, self.selection, adaptive = resolve_auto_t(
                    "auto", adaptive, a=self.a, b=self._auto_probe_b(),
                    select=cfg.adaptive.select,
                    candidates=cfg.adaptive.t_candidates,
                    tol=cfg.tol, machine=cfg.comm.machine,
                    backend=cfg.kernel.backend,
                    probe_iters=cfg.adaptive.probe_iters,
                    probe_rtol=cfg.adaptive.probe_rtol,
                    method=cfg.method.name, s=cfg.method.s,
                    reorth=cfg.method.reorth,
                )
            if tuned is None and cfg.kernel.backend == "pallas":
                # execute the tile the candidate costs were modeled with
                tuned = self.selection.configs.get(t)
        elif tuned is None and cfg.tune.active and cfg.kernel.backend == "pallas":
            from repro.tune import tune as run_tune

            with self._tracer.span("build/tune", cat="build",
                                   mode=cfg.tune.mode):
                tuned = run_tune(
                    self.a, t=t, machine=cfg.comm.machine, n_nodes=1, ppn=1,
                    backend="pallas", mode=cfg.tune.mode,
                )
        self.stats.builds += 1
        self.tuned = tuned
        self.t = t
        self.policy = resolve_policy(adaptive)
        self._segmented = False
        ell_block = tuned.ell_block if tuned is not None else cfg.kernel.ell_block
        if cfg.kernel.backend == "pallas":
            with self._tracer.span("build/convert", cat="build") as sp:
                self._build_ell_apply(ell_block)
                sp.args.update(
                    analyzed=self.stats.conv_analyzed,
                    reused=self.stats.conv_reused,
                )
        else:
            self._apply = lambda V: csr_spmbv(self.a, V)
        self._gram1 = self._gram2 = self._sqnorm = self._tail = None
        self._gram2p = self._sqnorm_cols = None
        self._split_fn = None
        self._precond = self._build_precond()

    def _build_ell_apply(self, ell_block):
        """Sequential Block-ELL apply, reusing supplied conversion artifacts.

        Priority: precomputed arrays (skip conversion outright — the
        eviction-aware warm path) > tile-analysis meta (skip the analysis
        pass, direct-fill the blocks) > full cold conversion.  The produced
        artifacts are published on ``self.conversion`` so the serve registry
        can persist/reshare them; ``stats.conv_analyzed``/``conv_reused``
        make the chosen path observable (gated in serve_bench).
        """
        from repro.kernels import make_block_ell_apply_from_arrays
        from repro.kernels.bsr_spmbv.ops import block_ell_arrays

        br, bc = (
            (ell_block, ell_block) if isinstance(ell_block, int) else ell_block
        )
        conv_in = self._conversion_in or {}
        reuse = conv_in.get("arrays")
        dtype = str(np.dtype(self.a.data.dtype))
        if reuse is not None and not (
            reuse.get("br") == br
            and reuse.get("bc") == bc
            and reuse.get("shape") == tuple(self.a.shape)
            and reuse.get("dtype") == dtype
        ):
            reuse = None  # stale artifacts (tile/shape/dtype changed): ignore
        if reuse is not None:
            blocks, indices, m_pad = (
                reuse["blocks"], reuse["indices"], reuse["m_pad"]
            )
            meta = reuse.get("meta")
            self.stats.conv_reused = True
        else:
            blocks, indices, m_pad, meta, analyzed = block_ell_arrays(
                self.a, br, bc, meta=conv_in.get("meta")
            )
            self.stats.conv_analyzed = analyzed
        self._apply = make_block_ell_apply_from_arrays(
            blocks, indices, m_pad, self.a.shape[0]
        )
        self.conversion = dict(
            arrays=dict(
                blocks=blocks, indices=indices, m_pad=m_pad,
                br=br, bc=bc, shape=tuple(self.a.shape), dtype=dtype,
                meta=meta,
            ),
            meta=meta,
        )

    def _build_distributed(self):
        from repro.sparse.partition import partition_csr
        from repro.sparse.spmbv import _make_distributed_spmbv

        cfg = self.config
        n_nodes, ppn = self.mesh.devices.shape
        if self._pm is None:
            with self._tracer.span("build/partition", cat="build",
                                   p=n_nodes * ppn):
                self._pm = partition_csr(self.a, n_nodes * ppn)

        t = cfg.t
        adaptive = "off" if cfg.adaptive.explicit_off else cfg.adaptive.policy
        tune_arg = cfg.tune.tuned if cfg.tune.tuned is not None else cfg.tune.mode
        strategy = cfg.comm.strategy
        overlap = cfg.comm.overlap
        ell_block = cfg.kernel.ell_block
        if isinstance(t, str):  # "auto"
            from repro.adaptive.select_t import resolve_auto_t

            tune_mode = (
                cfg.tune.mode if cfg.tune.mode in ("model", "model:structural")
                else "model"
            )
            with self._tracer.span("build/select_t", cat="build"):
                t, self.selection, adaptive = resolve_auto_t(
                    "auto", adaptive, a=self.a, b=self._auto_probe_b(),
                    select=cfg.adaptive.select,
                    candidates=cfg.adaptive.t_candidates,
                    tol=cfg.tol, machine=cfg.comm.machine,
                    n_nodes=n_nodes, ppn=ppn,
                    backend=cfg.kernel.backend, tune_mode=tune_mode,
                    probe_iters=cfg.adaptive.probe_iters,
                    probe_rtol=cfg.adaptive.probe_rtol,
                    method=cfg.method.name, s=cfg.method.s,
                    reorth=cfg.method.reorth,
                )
            if not cfg.tune.active:
                # execute the exact config the choice was modeled with — a t
                # optimized for one (strategy, tile, overlap) but run under
                # another would make the selection meaningless.  Explicit
                # comm/kernel settings are overridden (warn when that
                # discards a non-default request).
                tcfg = self.selection.configs.get(t)
                if tcfg is not None:
                    if strategy != "standard" or overlap or ell_block != (8, 8):
                        warnings.warn(
                            "t='auto' executes the tuner config its choice was "
                            f"modeled with ({tcfg.strategy}/{tcfg.ell_block}/"
                            f"{'overlap' if tcfg.overlap else 'blocking'}); the "
                            f"explicit strategy={strategy!r}/overlap={overlap}/"
                            f"ell_block={ell_block} settings are ignored — pass "
                            "a fixed t to force them",
                            stacklevel=4,
                        )
                    tune_arg = tcfg
        # one span for plan construction + tuning + Block-ELL conversion:
        # _make_distributed_spmbv owns those phases, and the span's
        # structural attributes (wire bytes, packed dispatch count) are the
        # accounting every later solve span inherits
        with self._tracer.span(
            "build/operator", cat="build", strategy=strategy, t=int(t),
        ) as sp:
            self.op = _make_distributed_spmbv(
                self.a, self.mesh, strategy, t=t, machine=cfg.comm.machine,
                pm=self._pm, backend=cfg.kernel.backend, overlap=overlap,
                ell_block=ell_block, tune=tune_arg,
                col_split=cfg.comm.col_split,
            )
            f = int(np.dtype(self.a.data.dtype).itemsize)
            sp.args.update(
                wire_bytes=int(self.op.plan.wire_bytes(f)),
                dispatch_count=int(self.op.plan.dispatch_count(packed=True)),
                tuned_strategy=(
                    self.op.tuned.strategy if self.op.tuned else strategy
                ),
            )
        self.stats.builds += 1
        if self.selection is not None and self.op.tuned is not None:
            self.op.tuned = dataclasses.replace(
                self.op.tuned, selection=self.selection
            )
        self.tuned = self.op.tuned
        self.t = t
        self.policy = resolve_policy(adaptive)
        self._segmented = self.policy is not None and not self.policy.restart
        self._apply = self.op.matvec_fn()
        with self._tracer.span("build/reducers", cat="build"):
            self._build_reducers()
        self._precond = self._build_precond()

    def _build_reducers(self):
        """The fused shard_map reductions of §3.1 (one psum each) and the
        padded-layout T_{r,t} splitting — built once per operator."""
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        from repro.kernels.block_update.ops import ecg_tail
        from repro.kernels.fused_gram.ops import fused_gram

        op, mesh = self.op, self.mesh
        backend = self.config.kernel.backend
        axes = ("node", "proc")
        vspec = op.vec_spec
        self._gram1 = shard_map(
            lambda z, az: jax.lax.psum(z.T @ az, axes),
            mesh=mesh,
            in_specs=(vspec, vspec),
            out_specs=P(None, None),
            check_rep=False,
        )
        if backend == "pallas":
            self._gram2 = shard_map(
                lambda pp, rr, ap, apo: jax.lax.psum(
                    fused_gram(pp, rr, ap, apo), axes
                ),
                mesh=mesh,
                in_specs=(vspec,) * 4,
                out_specs=P(None, None),
                check_rep=False,
            )
            self._tail = shard_map(
                lambda x, r, pp, ap, po, c, d, do: ecg_tail(
                    x, r, pp, ap, po, c, d, do
                ),
                mesh=mesh,
                in_specs=(vspec,) * 5 + (P(None, None),) * 3,
                out_specs=(vspec, vspec, vspec),
                check_rep=False,
            )
        else:
            self._gram2 = shard_map(
                lambda pp, rr, ap, apo: jax.lax.psum(
                    jnp.concatenate([pp.T @ rr, ap.T @ ap, apo.T @ ap], axis=1),
                    axes,
                ),
                mesh=mesh,
                in_specs=(vspec,) * 4,
                out_specs=P(None, None),
                check_rep=False,
            )
            self._tail = None
        self._sqnorm = shard_map(
            lambda v: jax.lax.psum(jnp.vdot(v, v), axes),
            mesh=mesh,
            in_specs=P(("node", "proc")),
            out_specs=P(),
            check_rep=False,
        )
        # per-column squared norms for packed multi-RHS solves: one psum of
        # g floats that REPLACES the scalar sqnorm collective in group mode
        # (the per-iteration collective count is identical to a solo solve)
        self._sqnorm_cols = shard_map(
            lambda m: jax.lax.psum(jnp.sum(m * m, axis=0), axes),
            mesh=mesh,
            in_specs=vspec,
            out_specs=P(None),
            check_rep=False,
        )
        # preconditioned packed reduction [PᵀR | APᵀW | AP_oldᵀW]: three
        # asymmetric products the fused_gram kernel cannot express, fused
        # locally so the payload still rides ONE psum — the §3.1 two-psum
        # structure survives preconditioning (asserted in dist_worker.py)
        self._gram2p = shard_map(
            lambda pp, rr, ap, apo, w: jax.lax.psum(
                jnp.concatenate([pp.T @ rr, ap.T @ w, apo.T @ w], axis=1),
                axes,
            ),
            mesh=mesh,
            in_specs=(vspec,) * 5,
            out_specs=P(None, None),
            check_rep=False,
        )

        # T_{r,t} on the padded layout: subdomains follow *true* global row
        # ids so the splitting matches the sequential solver exactly.
        t = self.t
        true_rows = op.true_row_of_slot()
        sub = np.where(true_rows >= 0, (true_rows * t) // op.n, 0)
        onehot_np = np.zeros((op.n_padded, t))
        onehot_np[np.arange(op.n_padded), np.minimum(sub, t - 1)] = (
            true_rows >= 0
        ).astype(float)
        self._onehot_np = onehot_np

        def split(r, t_):
            return r[:, None] * self._onehot(r.dtype)

        self._split_fn = split

    def _build_precond(self):
        """Build the preconditioner apply for this handle's operator
        (None when ``config.precondition`` is inactive)."""
        cfg = self.config
        if not cfg.precondition.active:
            return None
        if self.mesh is None:
            from repro.precondition import build_sequential_preconditioner

            return build_sequential_preconditioner(
                self.a, cfg.precondition, self._apply
            )
        from repro.precondition import build_distributed_preconditioner

        return build_distributed_preconditioner(
            self.a, cfg.precondition, self.op, self.mesh, self._apply
        )

    def _onehot(self, dtype):
        """Device-resident T_{r,t} one-hot for ``dtype``.

        Must be warmed *outside* a trace (see :meth:`solve`): the cached
        value is a concrete sharded array that the traced split closure then
        captures as a constant — device_put during tracing would leak a
        tracer into the cache.
        """
        from jax.sharding import NamedSharding

        key = jnp.dtype(dtype).name
        hit = self._onehot_cache.get(key)
        if hit is None:
            hit = jax.device_put(
                jnp.asarray(self._onehot_np, dtype),
                NamedSharding(self.mesh, self.op.vec_spec),
            )
            self._onehot_cache[key] = hit
        return hit

    # ------------------------------------------------------------- runners
    def _runner(self, width: int):
        runner = self._runners.get(width)
        if runner is None:
            cfg = self.config
            masked = None
            exit_bw = None
            if self._segmented:
                # Width-segmented exchange: the full-width segment still
                # carries the active mask (so the loop can exit on a
                # reduction event); narrower segments compact the payload.
                masked = (
                    (lambda z, act: self._apply(z)) if width == self.t
                    else self.op.masked_matvec_fn(width)
                )
                exit_bw = width
            runner = make_ecg_runner(
                self._apply, self.t, tol=cfg.tol, max_iters=cfg.max_iters,
                split=self._split_fn, gram1=self._gram1, gram2=self._gram2,
                sqnorm=self._sqnorm, tail=self._tail,
                backend=cfg.kernel.backend, policy=self.policy,
                a_apply_masked=masked, exit_below_width=exit_bw,
                method=cfg.method.name, s=cfg.method.s,
                reorth=cfg.method.reorth, rank_rtol=cfg.method.rank_rtol,
                precond=self._precond, gram2p=self._gram2p,
                precond_reseed=(
                    cfg.precondition.reseed
                    if cfg.precondition.kind == "inexact"
                    else None
                ),
            )
            self._runners[width] = runner
        return runner

    def _jit(self, width: int, kind: str):
        key = (width, kind)
        fn = self._jits.get(key)
        if fn is None:
            runner = self._runner(width)
            if kind == "fresh":
                def go(b, x0):
                    self.stats.traces += 1  # trace-time side effect only
                    return runner.run(runner.init(b, x0))
            else:
                def go(carry):
                    self.stats.traces += 1
                    return runner.run(carry)
            fn = jax.jit(go)
            self._jits[key] = fn
        return fn

    # -------------------------------------------------------------- solving
    def _device_vec(self, v):
        if self.mesh is not None:
            return self.op.shard_vector(np.asarray(v))
        return jnp.asarray(v)

    def _struct_attrs(self, width: int) -> dict:
        """Structural accounting of one solve segment at active ``width``
        — the attributes that make a trace self-describing (plan wire
        bytes at the re-sliced width, packed dispatch count, the scheme's
        psums/iteration).  Called only when tracing is enabled."""
        from repro.core.methods import get_method

        cfg = self.config
        spec = get_method(cfg.method.name)
        attrs = dict(psums_per_iter=float(
            spec.collectives_per_iteration(cfg.method.s, cfg.method.reorth)
        ))
        if self.op is not None:
            f = int(np.dtype(self.a.data.dtype).itemsize)
            plan_w = self.op.plan.at_width(width)
            attrs.update(
                wire_bytes=int(plan_w.wire_bytes(f)),
                dispatch_count=int(plan_w.dispatch_count(packed=True)),
            )
        return attrs

    def _emit_solve_telemetry(self, result):
        """Counters + per-iteration event markers for one finished solve.

        Lifts the recovery/reseed/re-slice events out of the device-side
        histories (``iter_trace`` is the reader) — a host transfer, so
        strictly gated on the tracer being enabled."""
        tr = self._tracer
        if not tr.enabled:
            return
        tr.counter("solver.solves", self.stats.solves)
        tr.counter("solver.traces", self.stats.traces)
        for k, before, after in result.reduction_events():
            tr.instant("solve/width_change", k=k, before=before, after=after)
        for k in result.recovery_events():
            tr.instant("solve/recovery", k=k)
        for k in result.reseed_events():
            tr.instant("solve/reseed", k=k)

    def solve(self, b, x0=None):
        """Solve A x = b; returns a :class:`~repro.core.cg.SolveResult`.

        ``b``/``x0`` are global (n,) vectors (numpy or jax); on a
        distributed handle they are laid out onto the mesh here and the
        returned ``res.x`` is in the padded per-rank layout — use
        :meth:`unshard` for the global vector.  The first call traces and
        compiles the solve loop; subsequent calls with the same operand
        shape/dtype reuse the compiled program (``stats.traces`` is flat).
        """
        cfg = self.config
        b_dev = self._device_vec(b)
        x0_dev = jnp.zeros_like(b_dev) if x0 is None else self._device_vec(x0)
        if self.mesh is not None:
            self._onehot(b_dev.dtype)  # warm eagerly — a trace must not put
        tr = self._tracer
        if not self._segmented:
            # dispatch span: the async enqueue only; finalize covers the
            # host syncs — together they bracket the whole device solve
            with tr.span("solve/dispatch", cat="solve", width=self.t) as spd:
                out = self._jit(self.t, "fresh")(b_dev, x0_dev)
            with tr.span("solve/finalize", cat="solve") as spf:
                result = finalize_result(
                    out, x0=x0_dev, t=self.t, tol=cfg.tol, policy=self.policy,
                    selection=self.selection,
                )
                spf.args.update(iters=result.n_iters,
                                converged=bool(result.converged))
            if tr.enabled:
                # one segment span covering dispatch through the finalize
                # host sync — the unsegmented solve's (width, iters, wall)
                tr.emit(
                    "solve/segment", spd.t0, spf.t0 + spf.dur - spd.t0,
                    cat="solve", width=self.t, iters=result.n_iters,
                    **self._struct_attrs(self.t),
                )
        else:
            # Width-segmented solve: each segment runs the jitted loop with
            # the exchange compacted to the current static active width;
            # when the reduction controller retires directions the loop
            # exits, the plan is re-sliced at the new width (cached host
            # work, no rebuild), and the solve resumes from the same carry.
            t_seg, carry, k_prev, segments = self.t, None, 0, []
            while True:
                with tr.span("solve/segment", cat="solve",
                             width=t_seg) as sp:
                    if carry is None:
                        carry = self._jit(t_seg, "fresh")(b_dev, x0_dev)
                    else:
                        carry = self._jit(t_seg, "resume")(carry)
                    k = int(carry["k"])
                    bd = bool(carry["bd"])
                    it_seg = k - k_prev
                    sp.args["iters"] = it_seg
                    if tr.enabled:
                        sp.args.update(self._struct_attrs(t_seg))
                segments.append((t_seg, it_seg))
                k_prev = k
                n_act = int(jnp.sum(carry["act"]))
                if (
                    bool(carry["rn"] <= cfg.tol)
                    or bd
                    or k >= cfg.max_iters
                    or n_act >= t_seg
                    # every direction dead (rank-0 Gram without a non-finite
                    # iterate) or a zero-progress segment: nothing a narrower
                    # re-slice could fix — stop instead of spinning
                    or n_act == 0
                    or it_seg == 0
                ):
                    break
                t_seg = max(n_act, 1)  # width-reduction event -> re-slice
            with tr.span("solve/finalize", cat="solve"):
                result = finalize_result(
                    carry, x0=x0_dev, t=self.t, tol=cfg.tol,
                    policy=self.policy, selection=self.selection,
                )
            result.comm_segments = segments
        self.stats.solves += 1
        self._emit_solve_telemetry(result)
        return result

    def solve_many(self, bs, x0s=None):
        """Solve the same operator against many right-hand sides.

        Every solve reuses the jitted while-loop — after the first solve,
        no retrace or recompile happens (asserted in the test suite via
        ``stats.traces``).  On a non-segmented handle the solves are
        *dispatch-pipelined*: all of them are enqueued on the device
        before the first host sync, so finalizing result ``i`` (the
        ``int(k)``/``bool(rn <= tol)`` transfers) overlaps the device
        compute of result ``i+1``.  Results are exactly what per-RHS
        :meth:`solve` calls would return — same programs, same operands.
        """
        x0s = [None] * len(bs) if x0s is None else list(x0s)
        if len(x0s) != len(bs):
            raise ValueError(f"got {len(bs)} rhs but {len(x0s)} initial guesses")
        if self._segmented:
            # width-segmented solves sync the host between segments anyway
            return [self.solve(b, x0) for b, x0 in zip(bs, x0s)]
        cfg = self.config
        tr = self._tracer
        fn = None
        outs = []
        # the dispatch span covers only async enqueues — it must NOT force
        # a host sync, or the pipelining this method exists for is gone
        with tr.span("solve_many/dispatch", cat="solve",
                     requests=len(bs), width=self.t):
            for b, x0 in zip(bs, x0s):
                b_dev = self._device_vec(b)
                x0_dev = (
                    jnp.zeros_like(b_dev) if x0 is None
                    else self._device_vec(x0)
                )
                if self.mesh is not None:
                    self._onehot(b_dev.dtype)  # warm eagerly — a trace must
                    #                            not put
                if fn is None:
                    fn = self._jit(self.t, "fresh")
                outs.append((fn(b_dev, x0_dev), x0_dev))
                self.stats.solves += 1
        with tr.span("solve_many/finalize", cat="solve", requests=len(bs)):
            results = [
                finalize_result(
                    out, x0=x0_dev, t=self.t, tol=cfg.tol, policy=self.policy,
                    selection=self.selection,
                )
                for out, x0_dev in outs
            ]
        if tr.enabled:
            tr.counter("solver.solves", self.stats.solves)
            tr.counter("solver.traces", self.stats.traces)
        return results

    # ------------------------------------------------------- packed solving
    def _packed_apply(self, width: int):
        """Full-width SpMBV for a packed solve (re-sliced plan at ``width``)."""
        fn = self._packed_applies.get(width)
        if fn is None:
            fn = self.op.matvec_fn(t_active=width)
            self._packed_applies[width] = fn
        return fn

    def _packed_runner(self, spec: GroupSpec, width_seg: int):
        key = ("pack", spec, width_seg)
        runner = self._runners.get(key)
        if runner is None:
            cfg = self.config
            width = spec.width
            if self.mesh is None:
                apply_w = self._apply  # width-polymorphic CSR/Block-ELL apply
                masked = None
                exit_bw = None
            else:
                apply_w = self._packed_apply(width)
                # group retirement drives the compacted exchange even with
                # no reduction policy: the full-width segment carries the
                # live mask so the loop can exit at a retirement event,
                # narrower segments compact the payload
                masked = (
                    (lambda z, act: apply_w(z)) if width_seg == width
                    else self.op.masked_matvec_fn(width_seg)
                )
                exit_bw = width_seg
            runner = make_ecg_runner(
                apply_w, width, tol=cfg.tol, max_iters=cfg.max_iters,
                split=self._split_fn, gram1=self._gram1, gram2=self._gram2,
                sqnorm=self._sqnorm, tail=self._tail,
                backend=cfg.kernel.backend, policy=self.policy,
                a_apply_masked=masked, exit_below_width=exit_bw,
                method=cfg.method.name, s=cfg.method.s,
                reorth=cfg.method.reorth, rank_rtol=cfg.method.rank_rtol,
                precond=self._precond, gram2p=self._gram2p,
                precond_reseed=(
                    cfg.precondition.reseed
                    if cfg.precondition.kind == "inexact"
                    else None
                ),
                groups=spec, sqnorm_cols=self._sqnorm_cols,
            )
            self._runners[key] = runner
        return runner

    def _packed_jit(self, spec: GroupSpec, width_seg: int, kind: str):
        key = ("pack", spec, width_seg, kind)
        fn = self._jits.get(key)
        if fn is None:
            runner = self._packed_runner(spec, width_seg)
            if kind == "fresh":
                def go(b, x0):
                    self.stats.traces += 1  # trace-time side effect only
                    return runner.run(runner.init(b, x0))
            else:
                def go(carry):
                    self.stats.traces += 1
                    return runner.run(carry)
            fn = jax.jit(go)
            self._jits[key] = fn
        return fn

    def solve_packed(self, bs, x0s=None, tols=None):
        """Solve k right-hand sides as ONE enlarged block solve of width
        ``k·t``, each request retiring against its own tolerance.

        Request j owns the contiguous column slab ``[j·t, (j+1)·t)`` of the
        packed program; all k requests share every halo exchange and both
        Gram psums per iteration (the amortization the paper prices per
        *column* now amortizes per *request*).  When a request's per-group
        residual norm reaches its tolerance its R/Z slabs are zero-retired,
        its solution freezes, and on a distributed handle the exchange is
        re-sliced at the shrunken live width (``ExchangePlan.at_width``) so
        late finishers stop paying early finishers' bytes.

        ``tols`` is one absolute residual-norm tolerance per request (None
        entries inherit ``config.tol``).  Results are NOT bit-identical to
        solo :meth:`solve` calls — the shared search space couples the
        iterates (that coupling is exactly why the pack converges in fewer
        total iterations than k solo solves) — so each
        :class:`~repro.core.cg.SolveResult` carries honest per-request
        telemetry: its own residual history/iteration count and a
        ``pack`` dict (group layout, retirement iteration, total packed
        iterations).  Requires ``method="classic"`` and no restart policy.
        """
        cfg = self.config
        if len(bs) == 0:
            raise ValueError("solve_packed needs at least one right-hand side")
        if cfg.method.name != "classic":
            raise ValueError(
                f"solve_packed requires method 'classic', got {cfg.method.name!r}"
            )
        if self.policy is None:
            raise ValueError(
                "solve_packed requires a rank-revealing policy (build with "
                "adaptive='rankrev' at minimum): retirement makes the Gram "
                "matrix structurally singular, which the pivoted "
                "factorization absorbs as zero-masked columns"
            )
        if self.policy.restart:
            raise ValueError(
                "solve_packed cannot run a restart policy (re-enlarging would "
                "mix request boundaries); use adaptive='rankrev' or 'reduce'"
            )
        x0s = [None] * len(bs) if x0s is None else list(x0s)
        tols = [None] * len(bs) if tols is None else list(tols)
        if len(x0s) != len(bs) or len(tols) != len(bs):
            raise ValueError(
                f"got {len(bs)} rhs but {len(x0s)} guesses / {len(tols)} tols"
            )
        spec = GroupSpec(
            t_each=self.t,
            tols=tuple(cfg.tol if tt is None else float(tt) for tt in tols),
        )
        g = spec.n_groups
        b_mat = np.stack([np.asarray(b) for b in bs], axis=1)
        x0_mat = np.stack(
            [np.zeros(b_mat.shape[0], b_mat.dtype) if x0 is None
             else np.asarray(x0) for x0 in x0s],
            axis=1,
        )
        if self.mesh is not None:
            b_dev = self.op.shard_vector(b_mat)
            x0_dev = self.op.shard_vector(x0_mat.astype(b_mat.dtype))
            self._onehot(b_dev.dtype)  # warm eagerly — a trace must not put
        else:
            b_dev = jnp.asarray(b_mat)
            x0_dev = jnp.asarray(x0_mat)
        tr = self._tracer
        segments = None
        if self.mesh is None:
            with tr.span("solve_packed/dispatch", cat="solve",
                         width=spec.width, groups=g):
                out = self._packed_jit(spec, spec.width, "fresh")(
                    b_dev, x0_dev
                )
        else:
            # width-segmented packed solve: each retirement (or policy
            # reduction) event exits the loop, the exchange re-slices at the
            # live width, and the solve resumes from the same carry
            t_seg, carry, k_prev, segments = spec.width, None, 0, []
            while True:
                with tr.span("solve/segment", cat="solve", width=t_seg,
                             packed=True, groups=g) as sp:
                    if carry is None:
                        carry = self._packed_jit(spec, t_seg, "fresh")(
                            b_dev, x0_dev
                        )
                    else:
                        carry = self._packed_jit(spec, t_seg, "resume")(carry)
                    k = int(carry["k"])
                    bd = bool(carry["bd"])
                    it_seg = k - k_prev
                    sp.args["iters"] = it_seg
                    if tr.enabled:
                        sp.args.update(self._struct_attrs(t_seg))
                segments.append((t_seg, it_seg))
                k_prev = k
                n_act = int(jnp.sum(carry["act"]))
                if (
                    not bool(jnp.any(carry["grp_live"]))
                    or bd
                    or k >= cfg.max_iters
                    or n_act >= t_seg
                    or n_act == 0
                ):
                    break
                new_w = max(n_act, 1)
                if it_seg == 0 and new_w == t_seg:
                    break  # zero-progress segment at a stable width
                # retirement (or reduction) event -> re-slice; a pack whose
                # groups arrive pre-converged (x0 at tolerance) exits its
                # first segment after zero iterations and re-slices straight
                # to the initial live width
                t_seg = new_w
            out = carry
        self.stats.solves += g
        with tr.span("solve_packed/finalize", cat="solve", groups=g):
            results = self._finalize_packed(out, x0_dev, spec, segments)
        if tr.enabled:
            tr.counter("solver.solves", self.stats.solves)
        return results

    def _finalize_packed(self, out, x0_dev, spec: GroupSpec, segments):
        """Split one packed loop carry into k honest per-request results."""
        te, g = spec.t_each, spec.n_groups
        big_x = out["X"]
        xs = x0_dev + big_x.reshape(big_x.shape[0], g, te).sum(axis=2)
        xs = np.asarray(xs)
        grp_iter = np.asarray(out["grp_iter"])
        grp_hist = np.asarray(out["grp_hist"])
        k_total = int(out["k"])
        bd = bool(out["bd"])
        results = []
        for j in range(g):
            retired = int(grp_iter[j]) >= 0
            nit = int(grp_iter[j]) if retired else k_total
            hist_j = grp_hist[:, j].copy()
            hist_j[nit + 1:] = np.nan  # frozen-past-retirement -> NaN padding
            results.append(SolveResult(
                x=xs[:, j],
                n_iters=nit,
                res_hist=hist_j,
                converged=retired,
                breakdown=bd and not retired,
                t=te,
                selection=self.selection,
                comm_segments=segments,
                pack=dict(
                    width=spec.width,
                    t_each=te,
                    n_groups=g,
                    group=j,
                    tol=spec.tols[j],
                    retired_iter=int(grp_iter[j]) if retired else None,
                    packed_iters=k_total,
                ),
            ))
        return results

    def unshard(self, arr):
        """Padded per-rank layout -> global (n, ...) numpy array (identity
        for a sequential handle)."""
        if self.op is None:
            return np.asarray(arr)
        return self.op.unshard(arr)

    @property
    def partition(self):
        """The row partition this session was built on — pass it back to
        ``ECGSolver.build(..., pm=)`` to share the partitioning cost across
        independently configured sessions of the same matrix (None for a
        sequential handle built without one)."""
        return self._pm

    # ----------------------------------------------------------- derivation
    def with_config(self, **overrides) -> "ECGSolver":
        """Derive a sibling handle with config overrides, reusing as much
        setup as the overrides permit.

        Solve-level overrides (``tol``, ``max_iters``, the adaptive policy)
        reuse the operator, plan, and tuning outright — only the solve loop
        is re-jitted.  Operator-level overrides (strategy/backend/tile/
        overlap/tune/t) rebuild the operator but always reuse the row
        partition.  Accepts the flat field spellings of
        :meth:`SolverConfig.replace`.
        """
        new_cfg = self.config.replace(**overrides)
        clone = ECGSolver.__new__(ECGSolver)
        clone.a, clone.mesh, clone.config = self.a, self.mesh, new_cfg
        clone._tracer = self._tracer
        clone.stats = SolverStats()
        clone.selection = None
        clone.tuned = None
        clone.op = None
        clone._pm = self._pm
        clone._probe_b = self._probe_b
        clone._runners, clone._jits = {}, {}
        clone._onehot_cache = {}
        clone._packed_applies = {}
        # siblings of the same matrix may reuse the parent's conversion
        # artifacts (validated against tile/shape/dtype at build time)
        clone._conversion_in = self.conversion
        clone.conversion = None
        reuse_op = (
            new_cfg.t == self.config.t
            and new_cfg.comm == self.config.comm
            and new_cfg.kernel == self.config.kernel
            and new_cfg.tune == self.config.tune
            # a t="auto" resolution is derived from the adaptive knobs
            # (candidates, cached select, probe budget/rtol, explicit off),
            # the tolerance (est_iters-to-tol drives the ranking), AND the
            # method (its synchronization term enters the per-iteration
            # cost): changing any of them must re-run the selection.  A
            # method change under a fixed t reuses the operator outright —
            # the SpMBV and reducers are method-agnostic; only the loop
            # closures differ, and those are rebuilt per clone anyway.
            and (
                not isinstance(self.config.t, str)
                or (
                    new_cfg.adaptive == self.config.adaptive
                    and new_cfg.tol == self.config.tol
                    and new_cfg.method == self.config.method
                )
            )
        )
        if reuse_op:
            clone.op = self.op
            clone.tuned = self.tuned
            clone.selection = self.selection
            clone.t = self.t
            clone._apply = self._apply
            clone._gram1, clone._gram2 = self._gram1, self._gram2
            clone._sqnorm, clone._tail = self._sqnorm, self._tail
            clone._gram2p = self._gram2p
            clone._sqnorm_cols = self._sqnorm_cols
            clone._split_fn = self._split_fn
            clone.conversion = self.conversion
            # the preconditioner depends only on (a, op, precondition cfg):
            # operator reuse keeps it unless the precondition knobs changed
            if new_cfg.precondition == self.config.precondition:
                clone._precond = self._precond
            else:
                clone._precond = clone._build_precond()
            clone._onehot_cache = self._onehot_cache
            if self.mesh is not None:
                clone._onehot_np = self._onehot_np
            if new_cfg.adaptive == self.config.adaptive:
                clone.policy = self.policy  # keeps auto-t's implied rankrev
            else:
                pol = new_cfg.adaptive.policy
                if (
                    pol is None
                    and clone.selection is not None
                    and not new_cfg.adaptive.explicit_off
                ):
                    # auto-t implies breakdown safety unless explicitly off
                    pol = resolve_policy("rankrev")
                clone.policy = pol
            clone._segmented = (
                clone.policy is not None
                and not clone.policy.restart
                and self.mesh is not None
            )
            clone.stats.op_reused = True
            clone.stats.partition_reused = self.mesh is not None
        else:
            clone._build()
            clone.stats.partition_reused = self.mesh is not None
        return clone

    # ---------------------------------------------------------- diagnostics
    def lowered_text(self, dtype=None, width: int | None = None) -> str:
        """Compiled HLO of the (fresh) solve program at ``width`` — used by
        the collective-structure tests (§3.1 two-psum invariant)."""
        import numpy as _np

        dtype = jnp.float64 if dtype is None else dtype
        width = self.t if width is None else width
        n = self.op.n_padded if self.op is not None else self.a.shape[0]
        if self.mesh is not None:
            self._onehot(dtype)  # warm eagerly — a trace must not put
        sds = jax.ShapeDtypeStruct((n,), _np.dtype(dtype))
        return self._jit(width, "fresh").lower(sds, sds).compile().as_text()

    def packed_lowered_text(
        self, tols, dtype=None, width_seg: int | None = None
    ) -> str:
        """Compiled HLO of the (fresh) *packed* solve program for a group
        layout of ``len(tols)`` requests, at exchange width ``width_seg`` —
        used by the retirement re-slice gates (all-reduce count unchanged,
        collective-permute payload drops with the live width)."""
        import numpy as _np

        dtype = jnp.float64 if dtype is None else dtype
        spec = GroupSpec(
            t_each=self.t,
            tols=tuple(
                self.config.tol if tt is None else float(tt) for tt in tols
            ),
        )
        width_seg = spec.width if width_seg is None else width_seg
        n = self.op.n_padded if self.op is not None else self.a.shape[0]
        if self.mesh is not None:
            self._onehot(dtype)  # warm eagerly — a trace must not put
        sds = jax.ShapeDtypeStruct((n, spec.n_groups), _np.dtype(dtype))
        fn = self._packed_jit(spec, width_seg, "fresh")
        return fn.lower(sds, sds).compile().as_text()
