"""Shared platform dispatch for the kernel ops (first slice of GPU support).

Every public kernel op (``bsr_spmbv``, ``fused_gram``, ``block_update``,
``ecg_tail``) dispatches Pallas-compiled on TPU and the pure-jnp oracle
elsewhere.  Historically the check was a bare ``backend == "tpu"`` that
silently lumped GPU hosts with CPU; this module makes the GPU case explicit:
the op still falls back to the oracle (the Triton/Mosaic-GPU lowering is a
ROADMAP item), but says so — once per op — when ``REPRO_KERNEL_VERBOSE`` is
set, so a GPU user who flipped ``backend="pallas"`` expecting a kernel can
see what actually ran.
"""

from __future__ import annotations

import os
import warnings

import jax

#: op names that already emitted their GPU-fallback warning this process
_warned: set[str] = set()


def reset_dispatch_warnings() -> None:
    """Clear the warn-once state.

    The module-level ``_warned`` set otherwise leaks across a test suite: a
    test that triggers the GPU-fallback warning silences it for every later
    test in the same process.  ``tests/conftest.py`` calls this between
    tests; library users only need it when re-enabling
    ``REPRO_KERNEL_VERBOSE`` diagnostics mid-process.
    """
    _warned.clear()


def verbose() -> bool:
    """True when REPRO_KERNEL_VERBOSE is set to a truthy value."""
    return os.environ.get("REPRO_KERNEL_VERBOSE", "") not in ("", "0", "false", "False")


def warn_gpu_fallback(op_name: str) -> None:
    """Warn (once per op, gated on REPRO_KERNEL_VERBOSE) that a kernel op is
    running its jnp oracle on a GPU host."""
    if op_name in _warned or not verbose():
        return
    _warned.add(op_name)
    warnings.warn(
        f"repro.kernels.{op_name}: no Pallas GPU lowering yet — dispatching "
        "to the pure-jnp oracle on platform 'gpu' (functionally identical, "
        "but not the fused kernel; unset REPRO_KERNEL_VERBOSE to silence)",
        RuntimeWarning,
        stacklevel=3,
    )


def resolve_dispatch(op_name: str, use_pallas: bool | None) -> tuple[bool, bool]:
    """Resolve a kernel op's ``use_pallas`` argument against the platform.

    Returns ``(use_pallas, interpret)``: compiled Pallas on TPU; on GPU the
    jnp oracle with an explicit warn-once (see module docstring) instead of
    the old silent CPU-style fallback; interpret-mode Pallas everywhere else
    when the caller forces ``use_pallas=True`` (the validation path).
    """
    platform = jax.default_backend()
    on_tpu = platform == "tpu"
    if use_pallas is None:
        use_pallas = on_tpu
        if platform == "gpu":
            warn_gpu_fallback(op_name)
    return use_pallas, not on_tpu
