"""Roofline analysis: HLO collective parser + term assembly."""

import pytest

from repro.analysis.roofline import (
    CellCost,
    Roofline,
    collective_bytes,
    count_collective_ops,
    model_flops,
    roofline_from_cost,
    _shape_bytes,
)
from repro.models.common import ArchConfig


HLO = """
HloModule jit_step
ENTRY %main {
  %p0 = bf16[256,1024]{1,0} parameter(0)
  %ar = bf16[256,1024]{1,0} all-reduce(%p0), replica_groups={}
  %ag = bf16[4096,1024]{1,0} all-gather(%p0), dimensions={0}
  %rs.1 = f32[64,1024]{1,0} reduce-scatter(%conv), dimensions={0}
  %a2a = (bf16[8,32]{1,0}, bf16[8,32]{1,0}) all-to-all(%x, %y)
  %cp-start = bf16[16,16]{1,0} collective-permute-start(%p0)
  %cp-done = bf16[16,16]{1,0} collective-permute-done(%cp-start)
  %tuple.ar = (f32[2048]{0}, f32[2048]{0}) all-reduce(%a, %b)
}
"""


class TestParser:
    def test_shape_bytes(self):
        assert _shape_bytes("bf16", "256,1024") == 256 * 1024 * 2
        assert _shape_bytes("f32", "64") == 256
        assert _shape_bytes("pred", "8,8") == 64

    def test_collective_bytes(self):
        got = collective_bytes(HLO)
        assert got["all-reduce"] == 256 * 1024 * 2 + 2 * 2048 * 4
        assert got["all-gather"] == 4096 * 1024 * 2
        assert got["reduce-scatter"] == 64 * 1024 * 4
        assert got["all-to-all"] == 2 * 8 * 32 * 2
        # permute counted once (start only, done skipped)
        assert got["collective-permute"] == 16 * 16 * 2

    def test_counts(self):
        got = count_collective_ops(HLO)
        assert got["all-reduce"] == 2
        assert got["collective-permute"] == 1  # start only


class TestExtrapolation:
    def test_linear_extrapolation(self):
        c1 = CellCost(flops=10.0, hbm_bytes=100.0, coll_bytes=4.0, coll_breakdown={"all-reduce": 4.0})
        c2 = CellCost(flops=16.0, hbm_bytes=130.0, coll_bytes=6.0, coll_breakdown={"all-reduce": 6.0})
        c = CellCost.extrapolate(c1, c2, 10)
        assert c.flops == pytest.approx(10 + 9 * 6)
        assert c.hbm_bytes == pytest.approx(100 + 9 * 30)
        assert c.coll_breakdown["all-reduce"] == pytest.approx(4 + 9 * 2)


class TestRoofline:
    def test_terms_and_dominant(self):
        cost = CellCost(flops=197e12, hbm_bytes=819e9 * 2, coll_bytes=5e10 * 0.5, coll_breakdown={})
        rl = roofline_from_cost(cost, chips=256, model_flops_global=197e12 * 256 * 0.5)
        assert rl.compute_s == pytest.approx(1.0)
        assert rl.memory_s == pytest.approx(2.0)
        assert rl.collective_s == pytest.approx(0.5)
        assert rl.dominant == "memory"
        assert rl.useful_flops_ratio == pytest.approx(0.5)
        # ideal 0.5s of useful compute vs 2.0s bound
        assert rl.roofline_fraction == pytest.approx(0.25)

    def test_model_flops(self):
        cfg = ArchConfig(name="x", family="dense", n_layers=2, d_model=64, n_heads=4,
                         n_kv_heads=4, d_ff=128, vocab=100)
        n = cfg.param_count()
        assert model_flops(cfg, "train", 128, 4) == 6.0 * n * 128 * 4
        assert model_flops(cfg, "decode", 128, 4) == 2.0 * n * 4

    def test_moe_uses_active_params(self):
        cfg = ArchConfig(name="m", family="moe", n_layers=2, d_model=64, n_heads=4,
                         n_kv_heads=4, d_ff=128, vocab=100, n_experts=8, top_k=2)
        assert model_flops(cfg, "train", 16, 1) == 6.0 * cfg.active_param_count() * 16
        assert cfg.active_param_count() < cfg.param_count()
