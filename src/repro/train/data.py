"""Synthetic deterministic token pipeline.

Stateless by construction: ``batch_at(seed, step)`` is a pure function, so
resume-after-restart is exact with no dispenser state to checkpoint, and no
central dataloader exists to straggle (DESIGN.md §6).  The token stream is a
mixture of Zipf-distributed ids with short Markov repeats — enough structure
for a language model to reduce loss on.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    batch: int
    seq: int
    seed: int = 0
    zipf_a: float = 1.2
    repeat_p: float = 0.3


def _zipf_probs(cfg: DataConfig) -> np.ndarray:
    ranks = np.arange(1, cfg.vocab + 1, dtype=np.float64)
    p = ranks ** -cfg.zipf_a
    return p / p.sum()


def batch_at(cfg: DataConfig, step: int, extra: dict | None = None) -> dict:
    """Batch for a given step (pure function of (cfg, step))."""
    rng = np.random.default_rng(np.random.SeedSequence([cfg.seed, step]))
    probs = _zipf_probs(cfg)
    toks = rng.choice(cfg.vocab, size=(cfg.batch, cfg.seq + 1), p=probs)
    # Markov repeats: with prob repeat_p, copy the previous token (gives the
    # model a learnable local dependency)
    rep = rng.random((cfg.batch, cfg.seq + 1)) < cfg.repeat_p
    for j in range(1, cfg.seq + 1):
        toks[:, j] = np.where(rep[:, j], toks[:, j - 1], toks[:, j])
    out = {
        "tokens": jnp.asarray(toks[:, :-1], jnp.int32),
        "labels": jnp.asarray(toks[:, 1:], jnp.int32),
    }
    if extra:
        for k, sds in extra.items():
            out[k] = jnp.asarray(
                rng.standard_normal([int(d) for d in sds.shape]) * 0.02, sds.dtype
            )
    return out
