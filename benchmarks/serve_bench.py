"""Serving-layer benchmark: packed/batched/sequential throughput + latency.

    PYTHONPATH=src python benchmarks/serve_bench.py [--smoke] [--json PATH]
                                                    [--check BASELINE]
                                                    [--seed N] [--repeats K]

Phases over the standard synthetic trace (32 single-RHS requests in
shuffled arrival order across 3 operators, 8 duplicate payloads — the
same generator as ``repro.launch.serve``; ``--seed`` picks the trace):

* **warm-start restart** — a ``t="auto"`` server on the Pallas kernel
  path registers the three operators cold (probes + selection + the
  CSR→Block-ELL tile analysis paid, everything persisted to the
  warm-start cache), then a second server on the same cache directory
  simulates the restart: every build must load its tuning from disk
  (``warm_retunes == 0``), the summed build latency must drop ≥ 5×, and
  **zero** builds may re-run the conversion analysis
  (``warm_conv_analyses == 0`` — the eviction-aware conversion cache).
* **throughput + latency** — the trace replayed through three policies:
  *sequential* (``max_batch=1``, dedup off), *batched* (per-operator
  coalescing + dedup + pipelined dispatch), and *packed*
  (``packing="width"``: compatible requests coalesce into one enlarged
  block solve with per-request retirement).  All are compile-warmed
  first; median-of-``--repeats`` wall time, plus p50/p95/p99 per-request
  latency per policy.  Gates: batched req/s ≥ sequential; packed req/s ≥
  1.2× batched (≥ 1× in ``--smoke``, where the operators are too small
  to amortize); every packed request's measured true relative residual
  ≤ its tolerance (the packing contract — packed results are *not*
  bit-identical to solo solves, so the server measures what it promises).
* **bit-identity** — every *batched* (pack off) result must equal a solo
  ``ECGSolver.solve`` of the same request bit-for-bit; the packed policy
  being opt-in means this guarantee is untouched.

``--check BASELINE`` is the CI gate against the committed
``BENCH_serve.json``: the deterministic counters (registry hits/misses,
dedup shares, batch layout, pack layout, warm retunes, conversion
analyses, bit-identity) must match the baseline exactly — they are pure
functions of the trace, independent of machine speed.  Wall-clock
numbers are informational except for the ratio gauges above, which
compare a run against itself.

``--smoke`` shrinks the operators and skips repeat timing; the trace
structure (and therefore every checked counter) is identical to the full
run.
"""

import argparse
import json
import sys
import tempfile


def register_all(server, ops):
    """Force-register every operator; returns the build records."""
    for _, a in ops:
        server.registry.get(a)
    return server.registry.stats()


def replay_sequential(server, ops, trace):
    tickets = []
    for op_i, b in trace:
        tk = server.submit(ops[op_i][1], b)  # max_batch=1 -> dispatches now
        server.flush()
        tickets.append(tk)
    return tickets


def replay_batched(server, ops, trace):
    tickets = [server.submit(ops[op_i][1], b) for op_i, b in trace]
    server.flush()
    return tickets


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="small operators for CI")
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--dups", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0,
                    help="trace seed (RHS draws + arrival shuffle)")
    ap.add_argument("--repeats", type=int, default=None,
                    help="timed replays per policy (median-of); "
                         "default 3, 1 smoke")
    ap.add_argument("--max-pack-width", type=int, default=16)
    ap.add_argument("--json", default="BENCH_serve.json")
    ap.add_argument("--check", metavar="BASELINE", default=None,
                    help="fail unless deterministic counters match this JSON")
    args = ap.parse_args()
    repeats = args.repeats or (1 if args.smoke else 3)
    scale = 4 if args.smoke else 8

    import jax

    jax.config.update("jax_enable_x64", True)

    import numpy as np

    from repro.launch.serve import build_trace
    from repro.observe import timed_median
    from repro.serve import ECGServer, ServeConfig, latency_percentiles
    from repro.solver import ECGSolver, SolverConfig

    ops, trace = build_trace(args.requests, args.dups, scale, seed=args.seed)
    print(f"# serve bench: {len(trace)} requests / {len(ops)} operators "
          f"({', '.join(f'{n}={a.shape[0]}' for n, a in ops)}), "
          f"{args.dups} dups, seed {args.seed}"
          + (" [smoke]" if args.smoke else ""))

    # ---- phase 1: cold vs warm builds through the warm-start cache.
    # kernel="pallas" puts the CSR->Block-ELL conversion on the build path
    # so the restart also exercises the persisted tile analysis.
    auto_solver = SolverConfig(t="auto", tol=1e-8,
                               kernel=dict(backend="pallas"))
    with tempfile.TemporaryDirectory() as cache_dir:
        cfg_auto = ServeConfig(solver=auto_solver, cache_dir=cache_dir)
        cold = register_all(ECGServer(cfg_auto), ops)
        warm = register_all(ECGServer(cfg_auto), ops)  # simulated restart
    cold_s = sum(r["build_s"] for r in cold["builds"])
    warm_s = sum(r["build_s"] for r in warm["builds"])
    warm_retunes = warm["cold_builds"]
    warm_conv_analyses = warm["conv_analyzed"]
    build_speedup = cold_s / warm_s if warm_s > 0 else float("inf")
    print(f"builds: cold {cold_s:.3f}s -> warm {warm_s:.3f}s "
          f"({build_speedup:.1f}x, {warm_retunes} re-tuned, "
          f"{warm_conv_analyses} conversions re-analyzed after restart)")

    # ---- phase 2: sequential vs batched vs packed throughput + latency
    fixed = ServeConfig(solver=SolverConfig(t=4, tol=1e-8, adaptive="rankrev"))
    packed_cfg = fixed.replace(
        packing=dict(pack="width", max_pack_width=args.max_pack_width)
    )
    policies = dict(
        sequential=(fixed.replace(max_batch=1, dedup=False), replay_sequential),
        batched=(fixed, replay_batched),
        packed=(packed_cfg, replay_batched),
    )
    walls, lats = {}, {}
    for name, (cfg, replay) in policies.items():
        server = ECGServer(cfg)
        # compile-warm with one untimed replay: the trace itself visits
        # every (operator, dispatch shape) the policy will trace — packed
        # programs are keyed by pack layout, so a per-operator solo solve
        # would leave them cold
        replay(server, ops, trace)
        # shared timer (one warmup already paid above, so warmup=0);
        # sync=False — replay drains the queue, results are already host
        tickets, wall = timed_median(
            replay, server, ops, trace,
            repeats=repeats, warmup=0, label=f"replay/{name}", sync=False,
        )
        walls[name] = wall
        lats[name] = latency_percentiles(tickets)
    rps = {name: len(trace) / w for name, w in walls.items()}
    for name in policies:
        p = lats[name]
        print(f"  {name:<10} {rps[name]:7.1f} req/s   "
              f"p50={p['p50'] * 1e3:7.1f}ms p95={p['p95'] * 1e3:7.1f}ms "
              f"p99={p['p99'] * 1e3:7.1f}ms")
    pack_speedup = rps["packed"] / rps["batched"]
    print(f"throughput: batched/sequential {rps['batched'] / rps['sequential']:.2f}x, "
          f"packed/batched {pack_speedup:.2f}x")

    # ---- phase 3: deterministic counters + contracts on fresh servers
    # (a) batched bit-identity vs solo solves (the pack="off" guarantee)
    bat_fresh = ECGServer(fixed)
    tickets = replay_batched(bat_fresh, ops, trace)
    solo = {name: ECGSolver.build(a, config=fixed.solver) for name, a in ops}
    bit_identical = True
    for (op_i, b), tk in zip(trace, tickets):
        name, a = ops[op_i]
        ref = solo[name].solve(b)
        same = (
            np.array_equal(np.asarray(tk.result.x), np.asarray(ref.x))
            and tk.result.n_iters == ref.n_iters
            and bool(tk.result.converged) == bool(ref.converged)
        )
        bit_identical = bit_identical and same
    st = bat_fresh.stats()
    reg, q = st["registry"], st["queue"]
    hit_rate = reg["hits"] / max(reg["hits"] + reg["misses"], 1)
    print(f"bit-identity vs solo solves: {bit_identical}; "
          f"registry hit rate {hit_rate:.2f}; "
          f"{q['batches']} batches {q['batch_sizes']}, "
          f"{q['dedup_shared']} dedup-shared")

    # (b) packed relres contract + pack layout
    pack_fresh = ECGServer(packed_cfg)
    ptickets = replay_batched(pack_fresh, ops, trace)
    tol = fixed.solver.tol
    relres_ok = all(
        tk.relres is not None and tk.relres <= tk.result.pack["tol"]
        for tk in ptickets
    )
    worst_relres = max(tk.relres for tk in ptickets)
    pq = pack_fresh.stats()["queue"]
    pack_groups = [lay["groups"] for lay in pq["pack_layouts"]]
    pack_widths = [lay["width"] for lay in pq["pack_layouts"]]
    print(f"packed: {pq['packs']} packs groups={pack_groups}, "
          f"worst relres {worst_relres:.2e} (tol {tol:.0e}), "
          f"contract {'OK' if relres_ok else 'VIOLATED'}")

    pct_present = all(
        p["n"] == len(trace)
        and all(p[k] is not None for k in ("mean", "p50", "p95", "p99"))
        for p in lats.values()
    )
    packed_floor = 1.0 if args.smoke else 1.2
    summary = dict(
        bit_identical=bool(bit_identical),
        batched_not_slower=bool(rps["batched"] >= rps["sequential"]),
        packed_speedup=float(pack_speedup),
        packed_speedup_ok=bool(pack_speedup >= packed_floor),
        packed_relres_ok=bool(relres_ok),
        percentiles_present=bool(pct_present),
        warm_speedup_5x=bool(build_speedup >= 5.0),
        warm_retunes=int(warm_retunes),
        warm_conv_analyses=int(warm_conv_analyses),
    )
    out = dict(
        config=dict(
            requests=len(trace), dups=args.dups, operators={
                n: int(a.shape[0]) for n, a in ops
            }, scale=scale, seed=args.seed, repeats=repeats, smoke=args.smoke,
            max_batch=fixed.max_batch, max_pack_width=args.max_pack_width,
            t=4, auto_t_for_builds=True,
        ),
        builds=dict(
            cold_s=cold_s, warm_s=warm_s, speedup=build_speedup,
            cold=cold["builds"], warm=warm["builds"],
            warm_retunes=int(warm_retunes),
            warm_conv_analyses=int(warm_conv_analyses),
        ),
        throughput={
            **{f"{name}_rps": rps[name] for name in policies},
            **{f"{name}_wall_s": walls[name] for name in policies},
            "batched_over_sequential": rps["batched"] / rps["sequential"],
            "packed_over_batched": pack_speedup,
        },
        latency={name: lats[name] for name in policies},
        batched=dict(
            hits=reg["hits"], misses=reg["misses"], hit_rate=hit_rate,
            batches=q["batches"], batch_sizes=q["batch_sizes"],
            dedup_shared=q["dedup_shared"],
        ),
        packed=dict(
            packs=pq["packs"], pack_groups=pack_groups,
            pack_widths=pack_widths,
            batch_sizes=pq["batch_sizes"],
            dedup_shared=pq["dedup_shared"],
            worst_relres=float(worst_relres), tol=float(tol),
            relres_ok=bool(relres_ok),
        ),
        summary=summary,
    )
    with open(args.json, "w") as f:
        json.dump(out, f, indent=2)
    print(f"summary: {json.dumps(summary)}")
    print(f"wrote {args.json}")

    failures = []
    if not summary["bit_identical"]:
        failures.append("batched results are not bit-identical to solo solves")
    if not summary["batched_not_slower"]:
        failures.append(
            f"batched throughput regressed below sequential "
            f"({rps['batched']:.1f} < {rps['sequential']:.1f} req/s)"
        )
    if not summary["packed_speedup_ok"]:
        failures.append(
            f"packed throughput {pack_speedup:.2f}x batched "
            f"< required {packed_floor:.1f}x"
        )
    if not summary["packed_relres_ok"]:
        failures.append(
            f"packed relres contract violated (worst {worst_relres:.2e} "
            f"> tol {tol:.0e})"
        )
    if not summary["percentiles_present"]:
        failures.append("latency percentiles missing for some policy")
    if not summary["warm_speedup_5x"]:
        failures.append(
            f"warm-start build speedup {build_speedup:.1f}x < 5x"
        )
    if summary["warm_retunes"]:
        failures.append(
            f"{warm_retunes} operator(s) re-tuned after restart (want 0)"
        )
    if summary["warm_conv_analyses"]:
        failures.append(
            f"{warm_conv_analyses} conversion(s) re-analyzed after restart "
            f"(want 0)"
        )
    if args.check:
        failures += check_counters(out, args.check)
        if not failures:
            print(f"counter gate OK vs {args.check}")
    if failures:
        print("SERVE GATE FAILED:", file=sys.stderr)
        for msg in failures:
            print(f"  {msg}", file=sys.stderr)
        sys.exit(1)


def check_counters(out: dict, baseline_path: str) -> list[str]:
    """Deterministic counters must match the committed baseline exactly."""
    with open(baseline_path) as f:
        base = json.load(f)
    failures = []
    for section, field in (
        ("config", "requests"), ("config", "dups"), ("config", "seed"),
        ("batched", "hits"), ("batched", "misses"),
        ("batched", "batches"), ("batched", "batch_sizes"),
        ("batched", "dedup_shared"),
        ("packed", "packs"), ("packed", "pack_groups"),
        ("packed", "pack_widths"), ("packed", "dedup_shared"),
        ("packed", "relres_ok"),
        ("builds", "warm_retunes"), ("builds", "warm_conv_analyses"),
        ("summary", "bit_identical"),
    ):
        got, want = out[section][field], base[section][field]
        if got != want:
            failures.append(f"{section}.{field}: {got!r} != baseline {want!r}")
    return failures


if __name__ == "__main__":
    main()
