"""Pallas TPU kernel: fused ECG block inner products.

Computes the packed (t, 3t) payload  [PᵀR | APᵀAP | AP_oldᵀAP]  in a single
pass over the row dimension.  The naive implementation reads P, R, AP, AP_old
from HBM in three separate GEMM passes (AP twice); this kernel streams each
operand tile exactly once — the local-compute counterpart of the paper's
"fuse the reductions" discipline (§3.1): one HBM pass feeding one allreduce.

Memory-bound analysis (per n-row shard, bf16/f32):
    naive:  reads P, R, 2·AP, AP_old  = 5·n·t·f bytes
    fused:  reads P, R, AP, AP_old    = 4·n·t·f bytes   (1.25x traffic cut)

Grid: 1-D over row tiles; the (t, 3t) accumulator lives in the revisited
output block (VMEM-resident across the whole grid).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(p_ref, r_ref, ap_ref, apo_ref, out_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    p, r = p_ref[...], r_ref[...]
    ap, apo = ap_ref[...], apo_ref[...]
    acc = out_ref.dtype
    c = jnp.dot(p.T, r, preferred_element_type=acc)
    d = jnp.dot(ap.T, ap, preferred_element_type=acc)
    d_old = jnp.dot(apo.T, ap, preferred_element_type=acc)
    out_ref[...] += jnp.concatenate([c, d, d_old], axis=1)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def fused_gram_pallas(p, r, ap, ap_old, *, block_rows: int = 512, interpret: bool = False):
    n, t = p.shape
    n_pad = (n + block_rows - 1) // block_rows * block_rows
    pad = lambda x: jnp.pad(x, ((0, n_pad - n), (0, 0)))
    p, r, ap, ap_old = map(pad, (p, r, ap, ap_old))
    grid = (n_pad // block_rows,)
    spec = pl.BlockSpec((block_rows, t), lambda i: (i, 0))
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[spec, spec, spec, spec],
        out_specs=pl.BlockSpec((t, 3 * t), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((t, 3 * t), p.dtype),
        interpret=interpret,
    )(p, r, ap, ap_old)
