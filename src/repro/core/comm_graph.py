"""Communication-graph statistics for node-aware SpMBV strategies.

Computes, from a row-partitioned sparse matrix and a (p, ppn) process layout,
the exact per-strategy quantities of the paper's Table 1:

    m, s                      — standard (per-process msgs / bytes)
    m_proc→node, s_proc       — 2-step
    m_node→node, s_node→node  — 3-step
    s_node                    — node-injected bytes (equal for 2-/3-step)
    n_opt, s_proc_opt         — nodal-optimal plan (§4.3, Fig 4.8)

Row counts are stored t-independently; byte sizes scale as
``rows * t * f * row_block`` (``row_block`` lets stats be computed on an
element-level graph and scaled to dof-level rows — DESIGN.md §5).

This is setup-phase (host/numpy) code, the analogue of building the MPI
communicator; it feeds both the performance models and the static exchange
plans used by the shard_map SpMBV.
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.sparse.partition import PartitionedMatrix
from repro.core.machines import MachineParams


@dataclasses.dataclass
class CommGraph:
    """Raw communication quantities in *row* units (t- and f-independent)."""

    p: int
    ppn: int
    n_nodes: int
    row_block: int  # dof rows per graph row (byte scaling factor)

    # standard (per process): duplicates included
    std_msgs: np.ndarray          # (p,) number of destination processes
    std_rows: np.ndarray          # (p,) rows sent (with duplication)

    # node-deduplicated (per process, per destination node)
    # rows_to_node[i] = {dst_node: n_rows}  (dedup'd across dst procs)
    rows_to_node: list[dict[int, int]]

    # per-node aggregates
    node_pair_rows: dict[tuple[int, int], int]  # (src_node, dst_node) -> rows
    node_injected_rows: np.ndarray              # (n_nodes,) dedup'd inter-node rows

    # ---- derived: standard ----
    @property
    def m_standard(self) -> int:
        return int(self.std_msgs.max()) if self.p > 1 else 0

    @property
    def s_standard_rows(self) -> int:
        return int(self.std_rows.max()) if self.p > 1 else 0

    @property
    def total_standard_rows(self) -> int:
        """Total rows crossing the network (with duplicates) — inter-node only."""
        return self._total_standard_internode

    # ---- derived: 2-step ----
    @property
    def m_proc_to_node(self) -> int:
        return max((len(d) for d in self.rows_to_node), default=0)

    @property
    def s_proc_rows(self) -> int:
        return max((sum(d.values()) for d in self.rows_to_node), default=0)

    # ---- derived: 3-step ----
    @property
    def m_node_to_node(self) -> int:
        """Max number of inter-node buffers sent by any node (one per dst)."""
        per_node: dict[int, int] = {}
        for (a, _b), r in self.node_pair_rows.items():
            if r:
                per_node[a] = per_node.get(a, 0) + 1
        return max(per_node.values(), default=0)

    @property
    def s_node_to_node_rows(self) -> int:
        return max(self.node_pair_rows.values(), default=0)

    @property
    def s_node_rows(self) -> int:
        """Max rows injected by a node (deduplicated — equal for 2-/3-step)."""
        return int(self.node_injected_rows.max()) if len(self.node_injected_rows) else 0

    @property
    def s_proc_3step_rows(self) -> int:
        """Busiest process under 3-step pairing (dst nodes round-robin over
        local ranks)."""
        worst = 0
        for a in range(self.n_nodes):
            dsts = sorted(b for (aa, b), r in self.node_pair_rows.items() if aa == a and r)
            loads = [0] * self.ppn
            for j, b in enumerate(dsts):
                loads[j % self.ppn] += self.node_pair_rows[(a, b)]
            worst = max(worst, max(loads, default=0))
        return worst

    @property
    def total_node_aware_rows(self) -> int:
        """Total deduplicated rows crossing the network (2-step == 3-step)."""
        return sum(self.node_pair_rows.values())


def build_comm_graph(pm: PartitionedMatrix, ppn: int, row_block: int = 1) -> CommGraph:
    p = pm.p
    n_nodes = (p + ppn - 1) // ppn
    node_of = np.arange(p) // ppn

    std_msgs = np.zeros(p, dtype=np.int64)
    std_rows = np.zeros(p, dtype=np.int64)
    rows_to_node: list[dict[int, int]] = []
    node_pair_rows: dict[tuple[int, int], int] = {}
    node_injected = np.zeros(n_nodes, dtype=np.int64)
    total_std_internode = 0

    for i in range(p):
        send = pm.comms[i].send_rows
        std_msgs[i] = len(send)
        std_rows[i] = sum(len(v) for v in send.values())
        a = node_of[i]
        per_node_rows: dict[int, set] = {}
        for q, rows in send.items():
            b = node_of[q]
            if b == a:
                continue
            total_std_internode += len(rows)
            per_node_rows.setdefault(int(b), set()).update(rows.tolist())
        counts = {b: len(s) for b, s in per_node_rows.items()}
        rows_to_node.append(counts)
        for b, c in counts.items():
            node_pair_rows[(int(a), b)] = node_pair_rows.get((int(a), b), 0) + c
            node_injected[a] += c

    g = CommGraph(
        p=p,
        ppn=ppn,
        n_nodes=n_nodes,
        row_block=row_block,
        std_msgs=std_msgs,
        std_rows=std_rows,
        rows_to_node=rows_to_node,
        node_pair_rows=node_pair_rows,
        node_injected_rows=node_injected,
    )
    g._total_standard_internode = total_std_internode  # type: ignore[attr-defined]
    return g


@dataclasses.dataclass
class OptimalPlan:
    """Static nodal-optimal plan (paper §4.3, Fig 4.8) for one (t, cutoff)."""

    t: int
    cutoff: int
    # per-node: list of (dst_node, bytes, kind) buffers; kind in
    # {"conglomerate", "retained", "split"}
    buffers_per_node: list[list[tuple[int, int, str]]]
    # per-process stats
    n_opt: np.ndarray        # (p,) messages injected by each process
    s_proc_opt: np.ndarray   # (p,) bytes injected by each process
    intra_moved: np.ndarray  # (p,) bytes moved on-node to stage buffers

    @property
    def max_msgs(self) -> int:
        return int(self.n_opt.max()) if len(self.n_opt) else 0

    @property
    def max_bytes(self) -> int:
        return int(self.s_proc_opt.max()) if len(self.s_proc_opt) else 0


def build_optimal_plan(g: CommGraph, t: int, machine: MachineParams) -> OptimalPlan:
    """Greedy per-node plan: conglomerate small per-proc messages per dst node,
    split very large node-pair buffers, assign buffers to processes in
    descending size order (least-loaded-first), bounded by eq. (4.4)."""
    f = machine.f
    cutoff = machine.eager_cutoff
    unit = t * f * g.row_block  # bytes per graph row
    p, ppn = g.p, g.ppn
    n_opt = np.zeros(p, dtype=np.int64)
    s_proc = np.zeros(p, dtype=np.int64)
    intra = np.zeros(p, dtype=np.int64)
    buffers_per_node: list[list[tuple[int, int, str]]] = []

    for a in range(g.n_nodes):
        procs = list(range(a * ppn, min((a + 1) * ppn, p)))
        local_ppn = len(procs)
        # 2-step message units from this node: (dst_node, owner_proc, bytes)
        units: list[tuple[int, int, int]] = [
            (b, i, rows * unit)
            for i in procs
            for b, rows in g.rows_to_node[i].items()
        ]
        # group by destination node
        by_dst: dict[int, list[tuple[int, int]]] = {}
        for b, i, size in units:
            by_dst.setdefault(b, []).append((i, size))

        buffers: list[tuple[int, int, str]] = []  # (dst, bytes, kind)
        for b, owners in by_dst.items():
            small = [(i, s) for i, s in owners if s < cutoff]
            large = [(i, s) for i, s in owners if s >= cutoff]
            if small:
                tot = sum(s for _, s in small)
                buffers.append((b, tot, "conglomerate"))
            for i, s in large:
                if s > cutoff:
                    # split across up to local_ppn chunks of >= cutoff bytes
                    n_chunks = min(math.ceil(s / cutoff), local_ppn)
                    chunk = math.ceil(s / n_chunks)
                    left = s
                    while left > 0:
                        buffers.append((b, min(chunk, left), "split"))
                        left -= chunk
                else:
                    buffers.append((b, s, "retained"))
        buffers.sort(key=lambda x: -x[1])
        buffers_per_node.append(buffers)

        # assign descending-size to least-loaded process (Fig 4.8 step 1)
        loads = {i: 0 for i in procs}
        counts = {i: 0 for i in procs}
        moved = {i: 0 for i in procs}
        for b, size, kind in buffers:
            i = min(procs, key=lambda q: (loads[q], counts[q]))
            loads[i] += size
            counts[i] += 1
            # staging: conglomerated/split buffers carry data owned by other
            # procs — count it as intra-node movement to the sender
            if kind in ("conglomerate", "split"):
                moved[i] += size
        for i in procs:
            n_opt[i] = counts[i]
            s_proc[i] = loads[i]
            intra[i] = moved[i]

    return OptimalPlan(
        t=t,
        cutoff=cutoff,
        buffers_per_node=buffers_per_node,
        n_opt=n_opt,
        s_proc_opt=s_proc,
        intra_moved=intra,
    )
