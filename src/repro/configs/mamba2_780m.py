"""mamba2-780m [ssm]: 48L d=1536 attn-free, ssm_state=128, SSD
[arXiv:2405.21060].  d_inner = 2*1536 = 3072, headdim 64 -> 48 SSD heads."""

from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-780m",
    family="ssm",
    n_layers=48,
    d_model=1536,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab=50280,
    d_state=128,
    expand=2,
    ssm_head_dim=64,
    tie_embeddings=True,
)

SMOKE = CONFIG.with_(
    name="mamba2-smoke", n_layers=2, d_model=64, vocab=512, d_state=16,
    ssm_head_dim=16, remat=False,
)

SHAPES = {
    "train_4k": "run",
    "prefill_32k": "run",
    "decode_32k": "run",
    "long_500k": "run",  # O(1) decode state — the sub-quadratic family
}
