"""repro.serve: fingerprinting, registry LRU, warm-start cache, batching.

The serving layer's core contract is *transparency*: a request dispatched
through the server — grouped, deduplicated, pipelined — must return
exactly what a solo ``ECGSolver.solve`` of the same ``(A, b)`` would
(bit-identical solution, iteration count, convergence flag).  Everything
else here pins the bookkeeping that makes the layer worth having:
content-stable fingerprints, LRU eviction under a byte budget, the
poisoned-cache fallback, zero retraces across a trace, and the typed
backpressure rejection.
"""

import dataclasses
import json
import os

import numpy as np
import pytest

import jax

from repro.serve import (
    ECGServer,
    OperatorRegistry,
    PackingConfig,
    RequestQueue,
    ServeConfig,
    ServeOverloaded,
    WarmStartCache,
    config_digest,
    fingerprint_csr,
    latency_percentiles,
    mesh_tag,
    operator_nbytes,
    payload_key,
    true_relres,
)
from repro.observe import MemorySink, Tracer
from repro.solver import ECGSolver, SolverConfig
from repro.sparse import aniso_laplace_2d, dg_laplace_2d, fd_laplace_2d


@pytest.fixture(scope="module")
def operators():
    return [fd_laplace_2d(12), aniso_laplace_2d(10, eps=0.01),
            dg_laplace_2d((4, 3), block=4)]


def _reorder_rows(a):
    """Same matrix, each row's entries stored in reversed order."""
    indptr = np.asarray(a.indptr)
    indices = np.asarray(a.indices).copy()
    data = np.asarray(a.data).copy()
    for i in range(a.shape[0]):
        lo, hi = indptr[i], indptr[i + 1]
        indices[lo:hi] = indices[lo:hi][::-1]
        data[lo:hi] = data[lo:hi][::-1]
    return dataclasses.replace(a, indices=indices, data=data)


# ---------------------------------------------------------------- fingerprint
class TestFingerprint:
    def test_stable_under_within_row_reorder(self, operators):
        a = operators[0]
        assert fingerprint_csr(a) == fingerprint_csr(_reorder_rows(a))

    def test_value_perturbation_changes_key(self, operators):
        a = operators[0]
        data = np.asarray(a.data).copy()
        data[7] += 1e-13
        assert fingerprint_csr(a) != fingerprint_csr(
            dataclasses.replace(a, data=data)
        )

    def test_distinct_operators_distinct_keys(self, operators):
        keys = {fingerprint_csr(a) for a in operators}
        assert len(keys) == len(operators)

    def test_deterministic_across_calls(self, operators):
        assert fingerprint_csr(operators[1]) == fingerprint_csr(operators[1])

    def test_operator_nbytes_counts_csr_arrays(self, operators):
        a = operators[0]
        expect = sum(
            np.asarray(x).nbytes for x in (a.indptr, a.indices, a.data)
        )
        assert operator_nbytes(a) == expect


# ------------------------------------------------------------------- registry
class TestRegistryLRU:
    def _registry(self, budget_ops, operators):
        """Budget sized to hold ``budget_ops`` of the test operators."""
        nbytes = max(operator_nbytes(a) for a in operators)
        return OperatorRegistry(ServeConfig(
            solver=SolverConfig(t=2, max_iters=50),
            registry_bytes=budget_ops * nbytes,
        ))

    def test_hit_returns_same_session(self, operators):
        reg = self._registry(4, operators)
        key1, s1 = reg.get(operators[0])
        key2, s2 = reg.get(operators[0])
        assert key1 == key2 and s1 is s2
        assert (reg.hits, reg.misses) == (1, 1)

    def test_eviction_is_lru_order(self, operators):
        reg = self._registry(2, operators)
        keys = [reg.get(a)[0] for a in operators]
        # third insert overflows the 2-operator budget: oldest key evicted
        assert keys[0] not in reg
        assert keys[1] in reg and keys[2] in reg
        assert reg.evictions == 1

    def test_use_refreshes_lru_position(self, operators):
        reg = self._registry(2, operators)
        k0, _ = reg.get(operators[0])
        k1, _ = reg.get(operators[1])
        reg.get(operators[0])  # touch: k1 becomes the LRU victim
        k2, _ = reg.get(operators[2])
        assert k1 not in reg
        assert k0 in reg and k2 in reg

    def test_newest_survives_even_over_budget(self, operators):
        nbytes = operator_nbytes(operators[0])
        reg = OperatorRegistry(ServeConfig(
            solver=SolverConfig(t=2, max_iters=50),
            registry_bytes=max(nbytes // 2, 1),  # below one operator
        ))
        key, solver = reg.get(operators[0])
        assert key in reg and len(reg) == 1
        # an eviction pass must never remove the session about to solve
        _, again = reg.get(operators[0])
        assert again is solver


# ------------------------------------------------------------ warm-start cache
class TestWarmStartCache:
    CFG = dict(t="auto", tol=1e-8, max_iters=200)

    def test_restart_skips_probes(self, operators, tmp_path):
        a = operators[1]
        serve_cfg = ServeConfig(
            solver=SolverConfig(**self.CFG), cache_dir=str(tmp_path)
        )
        cold = OperatorRegistry(serve_cfg)
        _, s_cold = cold.get(a)
        assert cold.stats()["cold_builds"] == 1
        warm = OperatorRegistry(serve_cfg)  # simulated restart
        _, s_warm = warm.get(a)
        st = warm.stats()
        assert st["cold_builds"] == 0 and st["warm_builds"] == 1
        # the warm session resolved the same t without re-probing
        assert s_warm.t == s_cold.t

    def test_roundtrip_preserves_solution(self, operators, tmp_path):
        a = operators[1]
        b = np.random.default_rng(3).standard_normal(a.shape[0])
        serve_cfg = ServeConfig(
            solver=SolverConfig(**self.CFG), cache_dir=str(tmp_path)
        )
        res_cold = OperatorRegistry(serve_cfg).get(a)[1].solve(b)
        res_warm = OperatorRegistry(serve_cfg).get(a)[1].solve(b)
        assert np.array_equal(np.asarray(res_cold.x), np.asarray(res_warm.x))
        assert res_cold.n_iters == res_warm.n_iters

    def test_poisoned_entry_falls_back_cold(self, operators, tmp_path):
        a = operators[1]
        serve_cfg = ServeConfig(
            solver=SolverConfig(**self.CFG), cache_dir=str(tmp_path)
        )
        OperatorRegistry(serve_cfg).get(a)
        entries = os.listdir(tmp_path)
        assert len(entries) == 1
        path = tmp_path / entries[0]
        path.write_text("{not json")
        with pytest.warns(UserWarning, match="unreadable"):
            reg = OperatorRegistry(serve_cfg)
            reg.get(a)
        assert reg.stats()["cold_builds"] == 1  # fell back, did not crash
        # the cold rebuild overwrote the poisoned entry
        json.loads(path.read_text())

    def test_unknown_schema_is_a_miss(self, operators, tmp_path):
        a = operators[1]
        serve_cfg = ServeConfig(
            solver=SolverConfig(**self.CFG), cache_dir=str(tmp_path)
        )
        OperatorRegistry(serve_cfg).get(a)
        path = tmp_path / os.listdir(tmp_path)[0]
        d = json.loads(path.read_text())
        d["schema"] = 99
        path.write_text(json.dumps(d))
        with pytest.warns(UserWarning, match="unreadable"):
            reg = OperatorRegistry(serve_cfg)
            reg.get(a)
        assert reg.stats()["cold_builds"] == 1

    def test_key_separates_configs_and_meshes(self):
        c1 = config_digest(SolverConfig(t=4))
        c2 = config_digest(SolverConfig(t=4, tol=1e-10))
        assert c1 != c2
        assert mesh_tag(None) == "seq"
        cache = WarmStartCache.__new__(WarmStartCache)
        cache.root = "/tmp"
        p1 = cache.path("f" * 32, c1, "seq")
        p2 = cache.path("f" * 32, c2, "seq")
        assert p1 != p2

    def test_payload_does_not_key_the_lookup(self, operators):
        # the digest identifies the BASE template: loading a selection into
        # it must not change which cache entry the next lookup reads
        base = SolverConfig(**self.CFG)
        solver = ECGSolver.build(operators[1], config=base)
        assert solver.selection is not None
        warmed = base.replace(select=solver.selection)
        assert config_digest(base) == config_digest(warmed)


# ------------------------------------------------------- batching / dispatch
class TestBatching:
    def _config(self, **kw):
        defaults = dict(
            solver=SolverConfig(t=4, tol=1e-8, adaptive="rankrev"),
            max_batch=4,
        )
        defaults.update(kw)
        return ServeConfig(**defaults)

    def test_trace_bit_identical_to_solo(self, operators):
        server = ECGServer(self._config())
        rng = np.random.default_rng(1)
        reqs = []
        for i in range(12):
            a = operators[i % 3]
            b = rng.standard_normal(a.shape[0])
            reqs.append((a, b, server.submit(a, b)))
        server.flush()
        solo = [ECGSolver.build(a, config=server.config.solver)
                for a in operators]
        for i, (a, b, tk) in enumerate(reqs):
            ref = solo[i % 3].solve(b)
            assert np.array_equal(np.asarray(tk.result.x), np.asarray(ref.x))
            assert tk.result.n_iters == ref.n_iters
            assert bool(tk.result.converged) == bool(ref.converged)

    def test_localized_rhs_bit_identical(self, operators):
        # zero outside the first quarter: some split columns are exactly
        # zero, exercising the rankrev-masked width machinery inside a batch
        a = operators[0]
        n = a.shape[0]
        b = np.zeros(n)
        b[: n // 4] = np.random.default_rng(2).standard_normal(n // 4)
        server = ECGServer(self._config())
        tk = server.submit(a, b)
        tk2 = server.submit(a, np.random.default_rng(3).standard_normal(n))
        server.flush()
        ref = ECGSolver.build(a, config=server.config.solver).solve(b)
        assert np.array_equal(np.asarray(tk.result.x), np.asarray(ref.x))
        assert bool(tk.result.converged)
        assert tk.batch_id == tk2.batch_id  # dispatched as one group

    def test_dedup_shares_one_solve(self, operators):
        a = operators[0]
        b = np.random.default_rng(4).standard_normal(a.shape[0])
        server = ECGServer(self._config())
        t1 = server.submit(a, b)
        t2 = server.submit(a, b.copy())  # equal bytes, distinct array
        server.flush()
        assert t1.result is t2.result
        assert not t1.deduped and t2.deduped
        assert server.queue.dedup_shared == 1
        solves = sum(server.registry.stats()["solver_solves"].values())
        assert solves == 1

    def test_dedup_off_solves_separately(self, operators):
        a = operators[0]
        b = np.random.default_rng(4).standard_normal(a.shape[0])
        server = ECGServer(self._config(dedup=False))
        t1 = server.submit(a, b)
        t2 = server.submit(a, b.copy())
        server.flush()
        assert t1.result is not t2.result
        assert np.array_equal(np.asarray(t1.result.x), np.asarray(t2.result.x))

    def test_max_batch_dispatches_eagerly(self, operators):
        a = operators[0]
        rng = np.random.default_rng(5)
        server = ECGServer(self._config(max_batch=2))
        t1 = server.submit(a, rng.standard_normal(a.shape[0]))
        assert not t1.done
        t2 = server.submit(a, rng.standard_normal(a.shape[0]))
        # the second distinct payload reached max_batch: dispatched inline
        assert t1.done and t2.done
        assert t1.batch_size == 2

    def test_zero_retraces_across_trace(self, operators):
        server = ECGServer(self._config())
        rng = np.random.default_rng(6)
        for a in operators:  # first solve per operator owns the trace
            server.solve(a, rng.standard_normal(a.shape[0]))
        traces0 = dict(server.registry.stats()["solver_traces"])
        for i in range(9):
            a = operators[i % 3]
            server.submit(a, rng.standard_normal(a.shape[0]))
        server.flush()
        assert server.registry.stats()["solver_traces"] == traces0

    def test_backpressure_rejects_typed(self, operators):
        a = operators[0]
        rng = np.random.default_rng(7)
        server = ECGServer(self._config(max_pending=2, max_batch=100))
        server.submit(a, rng.standard_normal(a.shape[0]))
        server.submit(a, rng.standard_normal(a.shape[0]))
        with pytest.raises(ServeOverloaded, match="max_pending"):
            server.submit(a, rng.standard_normal(a.shape[0]))
        assert server.queue.stats()["rejected"] == 1
        assert server.queue.stats()["pending"] == 2  # rejection changed nothing
        server.flush()
        tk = server.submit(a, rng.standard_normal(a.shape[0]))  # drained: ok
        server.flush()
        assert tk.done

    def test_stream_residuals_matches_history(self, operators):
        a = operators[0]
        b = np.random.default_rng(8).standard_normal(a.shape[0])
        server = ECGServer(self._config())
        tk = server.submit(a, b)
        hist = list(server.stream_residuals(tk))  # dispatches implicitly
        res = tk.result
        assert len(hist) == res.n_iters + 1
        np.testing.assert_array_equal(
            hist, np.asarray(res.res_hist)[: res.n_iters + 1]
        )
        assert hist[-1] <= server.config.solver.tol * 10

    def test_solution_returns_global_vector(self, operators):
        from repro.sparse.csr import csr_spmv
        import jax.numpy as jnp

        a = operators[0]
        b = np.random.default_rng(9).standard_normal(a.shape[0])
        server = ECGServer(self._config())
        x = server.solution(server.submit(a, b))
        relres = np.linalg.norm(
            np.asarray(csr_spmv(a, jnp.asarray(x))) - b
        ) / np.linalg.norm(b)
        assert relres < 1e-7


# -------------------------------------------------------------- telemetry
class TestServeTelemetry:
    """Counters and lifecycle spans are pure functions of the request
    trace: replaying the same 12 requests through a fresh traced server
    yields the same metric sequence, with final values derivable from the
    trace structure alone."""

    N_REQUESTS = 12

    def _trace(self, operators):
        rng = np.random.default_rng(5)
        reqs = []
        for i in range(self.N_REQUESTS - 2):
            a = operators[i % 2]
            reqs.append((a, rng.standard_normal(a.shape[0])))
        return reqs + [reqs[0], reqs[1]]  # 2 duplicate payloads

    def _replay(self, operators):
        sink = MemorySink()
        server = ECGServer(
            ServeConfig(solver=SolverConfig(t=4, tol=1e-8), max_batch=4),
            tracer=Tracer(sinks=[sink]),
        )
        tickets = [server.submit(a, b) for a, b in self._trace(operators)]
        server.flush()
        assert all(tk.done for tk in tickets)
        return sink, server, tickets

    def test_counters_derive_from_trace_structure(self, operators):
        sink, server, tickets = self._replay(operators)
        # 12 submissions over 2 distinct operators: first sight of each is
        # the only registry miss, everything else hits the resident session
        assert sink.counter_value("serve.submitted") == self.N_REQUESTS
        assert sink.counter_value("serve.completed") == self.N_REQUESTS
        assert sink.counter_value("registry.misses") == 2
        assert sink.counter_value("registry.hits") == self.N_REQUESTS - 2
        assert sink.counter_value("registry.builds") == 2
        assert sink.counter_value("serve.rejected") is None  # never emitted

    def test_lifecycle_spans_cover_every_request(self, operators):
        sink, server, tickets = self._replay(operators)
        waits = sink.by_name("serve/queue_wait")
        assert len(waits) == self.N_REQUESTS
        assert {s.args["request_id"] for s in waits} == set(
            range(self.N_REQUESTS)
        )
        assert all(s.dur >= 0 for s in waits)
        q = server.stats()["queue"]
        assert len(sink.by_name("serve/dispatch")) == q["batches"]
        drains = sink.by_name("serve/drain")
        assert len(drains) == len(sink.by_name("serve/retire"))
        assert sum(s.args["requests"] for s in drains) == self.N_REQUESTS
        # rolling window: every completion sampled, ordered percentiles
        roll = q["rolling"]
        assert roll["n"] == self.N_REQUESTS
        assert roll["p50"] <= roll["p95"] <= roll["p99"]

    def test_metric_sequence_is_replay_deterministic(self, operators):
        seq = []
        for _ in range(2):
            sink, _, _ = self._replay(operators)
            seq.append([
                (m["kind"], m["name"], m["value"]) for m in sink.metrics
            ])
        assert seq[0] == seq[1]

    def test_untraced_server_state_identical(self, operators):
        """The tracer is observation only: counters/batches/results of a
        traced replay match an untraced one exactly."""
        _, traced, t_tickets = self._replay(operators)
        plain = ECGServer(
            ServeConfig(solver=SolverConfig(t=4, tol=1e-8), max_batch=4)
        )
        p_tickets = [plain.submit(a, b) for a, b in self._trace(operators)]
        plain.flush()
        ts, ps = traced.stats(), plain.stats()
        for section in ("registry", "queue"):
            a, b = dict(ts[section]), dict(ps[section])
            # wall-time fields differ run to run; structure must not
            a.pop("builds", None), b.pop("builds", None)
            a.pop("rolling", None), b.pop("rolling", None)
            a.pop("solver_traces", None), b.pop("solver_traces", None)
            a.pop("solver_solves", None), b.pop("solver_solves", None)
            assert a == b
        for tk_t, tk_p in zip(t_tickets, p_tickets):
            assert np.array_equal(np.asarray(tk_t.result.x),
                                  np.asarray(tk_p.result.x))


# ------------------------------------------------------------------- config
class TestServeConfig:
    def test_defaults_coerce(self):
        cfg = ServeConfig.coerce(None)
        assert cfg.solver.adaptive.policy is not None  # rankrev default

    def test_dict_solver_coerces(self):
        cfg = ServeConfig(solver=dict(t=2, tol=1e-6))
        assert cfg.solver.t == 2

    @pytest.mark.parametrize("bad", [
        dict(registry_bytes=0),
        dict(max_batch=0),
        dict(max_wait_s=-1.0),
        dict(max_pending=0),
        dict(cache_dir=123),
    ])
    def test_validation_errors(self, bad):
        with pytest.raises(ValueError):
            ServeConfig(**bad)

    def test_replace_rejects_unknown(self):
        with pytest.raises(ValueError, match="unknown ServeConfig override"):
            ServeConfig().replace(no_such_field=1)

    def test_replace_derives(self):
        cfg = ServeConfig().replace(max_batch=3, dedup=False)
        assert cfg.max_batch == 3 and cfg.dedup is False


# --------------------------------------------------------------- solve_many
class TestSolveManyPipelined:
    def test_matches_individual_solves(self, operators):
        a = operators[2]
        rng = np.random.default_rng(10)
        bs = [rng.standard_normal(a.shape[0]) for _ in range(4)]
        solver = ECGSolver.build(a, config=SolverConfig(t=4, tol=1e-8))
        many = solver.solve_many(bs)
        solo = ECGSolver.build(a, config=SolverConfig(t=4, tol=1e-8))
        for b, res in zip(bs, many):
            ref = solo.solve(b)
            assert np.array_equal(np.asarray(res.x), np.asarray(ref.x))
            assert res.n_iters == ref.n_iters
        assert solver.stats.solves == 4
        assert solver.stats.traces == solo.stats.traces  # one program each


# ------------------------------------------------------------- width packing
class TestWidthPacking:
    def _config(self, **kw):
        defaults = dict(
            solver=SolverConfig(t=4, tol=1e-8, adaptive="rankrev"),
            packing=dict(pack="width", max_pack_width=16),
        )
        defaults.update(kw)
        return ServeConfig(**defaults)

    def test_packed_relres_contract_and_iter_bound(self, operators):
        """Every packed request meets its OWN tolerance, and the pack
        converges no slower than the slowest solo solve (the flexible-ECG
        shared-search-space bound, with slack for the coupling)."""
        a = operators[0]
        rng = np.random.default_rng(11)
        bs = [rng.standard_normal(a.shape[0]) for _ in range(4)]
        tols = [1e-4, 1e-6, 1e-8, 1e-8]
        server = ECGServer(self._config())
        tks = [server.submit(a, b, tol=tol) for b, tol in zip(bs, tols)]
        assert all(tk.done for tk in tks)  # capacity 4 -> eager dispatch
        solo = ECGSolver.build(a, config=server.config.solver)
        max_solo = max(solo.solve(b).n_iters for b in bs)
        for tk, b, tol in zip(tks, bs, tols):
            assert tk.result.pack["tol"] == tol
            assert tk.relres is not None
            # tol is an absolute residual-norm bound; the measured true
            # relres is ||r|| / ||b|| with ||b|| >> 1 here, so <= tol too
            assert tk.relres <= tol
            assert bool(tk.result.converged)
            assert tk.result.n_iters <= max_solo + 5
        assert tks[0].result.n_iters <= tks[3].result.n_iters  # loosest first

    def test_pack_off_is_bit_identical_to_solo(self, operators):
        """pack="off" (the default) leaves the dispatch-batched path — and
        its bit-identity guarantee — untouched."""
        a = operators[1]
        rng = np.random.default_rng(12)
        bs = [rng.standard_normal(a.shape[0]) for _ in range(3)]
        server = ECGServer(self._config(packing="off"))
        tks = [server.submit(a, b) for b in bs]
        server.flush()
        solo = ECGSolver.build(a, config=server.config.solver)
        for tk, b in zip(tks, bs):
            ref = solo.solve(b)
            assert np.array_equal(np.asarray(tk.result.x), np.asarray(ref.x))
            assert tk.result.n_iters == ref.n_iters
            assert tk.pack_id is None and tk.relres is None
            assert tk.completed_s is not None  # latency stamps on all paths
        assert server.queue.stats()["packs"] == 0

    def test_packed_not_bit_identical_but_honest(self, operators):
        """The coupling is real (iterate sequences differ from solo) and
        the telemetry is honest about it: per-request histories end at the
        request's own retirement, not at the pack's last iteration."""
        a = operators[0]
        rng = np.random.default_rng(13)
        bs = [rng.standard_normal(a.shape[0]) for _ in range(4)]
        server = ECGServer(self._config())
        tks = [server.submit(a, b) for b in bs]
        for tk in tks:
            res = tk.result
            assert res.pack["n_groups"] == 4 and res.pack["width"] == 16
            assert res.pack["packed_iters"] >= res.n_iters
            hist = np.asarray(res.res_hist)
            assert np.isfinite(hist[res.n_iters])
            assert hist[res.n_iters] <= res.pack["tol"]
            assert np.all(np.isnan(hist[res.n_iters + 1:]))

    def test_single_request_still_packs(self, operators):
        a = operators[2]
        b = np.random.default_rng(14).standard_normal(a.shape[0])
        server = ECGServer(self._config())
        tk = server.submit(a, b)
        server.flush()
        assert tk.done and tk.pack_width == 4 and tk.group_index == 0
        assert tk.relres <= server.config.solver.tol

    def test_tol_requires_packing(self, operators):
        server = ECGServer(self._config(packing="off"))
        with pytest.raises(ValueError, match="width-packing"):
            server.submit(operators[0], np.ones(operators[0].shape[0]),
                          tol=1e-4)

    def test_distinct_tols_do_not_dedup(self, operators):
        a = operators[0]
        b = np.random.default_rng(15).standard_normal(a.shape[0])
        server = ECGServer(self._config())
        t1 = server.submit(a, b, tol=1e-4)
        t2 = server.submit(a, b.copy(), tol=1e-8)  # same payload, other tol
        server.flush()
        assert t1.key != t2.key
        assert t1.result is not t2.result
        assert t1.group_index != t2.group_index  # separate slabs of one pack
        assert t1.pack_id == t2.pack_id
        fp = fingerprint_csr(a)
        assert payload_key(fp, b) == payload_key(fp, b, tol=None)
        assert payload_key(fp, b, tol=1e-4) != payload_key(fp, b)

    def test_deadline_timer_deterministic(self, operators):
        """An injected clock drives the packing deadline: the pack closes
        exactly when the oldest request ages past max_wait_s, and the
        resulting layout is a pure function of the (trace, clock) pair."""
        a = operators[0]
        solver = ECGSolver.build(
            a, config=SolverConfig(t=4, tol=1e-8, adaptive="rankrev")
        )
        fp = fingerprint_csr(a)
        rng = np.random.default_rng(16)
        bs = [rng.standard_normal(a.shape[0]) for _ in range(2)]

        def replay():
            now = [0.0]
            q = RequestQueue(
                packing=PackingConfig(pack="width", max_pack_width=16,
                                      max_wait_s=0.5),
                clock=lambda: now[0],
            )
            q.submit(fp, bs[0], solver=solver)
            now[0] = 0.4
            q.submit(fp, bs[1], solver=solver)
            assert not q.due()  # capacity 4 not reached, oldest aged 0.4
            now[0] = 0.6
            assert q.due()  # deadline: oldest request is now 0.6 old
            tickets = q.drain()
            now[0] = 0.7
            return q, tickets

        q1, tk1 = replay()
        q2, tk2 = replay()
        assert q1.stats()["pack_layouts"] == q2.stats()["pack_layouts"]
        assert [t.pack_id for t in tk1] == [t.pack_id for t in tk2]
        assert [t.completed_s for t in tk1] == [t.completed_s for t in tk2]
        for u, v in zip(tk1, tk2):
            assert np.array_equal(np.asarray(u.result.x),
                                  np.asarray(v.result.x))

    def test_retirement_byte_accounting(self):
        """The exchange re-slice behind per-request retirement, replayed on
        the host: at every retirement width the sliced plan delivers halos
        bit-exactly, and the wire bytes drop in proportion to the retired
        slabs — late finishers stop paying early finishers' bytes."""
        from repro.core.machines import BLUE_WATERS
        from repro.core.node_aware import build_exchange_plan, simulate_plan
        from repro.sparse import partition_csr

        a = fd_laplace_2d(13)
        pm = partition_csr(a, 8)
        plan = build_exchange_plan(pm, 2, 4, "optimal", t=16,
                                   machine=BLUE_WATERS)
        rng = np.random.default_rng(17)
        widths = [16, 12, 8, 4]  # 4 packed requests of t=4 retiring one by one
        bytes_seen = []
        for w in widths:
            x = rng.standard_normal((a.shape[0], w))
            halos = simulate_plan(plan, pm, x, at_width=w)
            for d in range(8):
                assert np.array_equal(halos[d], x[pm.halo_sources[d]])
            bytes_seen.append(plan.at_width(w).wire_bytes())
        assert bytes_seen == sorted(bytes_seen, reverse=True)
        assert bytes_seen[-1] < bytes_seen[0]
        # accounting consistency: slicing then counting == counting at width
        for w in widths[1:]:
            assert plan.at_width(w).wire_bytes() == plan.wire_bytes(width=w)

    def test_latency_percentiles_helper(self):
        class T:
            def __init__(self, s, c):
                self.submitted_s, self.completed_s = s, c

        p = latency_percentiles([T(0.0, 1.0), T(0.0, 2.0), T(1.0, 2.0),
                                 T(0.0, None)])
        assert p["n"] == 3
        assert p["mean"] == pytest.approx(4.0 / 3.0)
        assert p["p50"] == 1.0 and p["p50"] <= p["p95"] <= p["p99"] <= 2.0
        # no completed tickets -> explicit empty result, never NaN and
        # never np.percentile on an empty array
        for empty in (latency_percentiles([]),
                      latency_percentiles([T(0.0, None)])):
            assert empty == dict(n=0, mean=None, p50=None, p95=None,
                                 p99=None)

    def test_packing_config_validation(self):
        assert not PackingConfig().active
        assert PackingConfig.coerce("width").active
        assert PackingConfig.coerce(None).pack == "off"
        cfg = PackingConfig.coerce(dict(pack="width", max_pack_width=8))
        assert cfg.max_pack_width == 8
        with pytest.raises(ValueError, match="pack must be"):
            PackingConfig(pack="columns")
        with pytest.raises(ValueError, match="max_pack_width"):
            PackingConfig(max_pack_width=0)
        with pytest.raises(ValueError, match="max_wait_s"):
            PackingConfig(max_wait_s=-0.1)
        with pytest.raises(TypeError):
            PackingConfig.coerce(42)
        assert ServeConfig(packing="width").packing.active

    def test_true_relres_matches_dense(self, operators):
        a = operators[2]
        rng = np.random.default_rng(18)
        x = rng.standard_normal(a.shape[0])
        b = rng.standard_normal(a.shape[0])
        dense = np.asarray(a.todense())
        expect = np.linalg.norm(dense @ x - b) / np.linalg.norm(b)
        assert abs(true_relres(a, x, b) - expect) < 1e-12


# -------------------------------------------------- conversion warm starts
class TestConversionWarmStart:
    def _cfg(self, **kw):
        return ServeConfig(
            solver=SolverConfig(t=4, tol=1e-8, adaptive="rankrev",
                                kernel=dict(backend="pallas")),
            **kw,
        )

    def test_eviction_readmission_skips_conversion(self, operators):
        """An evicted operator's Block-ELL arrays survive in the side
        table: re-admission rebuilds the session with zero re-conversions
        and bit-identical results."""
        a1, a2 = operators[0], operators[1]
        reg = OperatorRegistry(self._cfg(registry_bytes=1))
        k1, s1 = reg.get(a1)
        assert s1.stats.conv_analyzed and not s1.stats.conv_reused
        reg.get(a2)  # tiny budget: evicts a1
        assert k1 not in reg
        _, s1b = reg.get(a1)  # re-admission
        assert s1b.stats.conv_reused and not s1b.stats.conv_analyzed
        b = np.random.default_rng(19).standard_normal(a1.shape[0])
        assert np.array_equal(np.asarray(s1.solve(b).x),
                              np.asarray(s1b.solve(b).x))
        st = reg.stats()
        assert st["conv_reused"] == 1 and st["conv_resident"] == 2

    def test_restart_skips_tile_analysis(self, operators, tmp_path):
        """A restarted server loads the persisted tile meta: the rebuild
        direct-fills the Block-ELL arrays without re-running the analysis
        pass (schema-2 warm-start entries)."""
        a = operators[0]
        cfg = self._cfg(cache_dir=str(tmp_path))
        reg1 = OperatorRegistry(cfg)
        _, s1 = reg1.get(a)
        assert s1.stats.conv_analyzed
        reg2 = OperatorRegistry(cfg)  # simulated restart: no arrays in memory
        _, s2 = reg2.get(a)
        rec = reg2.build_records[-1]
        assert rec["warm"] and not rec["conv_analyzed"]
        assert not rec["conv_reused"]  # arrays direct-filled, not reused
        b = np.random.default_rng(20).standard_normal(a.shape[0])
        assert np.array_equal(np.asarray(s1.solve(b).x),
                              np.asarray(s2.solve(b).x))

    def test_corrupt_conversion_meta_is_reanalyzed(self, operators, tmp_path):
        """A stale/garbled conversion entry triggers a fresh analysis,
        never an error (same corruption contract as the tuning payload)."""
        a = operators[0]
        cfg = self._cfg(cache_dir=str(tmp_path))
        OperatorRegistry(cfg).get(a)
        path = tmp_path / os.listdir(tmp_path)[0]
        d = json.loads(path.read_text())
        assert isinstance(d.get("conversion"), dict)  # schema 2 persisted it
        d["conversion"] = dict(br="bogus")
        path.write_text(json.dumps(d))
        reg = OperatorRegistry(cfg)
        _, s = reg.get(a)
        rec = reg.build_records[-1]
        assert rec["warm"] and rec["conv_analyzed"]  # fell back to analysis
        assert bool(s.solve(np.ones(a.shape[0])).converged)

    def test_schema1_entry_upgraded_in_place(self, operators, tmp_path):
        """A pre-conversion (schema 1) warm entry still hits for tuning and
        is upgraded with the conversion meta on the next build."""
        a = operators[0]
        cfg = self._cfg(cache_dir=str(tmp_path))
        OperatorRegistry(cfg).get(a)
        path = tmp_path / os.listdir(tmp_path)[0]
        d = json.loads(path.read_text())
        d["schema"] = 1
        d.pop("conversion")
        path.write_text(json.dumps(d))
        reg = OperatorRegistry(cfg)
        reg.get(a)
        rec = reg.build_records[-1]
        assert rec["warm"]  # schema-1 entries still answer
        upgraded = json.loads(path.read_text())
        assert upgraded["schema"] == 2
        assert isinstance(upgraded["conversion"], dict)
