"""Decoder-only transformer (dense / MoE / VLM-backbone) with 2-D sharding.

Covers: phi3-medium-14b, stablelm-1.6b, granite-20b/8b, phi3.5-moe, olmoe,
paligemma-3b (image prefix stubbed as precomputed patch embeddings per the
assignment).  Layers run under ``lax.scan`` with optional remat and
sequence-parallel residual stream.
"""

from __future__ import annotations

import functools
from typing import Any

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.models.common import ArchConfig, MeshAxes, constrain
from repro.models import layers as L
from repro.models.moe import moe_ffn


# ------------------------------------------------------------------ params
def layer_shapes(cfg: ArchConfig) -> dict[str, tuple]:
    d, f, h, kv, dh, n = cfg.d_model, cfg.d_ff, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim, cfg.n_layers
    shapes = {
        "ln1": (n, d),
        "wq": (n, d, h, dh),
        "wk": (n, d, kv, dh),
        "wv": (n, d, kv, dh),
        "wo": (n, h, dh, d),
        "ln2": (n, d),
    }
    if cfg.family == "moe":
        e = cfg.n_experts
        shapes |= {
            "router": (n, d, e),
            "we_g": (n, e, d, f),
            "we_u": (n, e, d, f),
            "we_d": (n, e, f, d),
        }
        if cfg.mlp != "swiglu":
            shapes.pop("we_g")
    else:
        shapes |= {"wg": (n, d, f), "wu": (n, d, f), "wd": (n, f, d)}
        if cfg.mlp != "swiglu":
            shapes.pop("wg")
    return shapes


def param_shapes(cfg: ArchConfig) -> dict[str, Any]:
    shapes = {
        "emb": (cfg.vocab_padded, cfg.d_model),
        "final_ln": (cfg.d_model,),
        "layers": layer_shapes(cfg),
    }
    if not cfg.tie_embeddings:
        shapes["lm_head"] = (cfg.d_model, cfg.vocab_padded)
    return shapes


def abstract_params(cfg: ArchConfig):
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s, cfg.dtype),
        param_shapes(cfg),
        is_leaf=lambda s: isinstance(s, tuple),
    )


def init_params(cfg: ArchConfig, key):
    shapes = param_shapes(cfg)
    flat, treedef = jax.tree.flatten(shapes, is_leaf=lambda s: isinstance(s, tuple))
    keys = jax.random.split(key, len(flat))
    leaves = []
    for k, shape in zip(keys, flat):
        fan_in = shape[-2] if len(shape) > 1 else shape[-1]
        if len(shape) <= 2 and shape[-1] == cfg.d_model:  # norms
            leaves.append(jnp.ones(shape, cfg.dtype))
        else:
            leaves.append(
                (jax.random.normal(k, shape) * (0.02 if len(shape) <= 2 else fan_in ** -0.5)).astype(cfg.dtype)
            )
    return jax.tree.unflatten(treedef, leaves)


def param_specs(cfg: ArchConfig, axes: MeshAxes) -> dict[str, Any]:
    """2-D FSDP x TP PartitionSpecs (divisibility-aware, DESIGN.md §4)."""
    d, f, h, kv, dh = cfg.d_model, cfg.d_ff, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    vp = cfg.vocab_padded
    fs, tp = axes.fs, axes.tp
    specs = {
        "emb": P(tp(vp), fs(d)),
        "final_ln": P(None),
        "layers": {
            "ln1": P(None, None),
            "ln2": P(None, None),
            "wq": P(None, fs(d), tp(h), None),
            "wk": P(None, fs(d), tp(kv), None),
            "wv": P(None, fs(d), tp(kv), None),
            "wo": P(None, tp(h), None, fs(d)),
        },
    }
    if cfg.family == "moe":
        e = cfg.n_experts
        specs["layers"] |= {
            "router": P(None, fs(d), None),
            "we_g": P(None, tp(e), fs(d), None),
            "we_u": P(None, tp(e), fs(d), None),
            "we_d": P(None, tp(e), None, fs(d)),
        }
        if cfg.mlp != "swiglu":
            specs["layers"].pop("we_g")
    else:
        specs["layers"] |= {
            "wg": P(None, fs(d), tp(f)),
            "wu": P(None, fs(d), tp(f)),
            "wd": P(None, tp(f), fs(d)),
        }
        if cfg.mlp != "swiglu":
            specs["layers"].pop("wg")
    if not cfg.tie_embeddings:
        specs["lm_head"] = P(fs(d), tp(vp))
    return specs


# ----------------------------------------------------------------- forward
def _residual_spec(cfg: ArchConfig, axes: MeshAxes, s: int):
    seq_ax = (
        axes.model
        if cfg.seq_parallel and axes.model and s % axes.size(axes.model) == 0
        else None
    )
    return (axes.batch, seq_ax, None)


def decoder_layer(cfg: ArchConfig, mesh: Mesh, axes: MeshAxes, x, p, positions, mask,
                  mask_kind: str = "causal"):
    s = x.shape[1]
    rspec = _residual_spec(cfg, axes, s)
    h = L.rms_norm(x, p["ln1"], cfg.norm_eps)
    q, k, v = L.qkv(cfg, h, p, positions)
    o = L.attention(cfg, mesh, axes, q, k, v, mask, mask_kind=mask_kind)
    x = x + constrain(jnp.einsum("bshe,hed->bsd", o, p["wo"]), mesh, *rspec)
    h = L.rms_norm(x, p["ln2"], cfg.norm_eps)
    if cfg.family == "moe":
        ff, aux = moe_ffn(cfg, mesh, axes, h, p)
    else:
        ff, aux = L.mlp_block(cfg, mesh, axes, h, p), 0.0
    x = x + constrain(ff, mesh, *rspec)
    return x, aux


def forward(
    cfg: ArchConfig,
    mesh: Mesh,
    params,
    tokens=None,           # (B, S) int32
    embeds=None,           # (B, S_img, D) for VLM prefix (stub frontend)
    positions=None,
    layer_range: tuple[int, int] | None = None,
):
    """Token (+ optional image-prefix) forward to final hidden states."""
    axes = MeshAxes.from_mesh(mesh)
    x = params["emb"][tokens].astype(cfg.dtype)
    if embeds is not None:
        x = jnp.concatenate([embeds.astype(cfg.dtype), x], axis=1)
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.arange(s)[None, :]
    rspec = _residual_spec(cfg, axes, s)
    x = constrain(x, mesh, *rspec)

    if cfg.family == "vlm" and embeds is not None:
        mask_kind = f"prefix:{embeds.shape[1]}"
        mask = None if cfg.attn_chunk else L.prefix_lm_mask(s, embeds.shape[1])
    else:
        mask_kind = "causal"
        mask = None if cfg.attn_chunk else L.causal_mask(s)

    def body(carry, lp):
        y, aux = decoder_layer(cfg, mesh, axes, carry, lp, positions, mask, mask_kind)
        return constrain(y, mesh, *rspec), aux

    if cfg.remat:
        body = jax.remat(body)
    if cfg.unroll:
        auxs = []
        for i in range(cfg.n_layers):
            x, a = body(x, jax.tree.map(lambda w: w[i], params["layers"]))
            auxs.append(a)
        auxs = jnp.stack(auxs) if cfg.family == "moe" else jnp.zeros(())
    else:
        x, auxs = jax.lax.scan(body, x, params["layers"])
    x = L.rms_norm(x, params["final_ln"], cfg.norm_eps)
    return x, jnp.sum(auxs) if cfg.family == "moe" else 0.0


def logits_from_hidden(cfg: ArchConfig, mesh: Mesh, params, x):
    axes = MeshAxes.from_mesh(mesh)
    head = params["emb"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x, head.astype(x.dtype))
    return constrain(logits, mesh, axes.batch, None, axes.tp(cfg.vocab_padded))


def cross_entropy(cfg: ArchConfig, logits, labels, mask=None):
    """Stable CE over the padded vocab (pad ids masked to -inf)."""
    vp = logits.shape[-1]
    valid = (jnp.arange(vp) < cfg.vocab)[None, None, :]
    logits = jnp.where(valid, logits.astype(jnp.float32), -jnp.inf)
    lse = jax.nn.logsumexp(logits, axis=-1)
    picked = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - picked
    if mask is not None:
        nll = nll * mask
        return nll.sum() / jnp.maximum(mask.sum(), 1)
    return nll.mean()


def lm_loss(cfg: ArchConfig, mesh: Mesh, params, x, labels):
    """Projection + CE, optionally chunked over the sequence so the fp32
    (B, S, V) logits never materialize at once (§Perf lever)."""
    if not cfg.loss_chunk or x.shape[1] % cfg.loss_chunk:
        return cross_entropy(cfg, logits_from_hidden(cfg, mesh, params, x), labels)
    c = cfg.loss_chunk
    nc = x.shape[1] // c
    xs = x.reshape(x.shape[0], nc, c, -1).transpose(1, 0, 2, 3)
    ls = labels.reshape(labels.shape[0], nc, c).transpose(1, 0, 2)

    def body(tot, inp):
        xc, lc = inp
        logits = logits_from_hidden(cfg, mesh, params, xc)
        return tot + cross_entropy(cfg, logits, lc) * lc.size, None

    tot, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (xs, ls))
    return tot / labels.size


def loss_fn(cfg: ArchConfig, mesh: Mesh):
    def f(params, batch):
        embeds = batch.get("patch_embeds") if cfg.family == "vlm" else None
        x, aux = forward(cfg, mesh, params, tokens=batch["tokens"], embeds=embeds)
        if embeds is not None:
            x = x[:, embeds.shape[1] :]  # loss over text positions only
        loss = lm_loss(cfg, mesh, params, x, batch["labels"])
        return loss + 0.01 * aux

    return f


# ------------------------------------------------------------------ decode
def cache_shapes(cfg: ArchConfig, batch: int, seq: int):
    kv, dh = cfg.n_kv_heads, cfg.head_dim
    return {
        "k": (cfg.n_layers, batch, seq, kv, dh),
        "v": (cfg.n_layers, batch, seq, kv, dh),
    }


def abstract_cache(cfg: ArchConfig, batch: int, seq: int):
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s, cfg.dtype),
        cache_shapes(cfg, batch, seq),
        is_leaf=lambda s: isinstance(s, tuple),
    )


def init_cache(cfg: ArchConfig, batch: int, seq: int):
    return jax.tree.map(
        lambda s: jnp.zeros(s, cfg.dtype),
        cache_shapes(cfg, batch, seq),
        is_leaf=lambda s: isinstance(s, tuple),
    )


def cache_specs(cfg: ArchConfig, axes: MeshAxes, batch: int, seq: int) -> dict:
    """KV sharded over "model" when divisible, else the *sequence* dim is
    sharded over "model" (memory-parallel attention — DESIGN.md §4)."""
    kv_tp = axes.tp(cfg.n_kv_heads)
    seq_tp = None if kv_tp else axes.tp(seq)
    batch_ax = axes.batch if batch % int(np.prod([axes.size(a) for a in axes.batch])) == 0 else None
    spec = P(None, batch_ax, seq_tp, kv_tp, None)
    return {"k": spec, "v": spec}


def decode_step(cfg: ArchConfig, mesh: Mesh):
    """One-token decode against a (B, S_cache) KV cache.

    batch = {"token": (B,) int32, "pos": (B,) int32 current positions}
    """
    axes = MeshAxes.from_mesh(mesh)

    def f(params, cache, batch):
        token, pos = batch["token"], batch["pos"]
        b = token.shape[0]
        x = params["emb"][token][:, None].astype(cfg.dtype)  # (B, 1, D)
        s_cache = cache["k"].shape[2]

        def body(carry, inputs):
            x = carry
            lp, kc, vc = inputs
            h = L.rms_norm(x, lp["ln1"], cfg.norm_eps)
            q, k, v = L.qkv(cfg, h, lp, pos[:, None])
            kc = _scatter_cache(kc, k, pos)
            vc = _scatter_cache(vc, v, pos)
            mask = (jnp.arange(s_cache)[None, None, None, :] <= pos[:, None, None, None])
            o = L.attention(cfg, mesh, axes, q, kc, vc, mask)
            x = x + jnp.einsum("bshe,hed->bsd", o, lp["wo"])
            h = L.rms_norm(x, lp["ln2"], cfg.norm_eps)
            if cfg.family == "moe":
                ff, _ = moe_ffn(cfg, mesh, axes, h, lp)
            else:
                ff = L.mlp_block(cfg, mesh, axes, h, lp)
            return x + ff, (kc, vc)

        if cfg.unroll:
            kcs, vcs = [], []
            for i in range(cfg.n_layers):
                lp = jax.tree.map(lambda w: w[i], params["layers"])
                x, (kc, vc) = body(x, (lp, cache["k"][i], cache["v"][i]))
                kcs.append(kc), vcs.append(vc)
            kcs, vcs = jnp.stack(kcs), jnp.stack(vcs)
        else:
            x, (kcs, vcs) = jax.lax.scan(body, x, (params["layers"], cache["k"], cache["v"]))
        x = L.rms_norm(x, params["final_ln"], cfg.norm_eps)
        logits = logits_from_hidden(cfg, mesh, params, x)[:, 0]
        return logits, {"k": kcs, "v": vcs}

    return f


def _scatter_cache(cache, kv_new, pos):
    """cache (B,S,KV,dh) <- kv_new (B,1,KV,dh) at per-batch positions."""
    b = cache.shape[0]
    onehot = jax.nn.one_hot(pos, cache.shape[1], dtype=cache.dtype)  # (B, S)
    return cache * (1 - onehot[..., None, None]) + kv_new * onehot[..., None, None]


def train_input_specs(cfg: ArchConfig, mesh: Mesh, batch: int, seq: int):
    axes = MeshAxes.from_mesh(mesh)
    bspec = P(axes.batch, None)
    out = {
        "tokens": (jax.ShapeDtypeStruct((batch, seq), jnp.int32), bspec),
        "labels": (jax.ShapeDtypeStruct((batch, seq), jnp.int32), bspec),
    }
    if cfg.family == "vlm":
        out["patch_embeds"] = (
            jax.ShapeDtypeStruct((batch, cfg.n_patches, cfg.d_model), cfg.dtype),
            P(axes.batch, None, None),
        )
    return out
