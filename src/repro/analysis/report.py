"""Render dry-run JSON into the EXPERIMENTS.md roofline tables.

    PYTHONPATH=src python -m repro.analysis.report experiments/dryrun.json
"""

from __future__ import annotations

import json
import sys
from pathlib import Path


def fmt_bytes(b: float) -> str:
    return f"{b/2**30:.2f}"


def _hint(r: dict) -> str:
    """One sentence on what would move the dominant term down."""
    rl = r["roofline"]
    dom = rl["dominant"]
    kind = r.get("kind", "")
    if dom == "memory":
        if kind in ("train", "prefill"):
            return "fuse/chunk attention + chunked CE loss to kill S×S and fp32-logit HBM traffic"
        return "widen per-chip batch or quantize KV cache; decode is bandwidth-bound by design"
    if dom == "collective":
        if r.get("arch", "").endswith("moe_42b") or "moe" in r.get("arch", ""):
            return "cast TP/EP combine psums to bf16 and overlap expert all-reduce with attention"
        return "reshard: bf16 psums, fold pod-axis gradient allreduce into hierarchical 2-step schedule"
    return "increase per-chip arithmetic intensity (larger microbatch) or reduce remat recompute"


def render(path: str) -> str:
    rs = json.loads(Path(path).read_text())
    singles = [r for r in rs if r.get("mesh") == "single" and "roofline" in r]
    multis = [r for r in rs if r.get("mesh") == "multi" and "memory" in r]
    skips = [r for r in rs if "skipped" in r]
    errors = [r for r in rs if "error" in r]

    out = []
    out.append("### Dry-run summary\n")
    out.append(
        f"- compiled cells: {len([r for r in rs if 'memory' in r])} "
        f"(single-pod {len(singles)} with roofline costs, multi-pod {len(multis)}); "
        f"skipped {len(skips)} (documented long_500k/full-attention cells); errors {len(errors)}\n"
    )

    out.append("\n### Roofline table (single-pod 16x16 = 256 chips, v5e constants)\n")
    out.append(
        "| arch | shape | compute s | memory s | collective s | dominant | "
        "MODEL/HLO flops | roofline frac | temp GiB/dev | hint |"
    )
    out.append("|---|---|---|---|---|---|---|---|---|---|")
    order = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2, "long_500k": 3}
    for r in sorted(singles, key=lambda r: (r["arch"], order.get(r["shape"], 9))):
        rl = r["roofline"]
        out.append(
            f"| {r['arch']} | {r['shape']} | {rl['compute_s']:.3g} | {rl['memory_s']:.3g} "
            f"| {rl['collective_s']:.3g} | **{rl['dominant']}** | {rl['useful_flops_ratio']:.2f} "
            f"| {rl['roofline_fraction']:.3f} | {fmt_bytes(r['memory']['temp_bytes_per_dev'])} "
            f"| {_hint(r)} |"
        )

    out.append("\n### Multi-pod (2x16x16 = 512 chips) compile matrix\n")
    out.append("| arch | shape | compiled | temp GiB/dev | collective schedule |")
    out.append("|---|---|---|---|---|")
    for r in sorted(multis, key=lambda r: (r["arch"], order.get(r["shape"], 9))):
        sched = ", ".join(f"{k}:{v}" for k, v in r["collective_ops_schedule"].items() if v)
        out.append(
            f"| {r['arch']} | {r['shape']} | yes ({r['compile_s']}s) "
            f"| {fmt_bytes(r['memory']['temp_bytes_per_dev'])} | {sched} |"
        )

    out.append("\n### Skipped cells\n")
    for r in sorted(skips, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        if r["mesh"] == "single":
            out.append(f"- {r['arch']} × {r['shape']}: {r['skipped']}")
    if errors:
        out.append("\n### Errors\n")
        for r in errors:
            out.append(f"- {r['arch']} × {r['shape']} × {r['mesh']}: {r['error'][:200]}")
    return "\n".join(out)


if __name__ == "__main__":
    print(render(sys.argv[1] if len(sys.argv) > 1 else "experiments/dryrun.json"))
