"""CSR and BSR sparse-matrix containers backed by JAX arrays.

The CSR container mirrors the row-wise storage the paper assumes (§3: "an
n x n matrix A with nnz nonzeros is partitioned row-wise").  The BSR
container is the TPU-native adaptation (DESIGN.md §2): fixed-size dense
tiles so the local SpMBV feeds the MXU instead of doing scalar gathers.

Both containers are pytrees, so they pass through jit/shard_map.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class CSRMatrix:
    """Compressed-sparse-row matrix.

    indptr:  (n_rows + 1,) int32
    indices: (nnz,) int32 column ids
    data:    (nnz,) values
    shape:   static (n_rows, n_cols)
    """

    indptr: jax.Array
    indices: jax.Array
    data: jax.Array
    shape: tuple[int, int]

    def tree_flatten(self):
        return (self.indptr, self.indices, self.data), self.shape

    @classmethod
    def tree_unflatten(cls, shape, leaves):
        return cls(*leaves, shape=shape)

    @property
    def nnz(self) -> int:
        return int(self.indices.shape[0])

    @property
    def n_rows(self) -> int:
        return self.shape[0]

    @property
    def n_cols(self) -> int:
        return self.shape[1]

    def todense(self) -> jax.Array:
        """Dense materialization (tests / small problems only)."""
        n, m = self.shape
        row_ids = jnp.repeat(
            jnp.arange(n, dtype=jnp.int32),
            jnp.diff(self.indptr),
            total_repeat_length=self.nnz,
        )
        dense = jnp.zeros((n, m), self.data.dtype)
        return dense.at[row_ids, self.indices].add(self.data)

    @classmethod
    def from_scipy(cls, mat) -> "CSRMatrix":
        mat = mat.tocsr()
        return cls(
            indptr=jnp.asarray(mat.indptr, jnp.int32),
            indices=jnp.asarray(mat.indices, jnp.int32),
            data=jnp.asarray(mat.data),
            shape=tuple(mat.shape),
        )

    @classmethod
    def from_dense(cls, dense) -> "CSRMatrix":
        dense = np.asarray(dense)
        n, m = dense.shape
        indptr = [0]
        indices = []
        data = []
        for i in range(n):
            (cols,) = np.nonzero(dense[i])
            indices.extend(cols.tolist())
            data.extend(dense[i, cols].tolist())
            indptr.append(len(indices))
        return cls(
            indptr=jnp.asarray(indptr, jnp.int32),
            indices=jnp.asarray(indices, jnp.int32),
            data=jnp.asarray(data, dense.dtype),
            shape=(n, m),
        )


def _expand_rows(indptr: jax.Array, nnz: int) -> jax.Array:
    """indptr -> per-nonzero row index (int32)."""
    n = indptr.shape[0] - 1
    return jnp.repeat(
        jnp.arange(n, dtype=jnp.int32), jnp.diff(indptr), total_repeat_length=nnz
    )


@partial(jax.jit, static_argnames=())
def csr_spmv(a: CSRMatrix, v: jax.Array) -> jax.Array:
    """w = A @ v for a single vector. Segment-sum formulation (XLA-friendly)."""
    rows = _expand_rows(a.indptr, a.nnz)
    prod = a.data * v[a.indices]
    return jax.ops.segment_sum(prod, rows, num_segments=a.n_rows)


@partial(jax.jit, static_argnames=())
def csr_spmbv(a: CSRMatrix, v: jax.Array) -> jax.Array:
    """W = A @ V for a block vector V of shape (n, t).

    The SpMBV kernel of the paper (§4, eq. 4.1): one gather of t-wide rows
    per nonzero + segment reduction.  This is the pure-JAX reference; the
    Pallas BSR kernel in ``repro.kernels`` is the TPU-optimized version.
    """
    rows = _expand_rows(a.indptr, a.nnz)
    prod = a.data[:, None] * v[a.indices, :]  # (nnz, t)
    return jax.ops.segment_sum(prod, rows, num_segments=a.n_rows)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class BSRMatrix:
    """Block-sparse-row matrix with fixed (br x bc) dense tiles.

    block_indptr:  (n_block_rows + 1,) int32
    block_indices: (n_blocks,) int32 block-column ids
    blocks:        (n_blocks, br, bc) values
    shape:         static (n_rows, n_cols) — multiples of (br, bc)
    """

    block_indptr: jax.Array
    block_indices: jax.Array
    blocks: jax.Array
    shape: tuple[int, int]

    def tree_flatten(self):
        return (self.block_indptr, self.block_indices, self.blocks), self.shape

    @classmethod
    def tree_unflatten(cls, shape, leaves):
        return cls(*leaves, shape=shape)

    @property
    def block_shape(self) -> tuple[int, int]:
        return tuple(self.blocks.shape[1:])

    @property
    def n_block_rows(self) -> int:
        return self.block_indptr.shape[0] - 1

    @property
    def n_blocks(self) -> int:
        return int(self.block_indices.shape[0])

    def todense(self) -> jax.Array:
        br, bc = self.block_shape
        nbr = self.n_block_rows
        nbc = self.shape[1] // bc
        brow = _expand_rows(self.block_indptr, self.n_blocks)
        dense = jnp.zeros((nbr, nbc, br, bc), self.blocks.dtype)
        dense = dense.at[brow, self.block_indices].add(self.blocks)
        return dense.transpose(0, 2, 1, 3).reshape(self.shape)


def csr_to_bsr(a: CSRMatrix, br: int, bc: int, pad_rows: bool = True) -> BSRMatrix:
    """Convert CSR -> BSR with (br x bc) tiles (host-side, numpy).

    Zero-pads the matrix up to tile multiples.  Tiles with any nonzero become
    dense blocks — this is the VMEM/MXU trade the paper's philosophy endorses:
    more local flops per communicated/loaded byte.
    """
    indptr = np.asarray(a.indptr)
    indices = np.asarray(a.indices)
    data = np.asarray(a.data)
    n, m = a.shape
    n_pad = (n + br - 1) // br * br if pad_rows else n
    m_pad = (m + bc - 1) // bc * bc
    nbr, nbc = n_pad // br, m_pad // bc

    # bucket nonzeros by (block_row, block_col)
    rows = np.repeat(np.arange(n, dtype=np.int64), np.diff(indptr))
    brow = rows // br
    bcol = indices // bc
    key = brow * nbc + bcol
    order = np.argsort(key, kind="stable")
    key_s = key[order]
    uniq, starts = np.unique(key_s, return_index=True)
    n_blocks = len(uniq)
    blocks = np.zeros((n_blocks, br, bc), dtype=data.dtype)
    block_rows = (uniq // nbc).astype(np.int64)
    block_cols = (uniq % nbc).astype(np.int32)
    ends = np.append(starts[1:], len(key_s))
    r_in = (rows % br)[order]
    c_in = (indices % bc)[order]
    d_s = data[order]
    for bi in range(n_blocks):
        sl = slice(starts[bi], ends[bi])
        blocks[bi, r_in[sl], c_in[sl]] = d_s[sl]

    block_indptr = np.zeros(nbr + 1, dtype=np.int32)
    np.add.at(block_indptr[1:], block_rows, 1)
    block_indptr = np.cumsum(block_indptr).astype(np.int32)
    return BSRMatrix(
        block_indptr=jnp.asarray(block_indptr),
        block_indices=jnp.asarray(block_cols),
        blocks=jnp.asarray(blocks),
        shape=(n_pad, m_pad),
    )
