"""Low-overhead span tracer for solve/comm/serve instrumentation.

The paper's contribution is a *performance analysis* — knowing where ECG
time goes (collectives vs. p2p messages vs. local work) and checking the
byte/latency models against measurements.  :class:`Tracer` is the
substrate that analysis runs on inside this repo: host-side spans around
the phases the models price (build pipeline, per-width solve segments,
serve request lifecycle), each span carrying the structural attributes
the accounting already computes (``wire_bytes``, ``dispatch_count``,
psums/iter) so a trace is self-describing.

Two invariants keep the tracer honest:

* **timers sit at dispatch boundaries, never inside jitted code** — a
  span may wrap the host call that enqueues a device program or the host
  sync that retires it, but nothing a trace would bake into HLO.  The
  hot-loop HLO is byte-identical with tracing on or off (gated in
  ``tests/test_observe.py``), and a traced warm ``solve_many`` stays
  within 3% of untraced (gated in ``benchmarks/observe_bench.py``).
* **off is free** — the default tracer is the :data:`NULL_TRACER`
  singleton whose ``span()`` returns a shared no-op context manager; no
  clock is read, no object allocated per call, and instrumented code
  never branches on a flag.

Usage::

    from repro.observe import Tracer, ChromeTraceSink

    tracer = Tracer(sinks=[ChromeTraceSink("trace.json")])
    with tracer.span("build/partition", cat="build", p=8):
        ...
    tracer.counter("serve.completed", 17)
    tracer.close()          # flush sinks (writes trace.json)

Non-nesting phases (a queue wait that started before the drain span
opened) use the explicit-timestamp :meth:`Tracer.emit`; paired
``begin``/``end`` cover phases that cannot be expressed as a ``with``
block.
"""

from __future__ import annotations

import dataclasses
import time


@dataclasses.dataclass
class Span:
    """One closed (or still-open) traced phase.

    ``t0``/``dur`` are seconds on the tracer's clock (default
    ``time.perf_counter`` — an arbitrary-origin monotonic timeline, not
    wall time).  ``args`` holds the structural attributes; mutate it
    inside the ``with`` block to attach results computed mid-span.
    """

    name: str
    cat: str = ""
    t0: float = 0.0
    dur: float | None = None  # None while open
    tid: int = 0
    args: dict = dataclasses.field(default_factory=dict)

    def to_dict(self) -> dict:
        return dict(name=self.name, cat=self.cat, t0=self.t0,
                    dur=self.dur, tid=self.tid, args=dict(self.args))


class _SpanCtx:
    """Context manager that closes ``span`` on exit — including via an
    exception, so a failing build still produces a well-formed trace."""

    __slots__ = ("_tracer", "span")

    def __init__(self, tracer: "Tracer", span: Span):
        self._tracer = tracer
        self.span = span

    def __enter__(self) -> Span:
        return self.span

    def __exit__(self, exc_type, exc, tb) -> bool:
        if exc_type is not None:
            self.span.args.setdefault("error", exc_type.__name__)
        self._tracer.end(self.span)
        return False  # never swallow


class Tracer:
    """Span + counter/gauge emitter fanning out to pluggable sinks.

    sinks:  objects implementing ``span(Span)`` and
            ``metric(kind, name, value, ts, attrs)`` (see
            :mod:`repro.observe.sinks`); both calls must be cheap — the
            tracer does no buffering of its own.
    clock:  seconds-returning monotonic callable (default
            ``time.perf_counter``).  Injectable so tests — and the serve
            queue, which must share a timeline with its latency stamps —
            control the clock.
    """

    enabled = True

    def __init__(self, sinks=(), clock=None):
        self.sinks = list(sinks)
        self.clock = time.perf_counter if clock is None else clock
        self._open = 0  # open-span depth (nesting sanity, tested)

    # ------------------------------------------------------------- spans
    def span(self, name: str, cat: str = "", **attrs) -> _SpanCtx:
        """Open a span; use as ``with tracer.span(...) as sp:``."""
        return _SpanCtx(self, self.begin(name, cat, **attrs))

    def begin(self, name: str, cat: str = "", **attrs) -> Span:
        """Explicitly open a span (pair with :meth:`end`)."""
        self._open += 1
        return Span(name=name, cat=cat, t0=self.clock(), args=attrs)

    def end(self, span: Span, **attrs) -> Span:
        """Close ``span`` and hand it to every sink."""
        if attrs:
            span.args.update(attrs)
        span.dur = self.clock() - span.t0
        self._open -= 1
        for s in self.sinks:
            s.span(span)
        return span

    def emit(self, name: str, t0: float, dur: float, cat: str = "",
             **attrs) -> Span:
        """Record a span with explicit timestamps (non-nesting phases —
        e.g. a queue wait that began before the enclosing drain span).
        ``t0`` must be on the tracer's clock."""
        span = Span(name=name, cat=cat, t0=t0, dur=float(dur), args=attrs)
        for s in self.sinks:
            s.span(span)
        return span

    @property
    def open_spans(self) -> int:
        return self._open

    # ----------------------------------------------------------- metrics
    def _metric(self, kind: str, name: str, value, attrs: dict):
        ts = self.clock()
        for s in self.sinks:
            s.metric(kind, name, value, ts, attrs)

    def counter(self, name: str, value, **attrs):
        """Sample of a monotonically non-decreasing counter."""
        self._metric("counter", name, value, attrs)

    def gauge(self, name: str, value, **attrs):
        """Sample of a point-in-time value (drift ratio, queue depth)."""
        self._metric("gauge", name, value, attrs)

    def instant(self, name: str, **attrs):
        """Zero-duration event (reseed/recovery/retirement markers)."""
        self._metric("instant", name, 1, attrs)

    # ------------------------------------------------------------- sinks
    def close(self):
        """Flush + close every sink that supports it."""
        for s in self.sinks:
            close = getattr(s, "close", None)
            if close is not None:
                close()


class _NullSpanArgs(dict):
    """Attribute dict that silently drops writes (shared, never grows)."""

    def __setitem__(self, key, value):
        pass

    def update(self, *a, **kw):
        pass

    def setdefault(self, key, default=None):
        return default


class _NullCtx:
    """Shared no-op context manager: ``with NULL_TRACER.span(...)`` costs
    two attribute lookups and no allocation."""

    __slots__ = ()

    def __enter__(self):
        return _NULL_SPAN

    def __exit__(self, exc_type, exc, tb) -> bool:
        return False


class NullTracer(Tracer):
    """The default, disabled tracer — every operation is a no-op.

    Instrumented code holds a tracer unconditionally and never branches;
    with this singleton installed the instrumentation is free (the ≤ 3%
    overhead gate in ``benchmarks/observe_bench.py`` bounds the *enabled*
    cost; the disabled cost is not measurable).
    """

    enabled = False

    def __init__(self):
        super().__init__(sinks=(), clock=lambda: 0.0)

    def span(self, name, cat="", **attrs):
        return _NULL_CTX

    def begin(self, name, cat="", **attrs):
        return _NULL_SPAN

    def end(self, span, **attrs):
        return span

    def emit(self, name, t0, dur, cat="", **attrs):
        return _NULL_SPAN

    def _metric(self, kind, name, value, attrs):
        pass

    def close(self):
        pass


_NULL_SPAN = Span(name="", dur=0.0, args=_NullSpanArgs())
_NULL_CTX = _NullCtx()

#: process-wide disabled tracer; ``tracer or NULL_TRACER`` is the idiom
#: instrumented constructors use to avoid None checks on the hot path.
NULL_TRACER = NullTracer()

_current: Tracer = NULL_TRACER


def get_tracer() -> Tracer:
    """The process-wide default tracer (:data:`NULL_TRACER` unless
    :func:`set_tracer` installed one)."""
    return _current


def set_tracer(tracer: Tracer | None) -> Tracer:
    """Install ``tracer`` as the process-wide default (None resets to the
    null tracer); returns the previous one so callers can restore it."""
    global _current
    prev = _current
    _current = NULL_TRACER if tracer is None else tracer
    return prev


def coerce_tracer(tracer) -> Tracer:
    """``None`` -> the process default; anything else passes through."""
    return _current if tracer is None else tracer
