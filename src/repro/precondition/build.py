"""Build the preconditioner apply callable for a solver handle.

Both builders return ``precond(V, k) -> M⁻¹ₖ V`` (or ``None`` for
``kind="none"``): V is the (n, t) block in the handle's vector layout
(padded per-rank slots distributed), k the traced iteration index — only
the inexact kind reads it.  All kinds are columnwise-linear with a zero
fixed point for fixed k, so zero-masked columns stay zero and the adaptive
width controller composes with every preconditioner unchanged.

Collective accounting (what keeps the two-psum invariant intact):

* block-Jacobi — rank-local batched triangular solves, **zero** extra
  communication of any kind;
* Chebyshev / inexact — extra *SpMBV* applications (p2p halo exchange
  only); no psum is ever issued by a preconditioner apply.
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.precondition.block_jacobi import (
    extract_blocks,
    factor_blocks,
    rank_slot_layout,
    slot_layout,
)
from repro.precondition.chebyshev import (
    distributed_power_matvec,
    make_chebyshev_apply,
    resolve_bounds,
)
from repro.precondition.config import PreconditionConfig
from repro.precondition.inexact import extract_diagonal, make_inexact_apply


def _block_apply(factors, n_rows: int, block: int):
    """Sequential block-Jacobi apply over the plain 0..n-1 row layout."""
    from repro.kernels import block_trisolve

    nb = factors.shape[0]
    n_slots = nb * block
    factors = jnp.asarray(factors)

    def apply(x, k):
        del k
        xp = jnp.pad(x, ((0, n_slots - n_rows), (0, 0)))
        y = block_trisolve(factors.astype(x.dtype), xp.reshape(nb, block, -1))
        return y.reshape(n_slots, -1)[:n_rows]

    return apply


def build_sequential_preconditioner(a, cfg: PreconditionConfig, a_apply):
    """Preconditioner for the single-device handle (``None`` when inactive).

    a_apply: the handle's (n, t) → (n, t) SpMBV — Chebyshev/inexact applies
    compose it, so they run whatever backend the operator was built with.
    """
    if not cfg.active:
        return None
    n = a.shape[0]
    if cfg.kind == "block_jacobi":
        row_of_slot, _ = slot_layout(n, cfg.block)
        factors = factor_blocks(extract_blocks(a, row_of_slot, cfg.block))
        return _block_apply(factors, n, cfg.block)
    if cfg.kind == "chebyshev":
        # λmax power iteration through the vectorized CSR SpMV (the
        # default matvec of estimate_lambda_max) — never a host row loop
        lmin, lmax = resolve_bounds(a, cfg)
        cheb = make_chebyshev_apply(a_apply, lmin, lmax, cfg.degree)
        return lambda x, k: cheb(x)
    # inexact
    diag = extract_diagonal(a)
    return make_inexact_apply(a_apply, diag, cfg.omega, cfg.sweeps)


def build_distributed_preconditioner(a, cfg: PreconditionConfig, op, mesh, a_apply):
    """Preconditioner for the distributed handle (``None`` when inactive).

    Block-Jacobi blocks are carved inside each rank's padded slot range
    (identity on padding slots, blocks never straddle ranks) and applied
    under ``shard_map`` — the solve stays free of preconditioner
    collectives.  Chebyshev/inexact compose the global distributed SpMBV.
    """
    if not cfg.active:
        return None
    if cfg.kind == "chebyshev":
        # λmax power iteration runs *distributed*: width-1 SpMBV sub-plan,
        # p2p halo exchange only — no densified operator on any host, and
        # zero all-reduces (pinned in tests/dist_worker.py)
        lmin, lmax = resolve_bounds(a, cfg, matvec=distributed_power_matvec(op))
        cheb = make_chebyshev_apply(a_apply, lmin, lmax, cfg.degree)
        return lambda x, k: cheb(x)
    if cfg.kind == "inexact":
        diag = extract_diagonal(a, row_of_slot=op.true_row_of_slot())
        return make_inexact_apply(a_apply, diag, cfg.omega, cfg.sweeps)

    # block_jacobi: per-rank factors, shard_map'd local batched solves
    import jax
    from jax.experimental.shard_map import shard_map
    from jax.sharding import NamedSharding
    from jax.sharding import PartitionSpec as P

    from repro.kernels import block_trisolve

    block = cfg.block
    p, rmax = op.p, op.rmax
    rmax_pad = -(-rmax // block) * block
    nb_rank = rmax_pad // block
    row_of_slot = rank_slot_layout(op.true_row_of_slot(), p, block)
    factors_np = factor_blocks(extract_blocks(a, row_of_slot, block))
    # (p * nb_rank, bs, bs), sharded so each rank holds its own factors —
    # device_put happens here, at build time, never inside a trace
    factors = jax.device_put(
        jnp.asarray(factors_np),
        NamedSharding(mesh, P(("node", "proc"), None, None)),
    )

    def local_solve(l, v):  # v: (rmax, t) local block rows
        vp = jnp.pad(v, ((0, rmax_pad - rmax), (0, 0)))
        y = block_trisolve(l.astype(v.dtype), vp.reshape(nb_rank, block, -1))
        return y.reshape(rmax_pad, -1)[:rmax]

    smapped = shard_map(
        local_solve,
        mesh=mesh,
        in_specs=(P(("node", "proc"), None, None), op.vec_spec),
        out_specs=op.vec_spec,
        check_rep=False,
    )
    return lambda x, k: smapped(factors, x)
