"""Breakdown-safe, rank-revealing Gram factorization (pivoted Cholesky).

ECG A-orthonormalizes the t search directions through ``G = ZᵀAZ = CᵀC``
every iteration.  When the columns of Z become (near-)linearly dependent —
a right-hand side that is zero on a subdomain, t larger than the number of
independent residual components, or directions that converged individually —
G is singular and the bare Cholesky propagates NaNs through the whole solve.

The fix, following the flexible/enlarged-CG literature (Moufawad 2023) and
the s-step stability analysis (Moufawad 2018), is structural: factorize G
with *diagonal pivoting* so the numerical rank is revealed, and keep the
block shape (n, t) with the dependent directions zero-masked.  Downstream
products (the packed gram reductions, the Pallas ``fused_gram``/``ecg_tail``
kernels, the two psums of §3.1) are untouched — a zero column contributes
zeros everywhere.

Everything here is jit-compatible with static shapes: t is tiny (≤ 16), so
the factorization is an O(t) ``fori_loop`` of O(t²) vector ops.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def default_rank_rtol(dtype) -> float:
    """Relative pivot threshold: diagonal entries below ``rtol · max(diag G)``
    are treated as numerically dependent directions.  Scaled well above the
    unit roundoff because G's entries already carry O(n) accumulated rounding
    from the gram product."""
    eps = float(jnp.finfo(dtype).eps)
    return eps ** (2.0 / 3.0)  # ~3.6e-11 (f64), ~2.4e-5 (f32)


def pivoted_cholesky(g: jax.Array, rtol: float | None = None):
    """Diagonally pivoted Cholesky of a PSD t x t matrix.

    Returns ``(l, perm, rank)`` with ``G[perm][:, perm] ≈ L·Lᵀ``, L lower
    triangular, and only the first ``rank`` columns of L nonzero.  Pivots are
    chosen greedily as the largest remaining diagonal entry, so once a pivot
    falls below ``rtol · max(diag G)`` all later ones do too — the dependent
    directions are exactly the trailing ``t − rank`` columns.
    """
    t = g.shape[0]
    if rtol is None:
        rtol = default_rank_rtol(g.dtype)
    idx = jnp.arange(t)
    thresh = rtol * jnp.maximum(jnp.max(jnp.diag(g)), jnp.asarray(0.0, g.dtype))

    def step(k, carry):
        a, l, perm, rank = carry
        # pivot: largest remaining diagonal entry (rows/cols >= k)
        d = jnp.where(idx >= k, jnp.diag(a), -jnp.inf)
        j = jnp.argmax(d)
        sw = idx.at[k].set(j).at[j].set(k)  # transposition k <-> j
        a = a[sw][:, sw]
        l = l[sw]
        perm = perm[sw]
        pivot = a[k, k]
        ok = pivot > thresh
        root = jnp.sqrt(jnp.where(ok, pivot, 1.0))
        col = jnp.where(idx > k, a[:, k] / root, 0.0).at[k].set(root)
        col = jnp.where(ok, col, 0.0)  # dependent direction: zero column
        l = l.at[:, k].set(col)
        a = a - jnp.outer(col, col)  # Schur complement update
        return a, l, perm, rank + ok.astype(jnp.int32)

    l0 = jnp.zeros_like(g)
    _, l, perm, rank = jax.lax.fori_loop(
        0, t, step, (g, l0, idx, jnp.int32(0))
    )
    return l, perm, rank


def rank_revealing_apply(g: jax.Array, *mats: jax.Array, rtol: float | None = None):
    """Breakdown-safe replacement for ``[M C⁻¹ for M in mats]``.

    Factorizes ``G[perm][:, perm] = L·Lᵀ`` by :func:`pivoted_cholesky` and
    returns ``(outs, rank, active)`` where ``outs[i] = mats[i][:, perm]·L⁻ᵀ``
    with the ``t − rank`` dependent columns zeroed, ``active`` is the
    (t,)-bool column mask (the first ``rank`` columns), and the outputs keep
    the full (n, t) shape.  The active columns of ``Z[:, perm]·L⁻ᵀ`` are
    A-orthonormal; column order follows the pivot order, which is immaterial
    to the solver (P and AP are permuted identically within one iteration,
    and no cross-iteration column identification is assumed anywhere).
    """
    t = g.shape[0]
    l, perm, rank = pivoted_cholesky(g, rtol=rtol)
    active = jnp.arange(t) < rank
    # unit-ize the dead columns so the triangular solve is nonsingular; their
    # solution rows are garbage and are masked out below.
    l_solve = l + jnp.diag(jnp.where(active, 0.0, 1.0).astype(l.dtype))
    colmask = active.astype(l.dtype)[None, :]
    outs = []
    for m in mats:
        mp = m[:, perm]
        # solve Y·Lᵀ = M_p row-wise  =>  L·Yᵀ = M_pᵀ (lower-triangular solve)
        y = jax.scipy.linalg.solve_triangular(l_solve, mp.T, lower=True).T
        outs.append(y * colmask)
    return outs, rank, active
