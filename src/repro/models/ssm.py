"""Mamba2 (SSD — state-space duality) and the Zamba2 hybrid.

Training uses the chunked matmul form of SSD (intra-chunk quadratic term +
inter-chunk state recurrence), which maps onto the MXU; decode is the O(1)
per-token state update.  Heads shard over "model" (48 and 64 heads for the
assigned configs — both divide 16).

Zamba2: Mamba2 backbone + ONE shared attention+MLP block applied every
``attn_period`` layers (parameters shared across applications, per the
Zamba2 design; per-application LoRA deltas are omitted — noted in DESIGN.md).
"""

from __future__ import annotations

import math
from typing import Any

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.models.common import ArchConfig, MeshAxes, constrain
from repro.models import layers as L


# ------------------------------------------------------------------ params
def ssm_layer_shapes(cfg: ArchConfig, n: int) -> dict[str, tuple]:
    d, di, nst, h = cfg.d_model, cfg.d_inner, cfg.d_state, cfg.n_ssm_heads
    conv_dim = di + 2 * nst
    return {
        "ln": (n, d),
        "in_proj": (n, d, 2 * di + 2 * nst + h),
        "conv_w": (n, cfg.conv_width, conv_dim),
        "conv_b": (n, conv_dim),
        "A_log": (n, h),
        "D_skip": (n, h),
        "dt_bias": (n, h),
        "out_ln": (n, di),
        "out_proj": (n, di, d),
    }


def ssm_layer_specs(cfg: ArchConfig, axes: MeshAxes, n_dim: bool = True) -> dict[str, P]:
    d, di, nst, h = cfg.d_model, cfg.d_inner, cfg.d_state, cfg.n_ssm_heads
    fs = axes.fs
    lead = (None,) if n_dim else ()
    return {
        "ln": P(*lead, None),
        "in_proj": P(*lead, fs(d), axes.tp(2 * di + 2 * nst + h)),
        "conv_w": P(*lead, None, None),
        "conv_b": P(*lead, None),
        "A_log": P(*lead, axes.tp(h)),
        "D_skip": P(*lead, axes.tp(h)),
        "dt_bias": P(*lead, axes.tp(h)),
        "out_ln": P(*lead, None),
        "out_proj": P(*lead, axes.tp(di), fs(d)),
    }


def param_shapes(cfg: ArchConfig) -> dict[str, Any]:
    shapes = {
        "emb": (cfg.vocab_padded, cfg.d_model),
        "final_ln": (cfg.d_model,),
        "layers": ssm_layer_shapes(cfg, cfg.n_layers),
    }
    if not cfg.tie_embeddings:
        shapes["lm_head"] = (cfg.d_model, cfg.vocab_padded)
    if cfg.family == "hybrid":
        d, f, h, kv, dh = cfg.d_model, cfg.d_ff, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
        shapes["shared"] = {
            "ln1": (d,), "ln2": (d,),
            "wq": (d, h, dh), "wk": (d, kv, dh), "wv": (d, kv, dh), "wo": (h, dh, d),
            "wg": (d, f), "wu": (d, f), "wd": (f, d),
        }
    return shapes


def param_specs(cfg: ArchConfig, axes: MeshAxes) -> dict[str, Any]:
    specs = {
        "emb": P(axes.tp(cfg.vocab_padded), axes.fs(cfg.d_model)),
        "final_ln": P(None),
        "layers": ssm_layer_specs(cfg, axes),
    }
    if not cfg.tie_embeddings:
        specs["lm_head"] = P(axes.fs(cfg.d_model), axes.tp(cfg.vocab_padded))
    if cfg.family == "hybrid":
        d, f, h, kv = cfg.d_model, cfg.d_ff, cfg.n_heads, cfg.n_kv_heads
        fs, tp = axes.fs, axes.tp
        specs["shared"] = {
            "ln1": P(None), "ln2": P(None),
            "wq": P(fs(d), tp(h), None), "wk": P(fs(d), tp(kv), None),
            "wv": P(fs(d), tp(kv), None), "wo": P(tp(h), None, fs(d)),
            "wg": P(fs(d), tp(f)), "wu": P(fs(d), tp(f)), "wd": P(tp(f), fs(d)),
        }
    return specs


def abstract_params(cfg: ArchConfig):
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s, cfg.dtype),
        param_shapes(cfg),
        is_leaf=lambda s: isinstance(s, tuple),
    )


def init_params(cfg: ArchConfig, key):
    shapes = param_shapes(cfg)
    flat, treedef = jax.tree_util.tree_flatten_with_path(shapes, is_leaf=lambda s: isinstance(s, tuple))
    keys = jax.random.split(key, len(flat))
    leaves = []
    for k, (path, shape) in zip(keys, flat):
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        if name in ("ln", "out_ln", "final_ln", "ln1", "ln2", "conv_b", "D_skip"):
            leaves.append(jnp.ones(shape, cfg.dtype))
        elif name == "A_log":
            leaves.append(jnp.log(jnp.broadcast_to(jnp.arange(1, shape[-1] + 1, dtype=jnp.float32), shape)).astype(cfg.dtype))
        elif name == "dt_bias":
            leaves.append(jnp.full(shape, -1.0, cfg.dtype))
        else:
            fan_in = shape[-2] if len(shape) > 1 else shape[-1]
            leaves.append((jax.random.normal(k, shape) * fan_in ** -0.5).astype(cfg.dtype))
    return jax.tree.unflatten(treedef, leaves)


# --------------------------------------------------------------------- SSD
def _causal_conv(x, w, b):
    """Depthwise causal conv, x (B,S,C), w (W,C)."""
    ww = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (ww - 1, 0), (0, 0)))
    out = sum(xp[:, i : i + x.shape[1]] * w[i] for i in range(ww))
    return out + b


def ssd_chunked(x, dt, A, B, C, chunk: int):
    """Chunked SSD scan.

    x: (b, s, h, p)   dt: (b, s, h)   A: (h,) negative
    B, C: (b, s, n)   returns y (b, s, h, p) and final state (b, h, p, n),
    both fp32 (state precision; callers cast activations back down).
    """
    x = x.astype(jnp.float32)
    b, s, h, p = x.shape
    n = B.shape[-1]
    nc = s // chunk
    xr = x.reshape(b, nc, chunk, h, p)
    dtr = dt.reshape(b, nc, chunk, h)
    Br = B.reshape(b, nc, chunk, n)
    Cr = C.reshape(b, nc, chunk, n)

    la = dtr * A  # (b, nc, q, h) log-decay per step (negative)
    cum = jnp.cumsum(la, axis=2)  # inclusive
    xbar = xr * dtr[..., None]

    # intra-chunk quadratic term (batched over chunks — one big einsum set).
    # mask the EXPONENT, not the result: exp() of the (positive) anti-causal
    # entries overflows and poisons gradients through the where.
    li = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # (b,nc,q,j,h)
    causal = jnp.tril(jnp.ones((chunk, chunk), dtype=bool))[None, None, :, :, None]
    li = jnp.where(causal, jnp.minimum(li, 0.0), -jnp.inf)
    decay = jnp.exp(li)
    cb = jnp.einsum("bcqn,bcjn->bcqj", Cr, Br)  # (b,nc,q,j)
    y_intra = jnp.einsum("bcqj,bcqjh,bcjhp->bcqhp", cb, decay, xbar)

    # inter-chunk recurrence over states
    sum_la = cum[:, :, -1, :]  # (b,nc,h)
    chunk_in = jnp.einsum(
        "bcjhp,bcjn,bcjh->bchpn", xbar, Br, jnp.exp(sum_la[:, :, None, :] - cum)
    )  # contribution of each chunk to its end-state

    def scan_fn(state, inp):
        ci, sl = inp  # (b,h,p,n), (b,h)
        new = state * jnp.exp(sl)[..., None, None] + ci
        return new, state  # emit the state *entering* the chunk

    s0 = jnp.zeros((b, h, p, n), x.dtype)
    final, entering = jax.lax.scan(
        scan_fn,
        s0,
        (chunk_in.transpose(1, 0, 2, 3, 4), sum_la.transpose(1, 0, 2)),
    )
    entering = entering.transpose(1, 0, 2, 3, 4)  # (b,nc,h,p,n)
    y_inter = jnp.einsum("bcqn,bchpn,bcqh->bcqhp", Cr, entering, jnp.exp(cum))
    y = (y_intra + y_inter).reshape(b, s, h, p)
    return y, final


def ssm_layer(cfg: ArchConfig, mesh: Mesh, axes: MeshAxes, x, p, chunk: int = 128):
    """One Mamba2 block (training path). x: (B, S, D)."""
    b, s, d = x.shape
    di, nst, h = cfg.d_inner, cfg.d_state, cfg.n_ssm_heads
    hd = cfg.ssm_head_dim
    res = x
    xn = L.rms_norm(x, p["ln"], cfg.norm_eps)
    zxbcdt = jnp.einsum("bsd,dk->bsk", xn, p["in_proj"])
    z, xbc, dt = jnp.split(zxbcdt, [di, 2 * di + 2 * nst], axis=-1)
    xbc = jax.nn.silu(_causal_conv(xbc, p["conv_w"], p["conv_b"]))
    xs, B, C = jnp.split(xbc, [di, di + nst], axis=-1)
    xs = constrain(xs.reshape(b, s, h, hd), mesh, axes.batch, None, axes.tp(h), None)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    y, _ = ssd_chunked(xs, dt, A, B.astype(jnp.float32), C.astype(jnp.float32), chunk=min(chunk, s))
    y = y.astype(x.dtype) + xs * p["D_skip"].astype(x.dtype)[None, None, :, None]
    y = y.reshape(b, s, di) * jax.nn.silu(z)
    y = L.rms_norm(y, p["out_ln"], cfg.norm_eps)
    return res + jnp.einsum("bsk,kd->bsd", y, p["out_proj"])


def ssm_decode_layer(cfg: ArchConfig, x, p, state):
    """One-token decode. x: (B, 1, D); state dict {conv: (B,W-1,convdim),
    ssm: (B,H,P,N)} -> (y, new_state)."""
    b = x.shape[0]
    di, nst, h, hd = cfg.d_inner, cfg.d_state, cfg.n_ssm_heads, cfg.ssm_head_dim
    res = x
    xn = L.rms_norm(x, p["ln"], cfg.norm_eps)
    zxbcdt = jnp.einsum("bsd,dk->bsk", xn, p["in_proj"])[:, 0]
    z, xbc, dt = jnp.split(zxbcdt, [di, 2 * di + 2 * nst], axis=-1)
    window = jnp.concatenate([state["conv"], xbc[:, None]], axis=1)  # (B,W,convdim)
    xbc = jax.nn.silu(jnp.einsum("bwc,wc->bc", window, p["conv_w"]) + p["conv_b"])
    new_conv = window[:, 1:]
    xs, B, C = jnp.split(xbc, [di, di + nst], axis=-1)
    xs = xs.reshape(b, h, hd)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    da = jnp.exp(dt * A)  # (B,H)
    s_new = state["ssm"] * da[..., None, None].astype(state["ssm"].dtype) + jnp.einsum(
        "bhp,bn,bh->bhpn", xs.astype(jnp.float32), B.astype(jnp.float32), dt
    ).astype(state["ssm"].dtype)
    y = jnp.einsum("bn,bhpn->bhp", C.astype(s_new.dtype), s_new).astype(x.dtype) \
        + xs * p["D_skip"].astype(x.dtype)[None, :, None]
    y = y.reshape(b, di) * jax.nn.silu(z)
    y = L.rms_norm(y, p["out_ln"], cfg.norm_eps)
    out = res + jnp.einsum("bk,kd->bd", y, p["out_proj"])[:, None].astype(res.dtype)
    return out, {"conv": new_conv.astype(res.dtype), "ssm": s_new}


# ---------------------------------------------------------------- forwards
def _shared_attn_block(cfg, mesh, axes, x, sp, positions):
    h = L.rms_norm(x, sp["ln1"], cfg.norm_eps)
    q, k, v = L.qkv(cfg, h, sp, positions)
    mask = None if cfg.attn_chunk else L.causal_mask(x.shape[1])
    o = L.attention(cfg, mesh, axes, q, k, v, mask, mask_kind="causal")
    x = x + jnp.einsum("bshe,hed->bsd", o, sp["wo"])
    h = L.rms_norm(x, sp["ln2"], cfg.norm_eps)
    return x + L.mlp_block(cfg, mesh, axes, h, sp)


def forward(cfg: ArchConfig, mesh: Mesh, params, tokens):
    axes = MeshAxes.from_mesh(mesh)
    x = params["emb"][tokens].astype(cfg.dtype)
    b, s, _ = x.shape
    rspec = (axes.batch, None, None)
    x = constrain(x, mesh, *rspec)
    positions = jnp.arange(s)[None, :]

    def seg_scan(x, seg_params):
        def body(carry, lp):
            y = ssm_layer(cfg, mesh, axes, carry, lp)
            return constrain(y, mesh, *rspec), None
        if cfg.remat:
            body = jax.remat(body)
        if cfg.unroll:
            k = jax.tree.leaves(seg_params)[0].shape[0]
            for i in range(k):
                x, _ = body(x, jax.tree.map(lambda w: w[i], seg_params))
            return x
        x, _ = jax.lax.scan(body, x, seg_params)
        return x

    n = cfg.n_layers
    if cfg.family == "hybrid" and cfg.attn_period:
        per = cfg.attn_period

        def shared_fn(xx, sp):
            return _shared_attn_block(cfg, mesh, axes, xx, sp, positions)

        shared = jax.remat(shared_fn) if cfg.remat else shared_fn
        for s0 in range(0, n, per):
            e0 = min(s0 + per, n)
            x = shared(x, params["shared"])
            x = seg_scan(x, jax.tree.map(lambda a: a[s0:e0], params["layers"]))
    else:
        x = seg_scan(x, params["layers"])
    return L.rms_norm(x, params["final_ln"], cfg.norm_eps)


def loss_fn(cfg: ArchConfig, mesh: Mesh):
    from repro.models.transformer import lm_loss

    def f(params, batch):
        x = forward(cfg, mesh, params, batch["tokens"])
        return lm_loss(cfg, mesh, params, x, batch["labels"])

    return f


# ------------------------------------------------------------------ decode
def cache_shapes(cfg: ArchConfig, batch: int, seq: int):
    di, nst, h, hd = cfg.d_inner, cfg.d_state, cfg.n_ssm_heads, cfg.ssm_head_dim
    conv_dim = di + 2 * nst
    shapes = {
        "conv": (cfg.n_layers, batch, cfg.conv_width - 1, conv_dim),
        "ssm": (cfg.n_layers, batch, h, hd, nst),
    }
    if cfg.family == "hybrid" and cfg.attn_period:
        n_apps = math.ceil(cfg.n_layers / cfg.attn_period)
        kv, dh = cfg.n_kv_heads, cfg.head_dim
        shapes |= {
            "k": (n_apps, batch, seq, kv, dh),
            "v": (n_apps, batch, seq, kv, dh),
        }
    return shapes


def abstract_cache(cfg, batch, seq):
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s, jnp.float32 if len(s) == 5 and s[-1] == cfg.d_state else cfg.dtype),
        cache_shapes(cfg, batch, seq),
        is_leaf=lambda s: isinstance(s, tuple),
    )


def init_cache(cfg, batch, seq):
    return jax.tree.map(
        lambda s: jnp.zeros(s.shape, s.dtype),
        abstract_cache(cfg, batch, seq),
        is_leaf=lambda s: isinstance(s, jax.ShapeDtypeStruct),
    )


def cache_specs(cfg: ArchConfig, axes: MeshAxes, batch: int, seq: int) -> dict:
    h = cfg.n_ssm_heads
    bsz = int(np.prod([axes.size(a) for a in axes.batch]))
    batch_ax = axes.batch if batch % bsz == 0 else None
    specs = {
        "conv": P(None, batch_ax, None, None),
        "ssm": P(None, batch_ax, axes.tp(h), None, None),
    }
    if cfg.family == "hybrid" and cfg.attn_period:
        kv_tp = axes.tp(cfg.n_kv_heads)
        # long-context hybrid decode: KV cache sequence-sharded over "data"
        # when the batch cannot occupy it (DESIGN.md §4, long_500k)
        seq_data = None
        if batch_ax is None and axes.fsdp and seq % axes.sizes[axes.fsdp] == 0:
            seq_data = axes.fsdp
        specs |= {
            "k": P(None, batch_ax, seq_data, kv_tp, None),
            "v": P(None, batch_ax, seq_data, kv_tp, None),
        }
    return specs


def decode_step(cfg: ArchConfig, mesh: Mesh):
    axes = MeshAxes.from_mesh(mesh)
    from repro.models.transformer import logits_from_hidden, _scatter_cache

    def f(params, cache, batch):
        token, pos = batch["token"], batch["pos"]
        x = params["emb"][token][:, None].astype(cfg.dtype)

        def ssm_seg(x, seg_params, seg_cache):
            def body(carry, inp):
                lp, cv, sm = inp
                y, ns = ssm_decode_layer(cfg, carry, lp, {"conv": cv, "ssm": sm})
                return y, (ns["conv"], ns["ssm"])
            if cfg.unroll:
                k = jax.tree.leaves(seg_params)[0].shape[0]
                cvs, sms = [], []
                for i in range(k):
                    lp = jax.tree.map(lambda w: w[i], seg_params)
                    x, (cv, sm) = body(x, (lp, seg_cache["conv"][i], seg_cache["ssm"][i]))
                    cvs.append(cv), sms.append(sm)
                return x, {"conv": jnp.stack(cvs), "ssm": jnp.stack(sms)}
            x, (cvs, sms) = jax.lax.scan(body, x, (seg_params, seg_cache["conv"], seg_cache["ssm"]))
            return x, {"conv": cvs, "ssm": sms}

        n = cfg.n_layers
        if cfg.family == "hybrid" and cfg.attn_period:
            per = cfg.attn_period
            new_conv, new_ssm, new_k, new_v = [], [], [], []
            s_cache = cache["k"].shape[2]
            for app, s0 in enumerate(range(0, n, per)):
                e0 = min(s0 + per, n)
                sp = params["shared"]
                hnorm = L.rms_norm(x, sp["ln1"], cfg.norm_eps)
                q, k, v = L.qkv(cfg, hnorm, sp, pos[:, None])
                kc = _scatter_cache(cache["k"][app], k, pos)
                vc = _scatter_cache(cache["v"][app], v, pos)
                new_k.append(kc), new_v.append(vc)
                mask = jnp.arange(s_cache)[None, None, None, :] <= pos[:, None, None, None]
                o = L.attention(cfg, mesh, axes, q, kc, vc, mask)
                x = x + jnp.einsum("bshe,hed->bsd", o, sp["wo"])
                hnorm = L.rms_norm(x, sp["ln2"], cfg.norm_eps)
                x = x + L.mlp_block(cfg, mesh, axes, hnorm, sp)
                seg = jax.tree.map(lambda a: a[s0:e0], params["layers"])
                segc = {"conv": cache["conv"][s0:e0], "ssm": cache["ssm"][s0:e0]}
                x, nsc = ssm_seg(x, seg, segc)
                new_conv.append(nsc["conv"]), new_ssm.append(nsc["ssm"])
            new_cache = {
                "conv": jnp.concatenate(new_conv),
                "ssm": jnp.concatenate(new_ssm),
                "k": jnp.stack(new_k),
                "v": jnp.stack(new_v),
            }
        else:
            x, new_cache = ssm_seg(x, params["layers"], cache)
        x = L.rms_norm(x, params["final_ln"], cfg.norm_eps)
        logits = logits_from_hidden(cfg, mesh, params, x)[:, 0]
        return logits, new_cache

    return f


def train_input_specs(cfg: ArchConfig, mesh: Mesh, batch: int, seq: int):
    from repro.models.transformer import train_input_specs as tis

    return {k: v for k, v in tis(cfg.with_(family="dense"), mesh, batch, seq).items()}
