"""phi3.5-moe-42b-a6.6b [moe]: 32L d=4096 32H (kv=8) d_ff=6400, 16 experts
top-2 [hf:microsoft/Phi-3.5-MoE-instruct]."""

from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="phi3.5-moe-42b",
    family="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=6400,
    vocab=32064,
    n_experts=16,
    top_k=2,
    mlp="swiglu",
)

SMOKE = CONFIG.with_(
    name="phi35moe-smoke", n_layers=2, d_model=128, n_heads=8, n_kv_heads=2,
    d_ff=64, vocab=512, n_experts=4, top_k=2, remat=False,
)

SHAPES = {
    "train_4k": "run",
    "prefill_32k": "run",
    "decode_32k": "run",
    "long_500k": "skip:pure full attention (DESIGN.md §Arch-applicability)",
}
