"""repro.observe: tracer, sinks, rolling metrics, drift, and the no-op gate.

The observability layer's contract is two-sided: with a tracer installed,
spans/counters faithfully describe the build/solve/serve pipeline (span
nesting, exception-closing, schema-valid Chrome export, atomic JSONL
append); with the default null tracer, instrumented code is byte-for-byte
a no-op — same solutions, same iteration counts, same lowered HLO for the
hot loop.
"""

import dataclasses
import json
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.observe import (
    ChromeTraceSink,
    JsonlSink,
    MemorySink,
    NULL_TRACER,
    NullTracer,
    RollingWindow,
    Span,
    Tracer,
    coerce_tracer,
    get_tracer,
    open_sink,
    set_tracer,
    timed_median,
    timed_median_us,
)
from repro.solver import ECGSolver, SolverConfig
from repro.sparse import fd_laplace_2d


@pytest.fixture
def fake_clock():
    """Deterministic injectable clock: every read advances 1.0s."""

    class Clock:
        def __init__(self):
            self.t = 0.0

        def __call__(self):
            self.t += 1.0
            return self.t

    return Clock()


# ------------------------------------------------------------------ tracer
class TestTracer:
    def test_span_records_name_cat_attrs_duration(self, fake_clock):
        sink = MemorySink()
        tr = Tracer(sinks=[sink], clock=fake_clock)
        with tr.span("build/partition", cat="build", p=8) as sp:
            sp.args["rows"] = 100
        (span,) = sink.spans
        assert span.name == "build/partition" and span.cat == "build"
        assert span.args == dict(p=8, rows=100)
        assert span.t0 == 1.0 and span.dur == 1.0  # two clock reads

    def test_nesting_depth_and_close_order(self):
        sink = MemorySink()
        tr = Tracer(sinks=[sink])
        assert tr.open_spans == 0
        with tr.span("outer"):
            assert tr.open_spans == 1
            with tr.span("inner"):
                assert tr.open_spans == 2
        assert tr.open_spans == 0
        # sinks see spans in close order: child before parent
        assert [s.name for s in sink.spans] == ["inner", "outer"]
        inner, outer = sink.spans
        assert outer.t0 <= inner.t0
        assert inner.t0 + inner.dur <= outer.t0 + outer.dur + 1e-9

    def test_exception_closes_span_and_propagates(self):
        sink = MemorySink()
        tr = Tracer(sinks=[sink])
        with pytest.raises(ValueError, match="boom"):
            with tr.span("build"):
                raise ValueError("boom")
        (span,) = sink.spans
        assert span.dur is not None  # closed despite the raise
        assert span.args["error"] == "ValueError"
        assert tr.open_spans == 0

    def test_begin_end_explicit_pair(self, fake_clock):
        sink = MemorySink()
        tr = Tracer(sinks=[sink], clock=fake_clock)
        sp = tr.begin("solve/dispatch", cat="solve")
        assert tr.open_spans == 1 and sp.dur is None
        tr.end(sp, iters=42)
        assert tr.open_spans == 0
        assert sink.spans[0].dur == 1.0 and sink.spans[0].args["iters"] == 42

    def test_emit_explicit_timestamps(self):
        sink = MemorySink()
        tr = Tracer(sinks=[sink])
        tr.emit("serve/queue_wait", 10.0, 2.5, cat="serve", request_id=3)
        (span,) = sink.spans
        assert span.t0 == 10.0 and span.dur == 2.5
        assert span.args == dict(request_id=3)

    def test_metrics_fan_to_sinks(self, fake_clock):
        sink = MemorySink()
        tr = Tracer(sinks=[sink], clock=fake_clock)
        tr.counter("solver.solves", 3)
        tr.gauge("model_drift", 1.2, strategy="3step")
        tr.instant("solve/reseed", k=7)
        kinds = [m["kind"] for m in sink.metrics]
        assert kinds == ["counter", "gauge", "instant"]
        assert sink.counter_value("solver.solves") == 3
        assert sink.metrics[1]["attrs"] == dict(strategy="3step")

    def test_multiple_sinks_all_receive(self):
        s1, s2 = MemorySink(), MemorySink()
        tr = Tracer(sinks=[s1, s2])
        with tr.span("x"):
            pass
        tr.counter("c", 1)
        assert len(s1.spans) == len(s2.spans) == 1
        assert len(s1.metrics) == len(s2.metrics) == 1


class TestNullTracer:
    def test_everything_is_a_noop(self):
        tr = NullTracer()
        assert not tr.enabled
        with tr.span("anything", cat="x", big=1) as sp:
            sp.args["dropped"] = True  # silently discarded
            sp.args.update(also="dropped")
            assert sp.args.setdefault("k", "default") == "default"
        assert dict(sp.args) == {}
        tr.counter("c", 1)
        tr.gauge("g", 2.0)
        tr.instant("i")
        tr.emit("e", 0.0, 1.0)
        tr.close()

    def test_shared_context_no_allocation(self):
        tr = NullTracer()
        assert tr.span("a") is tr.span("b")  # one shared ctx object
        assert tr.begin("a") is tr.begin("b")

    def test_ambient_tracer_install_restore(self):
        assert get_tracer() is NULL_TRACER
        mine = Tracer(sinks=[MemorySink()])
        prev = set_tracer(mine)
        try:
            assert prev is NULL_TRACER
            assert get_tracer() is mine
            assert coerce_tracer(None) is mine
            other = Tracer()
            assert coerce_tracer(other) is other
        finally:
            set_tracer(prev)
        assert get_tracer() is NULL_TRACER


# ------------------------------------------------------------------- sinks
class TestChromeTraceSink:
    def _trace(self, tmp_path, fake_clock):
        path = tmp_path / "trace.json"
        sink = ChromeTraceSink(str(path))
        tr = Tracer(sinks=[sink], clock=fake_clock)
        with tr.span("build", cat="build", n=100):
            with tr.span("build/tune", cat="build"):
                pass
            tr.counter("solver.builds", 1)
        tr.gauge("model_drift", 1.1, strategy="3step")
        tr.close()
        with open(path) as fh:
            return json.load(fh)

    def test_schema_valid_and_monotonic(self, tmp_path, fake_clock):
        doc = self._trace(tmp_path, fake_clock)
        assert set(doc) == {"traceEvents", "displayTimeUnit"}
        events = doc["traceEvents"]
        assert len(events) == 4
        ts = [e["ts"] for e in events]
        assert ts == sorted(ts)  # sorted at export time
        assert ts[0] == 0.0  # relative to the first event, not perf_counter
        for e in events:
            assert {"name", "ph", "ts", "pid", "tid", "args"} <= set(e)
            assert e["ph"] in ("X", "C", "i")
            if e["ph"] == "X":
                assert e["dur"] >= 0
            if e["ph"] == "i":
                assert e["s"] == "p"

    def test_event_kinds(self, tmp_path, fake_clock):
        events = self._trace(tmp_path, fake_clock)["traceEvents"]
        by_name = {e["name"]: e for e in events}
        # spans -> complete events with microsecond durations
        assert by_name["build"]["ph"] == "X"
        assert by_name["build/tune"]["dur"] == pytest.approx(1e6)  # 1 clock s
        # counter -> ph C keyed by the counter name
        assert by_name["solver.builds"]["ph"] == "C"
        assert by_name["solver.builds"]["args"] == {"solver.builds": 1}
        # gauge -> instant event carrying value + attrs
        assert by_name["model_drift"]["ph"] == "i"
        assert by_name["model_drift"]["args"] == dict(value=1.1,
                                                      strategy="3step")

    def test_out_of_order_emit_still_sorted(self, tmp_path):
        path = tmp_path / "t.json"
        sink = ChromeTraceSink(str(path))
        tr = Tracer(sinks=[sink])
        with tr.span("drain"):
            pass
        tr.emit("queue_wait", tr.clock() - 5.0, 5.0)  # began before drain
        tr.close()
        with open(path) as fh:
            ts = [e["ts"] for e in json.load(fh)["traceEvents"]]
        assert ts == sorted(ts)


class TestJsonlSink:
    def test_append_one_record_per_line(self, tmp_path):
        path = tmp_path / "log.jsonl"
        tr = Tracer(sinks=[JsonlSink(str(path))])
        with tr.span("build", cat="build", n=9):
            pass
        tr.counter("c", 2, warm=True)
        tr.close()
        lines = path.read_text().splitlines()
        assert len(lines) == 2
        span, counter = (json.loads(ln) for ln in lines)
        assert span["type"] == "span" and span["name"] == "build"
        assert span["args"] == dict(n=9)
        assert counter == dict(type="counter", name="c", value=2,
                               ts=counter["ts"], args=dict(warm=True))

    def test_append_is_atomic_across_writers(self, tmp_path):
        """Two sinks on one file (the forked-benchmark case): interleaved
        closes must still yield whole records, never partial lines."""
        path = tmp_path / "shared.jsonl"
        a, b = JsonlSink(str(path)), JsonlSink(str(path))
        tra, trb = Tracer(sinks=[a]), Tracer(sinks=[b])
        for i in range(50):
            tra.counter("from_a", i, pad="x" * 256)
            trb.counter("from_b", i, pad="y" * 256)
        tra.close()
        trb.close()
        lines = path.read_text().splitlines()
        assert len(lines) == 100
        records = [json.loads(ln) for ln in lines]  # every line parses
        assert sum(r["name"] == "from_a" for r in records) == 50
        assert sum(r["name"] == "from_b" for r in records) == 50

    def test_append_preserves_existing_log(self, tmp_path):
        path = tmp_path / "log.jsonl"
        for run in range(2):
            tr = Tracer(sinks=[JsonlSink(str(path))])
            tr.counter("run", run)
            tr.close()
        records = [json.loads(ln) for ln in path.read_text().splitlines()]
        assert [r["value"] for r in records] == [0, 1]

    def test_close_idempotent(self, tmp_path):
        sink = JsonlSink(str(tmp_path / "x.jsonl"))
        sink.close()
        sink.close()  # second close must not raise on the dead fd

    def test_open_sink_dispatch(self, tmp_path):
        assert isinstance(open_sink(tmp_path / "a.jsonl"), JsonlSink)
        assert isinstance(open_sink(tmp_path / "a.json"), ChromeTraceSink)


# ---------------------------------------------------------- rolling window
class TestRollingWindow:
    def test_empty_snapshot(self):
        w = RollingWindow(window_s=10.0)
        snap = w.snapshot(now=100.0)
        assert snap["rate_rps"] == 0.0 and snap["n"] == 0
        assert snap["p50"] is None and snap["mean"] is None

    def test_percentiles_and_rate(self):
        w = RollingWindow(window_s=10.0)
        for i in range(10):
            w.add(ts=float(i), value=float(i))
        snap = w.snapshot(now=9.0)
        assert snap["n"] == 10 and snap["rate_rps"] == 1.0
        assert snap["p50"] == 4.5 and snap["mean"] == 4.5
        assert snap["p50"] <= snap["p95"] <= snap["p99"] <= 9.0

    def test_old_samples_age_out(self):
        w = RollingWindow(window_s=10.0)
        w.add(ts=0.0, value=111.0)
        for i in range(5):
            w.add(ts=50.0 + i, value=1.0)
        snap = w.snapshot(now=55.0)
        assert snap["n"] == 5  # the t=0 sample fell out of the window
        assert snap["p99"] == 1.0


# ------------------------------------------------------------- timed_median
class TestTimedMedian:
    def test_returns_result_and_positive_median(self):
        calls = []
        out, s = timed_median(lambda x: calls.append(x) or 42, 1,
                              repeats=3, warmup=2, sync=False)
        assert out == 42 and s > 0
        assert len(calls) == 5  # warmup + repeats

    def test_spans_on_enabled_tracer(self):
        sink = MemorySink()
        tr = Tracer(sinks=[sink])
        timed_median(lambda: None, repeats=3, warmup=0, label="unit",
                     tracer=tr, sync=False)
        spans = sink.by_name("bench/unit")
        assert len(spans) == 3
        assert [s.args["rep"] for s in spans] == [0, 1, 2]

    def test_disabled_tracer_still_measures(self):
        # a NullTracer caller must not break timing (the original bug:
        # null spans report dur=0.0, not a measurement)
        _, s = timed_median(lambda: sum(range(200)), repeats=2,
                            tracer=NULL_TRACER, sync=False)
        assert s > 0
        assert timed_median_us(lambda: None, repeats=2, sync=False) > 0

    def test_rejects_zero_repeats(self):
        with pytest.raises(ValueError, match="repeats"):
            timed_median(lambda: None, repeats=0)


# ------------------------------------------- solver integration + no-op gate
@pytest.fixture(scope="module")
def seq_problem():
    a = fd_laplace_2d(12)
    rng = np.random.default_rng(7)
    return a, rng.standard_normal(a.shape[0])


class TestSolverTracing:
    def test_build_and_solve_spans(self, seq_problem):
        a, b = seq_problem
        sink = MemorySink()
        solver = ECGSolver.build(a, config=SolverConfig(t=4, tol=1e-8),
                                 tracer=Tracer(sinks=[sink]))
        res = solver.solve(b)
        names = [s.name for s in sink.spans]
        assert "build" in names
        assert "solve/dispatch" in names and "solve/finalize" in names
        (seg,) = [s for s in sink.spans if s.name == "solve/segment"]
        assert seg.args["width"] == 4
        assert seg.args["iters"] == res.n_iters
        assert sink.counter_value("solver.builds") == 1
        assert sink.counter_value("solver.solves") == 1

    def test_tracing_off_is_bit_identical(self, seq_problem):
        a, b = seq_problem
        cfg = SolverConfig(t=4, tol=1e-8)
        plain = ECGSolver.build(a, config=cfg)
        traced = ECGSolver.build(a, config=cfg,
                                 tracer=Tracer(sinks=[MemorySink()]))
        r0, r1 = plain.solve(b), traced.solve(b)
        assert np.array_equal(np.asarray(r0.x), np.asarray(r1.x))
        assert r0.n_iters == r1.n_iters
        assert bool(r0.converged) == bool(r1.converged)

    def test_hot_loop_hlo_unchanged_by_tracing(self, seq_problem):
        """Spans sit at dispatch boundaries: the jitted while-loop lowers
        to the same module with tracing on or off."""
        a, b = seq_problem
        cfg = SolverConfig(t=4, tol=1e-8)
        plain = ECGSolver.build(a, config=cfg)
        traced = ECGSolver.build(a, config=cfg,
                                 tracer=Tracer(sinks=[MemorySink()]))
        b_dev = jnp.asarray(b)
        x0 = jnp.zeros_like(b_dev)
        txt0 = plain._jit(plain.t, "fresh").lower(b_dev, x0).as_text()
        txt1 = traced._jit(traced.t, "fresh").lower(b_dev, x0).as_text()
        assert txt0 == txt1

    def test_with_config_clone_shares_tracer(self, seq_problem):
        a, _ = seq_problem
        tr = Tracer(sinks=[MemorySink()])
        solver = ECGSolver.build(a, config=SolverConfig(t=4), tracer=tr)
        clone = solver.with_config(tol=1e-6)
        assert clone._tracer is tr


class TestIterTrace:
    def test_rows_match_history(self, seq_problem):
        a, b = seq_problem
        solver = ECGSolver.build(a, config=SolverConfig(t=4, tol=1e-8))
        res = solver.solve(b)
        rows = res.iter_trace()
        assert len(rows) == res.n_iters + 1
        assert [r["k"] for r in rows] == list(range(res.n_iters + 1))
        hist = np.asarray(res.res_hist)
        for r in rows:
            assert r["resnorm"] == float(hist[r["k"]])
            assert np.isfinite(r["resnorm"])
        # the padded NaN tail past convergence is excluded
        assert rows[-1]["resnorm"] <= 1e-8 * rows[0]["resnorm"] * 10

    def test_padding_and_event_decoding(self, seq_problem):
        from repro.core.cg import EV_RECOVERY, EV_RESEED

        a, b = seq_problem
        solver = ECGSolver.build(a, config=SolverConfig(t=4, tol=1e-8))
        res = solver.solve(b)
        crafted = dataclasses.replace(
            res,
            res_hist=jnp.asarray([4.0, 2.0, 1.0, np.nan, np.nan]),
            active_hist=np.asarray([4, 4, 2, -1, -1]),
            event_hist=np.asarray(
                [0, EV_RECOVERY, EV_RECOVERY | EV_RESEED, -1, -1]
            ),
        )
        rows = crafted.iter_trace()
        assert len(rows) == 3  # NaN padding cuts the trace
        assert rows[0]["events"] == ()
        assert rows[1]["events"] == ("recovery",)
        assert rows[2]["events"] == ("recovery", "reseed")
        assert rows[2]["active"] == 2

    def test_all_finite_history(self, seq_problem):
        """A history with no padding (max_iters hit) keeps every row."""
        a, b = seq_problem
        solver = ECGSolver.build(
            a, config=SolverConfig(t=4, tol=1e-30, max_iters=5)
        )
        res = solver.solve(b)
        rows = res.iter_trace()
        assert len(rows) == np.asarray(res.res_hist).size


# ------------------------------------------------------------------- drift
class TestDriftHelpers:
    def test_hlo_collective_bytes_parses_both_forms(self):
        from repro.observe.drift import hlo_collective_bytes

        txt = "\n".join([
            "  %x = f64[3,4]{1,0} collective-permute(%a), channel_id=1",
            "  %y = (f32[8]{0}, f32[8]{0}) collective-permute-start(%b)",
            "  %z = f32[8]{0} collective-permute-done(%y)",
            "  %w = f64[2,2]{1,0} add(%c, %d)",
        ])
        # f64[3,4] = 96B and f32[8] = 32B, each x p=4; -done not counted
        assert hlo_collective_bytes(txt, p=4) == (96 + 32) * 4
        assert hlo_collective_bytes("", p=4) == 0

    def test_calibrated_drift_normalizes_by_median(self):
        from repro.observe.drift import calibrated_drift

        rows = [dict(time_drift=2.0), dict(time_drift=4.0),
                dict(time_drift=8.0)]
        out = calibrated_drift(rows)
        assert [r["calibrated_time_drift"] for r in out] == [0.5, 1.0, 2.0]
        assert "calibrated_time_drift" not in rows[0]  # copies, not mutation
        assert calibrated_drift([dict(time_drift=None)])[0][
            "calibrated_time_drift"] is None

    def test_predicted_iteration_seconds_needs_mesh(self, seq_problem):
        from repro.observe.drift import bytes_drift, predicted_iteration_seconds

        a, _ = seq_problem
        solver = ECGSolver.build(a, config=SolverConfig(t=4))
        with pytest.raises(ValueError, match="distributed"):
            predicted_iteration_seconds(solver)
        with pytest.raises(ValueError, match="distributed"):
            bytes_drift(solver)
