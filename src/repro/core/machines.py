"""Machine parameter sets for the communication/computation models.

Parameters follow Table 1 of the paper.  Blue Waters and Lassen constants are
estimates consistent with the published max-rate literature ([16], [4]) and
the qualitative crossovers in the paper's Fig 4.6 (exact measured constants
were not published); the TPU-v5e mapping (chip=process, pod=node) uses public
v5e specs.  All rates in bytes/second, latencies in seconds.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class MachineParams:
    name: str
    alpha: float        # inter-node latency (s)
    alpha_l: float      # intra-node latency (s)
    R_N: float          # NIC injection rate (B/s) per node
    R_b: float          # per-process network transport rate (B/s)
    R_bl: float         # intra-node (shared-memory) transport rate (B/s)
    ppn: int            # default processes per node
    gamma: float        # seconds per flop (inverse per-core flop rate)
    eager_cutoff: int   # rendezvous-protocol switch (B) — §4.3 cutoff
    f: int = 8          # bytes per float
    R_mem: float = 0.0  # local memory bandwidth (B/s) per process; 0 = flop-bound model
    dispatch_overhead: float = 0.0  # seconds per executor dispatch (pack /
    #                                 unpack / ppermute op) — drives the
    #                                 executor-structural cost model, which
    #                                 charges plan dispatches instead of the
    #                                 MPI max-rate terms

    def with_ppn(self, ppn: int) -> "MachineParams":
        return dataclasses.replace(self, ppn=ppn)


#: Cray XE6, 3D-torus Gemini, 2 AMD Interlagos/node (paper §3).
BLUE_WATERS = MachineParams(
    name="BlueWaters",
    alpha=2.0e-6,
    alpha_l=6.0e-7,
    R_N=5.8e9,       # Gemini per-node injection
    R_b=2.7e9,
    R_bl=5.0e9,
    ppn=16,
    gamma=1.0 / 10.4e9,  # ~10.4 GF/s/core sustained (Interlagos)
    eager_cutoff=8192,
    R_mem=4.0e9,         # per-core share of DDR3 stream bandwidth
    dispatch_overhead=2.0e-6,
)

#: IBM Power9 + EDR InfiniBand (paper §4.3).
LASSEN = MachineParams(
    name="Lassen",
    alpha=1.1e-6,
    alpha_l=3.5e-7,
    R_N=12.5e9,      # 100 Gb/s EDR
    R_b=3.1e9,       # ≈ R_N / 4: >4–5 active senders saturate the NIC (Fig 4.6)
    R_bl=14.0e9,
    ppn=40,
    gamma=1.0 / 15.0e9,
    eager_cutoff=16384,
    R_mem=8.0e9,         # per-core share of Power9 stream bandwidth
    dispatch_overhead=1.5e-6,
)

#: TPU v5e mapping of the paper's hierarchy: chip ↔ process, pod (ICI domain)
#: ↔ node, DCI ↔ inter-node network.  Used for the TPU column of the study.
TPU_V5E_POD = MachineParams(
    name="TPUv5e",
    alpha=1.0e-5,    # DCI (inter-pod) latency
    alpha_l=1.0e-6,  # ICI hop latency
    R_N=2.5e10,      # per-chip DCI injection (≈200 Gb/s)
    R_b=1.25e10,
    R_bl=4.5e10,     # ICI per-link ~50 GB/s, one link busy
    ppn=256,         # chips per v5e pod
    gamma=1.0 / 197e12,  # bf16 peak per chip
    eager_cutoff=65536,
    f=4,             # f32 solver data on TPU
    R_mem=819e9,     # HBM bandwidth per chip
    dispatch_overhead=2.0e-6,  # XLA op issue cost inside the jitted loop
)

#: Forced-host-device executor (tests, CI, laptops): ppermute is a memcpy,
#: so the max-rate network terms are meaningless — the structural model
#: (dispatches x overhead + bytes / memcpy rate) is the one that ranks
#: strategies correctly here.  Constants estimated from XLA-CPU op overheads.
HOST = MachineParams(
    name="Host",
    alpha=5.0e-7,
    alpha_l=2.0e-7,
    R_N=8.0e9,
    R_b=4.0e9,       # memcpy-through-buffer rate per "process"
    R_bl=8.0e9,
    ppn=4,
    gamma=1.0 / 5.0e9,
    eager_cutoff=8192,
    R_mem=8.0e9,
    dispatch_overhead=1.5e-5,  # XLA-CPU per-op dispatch (measured O(10us))
)

MACHINES = {m.name: m for m in (BLUE_WATERS, LASSEN, TPU_V5E_POD, HOST)}

# Roofline hardware constants (per chip) — TPU v5e targets for §Roofline.
V5E_PEAK_FLOPS = 197e12       # bf16 FLOP/s
V5E_HBM_BW = 819e9            # B/s
V5E_ICI_BW = 5.0e10           # B/s per link
