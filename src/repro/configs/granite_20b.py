"""granite-20b [dense]: 52L d=6144 48H (MQA kv=1) d_ff=24576 vocab=49152
[arXiv:2405.04324].  GPT-BigCode lineage: 2-matrix GELU MLP (the 20B param
count is only consistent with a non-gated FFN)."""

from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="granite-20b",
    family="dense",
    n_layers=52,
    d_model=6144,
    n_heads=48,
    n_kv_heads=1,
    d_ff=24576,
    vocab=49152,
    mlp="gelu",
)

SMOKE = CONFIG.with_(
    name="granite20-smoke", n_layers=2, d_model=128, n_heads=8, n_kv_heads=1,
    d_ff=512, vocab=512, remat=False,
)

SHAPES = {
    "train_4k": "run",
    "prefill_32k": "run",
    "decode_32k": "run",
    "long_500k": "skip:pure full attention (DESIGN.md §Arch-applicability)",
}
