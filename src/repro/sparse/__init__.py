"""Sparse-matrix substrate: containers, partitioning, generators, distributed SpMBV."""

from repro.sparse.csr import CSRMatrix, BSRMatrix, csr_to_bsr, csr_spmv, csr_spmbv
from repro.sparse.partition import RowPartition, PartitionedMatrix, partition_csr
from repro.sparse.matrices import (
    aniso_laplace_2d,
    dg_laplace_2d,
    fd_laplace_2d,
    fd_laplace_3d,
    random_spd,
    scaled_laplace_2d,
    suite_surrogate,
    SUITE_MATRICES,
    EXAMPLE_2_1,
)

__all__ = [
    "CSRMatrix",
    "BSRMatrix",
    "csr_to_bsr",
    "csr_spmv",
    "csr_spmbv",
    "RowPartition",
    "PartitionedMatrix",
    "partition_csr",
    "aniso_laplace_2d",
    "dg_laplace_2d",
    "fd_laplace_2d",
    "fd_laplace_3d",
    "random_spd",
    "scaled_laplace_2d",
    "suite_surrogate",
    "SUITE_MATRICES",
    "EXAMPLE_2_1",
]
