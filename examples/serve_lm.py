"""Batched autoregressive serving with a KV cache.

Prefills a batch of prompts (teacher-forced), then decodes greedily with the
one-token serve step — the same step the decode_32k/long_500k dry-run cells
lower at production scale.

    PYTHONPATH=src python examples/serve_lm.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke
from repro.launch.mesh import make_smoke_mesh
from repro.models.registry import model_api


def main():
    cfg = get_smoke("stablelm_1_6b").with_(dtype=jnp.float32)
    mesh = make_smoke_mesh()
    api = model_api(cfg)
    params = api.init_params(cfg, jax.random.key(0))
    step = jax.jit(api.decode_step(cfg, mesh))

    batch, prompt_len, gen_len, cache_len = 4, 8, 24, 64
    rng = np.random.default_rng(0)
    prompts = rng.integers(1, cfg.vocab, (batch, prompt_len))
    cache = api.init_cache(cfg, batch, cache_len)

    # prefill token-by-token (production uses the fused prefill graph;
    # the cache layout is identical)
    tok = jnp.asarray(prompts[:, 0], jnp.int32)
    for i in range(prompt_len):
        pos = jnp.full((batch,), i, jnp.int32)
        logits, cache = step(params, cache, {"token": tok, "pos": pos})
        tok = (
            jnp.asarray(prompts[:, i + 1], jnp.int32)
            if i + 1 < prompt_len
            else jnp.argmax(logits[:, : cfg.vocab], axis=-1).astype(jnp.int32)
        )

    outs = []
    for i in range(prompt_len, prompt_len + gen_len):
        pos = jnp.full((batch,), i, jnp.int32)
        logits, cache = step(params, cache, {"token": tok, "pos": pos})
        tok = jnp.argmax(logits[:, : cfg.vocab], axis=-1).astype(jnp.int32)
        outs.append(np.asarray(tok))

    gen = np.stack(outs, axis=1)
    print(f"prompts ({batch}x{prompt_len}):\n{prompts}")
    print(f"greedy continuations ({batch}x{gen_len}):\n{gen}")
    assert gen.shape == (batch, gen_len) and (gen >= 0).all() and (gen < cfg.vocab).all()
    print("serving OK")


if __name__ == "__main__":
    main()
