"""zamba2-1.2b [hybrid]: 38L d=2048, Mamba2 backbone + shared attention block
(32H kv=32, d_ff=8192) every 6 layers, ssm_state=64 [arXiv:2411.15242].
Per-application LoRA deltas of the shared block are omitted (DESIGN.md)."""

from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-1.2b",
    family="hybrid",
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab=32000,
    d_state=64,
    expand=2,
    ssm_head_dim=64,
    attn_period=6,
    tie_embeddings=True,
)

SMOKE = CONFIG.with_(
    name="zamba2-smoke", n_layers=5, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab=512, d_state=16, ssm_head_dim=16, attn_period=2, remat=False,
)

SHAPES = {
    "train_4k": "run",
    "prefill_32k": "run",
    "decode_32k": "run",
    "long_500k": "run",  # hybrid: SSM backbone + seq-sharded shared-attn KV
}
