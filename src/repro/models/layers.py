"""Core transformer building blocks (pure functions, sharding-annotated)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.models.common import ArchConfig, MeshAxes, constrain


def rms_norm(x, scale, eps):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps).astype(x.dtype)) * scale


def rope(q, positions, theta, dtype=None):
    """Rotary embedding over the last dim of (..., S, H, dh)."""
    dh = q.shape[-1]
    half = dh // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, half)
    cos = jnp.cos(angles)[..., None, :]  # (..., S, 1, half)
    sin = jnp.sin(angles)[..., None, :]
    q1, q2 = q[..., :half].astype(jnp.float32), q[..., half:].astype(jnp.float32)
    out = jnp.concatenate([q1 * cos - q2 * sin, q1 * sin + q2 * cos], axis=-1)
    return out.astype(dtype or q.dtype)


def attention(
    cfg: ArchConfig,
    mesh: Mesh,
    axes: MeshAxes,
    q,                      # (B, Sq, H, dh)
    k,                      # (B, Sk, KV, dh)
    v,                      # (B, Sk, KV, dh)
    mask,                   # broadcastable to (B, H, Sq, Sk) bool, or None
    mask_kind: str | None = None,   # "causal" | "prefix:<n>" | None — enables
                                    # the chunked path without an S×S mask
):
    """GQA attention with soft TP over heads (uneven OK via GSPMD padding),
    or query-position sharding over "model" (attn_seq_shard — §Perf)."""
    b_axes = axes.batch
    if cfg.attn_seq_shard and q.shape[1] % max(axes.size(axes.model), 1) == 0:
        # shard queries (not heads) over "model": no head-padding waste and
        # no seq<->head reshards against the seq-parallel residual stream
        h_tp = None
        q = constrain(q, mesh, b_axes, axes.model, None, None)
        k = constrain(k, mesh, b_axes, None, None, None)
        v = constrain(v, mesh, b_axes, None, None, None)
    else:
        h_tp = axes.model  # soft constraint — GSPMD pads when H % tp != 0
        q = constrain(q, mesh, b_axes, None, h_tp, None)
    rep = cfg.n_heads // cfg.n_kv_heads
    if rep > 1:
        if cfg.gqa_shard_fix:
            # gather the sequence dim and pin KV to the head-TP layout BEFORE
            # the repeat: without this GSPMD reshards (seq-sharded -> uneven
            # head-sharded) through an involuntary full rematerialization
            k = constrain(k, mesh, b_axes, None, None, None)
            v = constrain(v, mesh, b_axes, None, None, None)
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
        if cfg.gqa_shard_fix:
            k = constrain(k, mesh, b_axes, None, h_tp, None)
            v = constrain(v, mesh, b_axes, None, h_tp, None)
    if cfg.attn_chunk and q.shape[1] > 1 and k.shape[1] > cfg.attn_chunk:
        return _chunked_attention(cfg, mesh, axes, q, k, v, mask_kind or "full", h_tp)
    scale = cfg.head_dim ** -0.5
    logits = jnp.einsum("bqhe,bkhe->bhqk", q, k) * scale
    logits = constrain(logits, mesh, b_axes, h_tp, None, None)
    if cfg.attn_logits_f32:
        logits = logits.astype(jnp.float32)
    if mask is not None:
        logits = jnp.where(mask, logits, jnp.finfo(logits.dtype).min)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhqk,bkhe->bqhe", probs, v)
    return constrain(out, mesh, b_axes, None, h_tp, None)


def _chunked_attention(cfg: ArchConfig, mesh: Mesh, axes: MeshAxes, q, k, v, mask_kind: str,
                       h_tp=None):
    """Online-softmax attention over KV chunks (flash-style at HLO level).

    The (Sq, Sk) score matrix never materializes in HBM as a whole: each
    scan step touches a (Sq, C) tile once, cutting the ~6 full-matrix HBM
    passes of the naive path (einsum, mask, fp32 convert, softmax, cast,
    PV read) to ~2 tile passes.  The per-chunk mask is computed from
    positions, so no S×S bool mask exists either.
    """
    b, sq, h, dh = q.shape
    sk = k.shape[1]
    c = cfg.attn_chunk
    nc = sk // c
    assert sk % c == 0, (sk, c)
    b_axes = axes.batch
    q_seq = axes.model if (cfg.attn_seq_shard and h_tp is None) else None
    scale = dh ** -0.5
    prefix_len = int(mask_kind.split(":")[1]) if mask_kind.startswith("prefix") else 0
    q_pos = jnp.arange(sq)

    kc = k.reshape(b, nc, c, h, dh).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, nc, c, h, dh).transpose(1, 0, 2, 3, 4)

    def body(carry, inputs):
        m, l, acc = carry
        ci, k_i, v_i = inputs
        s = jnp.einsum("bqhe,bkhe->bhqk", q, k_i).astype(jnp.float32) * scale
        s = jax.lax.with_sharding_constraint(
            s, jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec(b_axes, h_tp, q_seq, None))
        )
        k_pos = ci * c + jnp.arange(c)
        if mask_kind == "causal":
            msk = k_pos[None, :] <= q_pos[:, None]
        elif prefix_len:
            msk = (k_pos[None, :] <= q_pos[:, None]) | (k_pos[None, :] < prefix_len)
        else:
            msk = None
        if msk is not None:
            s = jnp.where(msk[None, None], s, -jnp.inf)
        m_new = jnp.maximum(m, s.max(axis=-1))
        # all--inf rows (fully masked chunk) keep m = -inf; guard the exps
        safe_m = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.exp(s - safe_m[..., None])
        p = jnp.where(jnp.isfinite(s), p, 0.0)
        corr = jnp.where(jnp.isfinite(m), jnp.exp(m - safe_m), 0.0)
        l = l * corr + p.sum(axis=-1)
        pv = jnp.einsum("bhqk,bkhe->bqhe", p.astype(q.dtype), v_i).astype(jnp.float32)
        acc = acc * corr.transpose(0, 2, 1)[..., None] + pv
        return (m_new, l, acc), None

    m0 = jnp.full((b, h, sq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((b, h, sq), jnp.float32)
    a0 = jnp.zeros((b, sq, h, dh), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), (jnp.arange(nc), kc, vc))
    out = acc / jnp.maximum(l, 1e-30).transpose(0, 2, 1)[..., None]
    return constrain(out.astype(q.dtype), mesh, b_axes, q_seq, h_tp, None)


def causal_mask(s: int):
    return jnp.tril(jnp.ones((s, s), dtype=bool))[None, None]


def prefix_lm_mask(s: int, prefix_len: int):
    """Bidirectional over the first ``prefix_len`` positions, causal after
    (PaliGemma-style image-prefix attention)."""
    causal = jnp.tril(jnp.ones((s, s), dtype=bool))
    prefix = (jnp.arange(s)[None, :] < prefix_len) & (jnp.arange(s)[:, None] >= 0)
    return (causal | prefix)[None, None]


def mlp_block(cfg: ArchConfig, mesh: Mesh, axes: MeshAxes, x, p):
    b_axes = axes.batch
    f_tp = axes.tp(cfg.d_ff)
    if cfg.mlp == "swiglu":
        g = constrain(jnp.einsum("bsd,df->bsf", x, p["wg"]), mesh, b_axes, None, f_tp)
        u = constrain(jnp.einsum("bsd,df->bsf", x, p["wu"]), mesh, b_axes, None, f_tp)
        h = jax.nn.silu(g) * u
    else:  # gelu
        h = constrain(jnp.einsum("bsd,df->bsf", x, p["wu"]), mesh, b_axes, None, f_tp)
        h = jax.nn.gelu(h)
    return row_parallel_out(cfg, mesh, axes, h, p["wd"], "bsf,fd->bsd", f_tp)


def row_parallel_out(cfg: ArchConfig, mesh: Mesh, axes: MeshAxes, h, w, eq, contr_tp):
    """Row-parallel output projection.  With dense_scatter_combine the partial
    products reduce-scatter straight into the seq-sharded residual layout
    (half the bytes of all-reduce + slice) — §Perf lever."""
    ok = (
        cfg.dense_scatter_combine
        and cfg.seq_parallel
        and contr_tp is not None
        and axes.model
        and h.shape[1] % axes.size(axes.model) == 0
        and h.ndim == 3
    )
    if not ok:
        return jnp.einsum(eq, h, w)
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    def body(hh, ww):
        part = jnp.einsum(eq, hh, ww)
        return jax.lax.psum_scatter(part, axes.model, scatter_dimension=1, tiled=True)

    f = shard_map(
        body,
        mesh=mesh,
        in_specs=(P(axes.batch, None, axes.model), P(axes.model, None)),
        out_specs=P(axes.batch, axes.model, None),
        check_rep=False,
    )
    return f(h, w)


def qkv(cfg: ArchConfig, x, p, positions):
    q = jnp.einsum("bsd,dhe->bshe", x, p["wq"])
    k = jnp.einsum("bsd,dhe->bshe", x, p["wk"])
    v = jnp.einsum("bsd,dhe->bshe", x, p["wv"])
    if positions is not None:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    return q, k, v
