"""Checkpointing: atomic, step-tagged, mesh-agnostic, preemption-safe.

Design (DESIGN.md §6):
  * arrays are saved *logically* (full values, npz shards per pytree leaf
    group) so a checkpoint written on one mesh restores onto any other —
    elastic rescaling is a restore-time resharding, not a format concern;
  * writes go to ``<dir>/tmp.<step>`` then ``rename`` to ``step_<step>``
    (atomic on POSIX), and ``latest`` is a symlink flipped last — a crash
    mid-write can never corrupt the restore path;
  * ``install_preemption_handler`` checkpoints on SIGTERM (the cloud
    preemption signal) before re-raising.

On a real multi-host cluster the np.asarray gather below becomes a
process-local shard write (jax.experimental.multihost_utils); the format and
atomicity protocol are unchanged.
"""

from __future__ import annotations

import json
import os
import signal
import shutil
from pathlib import Path

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def save_checkpoint(ckpt_dir: str | os.PathLike, step: int, tree, extra: dict | None = None):
    ckpt_dir = Path(ckpt_dir)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    tmp = ckpt_dir / f"tmp.{step}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir()
    leaves, treedef = _flatten(tree)
    np.savez(tmp / "leaves.npz", **{f"leaf_{i}": np.asarray(x) for i, x in enumerate(leaves)})
    meta = {"step": step, "n_leaves": len(leaves), "extra": extra or {}}
    (tmp / "meta.json").write_text(json.dumps(meta))
    final = ckpt_dir / f"step_{step:08d}"
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)  # atomic
    latest = ckpt_dir / "latest"
    tmp_link = ckpt_dir / ".latest.tmp"
    if tmp_link.is_symlink() or tmp_link.exists():
        tmp_link.unlink()
    tmp_link.symlink_to(final.name)
    tmp_link.rename(latest)  # atomic flip
    return final


def latest_step(ckpt_dir: str | os.PathLike) -> int | None:
    latest = Path(ckpt_dir) / "latest"
    if not latest.exists():
        return None
    return json.loads((latest / "meta.json").read_text())["step"]


def restore_checkpoint(ckpt_dir: str | os.PathLike, like_tree, shardings=None, step: int | None = None):
    """Restore onto the current mesh: each leaf is device_put with the target
    sharding (elastic: the saved mesh shape is irrelevant)."""
    ckpt_dir = Path(ckpt_dir)
    src = ckpt_dir / ("latest" if step is None else f"step_{step:08d}")
    meta = json.loads((src / "meta.json").read_text())
    data = np.load(src / "leaves.npz")
    leaves_like, treedef = _flatten(like_tree)
    assert meta["n_leaves"] == len(leaves_like), "checkpoint/model structure mismatch"
    new_leaves = []
    shard_leaves = _flatten(shardings)[0] if shardings is not None else [None] * len(leaves_like)
    for i, (like, sh) in enumerate(zip(leaves_like, shard_leaves)):
        arr = data[f"leaf_{i}"]
        arr = arr.astype(like.dtype) if hasattr(like, "dtype") else arr
        new_leaves.append(jax.device_put(arr, sh) if sh is not None else jax.numpy.asarray(arr))
    return jax.tree.unflatten(treedef, new_leaves), meta


def install_preemption_handler(save_fn):
    """Checkpoint on SIGTERM (preemption) before exiting."""
    def handler(signum, frame):
        save_fn()
        raise SystemExit(143)

    signal.signal(signal.SIGTERM, handler)
