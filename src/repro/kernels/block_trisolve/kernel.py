"""Pallas TPU kernel: batched two-triangle solve for block-Jacobi applies.

One grid step solves one diagonal block: ``L Lᵀ y = x`` by forward then
backward substitution, with the factor tile and both substitution states
VMEM-resident.  The block-Jacobi apply is the solver-loop hot path of a
preconditioned iteration (one batched solve per iteration per rank); a
LAPACK-style column algorithm would serialize scalar work on the VPU, so
the substitutions are expressed as *masked row extractions + (1, bs)×(bs, t)
contractions* — every fori_loop step is dense vector/matrix work the TPU
can vectorize, and no dynamically-indexed loads hit the tile.

Substitution (per block, row i of the forward pass):

    y[i] = (x[i] − L[i, :] · y) / L[i, i]          (y rows ≥ i still zero)

and the backward pass mirrors it against L's columns (Lᵀ rows).  The
row/column extraction uses an iota mask, so the loop body is shape-static.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(l_ref, x_ref, out_ref):
    l = l_ref[0]  # (bs, bs) lower factor
    x = x_ref[0]  # (bs, t)
    bs = l.shape[0]
    iota = jax.lax.broadcasted_iota(jnp.int32, (bs, 1), 0)

    def fwd(i, y):
        row_mask = iota == i  # (bs, 1)
        row = jnp.sum(jnp.where(row_mask, l, 0.0), axis=0, keepdims=True)  # L[i, :]
        xi = jnp.sum(jnp.where(row_mask, x, 0.0), axis=0, keepdims=True)   # x[i, :]
        lii = jnp.sum(jnp.where(row_mask.T, row, 0.0))                     # L[i, i]
        yi = (xi - jnp.dot(row, y, preferred_element_type=y.dtype)) / lii
        return jnp.where(row_mask, yi, y)

    y = jax.lax.fori_loop(0, bs, fwd, jnp.zeros_like(x))

    def bwd(j, z):
        i = bs - 1 - j
        row_mask = iota == i
        col = jnp.sum(jnp.where(row_mask.T, l, 0.0), axis=1, keepdims=True)  # L[:, i]
        yi = jnp.sum(jnp.where(row_mask, y, 0.0), axis=0, keepdims=True)
        lii = jnp.sum(jnp.where(row_mask, col, 0.0))
        zi = (yi - jnp.dot(col.T, z, preferred_element_type=z.dtype)) / lii
        return jnp.where(row_mask, zi, z)

    out_ref[0] = jax.lax.fori_loop(0, bs, bwd, jnp.zeros_like(x))


@functools.partial(jax.jit, static_argnames=("interpret",))
def block_trisolve_pallas(l, x, *, interpret: bool = False):
    """Batched ``L Lᵀ y = x`` solve; see :mod:`.ref` for the oracle.

    l: (nb, bs, bs) lower Cholesky factors, x: (nb, bs, t) → (nb, bs, t).
    """
    nb, bs, _ = l.shape
    t = x.shape[2]
    l = l.astype(x.dtype)
    return pl.pallas_call(
        _kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((1, bs, bs), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, bs, t), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bs, t), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, bs, t), x.dtype),
        interpret=interpret,
    )(l, x)
