"""Windowed time-series over serve tickets: rolling req/s + percentiles.

``latency_percentiles`` (:mod:`repro.serve.packing`) collapses a whole
replay into one aggregate; a *server* wants the last-N-seconds view —
request rate and tail latency as they evolve.  :class:`RollingWindow` is
that view: samples carry the timestamp of the clock that stamped them
(the serve queue's injectable clock, so tests drive it deterministically)
and every read is evaluated "as of now", dropping samples older than the
window.
"""

from __future__ import annotations

from collections import deque

import numpy as np


class RollingWindow:
    """Fixed-horizon sample window (timestamp, value) with rate/percentile
    reads.

    window_s: horizon in clock seconds; samples older than ``now −
              window_s`` fall out on the next read or add.
    """

    def __init__(self, window_s: float = 60.0):
        if not window_s > 0:
            raise ValueError(f"window_s must be > 0, got {window_s!r}")
        self.window_s = float(window_s)
        self._samples: deque[tuple[float, float]] = deque()

    def add(self, ts: float, value: float):
        self._samples.append((float(ts), float(value)))
        self._trim(ts)

    def _trim(self, now: float):
        cutoff = now - self.window_s
        q = self._samples
        while q and q[0][0] < cutoff:
            q.popleft()

    def __len__(self) -> int:
        return len(self._samples)

    def rate(self, now: float) -> float:
        """Samples per second over the window ending at ``now``."""
        self._trim(now)
        return len(self._samples) / self.window_s

    def percentiles(self, now: float) -> dict:
        """``dict(n, mean, p50, p95, p99)`` of the windowed values — the
        same shape as :func:`repro.serve.latency_percentiles`, with None
        values when the window is empty (explicit, never NaN-from-empty)."""
        self._trim(now)
        vals = [v for _, v in self._samples]
        if not vals:
            return dict(n=0, mean=None, p50=None, p95=None, p99=None)
        arr = np.asarray(vals, np.float64)
        return dict(
            n=int(arr.size),
            mean=float(arr.mean()),
            p50=float(np.percentile(arr, 50)),
            p95=float(np.percentile(arr, 95)),
            p99=float(np.percentile(arr, 99)),
        )

    def snapshot(self, now: float) -> dict:
        """Rate + percentiles in one JSON-safe dict (the serve ``stats()``
        time-series entry)."""
        return dict(window_s=self.window_s, rate_rps=self.rate(now),
                    **self.percentiles(now))
