"""repro.adaptive: rank-revealing factorization, breakdown guards, dynamic
width reduction, and automatic t selection."""

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.adaptive import (
    ReductionPolicy,
    TSelection,
    default_rank_rtol,
    pivoted_cholesky,
    rank_revealing_apply,
    resolve_policy,
    select_t,
)
from repro.core import cg_solve, ecg_solve, split_rank
from repro.core.ecg import _chol_inv_apply
from repro.sparse import fd_laplace_2d, csr_spmbv, csr_spmv
from repro.sparse.csr import CSRMatrix


@pytest.fixture(scope="module")
def system():
    a = fd_laplace_2d(16)  # 256 rows
    b = np.random.default_rng(0).standard_normal(a.shape[0])
    return a, b


def deficient_rhs(n: int, t: int, m: int, seed: int = 0) -> np.ndarray:
    """RHS supported on only the first m of t contiguous subdomains, so
    split_residual produces t − m exactly-zero (dependent) columns."""
    b = np.zeros(n)
    lo = 0
    hi = (m * n) // t  # first m contiguous subdomains of subdomain_map_contiguous
    b[lo:hi] = np.random.default_rng(seed).standard_normal(hi - lo)
    return b


def as_dtype(a: CSRMatrix, b: np.ndarray, dtype):
    return (
        dataclasses.replace(a, data=a.data.astype(dtype)),
        jnp.asarray(b, dtype),
    )


class TestPivotedCholesky:
    def test_full_rank_reconstructs(self):
        rng = np.random.default_rng(1)
        f = rng.standard_normal((8, 8))
        g = jnp.asarray(f @ f.T + 8 * np.eye(8))
        l, perm, rank = pivoted_cholesky(g)
        assert int(rank) == 8
        gp = np.asarray(g)[np.asarray(perm)][:, np.asarray(perm)]
        np.testing.assert_allclose(np.asarray(l @ l.T), gp, atol=1e-10)

    @pytest.mark.parametrize("r", [1, 3, 6])
    def test_detects_numerical_rank(self, r):
        rng = np.random.default_rng(2)
        f = rng.standard_normal((8, r))
        g = jnp.asarray(f @ f.T)
        l, perm, rank = pivoted_cholesky(g)
        assert int(rank) == r
        # dependent directions are exactly the trailing zero columns
        assert np.allclose(np.asarray(l)[:, r:], 0.0)
        gp = np.asarray(g)[np.asarray(perm)][:, np.asarray(perm)]
        np.testing.assert_allclose(np.asarray(l @ l.T), gp, atol=1e-9)

    def test_f32_threshold_scales_with_dtype(self):
        assert default_rank_rtol(jnp.float32) > 100 * default_rank_rtol(jnp.float64)
        rng = np.random.default_rng(3)
        f = rng.standard_normal((6, 4)).astype(np.float32)
        g = jnp.asarray(f @ f.T)
        _, _, rank = pivoted_cholesky(g)
        assert int(rank) == 4

    def test_apply_a_orthonormalizes_active_block(self, system):
        """PᵀAP = I on the active columns, 0 on the masked ones — the
        breakdown-safe analogue of TestAOrthonormalization."""
        a, _ = system
        rng = np.random.default_rng(4)
        z_ind = rng.standard_normal((a.shape[0], 3))
        z = jnp.asarray(np.hstack([z_ind, z_ind[:, :2] @ [[1.0], [2.0]]]))  # col 3 dependent
        az = csr_spmbv(a, z)
        g = z.T @ az
        (p, ap), rank, active = rank_revealing_apply(g, z, az)
        assert int(rank) == 3
        assert np.asarray(active).sum() == 3
        ptap = np.asarray(p.T @ csr_spmbv(a, p))
        np.testing.assert_allclose(ptap[:3, :3], np.eye(3), atol=1e-8)
        assert np.allclose(ptap[3:], 0.0) and np.allclose(np.asarray(p)[:, 3:], 0.0)
        np.testing.assert_allclose(np.asarray(ap), np.asarray(csr_spmbv(a, p)), atol=1e-8)

    def test_matches_plain_cholesky_span_when_full_rank(self, system):
        a, _ = system
        rng = np.random.default_rng(5)
        z = jnp.asarray(rng.standard_normal((a.shape[0], 5)))
        az = csr_spmbv(a, z)
        g = z.T @ az
        p_ref, _ = _chol_inv_apply(g, z, az)
        (p, _), rank, _ = rank_revealing_apply(g, z, az)
        assert int(rank) == 5
        # same A-orthonormal span (columns may be permuted/rotated)
        ptap = np.asarray(p.T @ csr_spmbv(a, p))
        np.testing.assert_allclose(ptap, np.eye(5), atol=1e-8)
        # both bases span the same subspace
        q_ref, _ = np.linalg.qr(np.asarray(p_ref))
        resid = np.asarray(p) - q_ref @ (q_ref.T @ np.asarray(p))
        assert np.abs(resid).max() < 1e-8


class TestBreakdownGuard:
    @pytest.mark.parametrize("t", [4, 8])
    def test_fixed_ecg_reports_breakdown(self, system, t):
        a, _ = system
        b = deficient_rhs(a.shape[0], t, m=t // 2)
        res = ecg_solve(lambda V: csr_spmbv(a, V), jnp.asarray(b), t=t,
                        tol=1e-9, max_iters=500)
        assert res.breakdown and not res.converged
        # state froze at the last finite iterate — no NaN garbage escapes
        assert bool(jnp.isfinite(res.x).all())
        assert np.isfinite(np.asarray(res.res_hist)[res.n_iters])

    def test_cg_zero_curvature_breakdown(self):
        # singular diagonal matrix, b in the nullspace: p·Ap = 0 on step 1
        n = 4
        diag = jnp.asarray([1.0, 1.0, 1.0, 0.0])
        a = CSRMatrix(
            indptr=jnp.arange(n + 1, dtype=jnp.int32),
            indices=jnp.arange(n, dtype=jnp.int32),
            data=diag,
            shape=(n, n),
        )
        b = jnp.asarray([0.0, 0.0, 0.0, 1.0])
        res = cg_solve(lambda v: csr_spmv(a, v), b, tol=1e-10, max_iters=50)
        assert res.breakdown and not res.converged
        assert bool(jnp.isfinite(res.x).all())

    def test_healthy_solves_keep_flag_clear(self, system):
        a, b = system
        res = ecg_solve(lambda V: csr_spmbv(a, V), jnp.asarray(b), t=4,
                        tol=1e-9, max_iters=2000)
        assert res.converged and not res.breakdown
        res_cg = cg_solve(lambda v: csr_spmv(a, v), jnp.asarray(b), tol=1e-9,
                          max_iters=2000)
        assert res_cg.converged and not res_cg.breakdown


class TestAdaptiveReduction:
    @pytest.mark.parametrize("t", [2, 4, 8])
    @pytest.mark.parametrize("dtype", [jnp.float64, jnp.float32])
    def test_converges_where_fixed_breaks_down(self, system, t, dtype):
        a, _ = system
        m = max(t // 2, 1)
        b = deficient_rhs(a.shape[0], t, m=m)
        a_d, b_d = as_dtype(a, b, dtype)
        tol = 1e-9 if dtype == jnp.float64 else 2e-4
        fixed = ecg_solve(lambda V: csr_spmbv(a_d, V), b_d, t=t, tol=tol, max_iters=1500)
        assert fixed.breakdown
        res = ecg_solve(lambda V: csr_spmbv(a_d, V), b_d, t=t, tol=tol,
                        max_iters=1500, adaptive="reduce")
        assert res.converged and not res.breakdown
        ad = np.asarray(a.todense(), np.float64)
        relres = np.linalg.norm(ad @ np.asarray(res.x, np.float64) - b) / np.linalg.norm(b)
        assert relres < (1e-7 if dtype == jnp.float64 else 1e-2)
        # the dependent directions were dropped on the first iteration, down
        # to exactly the rank of the initial splitting
        assert int(split_rank(jnp.asarray(b), t)) == m
        ah = np.asarray(res.active_hist)
        assert ah[0] == t and ah[1] == m
        assert res.reduction_events()[0] == (1, t, m)

    def test_duplicated_rhs_blocks(self, system):
        """An exactly-duplicated splitting (rank 1) must degrade to CG."""
        a, b = system
        dup = lambda r, t_: jnp.tile(r[:, None], (1, t_)) / t_
        fixed = ecg_solve(lambda V: csr_spmbv(a, V), jnp.asarray(b), t=4,
                          tol=1e-9, max_iters=1500, split=dup)
        assert fixed.breakdown
        res = ecg_solve(lambda V: csr_spmbv(a, V), jnp.asarray(b), t=4,
                        tol=1e-9, max_iters=1500, split=dup, adaptive="reduce")
        assert res.converged
        assert int(np.asarray(res.active_hist)[1]) == 1
        cg = cg_solve(lambda v: csr_spmv(a, v), jnp.asarray(b), tol=1e-9, max_iters=1500)
        assert abs(res.n_iters - cg.n_iters) <= 2

    def test_no_spurious_drops_on_full_rank(self, system):
        a, b = system
        plain = ecg_solve(lambda V: csr_spmbv(a, V), jnp.asarray(b), t=4,
                          tol=1e-9, max_iters=2000)
        res = ecg_solve(lambda V: csr_spmbv(a, V), jnp.asarray(b), t=4,
                        tol=1e-9, max_iters=2000, adaptive="reduce")
        assert res.converged
        assert res.n_iters <= plain.n_iters + 2
        ah = np.asarray(res.active_hist)[: res.n_iters + 1]
        assert ah[0] == 4

    def test_policy_objects_and_errors(self):
        assert resolve_policy(None) is None and resolve_policy("off") is None
        pol = resolve_policy("reduce+restart")
        assert isinstance(pol, ReductionPolicy) and pol.restart
        custom = ReductionPolicy(min_t=2, drop_tol=1e-3)
        assert resolve_policy(custom) is custom
        with pytest.raises(ValueError):
            resolve_policy("bogus")
        with pytest.raises(TypeError):
            resolve_policy(3)

    def test_chol_eps_conflicts_with_adaptive(self, system):
        a, b = system
        with pytest.raises(ValueError, match="chol_eps"):
            ecg_solve(lambda V: csr_spmbv(a, V), jnp.asarray(b), t=4,
                      chol_eps=1e-10, adaptive="reduce")

    def test_explicit_off_honored_under_auto(self, system):
        """t='auto' defaults to rankrev, but an explicit adaptive='off' must
        keep the historical bare-Cholesky body (no trace recorded)."""
        a, b = system
        res = ecg_solve(lambda V: csr_spmbv(a, V), jnp.asarray(b), t="auto",
                        matrix=a, tol=1e-8, max_iters=2000, adaptive="off")
        assert res.converged and res.active_hist is None

    def test_restart_policy_smoke(self, system):
        a, _ = system
        b = deficient_rhs(a.shape[0], 4, m=2)
        res = ecg_solve(lambda V: csr_spmbv(a, V), jnp.asarray(b), t=4,
                        tol=1e-9, max_iters=1500,
                        adaptive=ReductionPolicy(restart=True, plateau_window=10))
        assert res.converged and res.restarts >= 0


class TestSelectT:
    def test_select_t_table_and_argmin(self, system):
        a, b = system
        sel = select_t(a, b, candidates=(1, 2, 4, 8), tol=1e-8)
        assert isinstance(sel, TSelection)
        assert sel.t in (1, 2, 4, 8)
        assert set(sel.table) == {1, 2, 4, 8}
        costs = {t: row["total_cost_s"] for t, row in sel.table.items()}
        assert sel.t == min(costs, key=costs.get)
        for row in sel.table.values():
            assert row["est_iters"] >= 1 and row["iter_cost_s"] > 0
        assert "chosen" in sel.summary()

    def test_distributed_cost_shifts_choice_upward(self, system):
        """Under a communication-dominated machine model the per-iteration
        cost is latency-bound, so larger t (fewer iterations) should never
        lose to t=1 by much — the paper's central trade-off."""
        a, b = system
        seq = select_t(a, b, candidates=(1, 8), tol=1e-8, n_nodes=1, ppn=1)
        dist = select_t(a, b, candidates=(1, 8), tol=1e-8, n_nodes=2, ppn=4)
        ratio_seq = seq.table[8]["iter_cost_s"] / seq.table[1]["iter_cost_s"]
        ratio_dist = dist.table[8]["iter_cost_s"] / dist.table[1]["iter_cost_s"]
        # communication amortizes the width: relative cost of t=8 shrinks
        assert ratio_dist < ratio_seq

    def test_ecg_solve_auto(self, system):
        a, b = system
        res = ecg_solve(lambda V: csr_spmbv(a, V), jnp.asarray(b), t="auto",
                        matrix=a, tol=1e-8, max_iters=2000)
        assert res.converged
        assert res.t in (1, 2, 4, 8, 16)
        assert isinstance(res.selection, TSelection)
        # auto-t implies breakdown safety (rankrev path records the trace)
        assert res.active_hist is not None

    def test_auto_requires_matrix_or_selection(self, system):
        a, b = system
        with pytest.raises(ValueError, match="matrix="):
            ecg_solve(lambda V: csr_spmbv(a, V), jnp.asarray(b), t="auto")
        with pytest.raises(ValueError, match="auto"):
            ecg_solve(lambda V: csr_spmbv(a, V), jnp.asarray(b), t="bogus")
        sel = select_t(a, b, candidates=(2, 4), tol=1e-8)
        res = ecg_solve(lambda V: csr_spmbv(a, V), jnp.asarray(b), t="auto",
                        select=sel, tol=1e-8, max_iters=2000)
        assert res.t == sel.t and res.selection is sel

    def test_kappa_mode(self, system):
        a, b = system
        sel = select_t(a, b, candidates=(1, 4), mode="kappa")
        assert sel.t in (1, 4) and sel.mode == "kappa"
        with pytest.raises(ValueError):
            select_t(a, b, mode="bogus")
        with pytest.raises(ValueError):
            select_t(a, None, mode="probe")


class TestKernelDispatch:
    def test_gpu_fallback_warns_once_when_verbose(self, monkeypatch):
        from repro.kernels import dispatch
        from repro.kernels.fused_gram.ops import fused_gram

        monkeypatch.setattr(jax, "default_backend", lambda: "gpu")
        dispatch.reset_dispatch_warnings()  # conftest resets too; explicit here
        monkeypatch.setenv("REPRO_KERNEL_VERBOSE", "1")
        m = jnp.ones((8, 2))
        with pytest.warns(RuntimeWarning, match="no Pallas GPU lowering"):
            fused_gram(m, m, m, m)
        import warnings as _w

        with _w.catch_warnings():
            _w.simplefilter("error")  # second call: warn-once means silence
            fused_gram(m, m, m, m)

    def test_gpu_fallback_silent_by_default(self, monkeypatch):
        from repro.kernels import dispatch
        from repro.kernels.bsr_spmbv.ops import bsr_spmbv

        monkeypatch.setattr(jax, "default_backend", lambda: "gpu")
        dispatch.reset_dispatch_warnings()
        monkeypatch.delenv("REPRO_KERNEL_VERBOSE", raising=False)
        blocks = jnp.ones((1, 1, 4, 4))
        idx = jnp.zeros((1, 1), jnp.int32)
        import warnings as _w

        with _w.catch_warnings():
            _w.simplefilter("error")
            out = bsr_spmbv(blocks, idx, jnp.ones((4, 2)))
        assert out.shape == (4, 2)

    def test_tpu_unaffected_cpu_oracle(self):
        from repro.kernels.dispatch import resolve_dispatch

        use, interpret = resolve_dispatch("fused_gram", None)
        assert use is False and interpret is True  # CPU host
        use, interpret = resolve_dispatch("fused_gram", True)
        assert use is True and interpret is True  # forced interpret-mode


class TestReductionEventTrace:
    """Regression: ``reduction_events()`` must report every recorded width
    change by scanning the full valid (-1-padded) trace, independently of
    ``n_iters`` bookkeeping — in particular a drop recorded on the *final*
    iteration (capped or converged) used to fall off the sliced view."""

    def test_events_do_not_depend_on_n_iters(self, system):
        from repro.core.cg import SolveResult

        # n_iters deliberately inconsistent with the trace: the events must
        # come from the trace alone
        res = SolveResult(
            x=jnp.zeros(4), n_iters=0, res_hist=jnp.zeros(5),
            converged=False, active_hist=jnp.asarray([4, 2, 2, 1, -1]),
        )
        assert res.reduction_events() == [(1, 4, 2), (3, 2, 1)]

    def test_padding_never_generates_events(self):
        from repro.core.cg import SolveResult

        res = SolveResult(
            x=jnp.zeros(4), n_iters=3, res_hist=jnp.zeros(5),
            converged=True, active_hist=jnp.asarray([4, 4, 4, -1, -1]),
        )
        assert res.reduction_events() == []
        assert SolveResult(
            x=jnp.zeros(4), n_iters=0, res_hist=jnp.zeros(1),
            converged=False, active_hist=None,
        ).reduction_events() == []

    def test_capped_final_iteration_drop_is_reported(self, system):
        """max_iters caps the solve on exactly the iteration that drops the
        width: the event must still be visible."""
        a, _ = system
        b = deficient_rhs(a.shape[0], 4, m=2)
        res = ecg_solve(lambda V: csr_spmbv(a, V), jnp.asarray(b), t=4,
                        tol=1e-9, max_iters=1, adaptive="reduce")
        assert not res.converged
        ah = np.asarray(res.active_hist)
        assert ah[0] == 4 and ah[1] == 2
        assert res.reduction_events() == [(1, 4, 2)]

    @pytest.mark.parametrize("method,s", [("classic", 1), ("pipelined", 1),
                                          ("sstep", 2)])
    def test_first_iteration_drop_reported_for_every_scheme(
        self, system, method, s
    ):
        a, _ = system
        b = deficient_rhs(a.shape[0], 4, m=2)
        res = ecg_solve(lambda V: csr_spmbv(a, V), jnp.asarray(b), t=4,
                        tol=1e-9, max_iters=1500, adaptive="reduce",
                        method=method, s=s)
        assert res.converged
        events = res.reduction_events()
        assert events and events[0][0] == 1 and events[0][1] == 4
        assert events[0][2] <= 2

    def test_converge_and_drop_on_same_iteration(self, system):
        """Width drop recorded on the convergence iteration itself: run the
        reduced solve to convergence, then cap a fresh run at exactly that
        count — both views must agree on the events."""
        a, _ = system
        b = deficient_rhs(a.shape[0], 4, m=2)
        full = ecg_solve(lambda V: csr_spmbv(a, V), jnp.asarray(b), t=4,
                         tol=1e-9, max_iters=1500, adaptive="reduce")
        assert full.converged
        capped = ecg_solve(lambda V: csr_spmbv(a, V), jnp.asarray(b), t=4,
                           tol=1e-9, max_iters=full.n_iters,
                           adaptive="reduce")
        assert capped.reduction_events() == full.reduction_events()
