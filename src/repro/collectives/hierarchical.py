"""Pod-aware hierarchical collectives — the paper's node-aware schemes applied
to multi-pod gradient reduction (DESIGN.md §4, beyond-paper).

The 2-step node-aware exchange (paper Fig 2.6) maps onto an allreduce as:

    step 1 (fast tier):  reduce-scatter over the intra-pod "data" axis
                         — every chip now owns a 1/|data| shard of the sum
    step 2 (slow tier):  all-reduce over the "pod" axis on shards only
                         — slow-tier bytes drop by |data|× vs a flat ring
    step 3 (fast tier):  all-gather over "data" to restore the full tensor

Total fast-tier bytes are unchanged vs a flat all-reduce; slow-tier (DCI)
bytes per chip drop from 2·(P-1)/P·n to 2·(pods-1)/pods·n/|data| — exactly
the deduplication the paper's 2-step scheme buys on MPI clusters.

``tiered_collective_bytes`` classifies the collectives of a compiled HLO by
whether their replica groups cross the pod boundary, so the dry-run can
report slow-tier traffic separately.
"""

from __future__ import annotations

import re

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.analysis.roofline import _SHAPE_RE, _shape_bytes


def hierarchical_allreduce(x, mesh: Mesh, pod_axis: str = "pod", fast_axis: str = "data"):
    """2-step pod-aware allreduce of a replicated array (see module doc).

    Falls back to a plain psum when the mesh has no pod axis or the leading
    dim does not divide the fast axis.
    """
    names = mesh.axis_names
    if pod_axis not in names:
        return shard_map(
            lambda v: jax.lax.psum(v, fast_axis),
            mesh=mesh, in_specs=P(), out_specs=P(), check_rep=False,
        )(x)
    fast = mesh.shape[fast_axis]
    if x.shape[0] % fast:
        return shard_map(
            lambda v: jax.lax.psum(v, (pod_axis, fast_axis)),
            mesh=mesh, in_specs=P(), out_specs=P(), check_rep=False,
        )(x)

    def body(v):
        # step 1: fast-tier reduce-scatter (chips end up with 1/|data| shards)
        shard = jax.lax.psum_scatter(v, fast_axis, scatter_dimension=0, tiled=True)
        # step 2: slow-tier all-reduce on shards only
        shard = jax.lax.psum(shard, pod_axis)
        # step 3: fast-tier all-gather
        return jax.lax.all_gather(shard, fast_axis, axis=0, tiled=True)

    return shard_map(body, mesh=mesh, in_specs=P(), out_specs=P(), check_rep=False)(x)


def tiered_collective_bytes(hlo_text: str, pod_size: int) -> dict[str, int]:
    """Split collective payload bytes into intra-pod vs cross-pod tiers by
    inspecting replica_groups: a group crosses pods iff it contains device
    ids from different ``id // pod_size`` blocks."""
    out = {"intra_pod": 0, "cross_pod": 0}
    for line in hlo_text.splitlines():
        line = line.strip()
        m = re.match(r"(?:ROOT\s+)?%?[\w.-]+\s*=\s*(.+?)\s+([\w-]+)\(", line)
        if not m:
            continue
        rt, op = m.groups()
        base = op.removesuffix("-start").removesuffix("-done")
        if base not in (
            "all-gather", "all-reduce", "reduce-scatter", "all-to-all", "collective-permute",
        ) or op.endswith("-done"):
            continue
        nbytes = sum(_shape_bytes(d, s) for d, s in _SHAPE_RE.findall(rt))
        crosses = False
        gm = re.search(r"replica_groups=\{?\{([0-9,{} ]*)\}", line)
        if gm:
            first_group = gm.group(1).split("}")[0]
            ids = [int(t) for t in first_group.replace("{", "").split(",") if t.strip().isdigit()]
            pods = {i // pod_size for i in ids}
            crosses = len(pods) > 1
        else:
            sm = re.search(r"source_target_pairs=\{\{(\d+),(\d+)", line)
            if sm:
                a, b = int(sm.group(1)), int(sm.group(2))
                crosses = a // pod_size != b // pod_size
        out["cross_pod" if crosses else "intra_pod"] += nbytes
    return out
