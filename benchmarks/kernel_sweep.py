"""Kernel-vs-oracle and overlap-vs-blocking benchmark sweep (8 host devices).

    PYTHONPATH=src python benchmarks/kernel_sweep.py [filter] [--json PATH]

Prints ``name,us_per_call,derived`` CSV and writes the same rows as
machine-readable JSON (default ``BENCH_kernel_sweep.json``) so the perf
trajectory is tracked across PRs:
  * ``spmbv/<strategy>_t<t>_<backend>_<blocking|overlap>`` — distributed
    SpMBV wall time for all four exchange strategies at t in {4, 8}, with
    the CSR jnp backend and the Block-ELL kernel backend, blocking vs
    comm-hiding (interior/boundary) schedules;
  * ``kernel/...`` — local hot-spot head-to-heads (Block-ELL vs scalar CSR,
    fused vs unfused gram and tail).

XLA_FLAGS is set before jax import so the sweep runs on a (2 nodes x 4
procs) mesh anywhere; pre-set XLA_FLAGS wins (e.g. a real TPU topology).
"""

import argparse
import json
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("filter", nargs="?", default=None)
    ap.add_argument("--json", default="BENCH_kernel_sweep.json")
    ap.add_argument("--seed", type=int, default=0,
                    help="operand RNG seed (fixed so host-mode numbers "
                         "reproduce run-to-run)")
    ap.add_argument("--repeats", type=int, default=5,
                    help="timing repeats per row; the median is reported")
    args = ap.parse_args()

    jax.config.update("jax_enable_x64", True)
    from repro.analysis.ecg_bench import kernel_vs_oracle, overlap_vs_blocking_sweep
    from repro.sparse import dg_laplace_2d

    n_dev = len(jax.devices())
    assert n_dev >= 8, f"need >= 8 devices, got {n_dev}"
    mesh = jax.sharding.Mesh(
        np.array(jax.devices()[:8]).reshape(2, 4), ("node", "proc")
    )

    a = dg_laplace_2d((16, 12), block=8)  # 1536 rows over 8 ranks
    print("name,us_per_call,derived")
    rows = overlap_vs_blocking_sweep(
        a, mesh, ts=(4, 8), seed=args.seed, repeats=args.repeats
    ) + kernel_vs_oracle(seed=args.seed + 2, repeats=args.repeats)
    for r in rows:
        if args.filter and args.filter not in r["name"]:
            continue
        print(f"{r['name']},{r['us']:.1f},{r['derived']}", flush=True)
    # the JSON always carries the full sweep (the filter only trims stdout),
    # so cross-PR trajectory comparisons never see partial files
    with open(args.json, "w") as fh:
        json.dump(dict(benchmark="kernel_sweep", seed=args.seed,
                       repeats=args.repeats, rows=rows), fh, indent=2)
    print(f"# wrote {args.json}")


if __name__ == "__main__":
    main()
