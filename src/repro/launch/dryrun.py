import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST precede every other import (jax locks the platform
device count at first init).  For each cell we:

  1. compile the full scan-based step on the production mesh — proves the
     sharding config is coherent (no sharding mismatch / unsupported
     collective) and yields memory_analysis (fits-on-chip evidence) and the
     collective schedule;
  2. compile 1-unit and 2-unit *unrolled* variants and extrapolate per-layer
     FLOPs / bytes / collective payloads (XLA cost analysis counts scan
     bodies once — see repro.analysis.roofline);
  3. assemble the three roofline terms with v5e constants.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch all --mesh both \
        --out experiments/dryrun.json
"""

import argparse
import json
import time
import traceback
from pathlib import Path

import jax

from repro.configs import ARCH_IDS, SHAPE_CELLS, get_config, get_shapes
from repro.launch.mesh import make_production_mesh
from repro.train.train_step import build_train_step, build_serve_step
from repro.analysis.roofline import (
    CellCost,
    cost_from_compiled,
    roofline_from_cost,
    model_flops,
    count_collective_ops,
)


def _unit_layers(cfg, k: int):
    """Config with k layer-units, unrolled (extrapolation pass).  remat is
    preserved so the measured FLOPs include the real recompute cost."""
    kw = dict(n_layers=k, unroll=True)
    if cfg.family == "hybrid" and cfg.attn_period:
        kw["n_layers"] = k * cfg.attn_period  # period-level units
    if cfg.family == "encdec":
        kw["n_enc_layers"] = k
    return cfg.with_(**kw)


def _n_units(cfg) -> float:
    if cfg.family == "hybrid" and cfg.attn_period:
        return cfg.n_layers / cfg.attn_period  # ~1.5% tail correction noted
    return float(cfg.n_layers)


def _lower_cell(cfg, mesh, kind: str, seq: int, batch: int):
    """Build + lower + compile one cell; returns (compiled, lower_s, compile_s)."""
    if kind == "train":
        bundle = build_train_step(cfg, mesh, batch=batch, seq=seq, donate=False)
        args = (bundle.abstract_params, bundle.abstract_opt, bundle.abstract_batch)
        fn = bundle.step_fn
    else:  # prefill is modeled as a train-shaped forward w/o optimizer: use loss
        if kind == "prefill":
            from repro.models.registry import model_api
            from repro.models.common import MeshAxes
            from jax.sharding import NamedSharding, PartitionSpec as P

            api = model_api(cfg)
            axes = MeshAxes.from_mesh(mesh)
            loss = api.loss_fn(cfg, mesh)
            pspecs = jax.tree.map(
                lambda s: NamedSharding(mesh, s),
                api.param_specs(cfg, axes),
                is_leaf=lambda s: isinstance(s, P),
            )
            binput = api.train_input_specs(cfg, mesh, batch, seq)
            abatch = {k: v[0] for k, v in binput.items()}
            bspecs = {
                k: NamedSharding(mesh, v[1]) for k, v in binput.items()
            }
            fn = jax.jit(loss, in_shardings=(pspecs, bspecs), out_shardings=NamedSharding(mesh, P()))
            args = (api.abstract_params(cfg), abatch)
        else:  # decode
            fn, meta = build_serve_step(cfg, mesh, batch=batch, seq=seq)
            args = (meta["abstract_params"], meta["abstract_cache"], meta["abstract_batch"])
    t0 = time.time()
    lowered = fn.lower(*args)
    t_low = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_comp = time.time() - t0
    return compiled, t_low, t_comp


def run_cell(arch: str, shape_name: str, multi_pod: bool, costs: bool = True) -> dict:
    cfg = get_config(arch)
    kind, seq, batch = SHAPE_CELLS[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = int(mesh.devices.size)
    rec: dict = dict(
        arch=arch, shape=shape_name, kind=kind, seq=seq, batch=batch,
        mesh="multi" if multi_pod else "single", chips=chips,
    )

    compiled, t_low, t_comp = _lower_cell(cfg, mesh, kind, seq, batch)
    ma = compiled.memory_analysis()
    rec["compile_s"] = round(t_comp, 2)
    rec["lower_s"] = round(t_low, 2)
    rec["memory"] = dict(
        argument_bytes_per_dev=int(ma.argument_size_in_bytes),
        output_bytes_per_dev=int(ma.output_size_in_bytes),
        temp_bytes_per_dev=int(ma.temp_size_in_bytes),
        alias_bytes_per_dev=int(ma.alias_size_in_bytes),
    )
    rec["collective_ops_schedule"] = count_collective_ops(compiled.as_text())

    if costs:
        c1, *_ = _lower_cell(_unit_layers(cfg, 1), mesh, kind, seq, batch)
        c2, *_ = _lower_cell(_unit_layers(cfg, 2), mesh, kind, seq, batch)
        cost = CellCost.extrapolate(cost_from_compiled(c1), cost_from_compiled(c2), _n_units(cfg))
        mf = model_flops(cfg, kind, seq, batch)
        rl = roofline_from_cost(cost, chips, mf)
        rec["cost"] = dict(
            flops_per_dev=cost.flops,
            hbm_bytes_per_dev=cost.hbm_bytes,
            coll_bytes_per_dev=cost.coll_bytes,
            coll_breakdown=cost.coll_breakdown,
        )
        rec["roofline"] = rl.as_dict()
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="experiments/dryrun.json")
    ap.add_argument("--no-costs", action="store_true")
    args = ap.parse_args()

    archs = ARCH_IDS if args.arch == "all" else [args.arch]
    shapes = list(SHAPE_CELLS) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    out_path = Path(args.out)
    out_path.parent.mkdir(parents=True, exist_ok=True)
    results = []
    if out_path.exists():
        results = json.loads(out_path.read_text())

    done = {(r["arch"], r["shape"], r["mesh"]) for r in results if "error" not in r}
    n_fail = 0
    for arch in archs:
        arch_shapes = get_shapes(arch)
        for shape in shapes:
            status = arch_shapes[shape]
            for mp in meshes:
                key = (arch, shape, "multi" if mp else "single")
                if key in done:
                    continue
                if status.startswith("skip:"):
                    results = [r for r in results if (r["arch"], r["shape"], r["mesh"]) != key]
                    results.append(dict(arch=arch, shape=shape, mesh=key[2], skipped=status[5:]))
                    out_path.write_text(json.dumps(results, indent=1))
                    print(f"SKIP {key}: {status[5:]}", flush=True)
                    continue
                print(f"RUN  {key} ...", flush=True)
                t0 = time.time()
                try:
                    # roofline costs only needed on the single-pod mesh
                    rec = run_cell(arch, shape, mp, costs=not (mp or args.no_costs))
                    print(
                        f"  ok {time.time()-t0:.0f}s compile={rec['compile_s']}s "
                        f"temp={rec['memory']['temp_bytes_per_dev']/2**30:.2f}GiB"
                        + (
                            f" dominant={rec['roofline']['dominant']}"
                            f" frac={rec['roofline']['roofline_fraction']:.3f}"
                            if "roofline" in rec
                            else ""
                        ),
                        flush=True,
                    )
                except Exception as e:  # noqa: BLE001 — record and continue
                    rec = dict(
                        arch=arch, shape=shape, mesh=key[2],
                        error=f"{type(e).__name__}: {e}",
                        trace=traceback.format_exc()[-2000:],
                    )
                    n_fail += 1
                    print(f"  FAIL {type(e).__name__}: {str(e)[:200]}", flush=True)
                results = [r for r in results if (r["arch"], r["shape"], r["mesh"]) != key]
                results.append(rec)
                out_path.write_text(json.dumps(results, indent=1))
    print(f"done: {len(results)} cells, {n_fail} failures", flush=True)
    return 1 if n_fail else 0


if __name__ == "__main__":
    raise SystemExit(main())
