"""Tracer sinks: in-memory (tests), JSONL append, Chrome-trace export.

A sink is any object with::

    span(span)                            # one closed Span
    metric(kind, name, value, ts, attrs)  # one counter/gauge/instant sample
    close()                               # optional: flush buffers

The three provided sinks cover the matrix the observability docs promise:

=============== ==================== =====================================
sink            destination          consumer
=============== ==================== =====================================
MemorySink      python lists         tests / ad-hoc inspection
JsonlSink       append-only .jsonl   log shippers, ``jq``, pandas
ChromeTraceSink trace.json           ``chrome://tracing`` / Perfetto UI
=============== ==================== =====================================
"""

from __future__ import annotations

import json
import os

from repro.observe.tracer import Span


class MemorySink:
    """Keeps every span and metric sample in Python lists (for tests)."""

    def __init__(self):
        self.spans: list[Span] = []
        self.metrics: list[dict] = []

    def span(self, span: Span):
        self.spans.append(span)

    def metric(self, kind, name, value, ts, attrs):
        self.metrics.append(
            dict(kind=kind, name=name, value=value, ts=ts, attrs=dict(attrs))
        )

    def by_name(self, name: str) -> list[Span]:
        return [s for s in self.spans if s.name == name]

    def counter_value(self, name: str):
        """Latest sample of counter/gauge ``name`` (None if never set)."""
        vals = [m["value"] for m in self.metrics if m["name"] == name]
        return vals[-1] if vals else None


class JsonlSink:
    """One JSON object per line, appended atomically.

    Each record is written with a single ``os.write`` on an
    ``O_APPEND`` descriptor — POSIX guarantees the append offset is
    atomic per write, so concurrent writers (a forked benchmark, a
    second server process sharing the log) interleave whole records,
    never partial lines (tested in ``tests/test_observe.py``).
    """

    def __init__(self, path: str):
        self.path = str(path)
        self._fd = os.open(self.path,
                           os.O_WRONLY | os.O_CREAT | os.O_APPEND, 0o644)

    def _write(self, record: dict):
        line = json.dumps(record, separators=(",", ":")) + "\n"
        os.write(self._fd, line.encode())

    def span(self, span: Span):
        self._write(dict(type="span", **span.to_dict()))

    def metric(self, kind, name, value, ts, attrs):
        self._write(dict(type=kind, name=name, value=value, ts=ts,
                         args=dict(attrs)))

    def close(self):
        if self._fd is not None:
            os.close(self._fd)
            self._fd = None


class ChromeTraceSink:
    """Chrome trace event format (the JSON ``chrome://tracing`` and
    Perfetto's legacy importer open directly).

    Spans become complete events (``ph="X"``), counters counter events
    (``ph="C"``), gauges/instants instant events (``ph="i"``).
    Timestamps are microseconds relative to the first event (the viewer
    needs small monotonic numbers, not perf_counter's arbitrary origin);
    events are sorted by timestamp at write time, so out-of-order
    ``emit`` calls (queue waits recorded at drain) still render.
    """

    def __init__(self, path: str, pid: int = 0):
        self.path = str(path)
        self.pid = pid
        self.events: list[dict] = []
        self._origin: float | None = None

    def _us(self, t: float) -> float:
        if self._origin is None:
            self._origin = t
        return (t - self._origin) * 1e6

    def span(self, span: Span):
        self.events.append(dict(
            name=span.name, cat=span.cat or "span", ph="X",
            ts=self._us(span.t0), dur=(span.dur or 0.0) * 1e6,
            pid=self.pid, tid=span.tid, args=dict(span.args),
        ))

    def metric(self, kind, name, value, ts, attrs):
        if kind == "counter":
            self.events.append(dict(
                name=name, cat="metric", ph="C", ts=self._us(ts),
                pid=self.pid, tid=0, args={name: value, **attrs},
            ))
        else:  # gauge / instant -> instant event with the value in args
            self.events.append(dict(
                name=name, cat=kind, ph="i", ts=self._us(ts), s="p",
                pid=self.pid, tid=0, args={"value": value, **attrs},
            ))

    def to_json(self) -> dict:
        # the running origin is the first *closed* event, so an outer span
        # that opened earlier lands at a negative ts; shift once at export
        # so the earliest event sits at 0
        events = sorted(self.events, key=lambda e: e["ts"])
        if events and events[0]["ts"] != 0:
            shift = events[0]["ts"]
            events = [dict(e, ts=e["ts"] - shift) for e in events]
        return dict(traceEvents=events, displayTimeUnit="ms")

    def close(self):
        with open(self.path, "w") as fh:
            json.dump(self.to_json(), fh)


def open_sink(path: str):
    """Sink for ``path`` by extension: ``.jsonl`` appends JSON lines,
    anything else writes a Chrome trace on close (the ``--trace PATH``
    CLI contract)."""
    if str(path).endswith(".jsonl"):
        return JsonlSink(path)
    return ChromeTraceSink(path)
