"""Distributed ECG with node-aware communication strategies on 8 devices.

Shows the paper's §4 result: per-strategy inter/intra-tier traffic and the
model-tuned strategy choice.

    PYTHONPATH=src python examples/ecg_node_aware.py
"""

import os

if "--xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax

jax.config.update("jax_enable_x64", True)

import numpy as np

from repro.sparse import dg_laplace_2d
from repro.sparse.partition import partition_csr
from repro.core.comm_graph import build_comm_graph
from repro.core.models import tune_strategy, STRATEGIES
from repro.core.machines import BLUE_WATERS
from repro.solver import CommConfig, ECGSolver, SolverConfig


def main():
    mesh = jax.make_mesh((2, 4), ("node", "proc"))  # 2 "nodes" x 4 "procs"
    a = dg_laplace_2d((12, 8), block=8)
    rng = np.random.default_rng(0)
    b = rng.standard_normal(a.shape[0])
    t = 8
    print(f"system: {a.shape[0]} rows, mesh 2x4, t={t}\n")

    print(f"{'strategy':10s} {'iters':>5s} {'inter rows':>10s} {'intra rows':>10s} {'steps':>5s}")
    pm = None
    for strategy in STRATEGIES:
        solver = ECGSolver.build(a, mesh, SolverConfig(
            t=t, tol=1e-8, max_iters=500, comm=CommConfig(strategy=strategy),
        ), pm=pm)
        pm = solver.partition  # partition once, reuse across strategy sessions
        res = solver.solve(b)
        rows = solver.op.plan.comm_rows()
        print(
            f"{strategy:10s} {res.n_iters:5d} {rows['inter']:10d} {rows['intra']:10d} "
            f"{len(solver.op.plan.steps):5d}"
        )

    pm = partition_csr(a, 8)
    g = build_comm_graph(pm, ppn=4)
    best, times = tune_strategy(g, t, BLUE_WATERS.with_ppn(4))
    print(f"\nmodel-tuned choice (BlueWaters constants): {best}")
    for k, v in times.items():
        print(f"  {k:10s} {v*1e6:8.1f} modeled us/exchange")


if __name__ == "__main__":
    main()
