"""Docs checker: intra-repo links resolve and fenced snippets execute.

    PYTHONPATH=src python tools/check_docs.py [--links-only|--syntax-only] [files...]

Defaults to README.md + docs/*.md. Every fenced block whose info string is
exactly ``python`` is part of the contract: the blocks of one document are
concatenated (in order, sharing a namespace, like a notebook) and executed
in a subprocess with 8 forced host devices, so distributed examples run
anywhere. ``bash``/``text`` blocks are never executed.

Exit code 0 = all links resolve and all snippets run as written.
"""

from __future__ import annotations

import argparse
import os
import re
import subprocess
import sys
import tempfile
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
FENCE_RE = re.compile(r"^```(\S*)\s*$")


def extract_snippets(text: str) -> list[tuple[int, str]]:
    """[(first_line_no, code)] for each ```python fence."""
    snippets, buf, lang, start = [], [], None, 0
    for i, line in enumerate(text.splitlines(), start=1):
        m = FENCE_RE.match(line)
        if m and lang is None:
            lang, buf, start = m.group(1), [], i + 1
        elif line.strip() == "```" and lang is not None:
            if lang == "python":
                snippets.append((start, "\n".join(buf) + "\n"))
            lang = None
        elif lang is not None:
            buf.append(line)
    return snippets


def check_links(doc: Path, text: str) -> list[str]:
    errors = []
    for target in LINK_RE.findall(text):
        if target.startswith(("http://", "https://", "mailto:", "#")):
            continue
        path = target.split("#", 1)[0]
        if not path:
            continue
        if not (doc.parent / path).resolve().exists():
            errors.append(f"{doc}: broken link -> {target}")
    return errors


def run_snippets(doc: Path, snippets: list[tuple[int, str]]) -> list[str]:
    if not snippets:
        return []
    # Concatenate with line-number markers so tracebacks point at the doc.
    parts = []
    for line_no, code in snippets:
        parts.append(f"# --- {doc.name}:{line_no} ---\n{code}")
    program = "\n".join(parts)
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src") + os.pathsep + env.get("PYTHONPATH", "")
    env.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
    env.setdefault("JAX_PLATFORMS", "cpu")
    with tempfile.NamedTemporaryFile(
        "w", suffix=f"_{doc.stem}.py", delete=False
    ) as fh:
        fh.write(program)
        tmp = fh.name
    try:
        proc = subprocess.run(
            [sys.executable, tmp], env=env, capture_output=True, text=True,
            timeout=900, cwd=ROOT,
        )
    finally:
        os.unlink(tmp)
    if proc.returncode != 0:
        return [
            f"{doc}: snippet execution failed (exit {proc.returncode})\n"
            f"--- stdout ---\n{proc.stdout[-2000:]}\n"
            f"--- stderr ---\n{proc.stderr[-4000:]}"
        ]
    return []


def check_syntax(doc: Path, snippets: list[tuple[int, str]]) -> list[str]:
    errors = []
    for line_no, code in snippets:
        try:
            compile(code, f"{doc}:{line_no}", "exec")
        except SyntaxError as e:
            errors.append(f"{doc}:{line_no}: snippet syntax error: {e}")
    return errors


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("files", nargs="*", type=Path)
    ap.add_argument("--links-only", action="store_true")
    ap.add_argument("--syntax-only", action="store_true",
                    help="compile snippets but do not execute them")
    args = ap.parse_args(argv)

    docs = args.files or [ROOT / "README.md", *sorted((ROOT / "docs").glob("*.md"))]
    errors = []
    for doc in docs:
        text = doc.read_text()
        doc_errors = check_links(doc, text)
        snippets = extract_snippets(text)
        if not args.links_only:
            doc_errors += check_syntax(doc, snippets)
            if not args.syntax_only and not doc_errors:
                doc_errors += run_snippets(doc, snippets)
        print(f"{doc.relative_to(ROOT)}: {len(snippets)} snippet(s) "
              f"{'checked' if args.syntax_only or args.links_only else 'executed'}, "
              "links OK"
              if not doc_errors else f"{doc.relative_to(ROOT)}: FAILURES",
              flush=True)
        errors += doc_errors
    if errors:
        print("\n".join(errors), file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
