"""Disk-backed warm-start cache: tuning + t-selection survive restarts.

The expensive part of registering an operator is not the partition or the
plan (milliseconds) but the *tuning work*: ``t="auto"`` convergence
probes and autotuner model evaluation.  Both already serialize losslessly
(:func:`~repro.tune.autotune.tunedconfig_to_dict`,
:func:`~repro.adaptive.select_t.tselection_to_dict`), and both feed
straight back into a build through ``SolverConfig.replace(tuned=...,
select=...)`` — so one small JSON file per operator turns every restart
rebuild into a probe-free warm build.

Schema 2 entries additionally persist the CSR→Block-ELL **conversion
meta** (:func:`~repro.kernels.block_ell_meta` — tile choice, ``kmax``,
padding histogram) when the build ran the Pallas kernel path: a restarted
or re-admitted-after-eviction operator then direct-fills its Block-ELL
arrays without re-running the tile analysis
(``SolverStats.conv_analyzed`` stays False — gated in
``benchmarks/serve_bench.py``).

Keying: ``(operator fingerprint, base-config digest, mesh tag)``.  The
config digest hashes the solver template *with its tuned/select payload
nulled* — a cached selection is only valid for the base configuration
(tolerance, method, candidates, machine…) it was probed under, while the
payload itself must not key the lookup it answers.  The mesh tag
(``seq`` or ``{nodes}x{ppn}``) keeps sequential and differently-shaped
distributed selections apart.

Corrupt or stale-schema files are a cache *miss*, never an error: the
loader warns and falls back to a cold build that overwrites the entry.
"""

from __future__ import annotations

import hashlib
import json
import os
import warnings

from repro.solver.config import SolverConfig, solverconfig_to_dict

_SCHEMA = 2


def config_digest(cfg: SolverConfig) -> str:
    """Digest of the base solver template, warm-start payload excluded."""
    d = solverconfig_to_dict(cfg)
    d["tune"]["tuned"] = None
    d["adaptive"]["select"] = None
    blob = json.dumps(d, sort_keys=True).encode()
    return hashlib.blake2b(blob, digest_size=8).hexdigest()


def mesh_tag(mesh) -> str:
    """``seq`` for a single-device handle, else ``{nodes}x{ppn}``."""
    if mesh is None:
        return "seq"
    n_nodes, ppn = mesh.devices.shape
    return f"{n_nodes}x{ppn}"


class WarmStartCache:
    """One JSON file per (fingerprint, config, mesh) warm-start entry."""

    def __init__(self, root: str):
        self.root = root
        os.makedirs(root, exist_ok=True)

    def path(self, fingerprint: str, cfg_digest: str, tag: str) -> str:
        return os.path.join(self.root, f"{fingerprint}-{cfg_digest}-{tag}.json")

    def load(self, fingerprint: str, cfg_digest: str, tag: str):
        """Return ``(hit, tuned, select, conversion)``; corrupt entries are
        misses.  Schema-1 entries (no conversion meta) still hit — their
        ``conversion`` is None and the next store upgrades them in place."""
        path = self.path(fingerprint, cfg_digest, tag)
        if not os.path.exists(path):
            return False, None, None, None
        try:
            with open(path) as f:
                d = json.load(f)
            if d.get("schema") not in (1, _SCHEMA):
                raise ValueError(f"unknown warm-start schema {d.get('schema')!r}")
            tuned = select = None
            if d.get("tuned") is not None:
                from repro.tune.autotune import tunedconfig_from_dict

                tuned = tunedconfig_from_dict(d["tuned"])
            if d.get("select") is not None:
                from repro.adaptive.select_t import tselection_from_dict

                select = tselection_from_dict(d["select"])
            conversion = d.get("conversion")
            if conversion is not None and not isinstance(conversion, dict):
                conversion = None
            return True, tuned, select, conversion
        except Exception as e:  # poisoned entry -> cold build, then overwrite
            warnings.warn(
                f"warm-start cache entry {path} unreadable ({e}); "
                "falling back to a cold build",
                stacklevel=3,
            )
            return False, None, None, None

    def store(self, fingerprint: str, cfg_digest: str, tag: str,
              tuned, select, conversion=None) -> str:
        """Persist a build's tuning outcome (atomic rename write).

        ``conversion`` is the JSON-safe tile-analysis meta from
        :func:`~repro.kernels.block_ell_meta` (or None when the build had
        no Pallas conversion to remember)."""
        d = dict(schema=_SCHEMA, fingerprint=fingerprint, tuned=None,
                 select=None, conversion=conversion)
        if tuned is not None:
            from repro.tune.autotune import tunedconfig_to_dict

            d["tuned"] = tunedconfig_to_dict(tuned)
        if select is not None:
            from repro.adaptive.select_t import tselection_to_dict

            d["select"] = tselection_to_dict(select)
        path = self.path(fingerprint, cfg_digest, tag)
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(d, f, indent=2)
        os.replace(tmp, path)
        return path
