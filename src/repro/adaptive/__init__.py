"""Adaptive ECG: breakdown-safe factorization, dynamic width reduction, and
automatic enlarging-factor selection.

Three layers, each usable on its own, all plugging into the existing solver
stack without touching the Pallas kernels or the two-allreduce invariant:

* :mod:`repro.adaptive.rankrev` — pivoted, rank-revealing Cholesky of the
  Gram matrix G = ZᵀAZ; reveals the numerical rank and a column mask so the
  solver drops dependent directions instead of propagating NaNs.
* :mod:`repro.adaptive.reduce` — the jit-compatible reduction controller
  (static shapes, zero-masked columns): stagnation drops per the
  flexible-ECG criterion, optional re-enlarge/restart on residual plateau.
* :mod:`repro.adaptive.select_t` — ``t="auto"``: an iterations-to-convergence
  model (probe- or condition-calibrated) composed with :mod:`repro.tune`'s
  per-iteration cost model to rank candidate widths at setup time.

Entry points: ``ecg_solve(..., adaptive="reduce")``,
``ecg_solve(..., t="auto", matrix=a)``, ``distributed_ecg(..., t="auto",
adaptive=...)``, and ``python -m repro.launch.solve --t auto``.
"""

from repro.adaptive.groups import GroupSpec
from repro.adaptive.rankrev import (
    default_rank_rtol,
    pivoted_cholesky,
    rank_revealing_apply,
)
from repro.adaptive.reduce import (
    POLICIES,
    ReductionPolicy,
    plateau_update,
    resolve_policy,
    stagnation_mask,
)
from repro.adaptive.select_t import (
    DEFAULT_CANDIDATES,
    TSelection,
    estimate_condition,
    iteration_cost,
    iters_from_condition,
    probe_decay_rate,
    resolve_auto_t,
    select_t,
    tselection_from_dict,
    tselection_to_dict,
)

__all__ = [
    "GroupSpec",
    "default_rank_rtol",
    "pivoted_cholesky",
    "rank_revealing_apply",
    "POLICIES",
    "ReductionPolicy",
    "plateau_update",
    "resolve_policy",
    "stagnation_mask",
    "DEFAULT_CANDIDATES",
    "TSelection",
    "estimate_condition",
    "iteration_cost",
    "iters_from_condition",
    "probe_decay_rate",
    "resolve_auto_t",
    "select_t",
    "tselection_from_dict",
    "tselection_to_dict",
]
