"""The one warmup + median-of-k wall timer, as measured tracer spans.

Before this module the repo carried the same seeded timing loop in three
places (``repro.analysis.ecg_bench._timeit``, ``benchmarks/common.timed``,
an inline loop in ``benchmarks/serve_bench.py``); they now all route
here, so every benchmark measures with identical discipline *and* every
measurement is a span a sink can export — run any sweep with a tracer
installed and the timing loop itself shows up in ``chrome://tracing``.
"""

from __future__ import annotations

import numpy as np

from repro.observe.tracer import Tracer

#: sink-less tracer whose spans are measured and dropped — the timing
#: backend when the caller installs no (enabled) tracer of their own
_MEASURER = Tracer()


def _sync(out):
    """Block until a jax result is actually materialized (no-op for host
    values) — the timed region must include device compute, not just the
    async dispatch."""
    try:
        import jax

        jax.block_until_ready(out)
    except Exception:
        pass
    return out


def timed_median(fn, *args, repeats: int = 3, warmup: int = 1,
                 label: str = "timed", tracer=None, sync=True, **kw):
    """``(result, median wall seconds per call)`` over ``repeats`` timed
    calls of ``fn(*args, **kw)``.

    warmup: untimed leading calls (compile/caches; 0 to time cold).
    tracer: each timed call becomes one ``bench/<label>`` span on it; a
            None or disabled tracer falls back to a sink-less measuring
            tracer (pure timing, zero records).
    sync:   ``jax.block_until_ready`` the result inside the timed region
            (set False for host-only callables to skip the import).
    """
    if repeats < 1:
        raise ValueError(f"repeats must be >= 1, got {repeats}")
    tr = tracer if (tracer is not None and tracer.enabled) else _MEASURER
    out = None
    for _ in range(warmup):
        out = fn(*args, **kw)
        if sync:
            _sync(out)
    ts = []
    for i in range(repeats):
        with tr.span(f"bench/{label}", cat="bench", rep=i) as sp:
            out = fn(*args, **kw)
            if sync:
                _sync(out)
        ts.append(sp.dur)
    return out, float(np.median(ts))


def timed_median_us(fn, *args, **kw) -> float:
    """Median wall **microseconds** per call — the historical ``_timeit``
    signature the kernel/comm sweeps print."""
    _, s = timed_median(fn, *args, **kw)
    return s * 1e6
