"""One benchmark per paper table/figure.

Each function returns CSV rows ``name,us_per_call,derived``:
  * ``us_per_call`` is a real measured wall time where the quantity is
    computable in this container (solves, kernels), and the *modeled* time
    (max-rate family, µs) where the paper's own methodology is model-driven
    (clearly suffixed ``_model``);
  * ``derived`` is the figure's headline quantity (iterations, %, speedup).

Machine constants + surrogate caveats: DESIGN.md §5, EXPERIMENTS.md.
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import comm_stats, example_graph, row, suite_graph, timed

T_VALUES = (5, 10, 15, 20)
P_VALUES = (256, 512, 1024, 2048, 4096, 8192)
SUITE = ("audikw_1", "Geo_1438", "thermal2", "ldoor", "Serena")


def _machines():
    from repro.core.machines import BLUE_WATERS, LASSEN

    return {"bw": BLUE_WATERS, "lassen": LASSEN.with_ppn(16)}


# ---------------------------------------------------------------- Fig 3.2
def fig3_2_convergence():
    """CG vs ECG iterations to 1e-6 on a reduced Example 2.1 (DG Laplace)."""
    from repro.sparse import dg_laplace_2d, csr_spmv
    from repro.core import cg_solve
    from repro.solver import ECGSolver, SolverConfig

    a = dg_laplace_2d((16, 16), block=16)  # 4096 rows, DG structure
    rng = np.random.default_rng(0)
    b = jnp.asarray(rng.standard_normal(a.shape[0]))
    rows = []
    res, us = timed(lambda: cg_solve(lambda v: csr_spmv(a, v), b, tol=1e-6, max_iters=4000).n_iters)
    rows.append(row("fig3_2/cg", us, res))
    for t in (2, 4, 8, 12, 20):
        solver = ECGSolver.build(a, config=SolverConfig(t=t, tol=1e-6, max_iters=4000))
        res, us = timed(lambda s=solver: s.solve(b).n_iters)
        rows.append(row(f"fig3_2/ecg_t{t}", us, res))
    return rows


# ---------------------------------------------------------------- Fig 3.3
def fig3_3_breakdown():
    """Modeled per-iteration decomposition (comp / p2p / collective)."""
    from repro.core.models import t_ecg_iteration
    from repro.core.ecg import ECGOperationCounts

    bw = _machines()["bw"]
    rows = []
    n, blk = example_graph()
    n_rows, nnz = n.shape[0] * blk, n.nnz * blk * blk
    for p in P_VALUES:
        g = comm_stats("example", p, 16)
        for t in T_VALUES:
            counts = ECGOperationCounts(n=n_rows, nnz=nnz, p=p, t=t)
            m = t_ecg_iteration(g, counts, bw, "standard")
            rows.append(
                row(f"fig3_3/p{p}_t{t}_model", m.total * 1e6, f"p2p%={m.p2p_fraction*100:.1f}")
            )
    return rows


# ---------------------------------------------------------------- Fig 3.4
def fig3_4_inner_product():
    """Block inner product cost: measured local gram + modeled allreduce."""
    from repro.core.models import t_collective

    bw = _machines()["bw"]
    rng = np.random.default_rng(1)
    rows = []
    n_loc = 1_310_720 // 4096  # rows per process at p=4096
    for t in T_VALUES:
        z = jnp.asarray(rng.standard_normal((n_loc, t)))
        f = jax.jit(lambda a: a.T @ a)
        _, us = timed(f, z)
        coll = t_collective(4096, t, bw) * 1e6
        rows.append(row(f"fig3_4/t{t}", us, f"allreduce_model_us={coll:.1f}"))
    return rows


# ---------------------------------------------------------------- Fig 3.5
def fig3_5_models():
    """Max-rate vs postal p2p models for Example 2.1."""
    from repro.core.models import t_standard, t_standard_postal

    bw = _machines()["bw"]
    rows = []
    for t in (5, 20):
        for p in P_VALUES:
            g = comm_stats("example", p, 16)
            mr = t_standard(g, t, bw)
            po = t_standard_postal(g, t, bw)
            rows.append(row(f"fig3_5/p{p}_t{t}_model", mr * 1e6, f"maxrate/postal={mr/po:.2f}"))
    return rows


# ----------------------------------------------------------------- Table 2
def table2_multistep():
    """Modeled multistep p2p share vs standard share of one ECG iteration."""
    from repro.core.models import t_ecg_iteration
    from repro.core.ecg import ECGOperationCounts

    bw = _machines()["bw"]
    gmat, blk = example_graph()
    n_rows, nnz = gmat.shape[0] * blk, gmat.nnz * blk * blk
    rows = []
    for p in P_VALUES:
        g = comm_stats("example", p, 16)
        for t in T_VALUES:
            counts = ECGOperationCounts(n=n_rows, nnz=nnz, p=p, t=t)
            std = t_ecg_iteration(g, counts, bw, "standard")
            for strat, label in (("2step", "a"), ("3step", "b")):
                ms = t_ecg_iteration(g, counts, bw, strat)
                rows.append(
                    row(
                        f"table2{label}/p{p}_t{t}_model",
                        ms.total * 1e6,
                        f"ms%={ms.p2p_fraction*100:.1f};std%={std.p2p_fraction*100:.1f}",
                    )
                )
    return rows


# ---------------------------------------------------------------- Fig 4.2
def fig4_2_message_sizes():
    """Inter-node message size distribution, 2-step vs 3-step (p=4096, t=20)."""
    g = comm_stats("example", 4096, 16)
    f = 8
    t = 20
    two = [r * t * f * g.row_block for d in g.rows_to_node for r in d.values()]
    three = [r * t * f * g.row_block for r in g.node_pair_rows.values()]
    rows = [
        row("fig4_2/2step_max", 0.0, max(two)),
        row("fig4_2/2step_mean", 0.0, int(np.mean(two))),
        row("fig4_2/2step_nmsgs", 0.0, len(two)),
        row("fig4_2/3step_max", 0.0, max(three)),
        row("fig4_2/3step_mean", 0.0, int(np.mean(three))),
        row("fig4_2/3step_nmsgs", 0.0, len(three)),
    ]
    return rows


# ------------------------------------------------------------ Fig 4.4/4.5
def fig4_4_suite_speedup():
    """2-/3-step speedup over standard across SuiteSparse surrogates."""
    from repro.core.models import t_p2p

    bw = _machines()["bw"]
    rows = []
    for name in SUITE:
        for p in (1024, 4096, 8192):
            g = comm_stats(name, p, 16)
            for t in (5, 20):
                std = t_p2p(g, t, bw, "standard")
                for strat in ("2step", "3step"):
                    sp = std / t_p2p(g, t, bw, strat)
                    rows.append(
                        row(f"fig4_4/{name}_p{p}_t{t}_{strat}_model", t_p2p(g, t, bw, strat) * 1e6,
                            f"speedup={sp:.2f}")
                    )
    return rows


# ------------------------------------------------------------ Fig 4.6/4.7
def fig4_6_4_7_curves():
    """Ping (socket/node/network) and split-send model curves, BW + Lassen."""
    from repro.core.models import ping_time, split_send_time

    rows = []
    for mname, m in _machines().items():
        for nbytes in (1e3, 1e4, 1e5, 1e6):
            for where in ("socket", "node", "network"):
                t = ping_time(m, nbytes, where, active=1)
                rows.append(row(f"fig4_6/{mname}_{where}_{int(nbytes)}B_model", t * 1e6, ""))
            t1 = ping_time(m, nbytes, "network", active=1)
            tsplit = split_send_time(m, nbytes, m.ppn)
            rows.append(
                row(f"fig4_7/{mname}_split{m.ppn}_{int(nbytes)}B_model", tsplit * 1e6,
                    f"speedup={t1/tsplit:.2f}")
            )
    return rows


# ---------------------------------------------------------------- Fig 4.9
def fig4_9_optimal():
    """Nodal-optimal speedup over standard (no tuning reduction)."""
    from repro.core.models import t_p2p

    rows = []
    for mname, m in _machines().items():
        for name in SUITE:
            for p in (4096, 8192):
                g = comm_stats(name, p, 16)
                for t in (5, 20):
                    std = t_p2p(g, t, m, "standard")
                    opt = t_p2p(g, t, m, "optimal")
                    rows.append(
                        row(f"fig4_9/{mname}_{name}_p{p}_t{t}_model", opt * 1e6,
                            f"speedup={std/opt:.2f}")
                    )
    return rows


# ------------------------------------------------------ Fig 4.10 + Table 4
def fig4_10_table4_tuned():
    """Tuned (best-of-4) speedup over standard + ECG p2p share (Table 4)."""
    from repro.core.models import tune_strategy, t_ecg_iteration
    from repro.core.ecg import ECGOperationCounts

    gmat, blk = example_graph()
    n_rows, nnz = gmat.shape[0] * blk, gmat.nnz * blk * blk
    rows = []
    for mname, m in _machines().items():
        # Fig 4.10: suite speedups with tuning
        for name in SUITE:
            g = comm_stats(name, 4096, 16)
            for t in (5, 20):
                best, times = tune_strategy(g, t, m)
                sp = times["standard"] / times[best]
                rows.append(
                    row(f"fig4_10/{mname}_{name}_t{t}_model", times[best] * 1e6,
                        f"best={best};speedup={sp:.2f}")
                )
        # Table 4: ECG iteration share with tuned p2p for Example 2.1
        for p in P_VALUES:
            g = comm_stats("example", p, 16)
            for t in T_VALUES:
                counts = ECGOperationCounts(n=n_rows, nnz=nnz, p=p, t=t)
                best, _ = tune_strategy(g, t, m)
                ms = t_ecg_iteration(g, counts, m, best)
                std = t_ecg_iteration(g, counts, m, "standard")
                rows.append(
                    row(f"table4/{mname}_p{p}_t{t}_model", ms.total * 1e6,
                        f"ms%={ms.p2p_fraction*100:.1f};std%={std.p2p_fraction*100:.1f};best={best}")
                )
    return rows


# --------------------------------------------------- kernels (real timing)
def kernels_local():
    """Measured local kernels: SpMBV, fused vs unfused gram, fused tail.

    Delegates to :func:`repro.analysis.ecg_bench.kernel_vs_oracle` (the same
    harness the multi-device ``benchmarks/kernel_sweep.py`` uses; it runs
    here at the paper's t values on a single device).
    """
    from repro.analysis.ecg_bench import kernel_vs_oracle

    return [
        row(r["name"].replace("kernel/", "kernels/"), r["us"], r["derived"])
        for r in kernel_vs_oracle(ts=(5, 20), repeats=3)
    ]


ALL = [
    fig3_2_convergence,
    fig3_3_breakdown,
    fig3_4_inner_product,
    fig3_5_models,
    table2_multistep,
    fig4_2_message_sizes,
    fig4_4_suite_speedup,
    fig4_6_4_7_curves,
    fig4_9_optimal,
    fig4_10_table4_tuned,
    kernels_local,
]
