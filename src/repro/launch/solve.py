"""ECG solve driver (single- or multi-device) on the ECGSolver handle API.

    PYTHONPATH=src python -m repro.launch.solve --matrix dg --t 8 \
        --strategy tuned [--devices 8] [--backend pallas] [--tune model] \
        [--adaptive reduce] [--t auto] [--method sstep --s 4]

The driver builds one :class:`repro.solver.ECGSolver` session — partition,
exchange plan, autotuning, t-selection, and Block-ELL conversion happen
once — then solves (the timed call reuses the compiled loop; a second RHS
would pay zero retraces).

--backend pallas routes the SpMBV through the Block-ELL Pallas kernel and
the gram/tail updates through the fused kernels (oracles on CPU).

--tune model (the default with --strategy tuned) hands strategy, Block-ELL
tile shape, and blocking-vs-overlap to the setup-time autotuner
(repro.tune); --tune measure calibrates with microbenchmarks on the real
mesh instead of the models; --tune off keeps the explicit --strategy /
--ell-block / --overlap flags.

--t auto picks the enlarging factor from the iterations-vs-cost model
(repro.adaptive.select_t) — it composes the tuner's per-iteration cost with
probe-calibrated convergence rates, so it requires the cost models and is
rejected together with an explicit --tune off.  --adaptive enables the
in-solve width controller (rank-revealing breakdown safety, flexible-ECG
stagnation drops, optional plateau restart); the run summary prints the
chosen t and every reduction event.
"""

from __future__ import annotations

import argparse
import os
import sys
import time


def _parse_t(value: str) -> int | str:
    if value == "auto":
        return "auto"
    try:
        t = int(value)
    except ValueError:
        raise argparse.ArgumentTypeError(f"--t must be a positive int or 'auto', got {value!r}")
    if t < 1:
        raise argparse.ArgumentTypeError(f"--t must be >= 1, got {t}")
    return t


def _print_adaptive_summary(res) -> None:
    """Chosen t, selection table, and reduction events for the run summary."""
    if res.selection is not None:
        print(res.selection.summary())
    events = res.reduction_events()
    if events:
        for k, before, after in events:
            kind = "re-enlarged" if after > before else "reduced"
            print(f"  iter {k}: active width {kind} {before} -> {after}")
        if res.restarts:
            print(f"  restarts: {res.restarts}")
    elif res.active_hist is not None:
        print(f"  active width constant at t={res.t}")
    if res.comm_segments and len(res.comm_segments) > 1:
        trace = ", ".join(f"{it} iters @ width {w}" for w, it in res.comm_segments)
        print(f"  exchange payload re-sliced: {trace}")
    if res.breakdown:
        print("  BREAKDOWN: solver stopped at the last finite iterate")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--matrix", default="dg", choices=["dg", "fd", "random"])
    ap.add_argument("--elements", type=int, default=16)
    ap.add_argument("--block", type=int, default=16)
    ap.add_argument("--t", type=_parse_t, default=8,
                    help="enlarging factor, or 'auto' to pick it from the "
                         "iterations-vs-cost model")
    ap.add_argument("--tol", type=float, default=1e-8)
    ap.add_argument("--strategy", default="tuned",
                    choices=["sequential", "standard", "2step", "3step", "optimal", "tuned"])
    ap.add_argument("--devices", type=int, default=0, help="force host devices (re-execs)")
    ap.add_argument("--ppn", type=int, default=4)
    ap.add_argument("--backend", default="jnp", choices=["jnp", "pallas"])
    ap.add_argument("--overlap", action="store_true",
                    help="hide halo exchange behind interior SpMBV compute")
    ap.add_argument("--ell-block", type=int, default=8, help="Block-ELL tile size")
    ap.add_argument("--tune", default=None,
                    choices=["model", "model:structural", "measure", "off"],
                    help="autotune strategy/tile/overlap (default: model when "
                         "--strategy tuned or --t auto, else off; "
                         "model:structural ranks strategies by the executor-"
                         "structural cost — plan dispatches + moved bytes — "
                         "the right model on host/TPU backends)")
    ap.add_argument("--adaptive", default=None,
                    choices=["off", "rankrev", "reduce", "reduce+restart"],
                    help="in-solve width controller: breakdown-safe rank "
                         "reveal / flexible-ECG reduction / plateau restart "
                         "(default: off, except --t auto implies rankrev; an "
                         "explicit 'off' is honored even with --t auto)")
    ap.add_argument("--method", default="classic",
                    choices=["classic", "pipelined", "sstep"],
                    help="iteration scheme: classic two-psum ECG, pipelined "
                         "(packed Gram psum overlapped with the SpMBV "
                         "exchange), or sstep (--s inner steps per psum pair)")
    ap.add_argument("--s", type=int, default=1,
                    help="s-step depth: inner iterations per collective pair "
                         "(sstep only)")
    ap.add_argument("--reorth", action="store_true",
                    help="sstep only: per-block Cholesky-QR2 second pass "
                         "(one extra psum per block) for tougher spectra")
    ap.add_argument("--precondition", default="none",
                    choices=["none", "block_jacobi", "chebyshev", "inexact"],
                    help="preconditioner: rank-local block-Jacobi, Chebyshev "
                         "polynomial, or the iteration-varying inexact kind "
                         "(flexible ECG; classic reseeds the residual, "
                         "incompatible with --method pipelined)")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="write a trace of the run: *.json = Chrome/Perfetto "
                         "trace (open in chrome://tracing or ui.perfetto.dev), "
                         "*.jsonl = append-only event log")
    args = ap.parse_args()
    if args.method == "pipelined" and args.precondition == "inexact":
        ap.error("--precondition inexact needs the flexible residual reseed, "
                 "which --method pipelined cannot absorb into its AZ "
                 "recurrence; use --method classic or sstep, or a fixed "
                 "preconditioner")
    if args.method != "sstep":
        if args.s != 1:
            ap.error(f"--s {args.s} only applies to --method sstep")
        if args.reorth:
            ap.error("--reorth only applies to --method sstep")
    if args.t == "auto" and args.tune == "off":
        ap.error("--t auto composes the tuner's cost models and cannot run "
                 "with --tune off; use --tune model (or --tune measure — the "
                 "t ranking itself is always model-based, measured "
                 "calibration applies to the operator tuning)")
    if args.t == "auto" and args.tune == "measure":
        print("note: --t auto ranks candidates with the model-mode cost; "
              "--tune measure calibrates the distributed operator tuning only")
    if args.tune is None:
        args.tune = "model" if (args.strategy == "tuned" or args.t == "auto") else "off"

    if args.devices and "--xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
        os.environ["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={args.devices}"
        os.execv(sys.executable, [sys.executable, "-m", "repro.launch.solve"] + sys.argv[1:])

    import jax

    jax.config.update("jax_enable_x64", True)
    import numpy as np
    import jax.numpy as jnp

    tracer = None
    if args.trace:
        # install as the ambient tracer: the solver build/solve spans and
        # counters flow to the sink without threading the handle through
        from repro.observe import Tracer, open_sink, set_tracer

        tracer = Tracer(sinks=[open_sink(args.trace)])
        set_tracer(tracer)

    def _close_trace():
        if tracer is not None:
            tracer.close()
            print(f"# trace written to {args.trace}")

    from repro.sparse import dg_laplace_2d, fd_laplace_2d, random_spd, csr_spmbv
    from repro.core.cg import _cg_solve
    from repro.core.machines import TPU_V5E_POD
    from repro.core.methods import get_method
    from repro.solver import (
        AdaptiveConfig, CommConfig, ECGSolver, KernelConfig, MethodConfig,
        SolverConfig, TuneConfig,
    )

    a = {
        "dg": lambda: dg_laplace_2d((args.elements, args.elements), block=args.block),
        "fd": lambda: fd_laplace_2d(args.elements * 4),
        "random": lambda: random_spd(1024, density=0.02),
    }[args.matrix]()
    rng = np.random.default_rng(0)
    b = rng.standard_normal(a.shape[0])
    print(f"matrix: {a.shape[0]} rows, {a.nnz} nnz; t={args.t}")

    sequential = args.strategy == "sequential" or not args.devices
    if sequential and args.tune == "measure":
        print("note: measured tuning needs a device mesh; using the model "
              "for the sequential run")
        args.tune = "model"
    strategy = args.strategy if args.strategy not in ("sequential", "tuned") else "standard"
    config = SolverConfig(
        t=args.t,
        tol=args.tol,
        max_iters=5000,
        comm=CommConfig(
            strategy=strategy,
            overlap=args.overlap,
            machine=None if sequential else TPU_V5E_POD.with_ppn(args.ppn),
        ),
        kernel=KernelConfig(backend=args.backend, ell_block=args.ell_block),
        # None = solver defaults (auto-t turns on rankrev); explicit "off" sticks
        adaptive=AdaptiveConfig(policy=args.adaptive),
        tune=TuneConfig(mode=args.tune),
        method=MethodConfig(name=args.method, s=args.s, reorth=args.reorth),
        precondition=args.precondition,
    )
    if config.precondition.active:
        print(f"preconditioner: {config.precondition.kind}")
    coll = get_method(args.method).collectives_per_iteration(args.s, args.reorth)
    mtag = args.method + (f"[s={args.s}]" if args.method == "sstep" else "")
    print(f"method: {mtag} ({coll:g} psums/iter)")

    if sequential:
        solver = ECGSolver.build(a, config=config, b=b)
        if solver.tuned is not None:
            print(f"tuned tile: {solver.tuned.ell_block} kmax={solver.tuned.kmax}")
        t0 = time.time()
        res = solver.solve(b)
        print(f"sequential ECG[{mtag}/{args.backend}] t={res.t}: iters={res.n_iters} "
              f"converged={res.converged} {time.time()-t0:.1f}s")
        _print_adaptive_summary(res)
        res_cg = _cg_solve(lambda v: csr_spmbv(a, v[:, None])[:, 0], jnp.asarray(b), tol=args.tol, max_iters=20000)
        print(f"reference CG:  iters={res_cg.n_iters}")
        _close_trace()
        return

    n_dev = len(jax.devices())
    mesh = jax.make_mesh((n_dev // args.ppn, args.ppn), ("node", "proc"))
    t0 = time.time()
    solver = ECGSolver.build(a, mesh, config, b=b)
    res = solver.solve(b)
    if solver.tuned is not None:
        cfg = solver.tuned
        strategy = cfg.strategy
        print(f"tuned[{cfg.mode}]: strategy={cfg.strategy} tile={cfg.ell_block} "
              f"kmax={cfg.kmax} overlap={cfg.overlap} col_split={cfg.col_split}")
        if "p2p" in cfg.predicted:
            print("  p2p model:",
                  {k: f"{v*1e6:.0f}us" for k, v in cfg.predicted["p2p"].items()})
    x = solver.unshard(res.x)
    relres = np.linalg.norm(np.asarray(a.todense(), np.float64) @ x - b) / np.linalg.norm(b) \
        if a.shape[0] <= 8192 else float("nan")
    print(
        f"distributed ECG[{mtag}/{strategy}/{args.backend}"
        f"{'/overlap' if solver.op.overlap else ''}] t={res.t} on {n_dev} devices: "
        f"iters={res.n_iters} converged={res.converged} relres={relres:.2e} "
        f"{time.time()-t0:.1f}s"
    )
    _print_adaptive_summary(res)
    _close_trace()


if __name__ == "__main__":
    main()
