"""Per-request column groups for packed (multi-RHS) enlarged solves.

Width packing coalesces k compatible right-hand sides into ONE enlarged
block solve of width ``k·t′``: request j owns the contiguous column slab
``[j·t′, (j+1)·t′)``.  The per-column residual invariant of the enlarged
splitting (each R column tracks its own share of its request's residual,
coupling enters only through the shared search directions) means each
request's true residual is recoverable per iteration by summing its own
slab — which is what lets every request converge against its *own*
tolerance and retire independently.

This is the flexible-ECG license (Moufawad, arXiv:2305.19013): the
enlargement width may shrink mid-solve as long as retired directions are
zero-masked, which is exactly the adaptive machinery the solver already
carries for rank/stagnation drops.  A retired request's R *and* Z slabs
are zeroed — its X freezes at the retirement iterate (the c = PᵀR rows
feeding its X columns are zero from then on), its directions leave the
search space, and the width-compacted exchange stops paying its bytes.

:class:`GroupSpec` is the static (hashable) description the method
closures and the solver's jit cache key both consume.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class GroupSpec:
    """Static layout of a packed solve: ``n_groups`` requests × ``t_each``
    columns, each group converging against its own absolute tolerance.

    Hashable on purpose — it is part of the solver handle's runner/jit
    cache key, so two packs with the same (k, tolerances) layout reuse one
    compiled program.
    """

    t_each: int
    tols: tuple[float, ...]

    def __post_init__(self):
        if not isinstance(self.t_each, int) or self.t_each < 1:
            raise ValueError(f"t_each must be an int >= 1, got {self.t_each!r}")
        if not self.tols:
            raise ValueError("a packed solve needs at least one group")
        tols = tuple(float(t) for t in self.tols)
        if any(t <= 0 for t in tols):
            raise ValueError(f"group tolerances must be positive, got {tols}")
        object.__setattr__(self, "tols", tols)

    @property
    def n_groups(self) -> int:
        return len(self.tols)

    @property
    def width(self) -> int:
        """Total packed enlargement width k·t′."""
        return self.n_groups * self.t_each
