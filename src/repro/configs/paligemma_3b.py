"""paligemma-3b [vlm]: 18L d=2048 8H (MQA kv=1, d_head 256) d_ff=16384
vocab=257216 [arXiv:2407.07726].  SigLIP vision tower STUBBED: input_specs
provides precomputed patch embeddings (B, 256, D); prefix-LM mask over the
image prefix."""

from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="paligemma-3b",
    family="vlm",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,
    d_head=256,
    d_ff=16384,
    vocab=257216,
    mlp="swiglu",
    n_patches=256,
    tie_embeddings=True,
)

SMOKE = CONFIG.with_(
    name="paligemma-smoke", n_layers=2, d_model=128, n_heads=4, n_kv_heads=1,
    d_head=32, d_ff=256, vocab=512, n_patches=8, remat=False,
)

SHAPES = {
    "train_4k": "run",
    "prefill_32k": "run",
    "decode_32k": "run",
    "long_500k": "skip:pure full attention (DESIGN.md §Arch-applicability)",
}
