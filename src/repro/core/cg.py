"""Classical conjugate gradients — the paper's baseline method."""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass
class SolveResult:
    x: jax.Array
    n_iters: int
    res_hist: jax.Array  # (max_iters + 1,), padded with NaN past convergence
    converged: bool

    def __iter__(self):  # convenient unpacking
        return iter((self.x, self.n_iters, self.res_hist, self.converged))


def cg_solve(
    a_apply: Callable[[jax.Array], jax.Array],
    b: jax.Array,
    x0: jax.Array | None = None,
    tol: float = 1e-8,
    max_iters: int = 1000,
) -> SolveResult:
    """Solve A x = b with CG. ``a_apply`` is the (possibly distributed) SpMV."""
    x0 = jnp.zeros_like(b) if x0 is None else x0
    r0 = b - a_apply(x0)
    rn0 = jnp.linalg.norm(r0)
    hist0 = jnp.full((max_iters + 1,), jnp.nan, dtype=b.dtype).at[0].set(rn0)

    def cond(carry):
        _, r, _, _, k, rn, _ = carry
        return (rn > tol) & (k < max_iters)

    def body(carry):
        x, r, p, rz, k, _, hist = carry
        ap = a_apply(p)
        alpha = rz / (p @ ap)
        x = x + alpha * p
        r = r - alpha * ap
        rz_new = r @ r
        beta = rz_new / rz
        p = r + beta * p
        rn = jnp.sqrt(rz_new)
        hist = hist.at[k + 1].set(rn)
        return x, r, p, rz_new, k + 1, rn, hist

    x, r, p, rz, k, rn, hist = jax.lax.while_loop(
        cond, body, (x0, r0, r0, r0 @ r0, jnp.int32(0), rn0, hist0)
    )
    return SolveResult(x=x, n_iters=int(k), res_hist=hist, converged=bool(rn <= tol))
