"""ECG-as-a-service: operator registry, warm-start cache, request batching.

The serving layer for the many-clients / few-operators regime — see
:mod:`repro.serve.server` for the model and ``docs/serve.md`` for the
lifecycle walkthrough.

    from repro.serve import ECGServer, ServeConfig
"""

from repro.serve.batching import RequestQueue, ServeOverloaded, Ticket, payload_key
from repro.serve.cache import WarmStartCache, config_digest, mesh_tag
from repro.serve.config import ServeConfig
from repro.serve.fingerprint import fingerprint_csr, operator_nbytes
from repro.serve.packing import (
    PackingConfig,
    WidthPacker,
    latency_percentiles,
    true_relres,
)
from repro.serve.registry import OperatorRegistry
from repro.serve.server import ECGServer

__all__ = [
    "ECGServer",
    "OperatorRegistry",
    "PackingConfig",
    "RequestQueue",
    "ServeConfig",
    "ServeOverloaded",
    "Ticket",
    "WarmStartCache",
    "WidthPacker",
    "config_digest",
    "fingerprint_csr",
    "latency_percentiles",
    "mesh_tag",
    "operator_nbytes",
    "payload_key",
    "true_relres",
]
