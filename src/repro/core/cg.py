"""Classical conjugate gradients — the paper's baseline method.

Plain CG *is* enlarged CG at t=1 (the splitting is the identity, the block
recurrences collapse to the scalar ones), so the standalone while-loop this
module used to carry is gone: :func:`_cg_solve` runs the classic method of
the pluggable ECG engine at width 1 and inherits its breakdown guard.  Only
:class:`SolveResult` (the result type every solver returns) and
:func:`_guarded_while` (the breakdown-guarded loop the engine drives) live
here.
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Callable

import jax
import jax.numpy as jnp

#: ``SolveResult.event_hist`` bitmask values.
EV_RECOVERY = 1  # rank-revealing factorization dropped live directions
EV_RESEED = 2    # flexible restart reseeded Z from the preconditioned residual

#: event-bit -> human-readable code name (the ``iter_trace`` spelling)
EVENT_NAMES = {EV_RECOVERY: "recovery", EV_RESEED: "reseed"}


@dataclasses.dataclass
class SolveResult:
    x: jax.Array
    n_iters: int
    res_hist: jax.Array  # (max_iters + 1,), padded with NaN past convergence
    converged: bool
    # --- breakdown / adaptive metadata (defaults keep old call sites valid)
    breakdown: bool = False          # a non-finite iterate was produced; the
    #                                  state (x, residual norm) froze at the
    #                                  last finite iteration instead of NaNs
    t: int | None = None             # enlarging factor used (ECG; via t="auto")
    active_hist: jax.Array | None = None  # (max_iters + 1,) active block width
    #                                  per iteration — the reduction trace
    #                                  (adaptive ECG only, -1 past the end)
    restarts: int = 0                # re-enlarge events (adaptive ECG)
    selection: object = None         # TSelection when t was chosen by "auto"
    comm_segments: list | None = None  # [(exchange width, iterations)] per
    #                                  width segment of the re-sliced solve
    #                                  (width-aware distributed ECG only)
    event_hist: jax.Array | None = None  # (max_iters + 1,) int32 event bitmask
    #                                  per iteration: EV_RECOVERY (the
    #                                  rank-revealing factorization dropped
    #                                  live directions — an in-flight
    #                                  breakdown recovery), EV_RESEED (the
    #                                  flexible restart reseeded the chain
    #                                  from the preconditioned residual).
    #                                  -1 past the recorded end; None when no
    #                                  tracked mechanism was active.
    pack: dict | None = None         # width-packing telemetry when this
    #                                  result came out of a packed multi-RHS
    #                                  solve (repro.serve width packing):
    #                                  total width, group layout, this
    #                                  request's group index/tolerance,
    #                                  retirement iteration, total packed
    #                                  iterations — None for solo solves
    final_carry: dict | None = dataclasses.field(default=None, repr=False)
    #                                ^ loop carry at exit — the resume handle
    #                                  the segmented solver threads between
    #                                  width segments

    def __iter__(self):  # convenient unpacking (historical 4-tuple)
        return iter((self.x, self.n_iters, self.res_hist, self.converged))

    def reduction_events(self) -> list[tuple[int, int, int]]:
        """[(iteration, width_before, width_after)] from the reduction trace
        — every iteration where the active block width changed.

        Scans the *full valid* trace (every entry >= 0) rather than slicing
        at ``n_iters``: the trace is -1-padded past the last recorded
        iteration, so the valid prefix **is** the recorded history and the
        events cannot depend on ``n_iters`` bookkeeping staying in lockstep
        with the history writes — in particular a width drop recorded on
        the final iteration (including a capped ``max_iters``-th one) is
        always reported.
        """
        if self.active_hist is None:
            return []
        import numpy as np

        h = np.asarray(self.active_hist).tolist()
        return [
            (k, h[k - 1], h[k])
            for k in range(1, len(h))
            if h[k] >= 0 and h[k - 1] >= 0 and h[k] != h[k - 1]
        ]

    def _event_iters(self, bit: int) -> list[int]:
        """Iterations whose event-bitmask entry carries ``bit`` (valid
        entries only — the trace is -1-padded past the recorded end, same
        full-valid-prefix convention as :meth:`reduction_events`)."""
        if self.event_hist is None:
            return []
        import numpy as np

        h = np.asarray(self.event_hist).tolist()
        return [k for k in range(len(h)) if h[k] >= 0 and int(h[k]) & bit]

    def recovery_events(self) -> list[int]:
        """Iterations where the rank-revealing factorization dropped live
        directions — the breakdown-recovery trace.  Classic/pipelined record
        a drop of the entering active width; s-step records every block
        whose mandatory safeguard rejected candidate basis columns (the
        monomial basis losing rank is the event the safeguard exists for)."""
        return self._event_iters(EV_RECOVERY)

    def reseed_events(self) -> list[int]:
        """Iterations where the flexible restart reseeded the direction
        chain from the preconditioned residual (classic + an
        iteration-varying preconditioner, every ``reseed``-th iteration)."""
        return self._event_iters(EV_RESEED)

    @property
    def n_recoveries(self) -> int:
        return len(self.recovery_events())

    @property
    def n_reseeds(self) -> int:
        return len(self.reseed_events())

    def iter_trace(self) -> list[dict]:
        """Structured per-iteration view over the recorded histories.

        One dict per *recorded* iteration ``k`` (including iteration 0,
        the initial residual)::

            dict(k, resnorm, active, events)

        ``resnorm`` is the residual norm, ``active`` the active block
        width (None when no reduction trace was recorded), ``events`` a
        tuple of event code names (``"recovery"`` / ``"reseed"``; empty
        when none fired or no mechanism was tracked).

        The valid prefix is the leading run of finite ``res_hist``
        entries: the history is NaN-padded past convergence — and, for a
        request out of a packed multi-RHS solve, past its *retirement*
        — so the rows stop exactly where this request's recorded history
        does, not at the shared loop's last iteration.  This is the
        tracer's solve-segment source (``repro.observe``).
        """
        import numpy as np

        hist = np.asarray(self.res_hist, np.float64)
        finite = np.isfinite(hist)
        end = int(np.argmin(finite)) if not finite.all() else hist.size
        act = (
            None if self.active_hist is None
            else np.asarray(self.active_hist).tolist()
        )
        ev = (
            None if self.event_hist is None
            else np.asarray(self.event_hist).tolist()
        )
        rows = []
        for k in range(end):
            events = ()
            if ev is not None and k < len(ev) and ev[k] > 0:
                events = tuple(
                    name for bit, name in sorted(EVENT_NAMES.items())
                    if int(ev[k]) & bit
                )
            active = None
            if act is not None and k < len(act) and act[k] >= 0:
                active = int(act[k])
            rows.append(dict(
                k=k, resnorm=float(hist[k]), active=active, events=events,
            ))
        return rows


def _guarded_while(cond_extra, body_fn, init: dict):
    """``lax.while_loop`` with a breakdown guard.

    ``body_fn`` computes the next carry; if it produces a non-finite residual
    norm (singular Gram matrix, zero curvature, ...), the previous — last
    finite — carry is kept and the ``bd`` flag is raised, terminating the
    loop.  The returned state is therefore always finite, and callers report
    ``breakdown=True`` with the last finite residual instead of NaN garbage.
    """

    def cond(carry):
        return (~carry["bd"]) & cond_extra(carry)

    def body(carry):
        new = body_fn(carry)
        ok = jnp.isfinite(new["rn"])
        merged = jax.tree_util.tree_map(
            lambda old, cur: jnp.where(ok, cur, old), carry, new
        )
        merged["bd"] = carry["bd"] | ~ok
        return merged

    init = dict(init, bd=~jnp.isfinite(init["rn"]))
    return jax.lax.while_loop(cond, body, init)


def _cg_solve(
    a_apply: Callable[[jax.Array], jax.Array],
    b: jax.Array,
    x0: jax.Array | None = None,
    tol: float = 1e-8,
    max_iters: int = 1000,
) -> SolveResult:
    """Plain CG = the classic ECG method at t=1 (internal spelling).

    ``a_apply`` is the (possibly distributed) *vector* SpMV — it is adapted
    to the engine's width-1 block shape here.  The t=1 Gram matrix is the
    1×1 curvature pᵀAp, so the engine's breakdown guard subsumes the old
    zero-curvature guard.
    """
    from repro.core.ecg import _ecg_solve  # lazy: ecg imports this module

    res = _ecg_solve(
        lambda v_block: a_apply(v_block[:, 0])[:, None],
        b, 1, x0=x0, tol=tol, max_iters=max_iters,
    )
    return dataclasses.replace(res, t=None)  # plain CG has no enlarging factor


def cg_solve(
    a_apply: Callable[[jax.Array], jax.Array],
    b: jax.Array,
    x0: jax.Array | None = None,
    tol: float = 1e-8,
    max_iters: int = 1000,
) -> SolveResult:
    """Solve A x = b with CG. ``a_apply`` is the (possibly distributed) SpMV.

    .. deprecated::
        Plain CG is enlarged CG at t=1; use the engine directly — a
        :class:`repro.solver.ECGSolver` handle with ``SolverConfig(t=1)``
        (compile-once / solve-many), or this one-shot shim.
    """
    warnings.warn(
        "cg_solve() now runs the classic ECG method at t=1; build a "
        "repro.solver.ECGSolver handle with SolverConfig(t=1) instead",
        DeprecationWarning,
        stacklevel=2,
    )
    return _cg_solve(a_apply, b, x0=x0, tol=tol, max_iters=max_iters)
