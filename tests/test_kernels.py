"""Pallas kernels: interpret-mode shape/dtype sweeps against pure-jnp oracles."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.sparse import dg_laplace_2d, csr_to_bsr, random_spd
from repro.kernels.bsr_spmbv.kernel import bsr_spmbv_pallas
from repro.kernels.bsr_spmbv.ref import bsr_spmbv_ref
from repro.kernels.bsr_spmbv.ops import bsr_to_block_ell
from repro.kernels.fused_gram.kernel import fused_gram_pallas
from repro.kernels.fused_gram.ref import fused_gram_ref
from repro.kernels.block_update.kernel import block_update_pallas, ecg_tail_pallas
from repro.kernels.block_update.ref import block_update_ref, ecg_tail_ref


def tol_for(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(rtol=2e-5, atol=2e-5)


class TestBsrSpmbv:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("blk,t", [(8, 1), (8, 4), (16, 8), (8, 20)])
    def test_against_ref_and_dense(self, rng, blk, t, dtype):
        a = dg_laplace_2d((4, 3), block=blk, dtype=jnp.float32)
        b = csr_to_bsr(a, blk, blk)
        blocks, indices = bsr_to_block_ell(b)
        blocks = blocks.astype(dtype)
        v = jnp.asarray(rng.standard_normal((b.shape[1], t)), dtype)
        w_ref = bsr_spmbv_ref(blocks, indices, v)
        w_pal = bsr_spmbv_pallas(blocks, indices, v, interpret=True)
        np.testing.assert_allclose(
            np.asarray(w_pal, np.float32), np.asarray(w_ref, np.float32), **tol_for(dtype)
        )
        if dtype == jnp.float32:
            ad = np.asarray(a.todense(), np.float64)
            np.testing.assert_allclose(
                np.asarray(w_pal, np.float64)[: a.shape[0]],
                ad @ np.asarray(v, np.float64),
                rtol=1e-4, atol=1e-4,
            )

    def test_irregular_block_rows(self, rng):
        """Rows with differing tile counts exercise the zero-padding path."""
        a = random_spd(48, density=0.15, seed=9)
        b = csr_to_bsr(a, 4, 4)
        blocks, indices = bsr_to_block_ell(b)
        per_row = np.diff(np.asarray(b.block_indptr))
        assert per_row.min() != per_row.max(), "want irregular structure"
        v = jnp.asarray(rng.standard_normal((b.shape[1], 3)), jnp.float32)
        w_pal = bsr_spmbv_pallas(blocks.astype(jnp.float32), indices, v, interpret=True)
        ad = np.asarray(a.todense(), np.float64)
        np.testing.assert_allclose(
            np.asarray(w_pal, np.float64)[:48], ad @ np.asarray(v, np.float64), rtol=1e-4, atol=1e-4
        )


class TestFusedGram:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("n,t,block_rows", [(64, 4, 16), (200, 5, 64), (1000, 20, 256), (37, 3, 8)])
    def test_against_ref(self, rng, n, t, block_rows, dtype):
        mats = [jnp.asarray(rng.standard_normal((n, t)), dtype) for _ in range(4)]
        got = fused_gram_pallas(*mats, block_rows=block_rows, interpret=True)
        want = fused_gram_ref(*mats)
        assert got.shape == (t, 3 * t)
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want, np.float32),
            rtol=3e-2 if dtype == jnp.bfloat16 else 1e-4,
            atol=(3e-1 if n >= 1000 else 1e-1) if dtype == jnp.bfloat16 else 1e-3,
        )


class TestBlockUpdate:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("n,t,block_rows", [(64, 4, 16), (130, 7, 32), (512, 20, 128)])
    def test_against_ref(self, rng, n, t, block_rows, dtype):
        x, r, p, ap = (jnp.asarray(rng.standard_normal((n, t)), dtype) for _ in range(4))
        c = jnp.asarray(rng.standard_normal((t, t)), dtype)
        xo, ro = block_update_pallas(x, r, p, ap, c, block_rows=block_rows, interpret=True)
        xw, rw = block_update_ref(x, r, p, ap, c)
        np.testing.assert_allclose(np.asarray(xo, np.float32), np.asarray(xw, np.float32), **tol_for(dtype))
        np.testing.assert_allclose(np.asarray(ro, np.float32), np.asarray(rw, np.float32), **tol_for(dtype))


# ---------------------------------------------------------------------------
# hot-path sweeps: interpret-mode Pallas vs oracle over {f32, f64} x t {2,4,8}
# (the dtypes and widths the solver backend switch actually runs)
# ---------------------------------------------------------------------------
SWEEP_DTYPES = [jnp.float32, jnp.float64]
SWEEP_T = [2, 4, 8]


def sweep_tol(dtype):
    return dict(rtol=1e-12, atol=1e-12) if dtype == jnp.float64 else dict(rtol=2e-5, atol=2e-5)


class TestHotPathSweeps:
    @pytest.mark.parametrize("dtype", SWEEP_DTYPES)
    @pytest.mark.parametrize("t", SWEEP_T)
    def test_bsr_spmbv_sweep(self, rng, t, dtype):
        a = dg_laplace_2d((4, 3), block=8, dtype=jnp.float32)
        blocks, indices = bsr_to_block_ell(csr_to_bsr(a, 8, 8))
        blocks = blocks.astype(dtype)
        v = jnp.asarray(rng.standard_normal((a.shape[1], t)), dtype)
        got = bsr_spmbv_pallas(blocks, indices, v, interpret=True)
        want = bsr_spmbv_ref(blocks, indices, v)
        assert got.dtype == dtype
        np.testing.assert_allclose(
            np.asarray(got, np.float64), np.asarray(want, np.float64), **sweep_tol(dtype)
        )

    @pytest.mark.parametrize("dtype", SWEEP_DTYPES)
    @pytest.mark.parametrize("t", SWEEP_T)
    def test_fused_gram_sweep(self, rng, t, dtype):
        mats = [jnp.asarray(rng.standard_normal((300, t)), dtype) for _ in range(4)]
        got = fused_gram_pallas(*mats, block_rows=64, interpret=True)
        want = fused_gram_ref(*mats)
        assert got.shape == (t, 3 * t) and got.dtype == dtype
        np.testing.assert_allclose(
            np.asarray(got, np.float64), np.asarray(want, np.float64),
            **(dict(rtol=1e-12, atol=1e-11) if dtype == jnp.float64
               else dict(rtol=1e-4, atol=1e-3)),
        )

    @pytest.mark.parametrize("dtype", SWEEP_DTYPES)
    @pytest.mark.parametrize("t", SWEEP_T)
    def test_ecg_tail_sweep(self, rng, t, dtype):
        n = 210
        x, r, p, ap, po = (
            jnp.asarray(rng.standard_normal((n, t)), dtype) for _ in range(5)
        )
        c, d, do = (jnp.asarray(rng.standard_normal((t, t)), dtype) for _ in range(3))
        got = ecg_tail_pallas(x, r, p, ap, po, c, d, do, block_rows=64, interpret=True)
        want = ecg_tail_ref(x, r, p, ap, po, c, d, do)
        for g, w in zip(got, want):
            assert g.shape == (n, t) and g.dtype == dtype
            np.testing.assert_allclose(
                np.asarray(g, np.float64), np.asarray(w, np.float64), **sweep_tol(dtype)
            )
