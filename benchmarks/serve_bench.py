"""Serving-layer benchmark: batched throughput, warm-start latency, hit rate.

    PYTHONPATH=src python benchmarks/serve_bench.py [--smoke] [--json PATH]
                                                    [--check BASELINE]

Three phases over the standard synthetic trace (32 single-RHS requests in
shuffled arrival order across 3 operators, 8 duplicate payloads — the
same generator as ``repro.launch.serve``):

* **warm-start restart** — a ``t="auto"`` server registers the three
  operators cold (probes + selection paid, outcome persisted to the
  warm-start cache), then a second server on the same cache directory
  simulates the restart: every build must load its tuning from disk
  (``warm_retunes == 0``) and the summed build latency must drop ≥ 5×.
* **throughput** — the trace replayed through (a) a *sequential* server
  (``max_batch=1``, dedup off: one dispatch per request) and (b) a
  *batched* server (per-operator coalescing + dedup + pipelined
  dispatch).  Both are compile-warmed first; best-of-``--repeats`` wall
  time.  Gate: batched requests/s ≥ sequential.
* **bit-identity** — every batched result must equal a solo
  ``ECGSolver.solve`` of the same request bit-for-bit.

``--check BASELINE`` is the CI gate against the committed
``BENCH_serve.json``: the deterministic counters (registry hits/misses,
dedup shares, batch layout, warm retunes, bit-identity) must match the
baseline exactly — they are pure functions of the trace, independent of
machine speed.  Wall-clock numbers are informational except for the two
ratio gauges above, which compare a run against itself.

``--smoke`` shrinks the operators and skips repeat timing; the trace
structure (and therefore every checked counter) is identical to the full
run.
"""

import argparse
import json
import sys
import tempfile
import time


def register_all(server, ops):
    """Force-register every operator; returns the build records."""
    for _, a in ops:
        server.registry.get(a)
    return server.registry.stats()


def replay_sequential(server, ops, trace):
    for op_i, b in trace:
        server.solve(ops[op_i][1], b)


def replay_batched(server, ops, trace):
    tickets = [server.submit(ops[op_i][1], b) for op_i, b in trace]
    server.flush()
    return tickets


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="small operators for CI")
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--dups", type=int, default=8)
    ap.add_argument("--repeats", type=int, default=None,
                    help="timed replays per mode (best-of); default 3, 1 smoke")
    ap.add_argument("--json", default="BENCH_serve.json")
    ap.add_argument("--check", metavar="BASELINE", default=None,
                    help="fail unless deterministic counters match this JSON")
    args = ap.parse_args()
    repeats = args.repeats or (1 if args.smoke else 3)
    scale = 4 if args.smoke else 8

    import jax

    jax.config.update("jax_enable_x64", True)

    import numpy as np

    from repro.launch.serve import build_trace
    from repro.serve import ECGServer, ServeConfig
    from repro.solver import ECGSolver, SolverConfig

    ops, trace = build_trace(args.requests, args.dups, scale)
    print(f"# serve bench: {len(trace)} requests / {len(ops)} operators "
          f"({', '.join(f'{n}={a.shape[0]}' for n, a in ops)}), "
          f"{args.dups} dups" + (" [smoke]" if args.smoke else ""))

    # ---- phase 1: cold vs warm builds through the warm-start cache
    auto_solver = SolverConfig(t="auto", tol=1e-8)
    with tempfile.TemporaryDirectory() as cache_dir:
        cfg_auto = ServeConfig(solver=auto_solver, cache_dir=cache_dir)
        cold = register_all(ECGServer(cfg_auto), ops)
        warm = register_all(ECGServer(cfg_auto), ops)  # simulated restart
    cold_s = sum(r["build_s"] for r in cold["builds"])
    warm_s = sum(r["build_s"] for r in warm["builds"])
    warm_retunes = warm["cold_builds"]
    build_speedup = cold_s / warm_s if warm_s > 0 else float("inf")
    print(f"builds: cold {cold_s:.3f}s -> warm {warm_s:.3f}s "
          f"({build_speedup:.1f}x, {warm_retunes} re-tuned after restart)")

    # ---- phase 2: batched vs sequential throughput (fixed-t template)
    fixed = ServeConfig(solver=SolverConfig(t=4, tol=1e-8, adaptive="rankrev"))
    seq_server = ECGServer(fixed.replace(max_batch=1, dedup=False))
    bat_server = ECGServer(fixed)
    # compile-warm both (one solve per operator) so timing excludes traces
    for _, a in ops:
        b0 = np.zeros(a.shape[0])
        b0[0] = 1.0
        seq_server.solve(a, b0)
        bat_server.solve(a, b0)
    seq_wall = min(
        _timed(replay_sequential, seq_server, ops, trace) for _ in range(repeats)
    )
    bat_wall = min(
        _timed(replay_batched, bat_server, ops, trace) for _ in range(repeats)
    )
    seq_rps = len(trace) / seq_wall
    bat_rps = len(trace) / bat_wall
    print(f"throughput: sequential {seq_rps:.1f} req/s, "
          f"batched {bat_rps:.1f} req/s ({bat_rps / seq_rps:.2f}x)")

    # ---- phase 3: bit-identity of the batched trace vs solo solves
    bat_fresh = ECGServer(fixed)
    tickets = replay_batched(bat_fresh, ops, trace)
    solo = {name: ECGSolver.build(a, config=fixed.solver) for name, a in ops}
    bit_identical = True
    for (op_i, b), tk in zip(trace, tickets):
        name, a = ops[op_i]
        ref = solo[name].solve(b)
        same = (
            np.array_equal(np.asarray(tk.result.x), np.asarray(ref.x))
            and tk.result.n_iters == ref.n_iters
            and bool(tk.result.converged) == bool(ref.converged)
        )
        bit_identical = bit_identical and same
    st = bat_fresh.stats()
    reg, q = st["registry"], st["queue"]
    hit_rate = reg["hits"] / max(reg["hits"] + reg["misses"], 1)
    print(f"bit-identity vs solo solves: {bit_identical}; "
          f"registry hit rate {hit_rate:.2f}; "
          f"{q['batches']} batches {q['batch_sizes']}, "
          f"{q['dedup_shared']} dedup-shared")

    summary = dict(
        bit_identical=bool(bit_identical),
        batched_not_slower=bool(bat_rps >= seq_rps),
        warm_speedup_5x=bool(build_speedup >= 5.0),
        warm_retunes=int(warm_retunes),
    )
    out = dict(
        config=dict(
            requests=len(trace), dups=args.dups, operators={
                n: int(a.shape[0]) for n, a in ops
            }, scale=scale, repeats=repeats, smoke=args.smoke,
            max_batch=fixed.max_batch, t=4, auto_t_for_builds=True,
        ),
        builds=dict(
            cold_s=cold_s, warm_s=warm_s, speedup=build_speedup,
            cold=cold["builds"], warm=warm["builds"],
            warm_retunes=int(warm_retunes),
        ),
        throughput=dict(
            sequential_rps=seq_rps, batched_rps=bat_rps,
            ratio=bat_rps / seq_rps,
            sequential_wall_s=seq_wall, batched_wall_s=bat_wall,
        ),
        batched=dict(
            hits=reg["hits"], misses=reg["misses"], hit_rate=hit_rate,
            batches=q["batches"], batch_sizes=q["batch_sizes"],
            dedup_shared=q["dedup_shared"],
        ),
        summary=summary,
    )
    with open(args.json, "w") as f:
        json.dump(out, f, indent=2)
    print(f"summary: {json.dumps(summary)}")
    print(f"wrote {args.json}")

    failures = []
    if not summary["bit_identical"]:
        failures.append("batched results are not bit-identical to solo solves")
    if not summary["batched_not_slower"]:
        failures.append(
            f"batched throughput regressed below sequential "
            f"({bat_rps:.1f} < {seq_rps:.1f} req/s)"
        )
    if not summary["warm_speedup_5x"]:
        failures.append(
            f"warm-start build speedup {build_speedup:.1f}x < 5x"
        )
    if summary["warm_retunes"]:
        failures.append(
            f"{warm_retunes} operator(s) re-tuned after restart (want 0)"
        )
    if args.check:
        failures += check_counters(out, args.check)
        if not failures:
            print(f"counter gate OK vs {args.check}")
    if failures:
        print("SERVE GATE FAILED:", file=sys.stderr)
        for msg in failures:
            print(f"  {msg}", file=sys.stderr)
        sys.exit(1)


def _timed(fn, *args):
    t0 = time.perf_counter()
    fn(*args)
    return time.perf_counter() - t0


def check_counters(out: dict, baseline_path: str) -> list[str]:
    """Deterministic counters must match the committed baseline exactly."""
    with open(baseline_path) as f:
        base = json.load(f)
    failures = []
    for section, field in (
        ("config", "requests"), ("config", "dups"),
        ("batched", "hits"), ("batched", "misses"),
        ("batched", "batches"), ("batched", "batch_sizes"),
        ("batched", "dedup_shared"),
        ("builds", "warm_retunes"),
        ("summary", "bit_identical"),
    ):
        got, want = out[section][field], base[section][field]
        if got != want:
            failures.append(f"{section}.{field}: {got!r} != baseline {want!r}")
    return failures


if __name__ == "__main__":
    main()
