"""Reproduce the §4 communication study on SuiteSparse surrogates.

For each matrix: exact comm statistics at p=4096 (ppn=16), modeled times for
all four strategies on Blue Waters + Lassen, tuned winner (paper Fig 4.10).

    PYTHONPATH=src python examples/suite_study.py
"""

from repro.sparse.matrices import surrogate_graph, SUITE_MATRICES
from repro.sparse.partition import partition_csr
from repro.core.comm_graph import build_comm_graph
from repro.core.models import tune_strategy
from repro.core.machines import BLUE_WATERS, LASSEN


def main():
    p, ppn = 4096, 16
    names = ("audikw_1", "Geo_1438", "thermal2", "ldoor")
    for name in names:
        g, blk = surrogate_graph(name)
        pm = partition_csr(g, p)
        cg = build_comm_graph(pm, ppn=ppn, row_block=blk)
        spec = SUITE_MATRICES[name]
        print(f"\n{name}: {spec.rows} rows (surrogate {g.shape[0]*blk}), "
              f"{spec.nnz_per_row:.0f} nnz/row target")
        print(f"  m_std={cg.m_standard} m_proc->node={cg.m_proc_to_node} "
              f"m_node->node={cg.m_node_to_node} dedup={cg.total_standard_rows/max(cg.total_node_aware_rows,1):.2f}x")
        for mach in (BLUE_WATERS, LASSEN.with_ppn(ppn)):
            for t in (5, 20):
                best, times = tune_strategy(cg, t, mach)
                sp = times["standard"] / times[best]
                print(f"  {mach.name:10s} t={t:2d}: best={best:8s} speedup={sp:5.2f}x")


if __name__ == "__main__":
    main()
