"""The ECG server: registry + warm-start cache + request batching, one API.

:class:`ECGServer` is the session layer over
:class:`~repro.solver.ECGSolver` for the many-clients / few-operators
regime: requests name an operator by content (the CSR itself), the server
resolves it to an already-built, already-compiled session via the
:class:`~repro.serve.registry.OperatorRegistry`, coalesces pending
single-RHS requests per operator through the
:class:`~repro.serve.batching.RequestQueue`, and answers each with its
own :class:`~repro.core.cg.SolveResult` — bit-identical to a solo
``ECGSolver.solve`` of the same request (asserted in
``tests/test_serve.py``).

    from repro.serve import ECGServer, ServeConfig

    server = ECGServer(ServeConfig(cache_dir="/tmp/ecg-cache"))
    tk = server.submit(a, b)          # registers a on first sight
    server.flush()                    # dispatch pending batches
    x = server.solution(tk)           # global solution vector

The synchronous single-thread model is deliberate: dispatch order is
deterministic (submit order within operator groups), which is what makes
request traces replayable and the bit-identity guarantee testable.
"""

from __future__ import annotations

import numpy as np

from repro.serve.batching import RequestQueue, Ticket
from repro.serve.config import ServeConfig
from repro.serve.registry import OperatorRegistry


class ECGServer:
    """ECG-as-a-service session layer (see module docstring).

    config: a :class:`~repro.serve.ServeConfig` (or dict / None).
    mesh:   optional ``("node", "proc")`` device mesh — every registered
            session then runs the distributed node-aware solver.
    tracer: optional :class:`~repro.observe.Tracer` — threads through the
            registry (build spans, hit/miss counters), the queue (request
            lifecycle spans), and every registered solver session (build-
            phase and solve-segment spans).  None uses the ambient tracer
            (:func:`~repro.observe.get_tracer`), which is a no-op unless
            one was installed.
    """

    def __init__(self, config: ServeConfig | dict | None = None, mesh=None,
                 tracer=None):
        self.config = ServeConfig.coerce(config)
        self.mesh = mesh
        self.registry = OperatorRegistry(self.config, mesh=mesh,
                                         tracer=tracer)
        self.queue = RequestQueue(
            max_batch=self.config.max_batch,
            max_wait_s=self.config.max_wait_s,
            max_pending=self.config.max_pending,
            dedup=self.config.dedup,
            packing=self.config.packing,
            tracer=tracer,
        )

    # ------------------------------------------------------------ requests
    def submit(self, a, b, x0=None, tol=None) -> Ticket:
        """Enqueue one request; may dispatch eagerly.

        Registers (or resolves) the operator, enqueues the request, and —
        when a batch-closing trigger fires (an operator group reached
        ``max_batch`` distinct payloads / the pack capacity, or the oldest
        request aged past a deadline) — drains the queue before returning.
        Raises :class:`~repro.serve.ServeOverloaded` when ``max_pending``
        is hit.

        ``tol`` is a per-request absolute residual-norm tolerance and
        requires the width-packing policy (``ServeConfig(packing="width")``)
        — only a packed solve retires each request against its own
        tolerance; the dispatch-batched path solves every request to the
        session's configured tolerance.
        """
        if tol is not None and not self.config.packing.active:
            raise ValueError(
                "per-request tol requires the width-packing policy "
                "(ServeConfig(packing='width')); the dispatch-batched path "
                "solves every request to the session tolerance"
            )
        key, solver = self.registry.get(a)
        ticket = self.queue.submit(key, b, x0, solver=solver, tol=tol)
        if self.queue.due():
            self.flush()
        return ticket

    def flush(self) -> list[Ticket]:
        """Dispatch every pending request; returns them, all completed."""
        return self.queue.drain()

    def solve(self, a, b, x0=None):
        """Submit + dispatch one request; returns its ``SolveResult``.

        The convenience spelling for sequential traffic — batching across
        requests needs :meth:`submit`/:meth:`flush`.
        """
        ticket = self.submit(a, b, x0)
        return self.result(ticket)

    # ------------------------------------------------------------- results
    def result(self, ticket: Ticket):
        """The ticket's :class:`~repro.core.cg.SolveResult`, dispatching
        pending work first if needed."""
        if not ticket.done:
            self.flush()
        return ticket.result

    def solution(self, ticket: Ticket) -> np.ndarray:
        """Global (n,) solution vector of a request (unsharded on a
        distributed server)."""
        res = self.result(ticket)
        return ticket.solver.unshard(res.x)

    def stream_residuals(self, ticket: Ticket):
        """Yield the request's residual-norm history ``r_0 … r_k`` one
        float at a time (dispatches first if the request is pending).

        Iterating costs one host transfer up front, then pure host reads —
        the shape a chunked/streaming transport encoding wants.
        """
        res = self.result(ticket)
        hist = np.asarray(res.res_hist)
        for k in range(int(res.n_iters) + 1):
            yield float(hist[k])

    # --------------------------------------------------------------- state
    def stats(self) -> dict:
        """JSON-safe counters of both layers: registry hits/misses/
        evictions/builds and queue batches/dedup/backpressure."""
        return dict(
            registry=self.registry.stats(),
            queue=self.queue.stats(),
        )
