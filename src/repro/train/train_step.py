"""Train-step builder: loss + grad + AdamW, with microbatch accumulation.

The returned jitted function carries full in/out shardings so it can be
``.lower().compile()``'d on the production mesh from ShapeDtypeStructs alone
(the dry-run path) or executed for real at smoke scale.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.common import ArchConfig, MeshAxes
from repro.models.registry import model_api
from repro.train.optimizer import (
    AdamWConfig,
    apply_adamw,
    abstract_opt_state,
    init_opt_state,
    opt_state_specs,
)


@dataclasses.dataclass(frozen=True)
class TrainStepBundle:
    step_fn: Any                 # jit'd (params, opt_state, batch) -> (params, opt, metrics)
    param_shardings: Any
    opt_shardings: Any
    batch_shardings: Any
    abstract_params: Any
    abstract_opt: Any
    abstract_batch: Any


def build_train_step(
    cfg: ArchConfig,
    mesh: Mesh,
    opt_cfg: AdamWConfig | None = None,
    batch: int = 8,
    seq: int = 128,
    microbatches: int = 1,
    donate: bool = True,
) -> TrainStepBundle:
    opt_cfg = opt_cfg or AdamWConfig()
    api = model_api(cfg)
    axes = MeshAxes.from_mesh(mesh)
    loss = api.loss_fn(cfg, mesh)

    def step(params, opt_state, batch_data):
        if microbatches > 1:
            def micro(i, acc):
                mb = jax.tree.map(
                    lambda x: jax.lax.dynamic_slice_in_dim(
                        x, i * (x.shape[0] // microbatches), x.shape[0] // microbatches
                    ),
                    batch_data,
                )
                l, g = jax.value_and_grad(loss)(params, mb)
                return (
                    acc[0] + l / microbatches,
                    jax.tree.map(lambda a, b: a + b / microbatches, acc[1], g),
                )
            zero_g = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            l, grads = jax.lax.fori_loop(0, microbatches, micro, (jnp.float32(0), zero_g))
        else:
            l, grads = jax.value_and_grad(loss)(params, batch_data)
        new_params, new_opt, stats = apply_adamw(opt_cfg, params, grads, opt_state)
        metrics = dict(loss=l, **stats)
        return new_params, new_opt, metrics

    aparams = api.abstract_params(cfg)
    pspecs = api.param_specs(cfg, axes)
    aopt = abstract_opt_state(aparams)
    ospecs = opt_state_specs(pspecs, axes, aparams)
    binput = api.train_input_specs(cfg, mesh, batch, seq)
    abatch = {k: v[0] for k, v in binput.items()}
    bspecs = {k: v[1] for k, v in binput.items()}

    to_sh = lambda tree: jax.tree.map(
        lambda s: NamedSharding(mesh, s), tree, is_leaf=lambda s: isinstance(s, P)
    )
    p_sh, o_sh, b_sh = to_sh(pspecs), to_sh(ospecs), to_sh(bspecs)
    metric_sh = dict(
        loss=NamedSharding(mesh, P()),
        grad_norm=NamedSharding(mesh, P()),
        lr=NamedSharding(mesh, P()),
    )
    step_fn = jax.jit(
        step,
        in_shardings=(p_sh, o_sh, b_sh),
        out_shardings=(p_sh, o_sh, metric_sh),
        donate_argnums=(0, 1) if donate else (),
    )
    return TrainStepBundle(
        step_fn=step_fn,
        param_shardings=p_sh,
        opt_shardings=o_sh,
        batch_shardings=b_sh,
        abstract_params=aparams,
        abstract_opt=aopt,
        abstract_batch=abatch,
    )


def build_serve_step(cfg: ArchConfig, mesh: Mesh, batch: int, seq: int):
    """Decode-step bundle for the inference shape cells."""
    from repro.models.registry import serve_input_specs

    api = model_api(cfg)
    axes = MeshAxes.from_mesh(mesh)
    f = api.decode_step(cfg, mesh)
    aparams = api.abstract_params(cfg)
    pspecs = api.param_specs(cfg, axes)
    acache = api.abstract_cache(cfg, batch, seq)
    cspecs = api.cache_specs(cfg, axes, batch, seq)
    binput = serve_input_specs(cfg, mesh, batch)
    abatch = {k: v[0] for k, v in binput.items()}
    bspecs = {k: v[1] for k, v in binput.items()}

    to_sh = lambda tree: jax.tree.map(
        lambda s: NamedSharding(mesh, s), tree, is_leaf=lambda s: isinstance(s, P)
    )
    p_sh, c_sh, b_sh = to_sh(pspecs), to_sh(cspecs), to_sh(bspecs)
    axes_b = MeshAxes.from_mesh(mesh)
    import numpy as np

    bsz = int(np.prod([axes_b.size(a) for a in axes_b.batch]))
    logit_spec = P(axes_b.batch if batch % bsz == 0 else None, axes_b.tp(cfg.vocab_padded))
    step_fn = jax.jit(
        f,
        in_shardings=(p_sh, c_sh, b_sh),
        out_shardings=(NamedSharding(mesh, logit_spec), c_sh),
        donate_argnums=(1,),
    )
    return step_fn, dict(
        param_shardings=p_sh,
        cache_shardings=c_sh,
        batch_shardings=b_sh,
        abstract_params=aparams,
        abstract_cache=acache,
        abstract_batch=abatch,
    )
