"""Preconditioner scoreboard: iterations for every operator x M x scheme x t.

    PYTHONPATH=src python benchmarks/scoreboard.py [--smoke] [--json PATH]
                                                   [--check BASELINE]

The full grid crosses

* **operators** — every Table-3 ``suite_surrogate`` (small scale; these are
  the window-shuffled ones), the 3D Laplacian, the DG block operator, and
  the two ill-conditioned testbeds (``aniso_laplace_2d``,
  ``scaled_laplace_2d``);
* **preconditioners** — none / block_jacobi / chebyshev / inexact
  (pipelined x inexact is skipped: the config layer rejects the pairing —
  an iteration-varying M needs the flexible residual reseed, which the AZ
  recurrence cannot absorb);
* **methods** — classic / pipelined / sstep(s=2);
* **t** — 2 and 8.

Every row records iterations (and effective iterations for sstep),
convergence, breakdown, true relative residual, wall seconds for the
*second* (compile-free) solve, and the solve's event telemetry —
``recoveries`` (rank-revealing drops the solve recovered from: every
s-step block whose mandatory safeguard rejected candidate columns, and
every adaptive classic/pipelined iteration whose factorization lost live
width) and ``reseeds`` (flexible-restart firings of the inexact kind).  Unconverged rows are kept — the
scoreboard is honest about where a preconditioner does NOT pay
(Chebyshev's default ``eig_ratio`` misses the ~1e8 condition number of
the diagonally-scaled operator, for instance).  Block-Jacobi runs with
64-row blocks (four grid lines of the 2D operators): iterations — not
block-factor setup — are the tracked metric, and the library-default 32
leaves the s=2 monomial basis marginal on the anisotropic operator.

One scheme-specific wrinkle the gauges account for: the pipelined
recurrence's *attainable accuracy* floors out near ``κ(A)·u`` (its AZ
recurrence drifts from the true residual — cf. Cornelis–Cools–Vanroose),
so on the κ~1e8 scaled operator at ``tol=1e-8`` it stops in a
rank-deficiency breakdown with a true relres of ~1.3e-8 instead of
crossing tol.  Rows record ``breakdown``; a breakdown row whose true
relres is within ``2×tol`` counts as *floored*, not failed (classic and
s-step carry the true residual and do cross tol there).

Gates:

* ``--check BASELINE`` — CI regression gate against a committed
  ``BENCH_scoreboard.json``: fail if any matching row needs **>10% more
  iterations** than the baseline or flips converged -> unconverged.
  (Rows are deterministic — seeded RHS, fixed operators — so iteration
  counts are exactly reproducible; wall time is informational only.)
* summary flag ``precond_helps_ill`` (asserted in CI): block-Jacobi
  converges on the diagonally-scaled operator where unpreconditioned ECG
  does not, and both block-Jacobi and Chebyshev cut iterations on the
  anisotropic operator at the same method/t.

``--smoke`` shrinks the grid (3 operators, classic+sstep, t=2) for CI.
"""

import argparse
import json
import sys
import time


def build_operators(smoke: bool):
    """name -> CSRMatrix, sized so the full grid stays minutes, not hours."""
    from repro.sparse import (
        SUITE_MATRICES,
        aniso_laplace_2d,
        dg_laplace_2d,
        fd_laplace_3d,
        scaled_laplace_2d,
        suite_surrogate,
    )

    ill = {
        "aniso2d": aniso_laplace_2d(16, eps=0.01),
        "scaled2d": scaled_laplace_2d(16, decades=4.0, seed=0),
    }
    if smoke:
        # identical construction to the full grid so --check rows line up
        return {"thermal2": suite_surrogate("thermal2", scale=0.06), **ill}
    ops = {
        name: suite_surrogate(
            name, scale=0.06 if SUITE_MATRICES[name].block == 1 else 0.035
        )
        for name in sorted(SUITE_MATRICES)
    }
    ops["fd3d"] = fd_laplace_3d(8)
    ops["dg2d"] = dg_laplace_2d((8, 6), block=4)
    ops.update(ill)
    return ops


def run_grid(ops, schemes, cands, preconds, tol, max_iters):
    import numpy as np

    from repro.core.methods import get_method
    from repro.solver import ECGSolver, SolverConfig

    rows = []
    for op_name, a in ops.items():
        n = a.shape[0]
        b = np.random.default_rng(0).standard_normal(n)
        bn = np.linalg.norm(b)
        for t in cands:
            for method, s in schemes:
                spec = get_method(method)
                base = ECGSolver.build(a, config=SolverConfig(
                    t=t, tol=tol, max_iters=max_iters,
                    method=dict(name=method, s=s)))
                for kind in preconds:
                    if method == "pipelined" and kind == "inexact":
                        continue  # rejected at config validation
                    # 64-row blocks (see module docstring) — other kinds
                    # run with their library defaults
                    override = (dict(kind="block_jacobi", block=64)
                                if kind == "block_jacobi" else kind)
                    solver = (base if kind == "none"
                              else base.with_config(precondition=override))
                    res = solver.solve(b)       # warm: owns the compile
                    t0 = time.perf_counter()
                    res = solver.solve(b)
                    wall_s = time.perf_counter() - t0
                    from repro.sparse.csr import csr_spmv
                    import jax.numpy as jnp

                    relres = float(np.linalg.norm(
                        np.asarray(csr_spmv(a, jnp.asarray(res.x)))
                        - b) / bn)
                    label = method + (f"[s={s}]" if s > 1 else "")
                    # event telemetry: rank-revealing drops the solve
                    # recovered from, and flexible-reseed firings (inexact)
                    recoveries = res.n_recoveries
                    reseeds = res.n_reseeds
                    rows.append(dict(
                        operator=op_name, n=n, precond=kind, method=label,
                        t=t, iters=int(res.n_iters),
                        eff_iters=int(res.n_iters * spec.iters_per_block(s)),
                        converged=bool(res.converged),
                        breakdown=bool(res.breakdown), relres=relres,
                        wall_s=wall_s,
                        recoveries=recoveries, reseeds=reseeds,
                    ))
                    print(f"{op_name:<12} t={t} {label:<10} {kind:<12} "
                          f"iters={res.n_iters:>5} "
                          f"conv={str(bool(res.converged)):<5} "
                          f"relres={relres:.2e}"
                          + (f" recov={recoveries}" if recoveries else "")
                          + (f" reseed={reseeds}" if reseeds else "")
                          + (" BREAKDOWN" if res.breakdown else ""))
    return rows


def summarize(rows, tol):
    def get(op, kind, method, t):
        return next(
            (r for r in rows
             if r["operator"] == op and r["precond"] == kind
             and r["method"] == method and r["t"] == t),
            None,
        )

    def resolved(r):
        """Converged, or stopped on the attainable-accuracy floor.

        A rank-deficiency breakdown whose *true* relres is within 2×tol
        is the pipelined recurrence flooring out near κ·u (see module
        docstring), not a convergence failure.
        """
        return r["converged"] or (r["breakdown"] and r["relres"] <= 2 * tol)

    helps = []
    for method in sorted({r["method"] for r in rows if "inexact" not in r["precond"]}):
        for t in sorted({r["t"] for r in rows}):
            none_an = get("aniso2d", "none", method, t)
            if none_an is None:
                continue
            for kind in ("block_jacobi", "chebyshev"):
                pr = get("aniso2d", kind, method, t)
                if pr is not None:
                    helps.append(pr["converged"]
                                 and pr["eff_iters"] < none_an["eff_iters"])
            none_sc = get("scaled2d", "none", method, t)
            bj_sc = get("scaled2d", "block_jacobi", method, t)
            if none_sc is not None and bj_sc is not None:
                # block-Jacobi rescues the κ~1e8 operator outright
                helps.append(resolved(bj_sc) and (
                    (not none_sc["converged"])
                    or bj_sc["eff_iters"] < none_sc["eff_iters"]
                ))
    return dict(
        precond_helps_ill=bool(helps) and all(helps),
        n_recoveries=sum(r.get("recoveries", 0) for r in rows),
        n_reseeds=sum(r.get("reseeds", 0) for r in rows),
        none_rows_all_converged_except_scaled=all(
            r["converged"] for r in rows
            if r["precond"] == "none" and r["operator"] != "scaled2d"
        ),
        block_jacobi_all_converged=all(
            resolved(r) for r in rows if r["precond"] == "block_jacobi"
        ),
        n_rows=len(rows),
    )


def check_regression(rows, baseline_path, slack=1.10):
    """>10% iteration regression or a convergence flip fails the gate."""
    with open(baseline_path) as f:
        base = json.load(f)
    key = lambda r: (r["operator"], r["precond"], r["method"], r["t"])
    base_rows = {key(r): r for r in base["rows"]}
    failures = []
    for r in rows:
        b = base_rows.get(key(r))
        if b is None:
            continue  # new grid point: no baseline yet
        if b["converged"] and not r["converged"]:
            failures.append(f"{key(r)}: converged -> UNCONVERGED")
        elif b["converged"] and r["iters"] > slack * b["iters"]:
            failures.append(
                f"{key(r)}: iters {b['iters']} -> {r['iters']} "
                f"(>{(slack - 1) * 100:.0f}% regression)"
            )
    return failures


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="reduced grid for CI")
    ap.add_argument("--t", type=int, nargs="+", default=None)
    ap.add_argument("--tol", type=float, default=1e-8)
    ap.add_argument("--max-iters", type=int, default=1500)
    ap.add_argument("--json", default="BENCH_scoreboard.json")
    ap.add_argument("--check", metavar="BASELINE", default=None,
                    help="fail on >10%% iteration regression vs this JSON")
    args = ap.parse_args()

    import jax

    jax.config.update("jax_enable_x64", True)

    ops = build_operators(args.smoke)
    if args.smoke:
        # a strict subset of the full grid (same operators/schemes/t keys)
        # so the --check regression gate compares like with like
        schemes = [("classic", 1), ("sstep", 2)]
        cands = args.t or [2]
    else:
        schemes = [("classic", 1), ("pipelined", 1), ("sstep", 2)]
        cands = args.t or [2, 8]
    preconds = ("none", "block_jacobi", "chebyshev", "inexact")
    print(f"# scoreboard: {len(ops)} operators x {len(preconds)} preconds x "
          f"{len(schemes)} schemes x t in {cands}"
          + (" [smoke]" if args.smoke else ""))

    rows = run_grid(ops, schemes, cands, preconds, args.tol, args.max_iters)
    summary = summarize(rows, args.tol)
    out = dict(
        config=dict(
            operators={k: int(v.shape[0]) for k, v in ops.items()},
            preconds=list(preconds), block_jacobi_block=64, t=cands, tol=args.tol,
            max_iters=args.max_iters, smoke=args.smoke,
            schemes=[m + (f"[s={s}]" if s > 1 else "") for m, s in schemes],
        ),
        rows=rows, summary=summary,
    )
    with open(args.json, "w") as f:
        json.dump(out, f, indent=2)
    print(f"summary: {json.dumps(summary)}")
    print(f"wrote {args.json}")

    if not summary["precond_helps_ill"]:
        print("FAIL: preconditioning did not pay on the ill-conditioned "
              "operators", file=sys.stderr)
        sys.exit(1)
    if args.check:
        failures = check_regression(rows, args.check)
        if failures:
            print("REGRESSION GATE FAILED:", file=sys.stderr)
            for f_ in failures:
                print(f"  {f_}", file=sys.stderr)
            sys.exit(1)
        print(f"regression gate OK vs {args.check}")


if __name__ == "__main__":
    main()
