"""Cross-request width packing: coalesce serve traffic into ONE enlarged
block solve with per-request retirement.

The dispatch batching in :mod:`repro.serve.batching` pipelines k compiled
width-``t`` programs; each request still runs its own full iteration loop
and pays its own halo exchanges and Gram reductions.  Width packing goes
further: k compatible requests (same operator fingerprint, same
:class:`~repro.solver.SolverConfig`) become contiguous column slabs of a
single ``(n, k·t)`` enlarged solve (``ECGSolver.solve_packed``) — every
iteration's two Gram psums and its halo exchange are shared by all k
requests, and the pack converges in far fewer *total* iterations than k
solo solves because the requests search one shared Krylov space.

The price is bit-identity: packed results are coupled through the shared
pivoted directions, so a packed request's iterate sequence differs from
its solo solve.  Packing is therefore **opt-in**
(``PackingConfig(pack="width")``) and the server reports the contract it
*does* enforce instead: every request's true relative residual
``‖A·x − b‖ / ‖b‖`` is measured host-side after the solve and attached to
its ticket (``Ticket.relres``), and each request retires only once its own
residual-norm tolerance is met (per-request retirement inside the packed
loop).  ``pack="off"`` (the default) leaves the dispatch-batching path —
and its bit-identity guarantee — byte-for-byte untouched.
"""

from __future__ import annotations

import dataclasses

import numpy as np

_PACK_MODES = ("off", "width")


@dataclasses.dataclass(frozen=True)
class PackingConfig:
    """Width-packing policy of a :class:`~repro.serve.RequestQueue`.

    pack:           ``"off"`` (default — dispatch batching only, bit-identical
                    to solo solves) or ``"width"`` (coalesce compatible
                    requests into one enlarged packed solve).
    max_pack_width: total packed column budget; a pack holds at most
                    ``max(1, max_pack_width // solver.t)`` requests, so the
                    packed Gram stays a small dense factorization.
    max_wait_s:     packing deadline timer — a ``submit`` that finds a
                    pending request older than this closes the pack early
                    (partial packs beat stalled clients).  ``0`` disables
                    the clock: packs close on capacity or ``flush()`` only,
                    keeping request traces deterministic.
    """

    pack: str = "off"
    max_pack_width: int = 16
    max_wait_s: float = 0.0

    def __post_init__(self):
        if self.pack not in _PACK_MODES:
            raise ValueError(
                f"pack must be one of {_PACK_MODES}, got {self.pack!r}"
            )
        if not isinstance(self.max_pack_width, int) or self.max_pack_width < 1:
            raise ValueError(
                f"max_pack_width must be an int >= 1, got {self.max_pack_width!r}"
            )
        if not self.max_wait_s >= 0:
            raise ValueError(f"max_wait_s must be >= 0, got {self.max_wait_s!r}")

    @property
    def active(self) -> bool:
        return self.pack != "off"

    @classmethod
    def coerce(cls, value) -> "PackingConfig":
        if value is None:
            return cls()
        if isinstance(value, cls):
            return value
        if isinstance(value, str):
            return cls(pack=value)
        if isinstance(value, dict):
            return cls(**value)
        raise TypeError(
            "packing must be a PackingConfig, a pack-mode string, or a dict "
            f"of PackingConfig fields, got {type(value)}"
        )


def true_relres(a, x, b) -> float:
    """Host-side true relative residual ``‖A·x − b‖ / ‖b‖`` of a solution.

    Computed from the raw CSR arrays with numpy (one bincount segment-sum)
    — independent of the solver's kernels and recurrences on purpose: this
    is the *measurement* side of the packed relres contract, so it must not
    share code with the machinery it audits.
    """
    x = np.asarray(x)
    b = np.asarray(b)
    indptr = np.asarray(a.indptr)
    indices = np.asarray(a.indices)
    data = np.asarray(a.data)
    n = int(a.shape[0])
    rows = np.repeat(np.arange(n), np.diff(indptr))
    ax = np.bincount(rows, weights=np.asarray(data * x[indices], np.float64),
                     minlength=n)
    nb = float(np.linalg.norm(b))
    return float(np.linalg.norm(ax - b) / (nb if nb > 0 else 1.0))


def latency_percentiles(tickets) -> dict:
    """``dict(n, mean, p50, p95, p99)`` per-request latency (seconds) of
    completed tickets.

    Latency is ``completed_s − submitted_s`` — queue wait *plus* solve, the
    number a client actually experiences.  Tickets without a completion
    stamp are skipped.  An empty or all-incomplete ticket list returns the
    **explicit empty result** ``dict(n=0, mean=None, p50=None, p95=None,
    p99=None)`` — never NaNs (which compare false silently) and never a
    ``np.percentile`` call on an empty array; callers branch on ``n``.
    """
    lats = [
        tk.completed_s - tk.submitted_s
        for tk in tickets
        if tk.completed_s is not None
    ]
    if not lats:
        return dict(n=0, mean=None, p50=None, p95=None, p99=None)
    arr = np.asarray(lats, np.float64)
    return dict(
        n=int(arr.size),
        mean=float(arr.mean()),
        p50=float(np.percentile(arr, 50)),
        p95=float(np.percentile(arr, 95)),
        p99=float(np.percentile(arr, 99)),
    )


class WidthPacker:
    """Dispatch helper that runs one pack through ``solve_packed``.

    Owns the pack counters (``packs``, ``pack_layouts``) and the
    per-request relres measurement; the :class:`~repro.serve.RequestQueue`
    owns grouping, dedup, and chunking-to-capacity.
    """

    def __init__(self, config: PackingConfig):
        self.config = config
        self.packs = 0
        self.pack_layouts: list[dict] = []

    def capacity(self, solver) -> int:
        """Requests per pack for this session's width: each request owns a
        ``solver.t``-column slab under the total ``max_pack_width`` budget
        (always >= 1 — a lone oversized session still packs solo)."""
        return max(1, self.config.max_pack_width // int(solver.t))

    def dispatch(self, chunk: list[list]) -> int:
        """Solve one pack: ``chunk`` is a list of dedup groups (lists of
        tickets sharing a payload); the first ticket of each group leads.
        Fills every ticket's result/pack telemetry; returns the number of
        tickets completed."""
        leads = [tickets[0] for tickets in chunk]
        solver = leads[0].solver
        results = solver.solve_packed(
            [tk.b for tk in leads],
            [tk.x0 for tk in leads],
            [tk.tol for tk in leads],
        )
        pack_id = self.packs
        self.packs += 1
        self.pack_layouts.append(dict(
            pack_id=pack_id,
            width=int(results[0].pack["width"]),
            t_each=int(results[0].pack["t_each"]),
            groups=len(leads),
            comm_segments=[
                [int(w), int(it)] for w, it in (results[0].comm_segments or [])
            ],
        ))
        done = 0
        for j, (tickets, res) in enumerate(zip(chunk, results)):
            relres = true_relres(solver.a, solver.unshard(res.x), leads[j].b)
            for i, tk in enumerate(tickets):
                tk.result = res
                tk.pack_id = pack_id
                tk.pack_width = int(res.pack["width"])
                tk.group_index = j
                tk.batch_size = len(leads)
                tk.deduped = i > 0
                tk.relres = relres
                done += 1
        return done
