"""Request queue + batching policy: coalesce single-RHS traffic per operator.

Every serving request is one ``(operator, b)`` pair, and every registered
session is a compiled **(n, t) block** program — the enlargement already
*is* the batch.  By default the queue does not pack columns (mixing
requests into one splitting entangles their Gram matrices and breaks
per-request bit-identity); its job is to:

* group pending requests by operator fingerprint, so consecutive solves
  reuse one compiled program with zero retraces (each request's RHS is
  split to the session's compiled width ``t`` — no shape ever changes);
* deduplicate identical ``(operator, b, x0)`` payloads — concurrent
  clients asking for the same solve share one result, bit-identical by
  construction;
* dispatch each group through ``ECGSolver.solve_many`` — the handle
  enqueues every solve on the device before the first host sync, so the
  host-side finalize of request *i* overlaps the device compute of
  request *i+1*;
* apply backpressure: a bounded pending queue that rejects with the typed
  :class:`ServeOverloaded` instead of growing without bound.

Batches close on three triggers: a per-operator group reaching
``max_batch`` distinct payloads (checked at ``submit``), the oldest
pending request aging past ``max_wait_s`` (checked at ``submit``;
disabled at the default ``0``), or an explicit ``flush()``.

With the **opt-in** width-packing policy
(:class:`~repro.serve.packing.PackingConfig`, ``pack="width"``) the
entanglement trade is made deliberately: per-operator dedup groups are
chunked to the pack capacity and dispatched through
``ECGSolver.solve_packed`` — one enlarged ``(n, k·t)`` solve whose k
requests retire independently against their own tolerances.  Packed
results are *not* bit-identical to solo solves; each ticket instead
carries its measured true relative residual (``Ticket.relres``) and pack
telemetry.  Packs additionally close when a per-operator group reaches
the pack capacity or the oldest pending request ages past the packing
deadline ``PackingConfig.max_wait_s``.  ``pack="off"`` leaves every
code path above byte-for-byte as it was.
"""

from __future__ import annotations

import dataclasses
import hashlib
import time
from collections import OrderedDict

import numpy as np

from repro.observe.metrics import RollingWindow
from repro.observe.tracer import coerce_tracer
from repro.serve.packing import PackingConfig, WidthPacker


class ServeOverloaded(RuntimeError):
    """Raised by ``submit`` when the pending queue is at ``max_pending``.

    The typed rejection is the backpressure contract: a client sees it
    *before* any device work is enqueued and can retry after a drain —
    nothing about the queue or the registry changed.
    """


def payload_key(fingerprint: str, b, x0=None, tol=None) -> str:
    """Dedup key: operator fingerprint + exact RHS/x0 bytes (+ per-request
    tolerance when one was given — two requests for the same payload at
    different tolerances must not share a solve)."""
    h = hashlib.blake2b(digest_size=16)
    h.update(fingerprint.encode())
    b = np.asarray(b)
    h.update(b.dtype.str.encode())
    h.update(np.ascontiguousarray(b).tobytes())
    if x0 is not None:
        x0 = np.asarray(x0)
        h.update(x0.dtype.str.encode())
        h.update(np.ascontiguousarray(x0).tobytes())
    if tol is not None:  # hashed only when set: default-tol keys are
        h.update(repr(float(tol)).encode())  # unchanged across versions
    return h.hexdigest()


@dataclasses.dataclass
class Ticket:
    """One submitted request and (after dispatch) its outcome.

    ``result`` is the request's own
    :class:`~repro.core.cg.SolveResult` — convergence, iteration count,
    and residual history are per-request even when the solve was shared
    (``deduped``) or dispatched in a group (``batch_id``/``batch_size``).
    """

    request_id: int
    fingerprint: str
    b: np.ndarray
    x0: np.ndarray | None
    key: str
    submitted_s: float
    solver: object = dataclasses.field(repr=False, default=None)
    result: object = None
    batch_id: int | None = None
    batch_size: int = 0
    deduped: bool = False
    # --- width-packing / latency telemetry (None outside pack="width")
    tol: float | None = None          # per-request tolerance (packed only)
    completed_s: float | None = None  # dispatch completion stamp (all
    #                                   policies — latency percentiles)
    pack_id: int | None = None        # which pack solved this request
    pack_width: int | None = None     # total packed column width
    group_index: int | None = None    # this request's column-slab index
    relres: float | None = None       # measured true ‖Ax−b‖/‖b‖ (the packed
    #                                   relres contract; None when unmeasured)

    @property
    def done(self) -> bool:
        return self.result is not None


class RequestQueue:
    """Bounded pending queue with the grouping/dedup/flush policy."""

    def __init__(self, max_batch: int = 8, max_wait_s: float = 0.0,
                 max_pending: int = 256, dedup: bool = True,
                 packing: PackingConfig | None = None, clock=None,
                 tracer=None, window_s: float = 60.0):
        self.max_batch = max_batch
        self.max_wait_s = max_wait_s
        self.max_pending = max_pending
        self.dedup = dedup
        self.packing = PackingConfig.coerce(packing)
        self.packer = WidthPacker(self.packing)
        self._tracer = coerce_tracer(tracer)
        # injectable clock (same contract as time.monotonic) — deadline
        # timers become deterministic under a test-controlled clock.  When
        # tracing is on and no clock was injected, the queue adopts the
        # tracer's clock so queue-wait spans (emitted from submit/complete
        # stamps) land on the same timeline as every other span.
        if clock is None:
            clock = (
                self._tracer.clock if self._tracer.enabled
                else time.monotonic
            )
        self._clock = clock
        self.pending: list[Ticket] = []
        self.submitted = 0
        self.rejected = 0
        self.batches = 0
        self.batch_sizes: list[int] = []
        self.dedup_shared = 0
        self.completed = 0
        # per-queue latency time-series: one sample per completed ticket,
        # read as rolling req/s + p50/p95/p99 over the trailing window
        self.window = RollingWindow(window_s=window_s)

    # ------------------------------------------------------------- intake
    def submit(self, fingerprint: str, b, x0=None, solver=None,
               tol=None) -> Ticket:
        if len(self.pending) >= self.max_pending:
            self.rejected += 1
            self._tracer.counter("serve.rejected", self.rejected)
            raise ServeOverloaded(
                f"{len(self.pending)} requests pending (max_pending="
                f"{self.max_pending}); flush or retry after a drain"
            )
        ticket = Ticket(
            request_id=self.submitted,
            fingerprint=fingerprint,
            b=np.asarray(b),
            x0=None if x0 is None else np.asarray(x0),
            key=payload_key(fingerprint, b, x0, tol),
            submitted_s=self._clock(),
            solver=solver,
            tol=None if tol is None else float(tol),
        )
        self.pending.append(ticket)
        self.submitted += 1
        self._tracer.counter("serve.submitted", self.submitted)
        return ticket

    def due(self) -> bool:
        """A batch-closing trigger fired: some operator group holds
        ``max_batch`` distinct payloads (pack capacity under
        ``pack="width"``), or the oldest request aged out."""
        if not self.pending:
            return False
        age = self._clock() - self.pending[0].submitted_s
        if self.max_wait_s > 0 and age >= self.max_wait_s:
            return True
        if (
            self.packing.active
            and self.packing.max_wait_s > 0
            and age >= self.packing.max_wait_s
        ):
            return True  # packing deadline: close a partial pack
        distinct: dict[str, set] = {}
        for tk in self.pending:
            keys = distinct.setdefault(tk.fingerprint, set())
            keys.add(tk.key if self.dedup else tk.request_id)
            close_at = self.max_batch
            if self.packing.active and tk.solver is not None:
                close_at = min(close_at, self.packer.capacity(tk.solver))
            if len(keys) >= close_at:
                return True
        return False

    # ----------------------------------------------------------- dispatch
    def drain(self) -> list[Ticket]:
        """Dispatch every pending request; returns them in submit order.

        Requests are grouped by operator (one compiled program per group),
        deduplicated, chunked to ``max_batch``, and pushed through
        ``solve_many``.  Results are split back out per ticket.
        """
        drained, self.pending = self.pending, []
        tr = self._tracer
        t_start = self._clock()
        with tr.span("serve/drain", cat="serve", requests=len(drained),
                     policy=self.packing.pack):
            if tr.enabled:
                # queue wait per request: submit stamp -> drain start.
                # Both ends are on the queue clock (the tracer's clock
                # unless one was injected), emitted with explicit
                # timestamps since the wait began before this span opened.
                for tk in drained:
                    tr.emit("serve/queue_wait", tk.submitted_s,
                            t_start - tk.submitted_s, cat="serve",
                            request_id=tk.request_id)
            with tr.span("serve/assemble", cat="serve") as spa:
                groups: OrderedDict[str, OrderedDict[str, list[Ticket]]] = (
                    OrderedDict()
                )
                for tk in drained:
                    per_op = groups.setdefault(tk.fingerprint, OrderedDict())
                    key = tk.key if self.dedup else f"req{tk.request_id}"
                    per_op.setdefault(key, []).append(tk)
                spa.args.update(
                    operators=len(groups),
                    payloads=sum(len(g) for g in groups.values()),
                )
            if self.packing.active:
                self._drain_packed(groups)
            else:
                self._drain_batched(groups)
            with tr.span("serve/retire", cat="serve", tickets=len(drained)):
                now = self._clock()
                for tk in drained:
                    tk.completed_s = now
                    self.window.add(now, now - tk.submitted_s)
            tr.counter("serve.completed", self.completed)
        return drained

    def _drain_batched(self, groups) -> None:
        """Dispatch-pipelined batching (the default, bit-identical path)."""
        for per_op in groups.values():
            unique = list(per_op.values())
            for lo in range(0, len(unique), self.max_batch):
                chunk = unique[lo:lo + self.max_batch]
                leads = [tickets[0] for tickets in chunk]
                solver = leads[0].solver
                with self._tracer.span("serve/dispatch", cat="serve",
                                       policy="batch", batch_id=self.batches,
                                       batch_size=len(leads)):
                    results = solver.solve_many(
                        [tk.b for tk in leads], [tk.x0 for tk in leads]
                    )
                batch_id = self.batches
                self.batches += 1
                self.batch_sizes.append(len(leads))
                for tickets, res in zip(chunk, results):
                    for i, tk in enumerate(tickets):
                        tk.result = res
                        tk.batch_id = batch_id
                        tk.batch_size = len(leads)
                        tk.deduped = i > 0
                        self.completed += 1
                    self.dedup_shared += len(tickets) - 1

    def _drain_packed(self, groups) -> None:
        """Width packing: per-operator dedup groups chunked to the pack
        capacity and solved as one enlarged block program each."""
        for per_op in groups.values():
            unique = list(per_op.values())
            solver = unique[0][0].solver
            cap = self.packer.capacity(solver)
            for lo in range(0, len(unique), cap):
                chunk = unique[lo:lo + cap]
                with self._tracer.span("serve/dispatch", cat="serve",
                                       policy="width", pack_id=self.batches,
                                       groups=len(chunk)):
                    self.completed += self.packer.dispatch(chunk)
                self.batches += 1
                self.batch_sizes.append(len(chunk))
                self.dedup_shared += sum(len(ts) - 1 for ts in chunk)

    # -------------------------------------------------------------- state
    def stats(self) -> dict:
        return dict(
            submitted=self.submitted, completed=self.completed,
            pending=len(self.pending), rejected=self.rejected,
            batches=self.batches, batch_sizes=list(self.batch_sizes),
            dedup_shared=self.dedup_shared,
            pack=self.packing.pack,
            packs=self.packer.packs,
            pack_layouts=[dict(d) for d in self.packer.pack_layouts],
            rolling=self.window.snapshot(self._clock()),
        )
