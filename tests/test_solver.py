"""repro.solver: typed config validation, handle reuse, and back-compat.

The heart of this file is the handle-reuse matrix: for every
t ∈ {2, 4, 8} × backend ∈ {jnp, pallas} × adaptive ∈ {off, reduce}, a
second ``ECGSolver.solve`` call must trigger **no retrace** (jit cache hit,
asserted via ``SolverStats.traces``) and be **bit-identical** to the
one-shot legacy ``ecg_solve`` path.  The distributed equivalents (4-RHS
``solve_many`` vs four legacy ``distributed_ecg`` calls, two-psum HLO
invariant) run in ``dist_worker.check_solver_handle``.
"""

import warnings

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.adaptive import ReductionPolicy, TSelection, select_t
from repro.core import ecg_solve
from repro.solver import (
    AdaptiveConfig,
    CommConfig,
    ECGSolver,
    KernelConfig,
    SolverConfig,
    TuneConfig,
)
from repro.sparse import dg_laplace_2d, fd_laplace_2d
from repro.sparse.csr import csr_spmbv
from repro.tune import TunedConfig, tune as run_tune


@pytest.fixture(scope="module")
def system():
    a = dg_laplace_2d((8, 6), block=4)  # 192 rows
    b = np.random.default_rng(7).standard_normal(a.shape[0])
    return a, b


# --------------------------------------------------------------- config
class TestSolverConfig:
    def test_validation_at_construction(self):
        with pytest.raises(ValueError, match="strategy"):
            CommConfig(strategy="bogus")
        with pytest.raises(ValueError, match="backend"):
            KernelConfig(backend="cuda")
        with pytest.raises(ValueError, match="tune mode"):
            TuneConfig(mode="magic")
        with pytest.raises(ValueError, match="adaptive mode"):
            AdaptiveConfig(policy="bogus")
        with pytest.raises(ValueError, match="col_split"):
            CommConfig(col_split=0)
        with pytest.raises(ValueError, match="ell_block"):
            KernelConfig(ell_block=(8, 0))
        with pytest.raises(ValueError, match="t must be"):
            SolverConfig(t=0)
        with pytest.raises(ValueError, match="t must be"):
            SolverConfig(t="automatic")
        with pytest.raises(ValueError, match="max_iters"):
            SolverConfig(max_iters=0)
        with pytest.raises(ValueError, match="probe_iters"):
            AdaptiveConfig(probe_iters=1)

    def test_coercions(self):
        cfg = SolverConfig(t=4, tune="model", adaptive="reduce",
                           kernel=KernelConfig(ell_block=8))
        assert cfg.tune == TuneConfig(mode="model")
        assert isinstance(cfg.adaptive.policy, ReductionPolicy)
        assert cfg.kernel.ell_block == (8, 8)
        # a precomputed TunedConfig slots into the tune field
        tc = TunedConfig(strategy="3step", br=4, bc=4, kmax=8, overlap=False,
                         backend="jnp", t=4, mode="model")
        cfg2 = SolverConfig(t=4, tune=tc)
        assert cfg2.tune.tuned is tc and cfg2.tune.active

    def test_replace_flat_and_nested(self):
        cfg = SolverConfig(t=4)
        c2 = cfg.replace(strategy="3step", backend="pallas", tol=1e-6,
                         policy="rankrev", tune_mode="model")
        assert c2.comm.strategy == "3step"
        assert c2.kernel.backend == "pallas"
        assert c2.tol == 1e-6
        assert isinstance(c2.adaptive.policy, ReductionPolicy)
        assert c2.tune.mode == "model"
        assert cfg.comm.strategy == "standard"  # original untouched
        with pytest.raises(ValueError, match="unknown config override"):
            cfg.replace(stratgy="3step")
        with pytest.raises(ValueError, match="cannot combine"):
            cfg.replace(comm=CommConfig(), strategy="3step")

    def test_frozen_and_comparable(self):
        assert SolverConfig(t=4) == SolverConfig(t=4)
        assert SolverConfig(t=4) != SolverConfig(t=8)
        with pytest.raises(Exception):
            SolverConfig(t=4).t = 8


# --------------------------------------------------------------- handle
class TestHandleReuse:
    @pytest.mark.parametrize("t", [2, 4, 8])
    @pytest.mark.parametrize("backend", ["jnp", "pallas"])
    @pytest.mark.parametrize("adaptive", [None, "reduce"])
    def test_second_solve_no_retrace_and_bit_identical_to_legacy(
        self, system, t, backend, adaptive
    ):
        a, b = system
        b2 = np.random.default_rng(t).standard_normal(a.shape[0])
        solver = ECGSolver.build(a, config=SolverConfig(
            t=t, tol=1e-8, max_iters=400,
            kernel=KernelConfig(backend=backend),
            adaptive=AdaptiveConfig(policy=adaptive),
        ))
        res1 = solver.solve(b)
        traces = solver.stats.traces
        res2 = solver.solve(b2)
        assert solver.stats.traces == traces, "second solve retraced"
        assert res1.converged and res2.converged

        if backend == "pallas":
            # the handle routes the SpMBV through the same Block-ELL apply
            from repro.kernels import make_block_ell_apply

            apply_ref = make_block_ell_apply(a, block=(8, 8))
        else:
            apply_ref = lambda V: csr_spmbv(a, V)
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", DeprecationWarning)
            ref = ecg_solve(
                apply_ref, jnp.asarray(b2), t=t, tol=1e-8,
                max_iters=400, backend=backend, adaptive=adaptive,
            )
        assert res2.n_iters == ref.n_iters
        assert np.array_equal(np.asarray(res2.x), np.asarray(ref.x)), (
            "handle solve is not bit-identical to the one-shot legacy path"
        )
        assert np.array_equal(
            np.asarray(res2.res_hist), np.asarray(ref.res_hist), equal_nan=True
        )

    def test_solve_many_zero_retraces(self, system):
        a, _ = system
        rng = np.random.default_rng(3)
        bs = [rng.standard_normal(a.shape[0]) for _ in range(4)]
        solver = ECGSolver.build(a, config=SolverConfig(t=4, max_iters=400))
        first = solver.solve(bs[0])
        traces = solver.stats.traces
        rest = solver.solve_many(bs[1:])
        assert solver.stats.traces == traces
        assert solver.stats.solves == 4
        assert all(r.converged for r in [first] + rest)
        # the solves are independent: each matches its own fresh handle
        fresh = ECGSolver.build(a, config=SolverConfig(t=4, max_iters=400))
        assert np.array_equal(
            np.asarray(rest[-1].x), np.asarray(fresh.solve(bs[-1]).x)
        )

    def test_with_config_reuses_or_rebuilds(self, system):
        a, b = system
        solver = ECGSolver.build(a, config=SolverConfig(t=4, max_iters=400))
        # solve-level override: same operator, fresh jit cache
        s_tol = solver.with_config(tol=1e-6)
        assert s_tol.stats.op_reused and s_tol.config.tol == 1e-6
        assert s_tol.solve(b).converged
        # policy override still reuses the operator
        s_ad = solver.with_config(policy="reduce")
        assert s_ad.stats.op_reused and s_ad.policy is not None
        assert s_ad.solve(b).converged
        # kernel override rebuilds (sequential handle: new apply closure)
        s_pl = solver.with_config(backend="pallas")
        assert not s_pl.stats.op_reused
        assert s_pl.solve(b).converged

    def test_x0_and_auto_t(self, system):
        a, b = system
        solver = ECGSolver.build(a, config=SolverConfig(t=4, max_iters=400))
        res = solver.solve(b, x0=solver.solve(b).x)
        assert res.converged and res.n_iters <= 2
        s_auto = ECGSolver.build(
            a,
            config=SolverConfig(t="auto", max_iters=400,
                                adaptive=AdaptiveConfig(t_candidates=(2, 4))),
            b=b,
        )
        assert s_auto.t in (2, 4)
        r = s_auto.solve(b)
        assert r.converged and r.selection is s_auto.selection
        assert set(r.selection.probe_iters_used) == {2, 4}

    def test_with_config_reselects_auto_t_on_adaptive_knob_change(self, system):
        a, b = system
        s = ECGSolver.build(a, config=SolverConfig(
            t="auto", max_iters=400,
            adaptive=AdaptiveConfig(t_candidates=(2, 4)),
        ), b=b)
        # changing a selection input on an auto-t handle must re-run the
        # selection, not silently reuse the stale one
        s2 = s.with_config(t_candidates=(8, 16))
        assert not s2.stats.op_reused
        assert s2.selection.candidates == (8, 16) and s2.t in (8, 16)
        # auto-t's implied rankrev guard survives the re-derivation
        assert s2.policy is not None
        # tol is a selection input too (est_iters-to-tol drives the ranking)
        s3 = s.with_config(tol=1e-4)
        assert not s3.stats.op_reused and s3.selection.tol == 1e-4
        # an unrelated solve-level knob on a fixed-t handle still reuses
        s_fixed = ECGSolver.build(a, config=SolverConfig(t=4, max_iters=400))
        assert s_fixed.with_config(tol=1e-6).stats.op_reused

    def test_explicit_off_suppresses_auto_t_rankrev(self, system):
        a, b = system
        on = ECGSolver.build(a, config=SolverConfig(
            t="auto", max_iters=400, adaptive=AdaptiveConfig(t_candidates=(2, 4)),
        ), b=b)
        assert on.policy is not None  # auto-t implies breakdown safety...
        off = ECGSolver.build(a, config=SolverConfig(
            t="auto", max_iters=400,
            adaptive=AdaptiveConfig(policy="off", t_candidates=(2, 4)),
        ), b=b)
        assert off.config.adaptive.explicit_off
        assert off.policy is None  # ...unless explicitly switched off
        res = off.solve(b)
        assert res.converged and res.active_hist is None
        # explicit_off is not sticky: overriding the policy later (on either
        # the reuse or the rebuild path) must honor the new policy
        back_on = off.with_config(policy="reduce", backend="pallas")
        assert not back_on.config.adaptive.explicit_off
        assert back_on.policy is not None
        assert back_on.solve(b).converged

    def test_new_api_emits_no_deprecation_warning(self, system):
        a, b = system
        with warnings.catch_warnings():
            warnings.simplefilter("error", DeprecationWarning)
            solver = ECGSolver.build(a, config=SolverConfig(t=4, max_iters=400))
            assert solver.solve(b).converged

    def test_legacy_spellings_warn(self, system):
        a, b = system
        with pytest.warns(DeprecationWarning, match="ECGSolver"):
            ecg_solve(lambda V: csr_spmbv(a, V), jnp.asarray(b), t=4,
                      max_iters=400)


# ---------------------------------------------------- satellite round trips
class TestConfigSerialization:
    def test_tunedconfig_json_round_trip_lossless(self, system):
        a, _ = system
        cfg = run_tune(a, t=4, n_nodes=2, ppn=4, backend="pallas")
        js = cfg.to_json()
        back = TunedConfig.from_json(js)
        assert back == cfg                     # dataclass fields
        assert back.machine == cfg.machine     # resolved MachineParams
        assert back.to_json() == js            # lossless fixed point
        # and it feeds straight back into the typed config
        solver = ECGSolver.build(a, config=SolverConfig(t=4, tune=back))
        assert solver.tuned is back

    def test_tselection_json_round_trip_lossless(self, system):
        a, b = system
        sel = select_t(a, b, candidates=(2, 4), tol=1e-8)
        js = sel.to_json()
        back = TSelection.from_json(js)
        assert back.t == sel.t and back.candidates == sel.candidates
        assert back.table == sel.table
        assert back.probe_iters_used == sel.probe_iters_used
        assert back.to_json() == js            # lossless fixed point
        # configs (TunedConfig per candidate) survive too
        assert set(back.configs) == set(sel.configs)
        assert all(back.configs[t] == sel.configs[t] for t in back.configs)
        # a selection loaded from disk skips the probes entirely
        solver = ECGSolver.build(a, config=SolverConfig(
            t="auto", adaptive=AdaptiveConfig(select=back, t_candidates=(2, 4)),
        ))
        assert solver.t == sel.t


class TestProbeEarlyStop:
    def test_early_stop_records_iters_used(self, system):
        a, b = system
        budget = 12
        sel = select_t(a, b, candidates=(2, 4), tol=1e-8, probe_iters=budget)
        assert set(sel.probe_iters_used) == {2, 4}
        assert all(3 <= u <= budget for u in sel.probe_iters_used.values())
        # on this smoothly-decaying system the fitted rate stabilizes well
        # before the budget — the early stop must actually engage
        assert any(u < budget for u in sel.probe_iters_used.values())

    def test_rtol_zero_disables_early_stop(self, system):
        a, b = system
        sel = select_t(a, b, candidates=(4,), tol=1e-8, probe_iters=6,
                       probe_rtol=0.0)
        assert sel.probe_iters_used == {4: 6}

    def test_estimates_stay_calibrated(self, system):
        a, b = system
        early = select_t(a, b, candidates=(4,), tol=1e-8, probe_iters=10)
        full = select_t(a, b, candidates=(4,), tol=1e-8, probe_iters=10,
                        probe_rtol=0.0)
        e1 = early.table[4]["est_iters"]
        e2 = full.table[4]["est_iters"]
        assert abs(e1 - e2) / max(e2, 1) <= 0.35, (e1, e2)


class TestDispatchOverheadMicrobench:
    def test_measures_positive_seconds(self):
        mesh = jax.sharding.Mesh(
            np.array(jax.devices()[:1]).reshape(1, 1), ("node", "proc")
        )
        from repro.tune import measure_dispatch_overhead

        v = measure_dispatch_overhead(mesh, repeats=3, chain=(2, 8))
        assert np.isfinite(v) and 0 < v < 1.0


# ------------------------------------------------------------- solve_packed
class TestSolvePacked:
    CFG = dict(t=4, tol=1e-8, adaptive="rankrev")

    def test_groupspec_validation(self):
        from repro.adaptive import GroupSpec

        spec = GroupSpec(t_each=4, tols=(1e-4, 1e-8))
        assert spec.width == 8 and spec.n_groups == 2
        assert hash(spec) == hash(GroupSpec(t_each=4, tols=(1e-4, 1e-8)))
        with pytest.raises(ValueError, match="t_each"):
            GroupSpec(t_each=0, tols=(1e-8,))
        with pytest.raises(ValueError, match="at least one group"):
            GroupSpec(t_each=4, tols=())
        with pytest.raises(ValueError, match="tol"):
            GroupSpec(t_each=4, tols=(1e-8, -1.0))

    def test_each_request_meets_its_tolerance(self, system):
        a, _ = system
        rng = np.random.default_rng(21)
        bs = [rng.standard_normal(a.shape[0]) for _ in range(3)]
        tols = [1e-3, 1e-6, 1e-9]
        solver = ECGSolver.build(a, config=SolverConfig(**self.CFG))
        results = solver.solve_packed(bs, tols=tols)
        dense = np.asarray(a.todense())
        for res, b, tol in zip(results, bs, tols):
            assert bool(res.converged)
            assert np.linalg.norm(dense @ np.asarray(res.x) - b) <= tol * 1.01
            assert res.pack["tol"] == tol and res.t == 4
        # retirement order follows tolerance order on a shared operator
        iters = [r.n_iters for r in results]
        assert iters == sorted(iters)
        assert solver.stats.solves == 3

    def test_pack_converges_faster_than_solo_total(self, system):
        a, _ = system
        rng = np.random.default_rng(22)
        bs = [rng.standard_normal(a.shape[0]) for _ in range(4)]
        solver = ECGSolver.build(a, config=SolverConfig(**self.CFG))
        packed = solver.solve_packed(bs)
        solo = ECGSolver.build(a, config=SolverConfig(**self.CFG))
        solo_iters = [solo.solve(b).n_iters for b in bs]
        # the shared search space: the pack's total iterations beat the
        # slowest solo solve, not just the sum
        assert packed[0].pack["packed_iters"] <= max(solo_iters)

    def test_x0_at_tolerance_retires_at_zero(self, system):
        a, b = system
        solver = ECGSolver.build(a, config=SolverConfig(**self.CFG))
        x_star = solver.solve(b).x
        rng = np.random.default_rng(23)
        b2 = rng.standard_normal(a.shape[0])
        res = solver.solve_packed([b, b2], x0s=[np.asarray(x_star), None])
        assert res[0].n_iters == 0 and bool(res[0].converged)
        assert res[0].pack["retired_iter"] == 0
        assert res[1].n_iters > 0 and bool(res[1].converged)

    def test_repack_same_layout_zero_retraces(self, system):
        a, _ = system
        rng = np.random.default_rng(24)
        solver = ECGSolver.build(a, config=SolverConfig(**self.CFG))
        solver.solve_packed([rng.standard_normal(a.shape[0]) for _ in range(3)])
        traces0 = solver.stats.traces
        solver.solve_packed([rng.standard_normal(a.shape[0]) for _ in range(3)])
        assert solver.stats.traces == traces0  # same (t_each, tols) layout

    def test_rejects_unsupported_configs(self, system):
        a, b = system
        bs = [b]
        no_policy = ECGSolver.build(a, config=SolverConfig(t=4, tol=1e-8))
        with pytest.raises(ValueError, match="rank-revealing"):
            no_policy.solve_packed(bs)
        sstep = ECGSolver.build(
            a, config=SolverConfig(t=4, adaptive="rankrev",
                                   method=dict(name="sstep"))
        )
        with pytest.raises(ValueError, match="classic"):
            sstep.solve_packed(bs)
        restart = ECGSolver.build(
            a, config=SolverConfig(t=4, adaptive="reduce+restart")
        )
        with pytest.raises(ValueError, match="restart"):
            restart.solve_packed(bs)
        solver = ECGSolver.build(a, config=SolverConfig(**self.CFG))
        with pytest.raises(ValueError, match="at least one"):
            solver.solve_packed([])
        with pytest.raises(ValueError, match="guesses"):
            solver.solve_packed(bs, x0s=[None, None])
