"""Preconditioned + flexible ECG: builders, kernel parity, and convergence.

Three layers are pinned here:

1. **Pieces** — block extraction / Cholesky factoring / the batched
   triangular-solve kernel (Pallas-interpret vs two independent oracles),
   Chebyshev bound estimation and polynomial application, diagonal
   extraction for the inexact smoother.
2. **Operator properties** every preconditioner apply must satisfy for the
   width-masked engine to stay correct: columnwise linearity (the apply
   acts independently on each of the t columns) and the zero-column fixed
   point (masked-out directions stay exactly zero).
3. **End-to-end** — ``precondition="none"`` is bit-identical to the
   unpreconditioned solve for every method; block-Jacobi and Chebyshev
   reduce iterations on ill-conditioned operators; the iteration-varying
   ``inexact`` kind converges on classic (via the periodic residual
   reseed) and s-step (reseeds every block by construction), *stagnates*
   on classic when the reseed is disabled (the truncated-FCG failure mode
   documented in ``repro.precondition.inexact``), and is rejected outright
   for pipelined at config validation.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.ecg import _ecg_solve
from repro.kernels import block_trisolve
from repro.kernels.block_trisolve.ref import block_trisolve_dense, block_trisolve_ref
from repro.precondition import (
    PRECONDITIONS,
    PreconditionConfig,
    build_sequential_preconditioner,
    estimate_lambda_max,
    make_chebyshev_apply,
)
from repro.precondition.block_jacobi import (
    extract_blocks,
    factor_blocks,
    rank_slot_layout,
    slot_layout,
)
from repro.precondition.inexact import extract_diagonal, make_inexact_apply
from repro.solver import ECGSolver, MethodConfig, SolverConfig
from repro.sparse import aniso_laplace_2d, fd_laplace_2d, scaled_laplace_2d
from repro.sparse.csr import csr_spmbv


@pytest.fixture(scope="module")
def system():
    a = fd_laplace_2d(12)  # 144 rows
    b = np.random.default_rng(0).standard_normal(a.shape[0])
    return a, b


def _dense(a):
    return np.asarray(a.todense())


# ------------------------------------------------------------- kernel
class TestBlockTrisolve:
    def _case(self, rng, nb=6, bs=8, t=4, dtype=np.float64):
        m = rng.standard_normal((nb, bs, bs))
        spd = m @ np.swapaxes(m, 1, 2) + bs * np.eye(bs)
        l = np.linalg.cholesky(spd).astype(dtype)
        x = rng.standard_normal((nb, bs, t)).astype(dtype)
        return jnp.asarray(l), jnp.asarray(x), spd

    def test_oracles_agree_with_direct_solve(self, rng):
        l, x, spd = self._case(rng)
        want = np.linalg.solve(spd, np.asarray(x))
        np.testing.assert_allclose(np.asarray(block_trisolve_ref(l, x)), want,
                                   rtol=1e-10, atol=1e-10)
        np.testing.assert_allclose(np.asarray(block_trisolve_dense(l, x)), want,
                                   rtol=1e-10, atol=1e-10)

    @pytest.mark.parametrize("bs,t", [(8, 4), (16, 2), (32, 8)])
    def test_pallas_interpret_matches_oracle(self, rng, bs, t):
        l, x, _ = self._case(rng, nb=4, bs=bs, t=t)
        got = block_trisolve(l, x, use_pallas=True)  # interpret off-TPU
        want = block_trisolve_dense(l, x)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-9, atol=1e-9)

    def test_default_dispatch_runs(self, rng):
        l, x, spd = self._case(rng, nb=2, bs=8, t=2)
        got = block_trisolve(l, x)
        want = np.linalg.solve(spd, np.asarray(x))
        np.testing.assert_allclose(np.asarray(got), want, rtol=1e-8, atol=1e-8)


# ------------------------------------------------------------ builders
class TestBlockJacobiPieces:
    def test_slot_layout_pads_to_block_multiple(self):
        row_of_slot, n_slots = slot_layout(20, 8)
        assert n_slots == 24 and len(row_of_slot) == 24
        assert list(row_of_slot[:20]) == list(range(20))
        assert all(r == -1 for r in row_of_slot[20:])

    def test_rank_slot_layout_pads_each_rank(self):
        # 2 ranks, rmax=5 → padded to 8 slots per rank
        true_row = np.array([0, 1, 2, 3, 4, 5, 6, 7, 8, -1], dtype=np.int64)
        ros = rank_slot_layout(true_row.reshape(2, 5).reshape(-1), 2, 4)
        assert ros.shape == (16,)
        assert list(ros[:5]) == [0, 1, 2, 3, 4] and list(ros[5:8]) == [-1] * 3
        assert list(ros[8:13]) == [5, 6, 7, 8, -1] and list(ros[13:]) == [-1] * 3

    def test_extract_blocks_matches_dense_submatrices(self):
        a = fd_laplace_2d(6)  # 36 rows
        row_of_slot, n_slots = slot_layout(a.shape[0], 9)
        blocks = extract_blocks(a, np.asarray(row_of_slot), 9)
        d = _dense(a)
        for i in range(n_slots // 9):
            sub = d[i * 9:(i + 1) * 9, i * 9:(i + 1) * 9]
            np.testing.assert_allclose(np.asarray(blocks[i]), sub)

    def test_extract_blocks_identity_on_padding(self):
        a = fd_laplace_2d(5)  # 25 rows → 32 slots at block=8
        row_of_slot, n_slots = slot_layout(a.shape[0], 8)
        blocks = extract_blocks(a, np.asarray(row_of_slot), 8)
        # last block has 7 padding rows: identity rows keep it SPD
        last = np.asarray(blocks[-1])
        np.testing.assert_allclose(last[1:, 1:], np.eye(7))
        assert np.all(np.linalg.eigvalsh(np.asarray(blocks)) > 0)

    def test_factor_blocks_is_lower_cholesky(self):
        a = fd_laplace_2d(6)
        row_of_slot, _ = slot_layout(a.shape[0], 12)
        blocks = extract_blocks(a, np.asarray(row_of_slot), 12)
        l = factor_blocks(blocks)
        np.testing.assert_allclose(l @ np.swapaxes(l, 1, 2), np.asarray(blocks),
                                   rtol=1e-12, atol=1e-12)
        assert np.allclose(l, np.tril(l))


class TestChebyshevPieces:
    def test_lambda_max_estimate_brackets_spectrum(self):
        a = fd_laplace_2d(10)
        lmax_true = np.linalg.eigvalsh(_dense(a)).max()
        est = estimate_lambda_max(a)
        assert lmax_true <= est <= 1.3 * lmax_true

    def test_apply_is_spd_polynomial_in_a(self, rng):
        a = fd_laplace_2d(8)
        d = _dense(a)
        ev = np.linalg.eigvalsh(d)
        app = make_chebyshev_apply(
            lambda v: csr_spmbv(a, v), ev[0], ev[-1], degree=4
        )
        n = a.shape[0]
        m = np.asarray(app(jnp.eye(n)))  # matrix representation
        np.testing.assert_allclose(m, m.T, atol=1e-10)
        assert np.all(np.linalg.eigvalsh(0.5 * (m + m.T)) > 0)
        # M⁻¹A is far better conditioned than A
        pa = m @ d
        k_pa = np.linalg.cond(0.5 * (pa + pa.T))
        assert k_pa < 0.2 * np.linalg.cond(d)


class TestInexactPieces:
    def test_extract_diagonal(self):
        a = fd_laplace_2d(6)
        d = np.asarray(extract_diagonal(a))
        np.testing.assert_allclose(d, np.diag(_dense(a)))

    def test_extract_diagonal_padding_slots_get_one(self):
        a = fd_laplace_2d(5)
        row_of_slot, _ = slot_layout(a.shape[0], 8)
        d = np.asarray(extract_diagonal(a, row_of_slot=np.asarray(row_of_slot)))
        assert d.shape == (32,)
        np.testing.assert_allclose(d[25:], 1.0)

    def test_varying_damping_differs_across_iterations(self, rng):
        a = fd_laplace_2d(6)
        app = make_inexact_apply(
            lambda v: csr_spmbv(a, v), extract_diagonal(a), 2.0 / 3.0, 2
        )
        x = jnp.asarray(rng.standard_normal((a.shape[0], 3)))
        y0, y1, y2 = app(x, 0), app(x, 1), app(x, 2)
        assert not np.allclose(np.asarray(y0), np.asarray(y1))
        np.testing.assert_allclose(np.asarray(y0), np.asarray(y2))  # period 2


# ---------------------------------------------------- operator properties
def _build_apply(kind, a):
    cfg = PreconditionConfig(kind=kind, block=12, degree=3, sweeps=2)
    return build_sequential_preconditioner(
        a, cfg, lambda v: csr_spmbv(a, v)
    )


class TestApplyProperties:
    """Every kind must be columnwise-linear with a zero fixed point —
    otherwise masked (zeroed) directions of a reduced-width solve would
    leak mass back into the active block."""

    @pytest.mark.parametrize("kind", [k for k in PRECONDITIONS if k != "none"])
    def test_columnwise_linear_and_zero_fixed_point(self, kind, rng):
        a = fd_laplace_2d(6)
        app = _build_apply(kind, a)
        x = jnp.asarray(rng.standard_normal((a.shape[0], 4)))
        for k in (0, 1):
            y = np.asarray(app(x, k))
            # column j of the output depends only on column j of the input
            for j in range(4):
                xj = jnp.zeros_like(x).at[:, j].set(x[:, j])
                np.testing.assert_allclose(
                    np.asarray(app(xj, k))[:, j], y[:, j], rtol=1e-12, atol=1e-13
                )
            # zero columns stay exactly zero (masked widths are safe)
            xz = x.at[:, 2].set(0.0)
            assert np.all(np.asarray(app(xz, k))[:, 2] == 0.0)
            # homogeneity
            np.testing.assert_allclose(
                np.asarray(app(2.5 * x, k)), 2.5 * y, rtol=1e-12, atol=1e-12
            )

    def test_none_kind_builds_nothing(self):
        a = fd_laplace_2d(6)
        assert not PreconditionConfig().active
        assert build_sequential_preconditioner(
            a, PreconditionConfig(), lambda v: csr_spmbv(a, v)
        ) is None

    def test_block_jacobi_is_exact_blockdiag_inverse(self, rng):
        a = fd_laplace_2d(6)
        app = _build_apply("block_jacobi", a)
        d = _dense(a)
        n = a.shape[0]
        x = jnp.asarray(rng.standard_normal((n, 2)))
        want = np.zeros((n, 2))
        for i in range(0, n, 12):
            sub = d[i:i + 12, i:i + 12]
            want[i:i + 12] = np.linalg.solve(sub, np.asarray(x)[i:i + 12])
        np.testing.assert_allclose(np.asarray(app(x, 0)), want,
                                   rtol=1e-10, atol=1e-10)


# ----------------------------------------------------------- end-to-end
class TestSolverIntegration:
    @pytest.mark.parametrize("method", ["classic", "pipelined", "sstep"])
    def test_none_bit_identical_to_unpreconditioned(self, system, method):
        a, b = system
        mc = MethodConfig(name=method, s=2 if method == "sstep" else 1)
        kw = dict(t=4, max_iters=300, method=mc)
        plain = ECGSolver.build(a, config=SolverConfig(**kw)).solve(b)
        noop = ECGSolver.build(
            a, config=SolverConfig(precondition="none", **kw)
        ).solve(b)
        assert np.array_equal(np.asarray(plain.x), np.asarray(noop.x))
        assert plain.n_iters == noop.n_iters

    @pytest.mark.parametrize("method", ["classic", "pipelined", "sstep"])
    @pytest.mark.parametrize("kind", ["block_jacobi", "chebyshev"])
    def test_fixed_preconditioners_cut_iterations(self, system, method, kind):
        a, b = system
        mc = MethodConfig(name=method, s=2 if method == "sstep" else 1)
        kw = dict(t=4, tol=1e-10, max_iters=300, method=mc)
        base = ECGSolver.build(a, config=SolverConfig(**kw)).solve(b)
        prec = ECGSolver.build(
            a, config=SolverConfig(precondition=kind, **kw)
        ).solve(b)
        assert base.converged and prec.converged
        assert prec.n_iters < base.n_iters
        x_ref = np.linalg.solve(_dense(a), b)
        np.testing.assert_allclose(np.asarray(prec.x), x_ref, rtol=1e-6)

    @pytest.mark.parametrize(
        "gen,kind",
        [
            (lambda: aniso_laplace_2d(16, eps=0.01), "block_jacobi"),
            (lambda: aniso_laplace_2d(16, eps=0.01), "chebyshev"),
            (lambda: scaled_laplace_2d(16, decades=4.0), "block_jacobi"),
        ],
    )
    def test_ill_conditioned_acceptance(self, gen, kind):
        """ISSUE acceptance: preconditioning reduces iterations on
        ill-conditioned operators at the same t / method.  (Chebyshev with
        default bounds is honest about its limits: it is *not* asserted on
        the diagonally-scaled matrix, whose κ≈1e8 defeats eig_ratio=30.)"""
        a = gen()
        b = np.random.default_rng(1).standard_normal(a.shape[0])
        kw = dict(t=4, tol=1e-9, max_iters=3000)
        base = ECGSolver.build(a, config=SolverConfig(**kw)).solve(b)
        prec = ECGSolver.build(
            a, config=SolverConfig(precondition=kind, **kw)
        ).solve(b)
        assert prec.converged
        assert (not base.converged) or prec.n_iters < base.n_iters

    def test_inexact_flexible_converges_on_classic_and_sstep(self, system):
        a, b = system
        for mc in (MethodConfig(name="classic"),
                   MethodConfig(name="sstep", s=2)):
            res = ECGSolver.build(a, config=SolverConfig(
                t=4, tol=1e-10, max_iters=300, method=mc,
                precondition="inexact",
            )).solve(b)
            assert res.converged, f"inexact did not converge for {mc.name}"
            x_ref = np.linalg.solve(_dense(a), b)
            np.testing.assert_allclose(np.asarray(res.x), x_ref, rtol=1e-6)

    def test_pipelined_rejects_inexact(self):
        with pytest.raises(ValueError, match="pipelined.*inexact"):
            SolverConfig(method="pipelined", precondition="inexact")

    def test_classic_inexact_without_reseed_stagnates(self, system):
        """Pin the flexible-ECG finding: the classic direction chain never
        re-reads the residual, so an iteration-varying M⁻¹ₖ *without* the
        periodic residual reseed stagnates (truncated-FCG failure mode,
        Notay SISC 22(4) 2000).  The reseed is what makes it converge."""
        a, b = system
        cfg = PreconditionConfig(kind="inexact")
        app = build_sequential_preconditioner(
            a, cfg, lambda v: csr_spmbv(a, v)
        )
        kw = dict(tol=1e-10, max_iters=250, precond=app)
        bad = _ecg_solve(lambda v: csr_spmbv(a, v), jnp.asarray(b), 4,
                         precond_reseed=None, **kw)
        good = _ecg_solve(lambda v: csr_spmbv(a, v), jnp.asarray(b), 4,
                          precond_reseed=cfg.reseed, **kw)
        assert good.converged and not bad.converged

    def test_with_config_reuses_operator_and_precond(self, system):
        a, b = system
        s = ECGSolver.build(a, config=SolverConfig(
            t=4, max_iters=300, precondition="block_jacobi"))
        s2 = s.with_config(tol=1e-6)
        assert s2.stats.op_reused
        assert s2.solve(b).converged
        # changing the preconditioner keeps the operator, rebuilds the apply
        s3 = s.with_config(precondition="chebyshev")
        assert s3.stats.op_reused
        assert s3.config.precondition.kind == "chebyshev"
        assert s3.solve(b).converged


# --------------------------------------------------------------- config
class TestPreconditionConfigRoundTrip:
    def test_json_round_trip(self):
        cfg = SolverConfig(
            t=4, precondition=PreconditionConfig(
                kind="chebyshev", degree=5, eig_bounds=(0.1, 7.5)),
        )
        back = SolverConfig.from_json(cfg.to_json())
        assert back == cfg
        assert back.precondition.eig_bounds == (0.1, 7.5)

    def test_flat_replace_spellings(self):
        cfg = SolverConfig(t=4)
        c2 = cfg.replace(precondition="block_jacobi", block=16)
        assert c2.precondition.kind == "block_jacobi"
        assert c2.precondition.block == 16
        assert cfg.precondition.kind == "none"  # original untouched

    def test_coerce_forms(self):
        assert PreconditionConfig.coerce(None) == PreconditionConfig()
        assert PreconditionConfig.coerce("chebyshev").kind == "chebyshev"
        assert PreconditionConfig.coerce(
            {"kind": "block_jacobi", "block": 8}).block == 8
        c = PreconditionConfig(kind="inexact", sweeps=3)
        assert PreconditionConfig.coerce(c) is c
