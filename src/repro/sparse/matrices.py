"""Sparse test-matrix generators.

``dg_laplace_2d`` reproduces the *structure* of the paper's Example 2.1 (a
discontinuous-Galerkin discretization of the Laplacian on the unit square:
dense element blocks on a 5-point element stencil).  At full scale
(``elements=(320, 256), block=16``) it yields exactly 1 310 720 rows and
~104.5M nonzeros (within 0.04% of the paper's 104 529 920 — the tiny gap is
boundary-face bookkeeping of the unknown exact MFEM grid).

The SuiteSparse matrices of Table 3 cannot be downloaded in this offline
container; ``suite_surrogate`` generates *structural surrogates* matched to
published rows / nnz-per-row / density (see DESIGN.md §5).
"""

from __future__ import annotations

import dataclasses

import numpy as np
import jax.numpy as jnp

from repro.sparse.csr import CSRMatrix


def _kron_block_csr(
    indptr: np.ndarray,
    indices: np.ndarray,
    data: np.ndarray,
    n: int,
    block: np.ndarray,
) -> CSRMatrix:
    """CSR(L) ⊗ dense SPD block  ->  CSR.  Kronecker of SPD x SPD is SPD."""
    b = block.shape[0]
    nnz = len(indices)
    # each scalar nonzero becomes a dense b x b block
    new_indptr = np.zeros(n * b + 1, dtype=np.int64)
    row_counts = np.diff(indptr)
    per_row = np.repeat(row_counts, b) * b
    new_indptr[1:] = np.cumsum(per_row)

    new_indices = np.empty(nnz * b * b, dtype=np.int32)
    new_data = np.empty(nnz * b * b, dtype=block.dtype)
    pos = 0
    col_offsets = np.arange(b, dtype=np.int32)
    for i in range(n):
        s, e = indptr[i], indptr[i + 1]
        cols = indices[s:e]
        vals = data[s:e]
        # block row layout: for each of the b sub-rows, all (col, b) entries
        blk_cols = (cols[:, None] * b + col_offsets[None, :]).reshape(-1)  # (k*b,)
        k = e - s
        for r in range(b):
            chunk = (vals[:, None] * block[r][None, :]).reshape(-1)
            new_indices[pos : pos + k * b] = blk_cols
            new_data[pos : pos + k * b] = chunk
            pos += k * b
    return CSRMatrix(
        indptr=jnp.asarray(new_indptr, jnp.int32),
        indices=jnp.asarray(new_indices),
        data=jnp.asarray(new_data),
        shape=(n * b, n * b),
    )


def _grid_laplacian_2d(nx: int, ny: int) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """5-point Laplacian (Dirichlet) on an nx x ny grid, scalar CSR arrays."""
    n = nx * ny
    idx = np.arange(n).reshape(nx, ny)
    rows, cols, vals = [], [], []
    for i in range(nx):
        for j in range(ny):
            r = idx[i, j]
            rows.append(r), cols.append(r), vals.append(4.0)
            for di, dj in ((-1, 0), (1, 0), (0, -1), (0, 1)):
                ii, jj = i + di, j + dj
                if 0 <= ii < nx and 0 <= jj < ny:
                    rows.append(r), cols.append(idx[ii, jj]), vals.append(-1.0)
    return _coo_to_csr(np.array(rows), np.array(cols), np.array(vals), n)


def _grid_laplacian_3d(nx: int, ny: int, nz: int):
    n = nx * ny * nz
    idx = np.arange(n).reshape(nx, ny, nz)
    rows, cols, vals = [], [], []
    for i in range(nx):
        for j in range(ny):
            for k in range(nz):
                r = idx[i, j, k]
                rows.append(r), cols.append(r), vals.append(6.0)
                for d in ((-1, 0, 0), (1, 0, 0), (0, -1, 0), (0, 1, 0), (0, 0, -1), (0, 0, 1)):
                    ii, jj, kk = i + d[0], j + d[1], k + d[2]
                    if 0 <= ii < nx and 0 <= jj < ny and 0 <= kk < nz:
                        rows.append(r), cols.append(idx[ii, jj, kk]), vals.append(-1.0)
    return _coo_to_csr(np.array(rows), np.array(cols), np.array(vals), n)


def _coo_to_csr(rows, cols, vals, n):
    order = np.lexsort((cols, rows))
    rows, cols, vals = rows[order], cols[order], vals[order]
    indptr = np.zeros(n + 1, dtype=np.int64)
    np.add.at(indptr[1:], rows, 1)
    indptr = np.cumsum(indptr)
    return indptr, cols.astype(np.int32), vals.astype(np.float64)


def _permute_graph(indptr, cols, vals, n, perm):
    """Symmetric permutation  A -> P A Pᵀ  of a scalar CSR graph."""
    inv = np.empty_like(perm)
    inv[perm] = np.arange(n)
    rows = np.repeat(np.arange(n, dtype=np.int64), np.diff(indptr))
    return _coo_to_csr(inv[rows], inv[cols], vals, n)


def window_shuffle_perm(n: int, window: int, seed: int = 0) -> np.ndarray:
    """Permutation shuffling ids within windows — emulates the 'natural'
    (non-graph-partitioned) ordering of unstructured FE meshes, which scatters
    geometric neighbours across nearby index ranges.  Used for the SuiteSparse
    surrogates so comm graphs show the paper's message heterogeneity."""
    rng = np.random.default_rng(seed)
    perm = np.arange(n)
    for s in range(0, n, window):
        e = min(s + window, n)
        perm[s:e] = rng.permutation(perm[s:e])
    return perm


def _spd_block(b: int, seed: int = 7) -> np.ndarray:
    """Deterministic dense SPD b x b block with unit diagonal scale."""
    rng = np.random.default_rng(seed)
    q = rng.standard_normal((b, b))
    m = q @ q.T / b + np.eye(b)
    return (m / np.linalg.norm(m, 2)).astype(np.float64) * 2.0


def fd_laplace_2d(nx: int, ny: int | None = None, dtype=jnp.float64) -> CSRMatrix:
    """5-point finite-difference Laplacian, Dirichlet BCs (SPD)."""
    ny = ny or nx
    indptr, cols, vals = _grid_laplacian_2d(nx, ny)
    return CSRMatrix(
        indptr=jnp.asarray(indptr, jnp.int32),
        indices=jnp.asarray(cols),
        data=jnp.asarray(vals, dtype),
        shape=(nx * ny, nx * ny),
    )


def fd_laplace_3d(nx: int, ny: int | None = None, nz: int | None = None, dtype=jnp.float64) -> CSRMatrix:
    ny, nz = ny or nx, nz or nx
    indptr, cols, vals = _grid_laplacian_3d(nx, ny, nz)
    n = nx * ny * nz
    return CSRMatrix(
        indptr=jnp.asarray(indptr, jnp.int32),
        indices=jnp.asarray(cols),
        data=jnp.asarray(vals, dtype),
        shape=(n, n),
    )


def dg_laplace_2d(
    elements: tuple[int, int] = (32, 32),
    block: int = 16,
    dtype=jnp.float64,
) -> CSRMatrix:
    """DG-structured Laplacian: dense ``block``-sized element blocks on the
    5-point element stencil (Example 2.1 surrogate).  SPD by construction
    (Kronecker of SPD factors)."""
    nx, ny = elements
    indptr, cols, vals = _grid_laplacian_2d(nx, ny)
    mat = _kron_block_csr(indptr, cols, vals, nx * ny, _spd_block(block))
    return CSRMatrix(mat.indptr, mat.indices, mat.data.astype(dtype), mat.shape)


def aniso_laplace_2d(
    nx: int, ny: int | None = None, eps: float = 0.01, dtype=jnp.float64
) -> CSRMatrix:
    """Anisotropic 5-point Laplacian: −u_xx − eps·u_yy (Dirichlet, SPD).

    ``eps`` ≪ 1 stretches the spectrum — the condition number grows like
    κ(isotropic)/eps, making this the standard ill-conditioned testbed where
    a preconditioner pays for itself (iterations with ``block_jacobi`` /
    ``chebyshev`` drop well below the unpreconditioned count).
    """
    if not 0 < eps <= 1:
        raise ValueError(f"eps must be in (0, 1], got {eps!r}")
    ny = ny or nx
    n = nx * ny
    idx = np.arange(n).reshape(nx, ny)
    rows, cols, vals = [], [], []
    for i in range(nx):
        for j in range(ny):
            r = idx[i, j]
            rows.append(r), cols.append(r), vals.append(2.0 + 2.0 * eps)
            for di, dj, w in ((-1, 0, 1.0), (1, 0, 1.0), (0, -1, eps), (0, 1, eps)):
                ii, jj = i + di, j + dj
                if 0 <= ii < nx and 0 <= jj < ny:
                    rows.append(r), cols.append(idx[ii, jj]), vals.append(-w)
    indptr, cols_s, vals_s = _coo_to_csr(np.array(rows), np.array(cols), np.array(vals), n)
    return CSRMatrix(
        indptr=jnp.asarray(indptr, jnp.int32),
        indices=jnp.asarray(cols_s),
        data=jnp.asarray(vals_s, dtype),
        shape=(n, n),
    )


def scaled_laplace_2d(
    nx: int,
    ny: int | None = None,
    decades: float = 4.0,
    seed: int = 0,
    dtype=jnp.float64,
) -> CSRMatrix:
    """Diagonally-scaled 5-point Laplacian: D^{1/2} L D^{1/2} with D drawn
    log-uniformly over ``decades`` orders of magnitude (SPD by congruence).

    Models wildly varying coefficients/row scales — the regime where
    (block-)Jacobi preconditioning is near-optimal, since M captures
    exactly the diagonal scaling that inflates κ.
    """
    if decades <= 0:
        raise ValueError(f"decades must be > 0, got {decades!r}")
    ny = ny or nx
    n = nx * ny
    indptr, cols, vals = _grid_laplacian_2d(nx, ny)
    rng = np.random.default_rng(seed)
    d_half = np.power(10.0, rng.uniform(-decades / 2, decades / 2, size=n))
    rows = np.repeat(np.arange(n, dtype=np.int64), np.diff(indptr))
    vals = vals * d_half[rows] * d_half[cols]
    return CSRMatrix(
        indptr=jnp.asarray(indptr, jnp.int32),
        indices=jnp.asarray(cols),
        data=jnp.asarray(vals, dtype),
        shape=(n, n),
    )


def random_spd(n: int, density: float = 0.05, seed: int = 0, dtype=jnp.float64) -> CSRMatrix:
    """Random sparse SPD: A = B Bᵀ + n·I structure via symmetrized mask."""
    rng = np.random.default_rng(seed)
    mask = rng.random((n, n)) < density
    mask = mask | mask.T
    np.fill_diagonal(mask, True)
    vals = rng.standard_normal((n, n)) * mask
    vals = (vals + vals.T) / 2
    # diagonal dominance => SPD
    np.fill_diagonal(vals, np.abs(vals).sum(axis=1) + 1.0)
    dense = vals
    rows, cols = np.nonzero(dense)
    indptr, cols_s, vals_s = _coo_to_csr(rows, cols, dense[rows, cols], n)
    return CSRMatrix(
        indptr=jnp.asarray(indptr, jnp.int32),
        indices=jnp.asarray(cols_s),
        data=jnp.asarray(vals_s, dtype),
        shape=(n, n),
    )


@dataclasses.dataclass(frozen=True)
class SuiteSpec:
    """Published stats (paper Table 3) + surrogate generator parameters."""

    rows: int
    nnz: int
    nnz_per_row: float
    # surrogate params: block size + element grid (2D) or grid (3D stencil)
    block: int
    grid: tuple[int, ...]
    # id-shuffle window (elements) emulating the unstructured natural ordering;
    # 0 = keep the structured ordering
    window: int = 2048


# Table 3 of the paper.  Surrogate: dense `block` blocks on a 5-pt (2D) or
# 7-pt (3D, thermal2) stencil, grid sized so rows and nnz/row approximate the
# published values (rows_surrogate = block * prod(grid)).
SUITE_MATRICES: dict[str, SuiteSpec] = {
    "audikw_1": SuiteSpec(943_695, 77_651_847, 82.3, 16, (243, 243)),
    "Geo_1438": SuiteSpec(1_437_960, 60_236_322, 41.9, 8, (424, 424)),
    "bone010": SuiteSpec(986_703, 47_851_783, 48.5, 9, (331, 331)),
    "Emilia_923": SuiteSpec(923_136, 40_373_538, 43.7, 9, (320, 320)),
    "Flan_1565": SuiteSpec(1_565_794, 114_165_372, 72.9, 15, (323, 323)),
    "Hook_1498": SuiteSpec(1_498_023, 59_374_451, 39.6, 8, (433, 433)),
    "ldoor": SuiteSpec(952_203, 42_493_817, 44.6, 9, (325, 325)),
    "Serena": SuiteSpec(1_391_349, 64_131_971, 46.1, 9, (393, 393)),
    "thermal2": SuiteSpec(1_228_045, 8_580_313, 7.0, 1, (107, 107, 107)),
}

#: Example 2.1 of the paper: 1 310 720 rows, ~104.5M nnz at full scale.
EXAMPLE_2_1 = dict(elements=(320, 256), block=16)


def suite_surrogate(name: str, scale: float = 1.0, dtype=jnp.float64) -> CSRMatrix:
    """Structural surrogate of a Table-3 matrix (optionally scaled down).

    ``scale`` < 1 shrinks the grid linearly (rows shrink ~quadratically for 2D
    surrogates); structure class (block size, stencil) is preserved.
    """
    spec = SUITE_MATRICES[name]
    grid = tuple(max(2, int(g * scale)) for g in spec.grid)
    if len(grid) == 3:
        indptr, cols, vals = _grid_laplacian_3d(*grid)
        n = grid[0] * grid[1] * grid[2]
    else:
        indptr, cols, vals = _grid_laplacian_2d(*grid)
        n = grid[0] * grid[1]
    if spec.window:
        window = max(16, int(spec.window * scale))
        perm = window_shuffle_perm(n, window, seed=hash(name) % 2**31)
        indptr, cols, vals = _permute_graph(indptr, cols, vals, n, perm)
    if spec.block == 1:
        return CSRMatrix(
            indptr=jnp.asarray(indptr, jnp.int32),
            indices=jnp.asarray(cols),
            data=jnp.asarray(vals, dtype),
            shape=(n, n),
        )
    mat = _kron_block_csr(indptr, cols, vals, n, _spd_block(spec.block))
    return CSRMatrix(mat.indptr, mat.indices, mat.data.astype(dtype), mat.shape)


def surrogate_graph(name: str, scale: float = 1.0) -> tuple[CSRMatrix, int]:
    """Element-level graph of a Table-3 surrogate + its ``row_block`` factor.

    Communication statistics computed on this graph with
    ``build_comm_graph(..., row_block=block)`` are identical to dof-level
    statistics when partitions align to element blocks (DESIGN.md §5) — and
    ~block² cheaper to build, so full published scale is tractable.
    """
    spec = SUITE_MATRICES[name]
    grid = tuple(max(2, int(g * scale)) for g in spec.grid)
    if len(grid) == 3:
        indptr, cols, vals = _grid_laplacian_3d(*grid)
    else:
        indptr, cols, vals = _grid_laplacian_2d(*grid)
    n = int(np.prod(grid))
    if spec.window:
        window = max(16, int(spec.window * scale))
        perm = window_shuffle_perm(n, window, seed=hash(name) % 2**31)
        indptr, cols, vals = _permute_graph(indptr, cols, vals, n, perm)
    g = CSRMatrix(
        indptr=jnp.asarray(indptr, jnp.int32),
        indices=jnp.asarray(cols),
        data=jnp.asarray(vals),
        shape=(n, n),
    )
    return g, spec.block


def example_2_1_graph(scale: float = 1.0) -> tuple[CSRMatrix, int]:
    """Element-level graph of Example 2.1 (320x256 elements, block 16)."""
    nx, ny = EXAMPLE_2_1["elements"]
    nx, ny = max(2, int(nx * scale)), max(2, int(ny * scale))
    indptr, cols, vals = _grid_laplacian_2d(nx, ny)
    g = CSRMatrix(
        indptr=jnp.asarray(indptr, jnp.int32),
        indices=jnp.asarray(cols),
        data=jnp.asarray(vals),
        shape=(nx * ny, nx * ny),
    )
    return g, EXAMPLE_2_1["block"]
