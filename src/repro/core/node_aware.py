"""Static exchange plans for node-aware SpMBV communication.

Each strategy (standard / 2-step / 3-step / nodal-optimal) compiles, at setup
time, into a common IR — a sequence of :class:`ExchangeStep` rounds — that the
shard_map executor in ``repro.sparse.spmbv`` replays with ``lax.ppermute``.
This mirrors the paper's design exactly: the communication *schedule* is
decided once from the matrix partition (the analogue of building the MPI
node-aware communicator), and the device program is a fixed pipeline of
gather → permute → scatter rounds.

Topology mapping (DESIGN.md §2): device grid = ("node", "proc") =
(slow-tier groups, fast-tier peers); on TPU, node=ICI-pod and proc=chip.

Round semantics, per device d with local vector x (rows it owns):
    src buffer  = x | stage
    buf         = src[gather_idx[d]]                  (c rows)
    buf         = ppermute(buf, axis, rotation offset)
    dst buffer  = dst.at[scatter_pos[d]].set(buf)     (halo | stage)
Padding rows use gather index 0 and scatter into a trailing dump slot, so
every device executes identical static shapes.

Wide-halo payload splitting (``col_split``): with enlarging factor t each
halo row is a t·f-byte payload, so for large t a single row can exceed the
§4.3 chunking granularity.  The nodal-optimal strategy may therefore compile
its plan in *column segments*: every row is split into ``col_split`` equal
column slices, indices address (row, segment) slots, and the executor
reshapes ``(rows, t) -> (rows·col_split, t/col_split)`` around the exchange.
Sub-row chunks of one wide buffer then ride different fast-tier senders —
the same byte model that splits large messages, applied inside a row.  The
choice of strategy (and of ``col_split``, tile shape, overlap) is automated
by the setup-time autotuner in :mod:`repro.tune`.

Width-aware slicing (``plan.at_width``): a plan is compiled for one block
width t, but the adaptive solver (:mod:`repro.adaptive`) retires search
directions mid-solve, so after a reduction event only ``t_active < t``
columns carry data.  ``plan.at_width(t_active)`` returns a cached sub-plan
whose row/column segments are recomputed for exactly ``t_active`` columns —
the message payload shrinks to ``t_active·rows·f`` bytes instead of riding
the full-width plan as zero columns.  Row-granular plans (``col_split == 1``)
are width-agnostic, so the re-slice is free; col-split plans re-derive their
segment expansion (not the partition or the communication pattern — the
plan's message structure is reused, which is what makes the re-slice cheap
relative to a full ``build_exchange_plan``).

Phase grouping (``plan.phases``): consecutive steps sharing
``(axis, src, dst)`` form one *phase* — the unit the packed-buffer executor
dispatches.  Instead of gather → ppermute → scatter per step, the executor
packs one contiguous send buffer per phase (``kernels/halo_pack``), runs one
ppermute per nonzero rotation offset, and unpacks once — O(phases) gather/
scatter dispatches instead of O(steps).  Grouping is validated at build
time: within a phase no gathered slot is also written, so hoisting all
gathers ahead of all scatters is always equivalent to the per-step replay.

:func:`simulate_plan` replays any plan on the host in numpy — the bit-exact
oracle used by the tests and docs (``at_width=`` verifies sliced sub-plans).
"""

from __future__ import annotations

import dataclasses
import math
from collections import defaultdict

import numpy as np

from repro.sparse.partition import PartitionedMatrix
from repro.core.machines import MachineParams


@dataclasses.dataclass
class ExchangeStep:
    axis: str        # "node" | "proc" | "flat" (both axes, node-major)
    offset: int      # rotation offset along `axis` (0 = local move, no comm)
    src: str         # "x" | "stage"
    dst: str         # "halo" | "stage"
    gather_idx: np.ndarray   # (p, c) int32
    scatter_pos: np.ndarray  # (p, c) int32

    @property
    def width(self) -> int:
        return self.gather_idx.shape[1]


@dataclasses.dataclass
class ExchangePhase:
    """Consecutive steps sharing (axis, src, dst) — one packed-buffer round.

    The packed executor gathers all of a phase's segments into ONE contiguous
    send buffer (``gather_idx``), ppermutes each step's slice (``bounds``
    delimit them; ``offsets[i] == 0`` slices move locally), and scatters the
    whole buffer once (``scatter_pos``).
    """

    axis: str
    src: str
    dst: str
    offsets: tuple[int, ...]      # per constituent step
    bounds: tuple[int, ...]       # cumulative widths; step i = [bounds[i], bounds[i+1])
    gather_idx: np.ndarray        # (p, W) — concatenated step gathers
    scatter_pos: np.ndarray       # (p, W) — concatenated step scatters

    @property
    def width(self) -> int:
        return self.gather_idx.shape[1]


@dataclasses.dataclass
class ExchangePlan:
    strategy: str
    n_nodes: int
    ppn: int
    steps: list[ExchangeStep]
    halo_size: int   # max halo slots over devices (excl. dump slot), in segments
    stage_size: int  # max stage slots over devices (excl. dump slot), in segments
    col_split: int = 1  # column segments per row (1 = whole-row exchange)
    t: int = 1          # block width the plan was compiled for
    # width-slicing machinery: rebuild closure attached by build_exchange_plan
    # (captures the partition-derived structures) + per-width sub-plan cache
    _rebuild: object = dataclasses.field(default=None, repr=False, compare=False)
    _width_cache: dict = dataclasses.field(
        default_factory=dict, repr=False, compare=False
    )
    _phases: list = dataclasses.field(default=None, repr=False, compare=False)

    @property
    def p(self) -> int:
        return self.n_nodes * self.ppn

    @property
    def halo_rows(self) -> int:
        """Halo size in *row* units (halo_size counts column segments)."""
        return self.halo_size // self.col_split

    # ----------------------------------------------------- width slicing
    def at_width(self, t_active: int) -> "ExchangePlan":
        """Bit-exact sub-plan for ``t_active`` active columns (cached).

        Row/column segments are recomputed so one exchange moves exactly
        ``t_active`` columns per halo row.  When the existing ``col_split``
        divides ``t_active`` the index arrays are already exact at that
        width and are shared (a re-slice is then just bookkeeping); otherwise
        the segment expansion is re-derived at ``t_active`` via the rebuild
        closure — reusing the partition structures, never re-partitioning.
        """
        t_active = int(t_active)
        if t_active < 1:
            raise ValueError(f"t_active must be >= 1, got {t_active}")
        if t_active == self.t:
            return self
        hit = self._width_cache.get(t_active)
        if hit is not None:
            return hit
        if t_active % self.col_split == 0:
            # segments now carry t_active/col_split columns each; every
            # gather/scatter index is unchanged, so share the step arrays
            # (and the computed phases — executors detect the identity and
            # reuse their device-resident copies)
            sub = dataclasses.replace(
                self, t=t_active, _rebuild=self._rebuild, _width_cache={},
                _phases=self.phases,
            )
        elif self._rebuild is not None:
            sub = self._rebuild(t_active)
        else:
            raise ValueError(
                f"cannot slice a col_split={self.col_split} plan to width "
                f"{t_active} without its rebuild closure (plan not built by "
                "build_exchange_plan?)"
            )
        self._width_cache[t_active] = sub
        return sub

    # ----------------------------------------------------- phase grouping
    @property
    def phases(self) -> list[ExchangePhase]:
        """Steps grouped into packed-buffer phases (computed once, validated).

        Consecutive steps sharing (axis, src, dst) merge: their gather and
        scatter arrays concatenate along the width axis.  Validation asserts
        the within-phase hazard-freedom the packed executor relies on: for
        stage-writing phases, no slot gathered by the phase is also written
        by it, so gathering everything before scattering anything replays
        identically to the per-step order.
        """
        if self._phases is not None:
            return self._phases
        groups: list[tuple[tuple, list[ExchangeStep]]] = []
        for s in self.steps:
            key = (s.axis, s.src, s.dst)
            if groups and groups[-1][0] == key:
                groups[-1][1].append(s)
            else:
                groups.append((key, [s]))
        phases = []
        for (axis, src, dst), ss in groups:
            bounds = [0]
            for s in ss:
                bounds.append(bounds[-1] + s.width)
            gather = np.concatenate([s.gather_idx for s in ss], axis=1)
            scatter = np.concatenate([s.scatter_pos for s in ss], axis=1)
            if src == dst == "stage":
                dump = self.stage_size
                for d in range(self.p):
                    written = set(scatter[d][scatter[d] < dump].tolist())
                    read = set(gather[d].tolist())
                    assert not (written & read), (
                        f"phase ({axis},{src}->{dst}) has a within-phase "
                        f"read/write hazard on device {d}; packed execution "
                        "would reorder it"
                    )
            phases.append(
                ExchangePhase(
                    axis=axis, src=src, dst=dst,
                    offsets=tuple(s.offset for s in ss),
                    bounds=tuple(bounds),
                    gather_idx=gather, scatter_pos=scatter,
                )
            )
        self._phases = phases
        return phases

    # ------------------------------------------------- structural accounting
    def wire_bytes(self, f: int = 8, width: int | None = None) -> int:
        """Bytes one exchange moves over links (nonzero-offset rounds).

        ``width`` defaults to the plan's compiled width; the executor pads
        the applied width up to a multiple of ``col_split``, and so does
        this count — the number a sliced plan reports is exactly what its
        ppermute buffers carry.
        """
        width = self.t if width is None else width
        segw = -(-width // self.col_split)
        total = 0
        for s in self.steps:
            if s.offset == 0:
                continue
            total += int((s.scatter_pos < self._dump(s)).sum()) * segw * f
        return total

    def local_bytes(self, f: int = 8, width: int | None = None) -> int:
        """Bytes moved by offset-0 (local staging) rounds of one exchange."""
        width = self.t if width is None else width
        segw = -(-width // self.col_split)
        total = 0
        for s in self.steps:
            if s.offset != 0:
                continue
            total += int((s.scatter_pos < self._dump(s)).sum()) * segw * f
        return total

    def dispatch_count(self, packed: bool = True) -> int:
        """Executor dispatches per exchange.

        The packed executor issues one ``halo_pack`` + one ``halo_unpack``
        per *phase* plus one ppermute per nonzero rotation offset; the
        historical per-step executor issued a gather and a scatter per
        *step* on top of the same ppermutes.
        """
        n_perm = sum(1 for s in self.steps if s.offset)
        if packed:
            return 2 * len(self.phases) + n_perm
        return 2 * len(self.steps) + n_perm

    def comm_rows(self) -> dict[str, int]:
        """Rows moved per tier (for tests vs CommGraph invariants).

        Counts are in row units: segment moves of a col-split plan are
        divided back by ``col_split`` (totals per tier are always whole rows
        even when an individual split chunk carries partial rows).
        """
        inter = intra = 0
        for s in self.steps:
            if s.offset == 0:
                continue
            moved = int((s.scatter_pos < self._dump(s)).sum())
            if s.axis == "proc":
                intra += moved
            elif s.axis == "node":
                inter += moved
            else:  # flat rotation: offset decides if it crosses nodes
                src = np.arange(self.p)
                dst = (src + s.offset) % self.p
                crosses = (src // self.ppn) != (dst // self.ppn)
                per_dev = (s.scatter_pos < self._dump(s)).sum(axis=1)
                inter += int(per_dev[crosses].sum())
                intra += int(per_dev[~crosses].sum())
        cs = self.col_split
        return dict(inter=int(round(inter / cs)), intra=int(round(intra / cs)))

    def _dump(self, s: ExchangeStep) -> int:
        return self.halo_size if s.dst == "halo" else self.stage_size


# --------------------------------------------------------------------------
# message construction helpers
# --------------------------------------------------------------------------
class _Msg:
    """One logical message: rows moving src_dev -> dst_dev in a given phase."""

    __slots__ = ("src_dev", "dst_dev", "src_kind", "dst_kind", "rows", "stage_keys")

    def __init__(self, src_dev, dst_dev, src_kind, dst_kind, rows, stage_keys=None):
        self.src_dev = src_dev
        self.dst_dev = dst_dev
        self.src_kind = src_kind
        self.dst_kind = dst_kind
        self.rows = rows                       # global row ids (np.ndarray)
        self.stage_keys = stage_keys           # per-row stage keys when src/dst is stage


def _compile_phase(
    msgs: list[_Msg],
    axis: str,
    n_nodes: int,
    ppn: int,
    local_index,           # (dev, global_row) -> local x index
    halo_slot,             # (dev, global_row) -> halo slot
    stage_slot,            # (dev, key) -> stage slot (assigning on demand)
) -> list[ExchangeStep]:
    """Group messages of one phase by rotation offset; emit ExchangeSteps."""
    p = n_nodes * ppn

    def rotation(src, dst):
        if axis == "proc":
            assert src // ppn == dst // ppn
            return (dst - src) % ppn
        if axis == "node":
            assert src % ppn == dst % ppn, "node-axis rounds keep local rank"
            return (dst // ppn - src // ppn) % n_nodes
        return (dst - src) % p

    by_off: dict[int, list[_Msg]] = defaultdict(list)
    for m in msgs:
        by_off[rotation(m.src_dev, m.dst_dev)].append(m)

    steps = []
    for off in sorted(by_off):
        group = by_off[off]
        per_src: dict[int, list[_Msg]] = defaultdict(list)
        for m in group:
            per_src[m.src_dev].append(m)
        width = max(sum(len(m.rows) for m in ms) for ms in per_src.values())
        gather = np.zeros((p, width), dtype=np.int32)
        scatter = np.full((p, width), -1, dtype=np.int32)  # -1 -> dump (fixed later)
        for src_dev, ms in per_src.items():
            pos = 0
            for m in ms:
                k = len(m.rows)
                if m.src_kind == "x":
                    gather[src_dev, pos : pos + k] = [
                        local_index(src_dev, r) for r in m.rows
                    ]
                else:
                    gather[src_dev, pos : pos + k] = [
                        stage_slot(src_dev, key, create=False)
                        for key in m.stage_keys
                    ]
                if m.dst_kind == "halo":
                    scatter[m.dst_dev, pos : pos + k] = [
                        halo_slot(m.dst_dev, r) for r in m.rows
                    ]
                else:
                    scatter[m.dst_dev, pos : pos + k] = [
                        stage_slot(m.dst_dev, key, create=True)
                        for key in m.stage_keys
                    ]
                pos += k
        steps.append(
            ExchangeStep(
                axis=axis,
                offset=off,
                src=group[0].src_kind,
                dst=group[0].dst_kind,
                gather_idx=gather,
                scatter_pos=scatter,
            )
        )
    return steps


def to_node_rows(pm: PartitionedMatrix, ppn: int) -> list[dict[int, np.ndarray]]:
    """Per owner process, the dedup'd row sets destined for each *other* node
    — the 2-step message units that drive every node-aware strategy and the
    §4.3 byte model (also consumed by ``repro.tune``)."""
    node_of = lambda d: d // ppn
    out: list[dict[int, np.ndarray]] = []
    for i in range(pm.p):
        acc: dict[int, set] = defaultdict(set)
        for q, rows in pm.comms[i].send_rows.items():
            if node_of(q) != node_of(i):
                acc[node_of(q)].update(rows.tolist())
        out.append({b: np.array(sorted(s), dtype=np.int64) for b, s in acc.items()})
    return out


def _auto_col_split(to_node, t: int, machine: MachineParams, ppn: int) -> int:
    """§4.3 byte model at sub-row granularity.

    A (owner proc → dst node) unit larger than the rendezvous cutoff is split
    into ~cutoff-sized chunks across the fast tier; with row granularity the
    smallest chunk is one t·f-byte row, so a unit with few-but-wide rows may
    have fewer rows than its chunk target.  Return the smallest column-split
    factor (a divisor of t) that restores enough grains for every unit.
    """
    unit = t * machine.f
    cs = 1
    for d in to_node:
        for rows in d.values():
            size = len(rows) * unit
            if len(rows) and size >= machine.eager_cutoff:
                n_chunks = min(math.ceil(size / machine.eager_cutoff), ppn)
                cs = max(cs, math.ceil(n_chunks / len(rows)))
    cs = min(cs, t)
    while t % cs:
        cs += 1
    return cs


def build_exchange_plan(
    pm: PartitionedMatrix,
    n_nodes: int,
    ppn: int,
    strategy: str = "standard",
    t: int = 1,
    machine: MachineParams | None = None,
    col_split: int | None = None,
) -> ExchangePlan:
    """Compile the halo exchange of ``pm`` into rounds for ``strategy``.

    ``t`` and ``machine`` matter only for the nodal-optimal strategy (its
    conglomerate/split cutoff is byte-based, per §4.3).  ``col_split``
    overrides the byte-model decision to split every t-wide row into column
    segments (nodal-optimal only; must divide t; ``None`` = automatic).
    """
    p = pm.p
    assert p == n_nodes * ppn, (p, n_nodes, ppn)
    node_of = lambda d: d // ppn
    lrank = lambda d: d % ppn

    starts = pm.part.starts
    halo_sources = pm.halo_sources

    # dedup'd (owner proc -> dst node) row sets — drives both the node-aware
    # message construction and the col-split byte model (standard needs none)
    to_node = to_node_rows(pm, ppn) if strategy != "standard" else []

    cs = 1
    if strategy == "optimal":
        machine = machine or _default_machine()
        cs = col_split if col_split else _auto_col_split(to_node, t, machine, ppn)
        assert t % cs == 0, f"col_split {cs} must divide t={t}"

    # All indices are in *segment* units: global row r splits into segments
    # r·cs + j, j in [0, cs); contiguous segments of a row stay adjacent in
    # the halo so the executor can reshape back to rows.  cs == 1 degenerates
    # to the plain row-granular plan.
    def expand(rows) -> np.ndarray:
        rows = np.asarray(rows, dtype=np.int64)
        if cs == 1:
            return rows
        return (rows[:, None] * cs + np.arange(cs, dtype=np.int64)).reshape(-1)

    def local_index(dev, seg):
        return int(seg - starts[dev] * cs)

    def halo_slot(dev, seg):
        r, j = divmod(int(seg), cs)
        return int(np.searchsorted(halo_sources[dev], r)) * cs + j

    stage_maps: list[dict] = [dict() for _ in range(p)]

    def stage_slot(dev, key, create):
        m = stage_maps[dev]
        if key not in m:
            if not create:
                raise KeyError(f"stage key {key} missing on dev {dev}")
            m[key] = len(m)
        return m[key]

    # ---- per-strategy message lists (phases in execution order) -------------
    phases: list[tuple[str, list[_Msg]]] = []

    if strategy == "standard":
        msgs = []
        for i in range(p):
            for q, rows in pm.comms[i].send_rows.items():
                msgs.append(_Msg(i, q, "x", "halo", rows))
        phases.append(("flat", msgs))

    else:
        # on-node direct exchange (common to all node-aware strategies)
        onnode = []
        for i in range(p):
            for q, rows in pm.comms[i].send_rows.items():
                if node_of(q) == node_of(i):
                    onnode.append(_Msg(i, q, "x", "halo", expand(rows)))

        # which procs on node B need row r (for final redistribution)
        def dest_procs(b_node, row, owner):
            res = []
            for q in range(b_node * ppn, (b_node + 1) * ppn):
                if owner in pm.comms[q].recv_rows and row in _recv_sets[q][owner]:
                    res.append(q)
            return res

        _recv_sets = [
            {src: set(rows.tolist()) for src, rows in pm.comms[q].recv_rows.items()}
            for q in range(p)
        ]

        if strategy == "2step":
            inter, redist = [], []
            for i in range(p):
                a = node_of(i)
                for b, rows in to_node[i].items():
                    j = b * ppn + lrank(i)  # paired process
                    keys = [("s", int(r)) for r in rows]
                    inter.append(_Msg(i, j, "x", "stage", rows, stage_keys=keys))
                    # local redistribution from j's stage to final halos
                    per_dst: dict[int, list[int]] = defaultdict(list)
                    for r in rows:
                        for q in dest_procs(b, int(r), i):
                            per_dst[q].append(int(r))
                    for q, rr in per_dst.items():
                        rr = np.array(rr, dtype=np.int64)
                        kk = [("s", int(r)) for r in rr]
                        redist.append(_Msg(j, q, "stage", "halo", rr, stage_keys=kk))
            phases = [("proc", onnode), ("node", inter), ("proc", redist)]

        elif strategy == "3step":
            gather_msgs, inter, redist = [], [], []
            for a in range(n_nodes):
                dsts = sorted(
                    {b for i in range(a * ppn, (a + 1) * ppn) for b in to_node[i]}
                )
                for bi, b in enumerate(dsts):
                    h = a * ppn + bi % ppn            # gathering proc on A for dst B
                    g = b * ppn + lrank(h)            # receiving proc on B (paired)
                    rows_all, owners = [], []
                    for i in range(a * ppn, (a + 1) * ppn):
                        if b in to_node[i]:
                            rows_all.extend(int(r) for r in to_node[i][b])
                            owners.extend([i] * len(to_node[i][b]))
                    keys = [("g", b, r) for r in rows_all]
                    # phase 0: owners stage rows on the handler h
                    per_owner: dict[int, tuple[list, list]] = defaultdict(lambda: ([], []))
                    for r, o, k in zip(rows_all, owners, keys):
                        per_owner[o][0].append(r)
                        per_owner[o][1].append(k)
                    for o, (rr, kk) in per_owner.items():
                        gather_msgs.append(
                            _Msg(o, h, "x", "stage", np.array(rr), stage_keys=kk)
                        )
                    # phase 1: handler -> paired receiver on B
                    keys_r = [("r", r) for r in rows_all]
                    inter.append(
                        _Msg(h, g, "stage", "stage", np.array(rows_all), stage_keys=list(zip(keys, keys_r)))
                    )
                    # phase 2: receiver redistributes on B
                    per_dst: dict[int, tuple[list, list]] = defaultdict(lambda: ([], []))
                    for r, o in zip(rows_all, owners):
                        for q in dest_procs(b, r, o):
                            per_dst[q][0].append(r)
                            per_dst[q][1].append(("r", r))
                    for q, (rr, kk) in per_dst.items():
                        redist.append(_Msg(g, q, "stage", "halo", np.array(rr), stage_keys=kk))
            phases = [("proc", onnode), ("proc", gather_msgs), ("node", inter), ("proc", redist)]

        elif strategy == "optimal":
            cutoff = machine.eager_cutoff
            unit = t * machine.f // cs  # bytes per column segment
            gather_msgs, inter, redist = [], [], []
            for a in range(n_nodes):
                procs = list(range(a * ppn, (a + 1) * ppn))
                # 2-step units in segment grains: (owner, dst node, segs)
                units = [
                    (i, b, expand(to_node[i][b])) for i in procs for b in to_node[i]
                ]
                by_dst: dict[int, list[tuple[int, np.ndarray]]] = defaultdict(list)
                for i, b, segs in units:
                    by_dst[b].append((i, segs))
                buffers = []  # (size_bytes, dst_node, [(owner, segs)])
                for b, owners in by_dst.items():
                    small = [(i, s) for i, s in owners if len(s) * unit < cutoff]
                    large = [(i, s) for i, s in owners if len(s) * unit >= cutoff]
                    if small:
                        buffers.append(
                            (sum(len(s) for _, s in small) * unit, b, small)
                        )
                    for i, s in large:
                        # split across ~cutoff-sized chunks; with cs > 1 the
                        # grains are sub-row, so chunks of one wide buffer
                        # ride different fast-tier senders
                        n_chunks = min(math.ceil(len(s) * unit / cutoff), ppn)
                        for ch in np.array_split(s, n_chunks):
                            if len(ch):
                                buffers.append((len(ch) * unit, b, [(i, ch)]))
                buffers.sort(key=lambda x: -x[0])
                loads = {i: 0 for i in procs}
                counts = {i: 0 for i in procs}
                for size, b, parts in buffers:
                    s_dev = min(procs, key=lambda q: (loads[q], counts[q]))
                    loads[s_dev] += size
                    counts[s_dev] += 1
                    g_dev = b * ppn + lrank(s_dev)  # paired receiver (Fig 4.8 step 2)
                    segs_all, owners = [], []
                    for i, ss in parts:
                        segs_all.extend(int(x) for x in ss)
                        owners.extend([i] * len(ss))
                    keys = [("o", b, s) for s in segs_all]
                    per_owner: dict[int, tuple[list, list]] = defaultdict(lambda: ([], []))
                    for s, o, k in zip(segs_all, owners, keys):
                        per_owner[o][0].append(s)
                        per_owner[o][1].append(k)
                    for o, (ss, kk) in per_owner.items():
                        # owner == s_dev stages locally (offset-0 round, no comm)
                        gather_msgs.append(_Msg(o, s_dev, "x", "stage", np.array(ss), stage_keys=kk))
                    keys_r = [("r", s) for s in segs_all]
                    inter.append(
                        _Msg(s_dev, g_dev, "stage", "stage", np.array(segs_all), stage_keys=list(zip(keys, keys_r)))
                    )
                    per_dst: dict[int, tuple[list, list]] = defaultdict(lambda: ([], []))
                    for s, o in zip(segs_all, owners):
                        for q in dest_procs(b, s // cs, o):
                            per_dst[q][0].append(s)
                            per_dst[q][1].append(("r", s))
                    for q, (ss, kk) in per_dst.items():
                        redist.append(_Msg(g_dev, q, "stage", "halo", np.array(ss), stage_keys=kk))
            phases = [("proc", onnode), ("proc", gather_msgs), ("node", inter), ("proc", redist)]
        else:
            raise ValueError(f"unknown strategy {strategy!r}")

    # ---- compile phases; _compile_phase_stage_aware resolves the
    # (src_key, dst_key) pairs carried by stage->stage messages -------------
    steps: list[ExchangeStep] = []
    for axis, msgs in phases:
        msgs = [m for m in msgs if len(m.rows)]
        if not msgs:
            continue
        steps.extend(
            _compile_phase_stage_aware(
                msgs, axis, n_nodes, ppn, local_index, halo_slot, stage_slot
            )
        )

    halo_size = max((len(h) for h in halo_sources), default=0) * cs
    stage_size = max((len(m) for m in stage_maps), default=0)
    # fix dump slots: scatter_pos == -1 -> dump index
    for s in steps:
        dump = halo_size if s.dst == "halo" else stage_size
        s.scatter_pos = np.where(s.scatter_pos < 0, dump, s.scatter_pos)
    # width-slicing rebuild closure: reuses the partition (pm) and machine —
    # at_width only falls back to it when the existing segment granularity
    # cannot express the requested width exactly
    rebuild = lambda w: build_exchange_plan(
        pm, n_nodes, ppn, strategy, t=w, machine=machine, col_split=None
    )
    return ExchangePlan(
        strategy=strategy,
        n_nodes=n_nodes,
        ppn=ppn,
        steps=steps,
        halo_size=halo_size,
        stage_size=stage_size,
        col_split=cs,
        t=t,
        _rebuild=rebuild,
    )


def _compile_phase_stage_aware(msgs, axis, n_nodes, ppn, local_index, halo_slot, stage_slot):
    """Like _compile_phase but handles (src_key, dst_key) pairs for
    stage->stage messages."""
    p = n_nodes * ppn

    def rotation(src, dst):
        if axis == "proc":
            return (dst % ppn - src % ppn) % ppn
        if axis == "node":
            return (dst // ppn - src // ppn) % n_nodes
        return (dst - src) % p

    by_off = defaultdict(list)
    for m in msgs:
        by_off[rotation(m.src_dev, m.dst_dev)].append(m)

    steps = []
    for off in sorted(by_off):
        group = by_off[off]
        per_src = defaultdict(list)
        for m in group:
            per_src[m.src_dev].append(m)
        width = max(sum(len(m.rows) for m in ms) for ms in per_src.values())
        gather = np.zeros((p, width), dtype=np.int32)
        scatter = np.full((p, width), -1, dtype=np.int32)
        for src_dev, ms in per_src.items():
            pos = 0
            for m in ms:
                k = len(m.rows)
                pair_keys = (
                    m.src_kind == "stage"
                    and m.dst_kind == "stage"
                    and m.stage_keys
                    and isinstance(m.stage_keys[0][0], tuple)
                )
                if m.src_kind == "x":
                    gather[src_dev, pos : pos + k] = [
                        local_index(src_dev, int(r)) for r in m.rows
                    ]
                else:
                    src_keys = [kk[0] for kk in m.stage_keys] if pair_keys else m.stage_keys
                    gather[src_dev, pos : pos + k] = [
                        stage_slot(src_dev, key, create=False) for key in src_keys
                    ]
                if m.dst_kind == "halo":
                    scatter[m.dst_dev, pos : pos + k] = [
                        halo_slot(m.dst_dev, int(r)) for r in m.rows
                    ]
                else:
                    dst_keys = [kk[1] for kk in m.stage_keys] if pair_keys else m.stage_keys
                    scatter[m.dst_dev, pos : pos + k] = [
                        stage_slot(m.dst_dev, key, create=True) for key in dst_keys
                    ]
                pos += k
        steps.append(
            ExchangeStep(
                axis=axis,
                offset=off,
                src=group[0].src_kind,
                dst=group[0].dst_kind,
                gather_idx=gather,
                scatter_pos=scatter,
            )
        )
    return steps


def simulate_plan(
    plan: ExchangePlan,
    pm: PartitionedMatrix,
    x: np.ndarray,
    at_width: int | None = None,
) -> list[np.ndarray]:
    """Host-side numpy replay of an ExchangePlan — the bit-exact oracle.

    ``x`` is the global ``(n,)`` or ``(n, t)`` array being exchanged.
    Returns, per device, the halo block ``(len(halo_sources[d]), t)`` the
    device executor's gather → permute → scatter rounds would deliver; a
    correct plan satisfies ``out[d] == x[pm.halo_sources[d]]`` exactly.
    Handles col-split plans (the reshape the executor performs around the
    exchange) and runs without any devices, so tests can verify plans for
    meshes larger than the host.  ``at_width`` replays
    ``plan.at_width(at_width)`` instead — the round-trip check for
    width-sliced sub-plans (``x`` should then carry ``at_width`` columns).
    """
    if at_width is not None:
        plan = plan.at_width(at_width)
    x = np.asarray(x)
    if x.ndim == 1:
        x = x[:, None]
    p, cs = plan.p, plan.col_split
    rmax = pm.part.max_local_rows
    t = x.shape[1]
    tp = -(-t // cs) * cs  # pad width up to a multiple of cs
    w = tp // cs
    xs = np.zeros((p, rmax * cs, w), x.dtype)
    for d in range(p):
        lo, hi = pm.part.local_range(d)
        xl = np.zeros((rmax, tp), x.dtype)
        xl[: hi - lo, :t] = x[lo:hi]
        xs[d] = xl.reshape(rmax * cs, w)
    halo = np.zeros((p, plan.halo_size + 1, w), x.dtype)
    stage = np.zeros((p, plan.stage_size + 1, w), x.dtype)
    ppn, n_nodes = plan.ppn, plan.n_nodes
    for step in plan.steps:
        src = xs if step.src == "x" else stage
        buf = np.stack([src[d][step.gather_idx[d]] for d in range(p)])
        if step.offset:
            recv = np.empty_like(buf)
            for d in range(p):  # device d receives from its rotation source
                if step.axis == "proc":
                    s_dev = (d // ppn) * ppn + (d % ppn - step.offset) % ppn
                elif step.axis == "node":
                    s_dev = ((d // ppn - step.offset) % n_nodes) * ppn + d % ppn
                else:
                    s_dev = (d - step.offset) % p
                recv[d] = buf[s_dev]
            buf = recv
        dst = halo if step.dst == "halo" else stage
        for d in range(p):
            dst[d][step.scatter_pos[d]] = buf[d]
    out = []
    for d in range(p):
        h = halo[d][: plan.halo_size].reshape(-1, tp)[:, :t]
        out.append(h[: len(pm.halo_sources[d])])
    return out


def _default_machine():
    from repro.core.machines import BLUE_WATERS

    return BLUE_WATERS
