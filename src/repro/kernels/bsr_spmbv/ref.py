"""Pure-jnp oracle for the Block-ELL SpMBV kernel."""

from __future__ import annotations

import jax.numpy as jnp


def bsr_spmbv_ref(blocks: jnp.ndarray, indices: jnp.ndarray, v: jnp.ndarray) -> jnp.ndarray:
    """W = A @ V for Block-ELL A.

    blocks:  (nbr, kmax, br, bc) dense tiles (zero tiles where padded)
    indices: (nbr, kmax) block-column ids (0 where padded — safe: zero tiles)
    v:       (nbc * bc, t)
    returns: (nbr * br, t)
    """
    nbr, kmax, br, bc = blocks.shape
    t = v.shape[1]
    vt = v.reshape(-1, bc, t)                  # (nbc, bc, t)
    gathered = vt[indices]                     # (nbr, kmax, bc, t)
    out = jnp.einsum("nkrc,nkct->nrt", blocks, gathered)
    return out.reshape(nbr * br, t)
