"""Sparse substrate: containers, generators, partitioning."""

import numpy as np
import jax.numpy as jnp
import pytest
from _hypothesis_compat import given, settings, st

from repro.sparse import (
    CSRMatrix,
    csr_spmv,
    csr_spmbv,
    csr_to_bsr,
    dg_laplace_2d,
    fd_laplace_2d,
    fd_laplace_3d,
    random_spd,
    suite_surrogate,
    partition_csr,
    SUITE_MATRICES,
)
from repro.sparse.matrices import example_2_1_graph, window_shuffle_perm


def dense(a):
    return np.asarray(a.todense(), np.float64)


class TestGenerators:
    def test_fd_laplace_2d_spd(self):
        a = fd_laplace_2d(10)
        d = dense(a)
        assert np.allclose(d, d.T)
        assert np.linalg.eigvalsh(d).min() > 0

    def test_fd_laplace_3d_spd(self):
        a = fd_laplace_3d(4)
        d = dense(a)
        assert np.allclose(d, d.T)
        assert np.linalg.eigvalsh(d).min() > 0

    def test_dg_laplace_structure(self):
        # Example 2.1 shape law: rows = elements * block, nnz/row ~= 5*block
        a = dg_laplace_2d((8, 8), block=16)
        assert a.shape[0] == 8 * 8 * 16
        assert a.nnz / a.shape[0] == pytest.approx(5 * 16, rel=0.1)
        d = dense(a)
        assert np.allclose(d, d.T, atol=1e-12)
        assert np.linalg.eigvalsh(d).min() > 0

    def test_example_2_1_full_scale_stats(self):
        # At full scale the surrogate must match the paper's published size.
        g, blk = example_2_1_graph()
        rows = g.shape[0] * blk
        nnz = g.nnz * blk * blk
        assert rows == 1_310_720
        assert abs(nnz - 104_529_920) / 104_529_920 < 0.001

    def test_random_spd(self):
        a = random_spd(40, density=0.2, seed=3)
        d = dense(a)
        assert np.allclose(d, d.T)
        assert np.linalg.eigvalsh(d).min() > 0

    @pytest.mark.parametrize("name", ["Geo_1438", "thermal2"])
    def test_suite_surrogate_stats(self, name):
        spec = SUITE_MATRICES[name]
        a = suite_surrogate(name, scale=0.1)
        # structure class preserved: nnz/row within 25% of published
        assert a.nnz / a.shape[0] == pytest.approx(spec.nnz_per_row, rel=0.30)

    def test_window_shuffle_is_permutation(self):
        p = window_shuffle_perm(1000, 64, seed=5)
        assert np.array_equal(np.sort(p), np.arange(1000))


class TestSpMV:
    def test_spmv_matches_dense(self, rng):
        a = dg_laplace_2d((5, 4), block=4)
        d = dense(a)
        v = rng.standard_normal(a.shape[0])
        assert np.allclose(np.asarray(csr_spmv(a, jnp.asarray(v))), d @ v, atol=1e-10)

    @pytest.mark.parametrize("t", [1, 2, 5, 20])
    def test_spmbv_matches_dense(self, rng, t):
        a = fd_laplace_2d(9)
        d = dense(a)
        V = rng.standard_normal((a.shape[0], t))
        W = np.asarray(csr_spmbv(a, jnp.asarray(V)))
        assert np.allclose(W, d @ V, atol=1e-10)

    def test_from_dense_roundtrip(self, rng):
        m = rng.standard_normal((7, 9)) * (rng.random((7, 9)) < 0.4)
        a = CSRMatrix.from_dense(m)
        assert np.allclose(np.asarray(a.todense()), m)


class TestBSR:
    @pytest.mark.parametrize("br,bc", [(2, 2), (4, 4), (4, 8)])
    def test_bsr_roundtrip(self, rng, br, bc):
        a = dg_laplace_2d((4, 4), block=4)
        b = csr_to_bsr(a, br, bc)
        db = np.asarray(b.todense(), np.float64)[: a.shape[0], : a.shape[1]]
        assert np.allclose(db, dense(a), atol=1e-12)

    @given(
        n=st.integers(6, 24),
        br=st.sampled_from([2, 3, 4]),
        seed=st.integers(0, 2**16),
    )
    @settings(max_examples=20, deadline=None)
    def test_bsr_roundtrip_property(self, n, br, seed):
        rng = np.random.default_rng(seed)
        m = rng.standard_normal((n, n)) * (rng.random((n, n)) < 0.3)
        a = CSRMatrix.from_dense(m)
        b = csr_to_bsr(a, br, br)
        db = np.asarray(b.todense(), np.float64)[:n, :n]
        assert np.allclose(db, m, atol=1e-12)


class TestPartition:
    @pytest.mark.parametrize("p", [2, 3, 7, 8])
    def test_partitioned_spmv_reconstructs(self, rng, p):
        a = dg_laplace_2d((6, 5), block=4)
        d = dense(a)
        pm = partition_csr(a, p)
        x = rng.standard_normal(a.shape[0])
        out = np.zeros(a.shape[0])
        for r in range(p):
            lo, hi = pm.part.local_range(r)
            xloc = np.concatenate([x[lo:hi], x[pm.halo_sources[r]]])
            ptr, idx = pm.local_indptr[r], pm.local_indices[r]
            dat = np.asarray(pm.local_data[r], np.float64)
            for i in range(hi - lo):
                out[lo + i] = dat[ptr[i] : ptr[i + 1]] @ xloc[idx[ptr[i] : ptr[i + 1]]]
        assert np.allclose(out, d @ x, atol=1e-10)

    def test_send_recv_transpose(self):
        a = fd_laplace_2d(12)
        pm = partition_csr(a, 6)
        for r in range(6):
            for q, rows in pm.comms[r].recv_rows.items():
                assert np.array_equal(pm.comms[q].send_rows[r], rows)

    def test_uneven_rows(self):
        a = fd_laplace_2d(7)  # 49 rows over 4 procs
        pm = partition_csr(a, 4)
        sizes = [pm.part.local_range(r)[1] - pm.part.local_range(r)[0] for r in range(4)]
        assert sum(sizes) == 49
        assert max(sizes) - min(sizes) <= 1

    @pytest.mark.parametrize("p", [2, 4, 6])
    def test_interior_boundary_split(self, p):
        """Interior/boundary sets partition the local rows, and interior rows
        reference no halo column (the invariant the overlap schedule needs)."""
        from repro.sparse.partition import interior_boundary_split

        a = fd_laplace_2d(11)
        pm = partition_csr(a, p)
        for r, (interior, boundary) in enumerate(interior_boundary_split(pm)):
            lo, hi = pm.part.local_range(r)
            n_local = hi - lo
            assert len(interior) + len(boundary) == n_local
            assert not set(interior) & set(boundary)
            ptr, ix = pm.local_indptr[r], pm.local_indices[r]
            for row in interior:
                assert (ix[ptr[row] : ptr[row + 1]] < n_local).all()
            for row in boundary:
                assert (ix[ptr[row] : ptr[row + 1]] >= n_local).any()


def _suite_small(name, dtype=jnp.float64):
    """Small-but-representative scale per suite family: 2D block surrogates
    shrink quadratically, the 3D scalar one cubically."""
    spec = SUITE_MATRICES[name]
    scale = 0.06 if spec.block == 1 else 0.035
    return suite_surrogate(name, scale=scale, dtype=dtype)


class TestSuiteInvariants:
    """Every Table-3 surrogate must be a genuine SPD operator at any scale:
    exactly symmetric, positive definite, and with the diagonal dominating
    each row (the structural property the Laplacian-plus-block construction
    promises).  These invariants are what the preconditioner builders
    (Cholesky block factors, Chebyshev bounds, positive diagonals) rely on."""

    @pytest.mark.parametrize("name", sorted(SUITE_MATRICES))
    def test_symmetric_spd_diag_dominant(self, name):
        a = _suite_small(name)
        d = dense(a)
        assert d.shape[0] >= 32  # scale kept it non-degenerate
        np.testing.assert_allclose(d, d.T, atol=1e-12)
        assert np.linalg.eigvalsh(d).min() > 0
        diag = np.diag(d)
        assert (diag > 0).all()
        if SUITE_MATRICES[name].block == 1:
            # scalar stencils are weakly diagonally dominant; the kron-block
            # surrogates are SPD by construction but trade dominance for the
            # published nnz/row, so only the scalar family asserts it
            off = np.abs(d).sum(axis=1) - np.abs(diag)
            assert (diag >= off * (1 - 1e-12)).all(), (
                f"{name}: diagonal dominance violated"
            )

    @pytest.mark.parametrize("name", sorted(SUITE_MATRICES))
    @pytest.mark.parametrize("dtype", [jnp.float64, jnp.float32])
    def test_convergence_smoke(self, name, dtype):
        """ECG at t=4 converges on every surrogate in both dtypes."""
        from repro.solver import ECGSolver, SolverConfig

        a = _suite_small(name, dtype=dtype)
        b = np.random.default_rng(11).standard_normal(a.shape[0]).astype(
            np.float64 if dtype == jnp.float64 else np.float32
        )
        tol = 1e-9 if dtype == jnp.float64 else 5e-4
        res = ECGSolver.build(
            a, config=SolverConfig(t=4, tol=tol, max_iters=4000)
        ).solve(b)
        assert res.converged, f"{name}/{np.dtype(dtype).name} did not converge"
        relres = np.linalg.norm(
            dense(a) @ np.asarray(res.x, np.float64) - b
        ) / np.linalg.norm(b)
        assert relres < (1e-7 if dtype == jnp.float64 else 5e-2)

    def test_dtype_respected(self):
        a32 = _suite_small("thermal2", dtype=jnp.float32)
        assert a32.data.dtype == jnp.float32


class TestIllConditionedGenerators:
    def test_aniso_laplace_2d_spd_and_conditioning(self):
        from repro.sparse import aniso_laplace_2d

        eps = 0.01
        a = aniso_laplace_2d(12, eps=eps)
        d = dense(a)
        np.testing.assert_allclose(d, d.T, atol=1e-12)
        ev = np.linalg.eigvalsh(d)
        assert ev.min() > 0
        # the stencil is genuinely anisotropic: x-coupling −1, y-coupling −eps
        np.testing.assert_allclose(np.diag(d), 2 + 2 * eps)
        np.testing.assert_allclose(d[0, 12], -1.0)  # x neighbor (row-major y,x)
        np.testing.assert_allclose(d[0, 1], -eps)   # y neighbor
        # small eigenvalues cluster: many more modes below the isotropic
        # minimum, which is what slows unpreconditioned CG down
        iso_min = np.linalg.eigvalsh(dense(fd_laplace_2d(12))).min()
        assert (ev < iso_min).sum() >= 8
        with pytest.raises(ValueError, match="eps"):
            aniso_laplace_2d(8, eps=0.0)

    def test_scaled_laplace_2d_spd_and_conditioning(self):
        from repro.sparse import scaled_laplace_2d

        a = scaled_laplace_2d(12, decades=4.0, seed=0)
        d = dense(a)
        np.testing.assert_allclose(d, d.T, atol=1e-9)
        ev = np.linalg.eigvalsh(d)
        assert ev.min() > 0
        iso = dense(fd_laplace_2d(12))
        ev_iso = np.linalg.eigvalsh(iso)
        assert ev.max() / ev.min() > 100 * ev_iso.max() / ev_iso.min()
        # seeds are reproducible and distinct
        same = dense(scaled_laplace_2d(12, decades=4.0, seed=0))
        np.testing.assert_array_equal(d, same)
        other = dense(scaled_laplace_2d(12, decades=4.0, seed=1))
        assert not np.array_equal(d, other)
        with pytest.raises(ValueError, match="decades"):
            scaled_laplace_2d(8, decades=0.0)
