"""Quickstart: solve a DG-Laplace system with ECG vs CG (paper Fig 3.2).

    PYTHONPATH=src python examples/quickstart.py
"""

import jax

jax.config.update("jax_enable_x64", True)

import numpy as np
import jax.numpy as jnp

from repro.sparse import dg_laplace_2d, csr_spmv
from repro.core import cg_solve
from repro.solver import ECGSolver, SolverConfig


def main():
    # Example 2.1 structure at reduced scale: DG element blocks on a 2-D grid
    a = dg_laplace_2d((16, 16), block=16)  # 4096 rows, ~80 nnz/row
    rng = np.random.default_rng(0)
    b = jnp.asarray(rng.standard_normal(a.shape[0]))
    print(f"system: {a.shape[0]} unknowns, {a.nnz} nonzeros")

    res = cg_solve(lambda v: csr_spmv(a, v), b, tol=1e-8, max_iters=4000)
    print(f"CG          : {res.n_iters:4d} iterations")

    for t in (2, 4, 8, 16):
        solver = ECGSolver.build(a, config=SolverConfig(t=t, tol=1e-8, max_iters=4000))
        res = solver.solve(b)
        print(f"ECG (t={t:2d})  : {res.n_iters:4d} iterations, converged={res.converged}")

    print("\nECG trades fewer iterations (fewer allreduces) for t-times denser")
    print("SpMBV messages — the communication trade the paper optimizes.")


if __name__ == "__main__":
    main()
