"""Pallas TPU kernels: packed halo-exchange send/recv buffers.

One exchange *phase* of an :class:`~repro.core.node_aware.ExchangePlan`
moves many (row, column-segment) slots at once.  The historical executor
issued one XLA gather and one scatter per *step*; these kernels assemble the
whole phase in two dispatches:

* ``halo_pack`` — gather: ``out[i] = src[idx[i]]``.  Scalar-prefetched slot
  indices drive the ``index_map`` of the source operand (the same pattern as
  the Block-ELL V operand in ``kernels/bsr_spmbv``), so each packed row
  streams HBM → VMEM exactly once, in send-buffer order — the buffer the
  ppermute rounds then slice is contiguous by construction.
* ``halo_unpack`` — scatter: ``dst[pos[i]] = buf[i]``, with ``dst`` aliased
  to the output so slots the phase does not write keep their prior contents
  (earlier phases' deliveries).  Out-of-range positions are pre-clamped by
  the plan to the trailing dump slot, so every program writes a valid block.

Row blocks are (1, w) with w = t_active/col_split — narrow for the lane
width, but the packed layout is what buys the win: the per-phase dispatch
count is O(1) instead of O(steps), and the ppermute payload is exactly the
active-width bytes.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _pack_kernel(idx_ref, src_ref, out_ref):
    del idx_ref  # consumed by the index_map (scalar prefetch)
    out_ref[...] = src_ref[...]


@functools.partial(jax.jit, static_argnames=("interpret",))
def halo_pack_pallas(src, idx, *, interpret: bool = False):
    """src (m, w); idx (c,) int32 -> packed (c, w) = src[idx]."""
    c = idx.shape[0]
    w = src.shape[1]
    return pl.pallas_call(
        _pack_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(c,),
            in_specs=[pl.BlockSpec((1, w), lambda i, idx: (idx[i], 0))],
            out_specs=pl.BlockSpec((1, w), lambda i, idx: (i, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((c, w), src.dtype),
        interpret=interpret,
    )(idx, src)


def _unpack_kernel(pos_ref, dst_ref, buf_ref, out_ref):
    del pos_ref, dst_ref  # position drives the out index_map; dst aliases out
    out_ref[...] = buf_ref[...]


@functools.partial(jax.jit, static_argnames=("interpret",), donate_argnums=(0,))
def halo_unpack_pallas(dst, buf, pos, *, interpret: bool = False):
    """dst (m, w); buf (c, w); pos (c,) int32 -> dst.at[pos].set(buf).

    ``dst`` is donated and aliased to the output: slots not named by ``pos``
    keep their previous contents without a copy.
    """
    c = pos.shape[0]
    m, w = dst.shape
    return pl.pallas_call(
        _unpack_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(c,),
            in_specs=[
                pl.BlockSpec((1, w), lambda i, pos: (pos[i], 0)),
                pl.BlockSpec((1, w), lambda i, pos: (i, 0)),
            ],
            out_specs=pl.BlockSpec((1, w), lambda i, pos: (pos[i], 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((m, w), dst.dtype),
        input_output_aliases={1: 0},  # dst (first post-prefetch operand) -> out
        interpret=interpret,
    )(pos, dst, buf)
