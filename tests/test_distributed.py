"""Multi-device distributed tests (8 forced host devices, subprocess-isolated
so the rest of the suite keeps a single device)."""

import os
import subprocess
import sys
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parents[1]


@pytest.mark.slow
def test_distributed_spmbv_and_ecg():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = str(ROOT / "src")
    # (dist_worker.py installs its own repro-internal DeprecationWarning →
    # error filter: PYTHONWARNINGS cannot express a module regex)
    proc = subprocess.run(
        [sys.executable, str(ROOT / "tests" / "dist_worker.py")],
        env=env,
        capture_output=True,
        text=True,
        timeout=1200,
    )
    assert proc.returncode == 0, f"STDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr[-4000:]}"
    assert "ALL DISTRIBUTED CHECKS PASSED" in proc.stdout
