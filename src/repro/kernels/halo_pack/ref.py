"""Pure-jnp oracles for the packed halo-buffer kernels."""

from __future__ import annotations


def halo_pack_ref(src, idx):
    """out[i] = src[idx[i]] — one fused gather for a whole exchange phase."""
    return src[idx]


def halo_unpack_ref(dst, buf, pos):
    """dst[pos[i]] = buf[i]; untouched slots keep their prior contents."""
    return dst.at[pos].set(buf)
