"""Pallas TPU kernels: fused ECG block-vector updates.

X += P·c and R -= AP·c share the (t x t) coefficient block c; fusing them
halves kernel dispatches and lets each (rows, t) tile of X/R be updated while
P/AP tiles are VMEM-resident.  Grid: 1-D over row tiles; c is broadcast to
every step (small, stays in VMEM).

``ecg_tail_pallas`` extends the fusion to the whole per-iteration tail of
Algorithm 3 — X += P·c, R -= AP·c, Z = AP − P·d − P_old·d_old — so each
(rows, t) tile of P and AP is read from HBM exactly once and feeds three
small MXU matmuls while VMEM-resident (P feeds both the X and Z updates, AP
feeds both the R and Z updates).  The unfused formulation reads P and AP
twice each: 7 tile reads instead of 5 (a 1.4x traffic cut on the tail).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, r_ref, p_ref, ap_ref, c_ref, xo_ref, ro_ref):
    c = c_ref[...]
    xo_ref[...] = x_ref[...] + jnp.dot(p_ref[...], c, preferred_element_type=x_ref.dtype)
    ro_ref[...] = r_ref[...] - jnp.dot(ap_ref[...], c, preferred_element_type=r_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def block_update_pallas(x, r, p, ap, c, *, block_rows: int = 512, interpret: bool = False):
    n, t = x.shape
    n_pad = (n + block_rows - 1) // block_rows * block_rows
    pad = lambda a: jnp.pad(a, ((0, n_pad - n), (0, 0)))
    xp, rp, pp, app = map(pad, (x, r, p, ap))
    grid = (n_pad // block_rows,)
    spec = pl.BlockSpec((block_rows, t), lambda i: (i, 0))
    cspec = pl.BlockSpec((t, t), lambda i: (0, 0))
    xo, ro = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[spec, spec, spec, spec, cspec],
        out_specs=[spec, spec],
        out_shape=[
            jax.ShapeDtypeStruct((n_pad, t), x.dtype),
            jax.ShapeDtypeStruct((n_pad, t), r.dtype),
        ],
        interpret=interpret,
    )(xp, rp, pp, app, c)
    return xo[:n], ro[:n]


def _tail_kernel(x_ref, r_ref, p_ref, ap_ref, po_ref, c_ref, d_ref, do_ref,
                 xo_ref, ro_ref, zo_ref):
    p, ap = p_ref[...], ap_ref[...]
    acc = xo_ref.dtype
    xo_ref[...] = x_ref[...] + jnp.dot(p, c_ref[...], preferred_element_type=acc)
    ro_ref[...] = r_ref[...] - jnp.dot(ap, c_ref[...], preferred_element_type=acc)
    zo_ref[...] = (
        ap
        - jnp.dot(p, d_ref[...], preferred_element_type=acc)
        - jnp.dot(po_ref[...], do_ref[...], preferred_element_type=acc)
    )


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def ecg_tail_pallas(x, r, p, ap, p_old, c, d, d_old, *, block_rows: int = 512,
                    interpret: bool = False):
    """Fused ECG tail: (X+P·c, R−AP·c, AP−P·d−P_old·d_old) in one row pass."""
    n, t = x.shape
    n_pad = (n + block_rows - 1) // block_rows * block_rows
    pad = lambda a: jnp.pad(a, ((0, n_pad - n), (0, 0)))
    xp, rp, pp, app, pop = map(pad, (x, r, p, ap, p_old))
    grid = (n_pad // block_rows,)
    spec = pl.BlockSpec((block_rows, t), lambda i: (i, 0))
    cspec = pl.BlockSpec((t, t), lambda i: (0, 0))
    xo, ro, zo = pl.pallas_call(
        _tail_kernel,
        grid=grid,
        in_specs=[spec, spec, spec, spec, spec, cspec, cspec, cspec],
        out_specs=[spec, spec, spec],
        out_shape=[
            jax.ShapeDtypeStruct((n_pad, t), x.dtype),
            jax.ShapeDtypeStruct((n_pad, t), r.dtype),
            jax.ShapeDtypeStruct((n_pad, t), ap.dtype),
        ],
        interpret=interpret,
    )(xp, rp, pp, app, pop, c, d, d_old)
    return xo[:n], ro[:n], zo[:n]
