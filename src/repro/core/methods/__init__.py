"""Pluggable ECG iteration schemes (classic / pipelined / s-step)."""

from __future__ import annotations

from repro.core.methods.base import MethodContext, MethodSpec
from repro.core.methods.classic import ClassicMethod
from repro.core.methods.pipelined import PipelinedMethod
from repro.core.methods.sstep import SStepMethod

METHODS: dict[str, MethodSpec] = {
    "classic": ClassicMethod(),
    "pipelined": PipelinedMethod(),
    "sstep": SStepMethod(),
}


def get_method(name: str) -> MethodSpec:
    """Look up an iteration scheme by name (``KeyError``-free)."""
    try:
        return METHODS[name]
    except KeyError:
        raise ValueError(
            f"unknown method {name!r}; expected one of {sorted(METHODS)}"
        ) from None


__all__ = [
    "METHODS",
    "MethodContext",
    "MethodSpec",
    "ClassicMethod",
    "PipelinedMethod",
    "SStepMethod",
    "get_method",
]
