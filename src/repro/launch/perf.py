import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""§Perf hillclimb driver: re-lower chosen cells with optimization levers and
record hypothesis → change → before → after (EXPERIMENTS.md §Perf).

    PYTHONPATH=src python -m repro.launch.perf --out experiments/perf.json

ECG mode — measure the solver hot path instead of the transformer cells
(kernel-vs-oracle + overlap-vs-blocking, on an 8-device (2x4) sub-mesh):

    PYTHONPATH=src python -m repro.launch.perf --ecg --out experiments/ecg_perf.json
"""

import argparse
import json
import time
from pathlib import Path

import jax

from repro.configs import SHAPE_CELLS, get_config
from repro.launch.mesh import make_production_mesh
from repro.launch.dryrun import _lower_cell, _unit_layers, _n_units
from repro.analysis.roofline import (
    CellCost,
    cost_from_compiled,
    roofline_from_cost,
    model_flops,
)

# (cell, iteration-name, overrides, hypothesis)
ITERATIONS = [
    # ---------------- stablelm train_4k: memory-dominant dense baseline ----
    ("stablelm_1_6b", "train_4k", "baseline", {}, "paper-faithful baseline"),
    (
        "stablelm_1_6b", "train_4k", "attn_chunk",
        dict(attn_chunk=512),
        "memory-dom 4.71s: naive attention makes ~6 HBM passes over the S² "
        "score matrix (bf16 write, mask, fp32 convert, softmax, bf16 cast, PV "
        "read) ≈ 1.0e12 B/dev of 3.9e12 total; online-softmax tiles cut this "
        "to ~2 tile passes → predict memory −25..35%",
    ),
    (
        "stablelm_1_6b", "train_4k", "attn+loss_chunk",
        dict(attn_chunk=512, loss_chunk=512),
        "fp32 (B,S,V/16) logits + lse make ~4 passes ≈ 2e10 B/dev → predict "
        "additional memory −1..3% (small; vocab already TP-sharded)",
    ),
    # ------------- phi3-medium train_4k: worst collective term (25.4s) -----
    ("phi3_medium_14b", "train_4k", "baseline", {}, "paper-faithful baseline"),
    (
        "phi3_medium_14b", "train_4k", "gqa_fix+attn_chunk",
        dict(gqa_shard_fix=True, attn_chunk=512),
        "collective-dom 25.4s: kv=10 repeat under a seq-sharded residual "
        "forces GSPMD involuntary full remats (full-tensor all-gathers) per "
        "layer; pinning K/V to gathered-then-head-TP layout + tiled attention "
        "→ predict collective −25..45%, memory −25%",
    ),
    (
        "phi3_medium_14b", "train_4k", "no_seq_parallel",
        dict(gqa_shard_fix=True, attn_chunk=512, seq_parallel=False),
        "remaining collective: SP all-gathers activations (S/16→S) every layer "
        "fwd+bwd; disabling SP trades +16x layer-boundary activation memory "
        "for −2 all-gathers/layer → predict collective −20%, temp +",
    ),
    # ------------- phi3.5-moe train_4k: collective-bound EP (paper analogue)
    ("phi35_moe_42b", "train_4k", "baseline", {}, "paper-faithful baseline"),
    (
        "phi35_moe_42b", "train_4k", "gqa_fix+attn_chunk",
        dict(gqa_shard_fix=True, attn_chunk=512),
        "collective-dom 13.1s with kv=8: same involuntary-remat pathology as "
        "phi3-medium → predict collective −20..35%",
    ),
    (
        "phi35_moe_42b", "train_4k", "moe_scatter_combine",
        dict(gqa_shard_fix=True, attn_chunk=512, moe_scatter_combine=True),
        "EP combine is a full (B,S,D) all-reduce per layer, but the residual "
        "stream is seq-sharded (SP): reduce-scatter straight into the sharded "
        "layout moves half the bytes (RS=(p-1)/p vs AR=2(p-1)/p) — the "
        "paper's 'shape the collective to the data layout' discipline applied "
        "to MoE → predict collective −10..20%",
    ),
    # --------------------------------- round 2 (from coll_breakdown data) --
    (
        "stablelm_1_6b", "train_4k", "dense_scatter",
        dict(attn_chunk=512, loss_chunk=512, dense_scatter_combine=True),
        "AR is 106 GB/dev — dominated by row-parallel dx/out psums of "
        "(B,S,D) per layer; reduce-scatter into the SP layout halves those "
        "bytes → predict all-reduce −30..45%, collective −20..30%",
    ),
    (
        "phi3_medium_14b", "train_4k", "attn_seq_shard",
        dict(gqa_shard_fix=True, attn_chunk=512, attn_seq_shard=True),
        "AG is 521 GB/dev — the uneven 40/16 head sharding forces padded "
        "full-tensor regathers of q/k/v/o every layer (fwd+bwd+remat). "
        "Sharding attention by QUERY POSITIONS over 'model' removes head "
        "padding entirely and aligns with the seq-sharded residual → predict "
        "all-gather −50%+, collective −35%, useful-flops ratio up",
    ),
    (
        "phi3_medium_14b", "train_4k", "attn_seq+dense_scatter",
        dict(gqa_shard_fix=True, attn_chunk=512, attn_seq_shard=True,
             dense_scatter_combine=True),
        "stack the RS-combine on the MLP down-proj (d_ff=17920 divides 16 "
        "even though heads don't) → predict further all-reduce −20%",
    ),
    (
        "phi35_moe_42b", "train_4k", "moe+dense_scatter",
        dict(gqa_shard_fix=True, attn_chunk=512, moe_scatter_combine=True,
             dense_scatter_combine=True),
        "attention out-proj (32 heads, even) still all-reduces (B,S,D); "
        "RS-combine it like the MoE outputs → predict all-reduce −15%",
    ),
]


def run_iteration(arch, shape, overrides):
    cfg = get_config(arch)
    if overrides:
        cfg = cfg.with_(**overrides)
    kind, seq, batch = SHAPE_CELLS[shape]
    mesh = make_production_mesh(multi_pod=False)
    chips = int(mesh.devices.size)

    compiled, _, t_comp = _lower_cell(cfg, mesh, kind, seq, batch)
    ma = compiled.memory_analysis()
    c1, *_ = _lower_cell(_unit_layers(cfg, 1), mesh, kind, seq, batch)
    c2, *_ = _lower_cell(_unit_layers(cfg, 2), mesh, kind, seq, batch)
    cost = CellCost.extrapolate(cost_from_compiled(c1), cost_from_compiled(c2), _n_units(cfg))
    rl = roofline_from_cost(cost, chips, model_flops(cfg, kind, seq, batch))
    return dict(
        compile_s=round(t_comp, 1),
        temp_gib=round(ma.temp_size_in_bytes / 2**30, 2),
        roofline=rl.as_dict(),
        coll_breakdown={k: round(v / 1e9, 2) for k, v in cost.coll_breakdown.items()},
    )


def run_ecg_sweep(out_path: Path, only: str | None = None):
    """ECG hot-path measurements (uses 8 of the forced host devices)."""
    import numpy as np

    from repro.analysis.ecg_bench import kernel_vs_oracle, overlap_vs_blocking_sweep
    from repro.sparse import dg_laplace_2d

    jax.config.update("jax_enable_x64", True)
    mesh = jax.sharding.Mesh(
        np.array(jax.devices()[:8]).reshape(2, 4), ("node", "proc")
    )
    a = dg_laplace_2d((16, 12), block=8)
    rows = overlap_vs_blocking_sweep(a, mesh, ts=(4, 8)) + kernel_vs_oracle()
    if only:
        rows = [r for r in rows if only in r["name"]]
    for r in rows:
        print(f"ECG {r['name']}: {r['us']:.1f}us  {r['derived']}", flush=True)
    out_path.write_text(json.dumps(rows, indent=1))
    print("ecg perf pass done", flush=True)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=None,
                    help="output JSON (default: experiments/perf.json, or "
                         "experiments/ecg_perf.json with --ecg)")
    ap.add_argument("--only", default=None, help="substring filter on cell/iteration")
    ap.add_argument("--ecg", action="store_true",
                    help="run the ECG kernel/overlap sweep instead of the cells")
    args = ap.parse_args()
    if args.ecg:
        out_path = Path(args.out or "experiments/ecg_perf.json")
        out_path.parent.mkdir(parents=True, exist_ok=True)
        run_ecg_sweep(out_path, args.only)
        return
    args.out = args.out or "experiments/perf.json"
    out_path = Path(args.out)
    out_path.parent.mkdir(parents=True, exist_ok=True)
    results = json.loads(out_path.read_text()) if out_path.exists() else []
    done = {(r["arch"], r["shape"], r["iteration"]) for r in results if "error" not in r}

    for arch, shape, name, overrides, hypothesis in ITERATIONS:
        key = (arch, shape, name)
        if key in done:
            continue
        if args.only and args.only not in f"{arch}/{shape}/{name}":
            continue
        print(f"PERF {arch} x {shape} :: {name}", flush=True)
        t0 = time.time()
        try:
            rec = run_iteration(arch, shape, overrides)
            rl = rec["roofline"]
            print(
                f"  {time.time()-t0:.0f}s  compute={rl['compute_s']:.3g} "
                f"memory={rl['memory_s']:.3g} collective={rl['collective_s']:.3g} "
                f"dominant={rl['dominant']} frac={rl['roofline_fraction']:.3f} "
                f"temp={rec['temp_gib']}GiB",
                flush=True,
            )
        except Exception as e:  # noqa: BLE001
            rec = dict(error=f"{type(e).__name__}: {e}")
            print(f"  FAIL {rec['error'][:200]}", flush=True)
        rec |= dict(arch=arch, shape=shape, iteration=name,
                    overrides={k: str(v) for k, v in overrides.items()},
                    hypothesis=hypothesis)
        results = [r for r in results if (r["arch"], r["shape"], r["iteration"]) != key]
        results.append(rec)
        out_path.write_text(json.dumps(results, indent=1))
    print("perf pass done", flush=True)


if __name__ == "__main__":
    main()
