"""Width-aware exchange compaction: plan slicing, packed phases, kernels,
structural cost model, segmented solve machinery (single-device tier-1;
the 8-device executor paths live in dist_worker.py)."""

import dataclasses

import numpy as np
import pytest
import jax.numpy as jnp
from _hypothesis_compat import given, settings, st

from repro.core.machines import BLUE_WATERS, HOST
from repro.core.node_aware import build_exchange_plan, simulate_plan
from repro.sparse import dg_laplace_2d, fd_laplace_2d, partition_csr

STRATEGIES = ("standard", "2step", "3step", "optimal")


@pytest.fixture(scope="module")
def fd():
    a = fd_laplace_2d(13)
    return a, partition_csr(a, 8)


# plans are expensive to build; property examples share one per (strategy, t)
_PLAN_CACHE: dict = {}


def _cached_plan(pm, strategy, t, **kw):
    key = (strategy, t, tuple(sorted(kw.items())))
    if key not in _PLAN_CACHE:
        _PLAN_CACHE[key] = build_exchange_plan(
            pm, 2, 4, strategy, t=t, machine=BLUE_WATERS, **kw
        )
    return _PLAN_CACHE[key]


class TestAtWidth:
    @settings(max_examples=24, deadline=None)
    @given(
        strategy=st.sampled_from(STRATEGIES),
        t=st.sampled_from([4, 8]),
        ta=st.integers(min_value=1, max_value=8),
    )
    def test_round_trip_bit_exact_at_every_width(self, fd, strategy, t, ta):
        """Property: for any strategy, compile width t, and active width
        ta <= t, ``simulate_plan(plan, pm, x, at_width=ta)`` delivers halos
        bit-identical to direct gathers ``x[pm.halo_sources[d]]``.

        Runs under ``_hypothesis_compat``: real hypothesis explores the
        space when installed; the deterministic fallback sweeps the
        boundary/midpoint cartesian product (which still covers every
        strategy x t with ta in {1, 4, 8}) — strictly more than the old
        hand-enumerated ta in {1, 2, 4} grid."""
        a, pm = fd
        ta = min(ta, t)
        plan = _cached_plan(pm, strategy, t)
        # derive the rhs deterministically from the example so distinct
        # examples exercise distinct payloads
        seed = hash((strategy, t, ta)) % 2**31
        x = np.random.default_rng(seed).standard_normal((a.shape[0], ta))
        halos = simulate_plan(plan, pm, x, at_width=ta)
        for d in range(8):
            assert np.array_equal(halos[d], x[pm.halo_sources[d]]), (
                strategy, t, ta, d,
            )

    @settings(max_examples=16, deadline=None)
    @given(
        strategy=st.sampled_from(STRATEGIES),
        ta=st.sampled_from([3, 5, 6, 7]),
    )
    def test_round_trip_at_non_power_of_two_widths(self, fd, strategy, ta):
        """Adaptive reduction can land on any rank, not just powers of two:
        the sliced plan must stay bit-exact at awkward widths too."""
        a, pm = fd
        plan = _cached_plan(pm, strategy, 8)
        x = np.random.default_rng(ta).standard_normal((a.shape[0], ta))
        halos = simulate_plan(plan, pm, x, at_width=ta)
        for d in range(8):
            assert np.array_equal(halos[d], x[pm.halo_sources[d]]), (
                strategy, ta, d,
            )

    @settings(max_examples=24, deadline=None)
    @given(
        strategy=st.sampled_from(STRATEGIES),
        ta=st.integers(min_value=1, max_value=8),
        f=st.sampled_from([4, 8]),
    )
    def test_payload_scales_linearly_with_active_width(self, fd, strategy, ta, f):
        """Property: wire/local bytes of a sliced plan are exactly
        (ta / t) x the full plan's — the width cut is never padded away."""
        _, pm = fd
        plan = _cached_plan(pm, strategy, 8)
        sub = plan.at_width(ta)
        assert sub.wire_bytes(f) * 8 == plan.wire_bytes(f) * ta
        assert sub.local_bytes(f) * 8 == plan.local_bytes(f) * ta

    def test_slice_is_cached_and_bytes_scale(self, fd):
        a, pm = fd
        plan = build_exchange_plan(pm, 2, 4, "3step", t=8, machine=BLUE_WATERS)
        sub = plan.at_width(2)
        assert plan.at_width(2) is sub          # cached
        assert plan.at_width(8) is plan         # identity at compile width
        assert sub.t == 2
        # payload is exactly t_active·segments·f — a 4x cut from t=8
        assert sub.wire_bytes(8) * 4 == plan.wire_bytes(8)
        assert sub.local_bytes(8) * 4 == plan.local_bytes(8)

    def test_col_split_reslice(self, fd):
        """A col-split plan sliced to a width the split does not divide must
        re-derive its segments (not pad): bytes stay exactly proportional."""
        a, pm = fd
        plan = build_exchange_plan(
            pm, 2, 4, "optimal", t=8, machine=BLUE_WATERS, col_split=4
        )
        sub = plan.at_width(2)   # 4 does not divide 2 -> re-slice
        assert sub.wire_bytes(8) * 4 == plan.wire_bytes(8)
        rng = np.random.default_rng(1)
        x = rng.standard_normal((a.shape[0], 2))
        halos = simulate_plan(sub, pm, x)
        for d in range(8):
            assert np.array_equal(halos[d], x[pm.halo_sources[d]])

    def test_invalid_width_rejected(self, fd):
        _, pm = fd
        plan = build_exchange_plan(pm, 2, 4, "standard")
        with pytest.raises(ValueError):
            plan.at_width(0)


class TestPhases:
    @pytest.mark.parametrize("strategy", STRATEGIES)
    def test_grouping_conserves_slots_and_cuts_dispatches(self, fd, strategy):
        _, pm = fd
        plan = build_exchange_plan(pm, 2, 4, strategy, t=8, machine=BLUE_WATERS)
        phases = plan.phases
        assert sum(p.width for p in phases) == sum(s.width for s in plan.steps)
        # phases group consecutive same-kind steps; keys stay in step order
        i = 0
        for p in phases:
            for off in p.offsets:
                s = plan.steps[i]
                assert (s.axis, s.src, s.dst, s.offset) == (p.axis, p.src, p.dst, off)
                i += 1
        assert i == len(plan.steps)
        assert plan.dispatch_count(packed=True) <= plan.dispatch_count(packed=False)
        if strategy != "standard":  # multi-step phases actually fuse
            assert plan.dispatch_count(packed=True) < plan.dispatch_count(packed=False)


class TestHaloPackKernels:
    @pytest.mark.parametrize("w", [1, 3, 8])
    def test_pack_unpack_pallas_matches_oracle(self, w):
        from repro.kernels import halo_pack, halo_unpack

        rng = np.random.default_rng(0)
        src = jnp.asarray(rng.standard_normal((17, w)))
        idx = jnp.asarray(rng.integers(0, 17, size=11), jnp.int32)
        ref = halo_pack(src, idx)
        assert np.array_equal(np.asarray(ref), np.asarray(src)[np.asarray(idx)])
        pal = halo_pack(src, idx, use_pallas=True)  # interpret-mode Pallas
        assert np.array_equal(np.asarray(ref), np.asarray(pal))

        dst = jnp.asarray(rng.standard_normal((23, w)))
        buf = jnp.asarray(rng.standard_normal((11, w)))
        pos = jnp.asarray(rng.choice(23, size=11, replace=False), jnp.int32)
        ref = halo_unpack(dst, buf, pos)
        expect = np.asarray(dst).copy()
        expect[np.asarray(pos)] = np.asarray(buf)
        assert np.array_equal(np.asarray(ref), expect)
        pal = halo_unpack(dst, buf, pos, use_pallas=True)
        assert np.array_equal(np.asarray(pal), expect)


class TestStructuralModel:
    def test_mode_recorded_and_plan_stats_present(self, fd):
        from repro.tune import tune

        a, pm = fd
        cfg = tune(a, t=8, machine=HOST, n_nodes=2, ppn=4, pm=pm,
                   mode="model:structural")
        assert cfg.mode == "model:structural"
        stats = cfg.predicted["plan_stats"]
        assert set(stats) == set(STRATEGIES)
        for s in STRATEGIES:
            assert stats[s]["dispatches"] > 0
            assert stats[s]["wire_bytes"] > 0

    def test_dispatch_dominated_host_prefers_standard(self, fd):
        """With per-op dispatch overhead dominating (free bytes), the
        structural model must pick the fewest-dispatch plan — standard.
        The analytic max-rate model cannot express this regime."""
        from repro.tune import tune

        a, pm = fd
        m = dataclasses.replace(
            HOST, dispatch_overhead=1.0, R_b=1e18, R_bl=1e18, ppn=4
        )
        cfg = tune(a, t=8, machine=m, n_nodes=2, ppn=4, pm=pm,
                   mode="model:structural")
        assert cfg.strategy == "standard"

    def test_byte_dominated_prefers_dedup(self, fd):
        """Free dispatches but costly wire bytes: the node-aware plans move
        fewer inter-node rows, so a structural byte model must not pick
        standard when dedup actually saves bytes."""
        from repro.tune import structural_exchange_costs

        a, pm = fd
        m = dataclasses.replace(
            HOST, dispatch_overhead=0.0, R_b=1.0, R_bl=1e18, ppn=4
        )
        costs, plans = structural_exchange_costs(pm, 8, m, 2, 4)
        # wire bytes of 2step <= standard would not hold here (this matrix
        # has little dedup), so just check the model == bytes/R_b exactly
        for s, plan in plans.items():
            assert costs[s] == pytest.approx(plan.wire_bytes(m.f) / m.R_b)

    def test_unknown_mode_rejected(self, fd):
        from repro.tune import tune

        a, pm = fd
        with pytest.raises(ValueError):
            tune(a, t=4, machine=HOST, n_nodes=2, ppn=4, pm=pm, mode="bogus")


class TestSegmentedSolve:
    def test_resume_matches_monolithic(self):
        """exit_below_width + resume_state replay the exact monolithic
        adaptive solve: same iterates, same iteration count, same history —
        the machinery the width-aware distributed solver is built on."""
        from repro.core import ecg_solve
        from repro.sparse.csr import csr_spmbv

        a = fd_laplace_2d(13)
        n = a.shape[0]
        t, m = 4, 2
        rng = np.random.default_rng(7)
        b = np.zeros(n)
        b[: (m * n) // t] = rng.standard_normal((m * n) // t)
        apply_a = lambda V: csr_spmbv(a, V)

        ref = ecg_solve(apply_a, jnp.asarray(b), t=t, tol=1e-8,
                        max_iters=300, adaptive="reduce")
        assert ref.converged

        # manual segmentation: full-width mask-aware apply (numerically
        # identical — retired columns are zero), exit on the width event
        masked = lambda z, act: apply_a(z)
        seg1 = ecg_solve(apply_a, jnp.asarray(b), t=t, tol=1e-8,
                         max_iters=300, adaptive="reduce",
                         a_apply_masked=masked, exit_below_width=t)
        assert not seg1.converged and seg1.n_iters < ref.n_iters
        n_act = int(jnp.sum(seg1.final_carry["act"]))
        assert n_act == m
        seg2 = ecg_solve(apply_a, jnp.asarray(b), t=t, tol=1e-8,
                         max_iters=300, adaptive="reduce",
                         a_apply_masked=masked, exit_below_width=n_act,
                         resume_state=seg1.final_carry)
        assert seg2.converged and seg2.n_iters == ref.n_iters
        h_ref = np.asarray(ref.res_hist)[: ref.n_iters + 1]
        h_seg = np.asarray(seg2.res_hist)[: seg2.n_iters + 1]
        np.testing.assert_array_equal(h_ref, h_seg)
        np.testing.assert_array_equal(np.asarray(ref.x), np.asarray(seg2.x))

    def test_select_t_discounts_reduced_width(self):
        """Probes on a deficient splitting observe a shrunken average active
        width; the distributed cost table must record it and charge the
        exchange at the reduced width."""
        from repro.adaptive import select_t

        a = fd_laplace_2d(13)
        n = a.shape[0]
        rng = np.random.default_rng(3)
        b = np.zeros(n)
        b[: n // 4] = rng.standard_normal(n // 4)  # 2 of 8 subdomains live
        sel = select_t(a, b, candidates=(2, 8), n_nodes=2, ppn=4,
                       machine=HOST, tune_mode="model:structural")
        assert sel.table[8]["avg_active"] < 8  # probe saw the reduction
        # same candidate on a full-rank RHS: no reduction, no discount —
        # the deficient case's modeled iteration cost must be cheaper
        b_full = np.random.default_rng(4).standard_normal(n)
        sel_full = select_t(a, b_full, candidates=(2, 8), n_nodes=2, ppn=4,
                            machine=HOST, tune_mode="model:structural")
        assert sel_full.table[8]["avg_active"] == 8
        if not sel.configs[8].overlap:
            assert sel.table[8]["iter_cost_s"] < sel_full.table[8]["iter_cost_s"]


class TestDispatchReset:
    def test_reset_clears_warn_once_state(self):
        from repro.kernels import dispatch

        dispatch._warned.add("probe_op")
        dispatch.reset_dispatch_warnings()
        assert not dispatch._warned
