"""Automatic enlarging-factor selection (``t="auto"``).

The paper's central trade-off — more search directions buy fewer iterations
at a higher per-iteration cost — is closed here at setup time:

    total_cost(t)  =  iters(t) · T_iter(t)

* **iters(t)** — an iterations-to-convergence model.  ``mode="probe"``
  calibrates it from a few real ECG iterations per candidate (geometric fit
  of the observed residual decay); ``mode="kappa"`` uses the CG bound
  ``½·√(κ/t)·ln(2·r₀/tol)`` with a power-iteration condition estimate —
  no solver probes, but cruder.
* **T_iter(t)** — composed from :mod:`repro.tune`'s per-iteration cost
  models: the tuner's best (strategy × tile × overlap) SpMBV time at this t,
  the §3.1 collective model (t² + 3t² floats), and the γ-weighted local
  flops of eq. (3.3) minus the SpMBV term the tuner already covers.
  ``tune_mode`` selects the tuner's exchange model — pass
  ``"model:structural"`` on host/TPU backends so strategy ranking follows
  the executor-structural cost (plan dispatches + moved bytes).

Post-reduction byte savings: the probes run with the adaptive controller,
so when a candidate's splitting loses directions mid-probe (rank drops or
stagnation), the *observed average active width* discounts that candidate's
exchange-byte term — the width-aware executor really will move fewer bytes
after the reduction, and the ranking accounts for it.

``select_t`` ranks the candidate widths and returns a :class:`TSelection`;
the solvers accept ``t="auto"`` and record the selection on
``SolveResult.selection`` (and ``TunedConfig.selection`` for the tuned
distributed path).
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np
import jax.numpy as jnp

# NOTE: repro.core.ecg / repro.tune are imported lazily inside the functions
# below — core.ecg imports repro.adaptive for the rank-revealing path, so a
# module-level import here would be circular.

#: Candidate enlarging factors ranked by default.
DEFAULT_CANDIDATES = (1, 2, 4, 8, 16)


@dataclasses.dataclass(frozen=True)
class TSelection:
    """Result of automatic t selection.

    table maps each candidate t to
    ``{"rate", "est_iters", "iter_cost_s", "total_cost_s"}`` —
    the calibrated per-iteration residual decay, the modeled iterations to
    ``tol``, the modeled per-iteration seconds, and their product.
    """

    t: int
    candidates: tuple
    table: dict
    tol: float
    mode: str          # "probe" | "kappa"
    probe_iters: int = 0
    configs: dict = dataclasses.field(default_factory=dict, compare=False, repr=False)
    # iterations each candidate's probe actually ran before the fitted rate
    # stabilized (early stop) — {t: iters}; empty for mode="kappa"
    probe_iters_used: dict = dataclasses.field(default_factory=dict)

    @property
    def total_cost(self) -> float:
        return self.table[self.t]["total_cost_s"]

    def to_json(self) -> str:
        """Serialize to a JSON string; lossless round trip via
        :meth:`from_json` (used to cache selections on disk next to
        :meth:`repro.tune.TunedConfig.to_json`)."""
        import json

        return json.dumps(tselection_to_dict(self))

    @classmethod
    def from_json(cls, data) -> "TSelection":
        """Inverse of :meth:`to_json`; accepts the JSON string or the
        already-parsed dict."""
        import json

        if isinstance(data, (str, bytes)):
            data = json.loads(data)
        return tselection_from_dict(data)

    def summary(self) -> str:
        lines = [f"t=auto[{self.mode}] -> t={self.t} (tol={self.tol:g})"]
        for t in self.candidates:
            row = self.table[t]
            mark = " <-- chosen" if t == self.t else ""
            act = row.get("avg_active", t)
            red = f" act~{act:.1f}" if act < t else ""
            used = self.probe_iters_used.get(t)
            probed = (
                f" probe={used}/{self.probe_iters}"
                if used is not None and used < self.probe_iters else ""
            )
            lines.append(
                f"  t={t:>2}: rate={row['rate']:.4f} iters~{row['est_iters']:>5} "
                f"iter={row['iter_cost_s']*1e6:8.1f}us "
                f"total={row['total_cost_s']*1e3:8.2f}ms{red}{probed}{mark}"
            )
        return "\n".join(lines)


def tselection_to_dict(sel: "TSelection") -> dict:
    """JSON-safe dict form of a TSelection (int keys stringified)."""
    from repro.tune.autotune import tunedconfig_to_dict

    return dict(
        t=sel.t,
        candidates=list(sel.candidates),
        table={str(t): dict(row) for t, row in sel.table.items()},
        tol=sel.tol,
        mode=sel.mode,
        probe_iters=sel.probe_iters,
        probe_iters_used={str(t): int(v) for t, v in sel.probe_iters_used.items()},
        configs={str(t): tunedconfig_to_dict(cfg) for t, cfg in sel.configs.items()},
    )


def tselection_from_dict(d: dict) -> "TSelection":
    """Inverse of :func:`tselection_to_dict` (int keys restored)."""
    from repro.tune.autotune import tunedconfig_from_dict

    return TSelection(
        t=int(d["t"]),
        candidates=tuple(int(t) for t in d["candidates"]),
        table={int(t): dict(row) for t, row in d["table"].items()},
        tol=float(d["tol"]),
        mode=str(d["mode"]),
        probe_iters=int(d.get("probe_iters", 0)),
        probe_iters_used={
            int(t): int(v) for t, v in d.get("probe_iters_used", {}).items()
        },
        configs={
            int(t): tunedconfig_from_dict(cfg)
            for t, cfg in d.get("configs", {}).items()
        },
    )


# ------------------------------------------------------- iterations models
def _fit_rate(hist) -> tuple[float | None, np.ndarray]:
    """Geometric per-iteration decay fit over the finite positive prefix of a
    residual history; (None, h) when fewer than two usable points exist."""
    h = np.asarray(hist, dtype=np.float64)
    h = h[np.isfinite(h)]
    h = h[h > 0.0]
    if len(h) < 2:
        return None, h
    return float((h[-1] / h[0]) ** (1.0 / (len(h) - 1))), h


def probe_decay_rate(
    a_apply,
    b,
    t: int,
    probe_iters: int = 8,
    mapping: str = "contiguous",
    adaptive: object = "rankrev",
    rtol: float = 0.01,
    min_iters: int = 4,
) -> tuple[float, float, float, int]:
    """Run up to ``probe_iters`` real ECG iterations at width t and fit a
    geometric per-iteration residual decay rate ρ; returns
    (ρ, r₀ norm, avg active width observed, iterations actually run).

    The probe drives the :class:`~repro.core.ecg.ECGRunner` one iteration at
    a time and **stops early** once the fitted rate has stabilized: after at
    least ``min_iters`` iterations, when the fit over k iterations agrees
    with the fit over k−1 within relative tolerance ``rtol``, the remaining
    probe budget is skipped (``rtol=0`` disables early stopping).  The
    number of iterations actually run is recorded as ``probe_iters_used``
    on the :class:`TSelection`.

    The probe runs with the adaptive controller (default ``"rankrev"``) so a
    rank-deficient splitting (e.g. t exceeding the number of nonzero
    subdomains) degrades gracefully instead of poisoning the calibration
    with NaNs — and so the observed reduction trace can discount the
    exchange-byte cost of candidates that will not sustain the full width.
    """
    from repro.adaptive.reduce import resolve_policy
    from repro.core.ecg import make_ecg_runner

    # the probe always needs a controller: "off"/None would leave the active
    # trace unset and a deficient splitting would NaN the fit
    import jax

    policy = resolve_policy("rankrev" if adaptive in (None, "off") else adaptive)
    runner = make_ecg_runner(
        a_apply, t, tol=0.0, max_iters=probe_iters, mapping=mapping,
        policy=policy,
    )
    # one compiled program per probe iteration (carry shapes are static, so
    # every iteration after the first is a jit cache hit); the per-iteration
    # host sync is inherent to the early-stop decision
    step = jax.jit(runner.step)
    b = jnp.asarray(b)
    carry = runner.init(b, jnp.zeros_like(b))
    used = 0
    rho = prev_rho = None
    if not bool(carry["bd"]):
        for k in range(probe_iters):
            new = step(carry)
            if not bool(jnp.isfinite(new["rn"])):
                break  # breakdown: keep the last finite iterate's history
            carry = new
            used = k + 1
            rho, _ = _fit_rate(carry["hist"][: used + 1])
            if float(carry["rn"]) <= 0.0:
                break  # converged exactly inside the probe
            if (
                rtol > 0.0
                and used >= min_iters
                and rho is not None
                and prev_rho is not None
                and abs(rho / prev_rho - 1.0) <= rtol
            ):
                break  # fitted rate stabilized — skip the rest of the budget
            prev_rho = rho
    ah = np.asarray(carry["ahist"][: used + 1])
    ah = ah[ah >= 0]
    avg_active = float(ah.mean()) if len(ah) else float(t)
    rho, h = _fit_rate(carry["hist"][: used + 1])
    if rho is None:
        # converged (or broke down) inside the first probe iteration
        return 1e-8, float(h[0]) if len(h) else 0.0, avg_active, used
    return float(np.clip(rho, 1e-8, 1.0 - 1e-12)), float(h[0]), avg_active, used


def estimate_condition(a_apply, n: int, iters: int = 50, seed: int = 0) -> float:
    """Power-iteration estimate of κ(A) for SPD A (λmax, then λmax of
    λmax·I − A for λmin).  A coarse but probe-free calibration input."""
    rng = np.random.default_rng(seed)

    def lam_max(apply_fn):
        v = jnp.asarray(rng.standard_normal(n))
        v = v / jnp.linalg.norm(v)
        lam = 1.0
        for _ in range(iters):
            w = apply_fn(v)
            lam = float(jnp.vdot(v, w))
            nw = jnp.linalg.norm(w)
            v = w / jnp.maximum(nw, 1e-300)
        return max(lam, 0.0)

    a_vec = lambda v: a_apply(v[:, None])[:, 0]
    lmax = lam_max(a_vec)
    if lmax == 0.0:
        return 1.0
    lmin = lmax - lam_max(lambda v: lmax * v - a_vec(v))
    return lmax / max(lmin, lmax * 1e-14)


def iters_from_condition(kappa: float, t: int, tol_ratio: float) -> float:
    """CG bound ½·√κ_eff·ln(2/tol_ratio) with the enlarged effective
    condition κ_eff ≈ κ/t (the paper's Fig 3.2 regime: iteration count
    shrinks roughly like √t)."""
    tol_ratio = min(max(tol_ratio, 1e-300), 1.0)
    return 0.5 * math.sqrt(kappa / max(t, 1)) * math.log(2.0 / tol_ratio) + 1.0


# ------------------------------------------------------ per-iteration cost
def iteration_cost(
    a,
    t: int,
    machine=None,
    n_nodes: int = 1,
    ppn: int = 1,
    pm=None,
    backend: str = "jnp",
    tune_mode: str = "model",
    method: str = "classic",
    s: int = 1,
    reorth: bool = False,
):
    """Modeled seconds for one *effective* ECG iteration at width t: the
    tuner's best SpMBV config + the scheme's synchronization term
    (:func:`repro.tune.method_sync_cost` — for ``method="classic"`` exactly
    the §3.1 collective model) + γ·(local non-SpMBV flops).

    ``tune_mode`` selects the tuner's exchange model (``"model"`` analytic
    max-rate, ``"model:structural"`` plan dispatches + moved bytes);
    ``method``/``s``/``reorth`` select the iteration scheme whose collective
    and local-work accounting is charged (classic is the default and
    reproduces the original cost exactly).

    Returns ``(seconds, TunedConfig)`` — the config is the same object
    ``make_distributed_spmbv(..., tune=cfg)`` would apply, so a ``t="auto"``
    choice and the executed plan can never drift apart.
    """
    from repro.core.ecg import ECGOperationCounts
    from repro.tune.autotune import _method_local_flops, method_sync_cost
    from repro.tune import tune as run_tune

    cfg = run_tune(
        a, t=t, machine=machine, n_nodes=n_nodes, ppn=ppn,
        pm=pm, backend=backend, mode=tune_mode,
    )
    machine = cfg.machine
    p = n_nodes * ppn
    spmbv = cfg.predicted["best"]
    counts = ECGOperationCounts(n=a.shape[0], nnz=a.nnz, p=p, t=t)
    local_flops = _method_local_flops(method, counts, s=s, reorth=reorth)
    collective = (
        method_sync_cost(
            method, t, p, machine, s=s, reorth=reorth, t_spmbv_window=spmbv
        )
        if p > 1
        else 0.0
    )
    return spmbv + machine.gamma * local_flops + collective, cfg


def _reduced_p2p(cfg, t: int, avg_active: float) -> float:
    """Exchange cost discounted to the probe-observed average active width.

    The width-aware executor moves ``avg_active/t`` of the full-width bytes
    after reduction events, so a candidate whose splitting cannot sustain
    its width should not be charged full-width exchange bytes.  With the
    structural model the byte and dispatch terms are separated exactly
    (``predicted["plan_stats"]``); with the analytic model the whole p2p
    term is scaled — its byte terms are linear in t, so this is first-order.
    """
    machine = cfg.machine
    frac = min(max(avg_active / max(t, 1), 0.0), 1.0)
    stats = cfg.predicted.get("plan_stats")
    if stats is not None and cfg.strategy in stats:
        st = stats[cfg.strategy]
        disp = st["dispatches"] * machine.dispatch_overhead
        return disp + frac * (
            st["wire_bytes"] / machine.R_b + st["local_bytes"] / machine.R_bl
        )
    return cfg.predicted["p2p"][cfg.strategy] * frac


# --------------------------------------------------------------- selection
def select_t(
    a,
    b=None,
    candidates=DEFAULT_CANDIDATES,
    tol: float = 1e-8,
    machine=None,
    n_nodes: int = 1,
    ppn: int = 1,
    pm=None,
    backend: str = "jnp",
    mode: str = "probe",
    probe_iters: int = 8,
    mapping: str = "contiguous",
    a_apply=None,
    tune_mode: str = "model",
    adaptive: object = "rankrev",
    probe_rtol: float = 0.01,
    method: str = "classic",
    s: int = 1,
    reorth: bool = False,
) -> TSelection:
    """Rank candidate enlarging factors and pick the modeled-cheapest one.

    a:        CSRMatrix (drives the tuner's cost model and default probes).
    b:        right-hand side — required for ``mode="probe"``.
    mode:     "probe" calibrates iters(t) from up to ``probe_iters`` real ECG
              iterations per candidate; "kappa" from a condition estimate.
    a_apply:  optional SpMBV override for the probes (defaults to the
              sequential CSR product — the iteration *count* does not depend
              on the execution backend, only on the math).
    tune_mode: exchange model for the per-iteration cost ("model" analytic,
              "model:structural" executor-structural).
    adaptive: controller the probes run with; when the probe observes a
              reduced average active width, the candidate's exchange-byte
              cost is discounted to it (see :func:`_reduced_p2p`).
    probe_rtol: early-stop tolerance of the probes — a candidate's probe
              stops as soon as its fitted decay rate is stable within this
              relative tolerance (0 disables; the iterations actually run
              are recorded in ``TSelection.probe_iters_used``).
    method/s/reorth: the iteration scheme whose per-effective-iteration cost
              is charged (see :mod:`repro.core.methods`).  The probes always
              run the classic scheme — all three schemes walk the same
              enlarged Krylov space, so the calibrated decay rate carries
              over to first order while the probe stays cheap.
    """
    from repro.sparse.csr import csr_spmbv

    n = a.shape[0]
    cands = sorted({int(t) for t in candidates if 1 <= int(t) <= n})
    if not cands:
        raise ValueError(f"no valid candidates in {candidates!r} for n={n}")
    if mode not in ("probe", "kappa"):
        raise ValueError(f"unknown selection mode {mode!r}")
    if mode == "probe" and b is None:
        raise ValueError('select_t(mode="probe") needs the right-hand side b')
    if a_apply is None:
        a_apply = lambda v: csr_spmbv(a, v)

    if mode == "kappa":
        kappa = estimate_condition(a_apply, n)
        rn0 = float(jnp.linalg.norm(jnp.asarray(b))) if b is not None else 1.0

    table, configs, iters_used = {}, {}, {}
    best_t, best_cost = cands[0], math.inf
    for t in cands:
        if mode == "probe":
            rate, rn0, avg_active, used = probe_decay_rate(
                a_apply, jnp.asarray(b), t, probe_iters=probe_iters,
                mapping=mapping, adaptive=adaptive, rtol=probe_rtol,
            )
            iters_used[t] = used
            est = _iters_to_tol(rate, rn0, tol, n)
        else:
            avg_active = float(t)
            rate = math.exp(-1.0 / max(iters_from_condition(kappa, t, 1.0 / math.e), 1.0))
            est = min(int(math.ceil(iters_from_condition(kappa, t, tol / max(rn0, tol)))), n)
        cost, cfg = iteration_cost(
            a, t, machine=machine, n_nodes=n_nodes, ppn=ppn, pm=pm,
            backend=backend, tune_mode=tune_mode,
            method=method, s=s, reorth=reorth,
        )
        if avg_active < t and n_nodes * ppn > 1 and not cfg.overlap:
            # post-reduction byte savings: the width-aware exchange moves
            # avg_active/t of the full-width bytes once directions retire
            # (blocking schedules only — an overlapped exchange is already
            # hidden behind interior compute, so there is nothing to save)
            full_p2p = cfg.predicted["p2p"][cfg.strategy]
            cost = cost - full_p2p + _reduced_p2p(cfg, t, avg_active)
        total = est * cost
        table[t] = dict(
            rate=rate, est_iters=est, iter_cost_s=cost, total_cost_s=total,
            avg_active=avg_active,
        )
        configs[t] = cfg
        if total < best_cost:
            best_t, best_cost = t, total
    return TSelection(
        t=best_t, candidates=tuple(cands), table=table, tol=tol, mode=mode,
        probe_iters=probe_iters if mode == "probe" else 0, configs=configs,
        probe_iters_used=iters_used,
    )


def resolve_auto_t(
    t: str,
    adaptive,
    *,
    a=None,
    b=None,
    select: TSelection | None = None,
    candidates=DEFAULT_CANDIDATES,
    tol: float = 1e-8,
    machine=None,
    n_nodes: int = 1,
    ppn: int = 1,
    backend: str = "jnp",
    tune_mode: str = "model",
    probe_iters: int = 8,
    probe_rtol: float = 0.01,
    method: str = "classic",
    s: int = 1,
    reorth: bool = False,
):
    """Shared ``t="auto"`` resolution for the solvers.

    Validates the string, runs :func:`select_t` unless a precomputed
    ``select`` is supplied (probes run with the requested ``adaptive``
    controller so reduction-aware byte savings enter the ranking), and
    defaults ``adaptive`` to ``"rankrev"`` (an explicit ``"off"`` is
    honored) — one implementation so the sequential and distributed solvers
    cannot drift apart.  Returns ``(t, selection, adaptive)``.
    """
    if t != "auto":
        raise ValueError(f"t must be an int or 'auto', got {t!r}")
    if select is None:
        if a is None:
            raise ValueError(
                "t='auto' needs matrix= (the CSRMatrix behind a_apply) "
                "or select= (a precomputed TSelection)"
            )
        probe_adaptive = "rankrev" if adaptive in (None, "off") else adaptive
        select = select_t(
            a, b, candidates=candidates, tol=tol, machine=machine,
            n_nodes=n_nodes, ppn=ppn, backend=backend,
            tune_mode=tune_mode, adaptive=probe_adaptive,
            probe_iters=probe_iters, probe_rtol=probe_rtol,
            method=method, s=s, reorth=reorth,
        )
    if adaptive is None:
        adaptive = "rankrev"  # auto-t implies breakdown safety
    return int(select.t), select, adaptive


def _iters_to_tol(rate: float, rn0: float, tol: float, n: int) -> int:
    """Iterations for rn0·rateᵏ ≤ tol, clipped to [1, n] (CG terminates in at
    most n exact-arithmetic steps; the enlarged method in fewer)."""
    if rn0 <= tol or rn0 == 0.0:
        return 1
    k = math.log(tol / rn0) / math.log(rate)
    return int(min(max(math.ceil(k), 1), n))
