"""Content fingerprinting of CSR operators — the registry key.

A solver session is worth caching exactly as long as the *matrix values*
are unchanged; object identity is useless across requests (every client
re-assembles its CSR) and ``(shape, nnz)`` collides trivially.  The
fingerprint therefore hashes the mathematical content:

* shape and data dtype;
* the row pointer (row lengths);
* column indices and values **canonicalized within each row** — two
  assemblies of the same matrix that emit a row's entries in different
  orders (a very common artifact of FEM assembly order) fingerprint
  identically, while perturbing any single stored value changes the key.

blake2b (128-bit digest) over the raw array bytes: collision probability
is negligible at any realistic registry size, and hashing is a single
pass over the CSR arrays — microseconds next to one solve.
"""

from __future__ import annotations

import hashlib

import numpy as np


def fingerprint_csr(a) -> str:
    """Hex content fingerprint of a :class:`~repro.sparse.csr.CSRMatrix`."""
    indptr = np.ascontiguousarray(np.asarray(a.indptr, dtype=np.int64))
    indices = np.asarray(a.indices, dtype=np.int64)
    data = np.asarray(a.data)
    n_rows = len(indptr) - 1
    # within-row canonical column order (stable for the extremely unlikely
    # duplicate-entry case: lexsort keys are (secondary, primary))
    row_of = np.repeat(np.arange(n_rows, dtype=np.int64), np.diff(indptr))
    order = np.lexsort((indices, row_of))
    h = hashlib.blake2b(digest_size=16)
    h.update(np.asarray(a.shape, dtype=np.int64).tobytes())
    h.update(data.dtype.str.encode())
    h.update(indptr.tobytes())
    h.update(np.ascontiguousarray(indices[order]).tobytes())
    h.update(np.ascontiguousarray(data[order]).tobytes())
    return h.hexdigest()


def operator_nbytes(a) -> int:
    """Byte footprint of the CSR arrays — the registry's eviction currency.

    A built session holds more than the CSR (plan index arrays, Block-ELL
    copies, compiled programs), but those all scale with the CSR footprint,
    so budgeting on it gives stable, explainable eviction behavior.
    """
    return int(sum(
        np.asarray(x).nbytes for x in (a.indptr, a.indices, a.data)
    ))
