"""Pallas TPU kernels for the ECG hot spots.

Each kernel ships as kernel.py (pl.pallas_call + BlockSpec), ops.py (public
jit'd wrapper with backend dispatch) and ref.py (pure-jnp oracle used by the
interpret-mode allclose test sweeps).
"""

from repro.kernels.bsr_spmbv.ops import (
    bsr_spmbv,
    bsr_to_block_ell,
    block_ell_from_csr,
    csr_arrays_to_block_ell,
    count_block_ell_tiles,
    make_block_ell_apply,
    make_block_ell_apply_from_arrays,
    block_ell_meta,
    block_ell_arrays,
)
from repro.kernels.fused_gram.ops import fused_gram
from repro.kernels.block_update.ops import block_update, ecg_tail
from repro.kernels.block_trisolve.ops import block_trisolve
from repro.kernels.halo_pack.ops import halo_pack, halo_unpack

__all__ = [
    "halo_pack",
    "halo_unpack",
    "bsr_spmbv",
    "bsr_to_block_ell",
    "block_ell_from_csr",
    "csr_arrays_to_block_ell",
    "count_block_ell_tiles",
    "make_block_ell_apply",
    "make_block_ell_apply_from_arrays",
    "block_ell_meta",
    "block_ell_arrays",
    "fused_gram",
    "block_update",
    "ecg_tail",
    "block_trisolve",
]
