"""Dynamic search-direction reduction for ECG (flexible-ECG controller).

The paper's central trade-off is that enlarging factor t buys fewer
iterations at the price of t²-sized reductions and denser messages.  Mid-
solve, two things erode the value of a large t:

* **rank deficiency** — the t residual columns become numerically dependent
  (detected by the pivoted factorization in :mod:`repro.adaptive.rankrev`);
* **stagnation** — a direction stops contributing to the error decrease.
  With P A-orthonormal, the A-norm² error drop of one iteration is ‖c‖²_F
  (c = PᵀR), and direction i's share is ‖c_{i,:}‖².  The flexible-ECG
  criterion retires direction i when ‖c_{i,:}‖ falls below ``drop_tol``
  relative to the current residual norm.

The controller is jit-compatible with **static shapes**: arrays stay (n, t)
and inactive directions are zero-masked columns.  A zero column flows
through the Pallas ``fused_gram``/``ecg_tail`` kernels and both psums
unchanged (zeros contribute zeros), so the §3.1 two-allreduce invariant and
the kernel suite are untouched.  Masking is self-propagating: a zeroed Z
column yields a zero G row/column, which the rank-revealing factorization
keeps dead — no mask needs to be carried across iterations, only the active
count for the trace.

An optional re-enlarge/restart rebuilds the full t-wide splitting from the
current residual when convergence plateaus with a reduced block.
"""

from __future__ import annotations

import dataclasses
import math

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ReductionPolicy:
    """Configuration of the in-solve width controller.

    rank_rtol:      pivot threshold of the rank-revealing factorization
                    (None = dtype default, see ``rankrev.default_rank_rtol``).
    drop_tol:       stagnation threshold τ — direction i is retired when
                    ‖c_{i,:}‖ ≤ τ·‖r‖ (None = sqrt(eps) of the solve dtype;
                    0.0 disables stagnation drops, keeping rank-only masking).
    min_t:          floor on the active width; stagnation drops never reduce
                    the block below it (rank deficiency still can — a
                    dependent direction is unusable at any floor).
    restart:        re-enlarge to the full t-wide splitting of the current
                    residual when the residual plateaus with a reduced block.
    plateau_window: iterations without sufficient progress that count as a
                    plateau.
    plateau_ratio:  progress means rn < plateau_ratio · best_rn.
    """

    rank_rtol: float | None = None
    drop_tol: float | None = None
    min_t: int = 1
    restart: bool = False
    plateau_window: int = 25
    plateau_ratio: float = 0.99

    def resolved_drop_tol(self, dtype) -> float:
        if self.drop_tol is not None:
            return float(self.drop_tol)
        return math.sqrt(float(jnp.finfo(dtype).eps))


#: ``adaptive=`` string shorthands accepted by the solvers.
POLICIES = {
    "rankrev": ReductionPolicy(drop_tol=0.0),
    "reduce": ReductionPolicy(),
    "reduce+restart": ReductionPolicy(restart=True),
}


def resolve_policy(adaptive) -> ReductionPolicy | None:
    """Map the solver's ``adaptive`` argument to a policy (or None = off)."""
    if adaptive is None or adaptive == "off":
        return None
    if isinstance(adaptive, ReductionPolicy):
        return adaptive
    if isinstance(adaptive, str):
        try:
            return POLICIES[adaptive]
        except KeyError:
            raise ValueError(
                f"unknown adaptive mode {adaptive!r}; expected one of "
                f"{sorted(POLICIES)}, 'off', None, or a ReductionPolicy"
            ) from None
    raise TypeError(f"adaptive must be str/None/ReductionPolicy, got {type(adaptive)}")


def stagnation_mask(c, rn, active, policy: ReductionPolicy):
    """Apply the flexible-ECG drop criterion; returns the shrunk column mask.

    c:      (t, t) step coefficients PᵀR of this iteration (rows = directions,
            in the same pivot order as the ``active`` mask).
    rn:     residual norm the scores are compared against.
    active: (t,) bool mask from the rank-revealing factorization.

    Jit-compatible, static shapes.  At most ``n_active − min_t`` directions
    are dropped per iteration (the lowest-scoring ones first).
    """
    tau = policy.resolved_drop_tol(c.dtype)
    if tau == 0.0:
        return active
    scores = jnp.sum(c * c, axis=1)  # ΔE_A² attributable to direction i
    stagnant = scores <= jnp.asarray(tau, c.dtype) ** 2 * rn * rn
    max_drops = jnp.maximum(jnp.sum(active) - policy.min_t, 0)
    # ascending rank of each direction's score among the active ones;
    # inactive directions sort last and are never "dropped" again
    order = jnp.argsort(jnp.where(active, scores, jnp.inf))
    pos = jnp.argsort(order)
    drop = active & stagnant & (pos < max_drops)
    return active & ~drop


def plateau_update(rn, best_rn, since_best, policy: ReductionPolicy):
    """Track progress for the restart trigger; returns (best_rn, since_best)."""
    improved = rn < policy.plateau_ratio * best_rn
    return jnp.minimum(best_rn, rn), jnp.where(improved, 0, since_best + 1)
