"""Training/serving substrate."""

from repro.train.optimizer import AdamWConfig, init_opt_state, apply_adamw
from repro.train.train_step import build_train_step, build_serve_step
from repro.train.data import DataConfig, batch_at
from repro.train.checkpoint import (
    save_checkpoint,
    restore_checkpoint,
    latest_step,
    install_preemption_handler,
)

__all__ = [
    "AdamWConfig",
    "init_opt_state",
    "apply_adamw",
    "build_train_step",
    "build_serve_step",
    "DataConfig",
    "batch_at",
    "save_checkpoint",
    "restore_checkpoint",
    "latest_step",
    "install_preemption_handler",
]
