"""Measured ECG hot-path benchmarks: kernel-vs-oracle and overlap-vs-blocking.

Shared by ``benchmarks/kernel_sweep.py`` (CSV, 8 forced host devices) and
``repro.launch.perf --ecg`` (JSON).  Two families:

* :func:`overlap_vs_blocking_sweep` — distributed SpMBV wall time over
  strategies x t x backend x {blocking, overlap}, so the comm-hiding win of
  the interior/boundary schedule is *measured*, not asserted.  On CPU hosts
  the ppermute rounds are memcpys, so overlap speedups are modest; on a real
  TPU mesh the interior compute hides actual ICI latency.
* :func:`kernel_vs_oracle` — local hot-spot formulations head to head:
  Block-ELL SpMBV (Pallas kernel on TPU, jnp oracle elsewhere) vs the
  scalar-gather CSR baseline, and the fused gram / fused tail vs their
  unfused counterparts.
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

STRATEGIES = ("standard", "2step", "3step", "optimal")


def _timeit(fn, *args, repeats: int = 3) -> float:
    """Median wall microseconds per call (after one warmup/compile call).

    Delegates to the shared :func:`repro.observe.timed_median_us` timer —
    the measurement discipline is identical across every benchmark, and an
    installed ambient tracer sees each timed call as a ``bench/*`` span.
    """
    from repro.observe import get_tracer, timed_median_us

    return timed_median_us(fn, *args, repeats=repeats, label="ecg_bench",
                           tracer=get_tracer())


def overlap_vs_blocking_sweep(
    a,
    mesh,
    ts=(4, 8),
    strategies=STRATEGIES,
    backends=("jnp", "pallas"),
    repeats: int = 5,
    machine=None,
    ell_block: int = 8,
    seed: int = 0,
):
    """Distributed SpMBV timings; returns rows of dicts (name/us/derived).

    ``seed`` fixes the operand RNG and ``repeats`` the median-of-k timing so
    host-mode numbers are reproducible run-to-run.
    """
    from repro.sparse.spmbv import _make_distributed_spmbv

    rng = np.random.default_rng(seed)
    rows = []
    for strategy in strategies:
        for t in ts:
            big_v = rng.standard_normal((a.shape[0], t))
            for backend in backends:
                base_us = None
                for overlap in (False, True):
                    op = _make_distributed_spmbv(
                        a, mesh, strategy, t=t, machine=machine,
                        backend=backend, overlap=overlap, ell_block=ell_block,
                    )
                    f = jax.jit(op.matvec_fn())
                    v = op.shard_vector(big_v)
                    us = _timeit(f, v, repeats=repeats)
                    if overlap:
                        derived = f"speedup_vs_blocking={base_us / us:.2f}"
                    else:
                        base_us = us
                        derived = f"halo={op.plan.halo_size}"
                    mode = "overlap" if overlap else "blocking"
                    rows.append(dict(
                        name=f"spmbv/{strategy}_t{t}_{backend}_{mode}",
                        us=us, derived=derived,
                    ))
    return rows


def kernel_vs_oracle(ts=(2, 4, 8), repeats: int = 5, elements=(16, 16), block: int = 16,
                     seed: int = 2):
    """Local hot-spot timings on the current default backend (fixed ``seed``
    + median-of-``repeats`` for run-to-run reproducibility)."""
    from repro.sparse import dg_laplace_2d, csr_spmbv, csr_to_bsr
    from repro.kernels import bsr_spmbv, bsr_to_block_ell, fused_gram, ecg_tail

    a = dg_laplace_2d(elements, block=block, dtype=jnp.float32)
    blocks, idx = bsr_to_block_ell(csr_to_bsr(a, block, block))
    rng = np.random.default_rng(seed)
    rows = []
    for t in ts:
        v = jnp.asarray(rng.standard_normal((a.shape[0], t)), jnp.float32)
        us_csr = _timeit(jax.jit(lambda vv: csr_spmbv(a, vv)), v, repeats=repeats)
        us_ell = _timeit(jax.jit(lambda vv: bsr_spmbv(blocks, idx, vv)), v, repeats=repeats)
        rows.append(dict(name=f"kernel/csr_spmbv_t{t}", us=us_csr, derived=f"nnz={a.nnz}"))
        rows.append(dict(
            name=f"kernel/block_ell_spmbv_t{t}", us=us_ell,
            derived=f"csr/ell={us_csr / us_ell:.2f}",
        ))

        n_loc = 32768
        mats = [jnp.asarray(rng.standard_normal((n_loc, t)), jnp.float32) for _ in range(4)]
        us_fused = _timeit(jax.jit(lambda *m: fused_gram(*m)), *mats, repeats=repeats)
        us_sep = _timeit(
            jax.jit(lambda p, r, ap, apo: (p.T @ r, ap.T @ ap, apo.T @ ap)),
            *mats, repeats=repeats,
        )
        rows.append(dict(
            name=f"kernel/fused_gram_t{t}", us=us_fused,
            derived=f"unfused/fused={us_sep / us_fused:.2f}",
        ))

        x, r, p, ap, po = (
            jnp.asarray(rng.standard_normal((n_loc, t)), jnp.float32) for _ in range(5)
        )
        c, d, do = (jnp.asarray(rng.standard_normal((t, t)), jnp.float32) for _ in range(3))
        us_tail = _timeit(
            jax.jit(lambda *args: ecg_tail(*args)), x, r, p, ap, po, c, d, do,
            repeats=repeats,
        )
        us_unf = _timeit(
            jax.jit(lambda x, r, p, ap, po, c, d, do: (
                x + p @ c, r - ap @ c, ap - p @ d - po @ do
            )),
            x, r, p, ap, po, c, d, do, repeats=repeats,
        )
        rows.append(dict(
            name=f"kernel/ecg_tail_t{t}", us=us_tail,
            derived=f"unfused/fused={us_unf / us_tail:.2f}",
        ))
    return rows
