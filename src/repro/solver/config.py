"""Typed, validated configuration for the ECG solver handle.

One frozen :class:`SolverConfig` replaces the ~20 loosely-typed keyword
arguments that had accreted on ``ecg_solve``/``distributed_ecg``/
``make_distributed_spmbv``.  It is composed of five orthogonal sub-configs,
one per subsystem:

* :class:`CommConfig`   — the node-aware exchange (strategy, overlap,
  col-split, machine parameters) → ``repro.core.node_aware`` + the
  interior/boundary schedule of ``repro.sparse.spmbv``.
* :class:`KernelConfig` — the local compute formulation (backend, Block-ELL
  tile) → ``repro.kernels``.
* :class:`TuneConfig`   — setup-time autotuning (mode, or a precomputed
  :class:`~repro.tune.TunedConfig`) → ``repro.tune``.
* :class:`AdaptiveConfig` — the in-solve width controller and ``t="auto"``
  selection knobs → ``repro.adaptive``.
* :class:`MethodConfig` — the iteration scheme (classic / pipelined /
  s-step and its knobs) → ``repro.core.methods``.
* :class:`~repro.precondition.PreconditionConfig` — the preconditioner
  (none / block_jacobi / chebyshev / inexact) → ``repro.precondition``.

Validation happens at construction: a bad strategy/backend/mode raises
``ValueError`` immediately, not three layers down inside a traced solve.
String shorthands from the legacy API are *coerced* into their typed form
(``adaptive="reduce"`` becomes a resolved
:class:`~repro.adaptive.ReductionPolicy`; ``tune="model"`` becomes
``TuneConfig(mode="model")``), so after ``__post_init__`` every field holds
exactly one well-typed value.

All four sub-configs (and ``SolverConfig`` itself) are frozen dataclasses:
hashable, comparable, safe to share between handles, and cheap to rebuild
with :meth:`SolverConfig.replace`.
"""

from __future__ import annotations

import dataclasses
from typing import Any

from repro.precondition.config import PreconditionConfig

STRATEGIES = ("standard", "2step", "3step", "optimal")
BACKENDS = ("jnp", "pallas")
TUNE_MODES = ("off", "model", "model:structural", "measure")
METHODS = ("classic", "pipelined", "sstep")


def _freeze(cls, **updates):
    """object.__setattr__-based update for frozen-dataclass __post_init__."""
    for k, v in updates.items():
        object.__setattr__(cls, k, v)


@dataclasses.dataclass(frozen=True)
class CommConfig:
    """Node-aware exchange configuration.

    strategy:  point-to-point exchange strategy (paper §4): one of
               ``standard | 2step | 3step | optimal``.
    overlap:   hide the halo-exchange rounds behind interior SpMBV compute
               (interior/boundary split schedule).
    col_split: wide-halo column-split factor for the nodal-optimal strategy
               (must divide t); ``None`` = §4.3 byte model decides.
    machine:   :class:`~repro.core.machines.MachineParams` the byte models
               use; ``None`` = per-mode default (TPU-v5e for the models).
    """

    strategy: str = "standard"
    overlap: bool = False
    col_split: int | None = None
    machine: Any = None

    def __post_init__(self):
        if self.strategy not in STRATEGIES:
            raise ValueError(
                f"unknown exchange strategy {self.strategy!r}; "
                f"expected one of {STRATEGIES}"
            )
        if self.col_split is not None and (
            not isinstance(self.col_split, int) or self.col_split < 1
        ):
            raise ValueError(f"col_split must be a positive int, got {self.col_split!r}")
        _freeze(self, overlap=bool(self.overlap))


@dataclasses.dataclass(frozen=True)
class KernelConfig:
    """Local-compute configuration.

    backend:   ``"jnp"`` (scalar-gather CSR + unfused updates) or
               ``"pallas"`` (Block-ELL SpMBV + fused gram/tail kernels;
               jnp oracles off-TPU, so always safe).
    ell_block: Block-ELL tile shape — an int for square tiles or an explicit
               ``(br, bc)`` pair; normalized to a tuple.
    """

    backend: str = "jnp"
    ell_block: int | tuple[int, int] = (8, 8)

    def __post_init__(self):
        if self.backend not in BACKENDS:
            raise ValueError(
                f"unknown backend {self.backend!r}; expected one of {BACKENDS}"
            )
        blk = self.ell_block
        if isinstance(blk, int):
            blk = (blk, blk)
        blk = tuple(int(x) for x in blk)
        if len(blk) != 2 or any(x < 1 for x in blk):
            raise ValueError(f"ell_block must be a positive int or (br, bc), got {self.ell_block!r}")
        _freeze(self, ell_block=blk)


@dataclasses.dataclass(frozen=True)
class TuneConfig:
    """Setup-time autotuning configuration.

    mode:   ``"off"`` (use the explicit :class:`CommConfig`/
            :class:`KernelConfig` values), ``"model"`` (paper's analytic
            max-rate models), ``"model:structural"`` (executor-structural:
            plan dispatches + moved bytes), or ``"measure"`` (setup-time
            microbenchmarks on the mesh).
    tuned:  a precomputed :class:`~repro.tune.TunedConfig` to apply verbatim
            (e.g. loaded back from ``TunedConfig.from_json``); wins over
            ``mode``.
    """

    mode: str = "off"
    tuned: Any = None

    def __post_init__(self):
        if self.mode not in TUNE_MODES:
            raise ValueError(
                f"unknown tune mode {self.mode!r}; expected one of {TUNE_MODES}"
            )
        if self.tuned is not None and not hasattr(self.tuned, "strategy"):
            raise TypeError(
                f"tuned must be a repro.tune.TunedConfig, got {type(self.tuned)}"
            )

    @classmethod
    def coerce(cls, value) -> "TuneConfig":
        """Normalize the accepted spellings into a TuneConfig."""
        if value is None:
            return cls()
        if isinstance(value, cls):
            return value
        if isinstance(value, dict):
            return cls(**value)
        if isinstance(value, str):
            return cls(mode=value)
        if hasattr(value, "strategy") and hasattr(value, "ell_block"):
            return cls(mode=getattr(value, "mode", "off"), tuned=value)
        raise TypeError(
            f"tune must be a TuneConfig, a mode string, a TunedConfig, or a "
            f"dict of TuneConfig fields; got {type(value)}"
        )

    @property
    def active(self) -> bool:
        return self.tuned is not None or self.mode != "off"


@dataclasses.dataclass(frozen=True)
class AdaptiveConfig:
    """In-solve width controller and ``t="auto"`` selection knobs.

    policy:       a resolved :class:`~repro.adaptive.ReductionPolicy`, or
                  None (fixed width).  String shorthands (``"rankrev"`` /
                  ``"reduce"`` / ``"reduce+restart"``) are coerced at
                  construction.  ``policy="off"`` also resolves to None but
                  records ``explicit_off`` — ``t="auto"`` normally implies
                  the rankrev breakdown guard, and only an *explicit* off
                  suppresses it (mirroring the legacy solvers).
    t_candidates: candidate enlarging factors ranked by ``t="auto"``.
    select:       a precomputed :class:`~repro.adaptive.TSelection` to use
                  instead of running the probes.
    probe_iters:  iteration budget per ``t="auto"`` probe.
    probe_rtol:   early-stop tolerance of the probe: stop once the fitted
                  per-iteration decay rate is stable within this relative
                  tolerance on consecutive iterations (0 = always run the
                  full ``probe_iters``).
    """

    policy: Any = None
    t_candidates: tuple[int, ...] = (1, 2, 4, 8, 16)
    select: Any = None
    probe_iters: int = 8
    probe_rtol: float = 0.01
    explicit_off: bool = False

    def __post_init__(self):
        from repro.adaptive.reduce import resolve_policy

        # explicit_off tracks the *latest* policy request: a new "off" sets
        # it, any other concrete policy clears it (so replace(policy=...)
        # on a formerly-off config is not sticky), and policy=None (no
        # request) carries the existing flag through replace().
        if self.policy == "off":
            explicit_off = True
        elif self.policy is not None:
            explicit_off = False
        else:
            explicit_off = bool(self.explicit_off)
        _freeze(
            self,
            policy=resolve_policy(self.policy),
            t_candidates=tuple(int(t) for t in self.t_candidates),
            explicit_off=explicit_off,
        )
        if self.probe_iters < 2:
            raise ValueError(f"probe_iters must be >= 2, got {self.probe_iters}")
        if self.probe_rtol < 0:
            raise ValueError(f"probe_rtol must be >= 0, got {self.probe_rtol}")

    @classmethod
    def coerce(cls, value) -> "AdaptiveConfig":
        from repro.adaptive.reduce import ReductionPolicy

        if value is None:
            return cls()
        if isinstance(value, cls):
            return value
        if isinstance(value, dict):
            return cls(**value)
        if isinstance(value, (str, ReductionPolicy)):
            return cls(policy=value)
        raise TypeError(
            f"adaptive must be an AdaptiveConfig, a policy (or its string "
            f"shorthand), a dict of AdaptiveConfig fields, or None; "
            f"got {type(value)}"
        )


@dataclasses.dataclass(frozen=True)
class MethodConfig:
    """Iteration-scheme configuration (see :mod:`repro.core.methods`).

    name:      ``"classic"`` (the paper's two-psum §3.1 iteration),
               ``"pipelined"`` (same collectives, packed Gram reduction
               overlapped with the SpMBV exchange via the AZ recurrence), or
               ``"sstep"`` (s SpMBV sweeps per collective pair,
               rank-revealing safeguarded).
    s:         inner-step count of the s-step scheme (psums amortize to
               2/s per effective iteration); must stay 1 for other methods.
    depth:     pipeline depth; only depth-1 (one iteration of overlap, the
               AZ recurrence) is implemented.
    reorth:    s-step per-block Cholesky-QR2 second pass — one extra (st)²
               psum per block, for matrices where a single pivoted
               factorization leaves too much A-orthogonality on the table.
    rank_rtol: pivot threshold override for method-mandated rank-revealing
               factorizations (None = the policy's threshold, else the
               dtype default).
    """

    name: str = "classic"
    s: int = 1
    depth: int = 1
    reorth: bool = False
    rank_rtol: float | None = None

    def __post_init__(self):
        if self.name not in METHODS:
            raise ValueError(
                f"unknown method {self.name!r}; expected one of {METHODS}"
            )
        if not isinstance(self.s, int) or self.s < 1:
            raise ValueError(f"s must be an int >= 1, got {self.s!r}")
        if self.s != 1 and self.name != "sstep":
            raise ValueError(
                f"s={self.s} only applies to method 'sstep' (got method "
                f"{self.name!r}); classic/pipelined have no inner-step count"
            )
        if self.depth != 1:
            raise ValueError(
                f"only depth-1 pipelining (the AZ recurrence) is implemented, "
                f"got depth={self.depth!r}"
            )
        if self.reorth and self.name != "sstep":
            raise ValueError(
                "reorth (per-block Cholesky-QR2) only applies to method 'sstep'"
            )
        if self.rank_rtol is not None and not self.rank_rtol > 0:
            raise ValueError(f"rank_rtol must be > 0 or None, got {self.rank_rtol!r}")
        _freeze(self, reorth=bool(self.reorth))

    @classmethod
    def coerce(cls, value) -> "MethodConfig":
        if value is None:
            return cls()
        if isinstance(value, cls):
            return value
        if isinstance(value, dict):
            return cls(**value)
        if isinstance(value, str):
            return cls(name=value)
        raise TypeError(
            f"method must be a MethodConfig, a method name, a dict of "
            f"MethodConfig fields, or None; got {type(value)}"
        )


#: Flat override spellings accepted by ``SolverConfig.replace`` /
#: ``ECGSolver.with_config`` — each maps to (sub-config field, field name).
_FLAT_FIELDS = {
    "strategy": ("comm", "strategy"),
    "overlap": ("comm", "overlap"),
    "col_split": ("comm", "col_split"),
    "machine": ("comm", "machine"),
    "backend": ("kernel", "backend"),
    "ell_block": ("kernel", "ell_block"),
    "tune_mode": ("tune", "mode"),
    "tuned": ("tune", "tuned"),
    "policy": ("adaptive", "policy"),
    "t_candidates": ("adaptive", "t_candidates"),
    "select": ("adaptive", "select"),
    "probe_iters": ("adaptive", "probe_iters"),
    "probe_rtol": ("adaptive", "probe_rtol"),
    "s": ("method", "s"),
    "depth": ("method", "depth"),
    "reorth": ("method", "reorth"),
    "block": ("precondition", "block"),
    "degree": ("precondition", "degree"),
    "eig_bounds": ("precondition", "eig_bounds"),
    "eig_ratio": ("precondition", "eig_ratio"),
    "power_iters": ("precondition", "power_iters"),
    "sweeps": ("precondition", "sweeps"),
    "omega": ("precondition", "omega"),
    "reseed": ("precondition", "reseed"),
}


@dataclasses.dataclass(frozen=True)
class SolverConfig:
    """The one config every ECG subsystem reads.

    t:         enlarging factor (int >= 1), or ``"auto"`` to pick it at
               build time from the iterations-vs-cost model.
    tol:       convergence tolerance on the residual norm.
    max_iters: iteration cap of the solve loop.
    comm/kernel/tune/adaptive: the four sub-configs (see their docs).  The
               constructor coerces convenient spellings: ``tune="model"``,
               ``tune=TunedConfig``, ``adaptive="reduce"``,
               ``adaptive=ReductionPolicy`` all normalize to typed fields.
    """

    t: int | str = 8
    tol: float = 1e-8
    max_iters: int = 1000
    comm: CommConfig = dataclasses.field(default_factory=CommConfig)
    kernel: KernelConfig = dataclasses.field(default_factory=KernelConfig)
    tune: TuneConfig = dataclasses.field(default_factory=TuneConfig)
    adaptive: AdaptiveConfig = dataclasses.field(default_factory=AdaptiveConfig)
    method: MethodConfig = dataclasses.field(default_factory=MethodConfig)
    precondition: PreconditionConfig = dataclasses.field(
        default_factory=PreconditionConfig
    )

    def __post_init__(self):
        if isinstance(self.t, str):
            if self.t != "auto":
                raise ValueError(f"t must be an int >= 1 or 'auto', got {self.t!r}")
        elif not isinstance(self.t, int) or self.t < 1:
            raise ValueError(f"t must be an int >= 1 or 'auto', got {self.t!r}")
        if not self.tol >= 0:
            raise ValueError(f"tol must be >= 0, got {self.tol!r}")
        if not isinstance(self.max_iters, int) or self.max_iters < 1:
            raise ValueError(f"max_iters must be an int >= 1, got {self.max_iters!r}")
        comm = self.comm if isinstance(self.comm, CommConfig) else CommConfig(**self.comm)
        kernel = (
            self.kernel if isinstance(self.kernel, KernelConfig)
            else KernelConfig(**self.kernel) if isinstance(self.kernel, dict)
            else KernelConfig(backend=self.kernel)
        )
        _freeze(
            self,
            comm=comm,
            kernel=kernel,
            tune=TuneConfig.coerce(self.tune),
            adaptive=AdaptiveConfig.coerce(self.adaptive),
            method=MethodConfig.coerce(self.method),
            precondition=PreconditionConfig.coerce(self.precondition),
        )
        policy = self.adaptive.policy
        if (
            self.method.name == "pipelined"
            and policy is not None
            and policy.restart
        ):
            raise ValueError(
                "method 'pipelined' cannot run a restart policy: re-enlarging "
                "would need an extra in-loop SpMBV to rebuild the AZ "
                "recurrence; use adaptive='reduce' (or method='classic')"
            )
        if self.method.name == "pipelined" and self.precondition.kind == "inexact":
            raise ValueError(
                "method 'pipelined' cannot run the iteration-varying "
                "'inexact' preconditioner: a varying M needs the flexible "
                "residual reseed, and rebuilding the AZ recurrence for a "
                "reseeded Z would need an extra in-loop SpMBV; use "
                "method='classic' (periodic reseed) or 'sstep' (reseeds "
                "every block), or a fixed preconditioner kind"
            )

    def replace(self, **overrides) -> "SolverConfig":
        """Return a new config with ``overrides`` applied.

        Accepts both sub-config values (``comm=CommConfig(...)``) and the
        flat spellings of their fields (``strategy="3step"``,
        ``backend="pallas"``, ``tune_mode="model"``, ``policy="reduce"`` …);
        unknown names raise ``ValueError`` listing the accepted keys.
        """
        top: dict = {}
        nested: dict[str, dict] = {}
        own = {f.name for f in dataclasses.fields(self)}
        for key, value in overrides.items():
            if key == "method" and isinstance(value, str):
                # replace(method="sstep", s=4) — route the string through the
                # nested dict so it composes with the flat s/depth/reorth
                nested.setdefault("method", {})["name"] = value
            elif key == "precondition" and isinstance(value, str):
                # replace(precondition="block_jacobi", block=64) — same
                # routing so the kind string composes with the flat knobs
                nested.setdefault("precondition", {})["kind"] = value
            elif key in _FLAT_FIELDS:
                sub, field = _FLAT_FIELDS[key]
                nested.setdefault(sub, {})[field] = value
            elif key in own:
                top[key] = value
            else:
                raise ValueError(
                    f"unknown config override {key!r}; expected a SolverConfig "
                    f"field ({sorted(own)}) or a flat sub-config field "
                    f"({sorted(_FLAT_FIELDS)})"
                )
        for sub, fields in nested.items():
            if sub in top:
                raise ValueError(
                    f"cannot combine {sub}= with flat overrides of its fields "
                    f"({sorted(fields)}) in one replace() call"
                )
            current = getattr(self, sub)
            if sub == "tune":
                current = TuneConfig.coerce(current)
            top[sub] = dataclasses.replace(current, **fields)
        return dataclasses.replace(self, **top)

    @classmethod
    def coerce(cls, value) -> "SolverConfig":
        if value is None:
            return cls()
        if isinstance(value, cls):
            return value
        if isinstance(value, dict):
            return cls(**value)
        raise TypeError(f"config must be a SolverConfig or dict, got {type(value)}")

    def to_json(self) -> str:
        """Serialize the full session spec to a JSON string.

        Lossless: composes the existing :meth:`repro.tune.TunedConfig` and
        :meth:`repro.adaptive.TSelection` round-trips plus the resolved
        :class:`~repro.adaptive.ReductionPolicy`, :class:`MachineParams`,
        and :class:`MethodConfig`, so a cached spec feeds straight back
        through :meth:`from_json` — fixed point asserted in the test suite.
        """
        import json

        return json.dumps(solverconfig_to_dict(self))

    @classmethod
    def from_json(cls, data) -> "SolverConfig":
        """Inverse of :meth:`to_json`; accepts the JSON string or the
        already-parsed dict."""
        import json

        if isinstance(data, (str, bytes)):
            data = json.loads(data)
        return solverconfig_from_dict(data)


def solverconfig_to_dict(cfg: SolverConfig) -> dict:
    """JSON-safe dict form of a SolverConfig (see ``SolverConfig.to_json``)."""
    from repro.tune.autotune import tunedconfig_to_dict

    machine = cfg.comm.machine
    policy = cfg.adaptive.policy
    select = cfg.adaptive.select
    tuned = cfg.tune.tuned
    return dict(
        t=cfg.t,
        tol=float(cfg.tol),
        max_iters=int(cfg.max_iters),
        comm=dict(
            strategy=cfg.comm.strategy,
            overlap=cfg.comm.overlap,
            col_split=cfg.comm.col_split,
            machine=None if machine is None else dataclasses.asdict(machine),
        ),
        kernel=dict(
            backend=cfg.kernel.backend,
            ell_block=list(cfg.kernel.ell_block),
        ),
        tune=dict(
            mode=cfg.tune.mode,
            tuned=None if tuned is None else tunedconfig_to_dict(tuned),
        ),
        adaptive=dict(
            policy=None if policy is None else dataclasses.asdict(policy),
            t_candidates=list(cfg.adaptive.t_candidates),
            select=None if select is None else _tselection_dict(select),
            probe_iters=int(cfg.adaptive.probe_iters),
            probe_rtol=float(cfg.adaptive.probe_rtol),
            explicit_off=bool(cfg.adaptive.explicit_off),
        ),
        method=dataclasses.asdict(cfg.method),
        precondition=_precondition_dict(cfg.precondition),
    )


def _precondition_dict(pc: PreconditionConfig) -> dict:
    d = dataclasses.asdict(pc)
    if d.get("eig_bounds") is not None:
        d["eig_bounds"] = list(d["eig_bounds"])  # JSON has no tuples
    return d


def _tselection_dict(select) -> dict:
    from repro.adaptive.select_t import tselection_to_dict

    return tselection_to_dict(select)


def solverconfig_from_dict(d: dict) -> SolverConfig:
    """Inverse of :func:`solverconfig_to_dict`."""
    from repro.adaptive.reduce import ReductionPolicy
    from repro.adaptive.select_t import tselection_from_dict
    from repro.core.machines import MachineParams
    from repro.tune.autotune import tunedconfig_from_dict

    comm = dict(d["comm"])
    if comm.get("machine") is not None:
        comm["machine"] = MachineParams(**comm["machine"])
    kernel = dict(d["kernel"])
    kernel["ell_block"] = tuple(kernel["ell_block"])
    tune = dict(d["tune"])
    if tune.get("tuned") is not None:
        tune["tuned"] = tunedconfig_from_dict(tune["tuned"])
    adaptive = dict(d["adaptive"])
    if adaptive.get("policy") is not None:
        adaptive["policy"] = ReductionPolicy(**adaptive["policy"])
    if adaptive.get("select") is not None:
        adaptive["select"] = tselection_from_dict(adaptive["select"])
    adaptive["t_candidates"] = tuple(adaptive["t_candidates"])
    precondition = dict(d.get("precondition") or {})
    if precondition.get("eig_bounds") is not None:
        precondition["eig_bounds"] = tuple(precondition["eig_bounds"])
    return SolverConfig(
        t=d["t"],
        tol=d["tol"],
        max_iters=d["max_iters"],
        comm=CommConfig(**comm),
        kernel=KernelConfig(**kernel),
        tune=TuneConfig(**tune),
        adaptive=AdaptiveConfig(**adaptive),
        method=MethodConfig(**d["method"]),
        precondition=PreconditionConfig(**precondition),
    )
