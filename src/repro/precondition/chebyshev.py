"""Chebyshev polynomial preconditioner + build-time eigenvalue bounds.

``M⁻¹ = p_d(A)`` with ``p_d`` the degree-``d`` Chebyshev acceleration
polynomial on an interval ``[λmin, λmax]`` covering the spectrum.  The
normalization fixes ``1 - λ p_d(λ)`` to the shifted-scaled Chebyshev
polynomial with value 1 at λ = 0, so ``p_d(λ) > 0`` on ``(0, λmax]`` —
M stays SPD for *any* SPD A whose spectrum the interval tops (an
overestimated λmax is safe, only suboptimal).

Each apply runs the standard semi-iterative recurrence (Saad, *Iterative
Methods*, Alg. 12.1) from a zero initial guess: ``degree - 1`` operator
applications, i.e. p2p SpMBV exchanges only — the preconditioner adds
**zero** collectives to the iteration, which is what lets the classic
scheme keep its two-psum HLO invariant under preconditioning.

λmax is estimated once at build time by power iteration *through the
operator apply* (deterministic seed): the sequential builder runs the
vectorized CSR SpMV, the distributed builder the width-1 node-aware
SpMBV sub-plan — p2p halo exchange only, no densification and no
collective beyond the plan's collective-permutes (the Rayleigh quotient
and norms reduce host-side after unshard; the zero-all-reduce property
is pinned in ``tests/dist_worker.py``).  λmin defaults to
λmax / eig_ratio — clipping the lowest modes is the usual
Chebyshev-preconditioning trade (they are cheap for CG itself to
resolve).
"""

from __future__ import annotations

import numpy as np


def estimate_lambda_max(a, iters: int = 25, seed: int = 0, *, matvec=None) -> float:
    """Power-iteration estimate of the largest eigenvalue of SPD ``a``
    (returns the final Rayleigh quotient × 1.05 safety).

    ``matvec`` is the ``(n,) -> (n,)`` operator apply the iteration runs
    through; the default is the vectorized CSR SpMV (never the historical
    per-row host loop).  The distributed builder passes
    :func:`distributed_power_matvec` so the estimate exercises the same
    p2p exchange path the solve itself will run.
    """
    n = a.shape[0]
    if matvec is None:
        import jax.numpy as jnp

        from repro.sparse.csr import csr_spmv

        matvec = lambda v: np.asarray(csr_spmv(a, jnp.asarray(v)))
    v = np.random.default_rng(seed).standard_normal(n)
    v /= np.linalg.norm(v)
    lam = 1.0
    for _ in range(iters):
        w = np.asarray(matvec(v), dtype=np.float64)
        lam = float(v @ w)
        nw = np.linalg.norm(w)
        if nw == 0:
            break
        v = w / nw
    return 1.05 * lam


def distributed_power_matvec(op):
    """``(n,) -> (n,)`` matvec through the distributed SpMBV for the λmax
    power iteration.

    Runs the width-1 sub-plan (``plan.at_width(1)``), so the halo exchange
    moves exactly one column of bytes through the plan's
    collective-permutes and the lowered step program carries **zero**
    all-reduces — the Rayleigh quotient and norms are reduced host-side
    after :meth:`~repro.sparse.spmbv.DistributedSpMBV.unshard`.  The
    collective structure is pinned in ``tests/dist_worker.py``.
    """
    import jax

    step = jax.jit(op.matvec_fn(t_active=1))

    def matvec(v):
        return op.unshard(step(op.shard_vector(np.asarray(v)[:, None])))[:, 0]

    return matvec


def resolve_bounds(a, cfg, *, matvec=None) -> tuple[float, float]:
    """The Chebyshev interval: explicit ``eig_bounds`` or the power-iteration
    estimate with ``λmin = λmax / eig_ratio``."""
    if cfg.eig_bounds is not None:
        return cfg.eig_bounds
    lmax = estimate_lambda_max(a, iters=cfg.power_iters, matvec=matvec)
    return lmax / cfg.eig_ratio, lmax


def make_chebyshev_apply(a_apply, lmin: float, lmax: float, degree: int):
    """Return ``f(V) -> p_d(A) V`` via the Chebyshev semi-iteration.

    ``a_apply`` is the (possibly distributed) block SpMBV; the recurrence is
    columnwise-linear, so zero columns stay zero — safe under the adaptive
    width mask.
    """
    theta = (lmax + lmin) / 2.0
    delta = (lmax - lmin) / 2.0
    sigma1 = theta / delta

    def apply(x):
        rho = 1.0 / sigma1
        d = x / theta
        y = d
        for _ in range(degree - 1):
            rho_new = 1.0 / (2.0 * sigma1 - rho)
            d = (rho_new * rho) * d + (2.0 * rho_new / delta) * (x - a_apply(y))
            y = y + d
            rho = rho_new
        return y

    return apply
