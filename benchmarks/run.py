"""Benchmark harness: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (model-driven rows are suffixed
``_model``; the rest are measured CPU wall times).

The multi-device kernel-vs-oracle / overlap-vs-blocking sweep is a separate
entry point (it must force 8 host devices before importing jax):

    PYTHONPATH=src python benchmarks/kernel_sweep.py
"""

import sys
import time
from pathlib import Path

# make `benchmarks.*` importable when invoked as `python benchmarks/run.py`
# (sys.path[0] is the script dir, not the repo root)
sys.path.insert(0, str(Path(__file__).resolve().parents[1]))


def main() -> None:
    from benchmarks.common import enable_x64

    enable_x64()
    from benchmarks import paper_figures

    only = sys.argv[1] if len(sys.argv) > 1 else None
    print("name,us_per_call,derived")
    for fn in paper_figures.ALL:
        if only and only not in fn.__name__:
            continue
        t0 = time.time()
        try:
            for r in fn():
                print(r, flush=True)
        except Exception as e:  # noqa: BLE001
            print(f"{fn.__name__}/ERROR,0,{type(e).__name__}: {e}", flush=True)
        print(f"# {fn.__name__} done in {time.time()-t0:.1f}s", file=sys.stderr, flush=True)


if __name__ == "__main__":
    main()
