"""Performance models from the paper (eqs. 2.5–2.8, 3.1–3.4, 4.2–4.4).

Every function returns seconds.  ``g`` is a :class:`CommGraph` (exact message
statistics measured from a partitioned matrix), ``machine`` a
:class:`MachineParams`, ``t`` the enlarging factor.
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.core.comm_graph import CommGraph, OptimalPlan, build_optimal_plan
from repro.core.machines import MachineParams
from repro.core.ecg import ECGOperationCounts


# ---------------------------------------------------------------- primitives
def postal(alpha: float, rate: float, m: float, s: float) -> float:
    """Standard postal model T = α·m + s/R   (eq. 2.6)."""
    return alpha * m + s / rate


def max_rate(machine: MachineParams, m: float, s: float, ppn: int | None = None) -> float:
    """Max-rate model T = α·m + max(ppn·s/R_N, s/R_b)   (eq. 2.5)."""
    ppn = machine.ppn if ppn is None else ppn
    return machine.alpha * m + max(ppn * s / machine.R_N, s / machine.R_b)


# ------------------------------------------------------- SpMBV p2p exchange
def t_standard_postal(g: CommGraph, t: int, machine: MachineParams) -> float:
    """Postal p2p term of eq. (3.1): α·m + s·t/R_b."""
    s = g.s_standard_rows * g.row_block * machine.f
    return postal(machine.alpha, machine.R_b, g.m_standard, s * t)


def t_standard(g: CommGraph, t: int, machine: MachineParams) -> float:
    """Max-rate p2p term of eq. (3.2): α·m + max(ppn·s·t/R_N, s·t/R_b)."""
    s = g.s_standard_rows * g.row_block * machine.f
    return max_rate(machine, g.m_standard, s * t, ppn=g.ppn)


def t_2step(g: CommGraph, t: int, machine: MachineParams) -> float:
    """2-step node-aware model with block factor t (eq. 4.2)."""
    f = machine.f * g.row_block
    s_node = g.s_node_rows * f
    s_proc = g.s_proc_rows * f
    inter = machine.alpha * g.m_proc_to_node + max(
        t * s_node / machine.R_N, t * s_proc / machine.R_b
    )
    intra = machine.alpha_l * (g.ppn - 1) + t * s_proc / machine.R_bl
    return inter + intra


def t_3step(g: CommGraph, t: int, machine: MachineParams) -> float:
    """3-step node-aware model with block factor t (eq. 4.3)."""
    f = machine.f * g.row_block
    s_node = g.s_node_rows * f
    s_proc = g.s_proc_3step_rows * f
    s_nn = g.s_node_to_node_rows * f
    inter = machine.alpha * g.m_node_to_node / g.ppn + max(
        t * s_node / machine.R_N, t * s_proc / machine.R_b
    )
    intra = 2 * (machine.alpha_l * (g.ppn - 1) + t * s_nn / machine.R_bl)
    return inter + intra


def t_optimal(
    g: CommGraph, t: int, machine: MachineParams, plan: OptimalPlan | None = None
) -> float:
    """Nodal-optimal model (§4.3): plan-derived message counts/sizes, bounded
    by eq. (4.4)."""
    plan = plan or build_optimal_plan(g, t, machine)
    f = machine.f * g.row_block
    s_node = g.s_node_rows * f * t  # bytes injected are dedup'd — same as 2-/3-step
    inter = machine.alpha * plan.max_msgs + max(
        s_node / machine.R_N, plan.max_bytes / machine.R_b
    )
    intra = 2 * (
        machine.alpha_l * (g.ppn - 1) + plan.intra_moved.max(initial=0) / machine.R_bl
    )
    return inter + intra


STRATEGIES = ("standard", "2step", "3step", "optimal")


def t_p2p(g: CommGraph, t: int, machine: MachineParams, strategy: str) -> float:
    return {
        "standard": t_standard,
        "2step": t_2step,
        "3step": t_3step,
        "optimal": t_optimal,
    }[strategy](g, t, machine)


def tune_strategy(g: CommGraph, t: int, machine: MachineParams) -> tuple[str, dict[str, float]]:
    """Paper §4.3 'tuning': evaluate all strategies, return (best, all-times).

    On the real machine this is four trial SpMBVs at communicator-setup time;
    here the same decision is made from the measured comm statistics + model.
    """
    times = {s: t_p2p(g, t, machine, s) for s in STRATEGIES}
    best = min(times, key=times.get)
    return best, times


# ----------------------------------------------------------- ECG iteration
def t_collective_n(
    p: int, machine: MachineParams, n_collectives: float, payload_floats: float
) -> float:
    """Generalized collective term: n·α·log(p) latency legs + f·payload/R_b.

    The classic scheme's eq. (3.1)/(3.2) term is the (2, 4t²) instance; the
    pluggable iteration schemes (:mod:`repro.core.methods`) charge their own
    (psums-per-block, payload) pairs through the same shape — see
    ``repro.tune.method_sync_cost``.
    """
    return (
        n_collectives * machine.alpha * math.log2(max(p, 2))
        + machine.f * payload_floats / machine.R_b
    )


def t_collective(p: int, t: int, machine: MachineParams) -> float:
    """Collective term of eqs. (3.1)/(3.2): 2·α·log(p) + f·4t²/R_b."""
    return t_collective_n(p, machine, 2, 4 * t * t)


def t_computation(counts: ECGOperationCounts, machine: MachineParams) -> float:
    """Computation model, eq. (3.3)."""
    return machine.gamma * counts.total_flops


def t_ecg_iteration(
    g: CommGraph,
    counts: ECGOperationCounts,
    machine: MachineParams,
    strategy: str = "standard",
) -> "ECGIterationModel":
    """Full per-iteration model, eq. (3.4), with selectable p2p strategy."""
    return ECGIterationModel(
        p2p=t_p2p(g, counts.t, machine, strategy),
        collective=t_collective(counts.p, counts.t, machine),
        computation=t_computation(counts, machine),
    )


@dataclasses.dataclass(frozen=True)
class ECGIterationModel:
    p2p: float
    collective: float
    computation: float

    @property
    def total(self) -> float:
        return self.p2p + self.collective + self.computation

    @property
    def p2p_fraction(self) -> float:
        return self.p2p / self.total

    def as_dict(self) -> dict[str, float]:
        return dict(
            p2p=self.p2p,
            collective=self.collective,
            computation=self.computation,
            total=self.total,
            p2p_fraction=self.p2p_fraction,
        )


# -------------------------------------------- ping / split curves (Fig 4.6/4.7)
def ping_time(machine: MachineParams, nbytes: float, where: str, active: int = 1) -> float:
    """Time to move ``nbytes`` between two processes.

    where: 'socket' | 'node' | 'network'.  ``active`` = concurrently
    communicating processes (drives the injection limit, Fig 4.6).
    """
    if where == "socket":
        return machine.alpha_l + nbytes / machine.R_bl
    if where == "node":
        # cross-socket on-node: ~2x the latency, somewhat lower bandwidth
        return 2 * machine.alpha_l + nbytes / (0.6 * machine.R_bl)
    if where == "network":
        return machine.alpha + max(active * nbytes / machine.R_N, nbytes / machine.R_b)
    raise ValueError(where)


def split_send_time(machine: MachineParams, nbytes: float, ppn: int) -> float:
    """Time to move ``nbytes`` node-to-node split across ppn processes (Fig 4.7)."""
    share = nbytes / ppn
    return machine.alpha + max(nbytes / machine.R_N, share / machine.R_b)
