"""Iteration-scheme sweep: classic vs pipelined vs s-step (per t).

    PYTHONPATH=src python benchmarks/method_sweep.py [--smoke] [--json PATH]

For every scheme x t in {2, 4, 8}, three observations:

* **iterations / wall seconds** — a sequential ECGSolver solve per scheme
  (sstep rows report both outer blocks and effective iterations = blocks·s);
* **measured collectives** — the scheme's *compiled* distributed program is
  lowered on an 8-device host mesh and its ``all-reduce`` opcodes counted:
  psums/iter = (all-reduces − 2 norm reductions) / iterations-per-block.
  This is the measured counterpart of ``MethodSpec.collectives_per_
  iteration`` — the sweep gates on the HLO, not on the spec's claim;
* **modeled ranking** — ``repro.tune.rank_methods`` under the structural
  exchange model, so the JSON tracks whether the synchronization-aware cost
  model still orders the schemes the way the measured collective counts say
  it should.

Gates (asserted in CI bench-smoke from the summary):

* every sstep row measures collectives/iter <= 2/s + eps — the amortization
  is real in the lowered program;
* pipelined measures no more collectives/iter than classic at every t and
  its packed Gram psum carries no SpMBV dependence (the overlap claim —
  proven structurally in ``tests/dist_worker.py``);
* every scheme converges, and sstep's effective iterations stay within 2x
  of classic's count (the monomial basis must not squander the psums it
  saves).

Writes machine-readable ``BENCH_method_sweep.json``; ``--smoke`` shrinks
the problem for the CI run.
"""

import argparse
import json
import os
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="small problem for CI")
    ap.add_argument("--t", type=int, nargs="+", default=[2, 4, 8])
    ap.add_argument("--s", type=int, nargs="+", default=[2, 4],
                    help="s-step depths to sweep")
    ap.add_argument("--tol", type=float, default=1e-8)
    ap.add_argument("--json", default="BENCH_method_sweep.json")
    args = ap.parse_args()

    # the measured-collectives column needs a device mesh; force host devices
    # before jax initializes (same re-exec dance as repro.launch.solve)
    if "--xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=8"
        ).strip()
        os.execv(sys.executable, [sys.executable] + sys.argv)

    import jax

    jax.config.update("jax_enable_x64", True)
    import numpy as np

    from repro.core.methods import get_method
    from repro.solver import CommConfig, ECGSolver, SolverConfig
    from repro.sparse import dg_laplace_2d, fd_laplace_2d
    from repro.tune import rank_methods

    if args.smoke:
        a = fd_laplace_2d(16)  # 256 rows
        max_iters = 800
    else:
        a = dg_laplace_2d((12, 12), block=8)  # 1152 rows
        max_iters = 4000
    n = a.shape[0]
    rng = np.random.default_rng(0)
    b = rng.standard_normal(n)
    cands = sorted({t for t in args.t if t <= n})
    schemes = [("classic", 1), ("pipelined", 1)] + [("sstep", s) for s in sorted(set(args.s))]
    mesh = jax.make_mesh((2, 4), ("node", "proc"))
    print(f"# method_sweep: {n} rows, {a.nnz} nnz, t in {cands}, "
          f"schemes {[m + (f'[s={s}]' if s > 1 else '') for m, s in schemes]}")

    rows = []
    for t in cands:
        for method, s in schemes:
            label = method + (f"[s={s}]" if s > 1 else "")
            spec = get_method(method)
            mcfg = dict(name=method, s=s)

            solver = ECGSolver.build(a, config=SolverConfig(
                t=t, tol=args.tol, max_iters=max_iters, method=mcfg), b=b)
            res = solver.solve(b)  # warm: owns the compile
            t0 = time.perf_counter()
            res = solver.solve(b)
            wall_s = time.perf_counter() - t0
            eff_iters = res.n_iters * spec.iters_per_block(s)

            dist = ECGSolver.build(a, mesh, SolverConfig(
                t=t, tol=args.tol, max_iters=max_iters,
                comm=CommConfig(strategy="3step"), method=mcfg))
            txt = dist.lowered_text()
            n_ar = txt.count(" all-reduce(")
            # 4 = body psums + body norm + init norm; measured psums/iter
            # excludes the two norm reductions (identical across schemes)
            meas_coll_iter = (n_ar - 2) / spec.iters_per_block(s)
            rows.append(dict(
                method=label, name=method, s=s, t=t,
                iters=int(res.n_iters), eff_iters=int(eff_iters),
                converged=bool(res.converged), wall_s=wall_s,
                hlo_allreduces=int(n_ar),
                collectives_per_iter_measured=meas_coll_iter,
                collectives_per_iter_spec=spec.collectives_per_iteration(s),
            ))
            print(f"t={t:>2} {label:<10} iters={res.n_iters:>4} "
                  f"(eff {eff_iters:>4}) wall={wall_s*1e3:7.1f}ms "
                  f"allreduce={n_ar} coll/iter={meas_coll_iter:.2f}")

    best, table = rank_methods(a, cands[len(cands) // 2], n_nodes=2, ppn=4,
                               s=max(args.s), mode="model:structural")
    print(f"modeled ranking (structural, t={cands[len(cands) // 2]}): best={best}")

    eps = 1e-9
    by = lambda m, t: next(r for r in rows if r["name"] == m and r["t"] == t and r["s"] == 1)
    sstep_rows = [r for r in rows if r["name"] == "sstep"]
    summary = dict(
        all_converged=all(r["converged"] for r in rows),
        sstep_collectives_leq_2_over_s=all(
            r["collectives_per_iter_measured"] <= 2 / r["s"] + eps
            for r in sstep_rows
        ),
        pipelined_leq_classic=all(
            by("pipelined", t)["collectives_per_iter_measured"]
            <= by("classic", t)["collectives_per_iter_measured"] + eps
            for t in cands
        ),
        sstep_eff_iters_within_2x_classic=all(
            r["eff_iters"] <= 2 * by("classic", r["t"])["iters"]
            for r in sstep_rows
        ),
        modeled_best=best,
        modeled_table={m: {k: float(v) for k, v in row.items()}
                       for m, row in table.items()},
    )
    out = dict(
        config=dict(n=n, nnz=a.nnz, t=cands, tol=args.tol, smoke=args.smoke,
                    schemes=[r["method"] for r in rows[: len(schemes)]]),
        rows=rows, summary=summary,
    )
    with open(args.json, "w") as f:
        json.dump(out, f, indent=2)
    print(f"summary: {json.dumps({k: v for k, v in summary.items() if not isinstance(v, dict)})}")
    print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
