"""End-to-end behaviour tests for the paper's headline claims."""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.sparse import dg_laplace_2d, csr_spmv, csr_spmbv, partition_csr
from repro.sparse.matrices import example_2_1_graph
from repro.core import cg_solve, ecg_solve
from repro.core.comm_graph import build_comm_graph
from repro.core.machines import BLUE_WATERS, LASSEN
from repro.core.models import t_2step, t_3step, tune_strategy, STRATEGIES
from repro.core.ecg import ECGOperationCounts
from repro.core.models import t_ecg_iteration


class TestPaperClaims:
    """Each test pins one claim from the paper to our implementation."""

    def test_claim_ecg_reduces_iterations(self):
        """Fig 3.2: ECG converges in fewer iterations than CG, improving with t."""
        a = dg_laplace_2d((12, 12), block=8)
        b = jnp.asarray(np.random.default_rng(0).standard_normal(a.shape[0]))
        it_cg = cg_solve(lambda v: csr_spmv(a, v), b, tol=1e-8, max_iters=4000).n_iters
        it_t4 = ecg_solve(lambda V: csr_spmbv(a, V), b, t=4, tol=1e-8, max_iters=4000).n_iters
        it_t16 = ecg_solve(lambda V: csr_spmbv(a, V), b, t=16, tol=1e-8, max_iters=4000).n_iters
        assert it_t4 < it_cg
        assert it_t16 < it_t4

    def test_claim_two_reductions_per_iteration(self):
        """§3.1: exactly two allreduce payloads, t² and 3t² floats."""
        c = ECGOperationCounts(n=1000, nnz=8000, p=4, t=6)
        assert c.allreduce_payload_floats == (36, 108)

    def test_claim_node_aware_bytes_equal(self):
        """§2.2: 2-step and 3-step move the same (deduplicated) bytes,
        never more than standard."""
        g, blk = example_2_1_graph(scale=0.2)
        pm = partition_csr(g, 128)
        cg = build_comm_graph(pm, ppn=16, row_block=blk)
        assert cg.total_node_aware_rows <= cg.total_standard_rows
        # 2-step bytes == 3-step bytes == sum of node-pair rows (both dedup'd)
        assert cg.node_injected_rows.sum() == cg.total_node_aware_rows

    def test_claim_p2p_is_the_bottleneck_at_scale(self):
        """§3.2/Fig 3.3: at scale, communication dominates the ECG iteration
        and p2p (the SpMBV exchange) is its largest component — 'the
        communication bottleneck of ECG shifted to the point-to-point
        communication'."""
        g, blk = example_2_1_graph()
        n_rows, nnz = g.shape[0] * blk, g.nnz * blk * blk
        comm_shares = []
        for p in (256, 2048, 8192):
            pm = partition_csr(g, p)
            cg = build_comm_graph(pm, ppn=16, row_block=blk)
            counts = ECGOperationCounts(n=n_rows, nnz=nnz, p=p, t=10)
            m = t_ecg_iteration(cg, counts, BLUE_WATERS, "standard")
            comm_shares.append((m.p2p + m.collective) / m.total)
            assert m.p2p > m.collective  # p2p, not the allreduces, dominates
            assert m.p2p > m.computation * 0.5
        # total communication share grows with p (strong-scaling limit)
        assert comm_shares[0] < comm_shares[-1]

    def test_claim_3step_loses_to_2step_as_t_grows(self):
        """§4.2: 'we now see that 2-step is generally the best fit ... as
        message size, and thus t, increases' — the 3-step/2-step time ratio
        must grow with t (single-buffer aggregation saturates)."""
        g, blk = example_2_1_graph(scale=0.25)
        pm = partition_csr(g, 256)
        cg = build_comm_graph(pm, ppn=16, row_block=blk)
        ratios = [
            t_3step(cg, t, BLUE_WATERS) / t_2step(cg, t, BLUE_WATERS) for t in (1, 5, 20)
        ]
        assert ratios[0] < ratios[-1], ratios

    def test_claim_eq_4_4(self):
        """§4.3 eq (4.4): optimal plan message count bounded by
        max(m_proc→node, ppn)."""
        from repro.core.comm_graph import build_optimal_plan

        g, blk = example_2_1_graph(scale=0.25)
        pm = partition_csr(g, 256)
        cg = build_comm_graph(pm, ppn=16, row_block=blk)
        for t in (1, 5, 20):
            plan = build_optimal_plan(cg, t, BLUE_WATERS)
            assert plan.max_msgs <= max(cg.m_proc_to_node, cg.ppn)

    def test_claim_tuning_never_loses(self):
        """§4.3: tuned communication (argmin of the four) is at least as good
        as every individual strategy, on both machines."""
        g, blk = example_2_1_graph(scale=0.25)
        pm = partition_csr(g, 256)
        cg = build_comm_graph(pm, ppn=16, row_block=blk)
        for mach in (BLUE_WATERS, LASSEN.with_ppn(16)):
            for t in (5, 20):
                best, times = tune_strategy(cg, t, mach)
                assert times[best] == min(times.values())
                assert times[best] <= times["standard"]


class TestFrameworkIntegration:
    def test_solver_framework_roundtrip(self):
        """quickstart path: build → solve → verify true residual."""
        a = dg_laplace_2d((8, 8), block=8)
        rng = np.random.default_rng(1)
        b = jnp.asarray(rng.standard_normal(a.shape[0]))
        res = ecg_solve(lambda V: csr_spmbv(a, V), b, t=8, tol=1e-9, max_iters=2000)
        ad = np.asarray(a.todense(), np.float64)
        relres = np.linalg.norm(ad @ np.asarray(res.x) - np.asarray(b)) / np.linalg.norm(b)
        assert res.converged and relres < 1e-7

    def test_all_strategies_available(self):
        assert set(STRATEGIES) == {"standard", "2step", "3step", "optimal"}
