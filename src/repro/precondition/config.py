"""Typed, validated preconditioner configuration.

:class:`PreconditionConfig` is the sixth sub-config of
:class:`~repro.solver.config.SolverConfig` — it selects and parameterizes
the preconditioner the solve loop applies, without adding a single keyword
argument to the solver API.  Like the other sub-configs it is a frozen
dataclass, validates at construction, and coerces the convenient string
spelling (``precondition="block_jacobi"``).

Four kinds ship (see :mod:`repro.precondition` for the operators):

* ``"none"``         — identity; the solve is bit-identical to an
                       unpreconditioned build.
* ``"block_jacobi"`` — block-diagonal M from the operator's own row blocks
                       (the partition's per-rank slot ranges distributed, a
                       uniform ``block`` split sequentially); applies are
                       batched triangular solves against host-Cholesky
                       factors, local to every rank.
* ``"chebyshev"``    — degree-``degree`` Chebyshev polynomial in A on an
                       eigenvalue interval; ``eig_bounds=None`` estimates
                       λmax by power iteration at build time and sets
                       λmin = λmax / ``eig_ratio``.  Applies cost
                       ``degree - 1`` extra SpMBVs (p2p only — no psum).
* ``"inexact"``      — iteration-varying weighted-Jacobi sweeps: the
                       flexible-ECG path (Moufawad arXiv:2305.19013).  The
                       classic scheme runs it with a periodic residual
                       reseed (``reseed``) — its direction chain never
                       re-reads the residual, so a varying M⁻¹ₖ needs the
                       flexible restart; s-step reseeds every block by
                       construction; pipelined cannot reseed at all and
                       rejects this kind.
"""

from __future__ import annotations

import dataclasses

PRECONDITIONS = ("none", "block_jacobi", "chebyshev", "inexact")


def _freeze(cls, **updates):
    for k, v in updates.items():
        object.__setattr__(cls, k, v)


@dataclasses.dataclass(frozen=True)
class PreconditionConfig:
    """Preconditioner selection + knobs (see module docstring).

    kind:        ``none | block_jacobi | chebyshev | inexact``.
    block:       block-Jacobi block size (rows per diagonal block).
    degree:      Chebyshev polynomial degree (>= 1; applies cost
                 ``degree - 1`` SpMBVs each).
    eig_bounds:  explicit ``(lambda_min, lambda_max)`` Chebyshev interval;
                 ``None`` = estimate at build time.
    eig_ratio:   λmax/λmin ratio assumed when only λmax is estimated.
    power_iters: power-iteration count of the build-time λmax estimate.
    sweeps:      weighted-Jacobi sweep count of the inexact kind (its
                 damping varies with the iteration index — that
                 variability is what makes it exercise the flexible path).
    omega:       weighted-Jacobi damping factor of the inexact kind.
    reseed:      flexible-restart period of the inexact kind under the
                 classic scheme: every that-many iterations the direction
                 chain reseeds from the preconditioned residual (costs no
                 collective; too small a period starves the chain of
                 conjugate directions — 8 is a robust default).
    """

    kind: str = "none"
    block: int = 32
    degree: int = 4
    eig_bounds: tuple[float, float] | None = None
    eig_ratio: float = 30.0
    power_iters: int = 25
    sweeps: int = 2
    omega: float = 2.0 / 3.0
    reseed: int = 8

    def __post_init__(self):
        if self.kind not in PRECONDITIONS:
            raise ValueError(
                f"unknown preconditioner kind {self.kind!r}; "
                f"expected one of {PRECONDITIONS}"
            )
        if not isinstance(self.block, int) or self.block < 1:
            raise ValueError(f"block must be an int >= 1, got {self.block!r}")
        if not isinstance(self.degree, int) or self.degree < 1:
            raise ValueError(f"degree must be an int >= 1, got {self.degree!r}")
        if self.eig_bounds is not None:
            eb = tuple(float(x) for x in self.eig_bounds)
            if len(eb) != 2 or not (0 < eb[0] < eb[1]):
                raise ValueError(
                    f"eig_bounds must be (lambda_min, lambda_max) with "
                    f"0 < lambda_min < lambda_max, got {self.eig_bounds!r}"
                )
            _freeze(self, eig_bounds=eb)
        if not self.eig_ratio > 1:
            raise ValueError(f"eig_ratio must be > 1, got {self.eig_ratio!r}")
        if not isinstance(self.power_iters, int) or self.power_iters < 1:
            raise ValueError(
                f"power_iters must be an int >= 1, got {self.power_iters!r}"
            )
        if not isinstance(self.sweeps, int) or self.sweeps < 1:
            raise ValueError(f"sweeps must be an int >= 1, got {self.sweeps!r}")
        if not 0 < self.omega <= 1:
            raise ValueError(f"omega must be in (0, 1], got {self.omega!r}")
        if not isinstance(self.reseed, int) or self.reseed < 2:
            raise ValueError(f"reseed must be an int >= 2, got {self.reseed!r}")

    @property
    def active(self) -> bool:
        return self.kind != "none"

    @classmethod
    def coerce(cls, value) -> "PreconditionConfig":
        """Normalize the accepted spellings into a PreconditionConfig."""
        if value is None:
            return cls()
        if isinstance(value, cls):
            return value
        if isinstance(value, dict):
            return cls(**value)
        if isinstance(value, str):
            return cls(kind=value)
        raise TypeError(
            f"precondition must be a PreconditionConfig, a kind string, a "
            f"dict of PreconditionConfig fields, or None; got {type(value)}"
        )
