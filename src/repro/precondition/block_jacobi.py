"""Block-Jacobi preconditioner: M = blockdiag(A) with host Cholesky factors.

Every block is a principal submatrix of the SPD operator, so M is SPD and
its Cholesky factorization exists unconditionally.  Factorization happens
once at build time on the host (numpy — the blocks are small and dense);
each apply is a batched two-triangle solve ``L Lᵀ y = x`` per block, served
by the :mod:`repro.kernels.block_trisolve` op (Pallas on TPU, jnp oracle
elsewhere).

Distributed, the blocks are carved *inside* each rank's padded slot range —
a block never straddles ranks, so the apply is embarrassingly local (zero
collectives, exactly what keeps the classic scheme's two-psum HLO invariant
intact).  Padding slots get identity rows, which makes M the identity on
the padding subspace: padded-slot zeros stay zero through every apply.
"""

from __future__ import annotations

import numpy as np


def _csr_arrays(a):
    return (
        np.asarray(a.indptr),
        np.asarray(a.indices),
        np.asarray(a.data),
    )


def extract_blocks(a, row_of_slot: np.ndarray, block: int) -> np.ndarray:
    """Dense diagonal blocks of A in *slot* order.

    row_of_slot: (n_slots,) true-row id per slot, -1 for padding slots.
    Returns (nb, block, block) with ``nb = n_slots // block`` (n_slots must
    already be padded to a multiple of ``block``); slot pairs whose rows
    live in the same block contribute ``A[ri, rj]``, padding slots
    contribute an identity row/column.
    """
    n_slots = row_of_slot.shape[0]
    if n_slots % block:
        raise ValueError(f"n_slots={n_slots} not a multiple of block={block}")
    indptr, indices, data = _csr_arrays(a)
    nb = n_slots // block
    out = np.zeros((nb, block, block), dtype=np.asarray(data).dtype)
    for bi in range(nb):
        rows = row_of_slot[bi * block : (bi + 1) * block]
        # true-row id -> local position inside this block
        local = {int(r): j for j, r in enumerate(rows) if r >= 0}
        for j, r in enumerate(rows):
            if r < 0:  # padding slot: identity row keeps M SPD and pads inert
                out[bi, j, j] = 1.0
                continue
            lo, hi = int(indptr[r]), int(indptr[r + 1])
            for c, v in zip(indices[lo:hi], data[lo:hi]):
                jj = local.get(int(c))
                if jj is not None:
                    out[bi, j, jj] = v
        if out[bi].diagonal().min() <= 0:
            raise ValueError(
                f"block {bi} has a non-positive diagonal entry — the operator "
                "is not SPD (block-Jacobi needs an SPD matrix)"
            )
    return out


def factor_blocks(blocks: np.ndarray) -> np.ndarray:
    """Lower Cholesky factor per block: blocks[i] = L[i] @ L[i].T."""
    return np.linalg.cholesky(blocks)


def slot_layout(n: int, block: int) -> tuple[np.ndarray, int]:
    """Sequential slot layout: rows 0..n-1 then identity padding slots up to
    the next multiple of ``block``.  Returns (row_of_slot, n_slots)."""
    n_slots = -(-n // block) * block
    row_of_slot = np.full(n_slots, -1, dtype=np.int64)
    row_of_slot[:n] = np.arange(n)
    return row_of_slot, n_slots


def rank_slot_layout(true_row_of_slot: np.ndarray, p: int, block: int) -> np.ndarray:
    """Distributed slot layout: each rank's ``rmax`` slots padded (with -1
    identity slots) to a multiple of ``block`` so no block straddles ranks.

    true_row_of_slot: (p * rmax,) from ``DistributedSpMBV.true_row_of_slot``.
    Returns (p * rmax_pad,) row-of-slot in the padded per-rank order.
    """
    rmax = true_row_of_slot.shape[0] // p
    rmax_pad = -(-rmax // block) * block
    out = np.full(p * rmax_pad, -1, dtype=np.int64)
    for r in range(p):
        out[r * rmax_pad : r * rmax_pad + rmax] = true_row_of_slot[
            r * rmax : (r + 1) * rmax
        ]
    return out
