"""Fixed-t vs auto-t vs reduction-on ECG sweep (iterations, wall time, model).

    PYTHONPATH=src python benchmarks/adaptive_sweep.py [--smoke] [--json PATH]

Three questions, one table:

* **fixed t** — for each candidate enlarging factor: iterations to tol,
  measured wall seconds, and the modeled total cost
  (``iters(t) · T_iter(t)`` from ``repro.adaptive.select_t``).
* **auto t** — does ``t="auto"`` pick a width whose modeled total cost is
  within 10% of the best fixed candidate?  (``auto_gap``/``within_10pct``
  in the summary — the acceptance gauge.)
* **reduction on** — on a rank-deficient splitting (RHS supported on half
  the subdomains) fixed-t breaks down; ``adaptive="reduce"`` must converge,
  and its iteration count is reported next to the breakdown row.
* **probe calibration** — predicted-vs-actual iterations for the probe
  model on (scaled) suite surrogate matrices: for each matrix and candidate
  t, the probe-estimated iteration count next to a full solve's observed
  count, with the per-matrix median absolute relative error as the gauge
  (``probe_calibration`` in the summary).

Writes machine-readable ``BENCH_adaptive_sweep.json`` so the adaptive-solver
trajectory is tracked across PRs; ``--smoke`` shrinks the problems for the
CI smoke run.
"""

import argparse
import json
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="small problem for CI")
    ap.add_argument("--t", type=int, nargs="+", default=[1, 2, 4, 8, 16])
    ap.add_argument("--tol", type=float, default=1e-8)
    ap.add_argument("--json", default="BENCH_adaptive_sweep.json")
    args = ap.parse_args()

    import jax

    jax.config.update("jax_enable_x64", True)
    import numpy as np
    import jax.numpy as jnp

    from repro.adaptive import select_t
    from repro.solver import AdaptiveConfig, ECGSolver, SolverConfig
    from repro.sparse import dg_laplace_2d, fd_laplace_2d

    if args.smoke:
        a = fd_laplace_2d(16)  # 256 rows
        max_iters = 800
    else:
        a = dg_laplace_2d((12, 12), block=8)  # 1152 rows
        max_iters = 4000
    n = a.shape[0]
    rng = np.random.default_rng(0)
    b = rng.standard_normal(n)
    cands = sorted({t for t in args.t if t <= n})
    print(f"# adaptive_sweep: {n} rows, {a.nnz} nnz, t in {cands}, tol={args.tol:g}")

    sel = select_t(a, b, candidates=cands, tol=args.tol)
    print(sel.summary())

    def timed_solve(matrix, bb, t, adaptive=None):
        # compile-once / solve-many: the first solve traces + compiles, the
        # timed second solve is a pure jit-cache hit on the same handle
        solver = ECGSolver.build(matrix, config=SolverConfig(
            t=t, tol=args.tol, max_iters=max_iters,
            adaptive=AdaptiveConfig(policy=adaptive),
        ))
        res = solver.solve(bb)  # warm-up + compile
        t0 = time.perf_counter()
        res = solver.solve(bb)
        jax.block_until_ready(res.x)
        assert solver.stats.traces == 1, "timed solve must not retrace"
        return res, time.perf_counter() - t0

    rows = []
    print("name,iters,wall_s,model_total_s,converged,breakdown")
    for t in cands:
        res, wall = timed_solve(a, b, t)
        model = sel.table[t]["total_cost_s"]
        rows.append(dict(
            name=f"adaptive/fixed_t{t}", mode="fixed", t=t, iters=res.n_iters,
            wall_s=wall, model_total_s=model, converged=res.converged,
            breakdown=res.breakdown,
        ))
        print(f"adaptive/fixed_t{t},{res.n_iters},{wall:.4f},{model:.3e},"
              f"{res.converged},{res.breakdown}", flush=True)

    # auto-t: reuses the selection above (same model) and solves at the pick
    res_auto, wall_auto = timed_solve(a, b, sel.t, adaptive="rankrev")
    rows.append(dict(
        name="adaptive/auto_t", mode="auto", t=sel.t, iters=res_auto.n_iters,
        wall_s=wall_auto, model_total_s=sel.table[sel.t]["total_cost_s"],
        converged=res_auto.converged, breakdown=res_auto.breakdown,
    ))
    print(f"adaptive/auto_t,{res_auto.n_iters},{wall_auto:.4f},"
          f"{sel.table[sel.t]['total_cost_s']:.3e},{res_auto.converged},"
          f"{res_auto.breakdown}", flush=True)

    # reduction-on: rank-deficient splitting (RHS on half the subdomains)
    t_def = max(cands)
    m = max(t_def // 2, 1)
    b_def = np.zeros(n)
    b_def[: (m * n) // t_def] = rng.standard_normal((m * n) // t_def)
    res_break = ECGSolver.build(a, config=SolverConfig(
        t=t_def, tol=args.tol, max_iters=max_iters,
    )).solve(jnp.asarray(b_def))
    res_red, wall_red = timed_solve(a, b_def, t_def, adaptive="reduce")
    events = res_red.reduction_events()
    # unmeasured fields are null, not NaN — bare NaN literals are invalid JSON
    rows.append(dict(
        name=f"adaptive/deficient_fixed_t{t_def}", mode="fixed-deficient", t=t_def,
        iters=res_break.n_iters, wall_s=None, model_total_s=None,
        converged=res_break.converged, breakdown=res_break.breakdown,
    ))
    rows.append(dict(
        name=f"adaptive/deficient_reduce_t{t_def}", mode="reduce", t=t_def,
        iters=res_red.n_iters, wall_s=wall_red, model_total_s=None,
        converged=res_red.converged, breakdown=res_red.breakdown,
        reduction_events=events, final_active=int(res_red.active_hist[res_red.n_iters]),
    ))
    print(f"adaptive/deficient_fixed_t{t_def},{res_break.n_iters},nan,nan,"
          f"{res_break.converged},{res_break.breakdown}")
    print(f"adaptive/deficient_reduce_t{t_def},{res_red.n_iters},{wall_red:.4f},nan,"
          f"{res_red.converged},{res_red.breakdown}")

    # probe-model calibration on suite surrogates (ROADMAP follow-up): how
    # well do the probe-estimated iteration counts predict full solves on
    # matrices with suite structure (blocked / stencil / shuffled), not just
    # the model problem above?
    from repro.sparse.matrices import suite_surrogate

    calib_specs = [("thermal2", 0.08), ("ldoor", 0.04)] if args.smoke else \
                  [("thermal2", 0.15), ("ldoor", 0.08), ("audikw_1", 0.08)]
    calib_t = [t for t in cands if t <= 8] or cands[:1]
    calib = {}
    for name, scale in calib_specs:
        am = suite_surrogate(name, scale=scale)
        nm = am.shape[0]
        bm = np.random.default_rng(1).standard_normal(nm)
        sel_m = select_t(am, bm, candidates=calib_t, tol=args.tol)
        per_t, errs = {}, []
        for t in calib_t:
            pred = sel_m.table[t]["est_iters"]
            res_m = ECGSolver.build(am, config=SolverConfig(
                t=t, tol=args.tol, max_iters=max_iters, adaptive="rankrev",
            )).solve(jnp.asarray(bm))
            actual = res_m.n_iters
            err = abs(pred - actual) / max(actual, 1)
            errs.append(err)
            per_t[str(t)] = dict(pred_iters=pred, actual_iters=actual,
                                 rel_err=err, converged=res_m.converged)
            print(f"calib/{name}_t{t},pred={pred},actual={actual},"
                  f"rel_err={err:.2f}", flush=True)
        calib[name] = dict(
            rows=nm, scale=scale, per_t=per_t,
            median_rel_err=float(np.median(errs)),
        )

    # The gauge must not be tautological: sel.t is the argmin of the *a
    # priori* model (probe-estimated iterations), so comparing against the
    # same table could never fail.  Re-model each candidate ex post with the
    # OBSERVED iteration counts x the modeled per-iteration cost — if the
    # probe calibration mispredicted convergence, the auto pick now shows a
    # real gap against the best fixed candidate.
    iters_obs = {r["t"]: r["iters"] for r in rows if r["mode"] == "fixed"}
    posthoc = {t: iters_obs[t] * sel.table[t]["iter_cost_s"] for t in cands}
    best_fixed = min(posthoc, key=posthoc.get)
    auto_gap = posthoc[sel.t] / posthoc[best_fixed] - 1.0
    fixed_walls = {r["t"]: r["wall_s"] for r in rows if r["mode"] == "fixed"}
    best_wall = min(fixed_walls, key=fixed_walls.get)
    summary = dict(
        auto_t=sel.t,
        # probe early-stop: iterations each candidate's probe actually ran
        # before its fitted rate stabilized (vs the probe_iters budget)
        probe_iters_budget=sel.probe_iters,
        probe_iters_used={str(t): v for t, v in sel.probe_iters_used.items()},
        best_fixed_model_t=best_fixed,
        best_fixed_wall_t=best_wall,
        posthoc_total_s={str(t): v for t, v in posthoc.items()},
        auto_model_gap=auto_gap,
        within_10pct=bool(auto_gap <= 0.10),
        deficient_fixed_breakdown=bool(res_break.breakdown),
        deficient_reduce_converged=bool(res_red.converged),
        reduction_events=events,
        probe_calibration=calib,
    )
    print(f"# auto t={sel.t} vs best fixed (observed iters x modeled iter cost) "
          f"t={best_fixed}: gap={auto_gap:+.1%} within_10pct={summary['within_10pct']}")
    print(f"# deficient t={t_def}: fixed breakdown={res_break.breakdown}, "
          f"reduce converged={res_red.converged} in {res_red.n_iters} iters "
          f"(events {events})")

    with open(args.json, "w") as fh:
        json.dump(dict(benchmark="adaptive_sweep", smoke=args.smoke,
                       tol=args.tol, rows=rows, summary=summary), fh, indent=2)
    print(f"# wrote {args.json}")


if __name__ == "__main__":
    main()
