"""Docs contract: intra-repo links resolve and code snippets are real.

The fast test checks links and snippet syntax on every run; the slow test
executes every ``python`` fence exactly as written (8 forced host devices,
subprocess-isolated — same harness the CI docs job runs via
``tools/check_docs.py``).
"""

import subprocess
import sys
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parents[1]
CHECKER = ROOT / "tools" / "check_docs.py"


def _run(*args, timeout):
    return subprocess.run(
        [sys.executable, str(CHECKER), *args],
        capture_output=True, text=True, timeout=timeout,
    )


def test_links_and_snippet_syntax():
    proc = _run("--syntax-only", timeout=120)
    assert proc.returncode == 0, f"STDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr}"


@pytest.mark.slow
def test_snippets_execute():
    proc = _run(timeout=1800)
    assert proc.returncode == 0, f"STDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr}"
