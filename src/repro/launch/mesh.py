"""Production mesh builders.

Defined as FUNCTIONS so importing this module never touches jax device state
(jax locks the device count on first backend init — see dryrun.py lines 1-2).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Assigned production meshes: 16x16 chips per pod; 2 pods multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_solver_mesh(*, multi_pod: bool = False, ppn: int = 16):
    """Two-level ("node", "proc") grid for the distributed ECG solver.

    On TPU the slow tier is the pod boundary: multi-pod uses (pods=2,
    chips-per-pod=256); the single-pod study groups chips into ICI
    neighbourhoods of ``ppn`` to mirror the paper's (node, ppn) layout.
    """
    n_dev = len(jax.devices())
    if multi_pod:
        return jax.make_mesh((2, n_dev // 2), ("node", "proc"))
    return jax.make_mesh((n_dev // ppn, ppn), ("node", "proc"))


def make_smoke_mesh():
    """1x1 mesh for CPU smoke paths."""
    return jax.make_mesh((1, 1), ("data", "model"))
