"""Observability benchmark: tracer overhead gate + model-drift validation.

    PYTHONPATH=src python benchmarks/observe_bench.py [--smoke] [--json PATH]
                                                      [--check BASELINE]
                                                      [--trace PATH]

Two phases, one JSON:

* **overhead gate** — the same warm ``solve_many`` batch timed on two
  identically-built sequential solvers, one untraced and one with a live
  :class:`~repro.observe.Tracer` feeding a memory sink.  All span clocks
  sit at dispatch boundaries (never inside jitted code) and ``solve_many``
  keeps its no-host-sync pipeline, so the traced run must come in within
  3% of the untraced wall.  Measurement is paired-interleaved min-of-k
  over three trials, gated on the best trial ratio (contention on a
  shared host only ever *adds* time) with a 1 ms absolute allowance so
  micro-walls don't gate on timer noise.
* **model drift** — on a (2 nodes x 4 procs) host mesh, every exchange
  strategy solves a full-rank RHS (width t) and a rank-deficient RHS
  (``adaptive="reduce"`` drops it to a narrow tail segment); the tracer's
  ``solve/segment`` spans supply measured ``(width, iters, wall)``, and
  :func:`repro.observe.model_drift` prices each against the structural
  cost model (HOST params, ``dispatch_overhead`` re-calibrated from
  :func:`repro.tune.measure_dispatch_overhead`) and against the
  plan-accounted exchange bytes vs. the compiled HLO's collective-permute
  payloads.  Gates: every *calibrated* time drift (normalized by the
  median across configurations — absolute machine speed cancels) in
  [0.5, 2.0]; per strategy the HLO/plan byte ratio is constant across
  widths within 15% (the re-slice moves active columns only — both
  accountings must shrink together).

``--check BASELINE`` additionally compares the deterministic byte
counters (plan and HLO bytes per (strategy, t_active)) against the
committed ``BENCH_observe.json`` — they are pure functions of the
partition and must match exactly.  ``--trace PATH`` records the whole
benchmark (build phases, solve segments, drift gauges) as a Chrome/
Perfetto trace — the CI artifact.
"""

import argparse
import dataclasses
import json
import os
import sys

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="small problem for CI")
    ap.add_argument("--t", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--repeats", type=int, default=None,
                    help="timed repeats (median-of); default 5, 3 smoke")
    ap.add_argument("--json", default="BENCH_observe.json")
    ap.add_argument("--check", metavar="BASELINE", default=None,
                    help="fail unless deterministic byte counters match")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="write the benchmark's own Chrome/Perfetto trace")
    args = ap.parse_args()
    repeats = args.repeats or (3 if args.smoke else 5)

    jax.config.update("jax_enable_x64", True)

    from repro.core.machines import HOST
    from repro.observe import (
        MemorySink, Tracer, calibrated_drift, model_drift, open_sink,
        timed_median,
    )
    from repro.solver import CommConfig, ECGSolver, SolverConfig
    from repro.sparse import dg_laplace_2d, fd_laplace_2d
    from repro.tune import measure_dispatch_overhead

    trace_sink = open_sink(args.trace) if args.trace else None
    run_tracer = Tracer(sinks=[trace_sink]) if trace_sink else None

    # ---- phase 1: tracer overhead on the warm solve_many hot path.
    # Two identical sequential sessions; only the tracer differs.  The
    # batch replays on compiled programs, so any slowdown is pure
    # instrumentation cost at the dispatch boundaries.
    n_seq = 16 if args.smoke else 24
    a_seq = fd_laplace_2d(n_seq)
    rng = np.random.default_rng(args.seed)
    bs = [rng.standard_normal(a_seq.shape[0]) for _ in range(8)]
    seq_cfg = SolverConfig(t=4, tol=1e-8)
    untraced = ECGSolver.build(a_seq, config=seq_cfg)
    traced = ECGSolver.build(a_seq, config=seq_cfg,
                             tracer=Tracer(sinks=[MemorySink()]))
    untraced.solve_many(bs)  # compile-warm both sessions
    traced.solve_many(bs)
    # paired interleaved repeats, min-of-k per trial, best trial ratio:
    # wall noise on a shared host is one-sided (contention only ever adds
    # time) and swamps a 3% gate under a single median — the minimum
    # observed traced/untraced ratio across independent trials is the
    # cleanest estimate of the true instrumentation cost
    ratios, plain_s, traced_s = [], None, None
    for _ in range(3):
        plain_ts, traced_ts = [], []
        for _ in range(repeats):
            _, s_u = timed_median(untraced.solve_many, bs, repeats=1,
                                  warmup=0, label="solve_many/untraced",
                                  sync=False)
            _, s_t = timed_median(traced.solve_many, bs, repeats=1,
                                  warmup=0, label="solve_many/traced",
                                  sync=False)
            plain_ts.append(s_u)
            traced_ts.append(s_t)
        ratios.append(min(traced_ts) / min(plain_ts))
        if plain_s is None or min(plain_ts) < plain_s:
            plain_s, traced_s = min(plain_ts), min(traced_ts)
    overhead_pct = (min(ratios) - 1.0) * 100.0
    overhead_ok = min(ratios) <= 1.03 or traced_s <= plain_s + 1e-3
    print(f"# overhead: untraced {plain_s * 1e3:.1f}ms -> traced "
          f"{traced_s * 1e3:.1f}ms ({overhead_pct:+.2f}% best-trial, "
          f"ratios {[round(r, 3) for r in ratios]}, gate <= 3%) "
          f"over {len(bs)} solves x 3 trials x {repeats} repeats")

    # ---- phase 2: model drift per (strategy, t_active) on a 2x4 mesh
    n_dev = len(jax.devices())
    assert n_dev >= 8, f"need >= 8 devices, got {n_dev}"
    mesh = jax.sharding.Mesh(
        np.array(jax.devices()[:8]).reshape(2, 4), ("node", "proc")
    )
    t = args.t
    a = fd_laplace_2d(13) if args.smoke else dg_laplace_2d((16, 12), block=8)
    n = a.shape[0]
    machine = dataclasses.replace(
        HOST, dispatch_overhead=float(measure_dispatch_overhead(mesh))
    )
    print(f"# drift: {n} rows, {a.nnz} nnz, t={t} on 2x4 mesh; "
          f"dispatch overhead {machine.dispatch_overhead * 1e6:.1f}us/op")

    b_full = rng.standard_normal(n)
    m = 2  # deficient splitting: t -> t_active=m at the first iteration
    b_def = np.zeros(n)
    b_def[: (m * n) // t] = rng.standard_normal((m * n) // t)

    strategies = (
        ("standard", "3step") if args.smoke
        else ("standard", "2step", "3step", "optimal")
    )
    rows = []
    pm = None
    for strategy in strategies:
        sink = MemorySink()
        sinks = [sink] + ([trace_sink] if trace_sink else [])
        solver = ECGSolver.build(a, mesh, SolverConfig(
            t=t, tol=1e-8, max_iters=600, adaptive="reduce",
            comm=CommConfig(strategy=strategy, machine=HOST),
        ), pm=pm, tracer=Tracer(sinks=sinks))
        pm = solver.partition  # one row partition across strategy builds
        for b in (b_full, b_def):  # compile-warm both segment layouts
            solver.solve(b)
        sink.spans.clear()
        for _ in range(repeats):
            solver.solve(b_full)
            solver.solve(b_def)
        # measured (width, iters, wall): aggregate the solve/segment spans
        # across repeats; segments shorter than 3 iterations are dropped —
        # a 1-iteration segment is all dispatch edge, not steady state
        agg: dict[int, list[float]] = {}
        for sp in sink.spans:
            if sp.name != "solve/segment":
                continue
            w, it = int(sp.args["width"]), int(sp.args["iters"])
            if it >= 3:
                agg.setdefault(w, []).append(sp.dur / it)
        segments = [
            (w, 1, float(np.median(per_iter)))
            for w, per_iter in sorted(agg.items(), reverse=True)
        ]
        srows = model_drift(solver, segments, machine=machine,
                            tracer=run_tracer, strategy=strategy)
        rows.extend(srows)
        for r in srows:
            print(f"drift/{strategy}_t{t}_active{r['t_active']},"
                  f"{r['measured_iter_s'] * 1e6:.1f}us,"
                  f"{r['predicted_iter_s'] * 1e6:.1f}us,"
                  f"{r['plan_bytes']},{r['hlo_bytes']}", flush=True)

    rows = calibrated_drift(rows)
    cal = [r["calibrated_time_drift"] for r in rows]
    time_drift_ok = all(c is not None and 0.5 <= c <= 2.0 for c in cal)
    by_strategy: dict[str, list[float]] = {}
    for r in rows:
        if r["bytes_drift"] is not None:
            by_strategy.setdefault(r["strategy"], []).append(r["bytes_drift"])
    bytes_consistent = all(
        max(v) / min(v) - 1.0 <= 0.15 for v in by_strategy.values() if v
    )
    print(f"# calibrated time drift: "
          f"{', '.join(f'{c:.2f}' for c in cal)} (gate [0.5, 2.0])")

    summary = dict(
        overhead_pct=float(overhead_pct),
        overhead_ok=bool(overhead_ok),
        time_drift_ok=bool(time_drift_ok),
        bytes_ratio_consistent_15pct=bool(bytes_consistent),
        configs_measured=len(rows),
    )
    out = dict(
        benchmark="observe", smoke=args.smoke, seed=args.seed,
        repeats=repeats, t=t,
        overhead=dict(
            untraced_s=float(plain_s), traced_s=float(traced_s),
            overhead_pct=float(overhead_pct), batch=len(bs),
        ),
        drift=rows,
        summary=summary,
    )
    with open(args.json, "w") as fh:
        json.dump(out, fh, indent=2)
    print(f"# gauges: {json.dumps(summary)}")
    print(f"# wrote {args.json}")

    if run_tracer is not None:
        run_tracer.close()
        print(f"# trace written to {args.trace}")

    failures = []
    if not overhead_ok:
        failures.append(
            f"tracer overhead {overhead_pct:+.2f}% exceeds the 3% gate "
            f"({plain_s * 1e3:.1f}ms -> {traced_s * 1e3:.1f}ms)"
        )
    if not time_drift_ok:
        failures.append(
            f"calibrated time drift outside [0.5, 2.0]: "
            f"{[round(c, 3) for c in cal]}"
        )
    if not bytes_consistent:
        failures.append(
            "HLO/plan byte ratio varies > 15% across widths within a "
            "strategy (the width re-slice leaked payload)"
        )
    if args.check:
        failures += check_counters(out, args.check)
        if not failures:
            print(f"counter gate OK vs {args.check}")
    if failures:
        print("OBSERVE GATE FAILED:", file=sys.stderr)
        for msg in failures:
            print(f"  {msg}", file=sys.stderr)
        sys.exit(1)


def check_counters(out: dict, baseline_path: str) -> list[str]:
    """Deterministic byte counters must match the committed baseline."""
    with open(baseline_path) as f:
        base = json.load(f)
    failures = []
    key = lambda r: (r["strategy"], r["t_active"])  # noqa: E731
    base_rows = {key(r): r for r in base["drift"]}
    for r in out["drift"]:
        br = base_rows.get(key(r))
        if br is None:
            failures.append(f"drift row {key(r)} missing from baseline")
            continue
        for field in ("plan_bytes", "hlo_bytes"):
            if r[field] != br[field]:
                failures.append(
                    f"drift[{key(r)}].{field}: {r[field]!r} != "
                    f"baseline {br[field]!r}"
                )
    return failures


if __name__ == "__main__":
    main()
