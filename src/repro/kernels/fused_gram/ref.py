"""Pure-jnp oracle for the fused block-inner-product kernel."""

from __future__ import annotations

import jax.numpy as jnp


def fused_gram_ref(p, r, ap, ap_old):
    """[PᵀR | APᵀAP | AP_oldᵀAP]  — the 3t² payload of ECG's allreduce #2.

    All inputs (n, t); output (t, 3t).
    """
    return jnp.concatenate([p.T @ r, ap.T @ ap, ap_old.T @ ap], axis=1)
