"""Mixture-of-Experts FFN with expert parallelism over the "model" axis.

DESIGN.md §Arch-applicability: this layer is the framework's closest analogue
of the paper's node-aware blocked communication.  Because activations are
TP-*replicated* across the "model" axis (they are only batch/seq-sharded),
every expert shard already holds the tokens it may need — so the usual
all-to-all *dispatch* is a purely local capacity-gather, and the only
collective is a single psum *combine* (the same collective a dense
row-parallel MLP needs).  Duplicated slow-tier traffic is traded for local
work: the 2-step/3-step philosophy applied to MoE routing.

Routing is top-k with per-device capacity  C = ceil(T_loc·k/E · cf)
(tokens over capacity are dropped — standard Switch/GShard semantics,
deterministic and static-shaped).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.models.common import ArchConfig, MeshAxes


def moe_ffn(cfg: ArchConfig, mesh: Mesh, axes: MeshAxes, x, p):
    """x: (B, S, D) batch-sharded; p: router (D,E), we_g/we_u (E,D,F), we_d (E,F,D)
    with E sharded over "model".  Returns (B, S, D)."""
    model_axis = axes.model
    e_shards = axes.size(model_axis)
    assert cfg.n_experts % max(e_shards, 1) == 0, "experts must divide model axis"
    b, s, d = x.shape

    scatter = bool(cfg.moe_scatter_combine and model_axis and s % e_shards == 0)
    in_specs = (
        P(axes.batch, None, None),            # x (replicated over model)
        P(None, None),                        # router (replicated)
        P(model_axis, None, None),            # we_g
        P(model_axis, None, None),            # we_u
        P(model_axis, None, None),            # we_d
    )
    # scatter-combine emits the output already sequence-sharded over "model"
    # (reduce-scatter = half the bytes of all-reduce) — §Perf lever
    out_x = P(axes.batch, model_axis, None) if scatter else P(axes.batch, None, None)
    out_specs = (out_x, P())

    f = shard_map(
        functools.partial(_moe_local, cfg, e_shards, model_axis, tuple(axes.batch), scatter),
        mesh=mesh,
        in_specs=in_specs,
        out_specs=out_specs,
        check_rep=False,
    )
    out, aux = f(x, p["router"], p["we_g"], p["we_u"], p["we_d"])
    return out, aux


def _moe_local(cfg, e_shards, model_axis, batch_axes, scatter, x, router, wg, wu, wd):
    """Per-device body: local top-k routing + capacity gather + local experts
    + weighted scatter + psum combine."""
    bl, s, d = x.shape
    t_loc = bl * s
    e_total = cfg.n_experts
    e_loc = e_total // e_shards
    k = cfg.top_k
    cap = int(max(1, -(-t_loc * k // e_total) * cfg.capacity_factor))

    xf = x.reshape(t_loc, d)
    gate_logits = jnp.einsum("td,de->te", xf.astype(jnp.float32), router.astype(jnp.float32))
    probs = jax.nn.softmax(gate_logits, axis=-1)          # (T, E)
    top_vals, top_ids = jax.lax.top_k(probs, k)           # (T, k)
    top_vals = top_vals / jnp.sum(top_vals, axis=-1, keepdims=True)

    # which experts this shard owns
    shard_id = jax.lax.axis_index(model_axis) if model_axis else 0
    e0 = shard_id * e_loc

    def one_expert(e_local, carry):
        e = e0 + e_local
        match = top_ids == e                              # (T, k)
        gate_e = jnp.sum(jnp.where(match, top_vals, 0.0), axis=-1)  # (T,)
        mem = jnp.any(match, axis=-1)                     # (T,)
        rank = jnp.cumsum(mem) - 1
        sel = mem & (rank < cap)
        order = jnp.argsort(~sel, stable=True)[:cap]      # selected first
        valid = sel[order]
        g = jnp.where(valid, gate_e[order], 0.0)          # (cap,)
        xe = xf[order]                                    # (cap, d)
        if cfg.mlp == "swiglu":
            h = jax.nn.silu(xe @ wg[e_local]) * (xe @ wu[e_local])
        else:
            h = jax.nn.gelu(xe @ wu[e_local])
        ye = (h @ wd[e_local]) * g[:, None].astype(x.dtype)
        return carry.at[order].add(ye)

    out = jnp.zeros_like(xf)
    for e_local in range(e_loc):
        out = one_expert(e_local, out)

    # combine across expert shards — ONE collective (cf. module docstring)
    if model_axis and scatter:
        out = out.reshape(bl, s, d)
        out = jax.lax.psum_scatter(out, model_axis, scatter_dimension=1, tiled=True)
        out = out.reshape(-1, d)
    elif model_axis:
        out = jax.lax.psum(out, model_axis)

    # load-balance aux loss (Switch): E * sum_e f_e * p_e, reduced globally
    density = jnp.mean(
        jax.nn.one_hot(top_ids, e_total, dtype=jnp.float32).sum(axis=1), axis=0
    )
    mean_probs = jnp.mean(probs, axis=0)
    aux = e_total * jnp.sum(density * mean_probs)
    if batch_axes:
        aux = jax.lax.pmean(aux, batch_axes)
    if scatter:
        return out.reshape(bl, s // e_shards, d), aux
    return out.reshape(bl, s, d), aux


def moe_ffn_reference(cfg: ArchConfig, x, p):
    """Dense (no-drop) oracle for tests: every token sees its top-k experts."""
    b, s, d = x.shape
    xf = x.reshape(-1, d)
    logits = xf.astype(jnp.float32) @ p["router"].astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    top_vals, top_ids = jax.lax.top_k(probs, cfg.top_k)
    top_vals = top_vals / top_vals.sum(-1, keepdims=True)
    out = jnp.zeros_like(xf)
    for e in range(cfg.n_experts):
        if cfg.mlp == "swiglu":
            h = jax.nn.silu(xf @ p["we_g"][e]) * (xf @ p["we_u"][e])
        else:
            h = jax.nn.gelu(xf @ p["we_u"][e])
        ye = h @ p["we_d"][e]
        gate = jnp.sum(jnp.where(top_ids == e, top_vals, 0.0), axis=-1)
        out = out + ye * gate[:, None].astype(x.dtype)
    return out.reshape(b, s, d)
