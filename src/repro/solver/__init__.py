"""Primary public API: compile-once / solve-many ECG sessions.

    from repro.solver import ECGSolver, SolverConfig, CommConfig

    solver = ECGSolver.build(a, mesh, SolverConfig(t=8, tol=1e-8))
    res = solver.solve(b)         # builds + compiles once
    more = solver.solve_many(bs)  # further RHS: zero retraces

One typed :class:`SolverConfig` (validated at construction, composed of
:class:`CommConfig` / :class:`KernelConfig` / :class:`TuneConfig` /
:class:`AdaptiveConfig` / :class:`MethodConfig` /
:class:`~repro.precondition.PreconditionConfig`) replaces the stringly-typed keyword sprawl of the
legacy ``ecg_solve`` / ``distributed_ecg`` / ``make_distributed_spmbv``
spellings, which remain as deprecated wrappers.  See ``docs/api.md`` for
the handle lifecycle, the config reference, and the migration table.
"""

from repro.precondition.config import PreconditionConfig
from repro.solver.config import (
    AdaptiveConfig,
    CommConfig,
    KernelConfig,
    MethodConfig,
    SolverConfig,
    TuneConfig,
)
from repro.solver.handle import ECGSolver, SolverStats

__all__ = [
    "AdaptiveConfig",
    "CommConfig",
    "KernelConfig",
    "MethodConfig",
    "PreconditionConfig",
    "SolverConfig",
    "TuneConfig",
    "ECGSolver",
    "SolverStats",
]
