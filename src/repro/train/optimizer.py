"""AdamW in pure JAX with ZeRO-1 state sharding.

Optimizer moments are fp32 and sharded like the parameters *plus* spread over
the data axis where the parameter spec leaves it free (ZeRO-1) — required to
fit the 14B/20B/42B assigned configs on 16 GB v5e chips (DESIGN.md §4).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.common import MeshAxes


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def lr_at(cfg: AdamWConfig, step):
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip(
        (step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0,
        1.0,
    )
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def init_opt_state(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "mu": jax.tree.map(zeros, params),
        "nu": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def abstract_opt_state(abstract_params):
    sds = lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32)
    return {
        "mu": jax.tree.map(sds, abstract_params),
        "nu": jax.tree.map(sds, abstract_params),
        "step": jax.ShapeDtypeStruct((), jnp.int32),
    }


def zero1_specs(param_specs, axes: MeshAxes, param_shapes) -> Any:
    """Moment PartitionSpecs: parameter spec + "data" on the largest free,
    divisible dim (ZeRO-1)."""
    fsdp = axes.fsdp
    fsize = axes.size(fsdp)

    def widen(spec: P, shape) -> P:
        if fsdp is None:
            return spec
        entries = list(spec) + [None] * (len(shape) - len(spec))
        if any(e == fsdp or (isinstance(e, tuple) and fsdp in e) for e in entries):
            return spec  # already data-sharded
        # pick the largest dim divisible by the data axis
        best, best_dim = -1, None
        for i, (e, dim) in enumerate(zip(entries, shape)):
            if e is None and dim % fsize == 0 and dim > best:
                best, best_dim = dim, i
        if best_dim is None:
            return spec
        entries[best_dim] = fsdp
        return P(*entries)

    return jax.tree.map(
        lambda s, p: widen(s, p.shape),
        param_specs,
        param_shapes,
        is_leaf=lambda s: isinstance(s, P),
    )


def opt_state_specs(param_specs, axes: MeshAxes, abstract_params):
    mom = zero1_specs(param_specs, axes, abstract_params)
    return {"mu": mom, "nu": mom, "step": P()}


def apply_adamw(cfg: AdamWConfig, params, grads, state, extra_reduce=None):
    """One AdamW step.  ``extra_reduce`` optionally post-processes the global
    grad-norm scalar (e.g. the fused-collective discipline of §3.1: ride every
    step statistic on one reduction)."""
    step = state["step"] + 1
    gsq = sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads))
    gnorm = jnp.sqrt(gsq)
    if extra_reduce is not None:
        gnorm = extra_reduce(gnorm)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    lr = lr_at(cfg, step)
    b1c = 1 - cfg.b1**step.astype(jnp.float32)
    b2c = 1 - cfg.b2**step.astype(jnp.float32)

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu = cfg.b1 * mu + (1 - cfg.b1) * g
        nu = cfg.b2 * nu + (1 - cfg.b2) * jnp.square(g)
        mhat = mu / b1c
        nhat = nu / b2c
        delta = mhat / (jnp.sqrt(nhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), mu, nu

    out = jax.tree.map(upd, params, grads, state["mu"], state["nu"])
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    new_mu = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    new_nu = jax.tree.map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
    return new_params, {"mu": new_mu, "nu": new_nu, "step": step}, dict(grad_norm=gnorm, lr=lr)
