"""Model-drift telemetry: predicted cost / accounted bytes vs. measured.

The repo carries four cost models (the ``repro.tune`` byte models, the
executor-structural model, ``method_sync_cost``, the ``select_t``
iteration model).  The paper validates its models by *measuring against
them* (§5); this module is that validation as a reusable layer: for each
``(strategy, t_active)`` a solve actually ran, compare

* **time drift** — measured wall seconds per iteration vs. the
  structural per-iteration prediction
  (:func:`predicted_iteration_seconds`), and
* **bytes drift** — collective-permute payload bytes counted in the
  *compiled HLO* (:func:`hlo_collective_bytes` — moved here from
  ``benchmarks/comm_sweep.py``, which now imports it back) vs. the bytes
  the :class:`~repro.core.node_aware.ExchangePlan` accounts for.

Bytes drift is deterministic and gated within 15% in CI.  Absolute time
drift soaks up the host machine's true speed, so the gate normalizes by
the median drift across all measured configurations
(:func:`calibrated_drift`) and requires every *relative* drift in
[0.5, 2.0] — the model must rank configurations within 2× even when its
absolute constants are off for the machine at hand.
"""

from __future__ import annotations

import re

import numpy as np

_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s32": 4, "u32": 4}
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def hlo_collective_bytes(compiled_text: str, p: int) -> int:
    """Sum of collective-permute payload bytes in a compiled module.

    Each instruction's (first) result shape is the per-device buffer; every
    device sends one, so the wire total is shape_bytes × p.  Handles both
    the synchronous form (``x = f64[c,w]{..} collective-permute(...)``) and
    the async start form, whose result is a tuple
    (``x = (f64[c,w]{..}, f64[c,w]{..}) collective-permute-start(...)`` —
    the first element is the send payload; ``-done`` is not counted).
    """
    total = 0
    for line in compiled_text.splitlines():
        # split at the op's opening paren (the SSA name at line start would
        # otherwise shadow the search); "-done" carries no payload
        if " collective-permute-start(" in line:
            head = line.split(" collective-permute-start(", 1)[0]
        elif " collective-permute(" in line:
            head = line.split(" collective-permute(", 1)[0]
        else:
            continue
        m = _SHAPE_RE.search(head.split("=", 1)[-1])
        if not m or m.group(1) not in _DTYPE_BYTES:
            continue
        n = 1
        for d in m.group(2).split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[m.group(1)] * p
    return total


def _resolve_machine(solver):
    """The MachineParams a drift row prices against: the tuner's
    dtype-resolved machine when the build tuned, else the comm config's,
    else HOST."""
    machine = None
    if solver.tuned is not None:
        machine = solver.tuned.machine
    if machine is None:
        machine = solver.config.comm.machine
    if machine is None:
        from repro.core.machines import HOST

        machine = HOST
    return machine


def predicted_iteration_seconds(solver, width: int | None = None,
                                machine=None) -> float:
    """Structural-model seconds for one iteration of ``solver`` at active
    width ``width`` (default: the compile width ``solver.t``).

    Mirrors what the executor actually runs at a reduced width: only the
    exchange payload and the SpMBV flops shrink with ``width`` — the Gram
    psums and the dense local updates stay full-``t``-shaped (masked
    columns, not narrower arrays), so their terms are charged at full t.
    """
    from repro.core.ecg import ECGOperationCounts
    from repro.tune.autotune import (
        _method_local_flops, method_sync_cost, structural_exchange_cost,
    )

    if solver.op is None:
        raise ValueError("model drift needs a distributed handle (mesh=)")
    t = int(solver.t)
    w = t if width is None else int(width)
    cfg = solver.config
    machine = _resolve_machine(solver) if machine is None else machine
    plan = solver.op.plan
    p = int(solver.op.p)
    exchange = structural_exchange_cost(plan, machine, width=w)
    counts_w = ECGOperationCounts(n=solver.a.shape[0], nnz=solver.a.nnz,
                                  p=p, t=w)
    counts_t = ECGOperationCounts(n=solver.a.shape[0], nnz=solver.a.nnz,
                                  p=p, t=t)
    spmbv_local = machine.gamma * counts_w.spmbv_flops
    local = machine.gamma * _method_local_flops(
        cfg.method.name, counts_t, s=cfg.method.s, reorth=cfg.method.reorth
    )
    sync = method_sync_cost(
        cfg.method.name, t, p, machine, s=cfg.method.s,
        reorth=cfg.method.reorth, t_spmbv_window=exchange + spmbv_local,
    ) if p > 1 else 0.0
    return spmbv_local + exchange + sync + local


def bytes_drift(solver, width: int | None = None, dtype=None) -> dict:
    """Plan-accounted vs. HLO-measured exchange bytes of one SpMBV apply.

    Lowers ``op.matvec_fn(t_active=width)`` *alone* (one apply — a full
    solve program would double-count the init apply) and counts its
    collective-permute payloads.  Returns ``dict(width, plan_bytes,
    hlo_bytes, ratio)``; ``ratio`` is hlo/plan, 1.0 when the accounting
    is exact.
    """
    import jax
    import jax.numpy as jnp

    if solver.op is None:
        raise ValueError("bytes drift needs a distributed handle (mesh=)")
    op = solver.op
    t = int(solver.t)
    w = t if width is None else int(width)
    dtype = jnp.float64 if dtype is None else dtype
    f = int(np.dtype(dtype).itemsize)
    plan_bytes = int(op.plan.at_width(w).wire_bytes(f))
    sds = jax.ShapeDtypeStruct((op.n_padded, w), dtype)
    txt = jax.jit(op.matvec_fn(t_active=w)).lower(sds).compile().as_text()
    hlo = hlo_collective_bytes(txt, op.p)
    return dict(
        width=w, plan_bytes=plan_bytes, hlo_bytes=int(hlo),
        ratio=(hlo / plan_bytes) if plan_bytes else None,
    )


def model_drift(solver, measured_segments, machine=None, tracer=None,
                strategy: str | None = None) -> list[dict]:
    """Drift rows for one solve's measured width segments.

    measured_segments: ``[(width, iters, wall_seconds)]`` — one entry per
        solve segment (the tracer's ``solve/segment`` spans carry exactly
        these three numbers).  Zero-iteration segments are skipped.
    machine: optional calibrated MachineParams override (e.g. with
        ``dispatch_overhead`` measured by
        :func:`repro.tune.measure_dispatch_overhead`).
    tracer: when given, each row is also emitted as a ``model_drift``
        gauge keyed by ``(strategy, t_active)``.

    Returns rows of ``dict(strategy, t_active, iters, measured_iter_s,
    predicted_iter_s, time_drift, plan_bytes, hlo_bytes, bytes_drift)``
    where ``time_drift = measured / predicted`` (> 1: the model is
    optimistic).
    """
    if strategy is None:
        strategy = (
            solver.tuned.strategy if solver.tuned is not None
            else solver.config.comm.strategy
        )
    rows = []
    for width, iters, wall_s in measured_segments:
        if iters <= 0:
            continue
        measured = float(wall_s) / iters
        predicted = predicted_iteration_seconds(solver, width, machine)
        bd = bytes_drift(solver, width)
        row = dict(
            strategy=strategy, t_active=int(width), iters=int(iters),
            measured_iter_s=measured, predicted_iter_s=predicted,
            time_drift=measured / predicted if predicted > 0 else None,
            plan_bytes=bd["plan_bytes"], hlo_bytes=bd["hlo_bytes"],
            bytes_drift=bd["ratio"],
        )
        rows.append(row)
        if tracer is not None:
            tracer.gauge(
                "model_drift", row["time_drift"], strategy=strategy,
                t_active=int(width), bytes_drift=bd["ratio"],
            )
    return rows


def calibrated_drift(rows) -> list[dict]:
    """Normalize each row's time drift by the median drift across rows.

    One scalar — the machine's true speed relative to the model's
    constants — soaks into the median; what remains is how well the model
    *ranks and scales* across (strategy, t_active), which is what the CI
    gate can assert on any host.  Adds ``calibrated_time_drift`` to a
    copy of each row.
    """
    drifts = [r["time_drift"] for r in rows if r["time_drift"] is not None]
    med = float(np.median(drifts)) if drifts else 1.0
    out = []
    for r in rows:
        r = dict(r)
        r["calibrated_time_drift"] = (
            r["time_drift"] / med if r["time_drift"] is not None and med > 0
            else None
        )
        out.append(r)
    return out
