"""Observability: span tracing, counters/gauges, model-drift telemetry.

The unified way to *watch* a solve or serve session run — see
``docs/observability.md`` for the span taxonomy and the sink matrix.

    from repro.observe import Tracer, ChromeTraceSink

    tracer = Tracer(sinks=[ChromeTraceSink("trace.json")])
    solver = ECGSolver.build(a, mesh, config, tracer=tracer)
    res = solver.solve(b)
    tracer.close()              # trace.json opens in chrome://tracing
"""

from repro.observe.bench import timed_median, timed_median_us
from repro.observe.drift import (
    bytes_drift,
    calibrated_drift,
    hlo_collective_bytes,
    model_drift,
    predicted_iteration_seconds,
)
from repro.observe.metrics import RollingWindow
from repro.observe.sinks import ChromeTraceSink, JsonlSink, MemorySink, open_sink
from repro.observe.tracer import (
    NULL_TRACER,
    NullTracer,
    Span,
    Tracer,
    coerce_tracer,
    get_tracer,
    set_tracer,
)

__all__ = [
    "ChromeTraceSink",
    "JsonlSink",
    "MemorySink",
    "NULL_TRACER",
    "NullTracer",
    "RollingWindow",
    "Span",
    "Tracer",
    "bytes_drift",
    "calibrated_drift",
    "coerce_tracer",
    "get_tracer",
    "hlo_collective_bytes",
    "model_drift",
    "open_sink",
    "predicted_iteration_seconds",
    "set_tracer",
    "timed_median",
    "timed_median_us",
]
