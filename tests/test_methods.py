"""repro.core.methods: the pluggable iteration-scheme engine.

Three families of guarantees:

* **Math** — every scheme converges on the SPD fixtures; pipelined tracks
  classic to solver tolerance; sstep amortizes collectives (s=2 halves the
  outer-step count) and survives adaptive reduction, restart, and the
  segmented exit/resume protocol the width-aware distributed solver uses.
* **Accounting** — each MethodSpec's declared collectives-per-iteration is
  what the synchronization cost model charges (the lowered-HLO counterpart
  runs in ``dist_worker.check_method_collective_structure``).
* **Config** — MethodConfig validation, the flat replace() spellings, and
  the lossless SolverConfig JSON round-trip.
"""

import dataclasses
import json

import numpy as np
import pytest

import jax.numpy as jnp

from repro.adaptive import ReductionPolicy
from repro.core.cg import _cg_solve
from repro.core.ecg import _ecg_solve, make_ecg_runner
from repro.core.machines import BLUE_WATERS
from repro.core.methods import METHODS, MethodSpec, get_method
from repro.solver import MethodConfig, SolverConfig
from repro.solver.config import solverconfig_from_dict, solverconfig_to_dict
from repro.sparse import dg_laplace_2d
from repro.sparse.csr import csr_spmbv


@pytest.fixture(scope="module")
def system():
    a = dg_laplace_2d((10, 10), block=8)  # 800 rows
    b = np.random.default_rng(0).standard_normal(a.shape[0])
    return a, jnp.asarray(b)


def _apply(a):
    return lambda v: csr_spmbv(a, v)


def _check(a, res, b, tol=1e-8):
    assert res.converged
    r = np.asarray(a.todense()) @ np.asarray(res.x) - np.asarray(b)
    assert np.linalg.norm(r) / np.linalg.norm(np.asarray(b)) < 100 * tol


# ------------------------------------------------------------------- math
class TestConvergence:
    @pytest.mark.parametrize("method", sorted(METHODS))
    def test_each_method_converges(self, system, method):
        a, b = system
        s = 2 if method == "sstep" else 1
        res = _ecg_solve(_apply(a), b, 4, tol=1e-8, max_iters=400,
                         method=method, s=s)
        _check(a, res, b)

    def test_pipelined_tracks_classic(self, system):
        """Same recurrence up to the AZ substitution: iterates agree to
        solver tolerance and iteration counts are within one."""
        a, b = system
        ref = _ecg_solve(_apply(a), b, 4, tol=1e-8, max_iters=400)
        pip = _ecg_solve(_apply(a), b, 4, tol=1e-8, max_iters=400,
                         method="pipelined")
        assert abs(pip.n_iters - ref.n_iters) <= 1
        assert np.linalg.norm(np.asarray(pip.x - ref.x)) < 1e-6 * np.linalg.norm(
            np.asarray(ref.x)
        )

    @pytest.mark.parametrize("s", [1, 2, 4])
    def test_sstep_outer_steps_amortize(self, system, s):
        """n_iters counts *blocks* for sstep; each block buys s effective
        iterations, so the block count shrinks close to 1/s."""
        a, b = system
        base = _ecg_solve(_apply(a), b, 4, tol=1e-8, max_iters=400,
                          method="sstep", s=1)
        res = _ecg_solve(_apply(a), b, 4, tol=1e-8, max_iters=400,
                         method="sstep", s=s)
        _check(a, res, b)
        # amortization with slack for the monomial basis's weaker conditioning
        assert res.n_iters <= base.n_iters // s + max(4, base.n_iters // (2 * s))

    def test_sstep_s1_matches_classic_count(self, system):
        """At s=1 the residual-seeded block is classic's search space: the
        step counts coincide on this fixture."""
        a, b = system
        ref = _ecg_solve(_apply(a), b, 4, tol=1e-8, max_iters=400)
        s1 = _ecg_solve(_apply(a), b, 4, tol=1e-8, max_iters=400,
                        method="sstep", s=1)
        assert abs(s1.n_iters - ref.n_iters) <= 2

    def test_sstep_reorth_converges(self, system):
        a, b = system
        res = _ecg_solve(_apply(a), b, 4, tol=1e-8, max_iters=400,
                         method="sstep", s=4, reorth=True)
        _check(a, res, b)
        plain = _ecg_solve(_apply(a), b, 4, tol=1e-8, max_iters=400,
                           method="sstep", s=4)
        assert res.n_iters <= plain.n_iters

    @pytest.mark.parametrize("method,s", [("pipelined", 1), ("sstep", 2)])
    def test_adaptive_reduction_per_method(self, system, method, s):
        """The width controller composes with every scheme: a rank-deficient
        splitting (t > nonzero RHS subdomains) must degrade gracefully."""
        a, _ = system
        n = a.shape[0]
        b = np.zeros(n)
        b[: n // 2] = np.random.default_rng(3).standard_normal(n // 2)
        res = _ecg_solve(_apply(a), jnp.asarray(b), 8, tol=1e-8, max_iters=400,
                         method=method, s=s, adaptive="reduce")
        _check(a, res, jnp.asarray(b))
        assert res.active_hist is not None
        assert int(np.asarray(res.active_hist)[res.n_iters]) < 8

    def test_sstep_restart_allowed_and_converges(self, system):
        """Restart is trivially compatible with sstep (the seed is rebuilt
        from the residual every block) — pipelined rejects it, sstep must
        not."""
        a, b = system
        res = _ecg_solve(_apply(a), b, 4, tol=1e-8, max_iters=400,
                         method="sstep", s=2, adaptive="reduce+restart")
        _check(a, res, b)

    def test_cg_is_classic_at_t1(self, system):
        a, b = system
        res = _cg_solve(lambda v: csr_spmbv(a, v[:, None])[:, 0], b,
                        tol=1e-8, max_iters=2000)
        assert res.converged and res.t is None
        ref = _ecg_solve(_apply(a), b, 1, tol=1e-8, max_iters=2000)
        assert res.n_iters == ref.n_iters
        np.testing.assert_array_equal(np.asarray(res.x), np.asarray(ref.x))


class TestSegmentedResume:
    @pytest.mark.parametrize("method,s", [("pipelined", 1), ("sstep", 2)])
    def test_resume_matches_monolithic(self, method, s):
        """exit_below_width + resume_state must replay each scheme's own
        monolithic adaptive solve exactly — the protocol the width-aware
        distributed executor re-slices exchange plans around."""
        a = dg_laplace_2d((10, 10), block=8)
        n = a.shape[0]
        t, m = 8, 2
        b = np.zeros(n)
        b[: (m * n) // t] = np.random.default_rng(7).standard_normal((m * n) // t)
        apply_a = _apply(a)
        masked = lambda z, act: apply_a(z)

        ref = _ecg_solve(apply_a, jnp.asarray(b), t, tol=1e-8, max_iters=400,
                         method=method, s=s, adaptive="reduce")
        assert ref.converged

        seg1 = _ecg_solve(apply_a, jnp.asarray(b), t, tol=1e-8, max_iters=400,
                          method=method, s=s, adaptive="reduce",
                          a_apply_masked=masked, exit_below_width=t)
        assert not seg1.converged and seg1.n_iters < ref.n_iters
        n_act = int(jnp.sum(seg1.final_carry["act"]))
        assert n_act == m
        seg2 = _ecg_solve(apply_a, jnp.asarray(b), t, tol=1e-8, max_iters=400,
                          method=method, s=s, adaptive="reduce",
                          a_apply_masked=masked, exit_below_width=n_act,
                          resume_state=seg1.final_carry)
        assert seg2.converged and seg2.n_iters == ref.n_iters
        h_ref = np.asarray(ref.res_hist)[: ref.n_iters + 1]
        h_seg = np.asarray(seg2.res_hist)[: seg2.n_iters + 1]
        np.testing.assert_array_equal(h_ref, h_seg)
        np.testing.assert_array_equal(np.asarray(ref.x), np.asarray(seg2.x))


# ------------------------------------------------------------- accounting
class TestAccounting:
    def test_registry(self):
        assert sorted(METHODS) == ["classic", "pipelined", "sstep"]
        for name, spec in METHODS.items():
            assert isinstance(spec, MethodSpec) and spec.name == name
            assert get_method(name) is spec
        with pytest.raises(ValueError, match="classic"):
            get_method("bogus")

    def test_collectives_per_iteration(self):
        assert get_method("classic").collectives_per_iteration() == 2
        assert get_method("pipelined").collectives_per_iteration() == 2
        for s in (1, 2, 4):
            assert get_method("sstep").collectives_per_iteration(s) == 2 / s
            assert get_method("sstep").collectives_per_iteration(s, reorth=True) == 3 / s

    def test_payloads(self):
        t = 4
        assert get_method("classic").psum_payload_floats(t) == 4 * t * t
        assert get_method("pipelined").psum_payload_floats(t) == 4 * t * t
        st = 2 * t
        assert get_method("sstep").psum_payload_floats(t, 2) == 3 * st * st + st * t
        assert (
            get_method("sstep").psum_payload_floats(t, 2, reorth=True)
            == 3 * st * st + st * t + st * st
        )

    def test_sync_cost_model(self):
        """method_sync_cost charges exactly the spec's accounting, and the
        classic instance reproduces the paper's §3.1 collective term."""
        from repro.core.models import t_collective, t_collective_n
        from repro.tune import method_sync_cost

        m, p, t = BLUE_WATERS, 64, 4
        assert method_sync_cost("classic", t, p, m) == t_collective(p, t, m)
        # a huge overlap window hides the packed psum entirely
        pip = method_sync_cost("pipelined", t, p, m, t_spmbv_window=1.0)
        assert pip == t_collective_n(p, m, 1, t * t)
        # no window: both psums on the critical path, same latency legs as
        # classic but pipelined still never costs more
        assert method_sync_cost("pipelined", t, p, m) == pytest.approx(
            t_collective(p, t, m)
        )
        for s in (2, 4):
            spec = get_method("sstep")
            assert method_sync_cost("sstep", t, p, m, s=s) == pytest.approx(
                t_collective_n(p, m, 2, spec.psum_payload_floats(t, s)) / s
            )

    def test_rank_methods_structural(self, system):
        """tune-mode ranking: on a latency-dominated machine, sstep's
        amortized synchronization must beat classic, and pipelined must
        never cost more than classic."""
        from repro.tune import rank_methods

        a, _ = system
        best, table = rank_methods(a, 4, machine=BLUE_WATERS, n_nodes=8,
                                   ppn=16, s=4, mode="model")
        assert set(table) == {"classic", "pipelined", "sstep"}
        for row in table.values():
            assert row["iter_s"] == pytest.approx(
                row["sync_s"] + row["spmbv_s"] + row["local_s"]
            )
        assert table["pipelined"]["iter_s"] <= table["classic"]["iter_s"]
        assert table["sstep"]["sync_s"] < table["classic"]["sync_s"]
        assert best == min(table, key=lambda k: table[k]["iter_s"])

    def test_iteration_cost_classic_unchanged(self, system):
        """The method-aware iteration_cost at its classic defaults must
        reproduce the original §3.1-based composition exactly."""
        from repro.adaptive.select_t import iteration_cost
        from repro.core.ecg import ECGOperationCounts
        from repro.core.models import t_collective

        a, _ = system
        cost, cfg = iteration_cost(a, 4, n_nodes=2, ppn=4)
        counts = ECGOperationCounts(n=a.shape[0], nnz=a.nnz, p=8, t=4)
        legacy = (
            cfg.predicted["best"]
            + cfg.machine.gamma * (counts.total_flops - counts.spmbv_flops)
            + t_collective(8, 4, cfg.machine)
        )
        assert cost == legacy


# ----------------------------------------------------------------- config
class TestMethodConfig:
    def test_validation(self):
        with pytest.raises(ValueError, match="method"):
            MethodConfig(name="bogus")
        with pytest.raises(ValueError, match="s"):
            MethodConfig(name="sstep", s=0)
        with pytest.raises(ValueError, match="sstep"):
            MethodConfig(name="classic", s=4)
        with pytest.raises(ValueError, match="sstep"):
            MethodConfig(name="pipelined", reorth=True)
        with pytest.raises(ValueError, match="depth"):
            MethodConfig(name="pipelined", depth=2)
        with pytest.raises(ValueError, match="rank_rtol"):
            MethodConfig(name="sstep", rank_rtol=-1.0)

    def test_coercions(self):
        assert SolverConfig(t=4).method == MethodConfig()
        assert SolverConfig(t=4, method="pipelined").method.name == "pipelined"
        cfg = SolverConfig(t=4, method=dict(name="sstep", s=4))
        assert cfg.method == MethodConfig(name="sstep", s=4)

    def test_flat_replace_routes_method_fields(self):
        cfg = SolverConfig(t=4)
        c2 = cfg.replace(method="sstep", s=4)
        assert c2.method == MethodConfig(name="sstep", s=4)
        c3 = c2.replace(s=2)
        assert c3.method == MethodConfig(name="sstep", s=2)
        c4 = c2.replace(method="classic", s=1)
        assert c4.method == MethodConfig()

    def test_pipelined_restart_rejected(self):
        with pytest.raises(ValueError, match="restart"):
            SolverConfig(t=4, method="pipelined", adaptive="reduce+restart")
        # engine-level guard too (runner built directly, no SolverConfig)
        from repro.adaptive.reduce import resolve_policy

        a = dg_laplace_2d((4, 4), block=4)
        with pytest.raises(ValueError, match="restart"):
            make_ecg_runner(_apply(a), 4, method="pipelined",
                            policy=resolve_policy("reduce+restart"))

    def test_engine_validation(self):
        a = dg_laplace_2d((4, 4), block=4)
        with pytest.raises(ValueError, match="unknown method"):
            make_ecg_runner(_apply(a), 4, method="bogus")
        with pytest.raises(ValueError, match="s"):
            make_ecg_runner(_apply(a), 4, method="sstep", s=0)
        with pytest.raises(ValueError, match="sstep"):
            make_ecg_runner(_apply(a), 4, method="classic", s=2)
        with pytest.raises(ValueError, match="rank_rtol"):
            make_ecg_runner(_apply(a), 4, method="sstep", s=2, chol_eps=1e-12)


class TestConfigJson:
    def _rich_config(self):
        from repro.adaptive.select_t import TSelection
        from repro.tune import TunedConfig

        tuned = TunedConfig(strategy="3step", br=8, bc=8, kmax=5,
                            overlap=True, backend="pallas", t=8,
                            mode="model", col_split=2,
                            machine=BLUE_WATERS,
                            predicted={"best": 1e-6, "p2p": {"standard": 2e-6}})
        sel = TSelection(
            t=8, candidates=(4, 8), tol=1e-8, mode="probe", probe_iters=8,
            table={4: dict(rate=0.9, est_iters=100, iter_cost_s=1e-6,
                           total_cost_s=1e-4, avg_active=4.0),
                   8: dict(rate=0.8, est_iters=50, iter_cost_s=1.5e-6,
                           total_cost_s=0.75e-4, avg_active=8.0)},
            probe_iters_used={4: 6, 8: 8},
        )
        return SolverConfig(
            t=8, tol=1e-10, max_iters=777,
            comm=dict(strategy="3step", overlap=True, machine=BLUE_WATERS,
                      col_split=2),
            kernel=dict(backend="pallas", ell_block=(8, 16)),
            adaptive=dict(policy=ReductionPolicy(drop_tol=1e-5, min_t=2),
                          select=sel, t_candidates=(4, 8), probe_iters=6),
            tune=tuned,
            method=dict(name="sstep", s=4, reorth=True, rank_rtol=1e-12),
        )

    def test_roundtrip_is_lossless(self):
        cfg = self._rich_config()
        back = SolverConfig.from_json(cfg.to_json())
        assert back == cfg
        assert back.method == cfg.method
        assert back.comm.machine == BLUE_WATERS
        assert back.adaptive.select.table == cfg.adaptive.select.table

    def test_dict_fixed_point(self):
        """to_dict ∘ from_dict is the identity on the JSON image — the
        cache-file invariant (a spec re-serialized from disk is
        byte-identical)."""
        for cfg in (self._rich_config(), SolverConfig(t=4),
                    SolverConfig(t="auto", adaptive="reduce",
                                 method="pipelined")):
            d = solverconfig_to_dict(cfg)
            s = json.dumps(d)  # must be JSON-serializable as-is
            assert solverconfig_to_dict(solverconfig_from_dict(json.loads(s))) == d

    def test_explicit_adaptive_off_survives(self):
        cfg = SolverConfig(t="auto", adaptive="off")
        back = SolverConfig.from_json(cfg.to_json())
        assert back.adaptive.explicit_off and back == cfg


class TestHandleIntegration:
    def test_with_config_method_change(self, system):
        """A method switch under a fixed t derives a sibling handle that
        reuses the partition and still solves correctly."""
        from repro.solver import ECGSolver

        a, b = system
        solver = ECGSolver.build(a, config=SolverConfig(t=4, max_iters=400),
                                 b=np.asarray(b))
        ref = solver.solve(np.asarray(b))
        assert ref.converged

        for overrides in (dict(method="pipelined"),
                          dict(method="sstep", s=2)):
            clone = solver.with_config(**overrides)
            res = clone.solve(np.asarray(b))
            assert res.converged
            assert clone.config.method.name == overrides["method"]
            _check(a, res, b)
        # classic results are untouched by cloning
        again = solver.solve(np.asarray(b))
        np.testing.assert_array_equal(np.asarray(again.x), np.asarray(ref.x))

    def test_solver_config_threads_method(self, system):
        from repro.solver import ECGSolver

        a, b = system
        cfg = SolverConfig(t=4, max_iters=400, method=dict(name="sstep", s=2))
        res = ECGSolver.build(a, config=cfg, b=np.asarray(b)).solve(np.asarray(b))
        _check(a, res, b)
        mono = _ecg_solve(_apply(a), b, 4, tol=1e-8, max_iters=400,
                          method="sstep", s=2)
        assert res.n_iters == mono.n_iters
        np.testing.assert_array_equal(np.asarray(res.x), np.asarray(mono.x))
