"""olmoe-1b-7b [moe]: 16L d=2048 16H (kv=16) d_ff=1024, 64 experts top-8
[arXiv:2409.02060]."""

from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="olmoe-1b-7b",
    family="moe",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1024,
    vocab=50304,
    n_experts=64,
    top_k=8,
    mlp="swiglu",
)

SMOKE = CONFIG.with_(
    name="olmoe-smoke", n_layers=2, d_model=128, n_heads=4, n_kv_heads=4,
    d_ff=64, vocab=512, n_experts=8, top_k=2, remat=False,
)

SHAPES = {
    "train_4k": "run",
    "prefill_32k": "run",
    "decode_32k": "run",
    "long_500k": "skip:pure full attention (DESIGN.md §Arch-applicability)",
}
