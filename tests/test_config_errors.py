"""Config validation error paths: every malformed spelling fails loudly.

The frozen config dataclasses are the API surface users hit first, so a
bad value must raise at *construction* with a message naming the field and
the accepted range — not surface later as a shape error inside a jitted
solve.  This file sweeps the rejection branches of
:class:`repro.precondition.PreconditionConfig`,
:class:`repro.solver.MethodConfig`, :class:`repro.solver.TuneConfig`, the
cross-field gates on :class:`repro.solver.SolverConfig`, and the malformed
inputs of the JSON / flat-override round-trips.
"""

import json

import pytest

from repro.precondition import PreconditionConfig
from repro.solver import (
    AdaptiveConfig,
    CommConfig,
    MethodConfig,
    SolverConfig,
    TuneConfig,
)


# ---------------------------------------------------- PreconditionConfig
class TestPreconditionConfigErrors:
    @pytest.mark.parametrize(
        "kwargs,match",
        [
            (dict(kind="jacobi"), "unknown preconditioner kind"),
            (dict(kind="ilu"), "unknown preconditioner kind"),
            (dict(block=0), "block must be an int >= 1"),
            (dict(block=16.0), "block must be an int >= 1"),
            (dict(degree=0), "degree must be an int >= 1"),
            (dict(eig_bounds=(1.0,)), "eig_bounds must be"),
            (dict(eig_bounds=(2.0, 1.0)), "eig_bounds must be"),
            (dict(eig_bounds=(0.0, 1.0)), "eig_bounds must be"),
            (dict(eig_bounds=(-1.0, 1.0)), "eig_bounds must be"),
            (dict(eig_ratio=1.0), "eig_ratio must be > 1"),
            (dict(eig_ratio=-3.0), "eig_ratio must be > 1"),
            (dict(power_iters=0), "power_iters must be an int >= 1"),
            (dict(sweeps=0), "sweeps must be an int >= 1"),
            (dict(omega=0.0), r"omega must be in \(0, 1\]"),
            (dict(omega=1.5), r"omega must be in \(0, 1\]"),
            (dict(reseed=1), "reseed must be an int >= 2"),
            (dict(reseed=8.0), "reseed must be an int >= 2"),
        ],
    )
    def test_rejected_at_construction(self, kwargs, match):
        with pytest.raises(ValueError, match=match):
            PreconditionConfig(**kwargs)

    def test_coerce_rejects_foreign_types(self):
        with pytest.raises(TypeError, match="precondition must be"):
            PreconditionConfig.coerce(42)
        with pytest.raises(ValueError, match="unknown preconditioner kind"):
            PreconditionConfig.coerce("ssor")
        with pytest.raises(TypeError):
            PreconditionConfig.coerce({"kind": "none", "bogus": 1})

    def test_frozen(self):
        cfg = PreconditionConfig(kind="block_jacobi")
        with pytest.raises(Exception):
            cfg.kind = "chebyshev"


# --------------------------------------------------------- MethodConfig
class TestMethodConfigErrors:
    @pytest.mark.parametrize(
        "kwargs,match",
        [
            (dict(name="cg"), "unknown method"),
            (dict(s=0), "s must be an int >= 1"),
            (dict(s=2.0), "s must be an int >= 1"),
            (dict(name="classic", s=2), "only applies to method 'sstep'"),
            (dict(name="pipelined", s=4), "only applies to method 'sstep'"),
            (dict(depth=2), "only depth-1 pipelining"),
            (dict(name="classic", reorth=True),
             "only applies to method 'sstep'"),
            (dict(rank_rtol=0.0), "rank_rtol must be > 0"),
            (dict(rank_rtol=-1e-8), "rank_rtol must be > 0"),
        ],
    )
    def test_rejected_at_construction(self, kwargs, match):
        with pytest.raises(ValueError, match=match):
            MethodConfig(**kwargs)


# ----------------------------------------------------------- TuneConfig
class TestTuneConfigErrors:
    def test_unknown_mode(self):
        with pytest.raises(ValueError, match="unknown tune mode"):
            TuneConfig(mode="exhaustive")

    def test_tuned_must_look_like_tunedconfig(self):
        with pytest.raises(TypeError, match="tuned must be"):
            TuneConfig(mode="model", tuned=object())


# --------------------------------------------- SolverConfig cross-field
class TestSolverConfigGates:
    def test_pipelined_rejects_inexact_with_reasoned_message(self):
        with pytest.raises(ValueError) as e:
            SolverConfig(method="pipelined", precondition="inexact")
        msg = str(e.value)
        assert "pipelined" in msg and "inexact" in msg
        # the message explains *why* (the flexible reseed needs an SpMBV)
        assert "reseed" in msg

    def test_pipelined_accepts_fixed_preconditioners(self):
        for kind in ("none", "block_jacobi", "chebyshev"):
            cfg = SolverConfig(method="pipelined", precondition=kind)
            assert cfg.precondition.kind == kind

    def test_precondition_field_validates_nested_kind(self):
        with pytest.raises(ValueError, match="unknown preconditioner kind"):
            SolverConfig(precondition="amg")
        with pytest.raises(ValueError, match="block must be an int >= 1"):
            SolverConfig(precondition={"kind": "block_jacobi", "block": -4})


# ------------------------------------------------- replace() / overrides
class TestReplaceErrors:
    def test_unknown_override_names_both_namespaces(self):
        cfg = SolverConfig(t=4)
        with pytest.raises(ValueError, match="unknown config override"):
            cfg.replace(preconditioner="block_jacobi")  # near-miss spelling
        with pytest.raises(ValueError, match="unknown config override"):
            cfg.replace(blocksize=8)

    def test_cannot_combine_nested_and_flat(self):
        cfg = SolverConfig(t=4)
        with pytest.raises(ValueError, match="cannot combine"):
            cfg.replace(precondition=PreconditionConfig(kind="block_jacobi"),
                        block=16)
        with pytest.raises(ValueError, match="cannot combine"):
            cfg.replace(comm=CommConfig(), strategy="3step")

    def test_flat_override_still_validated(self):
        cfg = SolverConfig(t=4)
        with pytest.raises(ValueError, match="reseed must be an int >= 2"):
            cfg.replace(precondition="inexact", reseed=1)
        with pytest.raises(ValueError, match="degree must be an int >= 1"):
            cfg.replace(precondition="chebyshev", degree=0)

    def test_replace_cannot_sneak_pipelined_inexact(self):
        cfg = SolverConfig(method="classic", precondition="inexact")
        with pytest.raises(ValueError, match="pipelined"):
            cfg.replace(method="pipelined")


# ----------------------------------------------------------------- JSON
class TestJsonErrors:
    def test_malformed_precondition_kind_rejected_on_load(self):
        d = json.loads(SolverConfig(t=4).to_json())
        d["precondition"]["kind"] = "spai"
        with pytest.raises(ValueError, match="unknown preconditioner kind"):
            SolverConfig.from_json(json.dumps(d))

    def test_malformed_method_rejected_on_load(self):
        d = json.loads(SolverConfig(t=4).to_json())
        d["method"]["name"] = "lanczos"
        with pytest.raises(ValueError, match="unknown method"):
            SolverConfig.from_json(json.dumps(d))

    def test_malformed_field_value_rejected_on_load(self):
        d = json.loads(SolverConfig(t=4).to_json())
        d["max_iters"] = 0
        with pytest.raises(ValueError, match="max_iters"):
            SolverConfig.from_json(json.dumps(d))

    def test_adaptive_probe_iters_rejected(self):
        with pytest.raises(ValueError, match="probe_iters"):
            AdaptiveConfig(probe_iters=1)

    def test_round_trip_is_fixed_point_for_every_kind(self):
        for kind in ("none", "block_jacobi", "chebyshev", "inexact"):
            cfg = SolverConfig(t=4, precondition=kind)
            assert SolverConfig.from_json(cfg.to_json()) == cfg
