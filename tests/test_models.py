"""Performance models + comm-graph statistics: paper invariants."""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.sparse import fd_laplace_2d, partition_csr, suite_surrogate
from repro.sparse.matrices import example_2_1_graph
from repro.core.comm_graph import build_comm_graph, build_optimal_plan
from repro.core.machines import BLUE_WATERS, LASSEN, MACHINES
from repro.core.models import (
    STRATEGIES,
    t_p2p,
    t_standard,
    t_standard_postal,
    t_2step,
    t_3step,
    t_optimal,
    t_collective,
    t_computation,
    t_ecg_iteration,
    tune_strategy,
    max_rate,
    postal,
    ping_time,
    split_send_time,
)
from repro.core.ecg import ECGOperationCounts


@pytest.fixture(scope="module")
def graph():
    g, blk = example_2_1_graph(scale=0.25)  # 80x64 elements
    pm = partition_csr(g, 64)
    return build_comm_graph(pm, ppn=8, row_block=blk)


class TestCommGraph:
    def test_bytes_2step_equals_3step_lte_standard(self, graph):
        """Paper §2.2: '2-step and 3-step bytes are the same' and both are
        deduplicated, hence <= standard."""
        assert graph.total_node_aware_rows <= graph.total_standard_rows
        # node-injected == sum of per-pair rows (both are the dedup'd volume)
        assert graph.node_injected_rows.sum() == graph.total_node_aware_rows

    def test_message_count_hierarchy(self, graph):
        # 2-step cannot need more distinct node destinations than standard's
        # distinct process destinations
        assert graph.m_proc_to_node <= graph.m_standard

    def test_eq_4_4_bounds(self, graph):
        """m_node→node/ppn <= n_opt <= max(m_proc→node, ppn) (eq. 4.4)."""
        for t in (1, 5, 20):
            for mach in (BLUE_WATERS, LASSEN):
                plan = build_optimal_plan(graph, t, mach.with_ppn(graph.ppn))
                lower = int(np.ceil(graph.m_node_to_node / graph.ppn))
                upper = max(graph.m_proc_to_node, graph.ppn)
                assert plan.max_msgs <= upper
                assert plan.max_msgs >= min(lower, 1)

    def test_optimal_plan_conserves_bytes(self, graph):
        mach = BLUE_WATERS.with_ppn(graph.ppn)
        for t in (1, 20):
            plan = build_optimal_plan(graph, t, mach)
            unit = t * mach.f * graph.row_block
            assert plan.s_proc_opt.sum() == graph.total_node_aware_rows * unit

    def test_splitting_kicks_in_at_large_t(self, graph):
        mach = BLUE_WATERS.with_ppn(graph.ppn)
        p1 = build_optimal_plan(graph, 1, mach)
        p20 = build_optimal_plan(graph, 20, mach)
        # larger t -> larger buffers -> more splitting -> >= messages
        assert p20.max_msgs >= p1.max_msgs


class TestModels:
    def test_max_rate_reduces_to_postal_without_injection_limit(self):
        m = BLUE_WATERS
        # when ppn*s/R_N < s/R_b the max picks the postal term
        s, msgs = 100.0, 3
        assert max_rate(m, msgs, s, ppn=1) <= postal(m.alpha, m.R_b, msgs, s) + 1e-12

    def test_models_monotone_in_t(self, graph):
        for strat in STRATEGIES:
            times = [t_p2p(graph, t, BLUE_WATERS.with_ppn(graph.ppn), strat) for t in (1, 5, 10, 20)]
            assert all(times[i] <= times[i + 1] + 1e-15 for i in range(len(times) - 1)), (strat, times)

    def test_max_rate_upper_bounds_postal_p2p(self, graph):
        for t in (1, 20):
            assert t_standard(graph, t, BLUE_WATERS.with_ppn(graph.ppn)) >= t_standard_postal(
                graph, t, BLUE_WATERS.with_ppn(graph.ppn)
            ) - 1e-15

    def test_collective_model_t_squared_growth(self):
        base = t_collective(1024, 1, BLUE_WATERS)
        big = t_collective(1024, 20, BLUE_WATERS)
        pure_latency = 2 * BLUE_WATERS.alpha * 10
        assert (big - pure_latency) / max(base - pure_latency, 1e-300) == pytest.approx(400, rel=0.01)

    def test_computation_model_eq_3_3(self):
        counts = ECGOperationCounts(n=10_000, nnz=90_000, p=8, t=5)
        got = t_computation(counts, BLUE_WATERS)
        expected = BLUE_WATERS.gamma * (
            (2 + 10) * 90_000 / 8 + (20 + 100) * 10_000 / 8 + 25 / 2 + 125 / 6
        )
        assert got == pytest.approx(expected)

    def test_iteration_model_composition(self, graph):
        counts = ECGOperationCounts(n=81920 * 4, nnz=81920 * 4 * 80, p=graph.p, t=5)
        m = t_ecg_iteration(graph, counts, BLUE_WATERS.with_ppn(graph.ppn), "2step")
        assert m.total == pytest.approx(m.p2p + m.collective + m.computation)
        assert 0 < m.p2p_fraction < 1

    def test_tuning_picks_argmin(self, graph):
        best, times = tune_strategy(graph, 10, LASSEN.with_ppn(graph.ppn))
        assert best in STRATEGIES
        assert times[best] == min(times.values())

    @given(nbytes=st.floats(1e2, 1e7), ppn=st.integers(1, 64))
    @settings(max_examples=30, deadline=None)
    def test_split_send_never_slower_than_single(self, nbytes, ppn):
        """Fig 4.7: splitting a fixed volume across ppn senders can only help
        (per-process bandwidth term shrinks; injection term unchanged)."""
        m = LASSEN
        assert split_send_time(m, nbytes, ppn) <= ping_time(m, nbytes, "network", active=1) + 1e-12

    def test_ping_network_vs_onnode_crossover(self):
        """Fig 4.6 (Lassen): small messages cross the network faster than
        cross-socket on-node; large volumes with many active senders do not."""
        m = LASSEN
        small = 1024
        assert ping_time(m, small, "network", active=1) < ping_time(m, small * 40, "node")
        big = 10**6
        assert ping_time(m, big, "network", active=40) > ping_time(m, big, "socket")
