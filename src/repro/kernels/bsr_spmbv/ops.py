"""Public op: Block-ELL SpMBV with Pallas-on-TPU / oracle-on-CPU dispatch.

Besides the kernel wrapper this module carries the host-side (numpy)
conversion machinery that puts the kernel on the solver hot path:

* :func:`csr_arrays_to_block_ell` / :func:`count_block_ell_tiles` convert raw
  CSR arrays (a rank's local [own ‖ halo] block in the distributed solver)
  into the fixed-``kmax`` Block-ELL layout the kernel consumes.  Conversion
  cost is O(nnz log nnz) (one sort + one pass over nonzeros) and is paid once
  at ``make_distributed_spmbv`` setup — the analogue of the MPI communicator
  setup phase, amortized over all solver iterations.
* :func:`make_block_ell_apply` builds a ``(n, t) -> (n, t)`` closure over a
  global CSR matrix for the sequential solver's ``backend="pallas"`` path.
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.sparse.csr import BSRMatrix, CSRMatrix, csr_to_bsr
from repro.kernels.bsr_spmbv.kernel import bsr_spmbv_pallas
from repro.kernels.bsr_spmbv.ref import bsr_spmbv_ref
from repro.kernels.dispatch import resolve_dispatch


def bsr_to_block_ell(b: BSRMatrix, kmax: int | None = None):
    """BSR -> Block-ELL (fixed tiles per block row; zero-padded)."""
    nbr = b.n_block_rows
    indptr = np.asarray(b.block_indptr)
    per_row = np.diff(indptr)
    kmax = int(per_row.max()) if kmax is None else kmax
    br, bc = b.block_shape
    blocks = np.zeros((nbr, kmax, br, bc), dtype=np.asarray(b.blocks).dtype)
    indices = np.zeros((nbr, kmax), dtype=np.int32)
    src_blocks = np.asarray(b.blocks)
    src_idx = np.asarray(b.block_indices)
    for i in range(nbr):
        s, e = indptr[i], indptr[i + 1]
        blocks[i, : e - s] = src_blocks[s:e]
        indices[i, : e - s] = src_idx[s:e]
    return jnp.asarray(blocks), jnp.asarray(indices)


def block_ell_from_csr(a: CSRMatrix, br: int, bc: int):
    return bsr_to_block_ell(csr_to_bsr(a, br, bc))


def count_block_ell_tiles(indptr, indices, n_rows: int, n_cols: int, br: int, bc: int) -> int:
    """Max distinct (br x bc) tiles in any block row of a raw-CSR matrix."""
    indptr = np.asarray(indptr, dtype=np.int64)
    indices = np.asarray(indices, dtype=np.int64)
    nnz = int(indptr[min(n_rows, len(indptr) - 1)])
    if nnz == 0:
        return 0
    rows = np.repeat(np.arange(n_rows, dtype=np.int64), np.diff(indptr[: n_rows + 1]))
    nbc = (n_cols + bc - 1) // bc
    tiles = np.unique((rows // br) * nbc + indices[:nnz] // bc)
    return int(np.bincount(tiles // nbc).max())


def csr_arrays_to_block_ell(
    indptr, indices, data, n_rows: int, n_cols: int, br: int, bc: int,
    nbr: int, kmax: int,
):
    """Raw CSR arrays -> Block-ELL with caller-fixed (nbr, kmax) padding.

    The caller fixes ``nbr``/``kmax`` so per-rank conversions can be stacked
    into one (p, nbr, kmax, br, bc) device array; unused tiles stay zero with
    block-column id 0 (safe: zero tiles contribute nothing).
    """
    indptr = np.asarray(indptr, dtype=np.int64)
    indices = np.asarray(indices, dtype=np.int64)
    data = np.asarray(data)
    blocks = np.zeros((nbr, kmax, br, bc), dtype=data.dtype)
    ell_idx = np.zeros((nbr, kmax), dtype=np.int32)
    nnz = int(indptr[min(n_rows, len(indptr) - 1)])
    if nnz == 0:
        return blocks, ell_idx
    rows = np.repeat(np.arange(n_rows, dtype=np.int64), np.diff(indptr[: n_rows + 1]))
    nbc = (n_cols + bc - 1) // bc
    brow = rows // br
    bcol = indices[:nnz] // bc
    key = brow * nbc + bcol
    order = np.argsort(key, kind="stable")
    key_s = key[order]
    uniq, starts = np.unique(key_s, return_index=True)
    ends = np.append(starts[1:], len(key_s))
    r_in = (rows % br)[order]
    c_in = (indices[:nnz] % bc)[order]
    d_s = data[:nnz][order]
    slot = np.zeros(nbr, dtype=np.int64)
    for u, s, e in zip(uniq, starts, ends):
        bi, bj = int(u // nbc), int(u % nbc)
        k = slot[bi]
        assert k < kmax, f"block row {bi} overflows kmax={kmax}"
        ell_idx[bi, k] = bj
        blocks[bi, k, r_in[s:e], c_in[s:e]] = d_s[s:e]
        slot[bi] += 1
    return blocks, ell_idx


def block_ell_meta(a: CSRMatrix, br: int, bc: int) -> dict:
    """Tile analysis of the CSR -> Block-ELL conversion — JSON-serializable.

    This is the *choice* part of the conversion (which tile grid, how many
    tile slots per block row, how much zero padding) separated from the
    *fill* part (scattering nonzeros into the slots): persisting the meta
    lets a rebuilt handle skip the analysis pass and direct-fill via
    :func:`csr_arrays_to_block_ell` (the serve layer's eviction-aware warm
    start).  ``pad_hist[k]`` counts block rows holding exactly k tiles —
    the padding histogram behind the ``kmax`` waste.
    """
    indptr = np.asarray(a.indptr, dtype=np.int64)
    indices = np.asarray(a.indices, dtype=np.int64)
    n, m = a.shape
    n_pad = (n + br - 1) // br * br
    m_pad = (m + bc - 1) // bc * bc
    nbr, nbc = n_pad // br, m_pad // bc
    rows = np.repeat(np.arange(n, dtype=np.int64), np.diff(indptr))
    tiles = np.unique((rows // br) * nbc + indices // bc)
    per_row = np.bincount((tiles // nbc).astype(np.int64), minlength=nbr)
    kmax = int(per_row.max()) if len(tiles) else 0
    return dict(
        br=int(br), bc=int(bc), shape=[int(n), int(m)], nnz=int(a.nnz),
        nbr=int(nbr), nbc=int(nbc), kmax=kmax,
        n_pad=int(n_pad), m_pad=int(m_pad),
        pad_hist=np.bincount(per_row, minlength=kmax + 1).tolist(),
    )


def _meta_matches(meta: dict | None, a: CSRMatrix, br: int, bc: int) -> bool:
    if not isinstance(meta, dict):
        return False
    try:
        return (
            int(meta["br"]) == br
            and int(meta["bc"]) == bc
            and [int(s) for s in meta["shape"]] == [int(s) for s in a.shape]
            and int(meta["nnz"]) == a.nnz
            and int(meta["kmax"]) >= 0
        )
    except (KeyError, TypeError, ValueError):
        return False


def block_ell_arrays(a: CSRMatrix, br: int, bc: int, meta: dict | None = None):
    """CSR -> Block-ELL device arrays, optionally skipping the analysis.

    Returns ``(blocks, indices, m_pad, meta, analyzed)``.  With a valid
    ``meta`` (from :func:`block_ell_meta` of the *same* matrix/tile) the
    tile-counting analysis is skipped and the nonzeros are direct-filled
    into the known (nbr, kmax) layout (``analyzed=False``); a stale or
    missing meta triggers a fresh analysis (``analyzed=True``), never an
    error.  The produced layout is bit-identical to the historical
    CSR -> BSR -> Block-ELL path (both fill tiles in ascending block-column
    order per block row).
    """
    analyzed = not _meta_matches(meta, a, br, bc)
    if analyzed:
        meta = block_ell_meta(a, br, bc)
    n, m = a.shape
    blocks, indices = csr_arrays_to_block_ell(
        a.indptr, a.indices, a.data, n, m, br, bc,
        nbr=int(meta["nbr"]), kmax=int(meta["kmax"]),
    )
    return (
        jnp.asarray(blocks), jnp.asarray(indices), int(meta["m_pad"]),
        meta, analyzed,
    )


def make_block_ell_apply_from_arrays(blocks, indices, m_pad: int, n: int,
                                     use_pallas: bool | None = None):
    """``apply(V: (n, t)) -> (n, t)`` over precomputed Block-ELL arrays —
    the closure :func:`make_block_ell_apply` builds, minus the conversion."""

    def apply(v):
        vp = jnp.pad(v, ((0, m_pad - v.shape[0]), (0, 0)))
        w = bsr_spmbv(blocks, indices, vp, use_pallas=use_pallas)
        return w[:n]

    return apply


def make_block_ell_apply(
    a: CSRMatrix, block: int | tuple[int, int] = 8, use_pallas: bool | None = None
):
    """Build the sequential solver's SpMBV closure over the Block-ELL kernel.

    Converts ``a`` once (CSR -> BSR -> Block-ELL) and returns
    ``apply(V: (n, t)) -> (n, t)`` that pads V to the tile grid, runs
    :func:`bsr_spmbv`, and slices back to true rows.  ``block`` is an int
    for square tiles or an explicit (br, bc) pair — e.g. the
    ``ell_block`` a :class:`repro.tune.TunedConfig` selected.
    """
    br, bc = (block, block) if isinstance(block, int) else block
    b = csr_to_bsr(a, br, bc)
    blocks, indices = bsr_to_block_ell(b)
    n = a.shape[0]
    m_pad = b.shape[1]

    def apply(v):
        vp = jnp.pad(v, ((0, m_pad - v.shape[0]), (0, 0)))
        w = bsr_spmbv(blocks, indices, vp, use_pallas=use_pallas)
        return w[:n]

    return apply


def bsr_spmbv(blocks, indices, v, use_pallas: bool | None = None):
    """W = A @ V.  Pallas kernel on TPU; interpret-mode Pallas or the jnp
    oracle elsewhere (``use_pallas=True`` forces interpret-mode validation).
    GPU hosts fall back to the oracle with an explicit warn-once (see
    :mod:`repro.kernels.dispatch`)."""
    use_pallas, interpret = resolve_dispatch("bsr_spmbv", use_pallas)
    if use_pallas:
        return bsr_spmbv_pallas(blocks, indices, v, interpret=interpret)
    return bsr_spmbv_ref(blocks, indices, v)
