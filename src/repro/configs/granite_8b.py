"""granite-8b [dense]: 36L d=4096 32H (GQA kv=8) d_ff=14336 vocab=49152
llama-arch SwiGLU [arXiv:2405.04324]."""

from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="granite-8b",
    family="dense",
    n_layers=36,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=49152,
    mlp="swiglu",
)

SMOKE = CONFIG.with_(
    name="granite8-smoke", n_layers=2, d_model=128, n_heads=8, n_kv_heads=2,
    d_ff=256, vocab=512, remat=False,
)

SHAPES = {
    "train_4k": "run",
    "prefill_32k": "run",
    "decode_32k": "run",
    "long_500k": "skip:pure full attention (DESIGN.md §Arch-applicability)",
}
