"""stablelm-1.6b [dense]: 24L d=2048 32H (kv=32) d_ff=5632 vocab=100352
[hf:stabilityai/stablelm-2-1_6b]."""

from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="stablelm-1.6b",
    family="dense",
    n_layers=24,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=5632,
    vocab=100352,
    mlp="swiglu",
)

SMOKE = CONFIG.with_(
    name="stablelm-smoke", n_layers=2, d_model=128, n_heads=8, n_kv_heads=8,
    d_ff=256, vocab=512, remat=False,
)

SHAPES = {
    "train_4k": "run",
    "prefill_32k": "run",
    "decode_32k": "run",
    "long_500k": "skip:pure full attention (DESIGN.md §Arch-applicability)",
}
