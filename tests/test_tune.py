"""Autotuner, block-row interior/boundary split, wide-halo col-split."""

import dataclasses

import numpy as np
import pytest

from repro.core.comm_graph import build_comm_graph
from repro.core.machines import BLUE_WATERS, LASSEN, TPU_V5E_POD
from repro.core.models import STRATEGIES, tune_strategy
from repro.core.node_aware import build_exchange_plan, simulate_plan
from repro.sparse import dg_laplace_2d, fd_laplace_2d, partition_csr
from repro.sparse.partition import interior_boundary_split
from repro.tune import DEFAULT_TILES, tile_stats, tune


@pytest.fixture(scope="module")
def dg():
    a = dg_laplace_2d((16, 12), block=8)  # natural 8x8 block structure
    return a, partition_csr(a, 8)


class TestTunerStrategy:
    def test_matches_table1_argmin(self, dg):
        """On blocking configs the joint argmin's strategy must coincide with
        the paper's §4.3 tuning (tune_strategy) — same models, same graph."""
        a, pm = dg
        g = build_comm_graph(pm, ppn=4)
        for mach in (BLUE_WATERS, LASSEN, TPU_V5E_POD):
            # tune() re-derives f from the matrix dtype (f64 here)
            m = dataclasses.replace(mach, ppn=4, f=8)
            for t in (4, 8):
                best, _ = tune_strategy(g, t, m)
                cfg = tune(a, t=t, machine=mach, n_nodes=2, ppn=4, pm=pm)
                if not cfg.overlap:  # overlap can legitimately hide T_exch
                    assert cfg.strategy == best, (mach.name, t)

    def test_known_winner_latency_bound(self, dg):
        """Synthetic machine with dominant inter-node latency and free
        bandwidth: Table 1 says the fewest-message strategy (3-step's
        m_node->node/ppn) must win over standard."""
        a, pm = dg
        m = dataclasses.replace(
            BLUE_WATERS, alpha=1.0, alpha_l=1e-9,
            R_N=1e15, R_b=1e15, R_bl=1e15, ppn=4,
        )
        g = build_comm_graph(pm, ppn=4)
        best, times = tune_strategy(g, 8, m)
        assert times["3step"] < times["standard"]
        cfg = tune(a, t=8, machine=m, n_nodes=2, ppn=4, pm=pm)
        assert cfg.strategy == best

    def test_known_winner_intranode_bound(self, dg):
        """Free network but catastrophic intra-node tier: the node-aware
        strategies pay the staging/redistribution cost, standard does not."""
        a, pm = dg
        m = dataclasses.replace(
            BLUE_WATERS, alpha=1e-9, alpha_l=10.0, R_bl=1.0, ppn=4
        )
        g = build_comm_graph(pm, ppn=4)
        best, _ = tune_strategy(g, 8, m)
        assert best == "standard"
        cfg = tune(a, t=8, machine=m, n_nodes=2, ppn=4, pm=pm)
        assert cfg.strategy == "standard"


class TestTunerTile:
    def test_picks_natural_block_size(self, dg):
        """On a DG matrix with native 8x8 blocks the fill-optimal tile is
        (8, 8): smaller tiles pay sublane padding, larger ones zero fill."""
        a, pm = dg
        fills = {tile: tile_stats(pm, *tile).fill for tile in DEFAULT_TILES}
        assert min(fills, key=fills.get) == (8, 8)
        for mach in (BLUE_WATERS, TPU_V5E_POD):
            cfg = tune(a, t=8, machine=mach, n_nodes=2, ppn=4, pm=pm)
            assert (cfg.br, cfg.bc) == (8, 8), mach.name

    def test_kmax_budget_sufficient(self):
        """TunedConfig.kmax must be exactly the budget the stacked Block-ELL
        conversion needs: conversion at that kmax succeeds for every rank."""
        from repro.kernels import csr_arrays_to_block_ell
        from repro.tune.autotune import _rebased_local

        a = fd_laplace_2d(13)  # uneven partition, irregular halo
        pm = partition_csr(a, 8)
        ts = tile_stats(pm, 8, 8)
        rmax = pm.part.max_local_rows
        n_cols = rmax + max(len(h) for h in pm.halo_sources)
        nbr = max(1, (rmax + 7) // 8)
        for ptr, ix, n_local in _rebased_local(pm):
            csr_arrays_to_block_ell(
                ptr, ix, np.ones(len(ix)), n_local, n_cols, 8, 8, nbr, ts.kmax
            )  # would assert-fail on kmax overflow

    def test_jnp_backend_ignores_tiles(self, dg):
        a, pm = dg
        cfg = tune(a, t=4, machine=BLUE_WATERS, n_nodes=2, ppn=4, pm=pm,
                   backend="jnp")
        assert cfg.backend == "jnp"
        assert (cfg.br, cfg.bc) == (8, 8)  # reference tile, unused by CSR


class TestTunerOverlap:
    def test_nothing_to_hide_keeps_blocking(self, dg):
        """Near-free exchange: overlap saves min(T_int, T_exch) ~ 0 but still
        pays the split overhead, so the model must keep blocking."""
        a, pm = dg
        m = dataclasses.replace(
            BLUE_WATERS, alpha=1e-12, alpha_l=1e-12,
            R_N=1e18, R_b=1e18, R_bl=1e18, ppn=4,
        )
        cfg = tune(a, t=8, machine=m, n_nodes=2, ppn=4, pm=pm)
        assert not cfg.overlap

    def test_slow_network_fat_compute_overlaps(self):
        """Exchange far larger than the interior product and interior work
        far larger than the split overhead: overlap must win.  Needs a
        matrix whose ranks have a genuine interior (the DG fixture's ranks
        are only two element-rows deep — all boundary)."""
        a = fd_laplace_2d(64)  # 512 rows/rank, interior fraction ~0.75
        m = dataclasses.replace(
            BLUE_WATERS, alpha=1e-3, gamma=1e-7, alpha_l=1e-9, R_mem=0.0, ppn=4
        )
        cfg = tune(a, t=8, machine=m, n_nodes=2, ppn=4)
        assert cfg.overlap


class TestBlockRowSplit:
    @pytest.mark.parametrize("br", [2, 4, 8])
    def test_partition_and_tile_alignment(self, br):
        a = dg_laplace_2d((8, 6), block=4)
        pm = partition_csr(a, 8)
        io_row = interior_boundary_split(pm)
        io_blk = interior_boundary_split(pm, block_row=br)
        for r, ((ir, _bd), (irb, bdb)) in enumerate(zip(io_row, io_blk)):
            lo, hi = pm.part.local_range(r)
            n_local = hi - lo
            # still an exact partition of the local rows
            assert sorted(np.concatenate([irb, bdb]).tolist()) == list(range(n_local))
            # conservative coarsening: block-row interior ⊆ row interior
            assert set(irb.tolist()) <= set(ir.tolist())
            # no re-blocking: each set is a union of whole br-aligned block
            # rows (the ragged tail block counts as one block)
            for rows in (irb, bdb):
                sel = set(rows.tolist())
                for blk in {x // br for x in sel}:
                    members = range(blk * br, min((blk + 1) * br, n_local))
                    assert sel.issuperset(members), (r, br, blk)

    @pytest.mark.parametrize("br", [1, 4])
    def test_numeric_match(self, br):
        """Recombining the gathered interior/boundary products equals the
        full local SpMBV — block-row coarsening changes the split, never the
        result."""
        from repro.sparse.spmbv import _gather_csr_rows

        a = dg_laplace_2d((8, 6), block=4)
        pm = partition_csr(a, 8)
        rng = np.random.default_rng(0)
        t = 3
        x = rng.standard_normal((a.shape[0], t))
        io = interior_boundary_split(pm, block_row=br)
        for r, (int_rows, bnd_rows) in enumerate(io):
            lo, hi = pm.part.local_range(r)
            n_local = hi - lo
            ptr = np.asarray(pm.local_indptr[r])
            ix = np.asarray(pm.local_indices[r])
            dat = np.asarray(pm.local_data[r])
            xfull = np.concatenate([x[lo:hi], x[pm.halo_sources[r]]])
            # reference: full local product
            w_ref = np.zeros((n_local, t))
            for i in range(n_local):
                s, e = ptr[i], ptr[i + 1]
                w_ref[i] = dat[s:e] @ xfull[ix[s:e]]
            # split: gather each subset, compute, scatter back
            w = np.zeros((n_local, t))
            for rows in (int_rows, bnd_rows):
                gptr, gix, gdat = _gather_csr_rows(ptr, ix, dat, rows)
                for k, row in enumerate(rows):
                    s, e = gptr[k], gptr[k + 1]
                    w[row] = gdat[s:e] @ xfull[gix[s:e]]
            np.testing.assert_array_equal(w, w_ref)


class TestWideHaloSplit:
    @pytest.mark.parametrize("t", [2, 4, 8])
    def test_roundtrip_bit_exact(self, t):
        """Forced col-split plans deliver bit-identical halos for t∈{2,4,8}."""
        a = fd_laplace_2d(13)
        pm = partition_csr(a, 8)
        rng = np.random.default_rng(1)
        x = rng.standard_normal((a.shape[0], t))
        expected = [x[src] for src in pm.halo_sources]
        for cs in (1, 2, t):
            plan = build_exchange_plan(
                pm, 2, 4, "optimal", t=t, machine=BLUE_WATERS, col_split=cs
            )
            assert plan.col_split == cs
            assert plan.halo_rows * cs == plan.halo_size
            halos = simulate_plan(plan, pm, x)
            for d in range(8):
                assert np.array_equal(halos[d], expected[d]), (t, cs, d)

    def test_byte_model_auto_trigger(self):
        """Few-row inter-node units + tiny cutoff: the §4.3 byte model must
        split rows, and the dedup'd inter-node row volume is unchanged."""
        a = fd_laplace_2d(4)  # 16 rows over 8 ranks -> 1-2 row units
        pm = partition_csr(a, 8)
        tiny = dataclasses.replace(BLUE_WATERS, eager_cutoff=16)
        plan = build_exchange_plan(pm, 2, 4, "optimal", t=8, machine=tiny)
        assert plan.col_split > 1
        ref = build_exchange_plan(pm, 2, 4, "optimal", t=8, machine=BLUE_WATERS)
        assert plan.comm_rows()["inter"] == ref.comm_rows()["inter"]
        rng = np.random.default_rng(2)
        x = rng.standard_normal((a.shape[0], 8))
        halos = simulate_plan(plan, pm, x)
        for d in range(8):
            assert np.array_equal(halos[d], x[pm.halo_sources[d]])

    def test_width_mismatch_pads(self):
        """A plan tuned for t=8 applied at widths 1 and 3 (initial residual
        path) still round-trips exactly."""
        a = fd_laplace_2d(13)
        pm = partition_csr(a, 8)
        plan = build_exchange_plan(
            pm, 2, 4, "optimal", t=8, machine=BLUE_WATERS, col_split=4
        )
        rng = np.random.default_rng(3)
        for shape in [(a.shape[0],), (a.shape[0], 3)]:
            x = rng.standard_normal(shape)
            halos = simulate_plan(plan, pm, x)
            x2 = x[:, None] if x.ndim == 1 else x
            for d in range(8):
                assert np.array_equal(halos[d], x2[pm.halo_sources[d]])

    def test_tuned_config_records_col_split(self):
        a = fd_laplace_2d(4)
        tiny = dataclasses.replace(BLUE_WATERS, eager_cutoff=16)
        cfg = tune(a, t=8, machine=tiny, n_nodes=2, ppn=4)
        if cfg.strategy == "optimal":
            plan = build_exchange_plan(
                partition_csr(a, 8), 2, 4, "optimal", t=8, machine=tiny
            )
            assert cfg.col_split == plan.col_split


class TestSendBytesDtype:
    def test_send_bytes_derives_f_from_dtype(self):
        import jax.numpy as jnp

        a64 = fd_laplace_2d(13)
        a32 = fd_laplace_2d(13, dtype=jnp.float32)
        c64 = partition_csr(a64, 8).comms
        c32 = partition_csr(a32, 8).comms
        for p64, p32 in zip(c64, c32):
            assert p64.send_bytes(t=4) == 2 * p32.send_bytes(t=4)
            # explicit f still wins
            assert p32.send_bytes(t=4, f=8) == p64.send_bytes(t=4)
