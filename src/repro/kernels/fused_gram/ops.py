"""Public op: fused ECG gram products (Pallas on TPU, oracle elsewhere).

Hot-path wiring: with ``backend="pallas"`` this op IS allreduce #2's local
compute — ``repro.core.ecg.ecg_solve`` wraps it in ``allreduce`` and
``repro.sparse.spmbv.distributed_ecg`` runs it per device inside the
shard_map ``gram2``, feeding exactly one psum (the 3t² payload of §3.1).
"""

from __future__ import annotations

from repro.kernels.dispatch import resolve_dispatch
from repro.kernels.fused_gram.kernel import fused_gram_pallas
from repro.kernels.fused_gram.ref import fused_gram_ref


def fused_gram(p, r, ap, ap_old, use_pallas: bool | None = None, block_rows: int = 512):
    use_pallas, interpret = resolve_dispatch("fused_gram", use_pallas)
    if use_pallas:
        return fused_gram_pallas(p, r, ap, ap_old, block_rows=block_rows, interpret=interpret)
    return fused_gram_ref(p, r, ap, ap_old)
