"""Uniform model API dispatch: family -> module functions."""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
from jax.sharding import Mesh

from repro.models.common import ArchConfig, MeshAxes
from repro.models import transformer as _tf
from repro.models import ssm as _ssm
from repro.models import encdec as _ed


@dataclasses.dataclass(frozen=True)
class ModelApi:
    init_params: Callable
    abstract_params: Callable
    param_specs: Callable         # (cfg, axes) -> pytree of PartitionSpec
    loss_fn: Callable             # (cfg, mesh) -> f(params, batch) -> loss
    decode_step: Callable         # (cfg, mesh) -> f(params, cache, batch)
    abstract_cache: Callable      # (cfg, batch, seq)
    init_cache: Callable
    cache_specs: Callable         # (cfg, axes, batch, seq)
    train_input_specs: Callable   # (cfg, mesh, batch, seq) -> {name: (sds, spec)}


_TRANSFORMER = ModelApi(
    init_params=_tf.init_params,
    abstract_params=_tf.abstract_params,
    param_specs=_tf.param_specs,
    loss_fn=_tf.loss_fn,
    decode_step=_tf.decode_step,
    abstract_cache=_tf.abstract_cache,
    init_cache=_tf.init_cache,
    cache_specs=_tf.cache_specs,
    train_input_specs=_tf.train_input_specs,
)

_SSM = ModelApi(
    init_params=_ssm.init_params,
    abstract_params=_ssm.abstract_params,
    param_specs=_ssm.param_specs,
    loss_fn=_ssm.loss_fn,
    decode_step=_ssm.decode_step,
    abstract_cache=_ssm.abstract_cache,
    init_cache=_ssm.init_cache,
    cache_specs=_ssm.cache_specs,
    train_input_specs=_ssm.train_input_specs,
)

_ENCDEC = ModelApi(
    init_params=_ed.init_params,
    abstract_params=_ed.abstract_params,
    param_specs=_ed.param_specs,
    loss_fn=_ed.loss_fn,
    decode_step=_ed.decode_step,
    abstract_cache=_ed.abstract_cache,
    init_cache=_ed.init_cache,
    cache_specs=_ed.cache_specs,
    train_input_specs=_ed.train_input_specs,
)

_BY_FAMILY = {
    "dense": _TRANSFORMER,
    "moe": _TRANSFORMER,
    "vlm": _TRANSFORMER,
    "ssm": _SSM,
    "hybrid": _SSM,
    "encdec": _ENCDEC,
}


def model_api(cfg: ArchConfig) -> ModelApi:
    return _BY_FAMILY[cfg.family]


def serve_input_specs(cfg: ArchConfig, mesh: Mesh, batch: int):
    """Decode-step inputs: one token + position per sequence."""
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    import numpy as np

    axes = MeshAxes.from_mesh(mesh)
    bsz = int(np.prod([axes.size(a) for a in axes.batch]))
    bspec = P(axes.batch) if batch % bsz == 0 else P()
    return {
        "token": (jax.ShapeDtypeStruct((batch,), jnp.int32), bspec),
        "pos": (jax.ShapeDtypeStruct((batch,), jnp.int32), bspec),
    }
