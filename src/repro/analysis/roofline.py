"""Roofline analysis from compiled dry-run artifacts.

Three terms per (arch x shape x mesh) cell, in seconds (TPU v5e constants):

    compute    = FLOPs_dev / peak_FLOP/s
    memory     = HBM_bytes_dev / HBM_bw
    collective = collective_bytes_dev / link_bw

Sources: ``compiled.cost_analysis()`` (flops / bytes accessed) and the HLO
text for collective payloads.  Notes on methodology (validated empirically in
/tmp probes, recorded in EXPERIMENTS.md §Dry-run):

  * XLA cost analysis counts a while/scan body ONCE, not x trip-count.  The
    dry-run therefore compiles 1-layer and 2-layer *unrolled* variants of each
    cell and extrapolates:  cost(L) = intercept + L · Δ  where
    Δ = cost(2L_unrolled) - cost(1L_unrolled).  This is exact for
    layer-homogeneous stacks (all assigned archs; Zamba2 uses period-level
    deltas with a ~1.5% tail correction noted inline).
  * cost_analysis numbers are per-device (the SPMD program); global figures
    multiply by chip count.
  * CPU-backend "bytes accessed" lacks TPU fusion, so the memory term is an
    upper-bound proxy; flagged in the report.
"""

from __future__ import annotations

import dataclasses
import re

from repro.core.machines import V5E_PEAK_FLOPS, V5E_HBM_BW, V5E_ICI_BW

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    b = _DTYPE_BYTES.get(dtype)
    if b is None:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * b


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Sum per-collective payload bytes (per device) from HLO text.

    For each collective instruction we take the larger of (result bytes,
    summed operand bytes) — an upper bound on the wire payload that is exact
    for all-reduce/permute and conservatively includes the gathered result
    for all-gather.
    """
    out = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        line = line.strip()
        m = re.match(r"(?:ROOT\s+)?%?[\w.-]+\s*=\s*(.+?)\s+([\w-]+)\(", line)
        if not m:
            continue
        result_type, op = m.groups()
        base = op.removesuffix("-start").removesuffix("-done")
        if base not in _COLLECTIVES:
            continue
        if op.endswith("-done"):
            continue  # counted at -start
        result_bytes = sum(_shape_bytes(d, s) for d, s in _SHAPE_RE.findall(result_type))
        args = line[m.end():]
        operand_bytes = sum(_shape_bytes(d, s) for d, s in _SHAPE_RE.findall(args.split("),")[0]))
        out[base] += max(result_bytes, operand_bytes)
    return out


def count_collective_ops(hlo_text: str) -> dict[str, int]:
    return {
        k: len(re.findall(rf"\b{k}(?:-start)?\(", hlo_text)) for k in _COLLECTIVES
    }


@dataclasses.dataclass
class CellCost:
    """Per-device extrapolated costs for one dry-run cell."""

    flops: float
    hbm_bytes: float
    coll_bytes: float
    coll_breakdown: dict[str, float]

    @staticmethod
    def extrapolate(c1: "CellCost", c2: "CellCost", n_units: float) -> "CellCost":
        """cost(L) = c1 + (n_units - 1) * (c2 - c1)   (1- and 2-unit compiles)."""
        d = lambda a, b: a + (n_units - 1) * (b - a)
        return CellCost(
            flops=d(c1.flops, c2.flops),
            hbm_bytes=d(c1.hbm_bytes, c2.hbm_bytes),
            coll_bytes=d(c1.coll_bytes, c2.coll_bytes),
            coll_breakdown={
                k: d(c1.coll_breakdown.get(k, 0), c2.coll_breakdown.get(k, 0))
                for k in set(c1.coll_breakdown) | set(c2.coll_breakdown)
            },
        )


def cost_from_compiled(compiled) -> CellCost:
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    txt = compiled.as_text()
    cb = collective_bytes(txt)
    return CellCost(
        flops=float(ca.get("flops", 0.0)),
        hbm_bytes=float(ca.get("bytes accessed", 0.0)),
        coll_bytes=float(sum(cb.values())),
        coll_breakdown={k: float(v) for k, v in cb.items()},
    )


@dataclasses.dataclass
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops_global: float
    hlo_flops_global: float
    chips: int

    @property
    def dominant(self) -> str:
        terms = dict(compute=self.compute_s, memory=self.memory_s, collective=self.collective_s)
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs — remat/padding/redundancy waste detector."""
        return self.model_flops_global / self.hlo_flops_global if self.hlo_flops_global else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the compute roofline achieved if the cell ran at its
        bound: (useful compute time) / (time the dominant term costs)."""
        ideal = self.model_flops_global / (self.chips * V5E_PEAK_FLOPS)
        return ideal / self.bound_s if self.bound_s else 0.0

    def as_dict(self):
        return dict(
            compute_s=self.compute_s,
            memory_s=self.memory_s,
            collective_s=self.collective_s,
            dominant=self.dominant,
            model_flops_global=self.model_flops_global,
            hlo_flops_global=self.hlo_flops_global,
            useful_flops_ratio=self.useful_flops_ratio,
            roofline_fraction=self.roofline_fraction,
            chips=self.chips,
        )


def roofline_from_cost(cost: CellCost, chips: int, model_flops_global: float) -> Roofline:
    return Roofline(
        compute_s=cost.flops / V5E_PEAK_FLOPS,
        memory_s=cost.hbm_bytes / V5E_HBM_BW,
        collective_s=cost.coll_bytes / V5E_ICI_BW,
        model_flops_global=model_flops_global,
        hlo_flops_global=cost.flops * chips,
        chips=chips,
    )


def model_flops(cfg, kind: str, seq: int, batch: int) -> float:
    """MODEL_FLOPS: 6·N·D for training, 2·N·D for inference (per step over
    ``batch`` tokens for decode), with N_active for MoE."""
    n = cfg.active_param_count()
    if kind == "train":
        return 6.0 * n * seq * batch
    if kind == "prefill":
        return 2.0 * n * seq * batch
    if kind == "decode":
        return 2.0 * n * batch  # one token per sequence
    raise ValueError(kind)
