"""Pod-aware hierarchical collectives (beyond-paper, DESIGN.md §4)."""

import os
import subprocess
import sys
from pathlib import Path

import pytest

from repro.collectives.hierarchical import tiered_collective_bytes

ROOT = Path(__file__).resolve().parents[1]

HLO = """
  %ar1 = bf16[64,8]{1,0} all-reduce(%a), replica_groups={{0,1,2,3},{4,5,6,7}}
  %ar2 = bf16[64,8]{1,0} all-reduce(%b), replica_groups={{0,4},{1,5},{2,6},{3,7}}
  %cp = bf16[8,8]{1,0} collective-permute(%c), source_target_pairs={{0,4},{4,0}}
"""


class TestTierClassifier:
    def test_intra_vs_cross(self):
        got = tiered_collective_bytes(HLO, pod_size=4)
        assert got["intra_pod"] == 64 * 8 * 2
        assert got["cross_pod"] == 64 * 8 * 2 + 8 * 8 * 2


@pytest.mark.slow
def test_hierarchical_allreduce_matches_flat():
    """2-step pod-aware allreduce == plain psum, and its slow-tier bytes are
    |data|x smaller (verified from lowered HLO)."""
    worker = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, numpy as np, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map
from repro.collectives.hierarchical import hierarchical_allreduce, tiered_collective_bytes

mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
x = jnp.arange(32.0).reshape(8, 4)
flat = shard_map(lambda v: jax.lax.psum(v, ("pod", "data")), mesh=mesh,
                 in_specs=P(), out_specs=P(), check_rep=False)
want = flat(x)
got = hierarchical_allreduce(x, mesh)
np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)

# slow-tier bytes: the hierarchical version's all-reduce (the only op that
# crosses pods) carries 1/|data| of the flat all-reduce payload
from repro.analysis.roofline import collective_bytes
txt_h = jax.jit(lambda v: hierarchical_allreduce(v, mesh)).lower(x).compile().as_text()
txt_f = jax.jit(flat).lower(x).compile().as_text()
cb_h, cb_f = collective_bytes(txt_h), collective_bytes(txt_f)
assert cb_f["all-reduce"] > 0
assert cb_h["all-reduce"] * 2 <= cb_f["all-reduce"], (cb_h, cb_f)
assert cb_h["reduce-scatter"] > 0 and cb_h["all-gather"] > 0
print("hierarchical ok", cb_h, cb_f)
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src")
    proc = subprocess.run([sys.executable, "-c", worker], env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "hierarchical ok" in proc.stdout
