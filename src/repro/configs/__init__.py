"""Assigned architecture configs (+ reduced smoke variants).

Every module exposes ``CONFIG`` (the exact assigned configuration),
``SMOKE`` (a reduced same-family config for CPU tests) and ``SHAPES``
(the applicable input-shape cells with skip annotations).
"""

from __future__ import annotations

import importlib

ARCH_IDS = [
    "phi3_medium_14b",
    "stablelm_1_6b",
    "granite_20b",
    "granite_8b",
    "mamba2_780m",
    "whisper_medium",
    "zamba2_1_2b",
    "phi35_moe_42b",
    "olmoe_1b_7b",
    "paligemma_3b",
]

# canonical shape cells (assignment): name -> (kind, seq_len, global_batch)
SHAPE_CELLS = {
    "train_4k": ("train", 4_096, 256),
    "prefill_32k": ("prefill", 32_768, 32),
    "decode_32k": ("decode", 32_768, 128),
    "long_500k": ("decode", 524_288, 1),
}


def get_config(arch_id: str):
    mod = importlib.import_module(f"repro.configs.{arch_id.replace('-', '_')}")
    return mod.CONFIG


def get_smoke(arch_id: str):
    mod = importlib.import_module(f"repro.configs.{arch_id.replace('-', '_')}")
    return mod.SMOKE


def get_shapes(arch_id: str) -> dict[str, str]:
    """shape cell -> "run" or "skip:<reason>"."""
    mod = importlib.import_module(f"repro.configs.{arch_id.replace('-', '_')}")
    return mod.SHAPES
