"""Global-reduction pipelined ECG (Cools & Ghysels-style overlap).

Same two psums per iteration as classic, but the SpMBV is moved *off the
critical path of the packed Gram reduction*.  The trick is the AZ
recurrence: carrying AZ across iterations makes gram1 a pure function of
the carry, and the one SpMBV of the body acts on AP — whose only
dependency is gram1.  The packed gram2 psum and the SpMBV exchange then
have **no def-use path between them** in the lowered HLO, so the compiler
is free to run the 3t² reduction inside the exchange + interior-compute
window (the structural property ``tests/dist_worker.py`` proves by operand
reachability; the existing ``overlap=True`` interior/boundary schedule
provides the window itself).

  per iteration —
    G     = ZᵀAZ             gram1 on the carry      (psum #1, t²)
    P, AP = Z C⁻¹, AZ C⁻¹    local chol + TRSMs
    packed = [PᵀR | APᵀAP | AP_oldᵀAP]   gram2       (psum #2, 3t²)  ┐ mutually
    S     = A · AP           SpMBV                   (p2p)           ┘ independent
    X += Pc ; R −= APc ; Z' = AP − Pd − P_old d_old
    AZ'   = S − AP d − AP_old d_old      (A·Z' by linearity — no extra SpMBV)

Init seeds the recurrence with one extra SpMBV (AZ₀ = A·Z₀).  The iterates
are algebraically identical to classic — only rounding differs (gram1
consumes the recurred AZ instead of a fresh product).

Restart policies are rejected: a plateau re-enlarge reseeds Z from the
current residual, and rebuilding AZ for it would need a conditional SpMBV
inside the loop — exactly the synchronization this scheme removes.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.adaptive.rankrev import rank_revealing_apply
from repro.adaptive.reduce import plateau_update, stagnation_mask
from repro.core.cg import EV_RECOVERY
from repro.core.methods.base import MethodContext, MethodSpec, _apply_vec, _chol_inv_apply


class PipelinedMethod(MethodSpec):
    """Classic collectives, with gram2 overlapped into the SpMBV region."""

    name = "pipelined"
    overlaps_gram = True

    def validate(self, ctx: MethodContext) -> None:
        super().validate(ctx)
        if ctx.policy is not None and ctx.policy.restart:
            raise ValueError(
                "method 'pipelined' cannot run a restart policy: re-enlarging "
                "reseeds Z from the current residual, which would need an "
                "extra in-loop SpMBV to rebuild the AZ recurrence; use "
                "adaptive='reduce' (or method='classic' for restarts)"
            )

    def build(self, ctx: MethodContext):
        t = ctx.t
        max_iters = ctx.max_iters
        policy = ctx.policy
        use_mask = ctx.use_mask
        chol_eps = ctx.chol_eps
        a_apply = ctx.a_apply
        a_apply_masked = ctx.a_apply_masked
        gram1, gram2, sqnorm, tail = ctx.gram1, ctx.gram2, ctx.sqnorm, ctx.tail
        split_fn = ctx.split_fn
        precond, gram2p = ctx.precond, ctx.gram2p

        def iterate(carry):
            big_x, big_r, z, az = carry["X"], carry["R"], carry["Z"], carry["AZ"]
            p_old, ap_old = carry["P"], carry["AP"]
            k, hist = carry["k"], carry["hist"]

            g = gram1(z, az)  # psum #1 (t²) — AZ comes from the recurrence
            if policy is None:
                p, ap = _chol_inv_apply(g, z, az, eps=chol_eps)
                active = None
            else:
                (p, ap), _rank, active = rank_revealing_apply(
                    g, z, az, rtol=policy.rank_rtol
                )

            # psum #2 (3t²) and the SpMBV are data-independent: packed needs
            # only (p, R, ap, ap_old), the product only ap — the compiler may
            # run the reduction inside the exchange/interior window.  The
            # pack mask is the *carried* act (ap's dead columns are zeros of
            # the previous mask, so packing with it is exact), keeping the
            # exchange independent of this iteration's gram2-derived mask.
            # Preconditioned, the new directions come from W = M⁻¹AP: the
            # packed psum reads (p, R, ap, ap_old, w) and the SpMBV acts on
            # W — there is still no def-use path from the SpMBV into the
            # reduction, so the overlap property survives preconditioning.
            if precond is None:
                w = ap
                packed = gram2(p, big_r, ap, ap_old)
            else:
                w = precond(ap, k)
                packed = gram2p(p, big_r, ap, ap_old, w)
            if use_mask:
                s_w = a_apply_masked(w, carry["act"])  # SpMBV [p2p]
            else:
                s_w = a_apply(w)  # SpMBV [p2p]
            c, d, d_old = jnp.split(packed, 3, axis=1)

            big_x, big_r, z_new = tail(big_x, big_r, p, ap, p_old, c, d, d_old)
            if precond is not None:
                # Z' = W − Pd − P_old d_old: the fused tail's Z plus (W − AP)
                z_new = z_new + (w - ap)
            # AZ' = A·Z' by linearity: A(W − Pd − P_old d_old)
            #     = S − AP d − AP_old d_old  — no second SpMBV
            az_new = s_w - ap @ d - ap_old @ d_old
            if policy is not None:
                active = stagnation_mask(c, carry["rn"], active, policy)
                colmask = active.astype(z_new.dtype)[None, :]
                z_new = z_new * colmask
                az_new = az_new * colmask  # A·(Z'·mask) = (A·Z')·mask
            rsum = big_r.sum(axis=1)
            rn = jnp.sqrt(sqnorm(rsum))
            hist = hist.at[k + 1].set(rn)
            out = dict(
                X=big_x, R=big_r, Z=z_new, AZ=az_new, P=p, AP=ap, k=k + 1,
                rn=rn, hist=hist, bd=carry["bd"],
            )
            if use_mask:
                out["act"] = active
            if policy is not None:
                n_active = jnp.sum(active).astype(jnp.int32)
                best_rn, since = plateau_update(
                    rn, carry["best_rn"], carry["since"], policy
                )
                out.update(
                    best_rn=best_rn, since=since, restarts=carry["restarts"],
                    ahist=carry["ahist"].at[k + 1].set(n_active),
                    # telemetry: pivots accepted below the entering active
                    # width = a rank drop the factorization recovered from
                    evhist=carry["evhist"].at[k + 1].set(
                        jnp.where(_rank < carry["ahist"][k], EV_RECOVERY, 0)
                    ),
                )
            return out

        def init(b, x0):
            n = b.shape[0]
            dtype = b.dtype
            zeros_nt = jnp.zeros((n, t), dtype)
            r0 = b - _apply_vec(a_apply, x0, t)
            big_r0 = split_fn(r0, t)
            z0 = big_r0 if precond is None else precond(big_r0, jnp.int32(0))
            rn0 = jnp.sqrt(sqnorm(r0))
            hist0 = jnp.full((max_iters + 1,), jnp.nan, dtype=dtype).at[0].set(rn0)
            carry = dict(X=zeros_nt, R=big_r0, Z=z0,
                         AZ=a_apply(z0),  # seed the recurrence (init-only SpMBV)
                         P=zeros_nt, AP=zeros_nt,
                         k=jnp.int32(0), rn=rn0, hist=hist0,
                         bd=~jnp.isfinite(rn0))
            if policy is not None:
                carry.update(
                    best_rn=rn0,
                    since=jnp.int32(0),
                    restarts=jnp.int32(0),
                    ahist=jnp.full((max_iters + 1,), -1, jnp.int32).at[0].set(t),
                    evhist=jnp.full((max_iters + 1,), -1, jnp.int32).at[0].set(0),
                )
            if use_mask:
                carry["act"] = jnp.ones((t,), bool)
            return carry

        return init, iterate
