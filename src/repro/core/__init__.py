"""Core: the paper's contribution — ECG + node-aware communication."""

from repro.core.cg import cg_solve, SolveResult
from repro.core.ecg import ecg_solve, ECGOperationCounts
from repro.core.enlarging import split_residual, split_rank, collapse
from repro.core.methods import METHODS, MethodSpec, get_method

__all__ = [
    "cg_solve",
    "ecg_solve",
    "SolveResult",
    "ECGOperationCounts",
    "METHODS",
    "MethodSpec",
    "get_method",
    "split_residual",
    "split_rank",
    "collapse",
]
