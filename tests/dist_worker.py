"""Multi-device worker executed in a subprocess by test_distributed.py.

Must run with XLA_FLAGS=--xla_force_host_platform_device_count=8 so ordinary
tests keep a single device (see conftest note).
"""

import os
import sys

assert "--xla_force_host_platform_device_count" in os.environ.get("XLA_FLAGS", ""), (
    "run me via test_distributed.py"
)

import warnings

# No repro-internal module may go through the deprecated back-compat shims
# (ecg_solve/distributed_ecg/make_distributed_spmbv) during these checks.
# This must be an in-process filter: PYTHONWARNINGS/-W escape the module
# field and match it in full, so they cannot express "any repro submodule".
# The worker itself (__main__) deliberately exercises the legacy spellings
# and only sees the warning.
warnings.filterwarnings("error", category=DeprecationWarning, module=r"repro\..*")

import jax

jax.config.update("jax_enable_x64", True)

import numpy as np
import jax.numpy as jnp

from repro.sparse import dg_laplace_2d, fd_laplace_2d
from repro.sparse.csr import csr_spmbv
from repro.sparse.spmbv import make_distributed_spmbv, distributed_ecg
from repro.core import ecg_solve
from repro.core.machines import BLUE_WATERS


def check_spmbv_strategies():
    mesh = jax.make_mesh((2, 4), ("node", "proc"))
    rng = np.random.default_rng(0)
    for a, label in [
        (dg_laplace_2d((8, 6), block=4), "dg"),
        (fd_laplace_2d(13), "fd-uneven"),  # 169 rows, uneven over 8
    ]:
        ad = np.asarray(a.todense(), np.float64)
        for t in (1, 3, 8):
            V = rng.standard_normal((a.shape[0], t))
            for strategy in ("standard", "2step", "3step", "optimal"):
                op = make_distributed_spmbv(a, mesh, strategy, t=t, machine=BLUE_WATERS)
                W = op.unshard(jax.jit(op.matvec_fn())(op.shard_vector(V)))
                err = np.abs(W - ad @ V).max()
                assert err < 1e-10, (label, strategy, t, err)
                rows = op.plan.comm_rows()
                if strategy != "standard":
                    assert rows["inter"] <= std_inter, (label, strategy, rows)
                else:
                    std_inter = rows["inter"]
        # backend x overlap sweep: kernel-backed and comm-hiding variants
        # must produce the same product as the blocking CSR reference
        V = rng.standard_normal((a.shape[0], 3))
        for strategy in ("standard", "2step", "3step", "optimal"):
            for backend in ("jnp", "pallas"):
                for overlap in (False, True):
                    op = make_distributed_spmbv(
                        a, mesh, strategy, t=3, machine=BLUE_WATERS,
                        backend=backend, overlap=overlap,
                    )
                    W = op.unshard(jax.jit(op.matvec_fn())(op.shard_vector(V)))
                    err = np.abs(W - ad @ V).max()
                    assert err < 1e-10, (label, strategy, backend, overlap, err)
    print("spmbv strategies OK")


def check_kernel_backend_ecg_parity():
    """Kernel-backed distributed ECG must match the jnp path: identical
    iterate count everywhere, and residual history to 1e-10 on the FD system
    (where the Block-ELL summation order coincides with CSR; the DG system's
    iteration dynamics amplify tile-order rounding, so it checks count +
    convergence only)."""
    mesh = jax.make_mesh((2, 4), ("node", "proc"))
    rng = np.random.default_rng(1)

    a = fd_laplace_2d(13)
    b = rng.standard_normal(a.shape[0])
    ref, _ = distributed_ecg(a, b, mesh, t=4, strategy="3step")
    h_ref = np.asarray(ref.res_hist)
    live = ~np.isnan(h_ref)
    for backend, overlap in (("pallas", False), ("pallas", True), ("jnp", True)):
        res, _ = distributed_ecg(a, b, mesh, t=4, strategy="3step",
                                 backend=backend, overlap=overlap)
        assert res.n_iters == ref.n_iters, (backend, overlap, res.n_iters, ref.n_iters)
        h = np.asarray(res.res_hist)
        dh = np.abs(h[live] - h_ref[live]).max()
        assert dh < 1e-10, (backend, overlap, dh)

    a = dg_laplace_2d((8, 6), block=4)
    ad = np.asarray(a.todense(), np.float64)
    b = rng.standard_normal(a.shape[0])
    ref, _ = distributed_ecg(a, b, mesh, t=4, strategy="optimal")
    res, op = distributed_ecg(a, b, mesh, t=4, strategy="optimal",
                              backend="pallas", overlap=True)
    assert res.converged and res.n_iters == ref.n_iters, (res.n_iters, ref.n_iters)
    x = op.unshard(res.x)
    relres = np.linalg.norm(ad @ x - b) / np.linalg.norm(b)
    assert relres < 1e-6, relres
    print("kernel-backend ecg parity OK")


def check_distributed_ecg_matches_sequential():
    mesh = jax.make_mesh((2, 4), ("node", "proc"))
    a = dg_laplace_2d((8, 6), block=4)
    ad = np.asarray(a.todense(), np.float64)
    rng = np.random.default_rng(1)
    b = rng.standard_normal(a.shape[0])
    res_seq = ecg_solve(lambda X: csr_spmbv(a, X), jnp.asarray(b), t=4, tol=1e-8, max_iters=500)
    for strategy in ("standard", "2step", "3step", "optimal"):
        res, op = distributed_ecg(a, b, mesh, t=4, strategy=strategy, tol=1e-8, max_iters=500)
        assert res.converged, strategy
        assert abs(res.n_iters - res_seq.n_iters) <= 2, (strategy, res.n_iters, res_seq.n_iters)
        x = op.unshard(res.x)
        relres = np.linalg.norm(ad @ x - b) / np.linalg.norm(b)
        assert relres < 1e-6, (strategy, relres)
    print("distributed ecg OK")


def check_tuned_and_col_split():
    """tune="model" end-to-end on devices, and a forced col-split plan
    through the real executor (including the width-1 initial-residual path)."""
    mesh = jax.make_mesh((2, 4), ("node", "proc"))
    rng = np.random.default_rng(3)
    a = dg_laplace_2d((8, 6), block=4)
    ad = np.asarray(a.todense(), np.float64)
    b = rng.standard_normal(a.shape[0])

    res, op = distributed_ecg(a, b, mesh, t=4, strategy="tuned", backend="pallas")
    cfg = op.tuned
    assert cfg is not None and cfg.mode == "model"
    assert cfg.strategy in ("standard", "2step", "3step", "optimal")
    assert op.ell_block == (cfg.br, cfg.bc) and op.overlap == cfg.overlap
    assert op.plan.col_split == cfg.col_split  # applied plan matches config

    # applying a precomputed TunedConfig must honor its col_split verbatim
    from repro.tune import TunedConfig

    cfg2 = TunedConfig(strategy="optimal", br=4, bc=4, kmax=8, overlap=False,
                       backend="jnp", t=4, mode="model", col_split=2)
    op2 = make_distributed_spmbv(a, mesh, t=4, tune=cfg2)
    assert op2.plan.col_split == 2, op2.plan.col_split
    V = rng.standard_normal((a.shape[0], 4))
    W = op2.unshard(jax.jit(op2.matvec_fn())(op2.shard_vector(V)))
    assert np.abs(W - ad @ V).max() < 1e-10
    x = op.unshard(res.x)
    relres = np.linalg.norm(ad @ x - b) / np.linalg.norm(b)
    assert res.converged and relres < 1e-6, (cfg.strategy, relres)

    for t, cs in ((4, 2), (8, 4)):
        V = rng.standard_normal((a.shape[0], t))
        op = make_distributed_spmbv(
            a, mesh, "optimal", t=t, machine=BLUE_WATERS, col_split=cs
        )
        assert op.plan.col_split == cs
        f = jax.jit(op.matvec_fn())
        W = op.unshard(f(op.shard_vector(V)))
        assert np.abs(W - ad @ V).max() < 1e-10, (t, cs)
        v1 = rng.standard_normal((a.shape[0], 1))
        W1 = op.unshard(f(op.shard_vector(v1)))
        assert np.abs(W1 - ad @ v1).max() < 1e-10, (t, cs, "width-1")
    print("tuned + col-split OK")


def check_adaptive_and_auto_t():
    """Adaptive ECG on the shard_map path: a rank-deficient splitting that
    breaks fixed-t must converge with adaptive="reduce", and the reduction
    trace must agree with the sequential solver (same math, same drops).
    t="auto" end-to-end records the selection on result + TunedConfig."""
    mesh = jax.make_mesh((2, 4), ("node", "proc"))
    a = fd_laplace_2d(13)
    n = a.shape[0]
    ad = np.asarray(a.todense(), np.float64)
    t, m = 4, 2
    rng = np.random.default_rng(7)
    b = np.zeros(n)
    b[: (m * n) // t] = rng.standard_normal((m * n) // t)  # t−m zero subdomains

    res_fixed, _ = distributed_ecg(a, b, mesh, t=t, strategy="3step", tol=1e-8)
    assert res_fixed.breakdown and not res_fixed.converged, "fixed t should break down"

    from repro.sparse.csr import csr_spmbv as seq_spmbv

    seq = ecg_solve(lambda X: seq_spmbv(a, X), jnp.asarray(b), t=t, tol=1e-8,
                    max_iters=300, adaptive="reduce")
    res, op = distributed_ecg(a, b, mesh, t=t, strategy="3step", tol=1e-8,
                              max_iters=300, adaptive="reduce")
    assert seq.converged and res.converged
    assert abs(res.n_iters - seq.n_iters) <= 2, (res.n_iters, seq.n_iters)
    # width-aware exchange: the reduction event re-sliced the plan, the tail
    # segment ran at the reduced width, and the wire payload shrank with it
    segs = res.comm_segments
    assert segs is not None and segs[0][0] == t and segs[-1][0] == m, segs
    assert sum(it for _, it in segs) == res.n_iters, (segs, res.n_iters)
    by_full = op.plan.wire_bytes(8)
    by_red = op.plan.at_width(m).wire_bytes(8)
    assert by_red * t == by_full * m, (by_full, by_red)  # exact t_active/t cut
    x = op.unshard(res.x)
    relres = np.linalg.norm(ad @ x - b) / np.linalg.norm(b)
    assert relres < 1e-6, relres
    # reduction traces agree: the dependent directions drop at iteration 1 on
    # both paths, and the active width histories match over the common prefix
    k = min(res.n_iters, seq.n_iters) + 1
    ah_d = np.asarray(res.active_hist)[:k]
    ah_s = np.asarray(seq.active_hist)[:k]
    assert ah_d[0] == t and ah_d[1] == m, ah_d[:2]
    assert np.array_equal(ah_d, ah_s), (ah_d, ah_s)
    h_d = np.asarray(res.res_hist)[:k]
    h_s = np.asarray(seq.res_hist)[:k]
    np.testing.assert_allclose(h_d, h_s, rtol=1e-5, atol=1e-10)

    # t="auto" on the tuned distributed path
    b_full = rng.standard_normal(n)
    res_a, op_a = distributed_ecg(a, b_full, mesh, t="auto", strategy="tuned",
                                  tol=1e-8, max_iters=300, t_candidates=(1, 2, 4))
    assert res_a.converged
    assert res_a.selection is not None and res_a.t == res_a.selection.t
    assert op_a.tuned is not None and op_a.tuned.selection is res_a.selection
    assert res_a.t in (1, 2, 4)
    print("adaptive + auto-t OK")


def check_adaptive_opcode_count():
    """The §3.1 invariant under adaptivity: one full adaptive iteration body
    (gram1 → rank-revealing factorization → packed gram2 → tail → norm)
    lowers to exactly the same all-reduce count as the fixed-width body —
    the pivoted factorization and masking run on replicated t x t data and
    add NO collectives."""
    mesh = jax.make_mesh((2, 4), ("node", "proc"))
    a = dg_laplace_2d((4, 4), block=4)
    op = make_distributed_spmbv(a, mesh, "3step", t=4, machine=BLUE_WATERS)
    apply_a = op.matvec_fn()
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map
    from repro.core.ecg import _chol_inv_apply
    from repro.adaptive import rank_revealing_apply, stagnation_mask
    from repro.adaptive.reduce import ReductionPolicy

    axes = ("node", "proc")
    vspec = op.vec_spec
    gram1 = shard_map(lambda z, az: jax.lax.psum(z.T @ az, axes), mesh=mesh,
                      in_specs=(vspec, vspec), out_specs=P(None, None), check_rep=False)
    gram2 = shard_map(
        lambda pp, rr, ap, apo: jax.lax.psum(
            jnp.concatenate([pp.T @ rr, ap.T @ ap, apo.T @ ap], axis=1), axes
        ),
        mesh=mesh, in_specs=(vspec,) * 4, out_specs=P(None, None), check_rep=False,
    )
    sqnorm = shard_map(lambda v: jax.lax.psum(jnp.vdot(v, v), axes), mesh=mesh,
                       in_specs=P(axes), out_specs=P(), check_rep=False)
    policy = ReductionPolicy()

    def body(z, r, p_old, ap_old, rn, adaptive):
        az = apply_a(z)
        g = gram1(z, az)
        if adaptive:
            (p, ap), _rank, active = rank_revealing_apply(g, z, az)
        else:
            p, ap = _chol_inv_apply(g, z, az)
        packed = gram2(p, r, ap, ap_old)
        c, d, d_old = jnp.split(packed, 3, axis=1)
        x2 = p @ c
        r2 = r - ap @ c
        z2 = ap - p @ d - p_old @ d_old
        if adaptive:
            active = stagnation_mask(c, rn, active, policy)
            z2 = z2 * active.astype(z2.dtype)[None, :]
        return x2, r2, z2, jnp.sqrt(sqnorm(r2.sum(axis=1)))

    sds = jax.ShapeDtypeStruct((op.n_padded, 4), jnp.float64)
    rn_sds = jax.ShapeDtypeStruct((), jnp.float64)
    counts = {}
    for adaptive in (False, True):
        fn = jax.jit(lambda z, r, po, apo, rn, ad=adaptive: body(z, r, po, apo, rn, ad))
        txt = fn.lower(sds, sds, sds, sds, rn_sds).compile().as_text()
        counts[adaptive] = txt.count(" all-reduce(")
    assert counts[False] == counts[True] == 3, counts  # gram1 + gram2 + norm
    print(f"adaptive opcode count OK (all-reduce x{counts[True]} per iteration, unchanged)")


def check_packed_exchange_lowering():
    """The packed-buffer executor's lowered collective structure: the SpMBV
    itself carries ZERO all-reduces at every active width (so the §3.1
    two-psum iteration invariant is preserved verbatim — check_adaptive_
    opcode_count exercises the full body against the same executor), and
    exactly one collective-permute per nonzero rotation offset of the plan
    — packing fused the gathers/scatters, not the rotations."""
    mesh = jax.make_mesh((2, 4), ("node", "proc"))
    a = dg_laplace_2d((8, 6), block=4)
    for strategy in ("standard", "2step", "3step", "optimal"):
        op = make_distributed_spmbv(a, mesh, strategy, t=8, machine=BLUE_WATERS)
        n_perm_plan = sum(1 for s in op.plan.steps if s.offset)
        for ta in (8, 2):
            plan_w = op.plan.at_width(ta)
            n_perm_w = sum(1 for s in plan_w.steps if s.offset)
            sds = jax.ShapeDtypeStruct((op.n_padded, ta), jnp.float64)
            txt = jax.jit(op.matvec_fn(t_active=None if ta == 8 else ta)) \
                .lower(sds).compile().as_text()
            n_ar = txt.count(" all-reduce(")
            n_cp = txt.count(" collective-permute(") + txt.count(
                " collective-permute-start("
            )
            assert n_ar == 0, (strategy, ta, n_ar)
            assert n_cp == n_perm_w, (strategy, ta, n_cp, n_perm_w)
        assert n_perm_plan == sum(1 for s in op.plan.at_width(2).steps if s.offset), (
            strategy, "re-slice must not change the rotation structure",
        )
    print("packed exchange lowering OK (0 all-reduce, 1 collective-permute "
          "per rotation, at full and reduced widths)")


def _permute_payload_elems(txt):
    """Total elements moved by collective-permutes in optimized HLO text —
    the p2p payload a packed solve pays per exchange sweep (sum over the
    operand shapes of every collective-permute / collective-permute-start)."""
    import re

    total = 0
    for line in txt.splitlines():
        m = re.search(
            r" collective-permute(?:-start)?\([a-z0-9]+\[([\d,]+)\]", line
        )
        if m:
            dims = [int(d) for d in m.group(1).split(",")]
            total += int(np.prod(dims))
    return total


def check_packed_retirement():
    """Cross-request width packing on the shard_map path: three requests
    with staggered tolerances solve as ONE enlarged width-12 block solve,
    and each retirement re-slices the exchange —

    * ``comm_segments`` widths strictly decrease (12 → 8 → 4) and every
      request's true residual meets its own tolerance;
    * the packed program's all-reduce count is 4 at EVERY segment width
      (3 body + 1 init — grouping the convergence norm into per-request
      norms is one psum of g floats, not g psums, and narrowing the
      exchange adds no collective);
    * the collective-permute payload (elements moved per sweep, read off
      the lowered HLO operand shapes) strictly drops at each retirement
      width while the permute COUNT stays fixed — re-slicing compacts
      bytes, never the rotation structure;
    * retirement iterations agree with the sequential packed solve on the
      same operator to a small margin (only SpMBV summation order differs;
      after a retirement the Gram is structurally singular, so pivot-order
      decisions amplify last-bit differences — the FD system keeps that
      chaos bounded, where the DG system does not).
    """
    from repro.solver import CommConfig, ECGSolver, SolverConfig

    mesh = jax.make_mesh((2, 4), ("node", "proc"))
    a = fd_laplace_2d(13)
    ad = np.asarray(a.todense(), np.float64)
    rng = np.random.default_rng(7)
    bs = [rng.standard_normal(a.shape[0]) for _ in range(3)]
    tols = [1e-2, 1e-5, 1e-8]

    cfg = SolverConfig(
        t=4, tol=1e-8, max_iters=500, adaptive="rankrev",
        comm=CommConfig(strategy="optimal", machine=BLUE_WATERS),
    )
    solver = ECGSolver.build(a, mesh, cfg)
    results = solver.solve_packed(bs, tols=tols)

    for res, b, tol in zip(results, bs, tols):
        assert bool(res.converged), res.pack
        rnorm = np.linalg.norm(ad @ solver.unshard(res.x) - np.asarray(b))
        assert rnorm <= tol * 1.01, (tol, rnorm)
    iters = [r.n_iters for r in results]
    assert iters == sorted(iters), iters

    segs = results[0].comm_segments
    widths = [w for w, _ in segs]
    assert widths[0] == 12 and len(widths) >= 3, segs
    assert all(w1 > w2 for w1, w2 in zip(widths, widths[1:])), segs

    seq = ECGSolver.build(a, config=cfg).solve_packed(bs, tols=tols)
    for res, sres in zip(results, seq):
        assert abs(res.n_iters - sres.n_iters) <= max(5, sres.n_iters // 3), (
            "distributed retirement diverged from sequential",
            res.n_iters, sres.n_iters,
        )

    # lowered collective structure at each live width the solve visited
    payloads, counts = [], []
    for w in widths:
        txt = solver.packed_lowered_text(tols, width_seg=w)
        n_ar = txt.count(" all-reduce(")
        assert n_ar == 4, (w, f"expected 3 body + 1 init all-reduces, got {n_ar}")
        counts.append(
            txt.count(" collective-permute(")
            + txt.count(" collective-permute-start(")
        )
        payloads.append(_permute_payload_elems(txt))
    assert len(set(counts)) == 1 and counts[0] > 0, (
        "retirement re-slice must not change the rotation structure", counts,
    )
    assert all(p1 > p2 for p1, p2 in zip(payloads, payloads[1:])), (
        "collective-permute payload must drop at each retirement width",
        list(zip(widths, payloads)),
    )
    print(
        "packed retirement OK (widths "
        + " -> ".join(str(w) for w in widths)
        + f"; all-reduce x4 at every width; permute payload "
        + " -> ".join(str(p) for p in payloads)
        + f" elems over {counts[0]} permutes; iters {iters})"
    )


def check_solver_handle():
    """The ECGSolver handle on the shard_map path: ``solve_many`` over 4 RHS
    compiles the loop exactly once (zero retraces after the first solve),
    every solve is bit-identical to a one-shot legacy ``distributed_ecg``
    call, and the §3.1 two-psum-per-iteration invariant holds through the
    handle's compiled program (3 all-reduces in the while body — gram1,
    packed gram2, convergence norm — plus exactly 1 for the initial
    residual norm)."""
    import warnings

    from repro.solver import CommConfig, ECGSolver, SolverConfig

    mesh = jax.make_mesh((2, 4), ("node", "proc"))
    a = dg_laplace_2d((8, 6), block=4)
    n = a.shape[0]
    rng = np.random.default_rng(11)
    bs = [rng.standard_normal(n) for _ in range(4)]

    solver = ECGSolver.build(a, mesh, SolverConfig(
        t=4, tol=1e-8, max_iters=500, comm=CommConfig(strategy="3step"),
    ))
    first = solver.solve(bs[0])
    traces_after_first = solver.stats.traces
    rest = solver.solve_many(bs[1:])
    results = [first] + rest
    assert solver.stats.traces == traces_after_first, (
        "solve_many retraced after the first solve",
        solver.stats.traces, traces_after_first,
    )
    assert solver.stats.solves == 4 and solver.stats.builds == 1

    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        for b, res in zip(bs, results):
            ref, _ = distributed_ecg(a, b, mesh, t=4, strategy="3step",
                                     tol=1e-8, max_iters=500)
            assert res.converged and res.n_iters == ref.n_iters
            assert np.array_equal(np.asarray(res.x), np.asarray(ref.x)), (
                "handle solve is not bit-identical to the one-shot legacy path"
            )
            assert np.array_equal(
                np.asarray(res.res_hist), np.asarray(ref.res_hist),
                equal_nan=True,
            )

    # §3.1 invariant through the handle's compiled program: the while body
    # carries gram1 + packed gram2 + norm = 3 all-reduces (2 psums + the
    # convergence norm), and the init adds exactly one more (r0 norm)
    txt = solver.lowered_text()
    n_ar = txt.count(" all-reduce(")
    assert n_ar == 4, f"expected 3 body + 1 init all-reduces, got {n_ar}"

    # width-segmented adaptive reuse: second solve of the same deficient
    # system replays the cached per-width programs — zero new traces
    t, m = 4, 2
    b_def = np.zeros(n)
    b_def[: (m * n) // t] = rng.standard_normal((m * n) // t)
    s_ad = solver.with_config(policy="reduce")
    assert s_ad.stats.op_reused and s_ad.op is solver.op
    r1 = s_ad.solve(b_def)
    traces = s_ad.stats.traces
    r2 = s_ad.solve(b_def)
    assert s_ad.stats.traces == traces, "adaptive re-solve retraced"
    assert r1.converged and r1.comm_segments == r2.comm_segments
    assert np.array_equal(np.asarray(r1.x), np.asarray(r2.x))
    print("solver handle OK (4-RHS solve_many: 0 retraces, bit-identical to "
          "legacy; 2 psums + norm per iteration through the handle path)")


def _hlo_computations(txt):
    """Split optimized HLO text into {computation_name: [instruction lines]}."""
    comps, cur, lines = {}, None, []
    for raw in txt.splitlines():
        stripped = raw.strip()
        if cur is None:
            if (stripped.startswith("%") or stripped.startswith("ENTRY")) and stripped.endswith("{"):
                cur, lines = stripped.split()[0], []
        elif stripped.startswith("}"):
            comps[cur] = lines
            cur = None
        elif " = " in stripped:
            lines.append(stripped)
    return comps


def _hlo_instr(line):
    """Parse one HLO instruction line -> (name, opcode, operand names).

    Operands are the %names inside the balanced parens right after the
    opcode — attributes (control-predecessors, calls=, sharding) come after
    the operand list and are deliberately excluded, so the def-use graph
    carries data dependencies only.
    """
    import re

    lhs, rhs = line.split(" = ", 1)
    name = lhs.strip().removeprefix("ROOT ").strip()
    rhs = rhs.strip()
    if rhs.startswith("("):  # tuple-shaped result: skip the balanced group
        depth = 0
        for k, ch in enumerate(rhs):
            depth += ch == "("
            depth -= ch == ")"
            if depth == 0:
                break
        rhs = rhs[k + 1:].lstrip()
    elif " " in rhs:  # plain shape token
        rhs = rhs.split(" ", 1)[1]
    i = rhs.find("(")
    opcode = rhs[:i].strip()
    depth = 0
    for j in range(i, len(rhs)):
        depth += rhs[j] == "("
        depth -= rhs[j] == ")"
        if depth == 0:
            break
    return name, opcode, re.findall(r"%[\w.\-]+", rhs[i:j + 1])


def _has_collective_permute_ancestor(comp_lines, target_name):
    """True iff a collective-permute reaches ``target_name`` through the
    def-use graph of one computation (data edges only)."""
    instrs = {}
    for ln in comp_lines:
        name, opcode, ops = _hlo_instr(ln)
        instrs[name] = (opcode, ops)
    seen, todo = set(), [target_name]
    while todo:
        cur = todo.pop()
        if cur in seen or cur not in instrs:
            continue
        seen.add(cur)
        opcode, ops = instrs[cur]
        if cur != target_name and opcode.startswith("collective-permute"):
            return True
        todo.extend(ops)
    return False


def check_method_collective_structure():
    """The tentpole's lowered-HLO gates, per iteration scheme:

    * every scheme's fresh solve program carries exactly 4 all-reduces
      (body psums + convergence norm + initial-residual norm) — sstep's 2
      psums serve s effective iterations, so its collectives/iter really is
      2/s in the compiled program, not just in the spec's accounting;
    * collective-permutes = plan rotations x SpMBV sweeps (classic 2: init
      r0 + body; pipelined 3: init r0 + init AZ0 + body; sstep s+1);
    * the overlap claim is structural, not aspirational: pipelined's packed
      (t, 3t) Gram all-reduce has NO collective-permute ancestor in the
      while body (it depends only on carried state, so XLA is free to run
      it concurrently with the exchange), while classic's same-shaped
      all-reduce provably depends on the body's SpMBV.
    """
    from repro.core.ecg import _ecg_solve
    from repro.core.methods import get_method
    from repro.solver import CommConfig, ECGSolver, SolverConfig

    mesh = jax.make_mesh((2, 4), ("node", "proc"))
    a = dg_laplace_2d((8, 6), block=4)
    ad = np.asarray(a.todense(), np.float64)
    rng = np.random.default_rng(23)
    b = rng.standard_normal(a.shape[0])
    t, s = 4, 2
    seq = {
        m: _ecg_solve(lambda X: csr_spmbv(a, X), jnp.asarray(b), t, tol=1e-8,
                      max_iters=500, method=m, s=s if m == "sstep" else 1)
        for m in ("classic", "pipelined", "sstep")
    }
    texts = {}
    for method in ("classic", "pipelined", "sstep"):
        ms = s if method == "sstep" else 1
        solver = ECGSolver.build(a, mesh, SolverConfig(
            t=t, tol=1e-8, max_iters=500, comm=CommConfig(strategy="3step"),
            method=dict(name=method, s=ms),
        ))
        res = solver.solve(b)
        assert res.converged and res.n_iters == seq[method].n_iters, (
            method, res.n_iters, seq[method].n_iters)
        x = solver.unshard(res.x)
        relres = np.linalg.norm(ad @ x - b) / np.linalg.norm(b)
        assert relres < 1e-6, (method, relres)

        txt = solver.lowered_text()
        texts[method] = txt
        n_ar = txt.count(" all-reduce(")
        assert n_ar == 4, (method, n_ar)
        spec = get_method(method)
        assert spec.psums_per_block(ms) / spec.iters_per_block(ms) == (
            {"classic": 2, "pipelined": 2, "sstep": 2 / s}[method]
        )
        rot = sum(1 for step in solver.op.plan.steps if step.offset)
        n_cp = txt.count(" collective-permute(") + txt.count(
            " collective-permute-start(")
        spmbvs = {"classic": 2, "pipelined": 3, "sstep": s + 1}[method]
        assert n_cp == rot * spmbvs, (method, n_cp, rot, spmbvs)

    # overlap proof on the packed (t, 3t) Gram reduction — it is the only
    # all-reduce in either program with a (t, 3t) result shape
    shape = f"f64[{t},{3 * t}]"
    for method, expect_dep in (("classic", True), ("pipelined", False)):
        found = None
        for cname, lines in _hlo_computations(texts[method]).items():
            for ln in lines:
                if " all-reduce(" not in ln:
                    continue
                name, opcode, _ = _hlo_instr(ln)
                if opcode == "all-reduce" and ln.split(" = ", 1)[1].lstrip().startswith(shape):
                    found = (cname, lines, name)
        assert found is not None, (method, "packed (t,3t) all-reduce not found")
        cname, lines, name = found
        dep = _has_collective_permute_ancestor(lines, name)
        assert dep == expect_dep, (
            method, f"packed Gram all-reduce in {cname}: collective-permute "
            f"ancestor={dep}, expected {expect_dep}")
    print("method collective structure OK (4 all-reduces each; CPs = "
          "rotations x {2,3,s+1}; pipelined packed Gram independent of the "
          "body exchange, classic dependent)")


def check_method_segmented_resume():
    """Width-segmented adaptive solves per scheme on the shard_map path: a
    deficient splitting must reduce t=8 -> 2 under pipelined and sstep and
    match each scheme's own monolithic sequential run exactly (count,
    history, reduction trace)."""
    from repro.core.ecg import _ecg_solve
    from repro.solver import CommConfig, ECGSolver, SolverConfig

    mesh = jax.make_mesh((2, 4), ("node", "proc"))
    a = fd_laplace_2d(13)
    n = a.shape[0]
    ad = np.asarray(a.todense(), np.float64)
    t, m = 8, 2
    rng = np.random.default_rng(7)
    b = np.zeros(n)
    b[: (m * n) // t] = rng.standard_normal((m * n) // t)

    for method, s in (("pipelined", 1), ("sstep", 2)):
        seq = _ecg_solve(lambda X: csr_spmbv(a, X), jnp.asarray(b), t,
                         tol=1e-8, max_iters=300, adaptive="reduce",
                         method=method, s=s)
        assert seq.converged, method
        solver = ECGSolver.build(a, mesh, SolverConfig(
            t=t, tol=1e-8, max_iters=300, comm=CommConfig(strategy="3step"),
            adaptive="reduce", method=dict(name=method, s=s),
        ))
        res = solver.solve(b)
        assert res.converged and res.n_iters == seq.n_iters, (
            method, res.n_iters, seq.n_iters)
        segs = res.comm_segments
        assert segs is not None and segs[0][0] == t and segs[-1][0] == m, (
            method, segs)
        assert sum(it for _, it in segs) == res.n_iters, (method, segs)
        k = res.n_iters + 1
        np.testing.assert_allclose(
            np.asarray(res.res_hist)[:k], np.asarray(seq.res_hist)[:k],
            rtol=1e-5, atol=1e-10)
        assert np.array_equal(np.asarray(res.active_hist)[:k],
                              np.asarray(seq.active_hist)[:k]), method
        x = solver.unshard(res.x)
        relres = np.linalg.norm(ad @ x - b) / np.linalg.norm(b)
        assert relres < 1e-6, (method, relres)
    print("method segmented resume OK (t=8->2 under pipelined and sstep, "
          "matching their monolithic runs)")


def check_rank_methods_structural():
    """tune="model:structural" ranks the three schemes on the real partition
    geometry: the table decomposes exactly, sstep amortizes synchronization,
    pipelined never syncs more than classic."""
    from repro.tune import rank_methods

    a = dg_laplace_2d((8, 6), block=4)
    best, table = rank_methods(a, 4, n_nodes=2, ppn=4, s=2,
                               mode="model:structural")
    assert set(table) == {"classic", "pipelined", "sstep"}
    for row in table.values():
        assert abs(row["iter_s"] - (row["sync_s"] + row["spmbv_s"] + row["local_s"])) < 1e-18
    assert table["sstep"]["sync_s"] < table["classic"]["sync_s"]
    assert table["pipelined"]["sync_s"] <= table["classic"]["sync_s"]
    assert best == min(table, key=lambda k: table[k]["iter_s"])
    print(f"rank_methods structural OK (best={best})")


def check_two_psums_per_iteration():
    """The §3.1 discipline: the iteration body must carry exactly 2 psums
    (plus the convergence-norm reduction) — inspect the lowered HLO.  Count
    the ``all-reduce(`` opcode, not the bare substring: each instruction's
    SSA name (e.g. ``%all-reduce.1``) would otherwise double-count."""
    mesh = jax.make_mesh((2, 4), ("node", "proc"))
    a = dg_laplace_2d((4, 4), block=4)
    op = make_distributed_spmbv(a, mesh, "3step", t=4, machine=BLUE_WATERS)
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map
    from repro.kernels import fused_gram

    def n_allreduce(txt):
        return txt.count(" all-reduce(")

    vspec = op.vec_spec
    sds = jax.ShapeDtypeStruct((op.n_padded, 4), jnp.float64)
    gram1 = shard_map(
        lambda z, az: jax.lax.psum(z.T @ az, ("node", "proc")),
        mesh=mesh, in_specs=(vspec, vspec), out_specs=P(None, None), check_rep=False,
    )
    txt = jax.jit(gram1).lower(sds, sds).compile().as_text()
    assert n_allreduce(txt) == 1, (
        f"fused gram should lower to one all-reduce, got {n_allreduce(txt)}"
    )
    # kernel-backed gram2 keeps the same collective structure: the packed
    # [PᵀR | APᵀAP | AP_oldᵀAP] product feeds exactly ONE psum
    gram2 = shard_map(
        lambda pp, rr, ap, apo: jax.lax.psum(
            fused_gram(pp, rr, ap, apo), ("node", "proc")
        ),
        mesh=mesh, in_specs=(vspec,) * 4, out_specs=P(None, None), check_rep=False,
    )
    txt2 = jax.jit(gram2).lower(sds, sds, sds, sds).compile().as_text()
    assert n_allreduce(txt2) == 1, (
        f"kernel-backed gram2 should lower to one all-reduce, got {n_allreduce(txt2)}"
    )
    print("psum fusion OK")



def check_preconditioned_solver():
    """Preconditioned ECG on the shard_map path.

    * classic + {none, block_jacobi, chebyshev}: the lowered program still
      carries exactly 4 all-reduces (2 body psums — gram1 and the packed
      preconditioned gram2 — + body norm + init norm).  The preconditioner
      applies add ZERO collectives: block-Jacobi solves rank-local blocks,
      Chebyshev only adds SpMBVs (point-to-point exchanges).
    * block_jacobi / chebyshev cut iterations vs none at the same t.
    * precondition="none" stays bit-identical to the unpreconditioned
      handle.
    * the iteration-varying "inexact" kind converges on classic (flexible
      residual reseed) and sstep (reseeds every block), and solutions hit
      the true residual tolerance.
    """
    from repro.solver import ECGSolver, MethodConfig, SolverConfig

    mesh = jax.make_mesh((2, 4), ("node", "proc"))
    a = fd_laplace_2d(14)  # 196 rows
    b = np.random.default_rng(0).standard_normal(a.shape[0])
    ad = np.asarray(a.todense())
    x_true = np.linalg.solve(ad, b)
    base_cfg = SolverConfig(t=4, tol=1e-10, max_iters=400)

    iters = {}
    for kind in ("none", "block_jacobi", "chebyshev"):
        solver = ECGSolver.build(
            a, mesh, base_cfg.replace(precondition=kind)
        )
        res = solver.solve(b)
        assert res.converged, f"classic+{kind} did not converge"
        np.testing.assert_allclose(solver.op.unshard(res.x), x_true, rtol=1e-6)
        iters[kind] = res.n_iters
        n_ar = solver.lowered_text().count(" all-reduce(")
        assert n_ar == 4, (
            f"classic+{kind}: expected 3 body + 1 init all-reduces "
            f"(preconditioning must not add collectives), got {n_ar}"
        )
        if kind == "none":
            plain = ECGSolver.build(a, mesh, base_cfg).solve(b)
            assert np.array_equal(np.asarray(res.x), np.asarray(plain.x)), (
                "precondition='none' is not bit-identical to unpreconditioned"
            )
            assert res.n_iters == plain.n_iters
    assert iters["block_jacobi"] < iters["none"], iters
    assert iters["chebyshev"] < iters["none"], iters

    for mc in (MethodConfig(name="classic"), MethodConfig(name="sstep", s=2)):
        solver = ECGSolver.build(
            a, mesh,
            base_cfg.replace(method=mc).replace(precondition="inexact"),
        )
        res = solver.solve(b)
        assert res.converged, f"{mc.name}+inexact did not converge"
        np.testing.assert_allclose(solver.op.unshard(res.x), x_true, rtol=1e-6)

    print(
        "preconditioned solver OK (4 all-reduces each; iters "
        + ", ".join(f"{k}={v}" for k, v in iters.items())
        + ")"
    )


def check_chebyshev_lambda_max_p2p():
    """The Chebyshev λmax power iteration runs through the width-1 SpMBV
    sub-plan, never a densified or host-looped operator:

    * the lowered power-step program carries ZERO all-reduces (the Rayleigh
      quotient and norms reduce host-side after unshard) and exactly the
      width-1 plan's collective-permutes — i.e. the estimate adds only p2p
      exchange, the same kind (and count) of collective as one SpMBV sweep;
    * the distributed estimate agrees with the sequential one (identical
      deterministic start vector, same iteration count — only SpMBV
      summation order differs);
    * a col_split > 1 plan re-slices to width 1 through its rebuild closure
      (the path a nodal-optimal operator takes at build time).
    """
    from repro.precondition.chebyshev import (
        distributed_power_matvec,
        estimate_lambda_max,
    )

    mesh = jax.make_mesh((2, 4), ("node", "proc"))
    a = dg_laplace_2d((8, 6), block=4)
    lam_seq = estimate_lambda_max(a)
    for strategy, col_split in (("2step", 1), ("optimal", 2)):
        op = make_distributed_spmbv(
            a, mesh, strategy, t=4, machine=BLUE_WATERS, col_split=col_split
        )
        plan1 = op.plan.at_width(1)
        n_perm = sum(1 for s in plan1.steps if s.offset)
        sds = jax.ShapeDtypeStruct((op.n_padded, 1), jnp.float64)
        txt = jax.jit(op.matvec_fn(t_active=1)).lower(sds).compile().as_text()
        n_ar = txt.count(" all-reduce(")
        n_cp = txt.count(" collective-permute(") + txt.count(
            " collective-permute-start(")
        assert n_ar == 0, (strategy, "power step must issue no all-reduce", n_ar)
        assert n_cp == n_perm, (strategy, n_cp, n_perm)
        lam_dist = estimate_lambda_max(a, matvec=distributed_power_matvec(op))
        assert abs(lam_dist - lam_seq) <= 1e-9 * abs(lam_seq), (
            strategy, lam_dist, lam_seq,
        )
    print(f"chebyshev lambda-max p2p OK (0 all-reduce, plan-exact permutes, "
          f"lmax={lam_seq:.6f} sequential == distributed)")


if __name__ == "__main__":
    assert len(jax.devices()) == 8
    check_spmbv_strategies()
    check_distributed_ecg_matches_sequential()
    check_kernel_backend_ecg_parity()
    check_tuned_and_col_split()
    check_adaptive_and_auto_t()
    check_adaptive_opcode_count()
    check_packed_exchange_lowering()
    check_packed_retirement()
    check_two_psums_per_iteration()
    check_solver_handle()
    check_preconditioned_solver()
    check_method_collective_structure()
    check_method_segmented_resume()
    check_rank_methods_structural()
    check_chebyshev_lambda_max_p2p()
    print("ALL DISTRIBUTED CHECKS PASSED")
