"""Pallas TPU kernel: Block-ELL sparse-matrix x block-vector product.

TPU adaptation of the paper's SpMBV hot spot (DESIGN.md §2): instead of the
CPU/GPU scalar-gather CSR formulation, the matrix is stored as dense
(br x bc) tiles in Block-ELL layout (fixed ``kmax`` tiles per block row —
DG/FE matrices are naturally block-uniform) so every inner step is a dense
(br x bc) @ (bc x t) MXU matmul.

Scalar-prefetched block-column indices drive the ``index_map`` of the V
operand, so the needed (bc, t) slice of V streams HBM -> VMEM exactly once
per nonzero tile; the output tile is revisited across the k grid dimension
and accumulated in VMEM.

Alignment notes (TPU):
  - br, bc should be multiples of (8, 128) for f32 tiles; t is padded to the
    lane width by the ops wrapper.
  - grid = (nbr, kmax), k innermost so the output tile stays resident.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(idx_ref, blocks_ref, v_ref, out_ref):
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    a = blocks_ref[0, 0]          # (br, bc)
    vv = v_ref[0]                 # (bc, t)
    out_ref[0] += jnp.dot(a, vv, preferred_element_type=out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def bsr_spmbv_pallas(blocks, indices, v, *, interpret: bool = False):
    """blocks (nbr, kmax, br, bc); indices (nbr, kmax); v (nbc*bc, t)."""
    nbr, kmax, br, bc = blocks.shape
    t = v.shape[1]
    v3 = v.reshape(-1, bc, t)

    grid = (nbr, kmax)
    out = pl.pallas_call(
        _kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, br, bc), lambda i, k, idx: (i, k, 0, 0)),
                pl.BlockSpec((1, bc, t), lambda i, k, idx: (idx[i, k], 0, 0)),
            ],
            out_specs=pl.BlockSpec((1, br, t), lambda i, k, idx: (i, 0, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((nbr, br, t), v.dtype),
        interpret=interpret,
    )(indices, blocks, v3)
    return out.reshape(nbr * br, t)
