"""Measured microbenchmark mode for the setup-time autotuner.

When the :class:`~repro.core.machines.MachineParams` constants are in doubt
(new machine, virtualized hosts, unknown NIC contention), the tuner can
*measure* instead of model: build the candidate distributed SpMBV operators
on the real mesh, time a few applications of each, and take the argmin.
This is the paper's "four trial SpMBVs at communicator-setup time" tuning,
extended to the tile-shape and overlap axes.

To keep setup cost bounded the search is coordinate descent rather than the
full grid: strategies first (blocking, reference tile), then tile shapes
under the winning strategy, then blocking-vs-overlap for the winning pair —
4 + |tiles| + 2 operator builds instead of 4·|tiles|·2.
``benchmarks/tuner_sweep.py`` measures the *full* grid to audit both the
models and this descent against the exhaustive answer.
"""

from __future__ import annotations

import numpy as np

from repro.sparse.partition import PartitionedMatrix, partition_csr


def measure_config(
    a,
    mesh,
    t: int,
    strategy: str,
    ell_block,
    overlap: bool,
    backend: str = "pallas",
    machine=None,
    pm: PartitionedMatrix | None = None,
    repeats: int = 3,
    seed: int = 0,
) -> float:
    """Wall microseconds per distributed SpMBV application for one config
    (fixed operand ``seed``, median of ``repeats`` — reproducible on hosts)."""
    import jax

    # the one warmup+median timer shared with the benchmark sweeps, so
    # tuner measurements and benchmark rows stay comparable
    from repro.analysis.ecg_bench import _timeit
    from repro.sparse.spmbv import _make_distributed_spmbv

    op = _make_distributed_spmbv(
        a, mesh, strategy, t=t, machine=machine, pm=pm,
        backend=backend, overlap=overlap, ell_block=ell_block,
    )
    f = jax.jit(op.matvec_fn())
    rng = np.random.default_rng(seed)
    v = op.shard_vector(rng.standard_normal((a.shape[0], t)))
    return _timeit(f, v, repeats=repeats)


def measure_dispatch_overhead(
    mesh,
    rows: int = 64,
    width: int = 4,
    chain: tuple[int, int] = (2, 16),
    repeats: int = 7,
    dtype=None,
) -> float:
    """Measured seconds per executor dispatch (one pack / ppermute / unpack
    op), the constant the structural cost model charges as
    ``MachineParams.dispatch_overhead``.

    Times two jitted shard_map programs that chain the packed executor's
    primitive triple — ``halo_pack`` → ``lax.ppermute`` → ``halo_unpack`` —
    ``chain[0]`` and ``chain[1]`` times over a tiny (rows, width) buffer,
    with a data dependency between links so XLA cannot elide or reorder
    them.  The buffer is deliberately small: the byte terms are negligible,
    so the wall-time *slope* over the extra links is pure per-op dispatch
    cost.  Returns the slope divided by 3 ops per link (clamped to a tiny
    positive floor so a noisy host never yields a non-positive constant).

    Feed the result back with
    ``dataclasses.replace(machine, dispatch_overhead=measured)`` to
    calibrate ``tune="model:structural"``; ``benchmarks/comm_sweep.py``
    records it in ``BENCH_comm_sweep.json``.
    """
    import jax
    import jax.numpy as jnp
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.analysis.ecg_bench import _timeit
    from repro.kernels.halo_pack.ops import halo_pack, halo_unpack

    dtype = dtype or np.float64
    p = int(mesh.devices.size)
    perm = [(i, (i + 1) % p) for i in range(p)]
    gidx = jnp.arange(rows, dtype=jnp.int32)
    spos = jnp.arange(rows, dtype=jnp.int32)

    def chain_fn(m):
        def per_device(x):
            for _ in range(m):
                buf = halo_pack(x, gidx)
                buf = jax.lax.ppermute(buf, ("node", "proc"), perm)
                stage = jnp.zeros((rows + 1, x.shape[1]), x.dtype)
                stage = halo_unpack(stage, buf, spos)
                x = stage[:rows]  # dependency: next link waits on this one
            return x
        return jax.jit(shard_map(
            per_device, mesh=mesh, in_specs=P(), out_specs=P(),
            check_rep=False,
        ))

    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((rows, width)), dtype)
    m_lo, m_hi = chain
    us_lo = _timeit(chain_fn(m_lo), x, repeats=repeats)
    us_hi = _timeit(chain_fn(m_hi), x, repeats=repeats)
    per_op_s = (us_hi - us_lo) * 1e-6 / ((m_hi - m_lo) * 3)
    return max(per_op_s, 1e-9)


def tune_measured(
    a,
    mesh,
    t: int,
    backend: str = "pallas",
    tiles=None,
    machine=None,
    pm: PartitionedMatrix | None = None,
    repeats: int = 3,
):
    """Coordinate-descent measured tuning; returns a TunedConfig."""
    from repro.core.models import STRATEGIES
    from repro.tune.autotune import DEFAULT_TILES, TunedConfig, tile_stats

    tiles = tiles or DEFAULT_TILES
    n_nodes, ppn = mesh.devices.shape
    pm = pm or partition_csr(a, n_nodes * ppn)
    rmax = pm.part.max_local_rows
    measured: dict[str, float] = {}

    def probe(strategy, tile, overlap):
        key = f"{strategy}/{tile[0]}x{tile[1]}/{'overlap' if overlap else 'blocking'}"
        if key not in measured:
            measured[key] = measure_config(
                a, mesh, t, strategy, tile, overlap,
                backend=backend, machine=machine, pm=pm, repeats=repeats,
            )
        return measured[key]

    ref_tile = (8, 8) if rmax >= 8 else (rmax, rmax)
    strategy = min(STRATEGIES, key=lambda s: probe(s, ref_tile, False))

    tile = ref_tile
    if backend == "pallas":
        cand = [(br, bc) for br, bc in tiles if br <= rmax and bc <= rmax] or [ref_tile]
        tile = min(cand, key=lambda tl: probe(strategy, tl, False))

    overlap = min((False, True), key=lambda ov: probe(strategy, tile, ov))

    ts = tile_stats(pm, *tile)
    return TunedConfig(
        strategy=strategy,
        br=tile[0],
        bc=tile[1],
        kmax=ts.kmax,
        overlap=overlap,
        backend=backend,
        t=t,
        mode="measure",
        machine=machine,
        predicted={"measured_us": dict(measured)},
    )
