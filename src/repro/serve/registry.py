"""Operator registry: build each ECGSolver session exactly once.

The registry is the serving layer's answer to the paper's §4 premise —
setup cost (partitioning, exchange planning, tuning, compilation) is paid
once per *operator*, then amortized across every request that names it.
Operators are keyed by content fingerprint
(:func:`~repro.serve.fingerprint_csr`), so clients never hold handles:
re-sending the same CSR (even with rows assembled in a different entry
order) lands on the already-built, already-compiled session.

Eviction is LRU under a byte budget counted in CSR bytes
(:func:`~repro.serve.operator_nbytes`); the most recently used entry
always survives, even when it alone exceeds the budget — a server must
never evict the session it is about to solve with.

Every build consults the :class:`~repro.serve.cache.WarmStartCache` (when
configured): a hit feeds the persisted ``TunedConfig``/``TSelection``
back through ``SolverConfig.replace(tuned=..., select=...)``, so the
rebuilt session skips its convergence probes and tuner evaluation — a
restarted server re-tunes **zero** operators (gated in
``benchmarks/serve_bench.py``); a miss stores this build's outcome for
the next restart.

Builds on the Pallas kernel path also produce CSR→Block-ELL conversion
artifacts (``ECGSolver.conversion``).  The registry keeps the *device
arrays* in a small in-memory side table that survives LRU eviction of the
session itself — a re-admitted evicted operator rebuilds with **zero
re-conversions** (``conv_reused``) — and persists the JSON tile-analysis
*meta* in the warm-start cache, so even a restarted process skips the
analysis pass (``conv_analyzed=False``).  Both are gated in
``benchmarks/serve_bench.py``.
"""

from __future__ import annotations

import dataclasses
import time
from collections import OrderedDict

from repro.observe.tracer import coerce_tracer
from repro.serve.cache import WarmStartCache, config_digest, mesh_tag
from repro.serve.config import ServeConfig
from repro.serve.fingerprint import fingerprint_csr, operator_nbytes


@dataclasses.dataclass
class _Entry:
    solver: object
    nbytes: int


class OperatorRegistry:
    """Fingerprint-keyed LRU of built :class:`~repro.solver.ECGSolver`
    sessions (see module docstring).

    Counters: ``hits`` / ``misses`` (lookups vs builds), ``evictions``,
    and per-build records ``build_records`` — dicts with the fingerprint,
    whether the warm-start cache answered (``warm``), and the build wall
    time (``build_s``, the cold-vs-warm latency the benchmark reports).
    """

    #: cap of the in-memory conversion-array side table — device arrays of
    #: the Block-ELL layout are a few× the CSR bytes, so the table is kept
    #: small and LRU'd independently of the session registry
    _CONV_CAP = 64

    def __init__(self, config: ServeConfig | None = None, mesh=None,
                 tracer=None):
        self.config = ServeConfig.coerce(config)
        self.mesh = mesh
        self._tracer = coerce_tracer(tracer)
        self._entries: OrderedDict[str, _Entry] = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.build_records: list[dict] = []
        self._conv_arrays: OrderedDict[str, dict] = OrderedDict()
        self._cache = (
            WarmStartCache(self.config.cache_dir)
            if self.config.cache_dir is not None else None
        )
        self._cfg_digest = config_digest(self.config.solver)
        self._mesh_tag = mesh_tag(mesh)

    # ------------------------------------------------------------- lookup
    def fingerprint(self, a) -> str:
        return fingerprint_csr(a)

    def get(self, a, fingerprint: str | None = None):
        """Return ``(fingerprint, solver)`` for operator ``a``, building
        (and possibly evicting) on a miss."""
        key = fingerprint if fingerprint is not None else fingerprint_csr(a)
        entry = self._entries.get(key)
        if entry is not None:
            self.hits += 1
            self._tracer.counter("registry.hits", self.hits,
                                 fingerprint=key[:12])
            self._entries.move_to_end(key)
            return key, entry.solver
        self.misses += 1
        self._tracer.counter("registry.misses", self.misses,
                             fingerprint=key[:12])
        solver, warm, build_s = self._build(a, key)
        self._entries[key] = _Entry(solver=solver, nbytes=operator_nbytes(a))
        self.build_records.append(dict(
            fingerprint=key, warm=warm, build_s=build_s,
            n=int(a.shape[0]), t=int(solver.t),
            conv_analyzed=bool(solver.stats.conv_analyzed),
            conv_reused=bool(solver.stats.conv_reused),
        ))
        self._evict()
        return key, solver

    # ------------------------------------------------------------- builds
    def _build(self, a, key: str):
        from repro.solver import ECGSolver

        cfg = self.config.solver
        warm = False
        conv_meta = None
        if self._cache is not None:
            warm, tuned, select, conv_meta = self._cache.load(
                key, self._cfg_digest, self._mesh_tag
            )
            overrides = {}
            if tuned is not None:
                overrides["tuned"] = tuned
            if select is not None:
                overrides["select"] = select
            if overrides:
                cfg = cfg.replace(**overrides)
        conversion = None
        conv_arrays = self._conv_arrays.get(key)
        if conv_arrays is not None or conv_meta is not None:
            conversion = dict(arrays=conv_arrays, meta=conv_meta)
        # build_s keeps its own perf_counter timing (it predates the
        # tracer and feeds the warm-speedup benchmark gate); the tracer
        # gets the same interval as a serve/build span — nested build-
        # phase spans come from the solver's own instrumentation
        with self._tracer.span("serve/build", cat="serve",
                               fingerprint=key[:12], warm=warm):
            t0 = time.perf_counter()
            solver = ECGSolver.build(a, self.mesh, cfg,
                                     conversion=conversion,
                                     tracer=self._tracer)
            build_s = time.perf_counter() - t0
        self._tracer.counter(
            "registry.builds", len(self.build_records) + 1, warm=warm
        )
        self._harvest_conversion(key, solver, warm, conv_meta)
        if self._cache is not None and not warm:
            self._cache.store(
                key, self._cfg_digest, self._mesh_tag,
                solver.tuned, solver.selection,
                conversion=self._solver_conv_meta(solver),
            )
        return solver, warm, build_s

    @staticmethod
    def _solver_conv_meta(solver):
        return None if solver.conversion is None else solver.conversion["meta"]

    def _harvest_conversion(self, key: str, solver, warm: bool, conv_meta):
        """Remember a build's Block-ELL artifacts: device arrays in the
        in-memory side table (survives session eviction), tile meta in the
        warm-start cache (survives restarts — stored as an in-place upgrade
        when a pre-conversion warm entry lacked it)."""
        if solver.conversion is None:
            return
        self._conv_arrays[key] = solver.conversion["arrays"]
        self._conv_arrays.move_to_end(key)
        while len(self._conv_arrays) > self._CONV_CAP:
            self._conv_arrays.popitem(last=False)
        if self._cache is not None and warm and conv_meta is None:
            self._cache.store(
                key, self._cfg_digest, self._mesh_tag,
                solver.tuned, solver.selection,
                conversion=solver.conversion["meta"],
            )

    # ----------------------------------------------------------- eviction
    def _evict(self):
        budget = self.config.registry_bytes
        while len(self._entries) > 1 and self.total_bytes > budget:
            self._entries.popitem(last=False)  # oldest-used first
            self.evictions += 1

    # -------------------------------------------------------------- state
    @property
    def total_bytes(self) -> int:
        return sum(e.nbytes for e in self._entries.values())

    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, fingerprint: str) -> bool:
        return fingerprint in self._entries

    def fingerprints(self) -> list[str]:
        """Resident fingerprints, least- to most-recently used."""
        return list(self._entries)

    def stats(self) -> dict:
        """JSON-safe counter snapshot (composes the per-session
        :class:`~repro.solver.handle.SolverStats` of every resident
        solver)."""
        return dict(
            hits=self.hits, misses=self.misses, evictions=self.evictions,
            resident=len(self._entries), resident_bytes=self.total_bytes,
            builds=[dict(r) for r in self.build_records],
            warm_builds=sum(1 for r in self.build_records if r["warm"]),
            cold_builds=sum(1 for r in self.build_records if not r["warm"]),
            conv_analyzed=sum(
                1 for r in self.build_records if r.get("conv_analyzed")
            ),
            conv_reused=sum(
                1 for r in self.build_records if r.get("conv_reused")
            ),
            conv_resident=len(self._conv_arrays),
            solver_traces={
                f: e.solver.stats.traces for f, e in self._entries.items()
            },
            solver_solves={
                f: e.solver.stats.solves for f, e in self._entries.items()
            },
        )
