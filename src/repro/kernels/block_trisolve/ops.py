"""Public op: batched block-Cholesky solve (Pallas on TPU, oracle elsewhere).

The apply kernel of the block-Jacobi preconditioner: given per-block lower
Cholesky factors of ``blockdiag(A)``, solve every ``L Lᵀ y = x`` in one
batched dispatch.  Dispatch follows the repo-wide convention
(:func:`repro.kernels.dispatch.resolve_dispatch`): compiled Pallas on TPU,
warn-once jnp oracle on GPU, interpret-mode when forced off-TPU.
"""

from __future__ import annotations

from repro.kernels.block_trisolve.kernel import block_trisolve_pallas
from repro.kernels.block_trisolve.ref import block_trisolve_ref
from repro.kernels.dispatch import resolve_dispatch


def block_trisolve(l, x, use_pallas: bool | None = None):
    """Solve ``L[i] L[i]ᵀ y[i] = x[i]`` for every block.

    l: (nb, bs, bs) lower Cholesky factors; x: (nb, bs, t) → (nb, bs, t).
    """
    use_pallas, interpret = resolve_dispatch("block_trisolve", use_pallas)
    if use_pallas:
        return block_trisolve_pallas(l, x, interpret=interpret)
    return block_trisolve_ref(l, x)
