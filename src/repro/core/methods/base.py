"""Iteration-scheme abstraction for the ECG engine.

One ECG configuration = one :class:`MethodSpec` (the *scheme*: which
collectives fire per iteration and what the loop carry holds) bound to one
:class:`MethodContext` (the *plumbing*: the SpMBV operator, the reduction
closures, the splitting, the adaptive policy).  ``repro.core.ecg.
make_ecg_runner`` builds the context once and delegates the ``init``/``step``
closures to the spec — the guarded while-loop, convergence condition, and
result finalization stay method-agnostic in the driver.

Three schemes ship (see their modules for the per-iteration maths):

* :mod:`~repro.core.methods.classic`   — the paper's §3.1 two-psum form.
* :mod:`~repro.core.methods.pipelined` — same collectives, but the packed
  Gram reduction is data-independent of the next SpMBV (AZ recurrence), so
  the compiler overlaps it with the exchange.
* :mod:`~repro.core.methods.sstep`     — s SpMBV sweeps per collective
  *pair*: 2 psums per s iterations, with the pivoted rank-revealing
  factorization as the mandatory stability safeguard.

Every spec also carries its **collective accounting**
(:meth:`MethodSpec.psums_per_block` / :meth:`~MethodSpec.iters_per_block` /
:meth:`~MethodSpec.psum_payload_floats`): the synchronization term of the
tuner's cost model (``repro.tune.method_sync_cost``) and the lowered-HLO
gates in ``tests/dist_worker.py`` both read the *same* numbers, so the model
and the compiled collective structure cannot drift apart.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp


def _chol_inv_apply(g: jax.Array, *mats: jax.Array, eps: float = 0.0):
    """Given G = CᵀC, return [M C⁻¹ for M in mats] via triangular solves."""
    t = g.shape[0]
    if eps:
        g = g + eps * jnp.eye(t, dtype=g.dtype)
    c = jnp.linalg.cholesky(g, upper=True)  # G = CᵀC with C upper-triangular
    outs = []
    for m in mats:
        # solve Y C = M  =>  Cᵀ Yᵀ = Mᵀ  (lower-triangular solve)
        y = jax.scipy.linalg.solve_triangular(c.T, m.T, lower=True).T
        outs.append(y)
    return outs


def _apply_vec(a_apply: Callable, v: jax.Array, t: int) -> jax.Array:
    """Apply the SpMBV operator to a single vector as a width-1 block.

    Used once, for the initial residual (Alg 3 line 1).  A width-1 SpMV costs
    t× fewer flops and bytes than the old formulation, which embedded v in a
    zero-padded (n, t) block and multiplied all t columns.
    """
    del t  # kept in the signature for call-site clarity; width is always 1
    return a_apply(v[:, None])[:, 0]


@dataclasses.dataclass(frozen=True)
class MethodContext:
    """Everything a :class:`MethodSpec` needs to build its loop closures.

    The reduction closures (``gram1``/``gram2``/``sqnorm``) already wrap
    their collective (identity single-shard, fused shard_map psum
    distributed); ``tail`` is the local X/R/Z update.  ``a_apply_masked``
    and ``use_mask`` carry the width-compacted exchange of the segmented
    solver; ``split_fn`` is T_{r,t}.  ``rank_rtol`` overrides the pivot
    threshold of method-mandated rank-revealing factorizations (s-step);
    None defers to the policy's threshold or the dtype default.

    ``precond`` is the preconditioner apply ``M⁻¹ₖ: (V, k) -> (n, t)`` (None
    = unpreconditioned); when set, schemes orthogonalize the preconditioned
    directions W = M⁻¹AP through ``gram2p`` — the 5-operand packed reduction
    ``[PᵀR | APᵀW | AP_oldᵀW]``, still exactly one psum, so each scheme's
    declared collective structure survives preconditioning.

    ``precond_reseed`` (classic only) reseeds the direction chain from the
    preconditioned residual every that-many iterations.  The classic chain
    ``Z' = W − Pd − P_old d_old`` never re-reads the residual, so an
    iteration-*varying* M⁻¹ₖ knocks it off the Krylov rails permanently —
    the truncated-flexible failure mode of Notay (SISC 22(4), 2000); the
    periodic reseed ``Z' = M⁻¹ₖR`` is the flexible restart that re-acquires
    the lost error components, and costs zero extra collectives (the next
    iteration's Gram/rank-revealing step absorbs the unorthogonalized
    seed).  The s-step scheme reseeds from the residual every block by
    construction and never needs it; pipelined cannot reseed at all (an
    in-loop SpMBV would be needed to rebuild the AZ recurrence).

    ``groups`` (classic only) is a :class:`~repro.adaptive.GroupSpec`
    describing a *packed* multi-RHS solve: ``t`` becomes the total width
    ``n_groups · t_each``, ``init`` takes (n, n_groups) operands, and each
    group converges against its own tolerance and retires (R and Z slabs
    zeroed) independently.  ``sqnorm_cols`` is the matching per-column
    squared-norm reduction ``(n, g) -> (g,)`` — it *replaces* the scalar
    ``sqnorm`` collective in group mode (one psum of g floats instead of
    one float), so the scheme's collective count is unchanged.
    """

    t: int
    s: int
    max_iters: int
    policy: object
    use_mask: bool
    chol_eps: float
    reorth: bool
    rank_rtol: float | None
    backend: str
    a_apply: Callable
    a_apply_masked: Callable | None
    split_fn: Callable
    gram1: Callable
    gram2: Callable
    sqnorm: Callable
    tail: Callable
    precond: Callable | None = None
    gram2p: Callable | None = None
    precond_reseed: int | None = None
    groups: object | None = None
    sqnorm_cols: Callable | None = None


class MethodSpec:
    """One iteration scheme: loop closures + collective accounting.

    Implementations override :meth:`build` (returning ``(init, step)``
    closures over a :class:`MethodContext`) and the accounting methods when
    they deviate from the classic 2-psums-per-iteration shape.
    ``overlaps_gram`` declares that the packed Gram reduction is issued
    data-independently of the SpMBV exchange (the pipelining invariant the
    HLO reachability gate asserts).
    """

    name: str = "?"
    overlaps_gram: bool = False

    # ------------------------------------------------------------ closures
    def validate(self, ctx: MethodContext) -> None:
        """Raise ``ValueError`` for context options this scheme cannot run."""
        if ctx.s != 1:
            raise ValueError(
                f"method {self.name!r} has no inner-step count; s={ctx.s} "
                "only applies to method 'sstep'"
            )
        if ctx.reorth:
            raise ValueError(
                "reorth (per-block Cholesky-QR2) only applies to method 'sstep'"
            )

    def build(self, ctx: MethodContext):
        """Return ``(init, step)``: ``init(b, x0) -> carry`` and one raw,
        unguarded ``step(carry) -> carry`` of this scheme."""
        raise NotImplementedError

    # ---------------------------------------------------------- accounting
    def iters_per_block(self, s: int = 1) -> int:
        """SpMBV sweeps amortized by one ``step`` call (s for s-step)."""
        return 1

    def psums_per_block(self, s: int = 1, reorth: bool = False) -> int:
        """Allreduce-shaped collectives one ``step`` call issues (the
        convergence-norm reduction is excluded — identical across schemes)."""
        return 2

    def psum_payload_floats(self, t: int, s: int = 1, reorth: bool = False) -> int:
        """Total floats those psums reduce (t² + 3t² for the classic shape)."""
        return 4 * t * t

    def collectives_per_iteration(self, s: int = 1, reorth: bool = False) -> float:
        """Psums per *effective* iteration — the number the tuner's
        synchronization term charges and the HLO gates assert."""
        return self.psums_per_block(s, reorth) / self.iters_per_block(s)
