"""Request queue + batching policy: coalesce single-RHS traffic per operator.

Every serving request is one ``(operator, b)`` pair, and every registered
session is a compiled **(n, t) block** program — the enlargement already
*is* the batch.  The queue's job is therefore not to pack columns (mixing
requests into one splitting would entangle their Gram matrices and break
per-request bit-identity) but to:

* group pending requests by operator fingerprint, so consecutive solves
  reuse one compiled program with zero retraces (each request's RHS is
  split to the session's compiled width ``t`` — no shape ever changes);
* deduplicate identical ``(operator, b, x0)`` payloads — concurrent
  clients asking for the same solve share one result, bit-identical by
  construction;
* dispatch each group through ``ECGSolver.solve_many`` — the handle
  enqueues every solve on the device before the first host sync, so the
  host-side finalize of request *i* overlaps the device compute of
  request *i+1*;
* apply backpressure: a bounded pending queue that rejects with the typed
  :class:`ServeOverloaded` instead of growing without bound.

Batches close on three triggers: a per-operator group reaching
``max_batch`` distinct payloads (checked at ``submit``), the oldest
pending request aging past ``max_wait_s`` (checked at ``submit``;
disabled at the default ``0``), or an explicit ``flush()``.
"""

from __future__ import annotations

import dataclasses
import hashlib
import time
from collections import OrderedDict

import numpy as np


class ServeOverloaded(RuntimeError):
    """Raised by ``submit`` when the pending queue is at ``max_pending``.

    The typed rejection is the backpressure contract: a client sees it
    *before* any device work is enqueued and can retry after a drain —
    nothing about the queue or the registry changed.
    """


def payload_key(fingerprint: str, b, x0=None) -> str:
    """Dedup key: operator fingerprint + exact RHS/x0 bytes."""
    h = hashlib.blake2b(digest_size=16)
    h.update(fingerprint.encode())
    b = np.asarray(b)
    h.update(b.dtype.str.encode())
    h.update(np.ascontiguousarray(b).tobytes())
    if x0 is not None:
        x0 = np.asarray(x0)
        h.update(x0.dtype.str.encode())
        h.update(np.ascontiguousarray(x0).tobytes())
    return h.hexdigest()


@dataclasses.dataclass
class Ticket:
    """One submitted request and (after dispatch) its outcome.

    ``result`` is the request's own
    :class:`~repro.core.cg.SolveResult` — convergence, iteration count,
    and residual history are per-request even when the solve was shared
    (``deduped``) or dispatched in a group (``batch_id``/``batch_size``).
    """

    request_id: int
    fingerprint: str
    b: np.ndarray
    x0: np.ndarray | None
    key: str
    submitted_s: float
    solver: object = dataclasses.field(repr=False, default=None)
    result: object = None
    batch_id: int | None = None
    batch_size: int = 0
    deduped: bool = False

    @property
    def done(self) -> bool:
        return self.result is not None


class RequestQueue:
    """Bounded pending queue with the grouping/dedup/flush policy."""

    def __init__(self, max_batch: int = 8, max_wait_s: float = 0.0,
                 max_pending: int = 256, dedup: bool = True):
        self.max_batch = max_batch
        self.max_wait_s = max_wait_s
        self.max_pending = max_pending
        self.dedup = dedup
        self.pending: list[Ticket] = []
        self.submitted = 0
        self.rejected = 0
        self.batches = 0
        self.batch_sizes: list[int] = []
        self.dedup_shared = 0
        self.completed = 0

    # ------------------------------------------------------------- intake
    def submit(self, fingerprint: str, b, x0=None, solver=None) -> Ticket:
        if len(self.pending) >= self.max_pending:
            self.rejected += 1
            raise ServeOverloaded(
                f"{len(self.pending)} requests pending (max_pending="
                f"{self.max_pending}); flush or retry after a drain"
            )
        ticket = Ticket(
            request_id=self.submitted,
            fingerprint=fingerprint,
            b=np.asarray(b),
            x0=None if x0 is None else np.asarray(x0),
            key=payload_key(fingerprint, b, x0),
            submitted_s=time.monotonic(),
            solver=solver,
        )
        self.pending.append(ticket)
        self.submitted += 1
        return ticket

    def due(self) -> bool:
        """A batch-closing trigger fired: some operator group holds
        ``max_batch`` distinct payloads, or the oldest request aged out."""
        if not self.pending:
            return False
        if (
            self.max_wait_s > 0
            and time.monotonic() - self.pending[0].submitted_s >= self.max_wait_s
        ):
            return True
        distinct: dict[str, set] = {}
        for tk in self.pending:
            keys = distinct.setdefault(tk.fingerprint, set())
            keys.add(tk.key if self.dedup else tk.request_id)
            if len(keys) >= self.max_batch:
                return True
        return False

    # ----------------------------------------------------------- dispatch
    def drain(self) -> list[Ticket]:
        """Dispatch every pending request; returns them in submit order.

        Requests are grouped by operator (one compiled program per group),
        deduplicated, chunked to ``max_batch``, and pushed through
        ``solve_many``.  Results are split back out per ticket.
        """
        drained, self.pending = self.pending, []
        groups: OrderedDict[str, OrderedDict[str, list[Ticket]]] = OrderedDict()
        for tk in drained:
            per_op = groups.setdefault(tk.fingerprint, OrderedDict())
            key = tk.key if self.dedup else f"req{tk.request_id}"
            per_op.setdefault(key, []).append(tk)
        for per_op in groups.values():
            unique = list(per_op.values())
            for lo in range(0, len(unique), self.max_batch):
                chunk = unique[lo:lo + self.max_batch]
                leads = [tickets[0] for tickets in chunk]
                solver = leads[0].solver
                results = solver.solve_many(
                    [tk.b for tk in leads], [tk.x0 for tk in leads]
                )
                batch_id = self.batches
                self.batches += 1
                self.batch_sizes.append(len(leads))
                for tickets, res in zip(chunk, results):
                    for i, tk in enumerate(tickets):
                        tk.result = res
                        tk.batch_id = batch_id
                        tk.batch_size = len(leads)
                        tk.deduped = i > 0
                        self.completed += 1
                    self.dedup_shared += len(tickets) - 1
        return drained

    # -------------------------------------------------------------- state
    def stats(self) -> dict:
        return dict(
            submitted=self.submitted, completed=self.completed,
            pending=len(self.pending), rejected=self.rejected,
            batches=self.batches, batch_sizes=list(self.batch_sizes),
            dedup_shared=self.dedup_shared,
        )
