"""Classical conjugate gradients — the paper's baseline method."""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass
class SolveResult:
    x: jax.Array
    n_iters: int
    res_hist: jax.Array  # (max_iters + 1,), padded with NaN past convergence
    converged: bool
    # --- breakdown / adaptive metadata (defaults keep old call sites valid)
    breakdown: bool = False          # a non-finite iterate was produced; the
    #                                  state (x, residual norm) froze at the
    #                                  last finite iteration instead of NaNs
    t: int | None = None             # enlarging factor used (ECG; via t="auto")
    active_hist: jax.Array | None = None  # (max_iters + 1,) active block width
    #                                  per iteration — the reduction trace
    #                                  (adaptive ECG only, -1 past the end)
    restarts: int = 0                # re-enlarge events (adaptive ECG)
    selection: object = None         # TSelection when t was chosen by "auto"
    comm_segments: list | None = None  # [(exchange width, iterations)] per
    #                                  width segment of the re-sliced solve
    #                                  (width-aware distributed ECG only)
    final_carry: dict | None = dataclasses.field(default=None, repr=False)
    #                                ^ loop carry at exit — the resume handle
    #                                  the segmented solver threads between
    #                                  width segments

    def __iter__(self):  # convenient unpacking (historical 4-tuple)
        return iter((self.x, self.n_iters, self.res_hist, self.converged))

    def reduction_events(self) -> list[tuple[int, int, int]]:
        """[(iteration, width_before, width_after)] from the reduction trace
        — every iteration where the active block width changed."""
        if self.active_hist is None:
            return []
        import numpy as np

        h = np.asarray(self.active_hist[: self.n_iters + 1]).tolist()
        return [
            (k, h[k - 1], h[k])
            for k in range(1, len(h))
            if h[k] != h[k - 1] and h[k] >= 0 and h[k - 1] >= 0
        ]


def _guarded_while(cond_extra, body_fn, init: dict):
    """``lax.while_loop`` with a breakdown guard.

    ``body_fn`` computes the next carry; if it produces a non-finite residual
    norm (singular Gram matrix, zero curvature, ...), the previous — last
    finite — carry is kept and the ``bd`` flag is raised, terminating the
    loop.  The returned state is therefore always finite, and callers report
    ``breakdown=True`` with the last finite residual instead of NaN garbage.
    """

    def cond(carry):
        return (~carry["bd"]) & cond_extra(carry)

    def body(carry):
        new = body_fn(carry)
        ok = jnp.isfinite(new["rn"])
        merged = jax.tree_util.tree_map(
            lambda old, cur: jnp.where(ok, cur, old), carry, new
        )
        merged["bd"] = carry["bd"] | ~ok
        return merged

    init = dict(init, bd=~jnp.isfinite(init["rn"]))
    return jax.lax.while_loop(cond, body, init)


def cg_solve(
    a_apply: Callable[[jax.Array], jax.Array],
    b: jax.Array,
    x0: jax.Array | None = None,
    tol: float = 1e-8,
    max_iters: int = 1000,
) -> SolveResult:
    """Solve A x = b with CG. ``a_apply`` is the (possibly distributed) SpMV."""
    x0 = jnp.zeros_like(b) if x0 is None else x0
    r0 = b - a_apply(x0)
    rn0 = jnp.linalg.norm(r0)
    hist0 = jnp.full((max_iters + 1,), jnp.nan, dtype=b.dtype).at[0].set(rn0)

    def body(carry):
        x, r, p, rz, k = carry["x"], carry["r"], carry["p"], carry["rz"], carry["k"]
        ap = a_apply(p)
        alpha = rz / (p @ ap)
        x = x + alpha * p
        r = r - alpha * ap
        rz_new = r @ r
        beta = rz_new / rz
        p = r + beta * p
        rn = jnp.sqrt(rz_new)
        hist = carry["hist"].at[k + 1].set(rn)
        return dict(x=x, r=r, p=p, rz=rz_new, k=k + 1, rn=rn, hist=hist, bd=carry["bd"])

    out = _guarded_while(
        lambda c: (c["rn"] > tol) & (c["k"] < max_iters),
        body,
        dict(x=x0, r=r0, p=r0, rz=r0 @ r0, k=jnp.int32(0), rn=rn0, hist=hist0),
    )
    breakdown = bool(out["bd"])
    return SolveResult(
        x=out["x"],
        n_iters=int(out["k"]),
        res_hist=out["hist"],
        converged=bool(out["rn"] <= tol) and not breakdown,
        breakdown=breakdown,
    )
